"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compiled hot path: everything
the Rust runtime executes lowers through these kernels, so allclose here +
the Rust-side artifact cross-check pins the whole stack's numerics.

Hypothesis sweeps shapes/dtypes/hyperparameters; fixed seeds keep CI
deterministic.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import estep, ref

F32 = jnp.float32


def make_inputs(rng, b, k, alpha, beta, w_dim, scale=5.0):
    theta = jnp.asarray(rng.random((b, k)) * scale, F32)
    phi = jnp.asarray(rng.random((b, k)) * scale, F32)
    phisum = jnp.asarray(rng.random(k) * scale * 50 + 1.0, F32)
    counts = jnp.asarray(rng.integers(1, 8, b), F32)
    consts = jnp.array([alpha - 1, beta - 1, w_dim * (beta - 1)], F32)
    return theta, phi, phisum, counts, consts


class TestEstepSingle:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(0)
        a, b_, w = 1.01, 1.01, 5000.0
        th, ph, ps, c, consts = make_inputs(rng, 512, 128, a, b_, w)
        mu, xmu = estep.estep_block(th, ph, ps[None, :], c[:, None], consts)
        mur, xmur = ref.estep_ref(th, ph, ps, c, a, b_, w)
        np.testing.assert_allclose(mu, mur, atol=1e-5)
        np.testing.assert_allclose(xmu, xmur, atol=1e-4)

    def test_rows_normalized(self):
        rng = np.random.default_rng(1)
        th, ph, ps, c, consts = make_inputs(rng, 256, 64, 1.01, 1.01, 1000.0)
        mu, _ = estep.estep_block(th, ph, ps[None, :], c[:, None], consts)
        np.testing.assert_allclose(np.sum(np.asarray(mu), axis=1), 1.0,
                                   atol=1e-5)

    def test_nonnegative(self):
        rng = np.random.default_rng(2)
        th, ph, ps, c, consts = make_inputs(rng, 256, 64, 1.01, 1.01, 1000.0)
        mu, xmu = estep.estep_block(th, ph, ps[None, :], c[:, None], consts)
        assert np.all(np.asarray(mu) >= 0)
        assert np.all(np.asarray(xmu) >= 0)

    def test_zero_count_padding_rows(self):
        """Padded entries (count 0) must contribute exactly zero xmu."""
        rng = np.random.default_rng(3)
        th, ph, ps, c, consts = make_inputs(rng, 256, 64, 1.01, 1.01, 1000.0)
        c = c.at[100:].set(0.0)
        _, xmu = estep.estep_block(th, ph, ps[None, :], c[:, None], consts)
        assert np.all(np.asarray(xmu)[100:] == 0.0)

    def test_topic_padding_contract(self):
        """theta = -(alpha-1) on padded topic columns -> mu exactly 0 there."""
        rng = np.random.default_rng(4)
        a = 1.01
        th, ph, ps, c, consts = make_inputs(rng, 256, 64, a, 1.01, 1000.0)
        th = th.at[:, 48:].set(-(a - 1.0))
        mu, _ = estep.estep_block(th, ph, ps[None, :], c[:, None], consts)
        mu = np.asarray(mu)
        assert np.all(mu[:, 48:] == 0.0)
        np.testing.assert_allclose(mu.sum(axis=1), 1.0, atol=1e-5)

    def test_fully_padded_row_is_zero(self):
        rng = np.random.default_rng(5)
        a = 1.01
        th, ph, ps, c, consts = make_inputs(rng, 128, 32, a, 1.01, 1000.0)
        th = th.at[7].set(-(a - 1.0))
        mu, xmu = estep.estep_block(th, ph, ps[None, :], c[:, None], consts)
        assert np.all(np.asarray(mu)[7] == 0.0)
        assert np.all(np.asarray(xmu)[7] == 0.0)

    @settings(max_examples=20, deadline=None)
    @given(
        b_blocks=st.integers(1, 4),
        block_b=st.sampled_from([8, 32, 128]),
        k=st.sampled_from([4, 16, 64, 200]),
        alpha=st.sampled_from([1.01, 1.1, 1.5, 2.0]),
        beta=st.sampled_from([1.01, 1.1, 1.5]),
        w_dim=st.sampled_from([100.0, 5000.0, 100000.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_sweep(self, b_blocks, block_b, k, alpha, beta,
                               w_dim, seed):
        rng = np.random.default_rng(seed)
        b = b_blocks * block_b
        th, ph, ps, c, consts = make_inputs(rng, b, k, alpha, beta, w_dim)
        mu, xmu = estep.estep_block(th, ph, ps[None, :], c[:, None], consts,
                                    block_b=block_b)
        mur, xmur = ref.estep_ref(th, ph, ps, c, alpha, beta, w_dim)
        np.testing.assert_allclose(mu, mur, atol=2e-5)
        np.testing.assert_allclose(xmu, xmur, atol=2e-4)


class TestEstepTiled:
    @settings(max_examples=12, deadline=None)
    @given(
        block_b=st.sampled_from([16, 64]),
        b_blocks=st.integers(1, 3),
        block_k=st.sampled_from([8, 32]),
        k_blocks=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_tiled_matches_single(self, block_b, b_blocks, block_k, k_blocks,
                                  seed):
        rng = np.random.default_rng(seed)
        b, k = block_b * b_blocks, block_k * k_blocks
        a, be, w = 1.01, 1.01, 10000.0
        th, ph, ps, c, consts = make_inputs(rng, b, k, a, be, w)
        mu1, xmu1 = estep.estep_block(th, ph, ps[None, :], c[:, None], consts,
                                      block_b=block_b)
        mu2, xmu2 = estep.estep_block_tiled(
            th, ph, ps[None, :], c[:, None], consts,
            block_b=block_b, block_k=block_k)
        np.testing.assert_allclose(mu2, mu1, atol=2e-5)
        np.testing.assert_allclose(xmu2, xmu1, atol=2e-4)

    def test_big_k_tiling(self):
        rng = np.random.default_rng(11)
        a, be, w = 1.01, 1.01, 50000.0
        th, ph, ps, c, consts = make_inputs(rng, 128, 2048, a, be, w)
        mu, _ = estep.estep_block_tiled(th, ph, ps[None, :], c[:, None],
                                        consts, block_b=64, block_k=256)
        mur, _ = ref.estep_ref(th, ph, ps, c, a, be, w)
        np.testing.assert_allclose(mu, mur, atol=2e-5)


class TestPredictLL:
    def test_matches_ref(self):
        rng = np.random.default_rng(20)
        b, k, a, be, w = 512, 96, 1.01, 1.01, 7000.0
        th, ph, ps, c, _ = make_inputs(rng, b, k, a, be, w)
        tt = jnp.sum(th, axis=1, keepdims=True)
        consts = jnp.array([a - 1, be - 1, w * (be - 1), k * (a - 1)], F32)
        ll, cnt = estep.predict_ll_block(th, tt, ph, ps[None, :], c[:, None],
                                         consts)
        llr, cntr = ref.predict_ll_ref(th, tt[:, 0], ph, ps, c, a, be, w, k)
        np.testing.assert_allclose(float(ll[0, 0]), float(llr), rtol=1e-4)
        np.testing.assert_allclose(float(cnt[0, 0]), float(cntr), rtol=1e-6)

    def test_zero_counts_contribute_nothing(self):
        rng = np.random.default_rng(21)
        b, k, a, be, w = 256, 32, 1.01, 1.01, 1000.0
        th, ph, ps, c, _ = make_inputs(rng, b, k, a, be, w)
        tt = jnp.sum(th, axis=1, keepdims=True)
        consts = jnp.array([a - 1, be - 1, w * (be - 1), k * (a - 1)], F32)
        ll_all, _ = estep.predict_ll_block(th, tt, ph, ps[None, :],
                                           c[:, None], consts)
        c2 = c.at[128:].set(0.0)
        ll_half, _ = estep.predict_ll_block(th, tt, ph, ps[None, :],
                                            c2[:, None], consts)
        llr, _ = ref.predict_ll_ref(th, tt[:, 0], ph, ps, c2, a, be, w, k)
        np.testing.assert_allclose(float(ll_half[0, 0]), float(llr),
                                   rtol=1e-4)
        assert float(ll_half[0, 0]) != pytest.approx(float(ll_all[0, 0]))

    @settings(max_examples=10, deadline=None)
    @given(
        block_b=st.sampled_from([32, 128]),
        b_blocks=st.integers(1, 3),
        k=st.sampled_from([8, 64, 300]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_sweep(self, block_b, b_blocks, k, seed):
        rng = np.random.default_rng(seed)
        b, a, be, w = block_b * b_blocks, 1.01, 1.01, 20000.0
        th, ph, ps, c, _ = make_inputs(rng, b, k, a, be, w)
        tt = jnp.sum(th, axis=1, keepdims=True)
        consts = jnp.array([a - 1, be - 1, w * (be - 1), k * (a - 1)], F32)
        ll, cnt = estep.predict_ll_block(th, tt, ph, ps[None, :],
                                         c[:, None], consts, block_b=block_b)
        llr, cntr = ref.predict_ll_ref(th, tt[:, 0], ph, ps, c, a, be, w, k)
        np.testing.assert_allclose(float(ll[0, 0]), float(llr), rtol=2e-4)
        np.testing.assert_allclose(float(cnt[0, 0]), float(cntr), rtol=1e-6)
