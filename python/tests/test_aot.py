"""AOT pipeline: HLO-text emission, manifest integrity, numeric round-trip.

The Rust integration tests re-execute these artifacts through PJRT; here we
verify the python side: that the emitted HLO text parses, that the manifest
describes real files, and that re-lowering is deterministic.
"""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestHloText:
    def test_estep_emits_hlo_text(self):
        lowered = jax.jit(model.estep_graph).lower(
            *model.example_args_estep(256, 32))
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_deterministic(self):
        args = model.example_args_estep(256, 32)
        t1 = aot.to_hlo_text(jax.jit(model.estep_graph).lower(*args))
        t2 = aot.to_hlo_text(jax.jit(model.estep_graph).lower(*args))
        assert t1 == t2

    def test_no_serialized_proto_used(self):
        """Guard: the interchange must be HLO text (64-bit-id protos from
        jax>=0.5 are rejected by xla_extension 0.5.1 on the Rust side)."""
        src = open(os.path.join(os.path.dirname(aot.__file__), "aot.py")).read()
        assert ".serialize()" not in src
        assert "as_hlo_text" in src


@pytest.mark.skipif(not os.path.isdir(ARTIFACT_DIR),
                    reason="run `make artifacts` first")
class TestManifest:
    def manifest(self):
        with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_lists_real_files(self):
        m = self.manifest()
        assert m["format"] == "hlo-text"
        assert len(m["artifacts"]) >= 4
        for a in m["artifacts"]:
            path = os.path.join(ARTIFACT_DIR, a["file"])
            assert os.path.isfile(path), a["file"]
            assert os.path.getsize(path) > 100

    def test_manifest_covers_every_graph_family(self):
        graphs = {a["graph"] for a in self.manifest()["artifacts"]}
        assert {"estep", "predict"} <= graphs

    def test_artifacts_are_hlo_text(self):
        m = self.manifest()
        for a in m["artifacts"][:3]:
            head = open(os.path.join(ARTIFACT_DIR, a["file"])).read(200)
            assert head.startswith("HloModule")
