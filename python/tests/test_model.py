"""L2 correctness: the AOT-able graphs vs the pure-jnp references.

Checks the SEM minibatch graph against ref.minibatch_sem_ref (sufficient
statistics conservation, scatter correctness, padding behavior) and that
shapes survive jit-lowering for every registered variant.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

F32, I32 = jnp.float32, jnp.int32


def make_minibatch(rng, b, k, ds, ws, pad_frac=0.0):
    """Random sparse minibatch in the dense-entry layout."""
    n_real = int(b * (1 - pad_frac))
    doc_ids = rng.integers(0, ds - 1, b).astype(np.int32)
    word_ids = rng.integers(0, ws - 1, b).astype(np.int32)
    counts = rng.integers(1, 5, b).astype(np.float32)
    if n_real < b:
        doc_ids[n_real:] = ds - 1
        word_ids[n_real:] = ws - 1
        counts[n_real:] = 0.0
    theta0 = rng.random((ds, k)).astype(np.float32) * 2
    phi_local = rng.random((ws, k)).astype(np.float32) * 3
    phisum = (rng.random(k) * 200 + 10).astype(np.float32)
    return (jnp.asarray(doc_ids), jnp.asarray(word_ids), jnp.asarray(counts),
            jnp.asarray(theta0), jnp.asarray(phi_local), jnp.asarray(phisum))


class TestMinibatchSem:
    def run_both(self, rng, b=256, k=32, ds=16, ws=64, iters=3,
                 a=1.01, be=1.01, w=5000.0, pad_frac=0.0):
        d, wd, c, th0, phl, ps = make_minibatch(rng, b, k, ds, ws, pad_frac)
        consts = jnp.array([a - 1, be - 1, w * (be - 1)], F32)
        theta, phi_delta, ll = model.minibatch_sem_graph(
            d[:, None], wd[:, None], c[:, None], th0, phl, ps[None, :],
            consts, n_iters=iters)
        theta_r, phi_delta_r, _ = ref.minibatch_sem_ref(
            d, wd, c, th0, phl, ps, a, be, w, iters)
        return (np.asarray(theta), np.asarray(phi_delta), float(ll[0, 0]),
                np.asarray(theta_r), np.asarray(phi_delta_r), np.asarray(c))

    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        th, pd, _, thr, pdr, _ = self.run_both(rng)
        np.testing.assert_allclose(th, thr, atol=1e-3)
        np.testing.assert_allclose(pd, pdr, atol=1e-3)

    def test_mass_conservation(self):
        """After the first M-step, sum_k theta_d(k) == sum of doc's counts
        and total phi_delta mass == total count mass."""
        rng = np.random.default_rng(1)
        th, pd, _, _, _, c = self.run_both(rng, iters=5)
        total = c.sum()
        np.testing.assert_allclose(th.sum(), total, rtol=1e-5)
        np.testing.assert_allclose(pd.sum(), total, rtol=1e-5)

    def test_padding_rows_isolated(self):
        """Padded entries scatter zero into the scratch rows."""
        rng = np.random.default_rng(2)
        th, pd, _, thr, pdr, _ = self.run_both(rng, pad_frac=0.25)
        np.testing.assert_allclose(th, thr, atol=1e-3)
        np.testing.assert_allclose(pd, pdr, atol=1e-3)

    def test_ll_finite_and_improves(self):
        """More inner sweeps should not decrease the training LL (EM
        monotonicity, Eq. 12), modulo tiny float noise."""
        rng = np.random.default_rng(3)
        lls = []
        for iters in (1, 3, 8):
            rng_i = np.random.default_rng(3)
            _, _, ll, _, _, _ = self.run_both(rng_i, iters=iters)
            lls.append(ll)
        assert all(np.isfinite(lls))
        assert lls[2] >= lls[0] - abs(lls[0]) * 1e-4

    @settings(max_examples=8, deadline=None)
    @given(
        b=st.sampled_from([64, 256]),
        k=st.sampled_from([8, 32]),
        ds=st.sampled_from([4, 16]),
        ws=st.sampled_from([32, 128]),
        iters=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_sweep(self, b, k, ds, ws, iters, seed):
        rng = np.random.default_rng(seed)
        th, pd, _, thr, pdr, _ = self.run_both(rng, b=b, k=k, ds=ds, ws=ws,
                                               iters=iters)
        np.testing.assert_allclose(th, thr, atol=2e-3)
        np.testing.assert_allclose(pd, pdr, atol=2e-3)


class TestLowering:
    """Every registered AOT variant must lower to valid HLO text."""

    @pytest.mark.parametrize("b,k", [(2048, 64), (2048, 256)])
    def test_estep_lowers(self, b, k):
        args = model.example_args_estep(b, k)
        lowered = jax.jit(model.estep_graph).lower(*args)
        text = str(lowered.compiler_ir("stablehlo"))
        assert "stablehlo" in text or "func" in text

    @pytest.mark.parametrize("b,k", [(2048, 64)])
    def test_predict_lowers(self, b, k):
        args = model.example_args_predict(b, k)
        lowered = jax.jit(model.predict_ll_graph).lower(*args)
        assert lowered.compiler_ir("stablehlo") is not None

    def test_sem_lowers_with_scan(self):
        import functools
        args = model.example_args_sem(512, 32, 64, 128)
        fn = functools.partial(model.minibatch_sem_graph, n_iters=4)
        lowered = jax.jit(fn).lower(*args)
        text = str(lowered.compiler_ir("stablehlo"))
        # lax.scan must survive as a loop, not be unrolled 4x.
        assert "while" in text
