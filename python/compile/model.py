"""L2: the JAX compute graphs that get AOT-lowered for the Rust runtime.

Three graph families, all built on the L1 Pallas kernels in
`kernels/estep.py`:

  * `estep_graph`         — one blocked E-step: (mu, xmu) from gathered rows.
  * `minibatch_sem_graph` — the whole SEM inner loop (Fig. 3 lines 4-8) for
    one minibatch: `n_iters` sweeps of E-step + local-theta M-step via
    `lax.scan` (scan, not unroll, keeps the HLO small and lets XLA reuse
    the loop body), then the phi-delta for the global update (Eq. 20/33).
  * `predict_ll_graph`    — the held-out log-likelihood block for the
    predictive perplexity (Eq. 21).

Contract with the Rust side (`rust/src/runtime/`): Rust owns all sparse
indexing and the parameter store; it gathers theta rows / phi columns into
dense blocks, calls these graphs through PJRT, and scatters the results
back.  Everything here is shape-static; Rust pads the entry axis with
zero-count rows and the topic axis with the `-(alpha-1)` theta padding
(see kernels/ref.py docstring), both of which produce exact zeros.

Scalars (alpha, beta, W, K) arrive packed in small const vectors so each
artifact stays a fixed-arity function of plain f32 arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import estep as kernels


def estep_graph(theta, phi, phisum, counts, consts):
    """One blocked E-step; exactly the L1 kernel, re-exported for AOT.

    Shapes: theta/phi [B, K], phisum [1, K], counts [B, 1], consts [3].
    Returns (mu, xmu) each [B, K].
    """
    return tuple(kernels.estep_block(theta, phi, phisum, counts, consts))


def predict_ll_graph(theta, theta_tot, phi, phisum, counts, consts):
    """Held-out LL block; consts [4]. Returns ([1,1] ll, [1,1] cnt)."""
    return tuple(
        kernels.predict_ll_block(theta, theta_tot, phi, phisum, counts, consts)
    )


def minibatch_sem_graph(doc_ids, word_ids, counts, theta0, phi_local, phisum,
                        consts, *, n_iters):
    """The SEM / FOEM-outer minibatch update as one fused XLA program.

    Args:
      doc_ids:   [B, 1] i32 — entry -> local document index (0..Ds-1).
      word_ids:  [B, 1] i32 — entry -> local vocab index (0..Ws-1), i.e.
        the row of `phi_local` that Rust gathered for that entry's word.
      counts:    [B, 1] f32 — x_{w,d}; 0 marks padding entries.
      theta0:    [Ds, K] f32 — initial doc-topic stats for the minibatch.
      phi_local: [Ws, K] f32 — gathered columns of the global phi_hat^{s-1}.
      phisum:    [1, K] f32 — global topic totals.
      consts:    [3] f32 — (alpha-1, beta-1, W*(beta-1)).
      n_iters:   static — number of inner E/M sweeps (the paper iterates
        until the training-perplexity delta < 10; Rust picks n_iters per
        its convergence check and can call this graph repeatedly).

    Returns:
      (theta, phi_delta, ll): [Ds, K] updated local doc-topic stats,
      [Ws, K] minibatch phi contribution `sum_d x mu`, and [1, 1] the
      training log-likelihood `sum x log(sum_k u)` for convergence checks.

    Padding contract: padded entries carry counts==0 AND doc_ids/word_ids
    pointing at dedicated scratch rows (Rust uses Ds-1/Ws-1), so their
    zero xmu lands harmlessly.
    """
    n_words = phi_local.shape[0]
    doc_ids_flat = doc_ids[:, 0]
    word_ids_flat = word_ids[:, 0]

    def body(theta, _):
        th_rows = theta[doc_ids_flat]
        ph_rows = phi_local[word_ids_flat]
        _, xmu = kernels.estep_block(th_rows, ph_rows, phisum, counts, consts)
        theta_new = jnp.zeros_like(theta).at[doc_ids_flat].add(xmu)
        return theta_new, None

    theta, _ = jax.lax.scan(body, theta0, None, length=n_iters)

    th_rows = theta[doc_ids_flat]
    ph_rows = phi_local[word_ids_flat]
    _, xmu = kernels.estep_block(th_rows, ph_rows, phisum, counts, consts)
    phi_delta = jnp.zeros((n_words, theta.shape[1]), theta.dtype) \
        .at[word_ids_flat].add(xmu)

    # Training LL for Rust's convergence check: sum x * log(sum_k u) with u
    # the unnormalized prior product — the same quantity the paper's
    # training-perplexity delta test tracks (constants cancel in the delta).
    am1, bm1, wbm1 = consts[0], consts[1], consts[2]
    u = (th_rows + am1) * (ph_rows + bm1) / (phisum + wbm1)
    z = jnp.maximum(jnp.sum(u, axis=1, keepdims=True), 1e-30)
    ll = jnp.sum(counts * jnp.log(z)).reshape(1, 1)
    return theta, phi_delta, ll


# ---------------------------------------------------------------------------
# Example-argument builders used by aot.py (and mirrored by pytest).
# ---------------------------------------------------------------------------

def example_args_estep(b_dim, k_dim):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((b_dim, k_dim), f32),   # theta
        jax.ShapeDtypeStruct((b_dim, k_dim), f32),   # phi
        jax.ShapeDtypeStruct((1, k_dim), f32),       # phisum
        jax.ShapeDtypeStruct((b_dim, 1), f32),       # counts
        jax.ShapeDtypeStruct((3,), f32),             # consts
    )


def example_args_predict(b_dim, k_dim):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((b_dim, k_dim), f32),   # theta
        jax.ShapeDtypeStruct((b_dim, 1), f32),       # theta_tot
        jax.ShapeDtypeStruct((b_dim, k_dim), f32),   # phi
        jax.ShapeDtypeStruct((1, k_dim), f32),       # phisum
        jax.ShapeDtypeStruct((b_dim, 1), f32),       # counts
        jax.ShapeDtypeStruct((4,), f32),             # consts
    )


def example_args_sem(b_dim, k_dim, ds_dim, ws_dim):
    f32, i32 = jnp.float32, jnp.int32
    return (
        jax.ShapeDtypeStruct((b_dim, 1), i32),       # doc_ids
        jax.ShapeDtypeStruct((b_dim, 1), i32),       # word_ids
        jax.ShapeDtypeStruct((b_dim, 1), f32),       # counts
        jax.ShapeDtypeStruct((ds_dim, k_dim), f32),  # theta0
        jax.ShapeDtypeStruct((ws_dim, k_dim), f32),  # phi_local
        jax.ShapeDtypeStruct((1, k_dim), f32),       # phisum
        jax.ShapeDtypeStruct((3,), f32),             # consts
    )
