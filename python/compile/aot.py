"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser on
the Rust side (`HloModuleProto::from_text_file`) reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Emits one artifact per (graph, shape-variant) plus `manifest.json`
describing every artifact's operands, shapes, and constants layout, which
`rust/src/runtime/registry.rs` parses at startup.

Run via `make artifacts`:  python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Shape variants compiled by default. The Rust coordinator pads any
# workload onto the nearest variant (entry axis up, topic axis up with the
# -(alpha-1) padding contract), so this small set covers every experiment:
#   K in {64, 128, 256, 512}; entry blocks of 2048; SEM minibatch graphs
#   sized for D_s<=1024 docs x 4096 entries x 2048 local words.
ESTEP_VARIANTS = [
    dict(b=2048, k=64),
    dict(b=2048, k=128),
    dict(b=2048, k=256),
    dict(b=2048, k=512),
]
PREDICT_VARIANTS = [
    dict(b=2048, k=64),
    dict(b=2048, k=128),
    dict(b=2048, k=256),
    dict(b=2048, k=512),
]
SEM_VARIANTS = [
    dict(b=4096, k=64, ds=1024, ws=2048, iters=8),
    dict(b=4096, k=128, ds=1024, ws=2048, iters=8),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_estep(v):
    args = model.example_args_estep(v["b"], v["k"])
    return jax.jit(model.estep_graph).lower(*args)


def lower_predict(v):
    args = model.example_args_predict(v["b"], v["k"])
    return jax.jit(model.predict_ll_graph).lower(*args)


def lower_sem(v):
    args = model.example_args_sem(v["b"], v["k"], v["ds"], v["ws"])
    fn = functools.partial(model.minibatch_sem_graph, n_iters=v["iters"])
    return jax.jit(fn).lower(*args)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--out", default=None,
                        help="legacy single-file mode: also write the first "
                             "estep artifact to this path")
    parser.add_argument("--skip-sem", action="store_true",
                        help="skip the (slower to lower) SEM graphs")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": []}

    def emit(name, lowered, entry):
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry.update(name=name, file=f"{name}.hlo.txt", bytes=len(text))
        manifest["artifacts"].append(entry)
        print(f"  {name}: {len(text)} chars")
        return path

    first_estep = None
    for v in ESTEP_VARIANTS:
        name = f"estep_b{v['b']}_k{v['k']}"
        p = emit(name, lower_estep(v), {
            "graph": "estep", "b": v["b"], "k": v["k"],
            "operands": ["theta[b,k]", "phi[b,k]", "phisum[1,k]",
                         "counts[b,1]", "consts[3]"],
            "outputs": ["mu[b,k]", "xmu[b,k]"],
            "consts": ["alpha-1", "beta-1", "W*(beta-1)"],
        })
        first_estep = first_estep or p

    for v in PREDICT_VARIANTS:
        name = f"predict_b{v['b']}_k{v['k']}"
        emit(name, lower_predict(v), {
            "graph": "predict", "b": v["b"], "k": v["k"],
            "operands": ["theta[b,k]", "theta_tot[b,1]", "phi[b,k]",
                         "phisum[1,k]", "counts[b,1]", "consts[4]"],
            "outputs": ["ll[1,1]", "cnt[1,1]"],
            "consts": ["alpha-1", "beta-1", "W*(beta-1)", "K*(alpha-1)"],
        })

    if not args.skip_sem:
        for v in SEM_VARIANTS:
            name = f"sem_b{v['b']}_k{v['k']}_ds{v['ds']}_ws{v['ws']}_t{v['iters']}"
            emit(name, lower_sem(v), {
                "graph": "sem", "b": v["b"], "k": v["k"], "ds": v["ds"],
                "ws": v["ws"], "iters": v["iters"],
                "operands": ["doc_ids[b,1]i32", "word_ids[b,1]i32",
                             "counts[b,1]", "theta0[ds,k]",
                             "phi_local[ws,k]", "phisum[1,k]", "consts[3]"],
                "outputs": ["theta[ds,k]", "phi_delta[ws,k]", "ll[1,1]"],
                "consts": ["alpha-1", "beta-1", "W*(beta-1)"],
            })

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    # Line-based manifest for the dependency-light Rust loader
    # (rust/src/runtime/registry.rs): one artifact per line,
    # space-separated `key=value` pairs.
    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        for a in manifest["artifacts"]:
            keys = ["name", "file", "graph", "b", "k", "ds", "ws", "iters"]
            parts = [f"{key}={a[key]}" for key in keys if key in a]
            f.write(" ".join(parts) + "\n")
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json "
          f"to {args.out_dir}")

    if args.out:
        # Back-compat with the original Makefile target.
        import shutil
        shutil.copyfile(first_estep, args.out)
        print(f"copied {first_estep} -> {args.out}")


if __name__ == "__main__":
    main()
