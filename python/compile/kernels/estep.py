"""L1 Pallas kernels: the blocked-dense LDA E-step hot-spot.

The paper's inner loop (Fig. 1 line 5 / Fig. 4 line 11) evaluates, for every
non-zero document-word entry, the responsibility

    mu(k) ∝ (theta_d(k)+alpha-1)(phi_w(k)+beta-1) / (phisum(k)+W(beta-1))

followed by normalization over k and the M-step weighting by the word count
x_{w,d}.  On a GPU this would be a warp-per-entry elementwise+rowreduce; on
TPU we re-think it as a VMEM-tiled [block_b, block_k] computation:

  * grid axis 0 walks entry blocks (HBM→VMEM streaming of theta/phi rows),
  * grid axis 1 walks topic tiles, so arbitrarily large K never exceeds
    VMEM; the row normalizer is accumulated across topic tiles in a small
    [block_b, 1] scratch accumulator and applied in a second grid pass
    (classic two-pass softmax-style normalization, no atomics needed).

There is no matmul in this op, so the MXU is idle by construction; the
roofline is VPU/memory-bound.  Block sizes are chosen so that
3 * block_b * block_k * 4B (theta, phi, u tiles) stays ≤ ~4 MiB — see
DESIGN.md §Perf.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO so the AOT artifact runs
on the Rust CPU client with identical numerics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Single-tile kernel: K fits in one VMEM tile (the common case: K ≤ 2048).
# ---------------------------------------------------------------------------

def _estep_kernel_single(theta_ref, phi_ref, phisum_ref, counts_ref,
                         consts_ref, mu_ref, xmu_ref):
    """One [block_b, K] tile: fused prior-product, normalize, weight.

    consts_ref is a [3] vector (alpha-1, beta-1, W*(beta-1)) so the scalars
    ride in as one tiny operand instead of three rank-0 params.
    """
    am1 = consts_ref[0]
    bm1 = consts_ref[1]
    wbm1 = consts_ref[2]
    theta = theta_ref[...]
    phi = phi_ref[...]
    u = (theta + am1) * (phi + bm1) / (phisum_ref[...] + wbm1)
    z = jnp.sum(u, axis=1, keepdims=True)
    safe = jnp.where(z > 0.0, z, 1.0)
    mu = jnp.where(z > 0.0, u / safe, 0.0)
    mu_ref[...] = mu
    xmu_ref[...] = counts_ref[...] * mu


@functools.partial(jax.jit, static_argnames=("block_b",))
def estep_block(theta, phi, phisum, counts, consts, *, block_b=256):
    """Blocked E-step over [B, K] gathered rows (single topic tile).

    Args:
      theta:  [B, K] f32 — gathered theta_hat rows (one per nnz entry).
      phi:    [B, K] f32 — gathered phi_hat rows.
      phisum: [1, K] f32 — topic totals (broadcast to every block).
      counts: [B, 1] f32 — word counts.
      consts: [3]    f32 — (alpha-1, beta-1, W*(beta-1)).
      block_b: entry-block size; B must be a multiple (callers pad with
        zero-count rows; the padding contract is tested).

    Returns:
      (mu, xmu): [B, K] responsibilities and count-weighted contributions.
    """
    b_dim, k_dim = theta.shape
    block_b = min(block_b, b_dim)
    assert b_dim % block_b == 0, (b_dim, block_b)
    grid = (b_dim // block_b,)
    return pl.pallas_call(
        _estep_kernel_single,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((1, k_dim), lambda i: (0, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k_dim), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_dim, k_dim), theta.dtype),
            jax.ShapeDtypeStruct((b_dim, k_dim), theta.dtype),
        ],
        interpret=True,
    )(theta, phi, phisum, counts, consts)


# ---------------------------------------------------------------------------
# Two-pass kernel: K tiled (big-model regime, K up to 10^5 in the paper).
# ---------------------------------------------------------------------------

def _prior_tile_kernel(theta_ref, phi_ref, phisum_ref, consts_ref,
                       u_ref, zacc_ref):
    """Pass 1 tile: unnormalized prior product u and per-row partial sums.

    Grid is (B blocks, K tiles); for each row block the normalizer is
    accumulated across the K-tile axis into zacc (the K-tile axis is the
    *minor* grid axis, so accumulation is sequential per row block).
    """
    am1 = consts_ref[0]
    bm1 = consts_ref[1]
    wbm1 = consts_ref[2]
    u = (theta_ref[...] + am1) * (phi_ref[...] + bm1) / (phisum_ref[...] + wbm1)
    u_ref[...] = u

    @pl.when(pl.program_id(1) == 0)
    def _init():
        zacc_ref[...] = jnp.zeros_like(zacc_ref)

    zacc_ref[...] += jnp.sum(u, axis=1, keepdims=True)


def _normalize_tile_kernel(u_ref, zacc_ref, counts_ref, mu_ref, xmu_ref):
    """Pass 2 tile: divide by the accumulated normalizer and weight."""
    z = zacc_ref[...]
    safe = jnp.where(z > 0.0, z, 1.0)
    mu = jnp.where(z > 0.0, u_ref[...] / safe, 0.0)
    mu_ref[...] = mu
    xmu_ref[...] = counts_ref[...] * mu


@functools.partial(jax.jit, static_argnames=("block_b", "block_k"))
def estep_block_tiled(theta, phi, phisum, counts, consts, *,
                      block_b=128, block_k=512):
    """Blocked E-step with the topic axis tiled (two grid passes).

    Semantically identical to `estep_block`; use when K is too large for a
    single VMEM tile. Shapes as in `estep_block`; K must be a multiple of
    block_k (pad topics per the `-(alpha-1)` contract in ref.py).
    """
    b_dim, k_dim = theta.shape
    block_b = min(block_b, b_dim)
    block_k = min(block_k, k_dim)
    assert b_dim % block_b == 0 and k_dim % block_k == 0
    grid = (b_dim // block_b, k_dim // block_k)

    u, zacc = pl.pallas_call(
        _prior_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_k), lambda i, j: (0, j)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_dim, k_dim), theta.dtype),
            jax.ShapeDtypeStruct((b_dim, 1), theta.dtype),
        ],
        interpret=True,
    )(theta, phi, phisum, consts)

    return pl.pallas_call(
        _normalize_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_dim, k_dim), theta.dtype),
            jax.ShapeDtypeStruct((b_dim, k_dim), theta.dtype),
        ],
        interpret=True,
    )(u, zacc, counts)


# ---------------------------------------------------------------------------
# Predictive log-likelihood kernel (Eq. 21 inner term).
# ---------------------------------------------------------------------------

def _predict_ll_kernel(theta_ref, theta_tot_ref, phi_ref, phisum_ref,
                       counts_ref, consts_ref, ll_ref, cnt_ref):
    """One [block_b, K] tile of the held-out word log-likelihood.

    consts is [4]: (alpha-1, beta-1, W*(beta-1), K*(alpha-1)).
    Accumulates scalar partials across the grid into [1,1] outputs.
    """
    am1 = consts_ref[0]
    bm1 = consts_ref[1]
    wbm1 = consts_ref[2]
    kam1 = consts_ref[3]
    theta_n = (theta_ref[...] + am1) / (theta_tot_ref[...] + kam1)
    phi_n = (phi_ref[...] + bm1) / (phisum_ref[...] + wbm1)
    p = jnp.sum(theta_n * phi_n, axis=1, keepdims=True)
    p = jnp.maximum(p, 1e-30)
    counts = counts_ref[...]
    ll = jnp.sum(counts * jnp.log(p))
    cnt = jnp.sum(counts)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        ll_ref[...] = jnp.zeros_like(ll_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    ll_ref[...] += ll
    cnt_ref[...] += cnt


@functools.partial(jax.jit, static_argnames=("block_b",))
def predict_ll_block(theta, theta_tot, phi, phisum, counts, consts, *,
                     block_b=256):
    """Held-out log-likelihood over a [B, K] block (see ref.predict_ll_ref).

    theta_tot is [B, 1]; counts [B, 1] with 0 marking padded entries;
    consts [4] = (alpha-1, beta-1, W*(beta-1), K*(alpha-1)).
    Returns ([1,1] ll_sum, [1,1] count_sum).
    """
    b_dim, k_dim = theta.shape
    block_b = min(block_b, b_dim)
    assert b_dim % block_b == 0
    grid = (b_dim // block_b,)
    return pl.pallas_call(
        _predict_ll_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((1, k_dim), lambda i: (0, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), theta.dtype),
            jax.ShapeDtypeStruct((1, 1), theta.dtype),
        ],
        interpret=True,
    )(theta, theta_tot, phi, phisum, counts, consts)
