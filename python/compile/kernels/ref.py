"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the *correctness ground truth* for the whole stack: the Pallas
kernels in `estep.py` are asserted allclose against these in
`python/tests/test_kernel.py`, and the Rust native E-step is cross-checked
against the AOT artifacts (which lower through the same code path) in
`rust/tests/`.

All formulas follow the paper "Fast Online EM for Big Topic Modeling"
(Zeng, Liu, Cao; IEEE TKDE, DOI 10.1109/TKDE.2015.2492565):

  E-step (Eq. 11):
      mu_{w,d}(k) ∝ (theta_d(k) + alpha - 1) * (phi_w(k) + beta - 1)
                    / (phisum(k) + W * (beta - 1))

  M-step contribution:  x_{w,d} * mu_{w,d}(k)

The kernels work on a *blocked dense* layout: a block of B "entries" (one
entry = one non-zero (w, d) cell of the document-word matrix), each with a
gathered row of document-topic stats `theta[B, K]`, a gathered row of
topic-word stats `phi[B, K]`, the shared topic totals `phisum[K]`, and the
word count `counts[B]`.
"""

from __future__ import annotations

import jax.numpy as jnp


def estep_ref(theta, phi, phisum, counts, alpha, beta, w_dim):
    """Reference blocked E-step (Eq. 11) + M-step weights.

    Args:
      theta:  [B, K] gathered doc-topic sufficient statistics rows.
      phi:    [B, K] gathered topic-word sufficient statistics rows.
      phisum: [K]    topic totals  phisum(k) = sum_w phi_w(k).
      counts: [B]    word counts x_{w,d} (float).
      alpha, beta: Dirichlet hyperparameters (the paper uses the MAP
        parameterization with `alpha - 1 = beta - 1 = 0.01`).
      w_dim:  vocabulary size W used in the shared denominator.

    Returns:
      (mu, xmu): both [B, K]; `mu` rows are normalized responsibilities,
      `xmu = counts[:, None] * mu` are the M-step contributions.

    Padding contract: rows may be *topic-padded* by setting
    `theta[:, k] = -(alpha - 1)` on padded columns, which zeroes the
    numerator so padded topics get exactly zero responsibility.
    """
    am1 = alpha - 1.0
    bm1 = beta - 1.0
    u = (theta + am1) * (phi + bm1) / (phisum[None, :] + w_dim * bm1)
    z = jnp.sum(u, axis=1, keepdims=True)
    # Guard all-zero rows (fully padded entries): keep them exactly zero.
    mu = jnp.where(z > 0.0, u / jnp.where(z > 0.0, z, 1.0), 0.0)
    xmu = counts[:, None] * mu
    return mu, xmu


def predict_ll_ref(theta, theta_tot, phi, phisum, counts, alpha, beta, w_dim, k_dim):
    """Reference predictive word log-likelihood block (for Eq. 21).

    Normalizes sufficient statistics into multinomial parameters
    (Eqs. 9 and 10) and evaluates

        ll = sum_b counts_b * log( sum_k theta_d(k) * phi_w(k) )

    Args:
      theta:     [B, K] doc-topic stats rows for each entry's document.
      theta_tot: [B]    per-document totals  sum_k theta_hat_d(k).
      phi:       [B, K] topic-word stats rows for each entry's word.
      phisum:    [K]    topic totals.
      counts:    [B]    held-out word counts (0 for padded entries).
      k_dim:     the *active* number of topics (for the theta normalizer).

    Returns:
      (ll_sum, count_sum): scalars; perplexity = exp(-ll_sum / count_sum)
      once accumulated over every held-out entry.
    """
    am1 = alpha - 1.0
    bm1 = beta - 1.0
    theta_n = (theta + am1) / (theta_tot[:, None] + k_dim * am1)
    phi_n = (phi + bm1) / (phisum[None, :] + w_dim * bm1)
    p = jnp.sum(theta_n * phi_n, axis=1)
    p = jnp.maximum(p, 1e-30)
    ll = jnp.sum(counts * jnp.log(p))
    return ll, jnp.sum(counts)


def minibatch_sem_ref(doc_ids, word_ids, counts, theta0, phi_local, phisum,
                      alpha, beta, w_dim, n_iters):
    """Reference SEM inner loop (Fig. 3 lines 4-8) on one minibatch.

    Holds the global topic-word stats fixed (`phi_local`, `phisum` are the
    minibatch's gathered columns of phi_hat^{s-1}) and alternates the
    blocked E-step with the local theta M-step for `n_iters` sweeps, then
    emits the minibatch's phi-delta `sum_d x^s mu^s` for the global update
    (Eq. 20 / Eq. 33).

    Returns (theta, phi_delta, mu) where theta is [Ds, K], phi_delta is
    [Ws_local, K] aligned with the gathered phi_local rows, mu is [B, K].
    """
    n_words = phi_local.shape[0]
    theta = theta0
    mu = jnp.zeros((doc_ids.shape[0], theta0.shape[1]), theta0.dtype)
    for _ in range(n_iters):
        th_rows = theta[doc_ids]
        ph_rows = phi_local[word_ids]
        mu, xmu = estep_ref(th_rows, ph_rows, phisum, counts, alpha, beta, w_dim)
        theta = jnp.zeros_like(theta).at[doc_ids].add(xmu)
    _, xmu = estep_ref(theta[doc_ids], phi_local[word_ids], phisum, counts,
                       alpha, beta, w_dim)
    phi_delta = jnp.zeros((n_words, theta.shape[1]), theta.dtype).at[word_ids].add(xmu)
    return theta, phi_delta, mu
