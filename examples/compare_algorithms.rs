//! End-to-end driver (the repo's headline validation run, recorded in
//! EXPERIMENTS.md): train ALL SEVEN online LDA algorithms — FOEM and the
//! paper's five comparators plus plain SEM — on the same NYTIMES-like
//! stream, logging each one's perplexity-vs-time curve, and print the
//! final comparison table. Reproduces the *shape* of Figs. 8-12 in one
//! run: FOEM/OGS/SCVB fast & accurate, OVB/RVB/SOI slower & higher
//! perplexity.
//!
//!     cargo run --release --example compare_algorithms

use foem::coordinator::config::{Algorithm, RunConfig, StoreKind};
use foem::coordinator::driver::Driver;
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::eval::{predictive_perplexity, EvalProtocol};
use foem::stream::{CorpusStream, StreamConfig};
use foem::util::Timer;

fn main() -> anyhow::Result<()> {
    let k = 50;
    let ds = 256;
    let passes = 2;
    let corpus = generate(&SyntheticConfig::nytimes_like(), 11);
    let (train, test) = corpus.split(200, 1);
    println!(
        "workload: {} | D={} W={} NNZ={} tokens={:.0} | K={k} Ds={ds} passes={passes}\n",
        corpus.name,
        train.n_docs(),
        train.n_words(),
        train.nnz(),
        train.n_tokens()
    );

    let scfg = StreamConfig { minibatch_docs: ds, shuffle: false, seed: 3 };
    let scale_s = CorpusStream::new(&train, scfg).batches_per_pass() as f64;
    let proto = EvalProtocol { fold_in_iters: 20, seed: 0, ..Default::default() };

    struct Run {
        name: &'static str,
        secs: f64,
        ppx: f64,
        trace: Vec<(f64, f64)>,
    }
    let mut summary: Vec<Run> = Vec::new();
    for algo_kind in Algorithm::all() {
        let cfg = RunConfig {
            algorithm: algo_kind,
            n_topics: k,
            minibatch_docs: ds,
            store: StoreKind::InMemory,
            seed: 7,
            // Keep every algorithm on the serial path so per-algorithm
            // times stay comparable (only FOEM/SEM have parallel paths).
            n_workers: 1,
            ..RunConfig::default()
        };
        let mut algo = Driver::new(cfg).build_algorithm(train.n_words(), scale_s)?;
        println!("[{}]", algo.name());
        let mut train_secs = 0.0f64;
        let mut batch_no = 0usize;
        let mut trace = Vec::new();
        let eval_every = (scale_s as usize / 3).max(1);
        for _pass in 0..passes {
            for mb in CorpusStream::new(&train, scfg) {
                let t = Timer::start();
                algo.process_minibatch(&mb);
                train_secs += t.seconds();
                batch_no += 1;
                if batch_no % eval_every == 0 {
                    let phi = algo.export_phi();
                    let ppx = predictive_perplexity(
                        &phi,
                        &algo.eval_params(),
                        &test.docs,
                        &proto,
                    );
                    println!("  {train_secs:7.2}s  perplexity {ppx:8.1}");
                    trace.push((train_secs, ppx));
                }
            }
        }
        let phi = algo.export_phi();
        let ppx =
            predictive_perplexity(&phi, &algo.eval_params(), &test.docs, &proto);
        trace.push((train_secs, ppx));
        println!("  final: {train_secs:.2}s, perplexity {ppx:.1}\n");
        summary.push(Run { name: algo.name(), secs: train_secs, ppx, trace });
    }

    println!("== summary (K={k}, Ds={ds}, {passes} passes) ==");
    // Fig. 12's comparison: time to reach a COMMON quality level — the
    // best perplexity the weakest algorithm ever achieves.
    let common_target = summary
        .iter()
        .map(|r| r.ppx)
        .fold(f64::MIN, f64::max);
    let time_to = |r: &Run| -> Option<f64> {
        r.trace
            .iter()
            .find(|&&(_, p)| p <= common_target)
            .map(|&(t, _)| t)
    };
    println!(
        "{:<8} {:>12} {:>14} {:>22}",
        "algo", "train time", "perplexity", "t->common quality"
    );
    for r in &summary {
        println!(
            "{:<8} {:>11.2}s {:>14.1} {:>21}",
            r.name,
            r.secs,
            r.ppx,
            time_to(r)
                .map(|t| format!("{t:.2}s"))
                .unwrap_or_else(|| "never".into())
        );
    }
    let foem = summary.iter().find(|r| r.name == "FOEM").unwrap();
    let scvb = summary.iter().find(|r| r.name == "SCVB").unwrap();
    if let (Some(tf), Some(ts)) = (time_to(foem), time_to(scvb)) {
        println!(
            "\nFOEM reaches SCVB-final quality {:.1}x faster ({tf:.2}s vs {ts:.2}s)\n\
             and ends {:.0} perplexity lower — the paper's Fig. 12 shape.",
            ts / tf,
            scvb.ppx - foem.ppx
        );
    }
    Ok(())
}
