//! Non-stationary streaming (DESIGN.md §15): a synthetic corpus whose
//! generating distribution SHIFTS mid-stream, a two-sided CUSUM monitor
//! over the per-batch training log-likelihood, and an adaptive response
//! (decay reset) applied the moment a shift is flagged.
//!
//! The stream schedules three regime changes — a mixture shift (half the
//! topics redrawn), a topic birth, and a vocabulary growth burst — and
//! the example reports, per change point, how many batches the detector
//! needed to flag it and how the training perplexity recovers after the
//! decay reset re-opens the Cappé stochastic-approximation schedule.
//!
//!     cargo run --release --example drift_stream

use foem::coordinator::drift::{
    DetectorKind, DriftMonitor, MonitorConfig, DECAY_FACTOR,
};
use foem::corpus::synthetic::{
    DriftConfig, DriftKind, DriftPoint, DriftingCorpus, SyntheticConfig,
};
use foem::em::foem::{Foem, FoemConfig};
use foem::store::InMemoryPhi;
use foem::LdaParams;

fn main() -> anyhow::Result<()> {
    let mut base = SyntheticConfig::small();
    base.n_docs = 0; // unused by the drifting generator
    base.n_words = 800;
    base.n_topics = 16;

    let n_batches = 120usize;
    let mut cfg = DriftConfig::stationary(base, 64, n_batches);
    cfg.max_words = 1_000;
    cfg.events = vec![
        DriftPoint { batch: 40, kind: DriftKind::MixtureShift { fraction: 0.5 } },
        DriftPoint { batch: 70, kind: DriftKind::TopicBirth },
        DriftPoint { batch: 95, kind: DriftKind::VocabGrowth { new_words: 200 } },
    ];
    let stream = DriftingCorpus::new(cfg, 42);
    let truth_shifts = stream.truth().shift_batches();
    println!(
        "scheduled change points at batches {truth_shifts:?} \
         (mixture shift, topic birth, vocab growth)"
    );

    // Trainer: in-memory store sized for the FULL drift vocabulary so
    // post-growth word ids always have columns; exact LL on because the
    // monitor consumes the per-batch training log-likelihood.
    let k = 16usize;
    let params = LdaParams::paper_defaults(k);
    let mut fc = FoemConfig::paper();
    fc.exact_ll = true;
    let store = InMemoryPhi::zeros(k, 1_000);
    let mut algo = Foem::new(params, store, fc, 7);

    // Monitor: paper-default CUSUM (threshold 8, window 16, warmup 12).
    let mcfg = MonitorConfig {
        detector: DetectorKind::Cusum,
        ..Default::default()
    };
    let mut monitor = DriftMonitor::new(mcfg);

    let mut alarms = Vec::new();
    println!("\nbatch | train ppx | cusum g | event");
    for mb in stream {
        let batch = mb.index;
        let report = algo.process_minibatch(&mb);
        let ll_per_token = report.train_ll / report.tokens.max(1.0);
        let shift = monitor.observe(batch, ll_per_token);
        let mut note = String::new();
        if truth_shifts.contains(&batch) {
            note.push_str("<- true shift ");
        }
        if let Some(event) = shift {
            alarms.push(event);
            // Adaptive response: halve the sufficient statistics, which
            // restarts Cappé's implicit 1/s schedule at s_eff = γ·s so
            // new evidence re-weighs the stale regime (DESIGN.md §15).
            algo.reset_decay(DECAY_FACTOR);
            note.push_str(&format!(
                "ALARM {} (score {:.1}) -> decay reset",
                event.direction.name(),
                event.score
            ));
        }
        if batch % 10 == 0 || !note.is_empty() {
            println!(
                "{batch:>5} | {:>9.1} | {:>7.2} | {note}",
                report.train_perplexity(),
                monitor.statistic()
            );
        }
    }

    println!("\ndetections:");
    for t in &truth_shifts {
        match alarms.iter().find(|a| a.batch >= *t) {
            Some(a) => println!(
                "  true shift at {t:>3}: flagged at batch {} \
                 (latency {} batches, direction {})",
                a.batch,
                a.batch - t,
                a.direction.name()
            ),
            None => println!("  true shift at {t:>3}: MISSED"),
        }
    }
    let false_alarms = alarms
        .iter()
        .filter(|a| !truth_shifts.iter().any(|t| a.batch >= *t && a.batch < t + 12))
        .count();
    println!(
        "{} alarms total, {false_alarms} outside any 12-batch \
         post-shift window",
        alarms.len()
    );
    Ok(())
}
