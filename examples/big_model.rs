//! Big-model demo (§4.2's headline, scaled to this testbed): extract
//! K=2048 topics while the K×W topic-word matrix lives ON DISK, with
//! only a fixed-size hot buffer resident — the configuration no other
//! online LDA algorithm in the comparison can run without K×W memory.
//!
//! The paper extracts K=10^4 from PUBMED with a 2 GB buffer on a 4 GB PC;
//! here K·W = 2048 × 2500 ≈ 20 MB is deliberately held to a ~2 MB buffer
//! (a 10% ratio, comparable to the paper's 2 GB / 10 GB) to exercise the
//! same streaming path.
//!
//!     cargo run --release --example big_model

use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::foem::{Foem, FoemConfig};
use foem::store::paged::PagedPhi;
use foem::store::PhiColumnStore;
use foem::stream::{CorpusStream, StreamConfig};
use foem::util::Timer;
use foem::LdaParams;

fn main() -> anyhow::Result<()> {
    let k = 2048usize;
    let mut profile = SyntheticConfig::pubmed_like();
    profile.n_docs = 2000;
    let corpus = generate(&profile, 9);
    let w = corpus.n_words();
    let full_bytes = k * w * 4;
    let buffer_bytes = full_bytes / 10;
    println!(
        "PUBMED-like stream: D={} W={w} | K={k} => phi matrix {:.1} MB,\n\
         resident buffer capped at {:.1} MB ({} columns)",
        corpus.n_docs(),
        full_bytes as f64 / 1e6,
        buffer_bytes as f64 / 1e6,
        buffer_bytes / (k * 4),
    );

    let dir = foem::util::TempDir::new("big-model");
    let p = LdaParams::paper_defaults(k);
    let mut fc = FoemConfig::paper(); // lambda_k*K = 10 topics per word
    fc.hot_words = buffer_bytes / 2 / (k * 4);
    fc.exact_ll = false; // throughput mode: skip the O(K*NNZ) LL pass
    fc.max_inner_iters = 10;
    // Parallel sharded E-step: the disk-backed store serves each
    // minibatch through a read-only column snapshot, so multiple workers
    // sweep concurrently while the store sees one read + one write per
    // column per minibatch.
    fc.n_workers = 4;
    // buffer_bytes covers phi + the streamed residual matrix (50/50).
    let mut algo =
        Foem::paged_create(p, &dir.path().join("phi.bin"), w, buffer_bytes, fc, 0)?;

    let scfg = StreamConfig { minibatch_docs: 512, ..Default::default() };
    let t = Timer::start();
    let mut batches = 0usize;
    for mb in CorpusStream::new(&corpus, scfg) {
        let r = algo.process_minibatch(&mb);
        batches += 1;
        println!(
            "  batch {batches}: {} inner sweeps, {:.2}s, {} local words",
            r.inner_iters,
            r.seconds,
            mb.n_local_words()
        );
    }
    let total = t.seconds();
    let io = algo.store.io_stats();
    println!(
        "\ndone: {batches} minibatches in {total:.1}s ({:.0} tokens/s)",
        corpus.n_tokens() / total
    );
    println!(
        "store I/O: {} column reads, {} writes, {} buffer hits ({:.0}% hit rate)",
        io.col_reads,
        io.col_writes,
        io.buffer_hits,
        100.0 * io.buffer_hits as f64
            / (io.buffer_hits + io.buffer_misses).max(1) as f64
    );
    // Fault tolerance: checkpoint, reopen, verify.
    algo.checkpoint_paged()?;
    algo.store.checkpoint(algo.step, &algo.phisum)?;
    let (step, phisum) = PagedPhi::load_checkpoint(&dir.path().join("phi.bin"))?;
    assert_eq!(step, batches);
    println!(
        "checkpoint verified: step {step}, phisum mass {:.0} == stream tokens {:.0}",
        phisum.iter().map(|&x| x as f64).sum::<f64>(),
        corpus.n_tokens()
    );
    Ok(())
}
