//! Big-model demo (§4.2's headline, scaled to this testbed): extract
//! K=2048 topics while the K×W topic-word matrix lives ON DISK, with
//! only a fixed-size hot buffer resident — the configuration no other
//! online LDA algorithm in the comparison can run without K×W memory.
//!
//! The paper extracts K=10^4 from PUBMED with a 2 GB buffer on a 4 GB PC;
//! here K·W = 2048 × 2500 ≈ 20 MB is deliberately held to a ~2 MB buffer
//! (a 10% ratio, comparable to the paper's 2 GB / 10 GB) to exercise the
//! same streaming path.
//!
//! The demo runs the SAME stream twice — synchronous (`pipeline depth 0`)
//! and pipelined (`depth 2`: prefetch + write-behind overlapped with
//! compute, `rust/DESIGN.md` §7) — and prints the overlapped-I/O
//! counters, so the Table 5 story plus its pipelined extension is
//! reproducible from one command:
//!
//!     cargo run --release --example big_model

use foem::coordinator::metrics::Metrics;
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::foem::{Foem, FoemConfig};
use foem::exec::pipeline::Pipeline;
use foem::store::paged::PagedPhi;
use foem::store::{IoStats, PhiColumnStore};
use foem::stream::{CorpusStream, StreamConfig};
use foem::util::Timer;
use foem::LdaParams;

fn main() -> anyhow::Result<()> {
    let k = 2048usize;
    let mut profile = SyntheticConfig::pubmed_like();
    profile.n_docs = 2000;
    let corpus = generate(&profile, 9);
    let w = corpus.n_words();
    let full_bytes = k * w * 4;
    let buffer_bytes = full_bytes / 10;
    println!(
        "PUBMED-like stream: D={} W={w} | K={k} => phi matrix {:.1} MB,\n\
         resident buffer capped at {:.1} MB ({} columns)",
        corpus.n_docs(),
        full_bytes as f64 / 1e6,
        buffer_bytes as f64 / 1e6,
        buffer_bytes / (k * 4),
    );

    let dir = foem::util::TempDir::new("big-model");
    let p = LdaParams::paper_defaults(k);
    let scfg = StreamConfig { minibatch_docs: 512, ..Default::default() };

    // One paged run of the whole stream at the given pipeline depth.
    // Returns (seconds, batches, phi-store IoStats, working-set peaks,
    // the trained model).
    let run = |depth: usize| -> anyhow::Result<(
        f64,
        usize,
        IoStats,
        (usize, usize),
        Metrics,
        Foem<PagedPhi>,
    )> {
        let mut fc = FoemConfig::paper(); // lambda_k*K = 10 topics per word
        fc.hot_words = buffer_bytes / 2 / (k * 4);
        fc.exact_ll = false; // throughput mode: skip the O(K*NNZ) LL pass
        fc.max_inner_iters = 10;
        // Parallel sharded E-step: the disk-backed store serves each
        // minibatch through a read-only column snapshot, so multiple
        // workers sweep concurrently while the store sees one read + one
        // write per column per minibatch.
        fc.n_workers = 4;
        // buffer_bytes covers phi + the streamed residual matrix (50/50).
        let mut algo = Foem::paged_create(
            p,
            &dir.path().join(format!("phi-d{depth}.bin")),
            w,
            buffer_bytes,
            fc,
            0,
        )?;
        let t = Timer::start();
        let mut batches = 0usize;
        let mut metrics = Metrics::new();
        Pipeline::new(depth).run(
            &mut algo,
            CorpusStream::new(&corpus, scfg),
            |_, batch_no, r| {
                batches = batch_no;
                metrics.record(batch_no, r, None, None);
                println!(
                    "  [d{depth}] batch {batch_no}: {} inner sweeps, {:.2}s",
                    r.inner_iters, r.seconds
                );
                Ok(())
            },
        )?;
        let peaks = (metrics.peak_resp_bytes, metrics.peak_scratch_bytes);
        Ok((t.seconds(), batches, algo.store.io_stats(), peaks, metrics, algo))
    };

    println!("\n-- synchronous parameter streaming (pipeline depth 0) --");
    let (t0, batches0, io0, (resp0, scratch0), _m0, _algo0) = run(0)?;
    println!("\n-- pipelined: prefetch + write-behind (depth 2) --");
    let (t2, batches2, io2, (resp2, scratch2), m2, mut algo2) = run(2)?;
    assert_eq!(batches0, batches2);

    // Per-batch telemetry round-trips through the CSV layer: this
    // consumer indexes columns by header name, so future appended
    // columns (e.g. the drift monitor's shift_dir/shift_score pair)
    // never break it.
    let csv = m2.to_csv();
    let parsed = Metrics::parse_csv(&csv)?;
    assert_eq!(parsed.records.len(), m2.records.len());
    println!(
        "per-batch CSV: {} rows x {} cols round-tripped (peak resp {:.2} MB)",
        parsed.records.len(),
        csv.lines().next().map_or(0, |h| h.split(',').count()),
        parsed.peak_resp_bytes as f64 / 1e6,
    );

    let hit_rate = |io: &IoStats| {
        100.0 * (io.buffer_hits + io.prefetch_hits) as f64
            / (io.buffer_hits + io.prefetch_hits + io.buffer_misses).max(1)
                as f64
    };
    println!(
        "\ndepth 0: {batches0} minibatches in {t0:.1}s ({:.0} tokens/s)\n\
         \x20        {} col reads, {} col writes, {} buffer hits, {} misses \
         ({:.0}% hit rate)",
        corpus.n_tokens() / t0,
        io0.col_reads,
        io0.col_writes,
        io0.buffer_hits,
        io0.buffer_misses,
        hit_rate(&io0),
    );
    println!(
        "depth 2: {batches2} minibatches in {t2:.1}s ({:.0} tokens/s)\n\
         \x20        {} col reads, {} col writes, {} buffer hits, {} misses \
         ({:.0}% hit rate)\n\
         \x20        overlapped: {} cols prefetched, {} prefetch hits, \
         {} write-behind flushes",
        corpus.n_tokens() / t2,
        io2.col_reads,
        io2.col_writes,
        io2.buffer_hits,
        io2.buffer_misses,
        hit_rate(&io2),
        io2.prefetched_cols,
        io2.prefetch_hits,
        io2.wb_writes,
    );
    println!(
        "blocking disk reads on the compute path: {} -> {} ({:.0}% hidden \
         by the stager thread)",
        io0.buffer_misses,
        io2.buffer_misses,
        100.0 * (1.0 - io2.buffer_misses as f64 / io0.buffer_misses.max(1) as f64),
    );

    // The §3.1 working-set claim, observable: the slot-compressed
    // responsibility arena holds O(NNZ·S) bytes (S = scheduled topics +
    // exploration slots) where the dense layout would hold O(NNZ·K).
    let lane = foem::em::resp::lane_capacity(
        foem::em::schedule::TopicSubset::Fixed(10).size(k),
        FoemConfig::paper().explore_slots,
        k,
    );
    // Lanes store (topic, weight) pairs + a spill head: ~(8·S + 4) bytes
    // per entry vs 4·K dense.
    let dense_equiv =
        |resp: usize| resp as f64 / (lane * 8 + 4) as f64 * (k * 4) as f64;
    println!(
        "working set (peak per minibatch):\n\
         \x20 depth 0: responsibility arena {:.2} MB (dense K-wide \
         equivalent ≈ {:.0} MB), scratch {:.2} MB\n\
         \x20 depth 2: responsibility arena {:.2} MB, scratch {:.2} MB",
        resp0 as f64 / 1e6,
        dense_equiv(resp0) / 1e6,
        scratch0 as f64 / 1e6,
        resp2 as f64 / 1e6,
        scratch2 as f64 / 1e6,
    );

    // Fault tolerance: checkpoint the pipelined model, reopen, verify.
    algo2.checkpoint_paged()?;
    algo2.store.checkpoint(algo2.step, &algo2.phisum)?;
    let (step, phisum) =
        PagedPhi::load_checkpoint(&dir.path().join("phi-d2.bin"))?;
    assert_eq!(step, batches2);
    println!(
        "checkpoint verified: step {step}, phisum mass {:.0} == stream tokens {:.0}",
        phisum.iter().map(|&x| x as f64).sum::<f64>(),
        corpus.n_tokens()
    );
    Ok(())
}
