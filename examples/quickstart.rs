//! Quickstart: train FOEM on a small synthetic corpus and print the
//! predictive perplexity.
//!
//!     cargo run --release --example quickstart

use foem::coordinator::config::RunConfig;
use foem::coordinator::driver::Driver;
use foem::corpus::synthetic::{generate, SyntheticConfig};

fn main() -> anyhow::Result<()> {
    // 1. A corpus. Real data: `foem::corpus::uci::load_docword(path)`.
    let corpus = generate(&SyntheticConfig::small(), 42);
    println!(
        "corpus: {} docs, {} vocabulary words, {} tokens",
        corpus.n_docs(),
        corpus.n_words(),
        corpus.n_tokens()
    );

    // 2. A run configuration. Defaults follow the paper (D_s = 1024,
    //    alpha-1 = beta-1 = 0.01, lambda_k*K = 10 scheduled topics/word).
    let cfg = RunConfig {
        n_topics: 20,
        minibatch_docs: 64,
        eval_every: 1,
        // Shard each minibatch's E-step across two worker threads
        // (n_workers = 1 is the exact serial path).
        n_workers: 2,
        ..RunConfig::default()
    };

    // 3. Train. The driver splits off a test set, streams minibatches
    //    through FOEM, and evaluates the paper's predictive perplexity
    //    (Eq. 21).
    let mut driver = Driver::new(cfg);
    let report = driver.train_corpus(&corpus)?;

    println!("\nperplexity trace (train seconds, predictive perplexity):");
    for (t, p) in report.metrics.eval_trace() {
        println!("  {t:7.2}s  {p:8.1}");
    }
    println!(
        "\nfinal predictive perplexity: {:.1}  ({:.0} tokens/s)",
        report.final_perplexity,
        report.metrics.tokens_per_second()
    );
    Ok(())
}
