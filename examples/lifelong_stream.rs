//! Lifelong topic modeling (§1, §3.2): an endless stream whose
//! vocabulary GROWS over time (`W ← W+1` as unseen words arrive), with
//! periodic checkpointing so the run can resume after a crash — the
//! scenario the paper argues no fixed-W online LDA algorithm handles.
//!
//! The stream is simulated as a sequence of epochs, each drawn from a
//! topic model over a progressively larger vocabulary (new terminology
//! entering the discourse).
//!
//!     cargo run --release --example lifelong_stream

use foem::baselines::OnlineLda;
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::foem::{Foem, FoemConfig};
use foem::eval::EvalProtocol;
use foem::store::paged::PagedPhi;
use foem::store::PhiColumnStore;
use foem::stream::{CorpusStream, StreamConfig};
use foem::LdaParams;

fn main() -> anyhow::Result<()> {
    let k = 64usize;
    let dir = foem::util::TempDir::new("lifelong");
    let store_path = dir.path().join("phi.bin");
    // Start with a minimal store; capacity grows with the vocabulary.
    let p = LdaParams::paper_defaults(k);
    let mut fc = FoemConfig::paper();
    fc.open_vocabulary = true;
    fc.hot_words = 128;
    fc.n_workers = 2; // lifelong streams ride the parallel E-step too
    let mut algo = Foem::paged_create(p, &store_path, 1, 1 << 20, fc, 0)?;

    // Unseen-document inference per epoch: scheduled fold-in (10 topics
    // per doc + exploration, per-doc cutoff, 2 workers) over a sparse
    // eval view of the paged store — the serving path, never a K×W
    // densification.
    let proto = EvalProtocol {
        fold_in_iters: 30,
        seed: 7,
        subset: foem::em::schedule::TopicSubset::Fixed(10),
        tol: 1e-2,
        workers: 2,
        ..Default::default()
    };

    println!("epoch | new vocab | effective W | train ppx | eval ppx | phi mass");
    for epoch in 0..4u64 {
        // Each epoch introduces fresh vocabulary: words are drawn from
        // [0, 600*(epoch+1)).
        let mut cfg = SyntheticConfig::small();
        cfg.n_docs = 300;
        cfg.n_words = 600 * (epoch as usize + 1);
        cfg.name = format!("epoch-{epoch}");
        let c = generate(&cfg, 1000 + epoch);
        // Hold out 40 docs of this epoch's discourse for predictive eval.
        let (train, held) = c.split(40, epoch);
        let scfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
        let mut last_ppx = f64::NAN;
        for mb in CorpusStream::new(&train, scfg) {
            last_ppx = algo.process_minibatch(&mb).train_perplexity();
        }
        // Held-out docs may carry words the training split never showed;
        // grow capacity so the eval view can materialize their columns
        // (zero columns — smoothed by beta — for the truly unseen), then
        // evaluate through the shared view-over-test-vocabulary helper.
        algo.store.ensure_capacity(held.docs.n_words);
        let eval_ppx = algo.eval_perplexity(&held.docs, &proto);
        println!(
            "{epoch:>5} | {:>9} | {:>11} | {last_ppx:>9.1} | {eval_ppx:>8.1} | {:>8.0}",
            c.n_words(),
            algo.effective_w(),
            algo.phisum_total()
        );
        // Checkpoint at epoch boundaries (fault tolerance).
        algo.checkpoint_paged()?;
        algo.store.checkpoint(algo.step, &algo.phisum)?;
    }

    // Simulated crash + restart: reopen the store and continue.
    let (step, phisum) = PagedPhi::load_checkpoint(&store_path)?;
    drop(algo);
    let mut fc2 = FoemConfig::paper();
    fc2.open_vocabulary = true;
    let mut resumed = Foem::paged_open(p, &store_path, 1 << 20, fc2, 1)?;
    resumed.step = step;
    resumed.phisum = phisum;
    println!(
        "\nrestarted from checkpoint at step {step}; phi mass {:.0} preserved",
        resumed.phisum_total()
    );
    let mut cfg = SyntheticConfig::small();
    cfg.n_docs = 200;
    cfg.n_words = 3000;
    let c = generate(&cfg, 99);
    let scfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
    for mb in CorpusStream::new(&c, scfg) {
        resumed.process_minibatch(&mb);
    }
    println!(
        "continued for {} more minibatches; final phi mass {:.0}",
        resumed.step - step,
        resumed.phisum_total()
    );
    Ok(())
}
