//! Train and serve from one process: a FOEM trainer publishes
//! epoch-tagged model snapshots to a `serve::ModelRegistry` while a
//! `serve::Server` answers unseen-document inference requests against
//! them concurrently — the paper's "infers the topic distribution from
//! previously unseen documents incrementally" claim, under live traffic.
//!
//! The two sides never share mutable state: the trainer's only output is
//! an atomic snapshot swap (`--serve-publish-every`), and every request
//! either follows the latest epoch or pins one explicitly. A request
//! pinned to epoch E is bit-deterministic no matter how many epochs the
//! trainer publishes meanwhile (`rust/DESIGN.md` §10).
//!
//!     cargo run --release --example serve_stream

use foem::coordinator::config::RunConfig;
use foem::coordinator::driver::Driver;
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::serve::{ModelRegistry, Server};
use std::collections::BTreeSet;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // One corpus: most documents train, 60 become the live traffic.
    let corpus = generate(&SyntheticConfig::small(), 11);
    let (train, live) = corpus.split(60, 0);
    let requests: Vec<Vec<(u32, f32)>> = (0..live.docs.n_docs)
        .map(|d| live.docs.iter_doc(d).collect())
        .collect();

    let cfg = RunConfig {
        n_topics: 32,
        minibatch_docs: 64,
        passes: 4,
        serve_publish_every: 1, // publish after every minibatch
        serve_workers: 2,
        ..RunConfig::default()
    };
    let registry = Arc::new(ModelRegistry::new());
    let server = Server::start(Arc::clone(&registry), cfg.serve_config());

    // The trainer runs on its own thread; the main thread is traffic.
    let trainer = {
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || {
            Driver::new(cfg).with_registry(registry).train_corpus(&train)
        })
    };

    // Wait for the first published epoch, then drive request waves until
    // training completes. Bail out (surfacing the training error) if the
    // trainer dies before ever publishing.
    while registry.latest().is_none() {
        if trainer.is_finished() {
            trainer.join().expect("trainer thread")?;
            anyhow::bail!("trainer finished without publishing an epoch");
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let mut epochs_seen = BTreeSet::new();
    let mut waves = 0usize;
    loop {
        let pending: Vec<_> = requests
            .iter()
            .enumerate()
            .map(|(i, doc)| server.submit(doc.clone(), i as u64))
            .collect::<anyhow::Result<_>>()?;
        for p in pending {
            epochs_seen.insert(p.wait()?.epoch);
        }
        waves += 1;
        if trainer.is_finished() {
            break;
        }
    }
    let train_report = trainer.join().expect("trainer thread")?;

    // One last request pinned to the final epoch: reproducible serving
    // against a frozen model, while the registry stays live.
    let final_snap = registry.latest().expect("final epoch");
    let resp = server
        .submit_pinned(requests[0].clone(), 0, Arc::clone(&final_snap))?
        .wait()?;
    println!(
        "pinned request @ epoch {}: perplexity {:.1}, {} sweeps, {:?}",
        final_snap.epoch(),
        resp.perplexity,
        resp.sweeps,
        resp.latency
    );

    let serve_report = server.shutdown();
    println!(
        "trainer: {} final predictive perplexity {:.1}",
        train_report.algorithm, train_report.final_perplexity
    );
    println!(
        "registry: {} epochs published, {} live at shutdown",
        registry.current_epoch(),
        registry.live_epochs().len()
    );
    println!(
        "traffic: {} request waves, epochs observed {:?}",
        waves, epochs_seen
    );
    println!(
        "serving: {} docs in {} batches (mean {:.1}/batch), \
         {:.0} docs/s, latency p50 {:.0}µs p99 {:.0}µs",
        serve_report.docs,
        serve_report.batches,
        serve_report.mean_batch_docs,
        serve_report.docs_per_sec,
        serve_report.p50_latency_us,
        serve_report.p99_latency_us
    );
    anyhow::ensure!(
        !epochs_seen.is_empty(),
        "traffic never observed a published epoch"
    );
    Ok(())
}
