//! Cross-module integration tests: corpus → stream → algorithms →
//! evaluation, the Fig. 9/11 ordering claims at test scale, and the
//! coordinator's fault-tolerance path.

use foem::baselines::{ogs, ovb, scvb, OnlineLda};
use foem::coordinator::config::{Algorithm, RunConfig, StoreKind};
use foem::coordinator::driver::Driver;
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::foem::{Foem, FoemConfig};
use foem::em::sem::{Sem, SemConfig};
use foem::eval::{predictive_perplexity, EvalProtocol};
use foem::store::paged::PagedPhi;
use foem::store::{InMemoryPhi, PhiColumnStore};
use foem::stream::{CorpusStream, StreamConfig};
use foem::LdaParams;

fn corpus_pair() -> (foem::corpus::Corpus, foem::corpus::Corpus) {
    let mut cfg = SyntheticConfig::small();
    cfg.n_docs = 400;
    let c = generate(&cfg, 7);
    c.split(60, 1)
}

fn eval<A: OnlineLda + ?Sized>(
    algo: &mut A,
    test: &foem::corpus::Corpus,
) -> f64 {
    let phi = algo.export_phi();
    predictive_perplexity(
        &phi,
        &algo.eval_params(),
        &test.docs,
        &EvalProtocol::default(),
    )
}

/// All seven algorithms train on the same stream and produce sane
/// perplexities; the EM/GS family must beat the VB family (the paper's
/// Fig. 9/11 group ordering).
#[test]
fn perplexity_group_ordering_matches_paper() {
    let (train, test) = corpus_pair();
    let k = 10;
    let scfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
    let s = CorpusStream::new(&train, scfg).batches_per_pass() as f64;
    let p = LdaParams::paper_defaults(k);

    let run = |algo: &mut dyn OnlineLda| -> f64 {
        for _pass in 0..3 {
            for mb in CorpusStream::new(&train, scfg) {
                algo.process_minibatch(&mb);
            }
        }
        eval(algo, &test)
    };

    let mut foem_a =
        Foem::new(p, InMemoryPhi::zeros(k, train.n_words()), FoemConfig::paper(), 0);
    let mut sem = Sem::new(p, train.n_words(), SemConfig::paper(s), 0);
    let mut scvb_a = scvb::Scvb::new(k, train.n_words(), scvb::ScvbConfig::paper(s), 0);
    let mut ogs_a = ogs::Ogs::new(k, train.n_words(), ogs::OgsConfig::paper(s), 0);
    let mut ovb_a = ovb::Ovb::new(k, train.n_words(), ovb::OvbConfig::paper(s), 0);

    let ppx_foem = run(&mut foem_a);
    let ppx_sem = run(&mut sem);
    let ppx_scvb = run(&mut scvb_a);
    let ppx_ogs = run(&mut ogs_a);
    let ppx_ovb = run(&mut ovb_a);

    println!(
        "FOEM={ppx_foem:.1} SEM={ppx_sem:.1} SCVB={ppx_scvb:.1} \
         OGS={ppx_ogs:.1} OVB={ppx_ovb:.1}"
    );
    for (name, v) in [
        ("FOEM", ppx_foem),
        ("SEM", ppx_sem),
        ("SCVB", ppx_scvb),
        ("OGS", ppx_ogs),
        ("OVB", ppx_ovb),
    ] {
        assert!(v > 1.0 && v < train.n_words() as f64, "{name}: {v}");
    }
    // Group claim: best EM-family model beats OVB (paper Figs. 9/11).
    let best_em = ppx_foem.min(ppx_sem).min(ppx_scvb);
    assert!(
        best_em < ppx_ovb,
        "EM family ({best_em}) should beat OVB ({ppx_ovb})"
    );
}

/// FOEM with the paged store survives a kill/restart cycle: state written
/// by checkpoint() is recovered and training continues (the §3.2 fault
/// tolerance claim).
#[test]
fn foem_restart_recovers_and_continues() {
    let dir = foem::util::TempDir::new("restart");
    let path = dir.path().join("phi.bin");
    let (train, test) = corpus_pair();
    let k = 6;
    let p = LdaParams::paper_defaults(k);
    let scfg = StreamConfig { minibatch_docs: 100, ..Default::default() };

    // Phase 1: train half the stream, checkpoint, drop (simulated crash).
    let phase1_ppx;
    {
        let mut foem_a = Foem::paged_create(
            p,
            &path,
            train.n_words(),
            64 * k * 4,
            FoemConfig::paper(),
            3,
        )
        .unwrap();
        let batches: Vec<_> = CorpusStream::new(&train, scfg).collect();
        for mb in &batches[..batches.len() / 2] {
            foem_a.process_minibatch(mb);
        }
        foem_a.checkpoint_paged().unwrap();
        phase1_ppx = eval(&mut foem_a, &test);
    }

    // Phase 2: reopen, restore, finish the stream.
    let (step, phisum) = PagedPhi::load_checkpoint(&path).unwrap();
    let mut foem_b = Foem::paged_open(
        p,
        &path,
        64 * k * 4,
        FoemConfig::paper(),
        3,
    )
    .unwrap();
    foem_b.step = step;
    foem_b.phisum = phisum;
    // Recovered mass must match what phase 1 accumulated.
    let recovered = foem_b.export_phi();
    for kk in 0..k {
        assert!(
            (recovered.phisum[kk] - foem_b.phisum[kk]).abs()
                < foem_b.phisum[kk].abs().max(1.0) * 1e-3,
            "checkpointed phisum inconsistent with store"
        );
    }
    let batches: Vec<_> = CorpusStream::new(&train, scfg).collect();
    for mb in &batches[batches.len() / 2..] {
        foem_b.process_minibatch(mb);
    }
    let phase2_ppx = eval(&mut foem_b, &test);
    assert!(
        phase2_ppx < phase1_ppx * 1.05,
        "continued training got worse: {phase1_ppx} -> {phase2_ppx}"
    );
}

/// Buffer size only changes I/O counts, never results (Table 5's premise).
#[test]
fn buffer_size_changes_io_not_results() {
    let (train, _) = corpus_pair();
    let k = 5;
    let p = LdaParams::paper_defaults(k);
    let scfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
    let run = |buf_cols: usize| {
        let dir = foem::util::TempDir::new("buf");
        let mut cfg = FoemConfig::paper();
        cfg.hot_words = buf_cols;
        let mut algo = Foem::paged_create(
            p,
            &dir.path().join("phi.bin"),
            train.n_words(),
            buf_cols * k * 4 * 2,
            cfg,
            5,
        )
        .unwrap();
        for mb in CorpusStream::new(&train, scfg) {
            algo.process_minibatch(&mb);
        }
        let io = algo.store.io_stats();
        (algo.export_phi(), io)
    };
    let (phi_small, io_small) = run(2);
    let (phi_big, io_big) = run(400);
    assert!(
        io_big.col_reads < io_small.col_reads,
        "bigger buffer should read less: {} vs {}",
        io_big.col_reads,
        io_small.col_reads
    );
    let mut max_rel = 0f32;
    for w in 0..train.n_words() {
        for kk in 0..k {
            let a = phi_small.word(w)[kk];
            let b = phi_big.word(w)[kk];
            max_rel = max_rel.max((a - b).abs() / a.abs().max(1.0));
        }
    }
    assert!(max_rel < 1e-4, "results diverged with buffer size: {max_rel}");
}

/// The driver + RunConfig path exercises the same pipeline as the manual
/// setup (guards against config plumbing rot).
#[test]
fn driver_matches_manual_foem() {
    let mut cfg_small = SyntheticConfig::small();
    cfg_small.n_docs = 150;
    let c = generate(&cfg_small, 17);
    let cfg = RunConfig {
        algorithm: Algorithm::Foem,
        n_topics: 5,
        minibatch_docs: 50,
        store: StoreKind::InMemory,
        seed: 9,
        ..RunConfig::default()
    };
    let mut driver = Driver::new(cfg);
    let report = driver.train_corpus(&c).unwrap();
    assert_eq!(report.algorithm, "FOEM");
    assert!(report.final_perplexity > 1.0);
    assert!(report.metrics.records.len() >= 2);
    // Tokens accounted exactly: all train-side tokens processed.
    let test_docs = (c.n_docs() / 10).clamp(1, 2000);
    let (train, _) = c.split(test_docs, 9);
    assert!((report.metrics.total_tokens - train.n_tokens()).abs() < 1e-6);
}

/// Topic recovery: trained on data from a known generative model, FOEM's
/// learned topics must align with the generating ones far better than
/// chance (greedy matching on L1 distance over the normalized rows).
#[test]
fn foem_recovers_generating_topics() {
    use foem::corpus::synthetic::{generate_with_truth, SyntheticConfig};
    let mut cfg = SyntheticConfig::small();
    cfg.n_docs = 500;
    cfg.n_topics = 8;
    cfg.mean_doc_len = 120.0;
    let (c, truth) = generate_with_truth(&cfg, 55);
    let k = 8;
    let p = LdaParams::paper_defaults(k);
    let mut algo = Foem::new(
        p,
        InMemoryPhi::zeros(k, c.n_words()),
        FoemConfig::paper(),
        1,
    );
    let scfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
    for _pass in 0..4 {
        for mb in CorpusStream::new(&c, scfg) {
            algo.process_minibatch(&mb);
        }
    }
    let phi = algo.export_phi();
    // Normalized learned topics, row per topic.
    let w = c.n_words();
    let mut learned = vec![vec![0.0f32; w]; k];
    for ww in 0..w {
        let pr = phi.prob(ww, &p);
        for kk in 0..k {
            learned[kk][ww] = pr[kk];
        }
    }
    // Greedy match learned -> truth by minimal L1 distance (max 2.0).
    let mut used = vec![false; k];
    let mut total_l1 = 0.0f32;
    for lt in &learned {
        let (mut best, mut best_d) = (usize::MAX, f32::INFINITY);
        for (ti, tt) in truth.phi.iter().enumerate() {
            if used[ti] {
                continue;
            }
            let d: f32 =
                lt.iter().zip(tt).map(|(a, b)| (a - b).abs()).sum();
            if d < best_d {
                best = ti;
                best_d = d;
            }
        }
        used[best] = true;
        total_l1 += best_d;
    }
    let mean_l1 = total_l1 / k as f32;
    // Random topic pairs on this W have L1 ~= 1.6-2.0; recovered topics
    // should be far closer.
    assert!(mean_l1 < 0.9, "topics not recovered: mean L1 = {mean_l1}");
}

/// Open-vocabulary lifelong mode: FOEM keeps learning as W grows without
/// losing earlier mass.
#[test]
fn lifelong_vocabulary_growth_is_safe() {
    let mut cfg = SyntheticConfig::small();
    cfg.n_docs = 200;
    cfg.n_words = 800;
    let c = generate(&cfg, 23);
    let k = 5;
    let p = LdaParams::paper_defaults(k);
    let mut fc = FoemConfig::paper();
    fc.open_vocabulary = true;
    // Start with a 1-word store; it must grow on demand.
    let mut algo = Foem::new(p, InMemoryPhi::zeros(k, 1), fc, 0);
    let scfg = StreamConfig { minibatch_docs: 40, ..Default::default() };
    for mb in CorpusStream::new(&c, scfg) {
        algo.process_minibatch(&mb);
    }
    let total = c.n_tokens();
    assert!((algo.phisum_total() - total).abs() < total * 1e-4);
    assert!(algo.store.n_words() <= cfg.n_words);
    assert!(algo.effective_w() > 400);
}
