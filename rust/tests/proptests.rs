//! Randomized property tests over the coordinator invariants (the
//! vendored crate set has no proptest, so these roll shrink-free random
//! sweeps with fixed seeds — each case runs dozens of random instances
//! and asserts the invariant exactly).

use foem::corpus::sparse::DocWordMatrix;
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::foem::{Foem, FoemConfig};
use foem::em::resp::top_n_indices;
use foem::em::schedule::TopicSubset;
use foem::em::{bem::Bem, iem::Iem, PhiStats};
use foem::store::paged::PagedPhi;
use foem::store::{InMemoryPhi, PhiColumnStore};
use foem::stream::{CorpusStream, Minibatch, StreamConfig};
use foem::util::Rng;
use foem::LdaParams;

fn random_docs(rng: &mut Rng, max_docs: usize, max_words: usize) -> DocWordMatrix {
    let n_docs = rng.below(max_docs) + 1;
    let n_words = rng.below(max_words) + 2;
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n_docs);
    for _ in 0..n_docs {
        let n_entries = rng.below(8) + 1;
        let mut row = std::collections::BTreeMap::new();
        for _ in 0..n_entries {
            let w = rng.below(n_words) as u32;
            *row.entry(w).or_insert(0.0) += (rng.below(4) + 1) as f32;
        }
        rows.push(row.into_iter().collect());
    }
    let refs: Vec<&[(u32, f32)]> = rows.iter().map(|r| r.as_slice()).collect();
    DocWordMatrix::from_rows(n_words, &refs)
}

/// Property: vocab-major reorganization is an exact permutation of the
/// doc-major entries (mass, NNZ, and per-cell counts all preserved).
#[test]
fn prop_vocab_major_is_permutation() {
    let mut rng = Rng::new(1000);
    for _case in 0..50 {
        let docs = random_docs(&mut rng, 20, 30);
        let vm = docs.to_vocab_major();
        assert_eq!(vm.nnz(), docs.nnz());
        assert!((vm.total_tokens() - docs.total_tokens()).abs() < 1e-9);
        // Per-cell check via lookup.
        for w in 0..docs.n_words {
            for (d, c) in vm.iter_word(w) {
                let found = docs
                    .iter_doc(d as usize)
                    .find(|&(ww, _)| ww as usize == w)
                    .map(|(_, cc)| cc);
                assert_eq!(found, Some(c), "cell ({w},{d})");
            }
        }
    }
}

/// Property: after any number of BEM sweeps, sufficient statistics
/// remain mass-consistent (sum theta_d == doc mass, phi total == corpus
/// mass, phisum == column sums).
#[test]
fn prop_bem_mass_conservation() {
    let mut rng = Rng::new(2000);
    for case in 0..25 {
        let docs = random_docs(&mut rng, 15, 25);
        let k = rng.below(6) + 2;
        let p = LdaParams::paper_defaults(k);
        let mut bem = Bem::init(&docs, p, case);
        let sweeps = rng.below(4) + 1;
        for _ in 0..sweeps {
            bem.sweep(&docs);
        }
        let total = docs.total_tokens();
        assert!(
            (bem.phi.total_mass() - total).abs() < total.max(1.0) * 1e-4,
            "case {case}"
        );
        for d in 0..docs.n_docs {
            assert!(
                (bem.theta.doc_total(d) - docs.doc_len(d)).abs()
                    < docs.doc_len(d).max(1.0) * 1e-4
            );
        }
        let mut rebuilt = bem.phi.clone();
        rebuilt.rebuild_phisum();
        for i in 0..k {
            assert!((bem.phi.phisum[i] - rebuilt.phisum[i]).abs() < 1e-2);
        }
    }
}

/// Property: IEM's mu rows stay normalized and non-negative after any
/// number of sweeps on any matrix.
#[test]
fn prop_iem_mu_is_distribution() {
    let mut rng = Rng::new(3000);
    for case in 0..20 {
        let docs = random_docs(&mut rng, 12, 20);
        let k = rng.below(5) + 2;
        let p = LdaParams::paper_defaults(k);
        let mut iem = Iem::init(&docs, p, case);
        for _ in 0..(rng.below(3) + 1) {
            iem.sweep(&docs);
        }
        for e in 0..docs.nnz() {
            let row = iem.resp.lane_dense(e);
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-3, "case {case} entry {e}: {s}");
            assert!(row.iter().all(|&x| x >= -1e-6));
        }
    }
}

/// Property: the trainers' top-topic selection (`resp::top_n_indices` at
/// a `TopicSubset`-derived size) always returns the true top set
/// (cross-checked against a full sort), for any residual vector.
#[test]
fn prop_scheduler_topk_exact() {
    let mut rng = Rng::new(4000);
    let mut sel: Vec<u32> = Vec::new();
    for _case in 0..100 {
        let k = rng.below(40) + 2;
        let n = TopicSubset::Fixed(rng.below(k) + 1).size(k);
        let res: Vec<f32> = (0..k).map(|_| rng.next_f32() * 10.0).collect();
        top_n_indices(&res, n, &mut sel);
        let got: std::collections::HashSet<u32> =
            sel.iter().copied().collect();
        let mut idx: Vec<u32> = (0..k as u32).collect();
        idx.sort_by(|&a, &b| {
            res[b as usize].partial_cmp(&res[a as usize]).unwrap()
        });
        let want: std::collections::HashSet<u32> =
            idx[..n].iter().copied().collect();
        // Sets can differ only on ties; compare residual-sum instead.
        let sum = |s: &std::collections::HashSet<u32>| -> f32 {
            s.iter().map(|&i| res[i as usize]).sum()
        };
        assert!((sum(&got) - sum(&want)).abs() < 1e-4);
        assert_eq!(got.len(), n);
    }
}

/// Property: the paged store behaves exactly like the in-memory store
/// under an arbitrary interleaving of column ops, hot-set changes,
/// capacity growth and flushes.
#[test]
fn prop_paged_store_equals_in_memory() {
    let mut rng = Rng::new(5000);
    for case in 0..10 {
        let k = rng.below(6) + 1;
        let w0 = rng.below(20) + 2;
        let dir = foem::util::TempDir::new("prop-store");
        let mut paged = PagedPhi::create(
            &dir.path().join("phi.bin"),
            k,
            w0,
            (rng.below(4) + 1) * k * 4,
        )
        .unwrap();
        let mut shadow = InMemoryPhi::zeros(k, w0);
        let mut w_cap = w0;
        for _op in 0..200 {
            match rng.below(10) {
                0 => {
                    // grow
                    let extra = rng.below(5) + 1;
                    w_cap += extra;
                    paged.ensure_capacity(w_cap);
                    shadow.ensure_capacity(w_cap);
                }
                1 => {
                    let hot: Vec<u32> = (0..rng.below(5))
                        .map(|_| rng.below(w_cap) as u32)
                        .collect();
                    paged.set_hot_words(&hot);
                }
                2 => {
                    paged.flush().unwrap();
                }
                _ => {
                    let w = rng.below(w_cap);
                    let kk = rng.below(k);
                    let delta = rng.next_f32();
                    paged.with_column(w, |c| c[kk] += delta);
                    shadow.with_column(w, |c| c[kk] += delta);
                }
            }
        }
        for w in 0..w_cap {
            let a = paged.read_column(w);
            let b = shadow.read_column(w);
            for i in 0..k {
                assert!(
                    (a[i] - b[i]).abs() < 1e-5,
                    "case {case} w={w} k={i}: {} vs {}",
                    a[i],
                    b[i]
                );
            }
        }
    }
}

/// Property: FOEM's accumulated global mass always equals the total
/// stream mass seen so far, for any minibatch framing and any subset
/// schedule (Eq. 33 invariant — scheduling moves mass, never creates it).
#[test]
fn prop_foem_mass_invariant_any_schedule() {
    let mut rng = Rng::new(6000);
    let mut cfg_small = SyntheticConfig::small();
    cfg_small.n_docs = 100;
    let c = generate(&cfg_small, 8);
    for case in 0..8 {
        let k = rng.below(8) + 2;
        let p = LdaParams::paper_defaults(k);
        let mut fc = FoemConfig::paper();
        fc.topic_subset = match rng.below(3) {
            0 => TopicSubset::All,
            1 => TopicSubset::Fixed(rng.below(k) + 1),
            _ => TopicSubset::Fraction(rng.next_f32().max(0.05)),
        };
        fc.lambda_w = 0.3 + 0.7 * rng.next_f32();
        fc.max_inner_iters = rng.below(8) + 1;
        fc.exact_ll = false;
        let mut algo =
            Foem::new(p, InMemoryPhi::zeros(k, c.n_words()), fc, case);
        let scfg = StreamConfig {
            minibatch_docs: rng.below(60) + 10,
            ..Default::default()
        };
        let mut seen = 0.0f64;
        for mb in CorpusStream::new(&c, scfg) {
            algo.process_minibatch(&mb);
            seen += mb.docs.total_tokens();
            assert!(
                (algo.phisum_total() - seen).abs() < seen.max(1.0) * 1e-4,
                "case {case}: {} vs {seen}",
                algo.phisum_total()
            );
        }
        // phisum must equal the column sums exactly.
        let dense: PhiStats = algo.export_phi();
        for kk in 0..k {
            assert!(
                (dense.phisum[kk] - algo.phisum[kk]).abs()
                    < algo.phisum[kk].abs().max(1.0) * 1e-3
            );
        }
    }
}

// ---------------------------------------------------------------------
// Shard-reduction properties: `exec::ParallelExecutor::reduce` /
// `em::SsDelta::merge` are the seam both the doc-sharded executor and
// the vocabulary-sharded fleet lean on for determinism, so their
// algebra is pinned here over random shard framings.
// ---------------------------------------------------------------------

fn random_delta(rng: &mut Rng, k: usize, words: &[u32]) -> foem::em::SsDelta {
    let mut d = foem::em::SsDelta::zeros(k, words.to_vec());
    for i in 0..words.len() {
        for t in 0..k {
            if rng.below(3) != 0 {
                // Strictly positive mass: avoids -0.0 artifacts so the
                // bit-equality assertions below are meaningful.
                d.add_at(i, t, rng.next_f32() + 0.25);
            }
        }
    }
    d
}

fn random_word_subset(rng: &mut Rng, vocab: usize, max_len: usize) -> Vec<u32> {
    let mut set = std::collections::BTreeSet::new();
    for _ in 0..(rng.below(max_len) + 1) {
        set.insert(rng.below(vocab) as u32);
    }
    set.into_iter().collect()
}

/// Property: reducing deltas over DISJOINT word ranges (the
/// vocabulary-sharded framing) is an exact scatter — every output
/// column is bit-identical to its sole contributor, no matter how many
/// shards there are.
#[test]
fn shard_prop_reduce_disjoint_is_exact_scatter() {
    let mut rng = Rng::new(8000);
    for case in 0..30 {
        let k = rng.below(6) + 1;
        let vocab = rng.below(40) + 8;
        let n_shards = rng.below(5) + 1;
        let span = vocab.div_ceil(n_shards).max(1);
        let mut deltas = Vec::new();
        for s in 0..n_shards {
            let lo = (s * span).min(vocab) as u32;
            let hi = ((s + 1) * span).min(vocab) as u32;
            let words: Vec<u32> = (lo..hi).collect();
            if !words.is_empty() {
                deltas.push(random_delta(&mut rng, k, &words));
            }
        }
        let all_words: Vec<u32> = (0..vocab as u32).collect();
        let acc = foem::exec::ParallelExecutor::new(1)
            .reduce(k, &all_words, deltas.iter());
        for d in &deltas {
            for (i, &w) in d.words().iter().enumerate() {
                let j = acc.index_of(w).unwrap();
                assert_eq!(acc.col(j), d.col(i), "case {case} word {w}");
            }
        }
    }
}

/// Property: reduction over OVERLAPPING shard vocabularies in fixed
/// shard order is bit-identical to the scalar reference fold (the same
/// `+=` sequence per column) — the doc-sharded determinism contract.
#[test]
fn shard_prop_reduce_overlapping_matches_reference() {
    let mut rng = Rng::new(8100);
    for case in 0..30 {
        let k = rng.below(6) + 1;
        let vocab = rng.below(30) + 4;
        let n_shards = rng.below(4) + 2;
        let deltas: Vec<foem::em::SsDelta> = (0..n_shards)
            .map(|_| {
                let words = random_word_subset(&mut rng, vocab, 12);
                random_delta(&mut rng, k, &words)
            })
            .collect();
        let all_words: Vec<u32> = (0..vocab as u32).collect();
        let acc = foem::exec::ParallelExecutor::new(4)
            .reduce(k, &all_words, deltas.iter());
        // Reference: identical per-column accumulation order.
        let mut reference = vec![0.0f32; vocab * k];
        let mut ref_phisum = vec![0.0f32; k];
        for d in &deltas {
            for (i, &w) in d.words().iter().enumerate() {
                for (t, &v) in d.col(i).iter().enumerate() {
                    reference[w as usize * k + t] += v;
                }
            }
            for (p, &q) in ref_phisum.iter_mut().zip(&d.phisum) {
                *p += q;
            }
        }
        for w in 0..vocab {
            let j = acc.index_of(w as u32).unwrap();
            assert_eq!(
                acc.col(j),
                &reference[w * k..(w + 1) * k],
                "case {case} word {w}"
            );
        }
        assert_eq!(acc.phisum, ref_phisum, "case {case} phisum");
    }
}

/// Property: with disjoint coverage the reduce order cannot change any
/// column (each has exactly one contributor) — only the per-topic
/// totals may move in the last float bits, and then only within
/// rounding of the reordered sum.
#[test]
fn shard_prop_reduce_disjoint_order_invariant() {
    let mut rng = Rng::new(8200);
    for case in 0..20 {
        let k = rng.below(5) + 1;
        let vocab = rng.below(24) + 6;
        let mid = vocab / 2;
        let a = random_delta(
            &mut rng,
            k,
            &(0..mid as u32).collect::<Vec<_>>(),
        );
        let b = random_delta(
            &mut rng,
            k,
            &(mid as u32..vocab as u32).collect::<Vec<_>>(),
        );
        let all_words: Vec<u32> = (0..vocab as u32).collect();
        let ex = foem::exec::ParallelExecutor::new(2);
        let fwd = ex.reduce(k, &all_words, [&a, &b]);
        let rev = ex.reduce(k, &all_words, [&b, &a]);
        for (j, &w) in fwd.words().iter().enumerate() {
            let jr = rev.index_of(w).unwrap();
            assert_eq!(fwd.col(j), rev.col(jr), "case {case} word {w}");
        }
        for t in 0..k {
            assert!(
                (fwd.phisum[t] - rev.phisum[t]).abs()
                    <= fwd.phisum[t].abs() * 1e-6,
                "case {case} topic {t}"
            );
        }
    }
}

/// Property: reducing a single delta over its own word list is the
/// identity, bit-for-bit (columns and totals).
#[test]
fn shard_prop_reduce_single_is_identity() {
    let mut rng = Rng::new(8300);
    for _case in 0..30 {
        let k = rng.below(6) + 1;
        let words = random_word_subset(&mut rng, 50, 20);
        let d = random_delta(&mut rng, k, &words);
        let acc = foem::exec::ParallelExecutor::new(1)
            .reduce(k, &words, [&d]);
        assert_eq!(acc.words(), d.words());
        for i in 0..words.len() {
            assert_eq!(acc.col(i), d.col(i));
        }
        assert_eq!(acc.phisum, d.phisum);
    }
}

/// The accumulator's word list must COVER every shard delta — a shard
/// producing a word outside the minibatch vocabulary is a framing bug
/// and must fail loudly, not be silently dropped.
#[test]
#[should_panic(expected = "word not covered by accumulator")]
fn shard_prop_merge_rejects_uncovered_word() {
    let mut rng = Rng::new(8400);
    let d = random_delta(&mut rng, 3, &[1, 5, 9]);
    // Accumulator misses word 5.
    foem::exec::ParallelExecutor::new(1).reduce(3, &[1, 9], [&d]);
}

/// Property: after any reduction, the accumulated per-topic totals
/// agree with the column sums (mass bookkeeping survives merging).
#[test]
fn shard_prop_reduce_phisum_consistent() {
    let mut rng = Rng::new(8500);
    for case in 0..20 {
        let k = rng.below(6) + 1;
        let vocab = rng.below(30) + 4;
        let deltas: Vec<foem::em::SsDelta> = (0..rng.below(4) + 1)
            .map(|_| {
                let words = random_word_subset(&mut rng, vocab, 10);
                random_delta(&mut rng, k, &words)
            })
            .collect();
        let all_words: Vec<u32> = (0..vocab as u32).collect();
        let acc = foem::exec::ParallelExecutor::new(1)
            .reduce(k, &all_words, deltas.iter());
        for t in 0..k {
            let col_sum: f32 =
                (0..vocab).map(|w| acc.col(w)[t]).sum();
            assert!(
                (acc.phisum[t] - col_sum).abs()
                    <= col_sum.abs().max(1.0) * 1e-5,
                "case {case} topic {t}: {} vs {col_sum}",
                acc.phisum[t]
            );
        }
    }
}

/// Property: minibatch framing is lossless for any minibatch size.
#[test]
fn prop_stream_framing_lossless() {
    let mut rng = Rng::new(7000);
    let c = generate(&SyntheticConfig::small(), 9);
    for _case in 0..20 {
        let ds = rng.below(300) + 1;
        let scfg = StreamConfig { minibatch_docs: ds, ..Default::default() };
        let mut docs = 0usize;
        let mut mass = 0.0f64;
        let mut last_index = 0usize;
        for mb in CorpusStream::new(&c, scfg) {
            docs += mb.n_docs();
            mass += mb.docs.total_tokens();
            assert_eq!(mb.index, last_index + 1);
            last_index = mb.index;
            assert!(mb.n_docs() <= ds);
            let _m: &Minibatch = &mb;
        }
        assert_eq!(docs, c.n_docs());
        assert!((mass - c.n_tokens()).abs() < 1e-6);
    }
}

use foem::coordinator::drift::{
    DetectorKind, DriftMonitor, MonitorConfig, ShiftEvent,
};

/// Default tuning with the CUSUM armed — MonitorConfig::default() keeps
/// the detector off (the bit-identity default), which would make every
/// alarm list trivially empty.
fn cusum_cfg() -> MonitorConfig {
    MonitorConfig { detector: DetectorKind::Cusum, ..Default::default() }
}

/// Feed `series` to a fresh CUSUM monitor and collect every alarm.
fn cusum_alarms(series: &[f64], cfg: MonitorConfig) -> Vec<ShiftEvent> {
    let mut monitor = DriftMonitor::new(cfg);
    series
        .iter()
        .enumerate()
        .filter_map(|(b, &x)| monitor.observe(b, x))
        .collect()
}

/// A noisy level series with one downward step at `shift_at`.
fn step_series(
    rng: &mut Rng,
    len: usize,
    shift_at: usize,
    delta: f64,
    sigma: f64,
) -> Vec<f64> {
    (0..len)
        .map(|b| {
            let level = if b < shift_at { -5.0 } else { -5.0 - delta };
            level + (rng.next_f64() * 2.0 - 1.0) * sigma
        })
        .collect()
}

/// Property: the CUSUM statistic standardizes against its own rolling
/// baseline, so adding a constant offset to the whole series changes
/// NOTHING — same alarm batches, same directions.
#[test]
fn shift_prop_cusum_offset_invariant() {
    let mut rng = Rng::new(8100);
    for _case in 0..30 {
        let sigma = 0.01 + rng.next_f64() * 0.1;
        let delta = 2.0 + rng.next_f64() * 4.0;
        let series = step_series(&mut rng, 70, 45, delta, sigma);
        let reference: Vec<(usize, _)> =
            cusum_alarms(&series, cusum_cfg())
                .into_iter()
                .map(|a| (a.batch, a.direction))
                .collect();
        for offset in [-1000.0, -3.25, 0.5, 777.0] {
            let shifted: Vec<f64> =
                series.iter().map(|x| x + offset).collect();
            let got: Vec<(usize, _)> =
                cusum_alarms(&shifted, cusum_cfg())
                    .into_iter()
                    .map(|a| (a.batch, a.direction))
                    .collect();
            assert_eq!(got, reference, "offset {offset} changed alarms");
        }
    }
}

/// Property: a bigger shift is never detected later. Deterministic
/// alternating baseline so latency depends only on the step size.
#[test]
fn shift_prop_cusum_monotone_in_magnitude() {
    let shift_at = 50usize;
    let latency = |delta: f64| -> Option<usize> {
        let series: Vec<f64> = (0..80)
            .map(|b| {
                let noise = if b % 2 == 0 { 0.1 } else { -0.1 };
                let level =
                    if b < shift_at { -5.0 } else { -5.0 - delta };
                level + noise
            })
            .collect();
        cusum_alarms(&series, cusum_cfg())
            .iter()
            .find(|a| a.batch >= shift_at)
            .map(|a| a.batch - shift_at)
    };
    let mut last = usize::MAX;
    for delta in [1.0, 2.0, 4.0, 8.0, 16.0] {
        let lat = latency(delta);
        if let Some(lat) = lat {
            assert!(
                lat <= last,
                "delta {delta}: latency {lat} > smaller-shift latency {last}"
            );
            last = lat;
        } else {
            assert_eq!(
                last,
                usize::MAX,
                "delta {delta} missed after a smaller shift was caught"
            );
        }
    }
    assert_ne!(last, usize::MAX, "even the largest shift was missed");
}

/// Property: an alarm fully resets the monitor — statistic zero,
/// disarmed, and silent through the whole re-warmup cooldown — then it
/// re-arms and can fire again on a later shift.
#[test]
fn shift_prop_cusum_resets_after_alarm() {
    let mut rng = Rng::new(8300);
    for _case in 0..20 {
        let sigma = 0.01 + rng.next_f64() * 0.05;
        let cfg = cusum_cfg();
        let mut monitor = DriftMonitor::new(cfg);
        let series = step_series(&mut rng, 120, 40, 8.0, sigma);
        let mut first_alarm = None;
        for (b, &x) in series.iter().enumerate() {
            if let Some(event) = monitor.observe(b, x) {
                first_alarm = Some(event.batch);
                break;
            }
        }
        let fired = first_alarm.expect("an 8-sigma step must alarm");
        assert_eq!(monitor.statistic(), 0.0, "statistic survives reset");
        assert!(!monitor.is_armed(), "armed through the cooldown");
        // Silent for the entire re-warmup, even though the post-shift
        // level keeps arriving.
        for b in fired + 1..fired + 1 + cfg.warmup {
            assert!(
                monitor.observe(b, series[b]).is_none(),
                "alarm during cooldown at {b}"
            );
        }
        // A second, later step is caught after re-arming.
        let tail_shift = fired + 1 + cfg.warmup + cfg.window;
        let mut caught = false;
        for b in fired + 1 + cfg.warmup..120 {
            let x = if b < tail_shift { series[b] } else { series[b] + 9.0 };
            if let Some(event) = monitor.observe(b, x) {
                assert!(event.batch >= tail_shift, "early re-alarm at {b}");
                caught = true;
                break;
            }
        }
        assert!(caught, "re-armed monitor missed the second shift");
    }
}
