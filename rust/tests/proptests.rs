//! Randomized property tests over the coordinator invariants (the
//! vendored crate set has no proptest, so these roll shrink-free random
//! sweeps with fixed seeds — each case runs dozens of random instances
//! and asserts the invariant exactly).

use foem::corpus::sparse::DocWordMatrix;
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::foem::{Foem, FoemConfig};
use foem::em::resp::top_n_indices;
use foem::em::schedule::TopicSubset;
use foem::em::{bem::Bem, iem::Iem, PhiStats};
use foem::store::paged::PagedPhi;
use foem::store::{InMemoryPhi, PhiColumnStore};
use foem::stream::{CorpusStream, Minibatch, StreamConfig};
use foem::util::Rng;
use foem::LdaParams;

fn random_docs(rng: &mut Rng, max_docs: usize, max_words: usize) -> DocWordMatrix {
    let n_docs = rng.below(max_docs) + 1;
    let n_words = rng.below(max_words) + 2;
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n_docs);
    for _ in 0..n_docs {
        let n_entries = rng.below(8) + 1;
        let mut row = std::collections::BTreeMap::new();
        for _ in 0..n_entries {
            let w = rng.below(n_words) as u32;
            *row.entry(w).or_insert(0.0) += (rng.below(4) + 1) as f32;
        }
        rows.push(row.into_iter().collect());
    }
    let refs: Vec<&[(u32, f32)]> = rows.iter().map(|r| r.as_slice()).collect();
    DocWordMatrix::from_rows(n_words, &refs)
}

/// Property: vocab-major reorganization is an exact permutation of the
/// doc-major entries (mass, NNZ, and per-cell counts all preserved).
#[test]
fn prop_vocab_major_is_permutation() {
    let mut rng = Rng::new(1000);
    for _case in 0..50 {
        let docs = random_docs(&mut rng, 20, 30);
        let vm = docs.to_vocab_major();
        assert_eq!(vm.nnz(), docs.nnz());
        assert!((vm.total_tokens() - docs.total_tokens()).abs() < 1e-9);
        // Per-cell check via lookup.
        for w in 0..docs.n_words {
            for (d, c) in vm.iter_word(w) {
                let found = docs
                    .iter_doc(d as usize)
                    .find(|&(ww, _)| ww as usize == w)
                    .map(|(_, cc)| cc);
                assert_eq!(found, Some(c), "cell ({w},{d})");
            }
        }
    }
}

/// Property: after any number of BEM sweeps, sufficient statistics
/// remain mass-consistent (sum theta_d == doc mass, phi total == corpus
/// mass, phisum == column sums).
#[test]
fn prop_bem_mass_conservation() {
    let mut rng = Rng::new(2000);
    for case in 0..25 {
        let docs = random_docs(&mut rng, 15, 25);
        let k = rng.below(6) + 2;
        let p = LdaParams::paper_defaults(k);
        let mut bem = Bem::init(&docs, p, case);
        let sweeps = rng.below(4) + 1;
        for _ in 0..sweeps {
            bem.sweep(&docs);
        }
        let total = docs.total_tokens();
        assert!(
            (bem.phi.total_mass() - total).abs() < total.max(1.0) * 1e-4,
            "case {case}"
        );
        for d in 0..docs.n_docs {
            assert!(
                (bem.theta.doc_total(d) - docs.doc_len(d)).abs()
                    < docs.doc_len(d).max(1.0) * 1e-4
            );
        }
        let mut rebuilt = bem.phi.clone();
        rebuilt.rebuild_phisum();
        for i in 0..k {
            assert!((bem.phi.phisum[i] - rebuilt.phisum[i]).abs() < 1e-2);
        }
    }
}

/// Property: IEM's mu rows stay normalized and non-negative after any
/// number of sweeps on any matrix.
#[test]
fn prop_iem_mu_is_distribution() {
    let mut rng = Rng::new(3000);
    for case in 0..20 {
        let docs = random_docs(&mut rng, 12, 20);
        let k = rng.below(5) + 2;
        let p = LdaParams::paper_defaults(k);
        let mut iem = Iem::init(&docs, p, case);
        for _ in 0..(rng.below(3) + 1) {
            iem.sweep(&docs);
        }
        for e in 0..docs.nnz() {
            let row = iem.resp.lane_dense(e);
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-3, "case {case} entry {e}: {s}");
            assert!(row.iter().all(|&x| x >= -1e-6));
        }
    }
}

/// Property: the trainers' top-topic selection (`resp::top_n_indices` at
/// a `TopicSubset`-derived size) always returns the true top set
/// (cross-checked against a full sort), for any residual vector.
#[test]
fn prop_scheduler_topk_exact() {
    let mut rng = Rng::new(4000);
    let mut sel: Vec<u32> = Vec::new();
    for _case in 0..100 {
        let k = rng.below(40) + 2;
        let n = TopicSubset::Fixed(rng.below(k) + 1).size(k);
        let res: Vec<f32> = (0..k).map(|_| rng.next_f32() * 10.0).collect();
        top_n_indices(&res, n, &mut sel);
        let got: std::collections::HashSet<u32> =
            sel.iter().copied().collect();
        let mut idx: Vec<u32> = (0..k as u32).collect();
        idx.sort_by(|&a, &b| {
            res[b as usize].partial_cmp(&res[a as usize]).unwrap()
        });
        let want: std::collections::HashSet<u32> =
            idx[..n].iter().copied().collect();
        // Sets can differ only on ties; compare residual-sum instead.
        let sum = |s: &std::collections::HashSet<u32>| -> f32 {
            s.iter().map(|&i| res[i as usize]).sum()
        };
        assert!((sum(&got) - sum(&want)).abs() < 1e-4);
        assert_eq!(got.len(), n);
    }
}

/// Property: the paged store behaves exactly like the in-memory store
/// under an arbitrary interleaving of column ops, hot-set changes,
/// capacity growth and flushes.
#[test]
fn prop_paged_store_equals_in_memory() {
    let mut rng = Rng::new(5000);
    for case in 0..10 {
        let k = rng.below(6) + 1;
        let w0 = rng.below(20) + 2;
        let dir = foem::util::TempDir::new("prop-store");
        let mut paged = PagedPhi::create(
            &dir.path().join("phi.bin"),
            k,
            w0,
            (rng.below(4) + 1) * k * 4,
        )
        .unwrap();
        let mut shadow = InMemoryPhi::zeros(k, w0);
        let mut w_cap = w0;
        for _op in 0..200 {
            match rng.below(10) {
                0 => {
                    // grow
                    let extra = rng.below(5) + 1;
                    w_cap += extra;
                    paged.ensure_capacity(w_cap);
                    shadow.ensure_capacity(w_cap);
                }
                1 => {
                    let hot: Vec<u32> = (0..rng.below(5))
                        .map(|_| rng.below(w_cap) as u32)
                        .collect();
                    paged.set_hot_words(&hot);
                }
                2 => {
                    paged.flush().unwrap();
                }
                _ => {
                    let w = rng.below(w_cap);
                    let kk = rng.below(k);
                    let delta = rng.next_f32();
                    paged.with_column(w, |c| c[kk] += delta);
                    shadow.with_column(w, |c| c[kk] += delta);
                }
            }
        }
        for w in 0..w_cap {
            let a = paged.read_column(w);
            let b = shadow.read_column(w);
            for i in 0..k {
                assert!(
                    (a[i] - b[i]).abs() < 1e-5,
                    "case {case} w={w} k={i}: {} vs {}",
                    a[i],
                    b[i]
                );
            }
        }
    }
}

/// Property: FOEM's accumulated global mass always equals the total
/// stream mass seen so far, for any minibatch framing and any subset
/// schedule (Eq. 33 invariant — scheduling moves mass, never creates it).
#[test]
fn prop_foem_mass_invariant_any_schedule() {
    let mut rng = Rng::new(6000);
    let mut cfg_small = SyntheticConfig::small();
    cfg_small.n_docs = 100;
    let c = generate(&cfg_small, 8);
    for case in 0..8 {
        let k = rng.below(8) + 2;
        let p = LdaParams::paper_defaults(k);
        let mut fc = FoemConfig::paper();
        fc.topic_subset = match rng.below(3) {
            0 => TopicSubset::All,
            1 => TopicSubset::Fixed(rng.below(k) + 1),
            _ => TopicSubset::Fraction(rng.next_f32().max(0.05)),
        };
        fc.lambda_w = 0.3 + 0.7 * rng.next_f32();
        fc.max_inner_iters = rng.below(8) + 1;
        fc.exact_ll = false;
        let mut algo =
            Foem::new(p, InMemoryPhi::zeros(k, c.n_words()), fc, case);
        let scfg = StreamConfig {
            minibatch_docs: rng.below(60) + 10,
            ..Default::default()
        };
        let mut seen = 0.0f64;
        for mb in CorpusStream::new(&c, scfg) {
            algo.process_minibatch(&mb);
            seen += mb.docs.total_tokens();
            assert!(
                (algo.phisum_total() - seen).abs() < seen.max(1.0) * 1e-4,
                "case {case}: {} vs {seen}",
                algo.phisum_total()
            );
        }
        // phisum must equal the column sums exactly.
        let dense: PhiStats = algo.export_phi();
        for kk in 0..k {
            assert!(
                (dense.phisum[kk] - algo.phisum[kk]).abs()
                    < algo.phisum[kk].abs().max(1.0) * 1e-3
            );
        }
    }
}

/// Property: minibatch framing is lossless for any minibatch size.
#[test]
fn prop_stream_framing_lossless() {
    let mut rng = Rng::new(7000);
    let c = generate(&SyntheticConfig::small(), 9);
    for _case in 0..20 {
        let ds = rng.below(300) + 1;
        let scfg = StreamConfig { minibatch_docs: ds, ..Default::default() };
        let mut docs = 0usize;
        let mut mass = 0.0f64;
        let mut last_index = 0usize;
        for mb in CorpusStream::new(&c, scfg) {
            docs += mb.n_docs();
            mass += mb.docs.total_tokens();
            assert_eq!(mb.index, last_index + 1);
            last_index = mb.index;
            assert!(mb.n_docs() <= ds);
            let _m: &Minibatch = &mb;
        }
        assert_eq!(docs, c.n_docs());
        assert!((mass - c.n_tokens()).abs() < 1e-6);
    }
}
