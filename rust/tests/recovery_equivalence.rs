//! Crash-recovery equivalence guards for the WAL + checkpoint stack
//! (`store::wal`, `coordinator::checkpoint`, `rust/DESIGN.md` §13),
//! driven entirely through the public API:
//!
//! * Kill the trainer at a batch boundary (`std::mem::forget`, the
//!   userspace analogue of `kill -9`: no flush, no Drop, no WAL
//!   truncation), recover via [`Foem::paged_resume`], finish the
//!   stream — trainer state, exported phi, and held-out perplexity
//!   must be BIT-identical to the uninterrupted same-seed run.
//! * A torn WAL tail (partial last frame, as a crash mid-append
//!   leaves behind) silently falls back to the last complete commit;
//!   the lost batch is simply retrained, and the final state is
//!   still bit-identical.
//! * Garbage appended past the last commit is discarded the same way.

use foem::baselines::OnlineLda;
use foem::coordinator::checkpoint::{self, TrainerCheckpoint};
use foem::em::foem::{Foem, FoemConfig, FoemTrainState};
use foem::store::wal::wal_path;
use foem::stream::{CorpusStream, StreamConfig};
use foem::util::TempDir;
use foem::LdaParams;

const K: usize = 6;
const SEED: u64 = 7;

fn corpus() -> foem::corpus::Corpus {
    let mut cfg = foem::corpus::synthetic::SyntheticConfig::small();
    cfg.n_docs = 250;
    foem::corpus::synthetic::generate(&cfg, 31)
}

/// 200 train docs / 50 per batch = exactly 4 batches per pass.
fn stream_cfg() -> StreamConfig {
    StreamConfig { minibatch_docs: 50, ..Default::default() }
}

fn foem_cfg() -> FoemConfig {
    let mut fc = FoemConfig::paper();
    // Small hot buffer: columns evict mid-batch, so the WAL's
    // extent-preservation and dirty-hot-sweep paths both run.
    fc.hot_words = 8;
    fc
}

fn mk(dir: &TempDir, name: &str, n_words: usize) -> Foem<foem::store::paged::PagedPhi> {
    Foem::paged_create(
        LdaParams::paper_defaults(K),
        &dir.path().join(name),
        n_words,
        32 * K * 4,
        foem_cfg(),
        SEED,
    )
    .unwrap()
}

fn ppx_bits(algo: &mut Foem<foem::store::paged::PagedPhi>, test: &foem::corpus::Corpus) -> u64 {
    let proto = foem::eval::EvalProtocol { fold_in_iters: 20, seed: 0, ..Default::default() };
    algo.eval_perplexity(&test.docs, &proto).to_bits()
}

/// The uninterrupted WAL-off reference run: final trainer state, phi
/// bits, and held-out perplexity bits. Everything a recovered run
/// must reproduce exactly.
fn reference(
    dir: &TempDir,
    train: &foem::corpus::Corpus,
    test: &foem::corpus::Corpus,
) -> (FoemTrainState, Vec<f32>, u64) {
    let mut a = mk(dir, "ref.bin", train.n_words());
    for mb in CorpusStream::new(train, stream_cfg()) {
        a.process_minibatch(&mb);
    }
    let state = a.export_train_state();
    let phi = a.export_phi().raw().to_vec();
    let ppx = ppx_bits(&mut a, test);
    (state, phi, ppx)
}

/// Run a WAL-armed trainer: coordinator checkpoint after
/// `checkpoint_after` batches, hard kill after `kill_after`, leaving
/// batches (checkpoint_after, kill_after] only in the WALs.
/// Returns the number of batches processed before the kill.
fn run_and_kill(
    dir: &TempDir,
    ckpt_dir: &std::path::Path,
    train: &foem::corpus::Corpus,
    checkpoint_after: usize,
    kill_after: usize,
) -> usize {
    let mut b = mk(dir, "phi.bin", train.n_words());
    b.enable_wal().unwrap();
    let mut done = 0usize;
    for mb in CorpusStream::new(train, stream_cfg()) {
        b.process_minibatch(&mb);
        done += 1;
        if done == checkpoint_after {
            b.checkpoint_paged().unwrap();
            checkpoint::save(
                ckpt_dir,
                &TrainerCheckpoint {
                    fingerprint: 0xfeed,
                    batch_cursor: done as u64,
                    epoch: 0,
                    state: b.export_train_state(),
                },
            )
            .unwrap();
            OnlineLda::truncate_wal(&mut b).unwrap();
        }
        if done == kill_after {
            break;
        }
    }
    // kill -9: no Drop, no flush, no .idx rewrite, no WAL truncation.
    std::mem::forget(b);
    done
}

/// Recover from the on-disk checkpoint + WALs, finish the remainder of
/// the stream, and assert bit-identity against the reference run.
fn resume_and_check(
    dir: &TempDir,
    ckpt_dir: &std::path::Path,
    train: &foem::corpus::Corpus,
    test: &foem::corpus::Corpus,
    want_last: u64,
    reference: &(FoemTrainState, Vec<f32>, u64),
) {
    let ckpt = checkpoint::load(ckpt_dir).unwrap().expect("checkpoint exists");
    let (mut r, last) = Foem::paged_resume(
        LdaParams::paper_defaults(K),
        &dir.path().join("phi.bin"),
        32 * K * 4,
        foem_cfg(),
        &ckpt.state,
    )
    .unwrap();
    assert_eq!(last, want_last, "WAL replay recovered the wrong batch cursor");
    for mb in CorpusStream::new(train, stream_cfg()).skip(last as usize) {
        r.process_minibatch(&mb);
    }
    assert_eq!(
        r.export_train_state(),
        reference.0,
        "recovered trainer state diverged from the uninterrupted run"
    );
    assert_eq!(
        r.export_phi().raw(),
        &reference.1[..],
        "recovered phi diverged from the uninterrupted run"
    );
    assert_eq!(
        ppx_bits(&mut r, test),
        reference.2,
        "recovered held-out perplexity diverged"
    );
}

#[test]
fn recovery_kill_and_resume_matches_uninterrupted_run() {
    let c = corpus();
    let (train, test) = c.split(50, 1);
    let rdir = TempDir::new("rec-kill-ref");
    let want = reference(&rdir, &train, &test);

    let dir = TempDir::new("rec-kill");
    let ckpt_dir = dir.path().join("ckpt");
    // Checkpoint at batch 2, die at batch 3: batch 3 exists ONLY as
    // committed WAL frames (the on-disk .idx still describes batch 2),
    // and batch 4 is retrained live after recovery.
    let done = run_and_kill(&dir, &ckpt_dir, &train, 2, 3);
    assert_eq!(done, 3);
    resume_and_check(&dir, &ckpt_dir, &train, &test, 3, &want);
}

#[test]
fn recovery_torn_wal_tail_falls_back_to_last_commit() {
    let c = corpus();
    let (train, test) = c.split(50, 1);
    let rdir = TempDir::new("rec-torn-ref");
    let want = reference(&rdir, &train, &test);

    let dir = TempDir::new("rec-torn");
    let ckpt_dir = dir.path().join("ckpt");
    let done = run_and_kill(&dir, &ckpt_dir, &train, 2, 4);
    assert_eq!(done, 4);

    // Tear the phi WAL mid-frame — the tail a crash inside append()
    // leaves. Batch 4's commit frame is destroyed, so recovery must
    // land on batch 3 and retrain batch 4 from the stream instead.
    let wal = wal_path(&dir.path().join("phi.bin"));
    let bytes = std::fs::read(&wal).unwrap();
    assert!(bytes.len() > 7, "phi WAL unexpectedly small");
    std::fs::write(&wal, &bytes[..bytes.len() - 7]).unwrap();

    resume_and_check(&dir, &ckpt_dir, &train, &test, 3, &want);
}

#[test]
fn recovery_garbage_wal_tail_is_ignored() {
    let c = corpus();
    let (train, test) = c.split(50, 1);
    let rdir = TempDir::new("rec-garbage-ref");
    let want = reference(&rdir, &train, &test);

    let dir = TempDir::new("rec-garbage");
    let ckpt_dir = dir.path().join("ckpt");
    let done = run_and_kill(&dir, &ckpt_dir, &train, 2, 3);
    assert_eq!(done, 3);

    // Append junk past the last commit on BOTH logs (a torn Begin frame
    // of a batch that never committed looks exactly like this). Every
    // committed frame before it must still replay.
    for store in ["phi.bin", "phi.res.bin"] {
        let wal = wal_path(&dir.path().join(store));
        let mut bytes = std::fs::read(&wal).unwrap();
        bytes.extend(std::iter::repeat(0xAB).take(64));
        std::fs::write(&wal, &bytes).unwrap();
    }

    resume_and_check(&dir, &ckpt_dir, &train, &test, 3, &want);
}
