//! The serving layer's acceptance contract: a request pinned to epoch E
//! returns bit-identical `(theta, perplexity)` to an offline
//! `em::infer::fold_in` + `eval::log_likelihood` run against that
//! epoch's snapshot — while a concurrent trainer keeps publishing new
//! epochs — and the batcher's backpressure refuses (rather than drops)
//! overload.

use foem::corpus::sparse::DocWordMatrix;
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::bem::Bem;
use foem::em::infer::{self, FoldInConfig};
use foem::em::{EvalPhiView, PhiAccess, PhiStats};
use foem::serve::{ModelRegistry, ModelSnapshot, ServeConfig, Server};
use foem::LdaParams;
use std::sync::Arc;

fn all_words(w: usize) -> Vec<u32> {
    (0..w as u32).collect()
}

#[test]
fn pinned_requests_bit_identical_under_concurrent_publishing() {
    let k = 16;
    let corpus = generate(&SyntheticConfig::small(), 5);
    let params = LdaParams::paper_defaults(k);
    let mut bem = Bem::init(&corpus.docs, params, 5);
    for _ in 0..5 {
        bem.sweep(&corpus.docs);
    }
    let words = all_words(corpus.n_words());
    let registry = Arc::new(ModelRegistry::new());
    let pinned: Arc<ModelSnapshot> =
        registry.publish(EvalPhiView::from_dense(&bem.phi, &words), params);
    let cfg = ServeConfig::default();
    let server = Server::start(Arc::clone(&registry), cfg);

    // Live requests: the first 24 corpus documents.
    let requests: Vec<Vec<(u32, f32)>> =
        (0..24).map(|d| corpus.docs.iter_doc(d).collect()).collect();

    std::thread::scope(|s| {
        // Concurrent trainer: keeps sweeping and publishing new epochs
        // the whole time the pinned requests are in flight.
        let publisher = {
            let registry = Arc::clone(&registry);
            let docs = &corpus.docs;
            let words = &words;
            s.spawn(move || {
                for _ in 0..20 {
                    bem.sweep(docs);
                    registry.publish(
                        EvalPhiView::from_dense(&bem.phi, words),
                        params,
                    );
                }
            })
        };

        let pending: Vec<_> = requests
            .iter()
            .enumerate()
            .map(|(i, doc)| {
                server
                    .submit_pinned(
                        doc.clone(),
                        1000 + i as u64,
                        Arc::clone(&pinned),
                    )
                    .unwrap()
            })
            .collect();
        for (i, pr) in pending.into_iter().enumerate() {
            let resp = pr.wait().unwrap();
            assert_eq!(resp.epoch, pinned.epoch());

            // Offline reference: the same fold-in against the pinned
            // snapshot, serial, same seed and protocol.
            let row: [&[(u32, f32)]; 1] = [&requests[i]];
            let doc = DocWordMatrix::from_rows(pinned.n_words(), &row);
            let mut fc: FoldInConfig = cfg.fold_in;
            fc.n_workers = 1;
            let theta = infer::fold_in(
                pinned.view(),
                pinned.params(),
                &doc,
                &fc,
                1000 + i as u64,
            );
            assert_eq!(resp.theta.len(), theta.doc(0).len());
            for (j, (a, b)) in
                resp.theta.iter().zip(theta.doc(0)).enumerate()
            {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "request {i}: theta diverged at topic {j}"
                );
            }
            let (ll, n) = foem::eval::log_likelihood(
                pinned.view(),
                pinned.params(),
                &theta,
                &doc,
            );
            let reference = foem::em::perplexity(ll, n);
            assert_eq!(
                resp.perplexity, reference,
                "request {i}: perplexity diverged"
            );
        }
        publisher.join().unwrap();
    });

    // The trainer published 20 epochs on top of the pinned one; an
    // unpinned request now follows the newest.
    assert_eq!(registry.current_epoch(), 21);
    let resp = server.submit(requests[0].clone(), 9).unwrap().wait().unwrap();
    assert_eq!(resp.epoch, 21);

    let report = server.shutdown();
    assert_eq!(report.docs, 25);
    assert_eq!(report.failed, 0);
    assert!(report.p99_latency_us >= report.p50_latency_us);

    // Retirement: the pinned epoch is still alive through our Arc; once
    // dropped, only the current epoch remains live.
    assert!(registry.live_epochs().contains(&pinned.epoch()));
    drop(pinned);
    assert_eq!(registry.live_epochs(), vec![21]);
}

#[test]
fn try_submit_applies_backpressure_when_the_queue_fills() {
    // A deliberately slow protocol (dense full-K sweeps, fixed budget)
    // and a tiny queue: a burst of immediate try_submits must overrun
    // the bound and be refused, never silently dropped.
    let k = 256;
    let w = 128;
    let params = LdaParams::paper_defaults(k);
    let mut rng = foem::util::Rng::new(9);
    let mut phi = PhiStats::zeros(k, w);
    let mut col = vec![0.0f32; k];
    for word in 0..w {
        for x in col.iter_mut() {
            *x = rng.next_f32() + 0.05;
        }
        phi.add_to_word(word, &col);
    }
    let registry = Arc::new(ModelRegistry::new());
    registry.publish(EvalPhiView::from_dense(&phi, &all_words(w)), params);

    let cfg = ServeConfig {
        max_batch_docs: 1,
        queue_docs: 2,
        workers: 1,
        fold_in: FoldInConfig::dense(300),
    };
    let server = Server::start(Arc::clone(&registry), cfg);
    let doc: Vec<(u32, f32)> = (0..120u32).map(|word| (word, 1.0)).collect();

    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for i in 0..50u64 {
        match server.try_submit(doc.clone(), i) {
            Ok(pending) => accepted.push(pending),
            Err(e) => {
                rejected += 1;
                assert!(e.to_string().contains("queue full"), "{e}");
            }
        }
    }
    assert!(rejected > 0, "50 instant submits never overran a 2-doc queue");
    assert!(!accepted.is_empty());
    let n_accepted = accepted.len() as u64;
    for pending in accepted {
        let resp = pending.wait().unwrap();
        assert_eq!(resp.epoch, 1);
    }
    let report = server.shutdown();
    assert_eq!(report.docs, n_accepted);
    assert_eq!(report.rejected, rejected);
    assert_eq!(report.failed, 0);
}
