//! Ground-truth guards for the non-stationary streaming stack
//! (`corpus::synthetic::DriftingCorpus`, `coordinator::drift`,
//! `rust/DESIGN.md` §15), driven through the public API:
//!
//! * Detection latency against the generator's OWN change log: every
//!   injected shift must be flagged within the documented bound, and
//!   the same-seed stationary control must raise ZERO alarms — the
//!   false-alarm contract that makes the responses safe to wire in.
//! * Bit-identity: `drift_detector off` (the default) leaves the
//!   driver's numerics exactly as they were — and detector-on with
//!   `drift_response none` changes telemetry only (same model bits,
//!   same final/periodic perplexity), because the monitor's input is
//!   the read-only exact-LL pass.
//! * Response wiring: with a hair-trigger threshold each response
//!   (decay-reset, widen, grow) runs to completion through the driver,
//!   records its alarms in the batch metrics CSV, and surfaces them
//!   through an attached serving registry; unsupported combinations
//!   are rejected before training starts.

use foem::coordinator::config::{Algorithm, RunConfig, StoreKind};
use foem::coordinator::drift::{
    DetectorKind, DriftMonitor, MonitorConfig, ShiftEvent,
};
use foem::coordinator::driver::Driver;
use foem::coordinator::metrics::Metrics;
use foem::corpus::synthetic::{
    DriftConfig, DriftKind, DriftPoint, DriftingCorpus, SyntheticConfig,
};
use foem::em::foem::{Foem, FoemConfig};
use foem::serve::ModelRegistry;
use foem::store::InMemoryPhi;
use foem::util::TempDir;
use foem::LdaParams;
use std::sync::Arc;

const K: usize = 16;
const W: usize = 600;

/// Detection-latency bound asserted here and documented in DESIGN.md
/// §15: ceil(h / (z_bar - slack)) batches after the change point for a
/// shift of z_bar sigma; the full-redraw shifts injected below are far
/// beyond the threshold, so 8 batches is generous.
const LATENCY_BOUND: usize = 8;

fn drift_stream(events: Vec<DriftPoint>, n_batches: usize) -> DriftingCorpus {
    let mut base = SyntheticConfig::small();
    base.n_docs = 0; // unused by the drifting generator
    base.n_words = W;
    base.n_topics = K;
    let mut cfg = DriftConfig::stationary(base, 48, n_batches);
    cfg.events = events;
    DriftingCorpus::new(cfg, 1234)
}

/// The subsystem harness: train FOEM over the drifting stream and feed
/// the monitor the per-batch training LL — exactly the driver's wiring,
/// minus the driver (whose stream framing is corpus-based). Returns
/// every alarm raised.
fn monitor_over(
    stream: DriftingCorpus,
    detector: DetectorKind,
) -> Vec<ShiftEvent> {
    let mut fc = FoemConfig::paper();
    fc.exact_ll = true;
    let mut algo = Foem::new(
        LdaParams::paper_defaults(K),
        InMemoryPhi::zeros(K, W),
        fc,
        9,
    );
    let mcfg = MonitorConfig { detector, ..Default::default() };
    let mut monitor = DriftMonitor::new(mcfg);
    let mut alarms = Vec::new();
    for mb in stream {
        let report = algo.process_minibatch(&mb);
        if let Some(event) = monitor
            .observe(mb.index, report.train_ll / report.tokens.max(1.0))
        {
            alarms.push(event);
        }
    }
    alarms
}

#[test]
fn drift_cusum_flags_every_true_shift_within_the_latency_bound() {
    let events = vec![
        DriftPoint { batch: 40, kind: DriftKind::MixtureShift { fraction: 1.0 } },
        DriftPoint { batch: 65, kind: DriftKind::MixtureShift { fraction: 1.0 } },
    ];
    let stream = drift_stream(events, 90);
    let truth = stream.truth().shift_batches();
    assert_eq!(truth, vec![40, 65], "generator change log");
    let alarms = monitor_over(stream, DetectorKind::Cusum);

    // Zero alarms before the first true shift.
    assert!(
        alarms.iter().all(|a| a.batch >= truth[0]),
        "alarm before any true shift: {alarms:?}"
    );
    // Every true shift flagged within the bound.
    for &t in &truth {
        let hit = alarms
            .iter()
            .find(|a| a.batch >= t && a.batch < t + LATENCY_BOUND);
        let hit = hit.unwrap_or_else(|| {
            panic!("shift at {t} not flagged within {LATENCY_BOUND}: {alarms:?}")
        });
        assert!(hit.score >= 8.0, "alarm score below threshold: {hit:?}");
    }
}

#[test]
fn drift_stationary_control_raises_zero_alarms() {
    // SAME generator seed as the shifting runs — the control differs
    // only in its (empty) event schedule. The detector must sit through
    // the entire convergence trend in silence, for both detector kinds.
    for detector in [DetectorKind::Cusum, DetectorKind::Window] {
        let alarms = monitor_over(drift_stream(Vec::new(), 90), detector);
        assert!(
            alarms.is_empty(),
            "{}: false alarms on stationary control: {alarms:?}",
            detector.name()
        );
    }
}

fn small_corpus() -> foem::corpus::Corpus {
    let mut cfg = SyntheticConfig::small();
    cfg.n_docs = 320;
    cfg.n_words = 400;
    foem::corpus::synthetic::generate(&cfg, 77)
}

fn driver_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.n_topics = K;
    cfg.minibatch_docs = 64;
    cfg.eval_every = 2;
    cfg
}

fn run(cfg: RunConfig) -> foem::coordinator::driver::TrainReport {
    Driver::new(cfg).train_corpus(&small_corpus()).unwrap()
}

#[test]
fn drift_detector_off_is_deterministic_and_on_changes_telemetry_only() {
    // Reference semantics: the default config (detector off).
    let off_a = run(driver_cfg());
    let off_b = run(driver_cfg());
    assert_eq!(
        off_a.final_perplexity.to_bits(),
        off_b.final_perplexity.to_bits(),
        "detector-off runs must be bit-reproducible"
    );
    assert!(off_a.metrics.shift_events().is_empty());
    // Detector-off reports carry no training LL (throughput mode:
    // train_ll = 0, so the per-batch training perplexity degenerates
    // to exp(0) = 1) — the pre-drift hot-path contract.
    assert!(off_a
        .metrics
        .records
        .iter()
        .all(|r| r.shift.is_none() && r.train_perplexity == 1.0));

    // Detector ON, response none: the monitor consumes the read-only
    // exact-LL pass, so the MODEL is bit-identical — same periodic
    // eval trace, same final perplexity — and only telemetry changes.
    let mut on_cfg = driver_cfg();
    on_cfg.set("drift_detector", "cusum").unwrap();
    let on = run(on_cfg);
    assert_eq!(
        on.final_perplexity.to_bits(),
        off_a.final_perplexity.to_bits(),
        "detector-on/response-none must not change model numerics"
    );
    let off_trace: Vec<u64> = off_a
        .metrics
        .eval_trace()
        .iter()
        .map(|&(_, p)| p.to_bits())
        .collect();
    let on_trace: Vec<u64> =
        on.metrics.eval_trace().iter().map(|&(_, p)| p.to_bits()).collect();
    assert_eq!(off_trace, on_trace, "periodic eval trace diverged");
    // The telemetry it DOES add: a real per-batch training perplexity.
    assert!(on
        .metrics
        .records
        .iter()
        .all(|r| r.train_perplexity.is_finite() && r.train_perplexity > 1.0));
}

#[test]
fn drift_detector_off_matches_on_paged_store_with_io() {
    let dir = TempDir::new("drift-paged");
    let mk = |name: &str, detector: &str| {
        let mut cfg = driver_cfg();
        cfg.store = StoreKind::Paged {
            path: dir.path().join(name),
            buffer_bytes: 64 * K * 4,
        };
        cfg.set("drift_detector", detector).unwrap();
        cfg
    };
    let off = run(mk("off.bin", "off"));
    let on = run(mk("on.bin", "cusum"));
    assert_eq!(
        off.final_perplexity.to_bits(),
        on.final_perplexity.to_bits(),
        "paged-store numerics must not depend on the detector"
    );
    // The paged run's write traffic is part of the bit-identity story:
    // the exact-LL pass is read-only, so column WRITES are unchanged.
    let (io_off, io_on) = (off.io.unwrap(), on.io.unwrap());
    assert_eq!(io_off.col_writes, io_on.col_writes);
}

/// Hair-trigger monitor tuning: stationary streams alarm within a few
/// batches, so response wiring is exercised end to end without needing
/// a long drifting run through the driver.
fn hair_trigger(cfg: &mut RunConfig, response: &str) {
    cfg.set("drift_detector", "cusum").unwrap();
    cfg.set("drift_response", response).unwrap();
    cfg.set("drift_threshold", "0.01").unwrap();
    // Slack 0 lets the convergence trend itself accumulate into the
    // CUSUM, so a stationary run alarms within a few batches.
    cfg.set("drift_slack", "0").unwrap();
    cfg.set("drift_window", "2").unwrap();
    cfg.set("drift_warmup", "1").unwrap();
}

#[test]
fn drift_driver_applies_each_response_and_records_the_alarms() {
    for response in ["decay-reset", "widen", "grow"] {
        let mut cfg = driver_cfg();
        hair_trigger(&mut cfg, response);
        cfg.set("drift_grow_topics", "4").unwrap();
        let registry = Arc::new(ModelRegistry::new());
        let report = Driver::new(cfg)
            .with_registry(Arc::clone(&registry))
            .train_corpus(&small_corpus())
            .unwrap_or_else(|e| panic!("response {response}: {e}"));
        let events = report.metrics.shift_events();
        assert!(
            !events.is_empty(),
            "hair-trigger run raised no alarms ({response})"
        );
        assert!(report.final_perplexity.is_finite());

        // The alarms land in the CSV (shift_dir/shift_score columns)
        // and round-trip through the header-indexed parser.
        let csv = report.metrics.to_csv();
        assert!(csv.lines().next().unwrap().contains("shift_dir"));
        let parsed = Metrics::parse_csv(&csv).unwrap();
        assert_eq!(parsed.shift_events(), events);

        // ... and in the serving registry's telemetry.
        let (n, last) = registry.shift_telemetry();
        assert_eq!(n, events.len() as u64);
        assert_eq!(last.map(|e| e.batch), events.last().map(|e| e.batch));
    }
}

#[test]
fn drift_detector_only_telemetry_works_under_pipelining() {
    let mut cfg = driver_cfg();
    cfg.pipeline_depth = 2;
    cfg.set("drift_detector", "cusum").unwrap();
    cfg.set("drift_threshold", "0.01").unwrap();
    cfg.set("drift_slack", "0").unwrap();
    cfg.set("drift_window", "2").unwrap();
    cfg.set("drift_warmup", "1").unwrap();
    let report = run(cfg);
    assert!(
        !report.metrics.shift_events().is_empty(),
        "pipelined hair-trigger run recorded no alarms"
    );
}

#[test]
fn drift_unsupported_response_combinations_are_rejected() {
    let corpus = small_corpus();
    let fails = |mutate: &dyn Fn(&mut RunConfig), needle: &str| {
        let mut cfg = driver_cfg();
        mutate(&mut cfg);
        let err = Driver::new(cfg)
            .train_corpus(&corpus)
            .expect_err(needle)
            .to_string();
        assert!(err.contains(needle), "{err:?} missing {needle:?}");
    };
    // A response with no detector is a dead knob, not a silent no-op.
    fails(
        &|c| c.set("drift_response", "widen").unwrap(),
        "needs a detector",
    );
    // Responses mutate the model mid-stream: incompatible with staged
    // pipeline batches.
    fails(
        &|c| {
            hair_trigger(c, "decay-reset");
            c.pipeline_depth = 1;
        },
        "pipeline_depth",
    );
    // Only FOEM implements the response verbs.
    fails(
        &|c| {
            hair_trigger(c, "decay-reset");
            c.algorithm = Algorithm::Scvb;
        },
        "foem",
    );
    // Paged column records pin K at creation: grow needs in-memory.
    let dir = TempDir::new("drift-grow-paged");
    fails(
        &|c| {
            hair_trigger(c, "grow");
            c.store = StoreKind::Paged {
                path: dir.path().join("phi.bin"),
                buffer_bytes: 64 * K * 4,
            };
        },
        "in-memory",
    );
}

#[test]
fn drift_grow_response_extends_k_mid_run() {
    // Direct verb check on the trainer the driver dispatches to: grow
    // re-strides phi/residual stores, extends phisum, and the next
    // batch trains under the larger K.
    let stream = drift_stream(Vec::new(), 6);
    let mut fc = FoemConfig::paper();
    fc.exact_ll = true;
    let mut algo = Foem::new(
        LdaParams::paper_defaults(K),
        InMemoryPhi::zeros(K, W),
        fc,
        9,
    );
    let mut grown = false;
    for mb in stream {
        if mb.index == 3 && !grown {
            assert!(algo.grow_topics(8), "in-memory grow must succeed");
            grown = true;
        }
        let report = algo.process_minibatch(&mb);
        assert!(report.train_ll.is_finite());
    }
    assert!(grown);
    assert_eq!(algo.params.n_topics, K + 8);
    assert_eq!(algo.phisum.len(), K + 8);
}
