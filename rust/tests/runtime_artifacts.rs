//! L3 ↔ L2/L1 composition tests: execute the AOT artifacts through PJRT
//! and cross-check against the native Rust implementations.
//!
//! These need `artifacts/` (run `make artifacts`); they self-skip with a
//! message when it is absent so `cargo test` stays green pre-build.

use foem::runtime::Executor;
use foem::util::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        // Without the pjrt feature the Executor is a metadata-only stub
        // whose run_* methods error by design — skip instead of panicking
        // even when artifacts are present.
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn registry_lists_all_graph_families() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = foem::runtime::registry::Registry::load(&dir).unwrap();
    assert!(reg.len() >= 4);
    let graphs: std::collections::HashSet<&str> =
        reg.iter().map(|a| a.graph.as_str()).collect();
    assert!(graphs.contains("estep"));
    assert!(graphs.contains("predict"));
}

#[test]
fn pjrt_estep_matches_native_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    let meta = exec.estep_variant_for(64).expect("no estep artifact");
    let (b, k) = (meta.b, meta.k);
    let mut rng = Rng::new(7);
    let theta: Vec<f32> = (0..b * k).map(|_| rng.next_f32() * 4.0).collect();
    let phi: Vec<f32> = (0..b * k).map(|_| rng.next_f32() * 2.0).collect();
    let phisum: Vec<f32> = (0..k).map(|_| rng.next_f32() * 50.0 + 1.0).collect();
    let counts: Vec<f32> = (0..b).map(|_| (rng.below(6) + 1) as f32).collect();
    let (am1, bm1, wbm1) = (0.01f32, 0.01, 50.0);
    let out = exec
        .run_estep(&meta.name, &theta, &phi, &phisum, &counts, am1, bm1, wbm1)
        .unwrap();

    let mut mu = vec![0.0f32; k];
    for e in 0..b {
        let z = foem::em::estep_unnormalized(
            &theta[e * k..(e + 1) * k],
            &phi[e * k..(e + 1) * k],
            &phisum,
            am1,
            bm1,
            wbm1,
            &mut mu,
        );
        let inv = 1.0 / z;
        for i in 0..k {
            let want_mu = mu[i] * inv;
            let got_mu = out.mu[e * k + i];
            assert!(
                (got_mu - want_mu).abs() < 1e-4,
                "mu[{e},{i}]: {got_mu} vs {want_mu}"
            );
            let want_xmu = counts[e] * want_mu;
            assert!((out.xmu[e * k + i] - want_xmu).abs() < 1e-3);
        }
    }
}

#[test]
fn pjrt_estep_respects_padding_contract() {
    let Some(dir) = artifacts_dir() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    let meta = exec.estep_variant_for(64).unwrap();
    let (b, k) = (meta.b, meta.k);
    let am1 = 0.01f32;
    let mut rng = Rng::new(8);
    let mut theta: Vec<f32> = (0..b * k).map(|_| rng.next_f32()).collect();
    let phi: Vec<f32> = (0..b * k).map(|_| rng.next_f32()).collect();
    let phisum: Vec<f32> = (0..k).map(|_| rng.next_f32() * 10.0 + 1.0).collect();
    let mut counts: Vec<f32> = (0..b).map(|_| 2.0).collect();
    // Topic-pad the last k/2 columns of every row; count-pad the last
    // quarter of entries.
    for e in 0..b {
        for i in k / 2..k {
            theta[e * k + i] = -am1;
        }
    }
    for c in counts.iter_mut().skip(3 * b / 4) {
        *c = 0.0;
    }
    let out = exec
        .run_estep(&meta.name, &theta, &phi, &phisum, &counts, am1, 0.01, 20.0)
        .unwrap();
    for e in 0..b {
        for i in k / 2..k {
            assert_eq!(out.mu[e * k + i], 0.0, "padded topic leaked");
        }
        let row_sum: f32 = out.mu[e * k..(e + 1) * k].iter().sum();
        assert!((row_sum - 1.0).abs() < 1e-4);
    }
    for e in 3 * b / 4..b {
        for i in 0..k {
            assert_eq!(out.xmu[e * k + i], 0.0, "padded entry leaked");
        }
    }
}

#[test]
fn pjrt_predict_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    let meta = exec
        .registry()
        .iter()
        .find(|m| m.graph == "predict")
        .unwrap()
        .clone();
    let (b, k) = (meta.b, meta.k);
    let mut rng = Rng::new(9);
    let theta: Vec<f32> = (0..b * k).map(|_| rng.next_f32() * 4.0).collect();
    let theta_tot: Vec<f32> = (0..b)
        .map(|e| theta[e * k..(e + 1) * k].iter().sum())
        .collect();
    let phi: Vec<f32> = (0..b * k).map(|_| rng.next_f32() * 2.0).collect();
    let phisum: Vec<f32> = (0..k).map(|_| rng.next_f32() * 50.0 + 1.0).collect();
    let counts: Vec<f32> = (0..b).map(|_| (rng.below(4)) as f32).collect();
    let (am1, bm1) = (0.01f32, 0.01f32);
    let wbm1 = 100.0f32;
    let kam1 = k as f32 * am1;
    let (ll, cnt) = exec
        .run_predict(
            &meta.name,
            &theta,
            &theta_tot,
            &phi,
            &phisum,
            &counts,
            [am1, bm1, wbm1, kam1],
        )
        .unwrap();

    // Native reference.
    let mut want_ll = 0.0f64;
    let mut want_cnt = 0.0f64;
    for e in 0..b {
        let mut p = 0.0f32;
        for i in 0..k {
            p += (theta[e * k + i] + am1) / (theta_tot[e] + kam1)
                * (phi[e * k + i] + bm1)
                / (phisum[i] + wbm1);
        }
        want_ll += counts[e] as f64 * (p.max(1e-30) as f64).ln();
        want_cnt += counts[e] as f64;
    }
    assert!(
        (ll as f64 - want_ll).abs() < want_ll.abs() * 1e-3 + 1e-2,
        "{ll} vs {want_ll}"
    );
    assert!((cnt as f64 - want_cnt).abs() < 1e-3);
}

#[test]
fn pjrt_sem_minibatch_graph_runs_and_conserves_mass() {
    let Some(dir) = artifacts_dir() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    let Some(meta) = exec
        .registry()
        .iter()
        .find(|m| m.graph == "sem")
        .cloned()
    else {
        eprintln!("skipping: no sem artifact (aot --skip-sem?)");
        return;
    };
    let (b, k, ds, ws) = (meta.b, meta.k, meta.ds, meta.ws);
    let mut rng = Rng::new(10);
    // Random minibatch: real entries in the first half, padding after.
    let real = b / 2;
    let mut doc_ids = vec![(ds - 1) as i32; b];
    let mut word_ids = vec![(ws - 1) as i32; b];
    let mut counts = vec![0.0f32; b];
    for e in 0..real {
        doc_ids[e] = rng.below(ds - 1) as i32;
        word_ids[e] = rng.below(ws - 1) as i32;
        counts[e] = (rng.below(3) + 1) as f32;
    }
    // theta0 consistent with counts (hard init on topic 0).
    let mut theta0 = vec![0.0f32; ds * k];
    for e in 0..real {
        theta0[doc_ids[e] as usize * k] += counts[e];
    }
    let phi_local: Vec<f32> = (0..ws * k).map(|_| rng.next_f32()).collect();
    let phisum: Vec<f32> = (0..k).map(|_| rng.next_f32() * 100.0 + 10.0).collect();
    let (theta, phi_delta, ll) = exec
        .run_sem(
            &meta.name,
            &doc_ids,
            &word_ids,
            &counts,
            &theta0,
            &phi_local,
            &phisum,
            [0.01, 0.01, 50.0],
        )
        .unwrap();
    let total: f32 = counts.iter().sum();
    let theta_mass: f32 = theta.iter().sum();
    let delta_mass: f32 = phi_delta.iter().sum();
    assert!(
        (theta_mass - total).abs() < total * 1e-3,
        "{theta_mass} vs {total}"
    );
    assert!((delta_mass - total).abs() < total * 1e-3);
    assert!(ll.is_finite());
}
