//! Equivalence guards for the vocabulary-sharded fleet
//! (`shard::{ShardedPhi, PhiShardOwner}`, `rust/DESIGN.md` §14),
//! driven entirely through the public API:
//!
//! * N=1 sharding is BIT-identical to the unsharded paged trainer —
//!   trainer state, exported phi, held-out perplexity, and the `IoStats`
//!   counters of the three-phase executor path (where the facade never
//!   adds or removes a store access — every verb routes 1:1).
//! * N>1 sharding is content-identical: same state/phi/perplexity bits
//!   (only buffer dynamics may differ, since each shard has its own hot
//!   buffer), and the logical access counts still agree.
//! * The scatter-gather serve router: per-shard view parts merged via
//!   `EvalPhiView::merge_shards` / `ModelRegistry::publish_distributed`
//!   are bit-identical to the single `eval_view`, and a fold-in against
//!   the merged snapshot is bit-identical to the unsharded serve path.
//! * Kill-and-resume of a sharded WAL-armed run (`std::mem::forget`,
//!   the userspace `kill -9`) recovers through `Foem::sharded_resume`
//!   to a bit-identical final state.
//! * Resume validation: a changed `--shards` is rejected both by the
//!   checkpoint fingerprint and by the on-disk shard layout check.

use foem::baselines::OnlineLda;
use foem::coordinator::checkpoint::{self, TrainerCheckpoint};
use foem::coordinator::config::{Algorithm, RunConfig, StoreKind};
use foem::coordinator::driver::Driver;
use foem::em::foem::{Foem, FoemConfig, FoemTrainState};
use foem::em::infer::{self, FoldInConfig};
use foem::em::{EvalPhiView, PhiAccess};
use foem::serve::ModelRegistry;
use foem::shard::ShardedPhi;
use foem::store::paged::PagedPhi;
use foem::store::{Codec, PhiColumnStore};
use foem::stream::{CorpusStream, StreamConfig};
use foem::util::TempDir;
use foem::LdaParams;

const K: usize = 6;
const SEED: u64 = 7;
const BUF: usize = 32 * K * 4;

fn corpus() -> foem::corpus::Corpus {
    let mut cfg = foem::corpus::synthetic::SyntheticConfig::small();
    cfg.n_docs = 250;
    foem::corpus::synthetic::generate(&cfg, 31)
}

/// 200 train docs / 50 per batch = exactly 4 batches per pass.
fn stream_cfg() -> StreamConfig {
    StreamConfig { minibatch_docs: 50, ..Default::default() }
}

fn foem_cfg() -> FoemConfig {
    let mut fc = FoemConfig::paper();
    // Small hot set: columns evict mid-batch on every shard, so the
    // equivalence below covers the paging machinery, not just buffers.
    fc.hot_words = 8;
    // Drive the three-phase executor path (snapshot / reduce / explicit
    // apply verbs) — the production path for sharded runs, and the one
    // whose store accesses route 1:1 through the fleet. The
    // single-worker serial path's closure access (`with_column`) is
    // emulated as load + store by the facade: still content-identical,
    // but its IoStats legitimately differ, so it cannot carry the
    // bit-identity assertions below.
    fc.n_workers = 2;
    fc
}

fn mk_unsharded(dir: &TempDir, n_words: usize) -> Foem<PagedPhi> {
    Foem::paged_create(
        LdaParams::paper_defaults(K),
        &dir.path().join("phi.bin"),
        n_words,
        BUF,
        foem_cfg(),
        SEED,
    )
    .unwrap()
}

fn mk_sharded(
    dir: &TempDir,
    n_shards: usize,
    n_words: usize,
) -> Foem<ShardedPhi> {
    Foem::sharded_create_with_codec(
        LdaParams::paper_defaults(K),
        &dir.path().join("phi.bin"),
        n_shards,
        n_words,
        // N shards get N× the single buffer so each shard's slice
        // matches the unsharded budget split at every N.
        BUF * n_shards,
        foem_cfg(),
        SEED,
        Codec::Auto,
    )
    .unwrap()
}

fn ppx_bits<S: PhiColumnStore>(
    algo: &mut Foem<S>,
    test: &foem::corpus::Corpus,
) -> u64 {
    let proto = foem::eval::EvalProtocol {
        fold_in_iters: 20,
        seed: 0,
        ..Default::default()
    };
    algo.eval_perplexity(&test.docs, &proto).to_bits()
}

fn train_all<S: PhiColumnStore>(
    algo: &mut Foem<S>,
    train: &foem::corpus::Corpus,
) {
    for mb in CorpusStream::new(train, stream_cfg()) {
        algo.process_minibatch(&mb);
    }
}

#[test]
fn shard_n1_bit_identical_to_unsharded() {
    let c = corpus();
    let (train, test) = c.split(50, 1);
    let udir = TempDir::new("shard-n1-u");
    let sdir = TempDir::new("shard-n1-s");
    let mut u = mk_unsharded(&udir, train.n_words());
    let mut s = mk_sharded(&sdir, 1, train.n_words());
    train_all(&mut u, &train);
    train_all(&mut s, &train);

    // The one-owner fleet executes the exact same store calls in the
    // exact same order, so even the buffer-dynamics counters agree.
    assert_eq!(
        u.store.io_stats(),
        s.store.io_stats(),
        "N=1 phi-stream IoStats diverged from the unsharded store"
    );
    assert_eq!(
        u.res_store.io_stats(),
        s.res_store.io_stats(),
        "N=1 residual-stream IoStats diverged"
    );
    assert_eq!(u.export_train_state(), s.export_train_state());
    assert_eq!(u.export_phi().raw(), s.export_phi().raw());
    assert_eq!(ppx_bits(&mut u, &test), ppx_bits(&mut s, &test));
}

#[test]
fn shard_n4_content_identical_to_unsharded() {
    let c = corpus();
    let (train, test) = c.split(50, 1);
    let udir = TempDir::new("shard-n4-u");
    let sdir = TempDir::new("shard-n4-s");
    let mut u = mk_unsharded(&udir, train.n_words());
    let mut s = mk_sharded(&sdir, 4, train.n_words());
    train_all(&mut u, &train);
    train_all(&mut s, &train);

    // Content bit-identity at any N: every column sees the same delta
    // sequence on some owner, and all resident EM state stays in the
    // coordinator. (Acceptance only demands 2% perplexity tolerance at
    // N=4; the design delivers exact bits, so pin exact bits.)
    assert_eq!(u.export_train_state(), s.export_train_state());
    assert_eq!(u.export_phi().raw(), s.export_phi().raw());
    assert_eq!(ppx_bits(&mut u, &test), ppx_bits(&mut s, &test));

    // Buffer dynamics (hits/misses, write-behind) legitimately shift
    // across per-shard buffers, but the logical access counts are the
    // same store calls and must sum exactly.
    let (ui, si) = (u.store.io_stats(), s.store.io_stats());
    assert_eq!(ui.col_reads, si.col_reads, "phi logical reads diverged");
    assert_eq!(ui.col_writes, si.col_writes, "phi logical writes diverged");
    let (ur, sr) = (u.res_store.io_stats(), s.res_store.io_stats());
    assert_eq!(ur.col_reads, sr.col_reads, "res logical reads diverged");
    assert_eq!(ur.col_writes, sr.col_writes, "res logical writes diverged");
}

#[test]
fn shard_scatter_gather_serve_matches_single_fold_in() {
    let c = corpus();
    let (train, test) = c.split(50, 1);
    let udir = TempDir::new("shard-serve-u");
    let sdir = TempDir::new("shard-serve-s");
    let mut u = mk_unsharded(&udir, train.n_words());
    let mut s = mk_sharded(&sdir, 3, train.n_words());
    train_all(&mut u, &train);
    train_all(&mut s, &train);

    let words: Vec<u32> = (0..train.n_words() as u32).collect();
    let single = u.eval_view(&words);

    // Scatter: per-shard parts; gather: one distributed snapshot.
    let reg = ModelRegistry::new();
    let snap =
        reg.publish_distributed(s.shard_eval_views(&words), s.eval_params());
    assert_eq!(snap.epoch(), 1);

    // The merged view is bit-identical to the unsharded single-store
    // view — same columns, same totals, same vocabulary extent.
    assert_eq!(snap.n_words(), single.n_words());
    assert_eq!(snap.phisum(), single.phisum());
    for w in 0..train.n_words() {
        assert_eq!(snap.word(w), single.word(w), "column {w} diverged");
    }

    // ... and so is the direct facade view (gather via the plain
    // snapshot path rather than merge_shards).
    let facade = s.eval_view(&words);
    assert_eq!(facade.phisum(), single.phisum());
    for w in 0..train.n_words() {
        assert_eq!(facade.word(w), single.word(w), "facade column {w}");
    }

    // End to end: folding test documents in against the distributed
    // snapshot is bit-identical to the unsharded serve path.
    let params = LdaParams::paper_defaults(K);
    let fold = FoldInConfig::dense(10);
    let via_snap = infer::fold_in(&*snap, &params, &test.docs, &fold, 0);
    let via_single = infer::fold_in(&single, &params, &test.docs, &fold, 0);
    assert_eq!(via_snap.raw(), via_single.raw(), "served theta diverged");

    let merged_again =
        EvalPhiView::merge_shards(s.shard_eval_views(&words));
    let via_merge = infer::fold_in(&merged_again, &params, &test.docs, &fold, 0);
    assert_eq!(via_merge.raw(), via_single.raw());
}

#[test]
fn shard_kill_and_resume_matches_uninterrupted_run() {
    const N: usize = 3;
    let c = corpus();
    let (train, test) = c.split(50, 1);

    // Uninterrupted sharded reference (WAL off).
    let rdir = TempDir::new("shard-kill-ref");
    let mut a = mk_sharded(&rdir, N, train.n_words());
    train_all(&mut a, &train);
    let want_state = a.export_train_state();
    let want_phi = a.export_phi().raw().to_vec();
    let want_ppx = ppx_bits(&mut a, &test);

    // WAL-armed run: coordinator checkpoint after batch 2, hard kill
    // after batch 3 — batch 3 lives ONLY in the per-shard WALs.
    let dir = TempDir::new("shard-kill");
    let ckpt_dir = dir.path().join("ckpt");
    let mut b = mk_sharded(&dir, N, train.n_words());
    b.enable_wal().unwrap();
    let mut done = 0usize;
    for mb in CorpusStream::new(&train, stream_cfg()) {
        b.process_minibatch(&mb);
        done += 1;
        if done == 2 {
            OnlineLda::checkpoint(&mut b).unwrap();
            checkpoint::save(
                &ckpt_dir,
                &TrainerCheckpoint {
                    fingerprint: 0xfeed,
                    batch_cursor: done as u64,
                    epoch: 0,
                    state: b.export_train_state(),
                },
            )
            .unwrap();
            OnlineLda::truncate_wal(&mut b).unwrap();
        }
        if done == 3 {
            break;
        }
    }
    // kill -9: no Drop, no flush, no fleet shutdown, no WAL truncation.
    std::mem::forget(b);

    let ckpt = checkpoint::load(&ckpt_dir).unwrap().expect("checkpoint");
    let (mut r, last) = Foem::sharded_resume(
        LdaParams::paper_defaults(K),
        &dir.path().join("phi.bin"),
        N,
        BUF * N,
        foem_cfg(),
        &ckpt.state,
    )
    .unwrap();
    assert_eq!(last, 3, "replay recovered the wrong global batch cursor");
    for mb in CorpusStream::new(&train, stream_cfg()).skip(last as usize) {
        r.process_minibatch(&mb);
    }
    assert_eq!(r.export_train_state(), want_state, "state diverged");
    assert_eq!(r.export_phi().raw(), &want_phi[..], "phi diverged");
    assert_eq!(ppx_bits(&mut r, &test), want_ppx, "perplexity diverged");
}

#[test]
fn shard_resume_rejects_mismatched_layout() {
    let c = corpus();
    let (train, _) = c.split(50, 1);
    let dir = TempDir::new("shard-layout");
    let mut t = mk_sharded(&dir, 2, train.n_words());
    let state: FoemTrainState = t.export_train_state();
    drop(t); // Clean fleet shutdown; the shard files stay on disk.

    for wrong in [1usize, 3] {
        let err = Foem::sharded_resume(
            LdaParams::paper_defaults(K),
            &dir.path().join("phi.bin"),
            wrong,
            BUF * wrong,
            foem_cfg(),
            &state,
        )
        .err()
        .unwrap_or_else(|| panic!("--shards {wrong} must be rejected"));
        assert!(
            err.to_string().contains("--shards"),
            "unhelpful layout error: {err}"
        );
    }
}

#[test]
fn shard_count_is_part_of_checkpoint_fingerprint() {
    let mut cfg = RunConfig { n_shards: 2, ..RunConfig::default() };
    let fp2 = checkpoint::config_fingerprint(&cfg);
    cfg.n_shards = 4;
    let fp4 = checkpoint::config_fingerprint(&cfg);
    assert_ne!(fp2, fp4, "--resume must reject a changed --shards");
    // Cadence knobs still don't pin the fingerprint.
    cfg.eval_every = 17;
    cfg.verbose = true;
    assert_eq!(checkpoint::config_fingerprint(&cfg), fp4);
}

#[test]
fn shard_driver_run_matches_unsharded_driver_run() {
    let c = foem::corpus::synthetic::generate(
        &foem::corpus::synthetic::SyntheticConfig::small(),
        92,
    );
    let run = |n_shards: usize, pipeline_depth: usize| {
        let dir = TempDir::new("shard-driver");
        let cfg = RunConfig {
            algorithm: Algorithm::Foem,
            n_topics: K,
            minibatch_docs: 64,
            n_shards,
            n_workers: 2,
            pipeline_depth,
            store: StoreKind::Paged {
                path: dir.path().join("phi.bin"),
                buffer_bytes: 64 << 10,
            },
            ..RunConfig::default()
        };
        let mut d = Driver::new(cfg);
        d.train_corpus(&c).unwrap()
    };
    let plain = run(0, 0);
    let sharded = run(2, 0);
    let sharded_pipelined = run(2, 2);
    assert_eq!(
        plain.final_perplexity.to_bits(),
        sharded.final_perplexity.to_bits(),
        "--shards 2 diverged from the single-store driver run"
    );
    assert_eq!(
        plain.final_perplexity.to_bits(),
        sharded_pipelined.final_perplexity.to_bits(),
        "--shards 2 --pipeline-depth 2 diverged"
    );
    // Truthful telemetry: the report's IoStats is the fleet-wide sum.
    let (pio, sio) = (plain.io.unwrap(), sharded.io.unwrap());
    assert_eq!(pio.col_reads, sio.col_reads);
    assert_eq!(pio.col_writes, sio.col_writes);
}
