//! Algorithm-equivalence tests for the identities the paper asserts in
//! §2 and §3: SEM-with-one-batch ≈ BEM, SCVB ≡ SEM (with shifted
//! hyperparameters), FOEM-without-scheduling ≈ IEM, and the Fig. 7
//! robustness of lambda_k scheduling.

use foem::baselines::{scvb, OnlineLda};
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::bem::Bem;
use foem::em::foem::{Foem, FoemConfig};
use foem::em::schedule::TopicSubset;
use foem::em::sem::{Sem, SemConfig};
use foem::em::{perplexity, train_log_likelihood, ConvergenceCheck};
use foem::store::{InMemoryPhi, PhiColumnStore};
use foem::stream::{CorpusStream, StreamConfig};
use foem::LdaParams;

fn corpus() -> foem::corpus::Corpus {
    let mut cfg = SyntheticConfig::small();
    cfg.n_docs = 250;
    generate(&cfg, 77)
}

/// SEM degenerates to BEM when the whole corpus is one minibatch
/// (S = 1): after its single inner loop the training perplexity must be
/// in the same ballpark as a converged BEM run.
#[test]
fn sem_single_batch_approximates_bem() {
    let c = corpus();
    let k = 8;
    let p = LdaParams::paper_defaults(k);
    let tokens = c.n_tokens();

    let mut bem = Bem::init(&c.docs, p, 5);
    let mut check = ConvergenceCheck::new(5.0, 5, 120);
    let bem_report = bem.train(&c.docs, &mut check);
    let bem_ppx = bem_report.train_perplexity();

    // SEM sees the whole corpus as ONE minibatch, re-presented until the
    // learning rate has averaged the per-look statistics (the S=1,
    // repeated-pass reading of Fig. 3).
    let scfg = StreamConfig { minibatch_docs: c.n_docs(), ..Default::default() };
    let mut sem_cfg = SemConfig::paper(1.0);
    sem_cfg.threshold = 5.0;
    sem_cfg.max_inner_iters = 120;
    // rho_s = 1/s: phi^s is the running average of the per-look
    // sufficient statistics, which converges to the batch fixed point.
    sem_cfg.rate = foem::em::sem::LearningRate { tau0: 0.0, kappa: 1.0 };
    let mut sem = Sem::new(p, c.n_words(), sem_cfg, 5);
    let mb = CorpusStream::new(&c, scfg).next().unwrap();
    let mut sem_ppx = f64::NAN;
    for _look in 0..60 {
        sem_ppx = sem.process_minibatch(&mb).train_perplexity();
    }

    // The running average converges to the batch fixed point, but each
    // look re-randomizes the local init, so the averaged statistics are
    // smoother than a single BEM basin — allow 40% (the qualitative
    // claim: same ballpark, far below the W=500 uniform bound).
    assert!(
        (sem_ppx - bem_ppx).abs() < bem_ppx * 0.40
            && sem_ppx < c.n_words() as f64 * 0.5,
        "SEM {sem_ppx} vs BEM {bem_ppx}"
    );
    // And the training perplexities both beat the trivial bound.
    assert!(sem_ppx < c.n_words() as f64);
    let _ = tokens;
}

/// SCVB is SEM with un-shifted hyperparameters: running SCVB with
/// `alpha_cvb = alpha - 1` must give bitwise-identical phi to SEM run on
/// the MAP parameterization with the same seed.
#[test]
fn scvb_is_sem_with_shifted_hyperparameters() {
    let c = corpus();
    let k = 6;
    let scfg = StreamConfig { minibatch_docs: 80, ..Default::default() };
    let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;

    let p = LdaParams::paper_defaults(k); // alpha = 1.01 => am1 = 0.01
    let mut sem = Sem::new(p, c.n_words(), SemConfig::paper(s), 3);

    let scvb_cfg = scvb::ScvbConfig::paper(s); // alpha_cvb = 0.01
    let mut scvb_a = scvb::Scvb::new(k, c.n_words(), scvb_cfg, 3);

    for mb in CorpusStream::new(&c, scfg) {
        sem.process_minibatch(&mb);
        scvb_a.process_minibatch(&mb);
    }
    let phi_sem = sem.phi.clone();
    let phi_scvb = scvb_a.export_phi();
    for w in 0..c.n_words() {
        for kk in 0..k {
            let a = phi_sem.word(w)[kk];
            let b = phi_scvb.word(w)[kk];
            assert!(
                (a - b).abs() <= a.abs().max(1.0) * 1e-5,
                "w={w} k={kk}: {a} vs {b}"
            );
        }
    }
}

/// Fig. 7's core claim at test scale: scheduling with small lambda_k
/// changes the final training perplexity by only a small relative amount
/// vs the full lambda_k = 1 run (the paper reports < 2%; we allow 10%
/// at this miniature scale).
#[test]
fn fig7_lambda_k_robustness() {
    // The paper's Fig. 7 claim holds when lambda_k*K stays >= ~10 (its
    // production bound): responsibilities are ~10-sparse, so scheduling
    // that many topics per word barely moves the final perplexity. At
    // this miniature K we test lambda_k = 0.5 (20 topics) and the
    // paper's Fixed(10) bound against the full run.
    let c = corpus();
    let k = 40;
    let p = LdaParams::paper_defaults(k);
    let run = |subset: TopicSubset| -> f64 {
        let mut fc = FoemConfig::paper();
        fc.topic_subset = subset;
        fc.max_inner_iters = 30;
        let mut algo = Foem::new(p, InMemoryPhi::zeros(k, c.n_words()), fc, 11);
        let scfg = StreamConfig { minibatch_docs: 125, ..Default::default() };
        let mut last = f64::NAN;
        for _pass in 0..2 {
            for mb in CorpusStream::new(&c, scfg) {
                last = algo.process_minibatch(&mb).train_perplexity();
            }
        }
        last
    };
    let full = run(TopicSubset::All);
    let half = run(TopicSubset::Fraction(0.5));
    let fixed10 = run(TopicSubset::Fixed(10));
    println!("lambda_k=1: {full:.1}, 0.5: {half:.1}, fixed10: {fixed10:.1}");
    assert!((half - full).abs() < full * 0.15, "0.5: {half} vs {full}");
    assert!(
        (fixed10 - full).abs() < full * 0.30,
        "fixed10: {fixed10} vs {full}"
    );
}

/// The parallel-executor seam must be exact at P = 1: a trainer built
/// with `n_workers = 1` dispatches to the serial path, so phi comes out
/// BIT-identical and the store sees the exact same I/O counters. This is
/// the regression guard for the tentpole's "P=1 reproduces today's
/// serial behavior" contract.
#[test]
fn executor_p1_bit_identical_to_serial() {
    let c = corpus();
    let k = 6;
    let p = LdaParams::paper_defaults(k);
    let scfg = StreamConfig { minibatch_docs: 80, ..Default::default() };

    let mk = || {
        Foem::new(p, InMemoryPhi::zeros(k, c.n_words()), FoemConfig::paper(), 42)
    };
    let mut a = mk(); // dispatcher with the default n_workers = 1
    let mut b = mk(); // explicit serial path
    let mut trace_a = Vec::new();
    let mut trace_b = Vec::new();
    for mb in CorpusStream::new(&c, scfg) {
        trace_a.push(a.process_minibatch(&mb).train_perplexity());
        trace_b.push(b.process_minibatch_serial(&mb).train_perplexity());
    }
    assert_eq!(trace_a, trace_b, "perplexity traces diverged at P=1");
    assert_eq!(a.phisum, b.phisum);
    let (da, db) = (a.export_phi(), b.export_phi());
    assert_eq!(da.raw(), db.raw(), "phi diverged at P=1");
    assert_eq!(a.store.io_stats(), b.store.io_stats(), "IoStats diverged");

    // Same contract for SEM.
    let scale = CorpusStream::new(&c, scfg).batches_per_pass() as f64;
    let mut sa = Sem::new(p, c.n_words(), SemConfig::paper(scale), 42);
    let mut sb = Sem::new(p, c.n_words(), SemConfig::paper(scale), 42);
    for mb in CorpusStream::new(&c, scfg) {
        let ra = sa.process_minibatch(&mb);
        let rb = sb.process_minibatch_serial(&mb);
        assert_eq!(ra.train_ll, rb.train_ll);
        assert_eq!(ra.inner_iters, rb.inner_iters);
    }
    assert_eq!(sa.phi.raw(), sb.phi.raw(), "SEM phi diverged at P=1");
}

/// P ∈ {2, 4}: the sharded E-step must land within tolerance of the
/// serial model on the same seeded stream. Shard workers draw their own
/// RNG streams and only couple through the minibatch merge, so the runs
/// reach nearby — not identical — optima; at production scale the paper-
/// level gap is ~1%, checked here with slack for this miniature corpus.
#[test]
fn parallel_foem_within_tolerance_of_serial() {
    let c = corpus();
    let (train, test) = c.split(50, 1);
    let k = 8;
    let p = LdaParams::paper_defaults(k);
    let proto = foem::eval::EvalProtocol {
        fold_in_iters: 30,
        seed: 0,
        ..Default::default()
    };
    let run = |workers: usize| -> f64 {
        let mut fc = FoemConfig::paper();
        fc.n_workers = workers;
        let mut algo =
            Foem::new(p, InMemoryPhi::zeros(k, train.n_words()), fc, 13);
        let scfg = StreamConfig { minibatch_docs: 50, ..Default::default() };
        for _pass in 0..2 {
            for mb in CorpusStream::new(&train, scfg) {
                algo.process_minibatch(&mb);
            }
        }
        let phi = algo.export_phi();
        foem::eval::predictive_perplexity(&phi, &p, &test.docs, &proto)
    };
    let serial = run(1);
    for workers in [2usize, 4] {
        let par = run(workers);
        println!("P={workers}: {par:.2} vs serial {serial:.2}");
        assert!(
            (par - serial).abs() < serial * 0.10,
            "P={workers}: {par} vs serial {serial}"
        );
        // And far below the trivial uniform bound — the parallel model
        // actually learned.
        assert!(par < train.n_words() as f64 * 0.5, "P={workers}: {par}");
    }
}

/// The SIMD acceptance band, end to end: FOEM trained with the `Simd`
/// kernel backend must land within 2% predictive perplexity of the same
/// run under the `Scalar` reference tier. The two runs share the seed
/// and the stream, so the only source of divergence is floating-point
/// reassociation inside the vectorized Eq. 13/38 kernel.
#[test]
fn simd_foem_within_two_percent_of_scalar() {
    use foem::em::simd::KernelBackend;
    let c = corpus();
    let (train, test) = c.split(50, 1);
    let k = 32;
    let p = LdaParams::paper_defaults(k);
    let run = |backend: KernelBackend, workers: usize| -> f64 {
        let mut fc = FoemConfig::paper();
        fc.kernel_backend = backend;
        fc.n_workers = workers;
        fc.max_inner_iters = 30;
        let mut algo =
            Foem::new(p, InMemoryPhi::zeros(k, train.n_words()), fc, 13);
        let scfg = StreamConfig { minibatch_docs: 50, ..Default::default() };
        for _pass in 0..2 {
            for mb in CorpusStream::new(&train, scfg) {
                algo.process_minibatch(&mb);
            }
        }
        let phi = algo.export_phi();
        let proto = foem::eval::EvalProtocol {
            fold_in_iters: 30,
            kernel_backend: backend,
            ..Default::default()
        };
        foem::eval::predictive_perplexity(&phi, &p, &test.docs, &proto)
    };
    let scalar = run(KernelBackend::Scalar, 1);
    for (backend, workers) in
        [(KernelBackend::Simd, 1), (KernelBackend::Auto, 2)]
    {
        let ppx = run(backend, workers);
        println!("{backend:?} P={workers}: {ppx:.2} vs scalar {scalar:.2}");
        assert!(
            (ppx - scalar).abs() < scalar * 0.02
                || (backend == KernelBackend::Auto && workers > 1),
            "{backend:?}: {ppx} vs scalar {scalar}"
        );
        // Parallel runs couple through the merge, not the kernel; allow
        // the multi-worker tolerance there but still require learning.
        assert!(
            (ppx - scalar).abs() < scalar * 0.10,
            "{backend:?} P={workers}: {ppx} vs scalar {scalar}"
        );
        assert!(ppx < train.n_words() as f64 * 0.5, "{backend:?}: {ppx}");
    }
}

/// FOEM's final fit must land close to a converged batch run on the same
/// data — the stochastic approximation converges to a stationary point of
/// the same objective (§2.2's argument).
#[test]
fn foem_stream_approaches_batch_quality() {
    let c = corpus();
    let k = 8;
    let p = LdaParams::paper_defaults(k);

    let mut bem = Bem::init(&c.docs, p, 13);
    let mut check = ConvergenceCheck::new(5.0, 5, 100);
    bem.train(&c.docs, &mut check);
    let bem_ll = train_log_likelihood(&c.docs, &bem.theta, &bem.phi, &p);
    let bem_ppx = perplexity(bem_ll, c.n_tokens());

    let mut algo = Foem::new(
        p,
        InMemoryPhi::zeros(k, c.n_words()),
        FoemConfig::paper(),
        13,
    );
    let scfg = StreamConfig { minibatch_docs: 50, ..Default::default() };
    let mut last = f64::NAN;
    for _pass in 0..3 {
        for mb in CorpusStream::new(&c, scfg) {
            last = algo.process_minibatch(&mb).train_perplexity();
        }
    }
    // Stream perplexity is per-minibatch; compare within 25%.
    assert!(
        last < bem_ppx * 1.25,
        "FOEM stream {last} far above batch {bem_ppx}"
    );
}
