//! Equivalence guards for the pipelined three-phase trainer seam
//! (`exec::pipeline`, `rust/DESIGN.md` §7):
//!
//! * `pipeline_depth = 0` bypasses the pipeline entirely and must be
//!   BIT-identical to the plain trainer loop — numerics, perplexity
//!   trace, and `IoStats` — for FOEM (in-memory and paged) and SEM.
//!   This extends PR 1's `n_workers = 1` invariant to the new seam.
//! * `pipeline_depth >= 1` changes only staleness (a batch stages
//!   against the store state with up to `depth` applies still pending):
//!   quality must match depth 0 within tolerance, the Eq. 33 mass
//!   invariant must hold exactly, and on a paged store the compute
//!   path's blocking `buffer_misses` must drop, replaced by prefetch
//!   hits, with dirty columns flushed off the critical path.

use foem::em::foem::{Foem, FoemConfig};
use foem::em::sem::{Sem, SemConfig};
use foem::exec::pipeline::Pipeline;
use foem::store::{InMemoryPhi, IoStats, PhiColumnStore};
use foem::stream::{CorpusStream, StreamConfig};
use foem::LdaParams;

fn corpus() -> foem::corpus::Corpus {
    let mut cfg = foem::corpus::synthetic::SyntheticConfig::small();
    cfg.n_docs = 250;
    foem::corpus::synthetic::generate(&cfg, 31)
}

#[test]
fn depth0_bypass_bit_identical_foem_in_memory() {
    let c = corpus();
    let k = 6;
    let p = LdaParams::paper_defaults(k);
    let scfg = StreamConfig { minibatch_docs: 80, ..Default::default() };
    let mk = || {
        Foem::new(p, InMemoryPhi::zeros(k, c.n_words()), FoemConfig::paper(), 42)
    };

    let mut piped = mk();
    let mut reports_piped = Vec::new();
    Pipeline::new(0)
        .run(&mut piped, CorpusStream::new(&c, scfg), |_, _, r| {
            reports_piped.push(*r);
            Ok(())
        })
        .unwrap();

    let mut plain = mk();
    let reports_plain: Vec<_> = CorpusStream::new(&c, scfg)
        .map(|mb| plain.process_minibatch(&mb))
        .collect();

    assert_eq!(reports_piped.len(), reports_plain.len());
    for (a, b) in reports_piped.iter().zip(&reports_plain) {
        assert_eq!(a.train_ll, b.train_ll, "perplexity trace diverged");
        assert_eq!(a.inner_iters, b.inner_iters);
    }
    assert_eq!(piped.phisum, plain.phisum);
    assert_eq!(piped.export_phi().raw(), plain.export_phi().raw());
    assert_eq!(piped.store.io_stats(), plain.store.io_stats());
}

#[test]
fn depth0_bypass_bit_identical_foem_paged() {
    let dir = foem::util::TempDir::new("d0-paged");
    let c = corpus();
    let k = 6;
    let p = LdaParams::paper_defaults(k);
    let scfg = StreamConfig { minibatch_docs: 80, ..Default::default() };
    let mk = |name: &str| {
        let mut fc = FoemConfig::paper();
        fc.hot_words = 16;
        Foem::paged_create(
            p,
            &dir.path().join(name),
            c.n_words(),
            32 * k * 4,
            fc,
            42,
        )
        .unwrap()
    };

    let mut piped = mk("a.bin");
    let mut trace_piped = Vec::new();
    Pipeline::new(0)
        .run(&mut piped, CorpusStream::new(&c, scfg), |_, _, r| {
            trace_piped.push(r.train_ll);
            Ok(())
        })
        .unwrap();

    let mut plain = mk("b.bin");
    let trace_plain: Vec<f64> = CorpusStream::new(&c, scfg)
        .map(|mb| plain.process_minibatch(&mb).train_ll)
        .collect();

    assert_eq!(trace_piped, trace_plain, "perplexity trace diverged");
    assert_eq!(piped.phisum, plain.phisum);
    assert_eq!(piped.export_phi().raw(), plain.export_phi().raw());
    // The full IoStats must match, including the zero overlapped-I/O
    // counters: depth 0 never switches the stores into async mode.
    let io = piped.store.io_stats();
    assert_eq!(io, plain.store.io_stats(), "IoStats diverged at depth 0");
    assert_eq!(io.prefetched_cols, 0);
    assert_eq!(io.prefetch_hits, 0);
    assert_eq!(io.wb_writes, 0);
    assert_eq!(
        piped.res_store.io_stats(),
        plain.res_store.io_stats(),
        "residual-stream IoStats diverged at depth 0"
    );
}

#[test]
fn depth0_bypass_bit_identical_sem() {
    let c = corpus();
    let k = 8;
    let p = LdaParams::paper_defaults(k);
    let scfg = StreamConfig { minibatch_docs: 80, ..Default::default() };
    let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;

    let mut piped = Sem::new(p, c.n_words(), SemConfig::paper(s), 42);
    let mut trace_piped = Vec::new();
    Pipeline::new(0)
        .run(&mut piped, CorpusStream::new(&c, scfg), |_, _, r| {
            trace_piped.push((r.train_ll, r.inner_iters));
            Ok(())
        })
        .unwrap();

    let mut plain = Sem::new(p, c.n_words(), SemConfig::paper(s), 42);
    let trace_plain: Vec<(f64, usize)> = CorpusStream::new(&c, scfg)
        .map(|mb| {
            let r = plain.process_minibatch(&mb);
            (r.train_ll, r.inner_iters)
        })
        .collect();

    assert_eq!(trace_piped, trace_plain, "SEM trace diverged at depth 0");
    assert_eq!(piped.phi.raw(), plain.phi.raw(), "SEM phi diverged");
}

/// Run a paged FOEM stream at the given pipeline depth; returns
/// (predictive perplexity, phi-store IoStats, accumulated mass).
fn run_paged_foem(
    depth: usize,
    train: &foem::corpus::Corpus,
    test: &foem::corpus::Corpus,
    dir: &foem::util::TempDir,
) -> (f64, IoStats, f64) {
    let k = 8;
    let p = LdaParams::paper_defaults(k);
    let mut fc = FoemConfig::paper();
    fc.exact_ll = false;
    fc.hot_words = 16;
    let mut algo = Foem::paged_create(
        p,
        &dir.path().join(format!("phi-d{depth}.bin")),
        train.n_words(),
        32 * k * 4,
        fc,
        13,
    )
    .unwrap();
    let scfg = StreamConfig { minibatch_docs: 50, ..Default::default() };
    for _pass in 0..2 {
        Pipeline::new(depth)
            .run(&mut algo, CorpusStream::new(train, scfg), |_, _, _| Ok(()))
            .unwrap();
    }
    let mass = algo.phisum_total();
    let phi = algo.export_phi();
    let proto = foem::eval::EvalProtocol {
        fold_in_iters: 30,
        seed: 0,
        ..Default::default()
    };
    let ppx = foem::eval::predictive_perplexity(&phi, &p, &test.docs, &proto);
    (ppx, algo.store.io_stats(), mass)
}

#[test]
fn depth2_paged_foem_overlaps_io_and_matches_depth0_quality() {
    let c = corpus();
    let (train, test) = c.split(50, 1);
    let d0 = foem::util::TempDir::new("pipe-d0");
    let d2 = foem::util::TempDir::new("pipe-d2");
    let (ppx0, io0, _mass0) = run_paged_foem(0, &train, &test, &d0);
    let (ppx2, io2, mass2) = run_paged_foem(2, &train, &test, &d2);
    println!("depth0: {ppx0:.2} {io0:?}\ndepth2: {ppx2:.2} {io2:?}");

    // Quality parity: pipelining only adds bounded staleness, the same
    // stochastic-approximation trade the P>1 executor makes.
    assert!(ppx0.is_finite() && ppx2.is_finite());
    assert!((ppx2 - ppx0).abs() < ppx0 * 0.20, "{ppx2} vs {ppx0}");
    assert!(ppx2 < train.n_words() as f64 * 0.5, "{ppx2}");

    // Eq. 33 accumulation survives any depth exactly: two passes deposit
    // exactly twice the stream's token mass.
    let want = 2.0 * train.n_tokens();
    assert!((mass2 - want).abs() < want * 1e-3, "{mass2} vs {want}");

    // The synchronous run must not touch the overlapped path at all...
    assert_eq!(io0.prefetched_cols, 0, "{io0:?}");
    assert_eq!(io0.prefetch_hits, 0, "{io0:?}");
    assert_eq!(io0.wb_writes, 0, "{io0:?}");
    // ...while the pipelined run prefetches ahead, serves stage-time
    // snapshot reads from the cache, and flushes dirty columns behind
    // the compute thread: blocking misses drop.
    assert!(io2.prefetched_cols > 0, "{io2:?}");
    assert!(io2.prefetch_hits > 0, "{io2:?}");
    assert!(io2.wb_writes > 0, "{io2:?}");
    assert!(
        io2.buffer_misses < io0.buffer_misses,
        "pipelined run did not reduce blocking misses: {io2:?} vs {io0:?}"
    );
}

#[test]
fn depth2_sem_matches_depth0_within_tolerance() {
    let c = corpus();
    let scfg = StreamConfig { minibatch_docs: 50, ..Default::default() };
    let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;
    let k = 8;
    let p = LdaParams::paper_defaults(k);
    let run = |depth: usize| -> (Sem, f64) {
        let mut sem = Sem::new(p, c.n_words(), SemConfig::paper(s), 4);
        let mut last = f64::NAN;
        for _pass in 0..2 {
            Pipeline::new(depth)
                .run(&mut sem, CorpusStream::new(&c, scfg), |_, _, r| {
                    last = r.train_perplexity();
                    Ok(())
                })
                .unwrap();
        }
        (sem, last)
    };
    let (_sem0, ppx0) = run(0);
    let (sem2, ppx2) = run(2);
    assert!(ppx0.is_finite() && ppx2.is_finite());
    assert!((ppx2 - ppx0).abs() < ppx0 * 0.25, "{ppx2} vs {ppx0}");
    // phisum stays consistent with the columns after pipelined folds.
    let mut rebuilt = sem2.phi.clone();
    rebuilt.rebuild_phisum();
    for i in 0..k {
        let (a, b) = (sem2.phi.phisum[i], rebuilt.phisum[i]);
        assert!((a - b).abs() < a.abs().max(1.0) * 1e-3, "{a} vs {b}");
    }
}

#[test]
fn pipelined_run_is_reproducible() {
    // The determinism claim of DESIGN.md §7: for a fixed
    // (seed, n_workers, depth), a pipelined run is exactly reproducible —
    // every RNG draw happens at stage time in batch order, and applies
    // land in strict batch order at fixed loop points.
    let c = corpus();
    let k = 6;
    let p = LdaParams::paper_defaults(k);
    let scfg = StreamConfig { minibatch_docs: 60, ..Default::default() };
    let run = || {
        let mut fc = FoemConfig::paper();
        fc.n_workers = 2;
        let mut algo = Foem::new(p, InMemoryPhi::zeros(k, c.n_words()), fc, 9);
        Pipeline::new(2)
            .run(&mut algo, CorpusStream::new(&c, scfg), |_, _, _| Ok(()))
            .unwrap();
        algo.export_phi()
    };
    let a = run();
    let b = run();
    assert_eq!(a.raw(), b.raw(), "pipelined run is not reproducible");
}
