//! Drift-response bench: ground-truth detection latency, post-shift
//! recovery, and false-alarm counts for the online shift detectors
//! (`coordinator::drift`) over the synthetic drifting stream
//! (`corpus::synthetic::DriftingCorpus`).
//!
//! Emits `BENCH_drift.json` lines:
//!
//!     cargo bench --bench drift
//!     scripts/bench.sh   # writes BENCH_drift.json at the repo root
//!
//! Scenarios × detectors, all seeded and timing-free (every metric is a
//! batch count, so the numbers are exactly reproducible):
//!
//! - `mixture_shift`: every generating topic is redrawn at batch 40 of
//!   80 — the abrupt-regime-change case. The detector must flag it
//!   within the documented latency bound (DESIGN.md §15), after which
//!   the decay-reset response halves the sufficient statistics and the
//!   trainer re-converges; `post_shift_recovery_batches` counts batches
//!   from the true shift until training perplexity is back within 10%
//!   of its pre-shift level.
//! - `stationary`: the same generator with no scheduled events — the
//!   false-alarm control. Both detectors must stay silent for the whole
//!   run (`false_alarms` = 0) despite the convergence trend in the
//!   monitored log-likelihood.

use foem::coordinator::drift::{
    DetectorKind, DriftMonitor, MonitorConfig, ShiftEvent, DECAY_FACTOR,
};
use foem::corpus::synthetic::{
    DriftConfig, DriftKind, DriftPoint, DriftingCorpus, SyntheticConfig,
};
use foem::em::foem::{Foem, FoemConfig};
use foem::store::InMemoryPhi;
use foem::LdaParams;

const K: usize = 16;
const W: usize = 800;
const N_BATCHES: usize = 80;
const SHIFT_BATCH: usize = 40;
/// Alarms this many batches past a true shift count as echoes of it,
/// not false alarms (the response itself perturbs the monitored LL).
const GRACE: usize = 12;

fn base() -> SyntheticConfig {
    let mut cfg = SyntheticConfig::small();
    cfg.n_docs = 0; // unused by the drifting generator
    cfg.n_words = W;
    cfg.n_topics = K;
    cfg
}

struct Outcome {
    detection_latency: usize,
    recovery: usize,
    false_alarms: usize,
    alarms: Vec<ShiftEvent>,
}

/// Train FOEM over the stream, feed the monitor, apply the decay-reset
/// response on alarm, and score against the generator's change log.
fn run(scenario: &str, detector: DetectorKind, seed: u64) -> Outcome {
    let mut cfg = DriftConfig::stationary(base(), 64, N_BATCHES);
    if scenario == "mixture_shift" {
        cfg.events = vec![DriftPoint {
            batch: SHIFT_BATCH,
            kind: DriftKind::MixtureShift { fraction: 1.0 },
        }];
    }
    let stream = DriftingCorpus::new(cfg, seed);
    let shifts = stream.truth().shift_batches();

    let mut fc = FoemConfig::paper();
    fc.exact_ll = true;
    let mut algo =
        Foem::new(LdaParams::paper_defaults(K), InMemoryPhi::zeros(K, W), fc, 7);
    let threshold = match detector {
        DetectorKind::Cusum => 8.0,
        // Shewhart limit in z units: one-shot, so set lower than the
        // CUSUM's accumulated threshold.
        _ => 4.0,
    };
    let mcfg = MonitorConfig { detector, threshold, ..Default::default() };
    let mut monitor = DriftMonitor::new(mcfg);

    let mut ppx = vec![f64::NAN; N_BATCHES];
    let mut alarms: Vec<ShiftEvent> = Vec::new();
    for mb in stream {
        let report = algo.process_minibatch(&mb);
        ppx[mb.index] = report.train_perplexity();
        if let Some(event) =
            monitor.observe(mb.index, report.train_ll / report.tokens.max(1.0))
        {
            alarms.push(event);
            algo.reset_decay(DECAY_FACTOR);
        }
    }

    let detection_latency = match shifts.first() {
        None => 0,
        Some(&t) => alarms
            .iter()
            .find(|a| a.batch >= t)
            .map(|a| a.batch - t + 1)
            .unwrap_or(N_BATCHES - t),
    };
    // Recovery: batches from the true shift until training perplexity
    // is back within 10% of the mean over the 8 batches before it.
    let recovery = match shifts.first() {
        None => 0,
        Some(&t) => {
            let pre: f64 =
                ppx[t - 8..t].iter().sum::<f64>() / 8.0;
            (t..N_BATCHES)
                .find(|&b| ppx[b] <= pre * 1.10)
                .map(|b| b - t)
                .unwrap_or(N_BATCHES - t)
        }
    };
    let false_alarms = alarms
        .iter()
        .filter(|a| {
            !shifts.iter().any(|&t| a.batch >= t && a.batch < t + GRACE)
        })
        .count();
    Outcome { detection_latency, recovery, false_alarms, alarms }
}

fn main() {
    println!(
        "== drift detection: latency + recovery + false alarms \
         (K={K} W={W} batches={N_BATCHES} shift@{SHIFT_BATCH}) =="
    );
    for scenario in ["mixture_shift", "stationary"] {
        for detector in [DetectorKind::Cusum, DetectorKind::Window] {
            let out = run(scenario, detector, 42);
            println!(
                "drift_{scenario}_{}: latency {} batches, recovery {} \
                 batches, {} false alarms ({} alarms total)",
                detector.name(),
                out.detection_latency,
                out.recovery,
                out.false_alarms,
                out.alarms.len()
            );
            println!(
                "BENCH_drift.json {{\"bench\":\"drift\",\
                 \"scenario\":\"{scenario}\",\"detector\":\"{}\",\
                 \"detection_latency_batches\":{},\
                 \"post_shift_recovery_batches\":{},\
                 \"false_alarms\":{}}}",
                detector.name(),
                out.detection_latency,
                out.recovery,
                out.false_alarms
            );
        }
    }
}
