//! Parallel E-step scaling: per-minibatch cost at P = 1/2/4/8 workers
//! for FOEM and SEM on a fixed stream — the throughput metric of the
//! sharded execution engine (`exec::ParallelExecutor`; see
//! `rust/DESIGN.md` §6). P=1 is the serial baseline, so the ratio of the
//! P=1 row to the others is the engine's speedup on this machine.
//!
//!     cargo bench --bench parallel_scaling

use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::foem::{Foem, FoemConfig};
use foem::em::sem::{Sem, SemConfig};
use foem::store::InMemoryPhi;
use foem::stream::{CorpusStream, StreamConfig};
use foem::util::bench::{black_box, run};
use foem::LdaParams;
use std::time::Duration;

fn main() {
    let mut cfg = SyntheticConfig::enron_like();
    cfg.n_docs = 1024;
    let corpus = generate(&cfg, 5);
    let scfg = StreamConfig { minibatch_docs: 512, ..Default::default() };
    let batches: Vec<_> = CorpusStream::new(&corpus, scfg).collect();
    let scale = batches.len() as f64;
    let workers = [1usize, 2, 4, 8];

    println!("== FOEM per-minibatch cost vs workers (K=128) ==");
    let k = 128usize;
    for &p_workers in &workers {
        let p = LdaParams::paper_defaults(k);
        let mut fc = FoemConfig::paper();
        fc.exact_ll = false;
        fc.max_inner_iters = 10;
        fc.n_workers = p_workers;
        let mut algo =
            Foem::new(p, InMemoryPhi::zeros(k, corpus.n_words()), fc, 1);
        let mut i = 0usize;
        run(&format!("foem_p{p_workers}"), Duration::from_secs(2), || {
            let r = algo.process_minibatch(&batches[i % batches.len()]);
            i += 1;
            black_box(r.inner_iters);
        });
    }

    println!("\n== SEM per-minibatch cost vs workers (K=64) ==");
    let k = 64usize;
    for &p_workers in &workers {
        let p = LdaParams::paper_defaults(k);
        let mut sc = SemConfig::paper(scale);
        sc.max_inner_iters = 20;
        sc.n_workers = p_workers;
        let mut algo = Sem::new(p, corpus.n_words(), sc, 1);
        let mut i = 0usize;
        run(&format!("sem_p{p_workers}"), Duration::from_secs(2), || {
            let r = algo.process_minibatch(&batches[i % batches.len()]);
            i += 1;
            black_box(r.inner_iters);
        });
    }
}
