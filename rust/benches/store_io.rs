//! Benchmarks of the parameter-streaming store (§3.2 / Table 5): column
//! access cost for buffered vs streamed columns, hot-set replacement,
//! and the in-memory reference.
//!
//!     cargo bench --bench store_io

use foem::store::paged::PagedPhi;
use foem::store::{InMemoryPhi, PhiColumnStore};
use foem::util::bench::{black_box, run};
use foem::util::Rng;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(800);
    let k = 1024usize;
    let w = 4096usize;

    println!("== column read-modify-write, K={k} ==");
    {
        let mut s = InMemoryPhi::zeros(k, w);
        let mut rng = Rng::new(1);
        run("in_memory_column", budget, || {
            let wid = rng.below(w);
            s.with_column(wid, |c| c[3] += 1.0);
            black_box(wid);
        });
    }
    {
        let dir = foem::util::TempDir::new("bench-miss");
        let mut s =
            PagedPhi::create(&dir.path().join("phi.bin"), k, w, k * 4).unwrap();
        let mut rng = Rng::new(2);
        run("paged_column_miss (read+write disk)", budget, || {
            let wid = rng.below(w);
            s.with_column(wid, |c| c[3] += 1.0);
            black_box(wid);
        });
    }
    {
        let dir = foem::util::TempDir::new("bench-hit");
        let mut s =
            PagedPhi::create(&dir.path().join("phi.bin"), k, w, 64 * k * 4)
                .unwrap();
        let hot: Vec<u32> = (0..64).collect();
        s.set_hot_words(&hot);
        let mut rng = Rng::new(3);
        run("paged_column_hit (buffered)", budget, || {
            let wid = rng.below(64);
            s.with_column(wid, |c| c[3] += 1.0);
            black_box(wid);
        });
    }

    println!("\n== hot-set replacement (64 columns) ==");
    {
        let dir = foem::util::TempDir::new("bench-hot");
        let mut s =
            PagedPhi::create(&dir.path().join("phi.bin"), k, w, 64 * k * 4)
                .unwrap();
        let mut rng = Rng::new(4);
        run("set_hot_words_64", Duration::from_millis(1500), || {
            let hot: Vec<u32> =
                (0..64).map(|_| rng.below(w) as u32).collect();
            s.set_hot_words(&hot);
            black_box(&s);
        });
    }

    println!("\n== checkpoint + reopen, K={k} W={w} ==");
    {
        let dir = foem::util::TempDir::new("bench-ckpt");
        let path = dir.path().join("phi.bin");
        let mut s = PagedPhi::create(&path, k, w, 16 * k * 4).unwrap();
        let phisum = vec![1.0f32; k];
        run("checkpoint", Duration::from_millis(1500), || {
            s.checkpoint(1, &phisum).unwrap();
        });
        run("reopen", Duration::from_millis(1500), || {
            let s2 = PagedPhi::open(&path, 16 * k * 4).unwrap();
            black_box(s2.n_words());
        });
    }
}
