//! Fold-in inference bench: the dense reference protocol (synchronous
//! full-K sweeps — the engine's `TopicSubset::All` path, bit-identical
//! to the historical `Bem::fold_in`) vs the residual-scheduled engine,
//! at K ∈ {64, 256, 1024} × workers ∈ {1, 4}. One bench iteration is one
//! complete fold-in of the evaluation corpus — the unit of work every
//! periodic driver evaluation pays.
//!
//! Emits `BENCH_foldin.json` lines (per-impl rows plus a summary row
//! with the scheduled-vs-dense speedup per configuration):
//!
//!     cargo bench --bench foldin
//!     scripts/bench.sh   # writes BENCH_foldin.json at the repo root
//!
//! The acceptance claim: at K = 1024 the scheduled engine (10 + 2
//! topics per doc per sweep) beats the dense reference, because its
//! sweep cost is O(NNZ·S) instead of O(NNZ·K).

use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::infer::{fold_in_with_report, FoldInConfig};
use foem::em::PhiStats;
use foem::util::bench::{black_box, run};
use foem::util::Rng;
use foem::LdaParams;
use std::time::Duration;

const SWEEPS: usize = 20;

/// A synthetic trained-phi stand-in: positive random mass. Fold-in cost
/// does not depend on phi being a converged model.
fn synth_phi(k: usize, w: usize, seed: u64) -> PhiStats {
    let mut rng = Rng::new(seed);
    let mut phi = PhiStats::zeros(k, w);
    let mut col = vec![0.0f32; k];
    for ww in 0..w {
        for x in col.iter_mut() {
            *x = rng.next_f32() * 3.0 + 0.05;
        }
        phi.add_to_word(ww, &col);
    }
    phi
}

fn main() {
    let budget = Duration::from_millis(600);
    let mut cfg = SyntheticConfig::small();
    cfg.n_docs = 256;
    let corpus = generate(&cfg, 42);
    let docs = &corpus.docs;
    println!(
        "== fold-in inference: dense reference vs scheduled engine \
         (D={} NNZ={} sweeps={SWEEPS}) ==",
        docs.n_docs,
        docs.nnz()
    );

    for &k in &[64usize, 256, 1024] {
        let p = LdaParams::paper_defaults(k);
        let phi = synth_phi(k, corpus.n_words(), 7 + k as u64);
        for &workers in &[1usize, 4] {
            let mut dense_cfg = FoldInConfig::dense(SWEEPS);
            dense_cfg.n_workers = workers;
            let mut sched_cfg = FoldInConfig::scheduled(10, SWEEPS);
            sched_cfg.tol = 0.0; // same fixed budget on both sides
            sched_cfg.n_workers = workers;

            // Sanity guard before timing: both engines must preserve the
            // per-document token mass (the fold-in invariant).
            let mut resp_bytes = [0usize; 2];
            for (i, c) in [&dense_cfg, &sched_cfg].into_iter().enumerate() {
                let (theta, rep) = fold_in_with_report(&phi, &p, docs, c, 1);
                resp_bytes[i] = rep.resp_bytes;
                for d in 0..docs.n_docs {
                    let (got, want) = (theta.doc_total(d), docs.doc_len(d));
                    assert!(
                        (got - want).abs() < want.max(1.0) * 1e-3,
                        "doc {d}: theta mass {got} vs tokens {want}"
                    );
                }
            }

            let rd = run(
                &format!("foldin_dense_k{k}_w{workers}"),
                budget,
                || {
                    black_box(fold_in_with_report(
                        &phi, &p, docs, &dense_cfg, 1,
                    ));
                },
            );
            let rs = run(
                &format!("foldin_sched_k{k}_w{workers}"),
                budget,
                || {
                    black_box(fold_in_with_report(
                        &phi, &p, docs, &sched_cfg, 1,
                    ));
                },
            );

            for (imp, rep, bytes) in [
                ("dense", &rd, resp_bytes[0]),
                ("scheduled", &rs, resp_bytes[1]),
            ] {
                println!(
                    "BENCH_foldin.json {{\"bench\":\"foldin\",\"k\":{k},\
                     \"workers\":{workers},\"impl\":\"{imp}\",\
                     \"mean_ns\":{:.0},\"p50_ns\":{:.0},\
                     \"resp_bytes\":{bytes},\"docs\":{},\"nnz\":{},\
                     \"sweeps\":{SWEEPS}}}",
                    rep.mean_ns,
                    rep.p50_ns,
                    docs.n_docs,
                    docs.nnz()
                );
            }
            println!(
                "BENCH_foldin.json {{\"bench\":\"foldin_summary\",\
                 \"k\":{k},\"workers\":{workers},\"speedup\":{:.3}}}",
                rd.mean_ns / rs.mean_ns
            );
        }
    }
}
