//! Serving-layer bench: end-to-end request throughput and latency of
//! the snapshot-isolated server (`serve::Server`) over a published model
//! snapshot, at workers ∈ {1, 4} × fold-in subset ∈ {Fixed(10), All}.
//! One configuration submits every request of the evaluation corpus in
//! waves and reports docs/sec plus p50/p99 submit-to-completion latency
//! from the server's own `ServeReport`.
//!
//! Emits `BENCH_serve.json` lines:
//!
//!     cargo bench --bench serve
//!     scripts/bench.sh   # writes BENCH_serve.json at the repo root
//!
//! The claim under test: the scheduled subset keeps per-request cost
//! O(NNZ·S) instead of O(NNZ·K), so at serving-sized K the Fixed(10)
//! configuration sustains a higher docs/sec at lower tail latency, and
//! workers scale throughput until the queue is the bottleneck.
//!
//! A second row family (`"sweep":"shards"`, N ∈ {1, 2, 4}) serves the
//! same workload against a DISTRIBUTED snapshot assembled from N
//! per-shard view parts (`ModelRegistry::publish_distributed`, the
//! gather half of the vocabulary-sharded router). The merged snapshot
//! is one contiguous view, so steady-state docs/sec must be invariant
//! in N — the shard count is paid once at publish (`publish_us`), never
//! per request. `scripts/bench_gate.py` keys these rows on `shards`.

use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::infer::FoldInConfig;
use foem::em::schedule::TopicSubset;
use foem::em::{EvalPhiView, PhiStats};
use foem::serve::{ModelRegistry, ServeConfig, Server};
use foem::util::Rng;
use foem::LdaParams;
use std::sync::Arc;

const SWEEPS: usize = 20;
const WAVES: usize = 3;

/// A synthetic trained-phi stand-in: positive random mass (serving cost
/// does not depend on phi being a converged model).
fn synth_phi(k: usize, w: usize, seed: u64) -> PhiStats {
    let mut rng = Rng::new(seed);
    let mut phi = PhiStats::zeros(k, w);
    let mut col = vec![0.0f32; k];
    for ww in 0..w {
        for x in col.iter_mut() {
            *x = rng.next_f32() * 3.0 + 0.05;
        }
        phi.add_to_word(ww, &col);
    }
    phi
}

fn main() {
    let k = 256usize;
    let mut cfg = SyntheticConfig::small();
    cfg.n_docs = 192;
    let corpus = generate(&cfg, 42);
    let requests: Vec<Vec<(u32, f32)>> = (0..corpus.docs.n_docs)
        .map(|d| corpus.docs.iter_doc(d).collect())
        .collect();
    let params = LdaParams::paper_defaults(k);
    let phi = synth_phi(k, corpus.n_words(), 7);
    let words: Vec<u32> = (0..corpus.n_words() as u32).collect();
    println!(
        "== serving layer: docs/sec + latency (K={k} D={} NNZ={} \
         sweeps={SWEEPS} waves={WAVES}) ==",
        corpus.docs.n_docs,
        corpus.docs.nnz()
    );

    for &workers in &[1usize, 4] {
        for (subset_name, subset, tol) in [
            ("fixed10", TopicSubset::Fixed(10), 1e-2),
            ("all", TopicSubset::All, 0.0),
        ] {
            let registry = Arc::new(ModelRegistry::new());
            registry.publish(
                EvalPhiView::from_dense(&phi, &words),
                params,
            );
            let serve_cfg = ServeConfig {
                max_batch_docs: 32,
                queue_docs: 1024,
                workers,
                fold_in: FoldInConfig {
                    subset,
                    explore_slots: 2,
                    max_sweeps: SWEEPS,
                    tol,
                    n_workers: 1,
                    kernel_backend: foem::em::simd::KernelBackend::Auto,
                },
            };
            // Warmup pass on a throwaway server (fills the process-wide
            // scratch pool and checks results), then a fresh server so
            // the timed report contains only the measured waves.
            let warm = Server::start(Arc::clone(&registry), serve_cfg);
            for (i, doc) in requests.iter().enumerate() {
                let resp = warm
                    .submit(doc.clone(), i as u64)
                    .expect("submit")
                    .wait()
                    .expect("warmup response");
                assert_eq!(resp.theta.len(), k, "bad theta length");
                let mass: f32 = resp.theta.iter().sum();
                let want: f32 = doc.iter().map(|&(_, c)| c).sum();
                assert!(
                    (mass - want).abs() < want.max(1.0) * 1e-2,
                    "doc {i}: theta mass {mass} vs tokens {want}"
                );
            }
            warm.shutdown();

            let server = Server::start(Arc::clone(&registry), serve_cfg);
            for wave in 0..WAVES {
                let pending: Vec<_> = requests
                    .iter()
                    .enumerate()
                    .map(|(i, doc)| {
                        server
                            .submit(doc.clone(), (wave * 1000 + i) as u64)
                            .expect("submit")
                    })
                    .collect();
                for p in pending {
                    p.wait().expect("response");
                }
            }
            let report = server.shutdown();
            let timed_docs = report.docs;
            println!(
                "serve_k{k}_w{workers}_{subset_name}: {} docs \
                 ({} batches, mean {:.1}/batch)  {:.0} docs/s  \
                 p50 {:.0}µs  p99 {:.0}µs",
                timed_docs,
                report.batches,
                report.mean_batch_docs,
                report.docs_per_sec,
                report.p50_latency_us,
                report.p99_latency_us
            );
            println!(
                "BENCH_serve.json {{\"bench\":\"serve\",\"k\":{k},\
                 \"workers\":{workers},\"subset\":\"{subset_name}\",\
                 \"docs\":{},\"batches\":{},\"mean_batch_docs\":{:.2},\
                 \"docs_per_sec\":{:.1},\"p50_us\":{:.1},\
                 \"p99_us\":{:.1},\"sweeps\":{SWEEPS}}}",
                timed_docs,
                report.batches,
                report.mean_batch_docs,
                report.docs_per_sec,
                report.p50_latency_us,
                report.p99_latency_us
            );
        }
    }

    // Shards sweep: gather N per-shard view parts into one distributed
    // snapshot, then serve the identical workload (workers=4, fixed10).
    // Publish cost scales with N; per-request cost must not.
    for &n_shards in &[1usize, 2, 4] {
        let span = corpus.n_words().div_ceil(n_shards).max(1);
        let registry = Arc::new(ModelRegistry::new());
        let publish_start = std::time::Instant::now();
        let parts: Vec<EvalPhiView> = (0..n_shards)
            .filter_map(|s| {
                let lo = (s * span).min(words.len());
                let hi = ((s + 1) * span).min(words.len());
                if lo == hi {
                    None
                } else {
                    Some(EvalPhiView::from_dense(&phi, &words[lo..hi]))
                }
            })
            .collect();
        registry.publish_distributed(parts, params);
        let publish_us = publish_start.elapsed().as_micros();
        let serve_cfg = ServeConfig {
            max_batch_docs: 32,
            queue_docs: 1024,
            workers: 4,
            fold_in: FoldInConfig {
                subset: TopicSubset::Fixed(10),
                explore_slots: 2,
                max_sweeps: SWEEPS,
                tol: 1e-2,
                n_workers: 1,
                kernel_backend: foem::em::simd::KernelBackend::Auto,
            },
        };
        let warm = Server::start(Arc::clone(&registry), serve_cfg);
        for (i, doc) in requests.iter().enumerate() {
            let resp = warm
                .submit(doc.clone(), i as u64)
                .expect("submit")
                .wait()
                .expect("warmup response");
            assert_eq!(resp.theta.len(), k, "bad theta length");
        }
        warm.shutdown();

        let server = Server::start(Arc::clone(&registry), serve_cfg);
        for wave in 0..WAVES {
            let pending: Vec<_> = requests
                .iter()
                .enumerate()
                .map(|(i, doc)| {
                    server
                        .submit(doc.clone(), (wave * 1000 + i) as u64)
                        .expect("submit")
                })
                .collect();
            for p in pending {
                p.wait().expect("response");
            }
        }
        let report = server.shutdown();
        println!(
            "serve_k{k}_shards{n_shards}: {} docs  {:.0} docs/s  \
             p50 {:.0}µs  p99 {:.0}µs  publish {publish_us}µs",
            report.docs,
            report.docs_per_sec,
            report.p50_latency_us,
            report.p99_latency_us
        );
        println!(
            "BENCH_serve.json {{\"bench\":\"serve\",\"k\":{k},\
             \"workers\":4,\"subset\":\"fixed10\",\
             \"sweep\":\"shards\",\"shards\":{n_shards},\
             \"docs\":{},\"batches\":{},\"mean_batch_docs\":{:.2},\
             \"docs_per_sec\":{:.1},\"p50_us\":{:.1},\
             \"p99_us\":{:.1},\"publish_us\":{publish_us},\
             \"sweeps\":{SWEEPS}}}",
            report.docs,
            report.batches,
            report.mean_batch_docs,
            report.docs_per_sec,
            report.p50_latency_us,
            report.p99_latency_us
        );
    }
}
