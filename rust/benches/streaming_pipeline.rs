//! Streaming-pipeline bench: paged FOEM at pipeline depth 0/1/2 ×
//! workers 1/4 — the §3.2 parameter-streaming workload with the
//! pipelined prefetch/write-behind overlap on top (`exec::pipeline`,
//! `rust/DESIGN.md` §7). Depth 0 is the synchronous baseline, so the
//! depth-0 row over the others is the overlap's speedup on this machine.
//!
//! A second sweep holds depth 0 / workers 1 fixed and varies the column
//! codec (`--phi-codec`, `rust/DESIGN.md` §12): same synthetic
//! sparse-phi workload, per-codec throughput + bytes. `disk_bytes /
//! logical_bytes` is the exact compression ratio of real disk traffic
//! and `file_bytes` is the backing file's high-water data size, so the
//! raw row over a compressed row is the bytes-on-disk reduction the
//! acceptance gate tracks.
//!
//! A third sweep measures the write-ahead log (`--wal`,
//! `rust/DESIGN.md` §13): the same workload with the WAL off vs armed,
//! no mid-run truncation — the worst case, every column write logged
//! for the whole run plus one fsync per batch. The off/on
//! `tokens_per_sec` ratio is the durability tax, and `wal_bytes` is the
//! log growth a `--checkpoint-every` cadence bounds in production.
//!
//! Emits one `BENCH_pipeline.json`-compatible line per configuration so
//! the perf trajectory accumulates across PRs:
//!
//!     cargo bench --bench streaming_pipeline
//!     cargo bench --bench streaming_pipeline | grep BENCH_pipeline.json

use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::foem::{Foem, FoemConfig};
use foem::exec::pipeline::Pipeline;
use foem::store::{Codec, PhiColumnStore};
use foem::stream::{CorpusStream, StreamConfig};
use foem::util::{TempDir, Timer};
use foem::LdaParams;

fn main() {
    let mut profile = SyntheticConfig::enron_like();
    profile.n_docs = 1024;
    let corpus = generate(&profile, 7);
    let k = 128usize;
    let p = LdaParams::paper_defaults(k);
    let scfg = StreamConfig { minibatch_docs: 256, ..Default::default() };
    println!(
        "== paged FOEM streaming pipeline (K={k}, D={}, W={}) ==",
        corpus.n_docs(),
        corpus.n_words()
    );
    for &workers in &[1usize, 4] {
        for &depth in &[0usize, 1, 2] {
            let dir = TempDir::new("bench-pipe");
            let mut fc = FoemConfig::paper();
            fc.exact_ll = false;
            fc.max_inner_iters = 10;
            fc.n_workers = workers;
            fc.hot_words = 32;
            let mut algo = Foem::paged_create(
                p,
                &dir.path().join("phi.bin"),
                corpus.n_words(),
                64 * k * 4,
                fc,
                1,
            )
            .expect("create paged store");
            let timer = Timer::start();
            Pipeline::new(depth)
                .run(&mut algo, CorpusStream::new(&corpus, scfg), |_, _, _| {
                    Ok(())
                })
                .expect("pipeline run");
            let seconds = timer.seconds();
            let io = algo.store.io_stats();
            let tokens_per_sec = corpus.n_tokens() / seconds.max(1e-9);
            println!(
                "BENCH_pipeline.json {{\"bench\":\"streaming_pipeline\",\
                 \"algo\":\"foem_paged\",\"k\":{k},\"depth\":{depth},\
                 \"workers\":{workers},\"codec\":\"auto\",\
                 \"seconds\":{seconds:.4},\
                 \"tokens_per_sec\":{tokens_per_sec:.1},\
                 \"col_reads\":{},\"col_writes\":{},\"buffer_misses\":{},\
                 \"prefetched_cols\":{},\"prefetch_hits\":{},\
                 \"wb_writes\":{},\"logical_bytes\":{},\"disk_bytes\":{}}}",
                io.col_reads,
                io.col_writes,
                io.buffer_misses,
                io.prefetched_cols,
                io.prefetch_hits,
                io.wb_writes,
                io.logical_bytes,
                io.disk_bytes
            );
        }
    }

    println!("== column codec sweep (depth 0, workers 1) ==");
    for codec in Codec::all() {
        let dir = TempDir::new("bench-codec");
        let mut fc = FoemConfig::paper();
        fc.exact_ll = false;
        fc.max_inner_iters = 10;
        fc.n_workers = 1;
        fc.hot_words = 32;
        let mut algo = Foem::paged_create_with_codec(
            p,
            &dir.path().join("phi.bin"),
            corpus.n_words(),
            64 * k * 4,
            fc,
            1,
            codec,
        )
        .expect("create paged store");
        let timer = Timer::start();
        Pipeline::new(0)
            .run(&mut algo, CorpusStream::new(&corpus, scfg), |_, _, _| Ok(()))
            .expect("pipeline run");
        algo.store.flush().expect("flush");
        let seconds = timer.seconds();
        let io = algo.store.io_stats();
        let tokens_per_sec = corpus.n_tokens() / seconds.max(1e-9);
        println!(
            "BENCH_pipeline.json {{\"bench\":\"streaming_pipeline\",\
             \"algo\":\"foem_paged\",\"sweep\":\"codec\",\"k\":{k},\
             \"depth\":0,\"workers\":1,\"codec\":\"{}\",\
             \"seconds\":{seconds:.4},\
             \"tokens_per_sec\":{tokens_per_sec:.1},\
             \"col_reads\":{},\"col_writes\":{},\
             \"logical_bytes\":{},\"disk_bytes\":{},\"file_bytes\":{}}}",
            codec.name(),
            io.col_reads,
            io.col_writes,
            io.logical_bytes,
            io.disk_bytes,
            algo.store.data_bytes_on_disk()
        );
    }

    println!("== write-ahead log sweep (depth 0, workers 1) ==");
    for &wal in &[false, true] {
        let dir = TempDir::new("bench-wal");
        let mut fc = FoemConfig::paper();
        fc.exact_ll = false;
        fc.max_inner_iters = 10;
        fc.n_workers = 1;
        fc.hot_words = 32;
        let mut algo = Foem::paged_create(
            p,
            &dir.path().join("phi.bin"),
            corpus.n_words(),
            64 * k * 4,
            fc,
            1,
        )
        .expect("create paged store");
        if wal {
            algo.enable_wal().expect("arm WAL");
        }
        let timer = Timer::start();
        for mb in CorpusStream::new(&corpus, scfg) {
            algo.process_minibatch(&mb);
        }
        algo.checkpoint_paged().expect("checkpoint");
        let seconds = timer.seconds();
        let io = algo.store.io_stats();
        let tokens_per_sec = corpus.n_tokens() / seconds.max(1e-9);
        let wal_field = if wal {
            format!(
                ",\"wal_bytes\":{}",
                algo.store.wal_bytes() + algo.res_store.wal_bytes()
            )
        } else {
            String::new()
        };
        println!(
            "BENCH_pipeline.json {{\"bench\":\"streaming_pipeline\",\
             \"algo\":\"foem_paged\",\"sweep\":\"wal\",\"k\":{k},\
             \"depth\":0,\"workers\":1,\"codec\":\"auto\",\
             \"wal\":\"{}\",\"seconds\":{seconds:.4},\
             \"tokens_per_sec\":{tokens_per_sec:.1},\
             \"col_reads\":{},\"col_writes\":{},\
             \"logical_bytes\":{},\"disk_bytes\":{}{wal_field}}}",
            if wal { "on" } else { "off" },
            io.col_reads,
            io.col_writes,
            io.logical_bytes,
            io.disk_bytes
        );
    }
}
