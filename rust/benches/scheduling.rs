//! Benchmarks of the dynamic scheduler (§3.1): the `O(K)` partial
//! top-lambda_k selection vs a full sort, and residual bookkeeping.
//!
//!     cargo bench --bench scheduling

use foem::em::schedule::{ResidualScheduler, TopicSubset};
use foem::util::bench::{black_box, run};
use foem::util::Rng;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(600);
    println!("== top-10 topic selection: partial select vs full sort ==");
    for &k in &[64usize, 256, 1024, 4096, 16384] {
        let mut rng = Rng::new(1);
        let res: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let mut sched = ResidualScheduler::new(k, 1);
        sched.set_word_residuals(0, &res);
        run(&format!("partial_select_k{k}"), budget, || {
            let top = sched.top_topics(0, TopicSubset::Fixed(10));
            black_box(top[0]);
        });
        let res2 = res.clone();
        run(&format!("full_sort_k{k}"), budget, || {
            let mut idx: Vec<u32> = (0..k as u32).collect();
            idx.sort_unstable_by(|&a, &b| {
                res2[b as usize].partial_cmp(&res2[a as usize]).unwrap()
            });
            black_box(idx[0]);
        });
    }

    println!("\n== per-sweep word ordering (W_s local words) ==");
    for &ws in &[512usize, 2048, 8192] {
        let mut rng = Rng::new(2);
        let mut sched = ResidualScheduler::new(8, ws);
        for lw in 0..ws {
            let res: Vec<f32> = (0..8).map(|_| rng.next_f32()).collect();
            sched.set_word_residuals(lw, &res);
        }
        run(&format!("word_order_ws{ws}"), budget, || {
            let order = sched.word_order(1.0);
            black_box(order.len());
        });
    }

    println!("\n== residual update (accumulate + overwrite) ==");
    for &k in &[256usize, 1024] {
        let mut rng = Rng::new(3);
        let mut sched = ResidualScheduler::new(k, 64);
        let fresh: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        run(&format!("residual_set_k{k}"), budget, || {
            sched.set_word_residuals(7, black_box(&fresh));
            black_box(sched.word_total(7));
        });
    }
}
