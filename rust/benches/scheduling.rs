//! Benchmarks of the dynamic-scheduling primitives (§3.1) as the
//! trainers actually run them: the `O(K)` scan-based top-lambda_k topic
//! selection (`resp::top_n_indices`) vs a full sort, and the per-sweep
//! word ordering by resident residual totals.
//!
//!     cargo bench --bench scheduling

use foem::em::resp::top_n_indices;
use foem::em::schedule::TopicSubset;
use foem::util::bench::{black_box, run};
use foem::util::Rng;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(600);
    println!("== top-10 topic selection: linear scan vs full sort ==");
    for &k in &[64usize, 256, 1024, 4096, 16384] {
        let mut rng = Rng::new(1);
        let res: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let n = TopicSubset::Fixed(10).size(k);
        let mut sel: Vec<u32> = Vec::with_capacity(n);
        run(&format!("scan_select_k{k}"), budget, || {
            top_n_indices(black_box(&res), n, &mut sel);
            black_box(sel[0]);
        });
        let res2 = res.clone();
        run(&format!("full_sort_k{k}"), budget, || {
            let mut idx: Vec<u32> = (0..k as u32).collect();
            idx.sort_unstable_by(|&a, &b| {
                res2[b as usize].partial_cmp(&res2[a as usize]).unwrap()
            });
            black_box(idx[0]);
        });
    }

    println!("\n== per-sweep word ordering (W_s local words, by r_w) ==");
    // The trainers sort a hoisted index Vec by the resident residual
    // totals each sweep (Eq. 37) — this is that loop, verbatim.
    for &ws in &[512usize, 2048, 8192] {
        let mut rng = Rng::new(2);
        let r_totals: Vec<f32> = (0..ws).map(|_| rng.next_f32()).collect();
        let mut order: Vec<u32> = Vec::with_capacity(ws);
        run(&format!("word_order_ws{ws}"), budget, || {
            order.clear();
            order.extend(0..ws as u32);
            order.sort_unstable_by(|&a, &b| {
                let ra = r_totals[a as usize];
                let rb = r_totals[b as usize];
                rb.partial_cmp(&ra).unwrap_or(std::cmp::Ordering::Equal)
            });
            black_box(order[0]);
        });
    }

    println!("\n== selection at TopicSubset sizes ==");
    for &k in &[256usize, 1024] {
        let mut rng = Rng::new(3);
        let res: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        for (label, subset) in [
            ("fixed10", TopicSubset::Fixed(10)),
            ("frac10", TopicSubset::Fraction(0.1)),
        ] {
            let n = subset.size(k);
            let mut sel: Vec<u32> = Vec::with_capacity(n);
            run(&format!("select_{label}_k{k}"), budget, || {
                top_n_indices(black_box(&res), n, &mut sel);
                black_box(sel.len());
            });
        }
    }
}
