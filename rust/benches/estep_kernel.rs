//! E-step working-set bench: the slot-compressed responsibility arena
//! (`em::resp`, O(NNZ·S)) vs the historical dense `nnz × K` buffer, on
//! the same scheduled exclude/recompute/renormalize sweep — the Table 3
//! space/time trade the arena PR targets. One bench iteration is one
//! minibatch-equivalent: reset + one-hot init + `SWEEPS` scheduled
//! sweeps over every word, with identical float math and identical
//! selections on both sides (verified bitwise before timing).
//!
//! Emits `BENCH_estep.json` lines (per-impl rows — dense, arena, and the
//! runtime-dispatched SIMD arena tier with its detected ISA — plus a
//! summary row with the bytes ratio, arena speedup, and scalar-vs-SIMD
//! speedup per configuration) so the perf trajectory accumulates across
//! PRs:
//!
//!     cargo bench --bench estep_kernel
//!     scripts/bench.sh   # writes BENCH_estep.json at the repo root

use foem::em::resp::{self, RespArena, SweepKernel};
use foem::em::schedule::TopicSubset;
use foem::em::simd::KernelBackend;
use foem::util::bench::{black_box, run};
use foem::util::Rng;
use std::time::Duration;

const EXPLORE_SLOTS: usize = 4;
const SWEEPS: usize = 3;
const WORDS: usize = 128;
const ENTRIES_PER_WORD: usize = 32;
const DOCS: usize = 512;

struct Workload {
    k: usize,
    nnz: usize,
    doc_ids: Vec<u32>,
    counts: Vec<f32>,
    init_topics: Vec<usize>,
    /// Residual columns driving per-word topic selection, word-major.
    res_cols: Vec<f32>,
    /// Initial phi columns, word-major.
    phi_cols: Vec<f32>,
    phisum0: Vec<f32>,
}

impl Workload {
    fn new(k: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let nnz = WORDS * ENTRIES_PER_WORD;
        Self {
            k,
            nnz,
            doc_ids: (0..nnz).map(|e| ((e * 7) % DOCS) as u32).collect(),
            counts: (0..nnz).map(|e| (e % 3 + 1) as f32).collect(),
            init_topics: (0..nnz).map(|_| rng.below(k)).collect(),
            res_cols: (0..WORDS * k).map(|_| rng.next_f32() * 4.0).collect(),
            phi_cols: (0..WORDS * k)
                .map(|_| rng.next_f32() * 2.0 + 0.1)
                .collect(),
            phisum0: (0..k).map(|_| rng.next_f32() * 100.0 + 10.0).collect(),
        }
    }

    /// The word's selection for a given sweep: top-`n_sel` residuals with
    /// the last slot swapped for a rotating pseudo-exploration topic, so
    /// the scheduled support widens across sweeps like in real FOEM.
    fn select(&self, w: usize, sweep: usize, n_sel: usize, sel: &mut Vec<u32>) {
        resp::top_n_indices(
            &self.res_cols[w * self.k..(w + 1) * self.k],
            n_sel,
            sel,
        );
        if n_sel < self.k {
            let cand = ((w * 31 + sweep * 17 + 5) % self.k) as u32;
            if !sel.contains(&cand) {
                let last = sel.len() - 1;
                sel[last] = cand;
            }
        }
    }
}

/// Reusable dense-baseline state (the historical layout).
struct DenseState {
    mu: Vec<f32>,
    theta: Vec<f32>,
    phi: Vec<f32>,
    phisum: Vec<f32>,
}

/// One minibatch-equivalent on the dense `nnz × K` buffer — the
/// pre-arena code shape: zero the matrix, one-hot init, scheduled
/// exclude/recompute/include sweeps with K-strided row access.
fn run_dense(wl: &Workload, st: &mut DenseState, n_sel: usize) -> f32 {
    let k = wl.k;
    st.mu.clear();
    st.mu.resize(wl.nnz * k, 0.0);
    st.theta.clear();
    st.theta.resize(DOCS * k, 0.0);
    st.phi.clear();
    st.phi.extend_from_slice(&wl.phi_cols);
    st.phisum.clear();
    st.phisum.extend_from_slice(&wl.phisum0);
    for e in 0..wl.nnz {
        st.mu[e * k + wl.init_topics[e]] = 1.0;
        st.theta[wl.doc_ids[e] as usize * k + wl.init_topics[e]] +=
            wl.counts[e];
    }
    let (am1, bm1, wbm1) = (0.01f32, 0.01f32, 0.01 * WORDS as f32);
    let mut sel: Vec<u32> = Vec::with_capacity(n_sel);
    let mut scratch = vec![0.0f32; n_sel];
    let mut fresh = vec![0.0f32; n_sel];
    for sweep in 0..SWEEPS {
        for w in 0..WORDS {
            wl.select(w, sweep, n_sel, &mut sel);
            fresh.iter_mut().for_each(|x| *x = 0.0);
            let col = &mut st.phi[w * k..(w + 1) * k];
            let base = w * ENTRIES_PER_WORD;
            for off in 0..ENTRIES_PER_WORD {
                let e = base + off;
                let d = wl.doc_ids[e] as usize;
                let c = wl.counts[e];
                let mu_row = &mut st.mu[e * k..(e + 1) * k];
                let th = &mut st.theta[d * k..(d + 1) * k];
                let mut m_old = 0.0f32;
                for &kk in &sel {
                    m_old += mu_row[kk as usize];
                }
                if m_old <= 1e-12 {
                    continue;
                }
                let mut z = 0.0f32;
                for (j, &kk) in sel.iter().enumerate() {
                    let kk = kk as usize;
                    let excl = c * mu_row[kk];
                    let u = (th[kk] - excl + am1) * (col[kk] - excl + bm1)
                        / (st.phisum[kk] - excl + wbm1);
                    scratch[j] = u.max(0.0);
                    z += scratch[j];
                }
                if z <= 0.0 {
                    continue;
                }
                let renorm = m_old / z;
                for (j, &kk) in sel.iter().enumerate() {
                    let kk = kk as usize;
                    let new = scratch[j] * renorm;
                    let delta = c * (new - mu_row[kk]);
                    th[kk] += delta;
                    col[kk] += delta;
                    st.phisum[kk] += delta;
                    fresh[j] += delta.abs();
                    mu_row[kk] = new;
                }
            }
        }
    }
    st.theta.iter().sum()
}

/// Reusable arena state.
struct ArenaState {
    mu: RespArena,
    kern: SweepKernel,
    theta: Vec<f32>,
    phi: Vec<f32>,
    phisum: Vec<f32>,
}

/// The same minibatch-equivalent through `em::resp` (shared kernel over
/// slot-compressed lanes).
fn run_arena(wl: &Workload, st: &mut ArenaState, n_sel: usize) -> f32 {
    let k = wl.k;
    st.mu.reset(k, wl.nnz, resp::lane_capacity(n_sel, EXPLORE_SLOTS, k));
    st.theta.clear();
    st.theta.resize(DOCS * k, 0.0);
    st.phi.clear();
    st.phi.extend_from_slice(&wl.phi_cols);
    st.phisum.clear();
    st.phisum.extend_from_slice(&wl.phisum0);
    for e in 0..wl.nnz {
        st.mu.set_one_hot(e, wl.init_topics[e]);
        st.theta[wl.doc_ids[e] as usize * k + wl.init_topics[e]] +=
            wl.counts[e];
    }
    let (am1, bm1, wbm1) = (0.01f32, 0.01f32, 0.01 * WORDS as f32);
    let mut sel: Vec<u32> = Vec::with_capacity(n_sel);
    let mut fresh = vec![0.0f32; n_sel];
    for sweep in 0..SWEEPS {
        for w in 0..WORDS {
            wl.select(w, sweep, n_sel, &mut sel);
            fresh.iter_mut().for_each(|x| *x = 0.0);
            let col = &mut st.phi[w * k..(w + 1) * k];
            let base = w * ENTRIES_PER_WORD;
            resp::sweep_word(
                &mut st.mu,
                &mut st.kern,
                &sel,
                base,
                &wl.doc_ids[base..base + ENTRIES_PER_WORD],
                &wl.counts[base..base + ENTRIES_PER_WORD],
                &mut st.theta,
                col,
                &mut st.phisum,
                am1,
                bm1,
                wbm1,
                &mut fresh,
            );
        }
    }
    st.theta.iter().sum()
}

fn main() {
    let budget = Duration::from_millis(900);
    println!(
        "== E-step working set: dense nnz*K vs responsibility arena \
         (NNZ={}, {SWEEPS} sweeps) ==",
        WORDS * ENTRIES_PER_WORD
    );
    for &k in &[64usize, 256, 1024] {
        for (label, subset) in
            [("fixed10", TopicSubset::Fixed(10)), ("all", TopicSubset::All)]
        {
            let n_sel = subset.size(k);
            let wl = Workload::new(k, 7 + k as u64);
            let mut ds = DenseState {
                mu: Vec::new(),
                theta: Vec::new(),
                phi: Vec::new(),
                phisum: Vec::new(),
            };
            let mut ar = ArenaState {
                mu: RespArena::new(),
                kern: SweepKernel::new(),
                theta: Vec::new(),
                phi: Vec::new(),
                phisum: Vec::new(),
            };
            let mut av = ArenaState {
                mu: RespArena::new(),
                kern: SweepKernel::new(),
                theta: Vec::new(),
                phi: Vec::new(),
                phisum: Vec::new(),
            };
            av.kern.set_backend(KernelBackend::Simd);
            let isa = KernelBackend::Simd.resolve();
            // Bit-identity guard: both scalar sides must produce the same
            // numbers before their times mean anything.
            let cd = run_dense(&wl, &mut ds, n_sel);
            let ca = run_arena(&wl, &mut ar, n_sel);
            assert_eq!(
                cd.to_bits(),
                ca.to_bits(),
                "dense/arena diverged at k={k} {label}"
            );
            // The vector tier reassociates reductions, so it is held to a
            // tolerance instead of bit identity.
            let cv = run_arena(&wl, &mut av, n_sel);
            assert!(
                (cv - cd).abs() <= cd.abs().max(1.0) * 1e-3,
                "scalar/simd diverged at k={k} {label}: {cd} vs {cv}"
            );
            let dense_bytes = wl.nnz * k * 4;
            let arena_bytes = ar.mu.bytes();

            let rd = run(&format!("estep_dense_k{k}_{label}"), budget, || {
                black_box(run_dense(&wl, &mut ds, n_sel));
            });
            let ra = run(&format!("estep_arena_k{k}_{label}"), budget, || {
                black_box(run_arena(&wl, &mut ar, n_sel));
            });
            let rv = run(
                &format!("estep_arena_simd_k{k}_{label}_{}", isa.name()),
                budget,
                || {
                    black_box(run_arena(&wl, &mut av, n_sel));
                },
            );

            for (imp, rep, bytes) in [
                ("dense", &rd, dense_bytes),
                ("arena", &ra, arena_bytes),
                ("arena_simd", &rv, arena_bytes),
            ] {
                println!(
                    "BENCH_estep.json {{\"bench\":\"estep_kernel\",\
                     \"k\":{k},\"subset\":\"{label}\",\"impl\":\"{imp}\",\
                     \"isa\":\"{}\",\
                     \"mean_ns\":{:.0},\"p50_ns\":{:.0},\
                     \"resp_bytes\":{bytes},\"entries\":{},\
                     \"sweeps\":{SWEEPS}}}",
                    if imp == "arena_simd" { isa.name() } else { "scalar" },
                    rep.mean_ns,
                    rep.p50_ns,
                    wl.nnz
                );
            }
            println!(
                "BENCH_estep.json {{\"bench\":\"estep_kernel_summary\",\
                 \"k\":{k},\"subset\":\"{label}\",\
                 \"resp_bytes_ratio\":{:.2},\"speedup\":{:.3},\
                 \"simd_speedup\":{:.3},\"isa\":\"{}\"}}",
                dense_bytes as f64 / arena_bytes as f64,
                rd.mean_ns / ra.mean_ns,
                ra.mean_ns / rv.mean_ns,
                isa.name()
            );
        }
    }
}
