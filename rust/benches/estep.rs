//! Microbenchmarks of the E-step hot paths (the `2K` inner loop of
//! Table 3) — native Rust per-entry E-step across K, the FOEM scheduled
//! variant (cost ~flat in K), and the PJRT-executed AOT kernel when
//! artifacts are present.
//!
//!     cargo bench --bench estep

use foem::util::bench::{black_box, run};
use foem::util::Rng;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(800);
    println!("== E-step per-entry cost vs K (native, full K) ==");
    for &k in &[64usize, 128, 256, 512, 1024] {
        let mut rng = Rng::new(1);
        let theta: Vec<f32> = (0..k).map(|_| rng.next_f32() * 4.0).collect();
        let phi: Vec<f32> = (0..k).map(|_| rng.next_f32() * 2.0).collect();
        let phisum: Vec<f32> =
            (0..k).map(|_| rng.next_f32() * 100.0 + 1.0).collect();
        let mut mu = vec![0.0f32; k];
        run(&format!("estep_full_k{k}"), budget, || {
            let z = foem::em::estep_unnormalized(
                black_box(&theta),
                black_box(&phi),
                black_box(&phisum),
                0.01,
                0.01,
                50.0,
                &mut mu,
            );
            black_box(z);
        });
    }

    println!("\n== FOEM scheduled E-step: 10 topics regardless of K ==");
    for &k in &[64usize, 256, 1024, 4096] {
        let mut rng = Rng::new(2);
        let theta: Vec<f32> = (0..k).map(|_| rng.next_f32() * 4.0).collect();
        let mut phi: Vec<f32> = (0..k).map(|_| rng.next_f32() * 2.0).collect();
        let mut phisum: Vec<f32> =
            (0..k).map(|_| rng.next_f32() * 100.0 + 1.0).collect();
        let mut mu = vec![0.0f32; k];
        // seed mu as a distribution
        let z: f32 = k as f32;
        mu.iter_mut().for_each(|m| *m = 1.0 / z);
        let sel: Vec<u32> = (0..10u32.min(k as u32)).collect();
        let mut theta_l = theta.clone();
        let c = 2.0f32;
        run(&format!("estep_sched10_k{k}"), budget, || {
            // The FOEM inner update on a 10-topic subset (exclude,
            // recompute, Eq. 38 renormalize, include).
            let mut m_old = 0.0f32;
            for &kk in &sel {
                m_old += mu[kk as usize];
            }
            let mut scratch = [0.0f32; 10];
            let mut zs = 0.0f32;
            for (j, &kk) in sel.iter().enumerate() {
                let kk = kk as usize;
                let excl = c * mu[kk];
                let u = (theta_l[kk] - excl + 0.01)
                    * (phi[kk] - excl + 0.01)
                    / (phisum[kk] - excl + 50.0);
                scratch[j] = u.max(0.0);
                zs += scratch[j];
            }
            let renorm = m_old / zs.max(1e-30);
            for (j, &kk) in sel.iter().enumerate() {
                let kk = kk as usize;
                let new = scratch[j] * renorm;
                let delta = c * (new - mu[kk]);
                theta_l[kk] += delta;
                phi[kk] += delta;
                phisum[kk] += delta;
                mu[kk] = new;
            }
            black_box(&mu);
        });
    }

    // PJRT path (blocked dense E-step through the AOT artifact).
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.tsv").exists() {
        println!("\n== PJRT-executed AOT kernel (per [B,K] block) ==");
        let mut exec = foem::runtime::Executor::new(dir).unwrap();
        for k in [64usize, 128, 256] {
            let Some(meta) = exec.estep_variant_for(k) else { continue };
            if meta.k != k {
                continue;
            }
            let (b, kk) = (meta.b, meta.k);
            let mut rng = Rng::new(3);
            let theta: Vec<f32> =
                (0..b * kk).map(|_| rng.next_f32() * 4.0).collect();
            let phi: Vec<f32> =
                (0..b * kk).map(|_| rng.next_f32() * 2.0).collect();
            let phisum: Vec<f32> =
                (0..kk).map(|_| rng.next_f32() * 100.0 + 1.0).collect();
            let counts: Vec<f32> =
                (0..b).map(|_| (rng.below(5) + 1) as f32).collect();
            let name = meta.name.clone();
            run(
                &format!("pjrt_estep_b{b}_k{kk}"),
                Duration::from_secs(2),
                || {
                    let out = exec
                        .run_estep(
                            &name, &theta, &phi, &phisum, &counts, 0.01,
                            0.01, 50.0,
                        )
                        .unwrap();
                    black_box(out.mu.len());
                },
            );
        }
    } else {
        println!("\n(skipping PJRT benches: run `make artifacts`)");
    }
}
