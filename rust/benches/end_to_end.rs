//! End-to-end minibatch benchmarks — one per paper table/figure family:
//! per-minibatch cost of every algorithm at fixed K (Fig. 8's time axis),
//! FOEM across K (Fig. 10's flat-in-K claim), and FOEM with the paged
//! store across buffer sizes (Table 5).
//!
//! (`expfig` runs the full sweeps with convergence + perplexity; these
//! benches isolate steady-state per-minibatch cost for profiling.)
//!
//!     cargo bench --bench end_to_end

use foem::coordinator::config::{Algorithm, RunConfig, StoreKind};
use foem::coordinator::driver::Driver;
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::em::foem::{Foem, FoemConfig};
use foem::store::InMemoryPhi;
use foem::stream::{CorpusStream, StreamConfig};
use foem::util::bench::{black_box, run};
use foem::LdaParams;
use std::time::Duration;

fn main() {
    let mut cfg = SyntheticConfig::enron_like();
    cfg.n_docs = 512;
    let corpus = generate(&cfg, 5);
    let scfg = StreamConfig { minibatch_docs: 256, ..Default::default() };
    let batches: Vec<_> = CorpusStream::new(&corpus, scfg).collect();
    let scale = batches.len() as f64;

    println!("== per-minibatch cost, K=64 (all algorithms) ==");
    for algo_kind in Algorithm::all() {
        let rc = RunConfig {
            algorithm: algo_kind,
            n_topics: 64,
            minibatch_docs: 256,
            store: StoreKind::InMemory,
            seed: 1,
            ..RunConfig::default()
        };
        let mut algo = Driver::new(rc)
            .build_algorithm(corpus.n_words(), scale)
            .unwrap();
        let mut i = 0usize;
        run(
            &format!("minibatch_{}", algo_kind.name()),
            Duration::from_secs(2),
            || {
                let r = algo.process_minibatch(&batches[i % batches.len()]);
                i += 1;
                black_box(r.inner_iters);
            },
        );
    }

    println!("\n== FOEM per-minibatch cost vs K (flat-in-K claim) ==");
    for &k in &[64usize, 128, 256, 512, 1024] {
        let p = LdaParams::paper_defaults(k);
        let mut fc = FoemConfig::paper();
        fc.exact_ll = false;
        fc.max_inner_iters = 10;
        let mut algo =
            Foem::new(p, InMemoryPhi::zeros(k, corpus.n_words()), fc, 1);
        let mut i = 0usize;
        run(&format!("foem_k{k}"), Duration::from_secs(2), || {
            let r = algo.process_minibatch(&batches[i % batches.len()]);
            i += 1;
            black_box(r.inner_iters);
        });
    }

    println!("\n== FOEM + paged store vs buffer size, K=256 (Table 5) ==");
    let k = 256usize;
    for &buf_cols in &[1usize, 64, 512, corpus.n_words()] {
        let dir = foem::util::TempDir::new("bench-e2e");
        let p = LdaParams::paper_defaults(k);
        let mut fc = FoemConfig::paper();
        fc.exact_ll = false;
        fc.max_inner_iters = 10;
        fc.hot_words = buf_cols;
        let mut algo = Foem::paged_create(
            p,
            &dir.path().join("phi.bin"),
            corpus.n_words(),
            buf_cols * k * 4 * 2,
            fc,
            1,
        )
        .unwrap();
        let mut i = 0usize;
        run(
            &format!("foem_paged_buf{buf_cols}"),
            Duration::from_secs(2),
            || {
                let r = algo.process_minibatch(&batches[i % batches.len()]);
                i += 1;
                black_box(r.inner_iters);
            },
        );
    }
}
