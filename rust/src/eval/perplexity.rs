//! Predictive perplexity (paper §2.4, Eq. 21).
//!
//! Protocol: fix the trained `phi_hat`; split each *test* document's
//! tokens 80/20; fold in `theta_hat` on the 80% side (E/M steps on theta
//! only, via the fold-in inference engine [`crate::em::infer`]); evaluate
//!
//!   P = exp( - sum x^{20%} log( sum_k theta_d(k) phi_w(k) ) / sum x^{20%} )
//!
//! on the held-out 20%. Lower is better. This is the measure behind
//! Figs. 9, 11 and 12.
//!
//! The held-out mixture probability accumulates in **f64**: a K-term f32
//! sum loses ~`K·ε` relative accuracy, which is material at K ≥ 1024
//! (cf. the sparsity/precision discussion in Than & Ho, *Inference in
//! topic models: sparsity and trade-off*). Guarded by the all-f64
//! regression test below.

use crate::corpus::sparse::DocWordMatrix;
use crate::em::infer::{self, FoldInConfig};
use crate::em::schedule::TopicSubset;
use crate::em::{PhiAccess, ThetaStats};
use crate::LdaParams;

/// Evaluation protocol parameters. The fold-in fields mirror
/// [`FoldInConfig`]; the defaults reproduce the historical dense
/// protocol exactly (synchronous full-K sweeps, fixed budget, serial).
///
/// # Examples
///
/// The knobs map one-to-one onto the fold-in engine configuration — a
/// scheduled, parallel protocol selects the incremental kernel with a
/// per-document convergence cutoff:
///
/// ```
/// use foem::em::schedule::TopicSubset;
/// use foem::eval::EvalProtocol;
///
/// let proto = EvalProtocol {
///     subset: TopicSubset::Fixed(10),
///     tol: 1e-2,
///     workers: 4,
///     ..Default::default()
/// };
/// let cfg = proto.fold_in_config();
/// assert_eq!(cfg.subset, TopicSubset::Fixed(10));
/// assert_eq!(cfg.n_workers, 4);
/// assert_eq!(cfg.max_sweeps, 50); // the default fold_in_iters budget
///
/// // The defaults are the historical dense reference protocol:
/// // full-K synchronous sweeps, fixed budget, serial.
/// let dense = EvalProtocol::default().fold_in_config();
/// assert_eq!(dense.subset, TopicSubset::All);
/// assert_eq!(dense.tol, 0.0);
/// assert_eq!(dense.n_workers, 1);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct EvalProtocol {
    /// Fold-in sweep budget on the observed 80% (the paper uses up to
    /// 500; the estimate stabilizes far earlier at our scales).
    pub fold_in_iters: usize,
    /// Seed for the 80/20 token split and the fold-in init.
    pub seed: u64,
    /// Topics scheduled per document and sweep during fold-in
    /// (`All` = the historical dense protocol).
    pub subset: TopicSubset,
    /// ε-greedy exploration slots for scheduled fold-in.
    pub explore_slots: usize,
    /// Per-document fold-in convergence cutoff (`0.0` = fixed budget).
    pub tol: f64,
    /// Fold-in worker threads.
    pub workers: usize,
    /// E-step kernel backend for fold-in (`Scalar` = the bit-identity
    /// reference tier).
    pub kernel_backend: crate::em::simd::KernelBackend,
}

impl Default for EvalProtocol {
    fn default() -> Self {
        Self {
            fold_in_iters: 50,
            seed: 0,
            subset: TopicSubset::All,
            explore_slots: 2,
            tol: 0.0,
            workers: 1,
            kernel_backend: crate::em::simd::KernelBackend::Scalar,
        }
    }
}

impl EvalProtocol {
    /// The fold-in engine configuration this protocol induces.
    pub fn fold_in_config(&self) -> FoldInConfig {
        FoldInConfig {
            subset: self.subset,
            explore_slots: self.explore_slots,
            max_sweeps: self.fold_in_iters,
            tol: self.tol,
            n_workers: self.workers.max(1),
            kernel_backend: self.kernel_backend,
        }
    }
}

/// Compute the predictive perplexity of `phi` on `test_docs`.
///
/// `params` must be the smoothing parameterization that matches how `phi`
/// was produced (see `OnlineLda::eval_params`). Generic over
/// [`PhiAccess`], so it evaluates a dense `PhiStats` and a sparse
/// `EvalPhiView` (the paged store's memory-bounded evaluation path)
/// identically — the view only needs the test corpus's columns.
pub fn predictive_perplexity<P: PhiAccess + Sync>(
    phi: &P,
    params: &LdaParams,
    test_docs: &DocWordMatrix,
    protocol: &EvalProtocol,
) -> f64 {
    let (observed, held_out) = test_docs.split_tokens_80_20(protocol.seed);
    let theta = infer::fold_in(
        phi,
        params,
        &observed,
        &protocol.fold_in_config(),
        protocol.seed ^ 0x5EED,
    );
    let (ll, n) = log_likelihood(phi, params, &theta, &held_out);
    crate::em::perplexity(ll, n)
}

/// Log-likelihood of `docs` under `(theta, phi)` — the Eq. 21 numerator,
/// accumulated in f64 (per-token mixture sum AND the theta normalizer).
/// Returns `(log-likelihood, token mass)`; feed it to
/// [`crate::em::perplexity`] for the Eq. 21 outer form.
///
/// `theta` is indexed by document: row `d` scores `docs` row `d`. Shared
/// by the held-out side of [`predictive_perplexity`] and by the serving
/// layer's per-request perplexity ([`crate::serve`]), so the two paths
/// cannot drift numerically.
pub fn log_likelihood<P: PhiAccess>(
    phi: &P,
    params: &LdaParams,
    theta: &ThetaStats,
    docs: &DocWordMatrix,
) -> (f64, f64) {
    let k = params.n_topics;
    let am1 = params.am1();
    let bm1 = params.bm1();
    let wbm1 = params.wbm1(phi.n_words());
    let kam1 = (k as f32 * am1) as f64;
    let phisum = phi.phisum();
    let mut ll = 0.0f64;
    let mut n = 0.0f64;
    for d in 0..docs.n_docs {
        let trow = theta.doc(d);
        let tden = trow.iter().map(|&x| x as f64).sum::<f64>() + kam1;
        if tden <= 0.0 {
            continue;
        }
        for (w, c) in docs.iter_doc(d) {
            let col = phi.word(w as usize);
            let mut p = 0.0f64;
            for i in 0..k {
                p += (trow[i] + am1) as f64 / tden * (col[i] + bm1) as f64
                    / (phisum[i] + wbm1) as f64;
            }
            ll += c as f64 * p.max(1e-300).ln();
            n += c as f64;
        }
    }
    (ll, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::em::bem::Bem;
    use crate::em::{ConvergenceCheck, EvalPhiView, PhiStats};
    use crate::store::PhiColumnStore;

    fn setup() -> (crate::corpus::Corpus, crate::corpus::Corpus) {
        let c = generate(&SyntheticConfig::small(), 81);
        c.split(40, 0)
    }

    #[test]
    fn trained_model_beats_untrained() {
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(10);
        // Untrained phi: tiny uniform mass.
        let mut phi0 = PhiStats::zeros(10, train.n_words());
        for w in 0..train.n_words() {
            phi0.add_to_word(w, &vec![0.01; 10]);
        }
        let proto = EvalProtocol::default();
        let ppx0 = predictive_perplexity(&phi0, &p, &test.docs, &proto);

        let mut bem = Bem::init(&train.docs, p, 0);
        let mut check = ConvergenceCheck::new(5.0, 5, 100);
        bem.train(&train.docs, &mut check);
        let ppx1 = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
        assert!(
            ppx1 < ppx0 * 0.9,
            "trained {ppx1} not clearly better than uniform {ppx0}"
        );
    }

    #[test]
    fn perplexity_bounded_by_vocab() {
        // A uniform predictive distribution gives perplexity == W; any
        // model should be in (1, W * slack).
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(5);
        let mut bem = Bem::init(&train.docs, p, 1);
        for _ in 0..10 {
            bem.sweep(&train.docs);
        }
        let ppx = predictive_perplexity(
            &bem.phi,
            &p,
            &test.docs,
            &EvalProtocol::default(),
        );
        assert!(ppx > 1.0);
        assert!(ppx < train.n_words() as f64 * 2.0, "{ppx}");
    }

    #[test]
    fn protocol_is_deterministic() {
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(5);
        let mut bem = Bem::init(&train.docs, p, 1);
        for _ in 0..5 {
            bem.sweep(&train.docs);
        }
        let proto = EvalProtocol::default();
        let a = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
        let b = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
        assert_eq!(a, b);
    }

    #[test]
    fn sparse_view_evaluates_identically_to_dense() {
        // The driver's memory-bounded evaluation path (EvalPhiView over
        // just the test vocabulary) must reproduce the dense result
        // bit-for-bit: same fold-in, same held-out likelihood.
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(6);
        let mut bem = Bem::init(&train.docs, p, 4);
        for _ in 0..8 {
            bem.sweep(&train.docs);
        }
        let proto = EvalProtocol::default();
        let dense = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
        let test_words = test.docs.distinct_words();
        let view = EvalPhiView::from_dense(&bem.phi, &test_words);
        let sparse = predictive_perplexity(&view, &p, &test.docs, &proto);
        assert_eq!(dense, sparse);
    }

    /// Satellite: eval through the *paged* store. A `PagedPhi`-backed
    /// `EvalPhiView` must evaluate bit-identically to the dense matrix,
    /// and its fold-in column reads must show up in `IoStats`.
    #[test]
    fn paged_store_view_evaluates_identically_and_counts_io() {
        let (train, test) = setup();
        let k = 6;
        let p = LdaParams::paper_defaults(k);
        let mut bem = Bem::init(&train.docs, p, 4);
        for _ in 0..8 {
            bem.sweep(&train.docs);
        }
        // Mirror the trained phi into a disk-backed store.
        let dir = crate::util::TempDir::new("eval-paged");
        let mut store = crate::store::paged::PagedPhi::create(
            &dir.path().join("phi.bin"),
            k,
            train.n_words(),
            8 * k * 4,
        )
        .unwrap();
        for w in 0..train.n_words() {
            store.store_column(w, bem.phi.word(w));
        }
        store.flush().unwrap();

        let test_words = test.docs.distinct_words();
        let before = store.io_stats();
        let snap = store.snapshot_columns(&test_words);
        let io = store.io_stats();
        assert!(
            io.col_reads + io.buffer_hits
                >= before.col_reads + before.buffer_hits
                    + test_words.len() as u64,
            "eval snapshot reads not accounted: {io:?} (before {before:?})"
        );
        let view = EvalPhiView::from_snapshot(
            snap,
            bem.phi.phisum.clone(),
            train.n_words(),
        );

        let proto = EvalProtocol::default();
        let dense = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
        let paged = predictive_perplexity(&view, &p, &test.docs, &proto);
        assert_eq!(dense, paged);
    }

    /// The acceptance invariant: the engine's `TopicSubset::All` + one
    /// worker configuration reproduces the retained dense reference
    /// (`em::infer::dense_ref`) bit-for-bit, through to the perplexity.
    #[test]
    fn engine_all_serial_bit_identical_to_dense_reference() {
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(8);
        let mut bem = Bem::init(&train.docs, p, 2);
        for _ in 0..8 {
            bem.sweep(&train.docs);
        }
        let proto = EvalProtocol { fold_in_iters: 25, ..Default::default() };
        let engine = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);

        let (observed, held_out) =
            test.docs.split_tokens_80_20(proto.seed);
        let theta = crate::em::infer::dense_ref::fold_in(
            &bem.phi,
            &p,
            &observed,
            proto.fold_in_iters,
            proto.seed ^ 0x5EED,
        );
        let (ll, n) =
            log_likelihood(&bem.phi, &p, &theta, &held_out);
        let reference = crate::em::perplexity(ll, n);
        assert_eq!(engine, reference);
    }

    /// The acceptance tolerance: scheduled and parallel fold-in stay
    /// within 2% relative perplexity of the dense serial protocol.
    #[test]
    fn scheduled_and_parallel_fold_in_within_two_percent() {
        let (train, test) = setup();
        let k = 24;
        let p = LdaParams::paper_defaults(k);
        let mut bem = Bem::init(&train.docs, p, 6);
        for _ in 0..20 {
            bem.sweep(&train.docs);
        }
        let dense = predictive_perplexity(
            &bem.phi,
            &p,
            &test.docs,
            &EvalProtocol { fold_in_iters: 80, ..Default::default() },
        );
        let variants = [
            // scheduled, serial
            EvalProtocol {
                fold_in_iters: 80,
                subset: TopicSubset::Fixed(10),
                explore_slots: 4,
                ..Default::default()
            },
            // dense, parallel (per-shard init streams)
            EvalProtocol { fold_in_iters: 80, workers: 4, ..Default::default() },
            // scheduled, parallel
            EvalProtocol {
                fold_in_iters: 80,
                subset: TopicSubset::Fixed(10),
                explore_slots: 4,
                workers: 4,
                ..Default::default()
            },
        ];
        for proto in variants {
            let ppx = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
            assert!(
                (ppx - dense).abs() < dense * 0.02,
                "{proto:?}: {ppx} vs dense {dense}"
            );
        }
    }

    /// The SIMD acceptance tolerance: fold-in under the `Simd` backend
    /// (AVX2 where detected, portable-unrolled elsewhere) stays within 2%
    /// relative perplexity of the scalar dense protocol, in every engine
    /// configuration the eval path can select.
    #[test]
    fn simd_fold_in_within_two_percent_of_scalar() {
        use crate::em::simd::KernelBackend;
        let (train, test) = setup();
        let k = 24;
        let p = LdaParams::paper_defaults(k);
        let mut bem = Bem::init(&train.docs, p, 6);
        for _ in 0..20 {
            bem.sweep(&train.docs);
        }
        let dense = predictive_perplexity(
            &bem.phi,
            &p,
            &test.docs,
            &EvalProtocol { fold_in_iters: 80, ..Default::default() },
        );
        let variants = [
            // dense layout, serial, SIMD
            EvalProtocol {
                fold_in_iters: 80,
                kernel_backend: KernelBackend::Simd,
                ..Default::default()
            },
            // scheduled (slot-compressed arena), serial, SIMD
            EvalProtocol {
                fold_in_iters: 80,
                subset: TopicSubset::Fixed(10),
                explore_slots: 4,
                kernel_backend: KernelBackend::Simd,
                ..Default::default()
            },
            // scheduled, parallel, auto-dispatched
            EvalProtocol {
                fold_in_iters: 80,
                subset: TopicSubset::Fixed(10),
                explore_slots: 4,
                workers: 4,
                kernel_backend: KernelBackend::Auto,
                ..Default::default()
            },
        ];
        for proto in variants {
            let ppx = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
            assert!(
                (ppx - dense).abs() < dense * 0.02,
                "{proto:?}: {ppx} vs dense {dense}"
            );
        }
    }

    /// Satellite regression: the held-out likelihood must match an
    /// all-f64 reference to ~f32-input precision at K = 1024 (the f32
    /// accumulation it replaces drifted orders of magnitude more).
    #[test]
    fn f64_accumulation_matches_reference_at_k1024() {
        let k = 1024usize;
        let w = 64usize;
        let p = LdaParams::paper_defaults(k);
        let mut rng = crate::util::Rng::new(5);
        // Phi and theta with magnitudes spread over several decades so an
        // f32 sum visibly loses low-order terms.
        let mut phi = PhiStats::zeros(k, w);
        for ww in 0..w {
            let col: Vec<f32> = (0..k)
                .map(|_| 10f32.powf(rng.next_f32() * 4.0 - 2.0))
                .collect();
            phi.add_to_word(ww, &col);
        }
        let mut theta = ThetaStats::zeros(k, 3);
        for d in 0..3 {
            let row = theta.doc_mut(d);
            for x in row.iter_mut() {
                *x = 10f32.powf(rng.next_f32() * 4.0 - 2.0);
            }
        }
        let rows: Vec<Vec<(u32, f32)>> = (0..3)
            .map(|d| (0..8).map(|i| ((d * 8 + i) as u32, 2.0f32)).collect())
            .collect();
        let refs: Vec<&[(u32, f32)]> =
            rows.iter().map(|r| r.as_slice()).collect();
        let held = DocWordMatrix::from_rows(w, &refs);

        let (ll, n) = log_likelihood(&phi, &p, &theta, &held);

        // All-f64 reference, computed independently.
        let am1 = p.am1() as f64;
        let bm1 = p.bm1() as f64;
        let wbm1 = p.wbm1(w) as f64;
        let mut ll_ref = 0.0f64;
        let mut n_ref = 0.0f64;
        for d in 0..held.n_docs {
            let trow = theta.doc(d);
            let tden: f64 = trow.iter().map(|&x| x as f64).sum::<f64>()
                + k as f64 * am1;
            for (ww, c) in held.iter_doc(d) {
                let col = phi.word(ww as usize);
                let mut prob = 0.0f64;
                for i in 0..k {
                    prob += (trow[i] as f64 + am1) / tden
                        * (col[i] as f64 + bm1)
                        / (phi.phisum[i] as f64 + wbm1);
                }
                ll_ref += c as f64 * prob.max(1e-300).ln();
                n_ref += c as f64;
            }
        }
        assert_eq!(n, n_ref);
        // The production path differs from the reference only by the f32
        // `+am1`/`+bm1` pre-adds (~1e-7 relative per factor); the f32
        // *accumulation* this test guards against drifted ~K·ε ≈ 1e-4
        // on the mixture sum — orders of magnitude outside this bound.
        assert!(
            (ll - ll_ref).abs() <= ll_ref.abs() * 1e-6,
            "held-out LL drifted from f64 reference: {ll} vs {ll_ref}"
        );
    }

    #[test]
    fn more_training_lowers_perplexity() {
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(10);
        let mut bem = Bem::init(&train.docs, p, 3);
        bem.sweep(&train.docs);
        let early = predictive_perplexity(
            &bem.phi,
            &p,
            &test.docs,
            &EvalProtocol::default(),
        );
        for _ in 0..30 {
            bem.sweep(&train.docs);
        }
        let late = predictive_perplexity(
            &bem.phi,
            &p,
            &test.docs,
            &EvalProtocol::default(),
        );
        assert!(late < early, "{late} !< {early}");
    }
}
