//! Predictive perplexity (paper §2.4, Eq. 21).
//!
//! Protocol: fix the trained `phi_hat`; split each *test* document's
//! tokens 80/20; fold in `theta_hat` on the 80% side (E/M steps on theta
//! only); evaluate
//!
//!   P = exp( - sum x^{20%} log( sum_k theta_d(k) phi_w(k) ) / sum x^{20%} )
//!
//! on the held-out 20%. Lower is better. This is the measure behind
//! Figs. 9, 11 and 12.

use crate::corpus::sparse::DocWordMatrix;
use crate::em::bem::Bem;
use crate::em::PhiAccess;
use crate::LdaParams;

/// Evaluation protocol parameters.
#[derive(Debug, Clone, Copy)]
pub struct EvalProtocol {
    /// Fold-in sweeps on the observed 80% (the paper uses up to 500; the
    /// estimate stabilizes far earlier at our scales).
    pub fold_in_iters: usize,
    /// Seed for the 80/20 token split and the fold-in init.
    pub seed: u64,
}

impl Default for EvalProtocol {
    fn default() -> Self {
        Self { fold_in_iters: 50, seed: 0 }
    }
}

/// Compute the predictive perplexity of `phi` on `test_docs`.
///
/// `params` must be the smoothing parameterization that matches how `phi`
/// was produced (see `OnlineLda::eval_params`). Generic over
/// [`PhiAccess`], so it evaluates a dense `PhiStats` and a sparse
/// `EvalPhiView` (the paged store's memory-bounded evaluation path)
/// identically — the view only needs the test corpus's columns.
pub fn predictive_perplexity<P: PhiAccess>(
    phi: &P,
    params: &LdaParams,
    test_docs: &DocWordMatrix,
    protocol: &EvalProtocol,
) -> f64 {
    let (observed, held_out) = test_docs.split_tokens_80_20(protocol.seed);
    let theta = Bem::fold_in(
        phi,
        params,
        &observed,
        protocol.fold_in_iters,
        protocol.seed ^ 0x5EED,
    );

    let k = params.n_topics;
    let am1 = params.am1();
    let bm1 = params.bm1();
    let wbm1 = params.wbm1(phi.n_words());
    let kam1 = k as f32 * am1;
    let phisum = phi.phisum();
    let mut ll = 0.0f64;
    let mut n = 0.0f64;
    for d in 0..held_out.n_docs {
        let trow = theta.doc(d);
        let tden = trow.iter().sum::<f32>() + kam1;
        if tden <= 0.0 {
            continue;
        }
        for (w, c) in held_out.iter_doc(d) {
            let col = phi.word(w as usize);
            let mut p = 0.0f32;
            for i in 0..k {
                p += (trow[i] + am1) / tden * (col[i] + bm1)
                    / (phisum[i] + wbm1);
            }
            ll += c as f64 * (p.max(1e-30) as f64).ln();
            n += c as f64;
        }
    }
    crate::em::perplexity(ll, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::em::bem::Bem;
    use crate::em::{ConvergenceCheck, EvalPhiView, PhiStats};

    fn setup() -> (crate::corpus::Corpus, crate::corpus::Corpus) {
        let c = generate(&SyntheticConfig::small(), 81);
        c.split(40, 0)
    }

    #[test]
    fn trained_model_beats_untrained() {
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(10);
        // Untrained phi: tiny uniform mass.
        let mut phi0 = PhiStats::zeros(10, train.n_words());
        for w in 0..train.n_words() {
            phi0.add_to_word(w, &vec![0.01; 10]);
        }
        let proto = EvalProtocol::default();
        let ppx0 = predictive_perplexity(&phi0, &p, &test.docs, &proto);

        let mut bem = Bem::init(&train.docs, p, 0);
        let mut check = ConvergenceCheck::new(5.0, 5, 100);
        bem.train(&train.docs, &mut check);
        let ppx1 = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
        assert!(
            ppx1 < ppx0 * 0.9,
            "trained {ppx1} not clearly better than uniform {ppx0}"
        );
    }

    #[test]
    fn perplexity_bounded_by_vocab() {
        // A uniform predictive distribution gives perplexity == W; any
        // model should be in (1, W * slack).
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(5);
        let mut bem = Bem::init(&train.docs, p, 1);
        for _ in 0..10 {
            bem.sweep(&train.docs);
        }
        let ppx = predictive_perplexity(
            &bem.phi,
            &p,
            &test.docs,
            &EvalProtocol::default(),
        );
        assert!(ppx > 1.0);
        assert!(ppx < train.n_words() as f64 * 2.0, "{ppx}");
    }

    #[test]
    fn protocol_is_deterministic() {
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(5);
        let mut bem = Bem::init(&train.docs, p, 1);
        for _ in 0..5 {
            bem.sweep(&train.docs);
        }
        let proto = EvalProtocol::default();
        let a = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
        let b = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
        assert_eq!(a, b);
    }

    #[test]
    fn sparse_view_evaluates_identically_to_dense() {
        // The driver's memory-bounded evaluation path (EvalPhiView over
        // just the test vocabulary) must reproduce the dense result
        // bit-for-bit: same fold-in, same held-out likelihood.
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(6);
        let mut bem = Bem::init(&train.docs, p, 4);
        for _ in 0..8 {
            bem.sweep(&train.docs);
        }
        let proto = EvalProtocol::default();
        let dense = predictive_perplexity(&bem.phi, &p, &test.docs, &proto);
        let test_words = test.docs.distinct_words();
        let view = EvalPhiView::from_dense(&bem.phi, &test_words);
        let sparse = predictive_perplexity(&view, &p, &test.docs, &proto);
        assert_eq!(dense, sparse);
    }

    #[test]
    fn more_training_lowers_perplexity() {
        let (train, test) = setup();
        let p = LdaParams::paper_defaults(10);
        let mut bem = Bem::init(&train.docs, p, 3);
        bem.sweep(&train.docs);
        let early = predictive_perplexity(
            &bem.phi,
            &p,
            &test.docs,
            &EvalProtocol::default(),
        );
        for _ in 0..30 {
            bem.sweep(&train.docs);
        }
        let late = predictive_perplexity(
            &bem.phi,
            &p,
            &test.docs,
            &EvalProtocol::default(),
        );
        assert!(late < early, "{late} !< {early}");
    }
}
