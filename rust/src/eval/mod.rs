//! Evaluation harness: the paper's predictive-perplexity protocol (§2.4)
//! and topic-quality diagnostics.

pub mod perplexity;

pub use perplexity::{log_likelihood, predictive_perplexity, EvalProtocol};
