//! Special functions needed by the VB-family baselines (OVB, RVB, SOI):
//! the digamma function Ψ(x) and exp(Ψ(x)).
//!
//! The paper's complexity analysis (Table 3) charges VB a `digamma`
//! multiplier per E-step coordinate — these routines ARE that cost, so
//! they are implemented carefully but without lookup-table tricks that
//! would distort the comparison.

/// Digamma Ψ(x) for x > 0 via upward recurrence + asymptotic series.
/// Max abs error < 1e-9 for x >= 1e-3 (tested against reference values).
pub fn digamma(x: f64) -> f64 {
    debug_assert!(x > 0.0, "digamma domain: x > 0, got {x}");
    let mut x = x;
    let mut result = 0.0;
    // Recurrence Ψ(x) = Ψ(x+1) - 1/x until x >= 6.
    while x < 6.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    // Asymptotic: Ψ(x) ~ ln x - 1/(2x) - Σ B_2n / (2n x^{2n}).
    let f = 1.0 / (x * x);
    result + x.ln() - 0.5 / x
        - f * (1.0 / 12.0
            - f * (1.0 / 120.0
                - f * (1.0 / 252.0
                    - f * (1.0 / 240.0 - f * (1.0 / 132.0)))))
}

/// `exp(Ψ(x))` — the quantity OVB's E-step actually multiplies (Eq. 23).
#[inline]
pub fn exp_digamma(x: f64) -> f64 {
    digamma(x).exp()
}

/// Fill `out[i] = exp(Ψ(xs[i]))` (vector form for column updates).
pub fn exp_digamma_slice(xs: &[f32], out: &mut [f32]) {
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = exp_digamma(x.max(1e-8) as f64) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digamma_known_values() {
        // Reference values (SciPy):
        let cases = [
            (1.0, -0.5772156649015329), // -EulerGamma
            (0.5, -1.9635100260214235),
            (2.0, 0.42278433509846713),
            (10.0, 2.2517525890667214),
            (100.0, 4.600161852738087),
            (0.01, -100.56088545786867),
        ];
        for (x, want) in cases {
            let got = digamma(x);
            assert!(
                (got - want).abs() < 1e-8,
                "digamma({x}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn digamma_recurrence_identity() {
        // Ψ(x+1) = Ψ(x) + 1/x
        for &x in &[0.1, 0.7, 1.5, 3.3, 12.0] {
            let lhs = digamma(x + 1.0);
            let rhs = digamma(x) + 1.0 / x;
            assert!((lhs - rhs).abs() < 1e-10, "x={x}");
        }
    }

    #[test]
    fn digamma_monotone_increasing() {
        let mut prev = digamma(0.05);
        for i in 1..200 {
            let x = 0.05 + i as f64 * 0.5;
            let cur = digamma(x);
            assert!(cur > prev);
            prev = cur;
        }
    }

    #[test]
    fn exp_digamma_slice_matches_scalar() {
        let xs = [0.5f32, 1.0, 7.25, 42.0];
        let mut out = [0.0f32; 4];
        exp_digamma_slice(&xs, &mut out);
        for (i, &x) in xs.iter().enumerate() {
            assert!((out[i] as f64 - exp_digamma(x as f64)).abs() < 1e-6);
        }
    }
}
