//! Sparse stochastic Online Inference — the paper's "SOI" comparator
//! (Mimno, Hoffman & Blei, ICML 2012: "Sparse stochastic inference for
//! latent Dirichlet allocation").
//!
//! SOI is the hybrid of OVB and OGS (§2.5): the *local* step samples
//! topic assignments per document with collapsed Gibbs against
//! `exp(E[log beta])` (so the per-token cost is sampling, not a dense
//! digamma vector per word), and the *global* step is the OVB
//! natural-gradient lambda update driven by the *sampled, sparse*
//! sufficient statistics — only the (word, topic) pairs that were
//! actually sampled are touched, roughly halving OVB's per-minibatch
//! cost (the paper: "SOI uses around half of the OVB's training
//! convergence time").

use super::special::digamma;
use super::OnlineLda;
use crate::em::sem::LearningRate;
use crate::em::{MinibatchReport, PhiStats};
use crate::stream::Minibatch;
use crate::util::{Rng, Timer};
use crate::LdaParams;

/// SOI hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct SoiConfig {
    pub alpha: f32,
    pub eta: f32,
    pub rate: LearningRate,
    pub scale_s: f64,
    /// Gibbs sweeps per document (burn-in + sample).
    pub sweeps: usize,
}

impl SoiConfig {
    pub fn paper(scale_s: f64) -> Self {
        Self {
            alpha: 0.01,
            eta: 0.01,
            rate: LearningRate::paper(),
            scale_s,
            sweeps: 5,
        }
    }
}

/// SOI trainer.
pub struct Soi {
    pub k: usize,
    pub n_words: usize,
    pub cfg: SoiConfig,
    /// Variational Dirichlet parameters over topic-word distributions.
    pub lambda: PhiStats,
    pub step: usize,
    rng: Rng,
    params: LdaParams,
}

impl Soi {
    pub fn new(k: usize, n_words: usize, cfg: SoiConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut lambda = PhiStats::zeros(k, n_words);
        for w in 0..n_words {
            let mut col = vec![0.0f32; k];
            for x in col.iter_mut() {
                *x = (rng.gamma(100.0) / 100.0) as f32;
            }
            lambda.add_to_word(w, &col);
        }
        Self {
            k,
            n_words,
            cfg,
            lambda,
            step: 0,
            rng,
            params: LdaParams {
                n_topics: k,
                alpha: 1.0 + cfg.alpha,
                beta: 1.0 + cfg.eta,
            },
        }
    }
}

impl OnlineLda for Soi {
    fn name(&self) -> &'static str {
        "SOI"
    }

    fn params(&self) -> &LdaParams {
        &self.params
    }

    fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport {
        let timer = Timer::start();
        let k = self.k;
        let alpha = self.cfg.alpha;
        self.step += 1;
        let docs = &mb.docs;
        let tokens = docs.total_tokens();

        // exp(E[log beta]) rows for local words (one digamma pass — the
        // savings relative to OVB come from the sampled local step).
        let local_index: std::collections::HashMap<u32, usize> = mb
            .local_words
            .iter()
            .enumerate()
            .map(|(i, &w)| (w, i))
            .collect();
        let mut psi_sum = vec![0.0f64; k];
        for (kk, &s) in self.lambda.phisum.iter().enumerate() {
            psi_sum[kk] = digamma((s as f64).max(1e-8));
        }
        let mut elog_beta = vec![0.0f32; mb.local_words.len() * k];
        for (lw, &w) in mb.local_words.iter().enumerate() {
            let col = self.lambda.word(w as usize);
            let row = &mut elog_beta[lw * k..(lw + 1) * k];
            for kk in 0..k {
                row[kk] = (digamma((col[kk] as f64).max(1e-8)) - psi_sum[kk])
                    .exp() as f32;
            }
        }

        // Sampled sparse sufficient statistics.
        let mut sstats = vec![0.0f32; mb.local_words.len() * k];
        let mut touched = vec![false; mb.local_words.len() * k];
        let mut ll = 0.0f64;
        let mut weights = vec![0.0f32; k];

        for d in 0..docs.n_docs {
            let words = docs.doc_words(d);
            let counts = docs.doc_counts(d);
            // Token expansion for the Gibbs local step.
            let mut tok_word_lw: Vec<u32> = Vec::new();
            for (&w, &c) in words.iter().zip(counts) {
                let lw = local_index[&w] as u32;
                for _ in 0..c.round() as usize {
                    tok_word_lw.push(lw);
                }
            }
            let n_tok = tok_word_lw.len();
            if n_tok == 0 {
                continue;
            }
            let mut z = vec![0u32; n_tok];
            let mut ndk = vec![0.0f32; k];
            for i in 0..n_tok {
                let t = self.rng.below(k) as u32;
                z[i] = t;
                ndk[t as usize] += 1.0;
            }
            for sweep in 0..self.cfg.sweeps {
                let last = sweep + 1 == self.cfg.sweeps;
                for i in 0..n_tok {
                    let lw = tok_word_lw[i] as usize;
                    let old = z[i] as usize;
                    ndk[old] -= 1.0;
                    let row = &elog_beta[lw * k..(lw + 1) * k];
                    let mut zsum = 0.0f32;
                    for kk in 0..k {
                        let wgt = (ndk[kk] + alpha) * row[kk];
                        weights[kk] = wgt;
                        zsum += wgt;
                    }
                    let new = self.rng.categorical(&weights);
                    z[i] = new as u32;
                    ndk[new] += 1.0;
                    if last {
                        sstats[lw * k + new] += 1.0;
                        touched[lw * k + new] = true;
                        let doc_mass =
                            (n_tok as f32 - 1.0) + k as f32 * alpha;
                        ll += ((zsum / doc_mass) as f64).max(1e-300).ln();
                    }
                }
            }
        }

        // Sparse global natural-gradient step: only touched coordinates
        // move toward the stochastic target; the decay toward the prior
        // is applied densely (cheap: two fused scalar passes).
        let rho = self.cfg.rate.rho(self.step) as f32;
        let scale = self.cfg.scale_s as f32;
        let eta = self.cfg.eta;
        self.lambda.raw_mut().iter_mut().for_each(|x| {
            *x = (1.0 - rho) * *x + rho * eta;
        });
        self.lambda
            .phisum
            .iter_mut()
            .for_each(|x| *x = (1.0 - rho) * *x + rho * eta * 1.0);
        // phisum decay must account for all W words' prior mass:
        let extra_prior = rho * eta * (self.n_words as f32 - 1.0);
        self.lambda.phisum.iter_mut().for_each(|x| *x += extra_prior);
        for (lw, &w) in mb.local_words.iter().enumerate() {
            let row = &sstats[lw * k..(lw + 1) * k];
            let hit = &touched[lw * k..(lw + 1) * k];
            let (col, phisum) = self.lambda.word_and_sum_mut(w as usize);
            for kk in 0..k {
                if hit[kk] {
                    let v = rho * scale * row[kk];
                    col[kk] += v;
                    phisum[kk] += v;
                }
            }
        }

        MinibatchReport {
            inner_iters: self.cfg.sweeps,
            seconds: timer.seconds(),
            train_ll: ll,
            tokens,
            ..Default::default()
        }
    }

    fn export_phi(&mut self) -> PhiStats {
        let mut phi = PhiStats::zeros(self.k, self.n_words);
        let eta = self.cfg.eta;
        for w in 0..self.n_words {
            let col: Vec<f32> = self
                .lambda
                .word(w)
                .iter()
                .map(|&x| (x - eta).max(0.0))
                .collect();
            phi.add_to_word(w, &col);
        }
        phi
    }

    fn eval_params(&self) -> LdaParams {
        self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::stream::{CorpusStream, StreamConfig};

    fn scfg() -> StreamConfig {
        StreamConfig { minibatch_docs: 64, ..Default::default() }
    }

    #[test]
    fn lambda_stays_positive_finite() {
        let c = generate(&SyntheticConfig::small(), 71);
        let s = CorpusStream::new(&c, scfg()).batches_per_pass() as f64;
        let mut soi = Soi::new(6, c.n_words(), SoiConfig::paper(s), 0);
        for mb in CorpusStream::new(&c, scfg()) {
            let r = soi.process_minibatch(&mb);
            assert!(r.train_ll.is_finite());
        }
        assert!(soi.lambda.raw().iter().all(|&x| x.is_finite() && x >= 0.0));
    }

    #[test]
    fn phisum_consistent() {
        let c = generate(&SyntheticConfig::small(), 72);
        let s = CorpusStream::new(&c, scfg()).batches_per_pass() as f64;
        let mut soi = Soi::new(4, c.n_words(), SoiConfig::paper(s), 0);
        for mb in CorpusStream::new(&c, scfg()) {
            soi.process_minibatch(&mb);
        }
        let mut rebuilt = soi.lambda.clone();
        rebuilt.rebuild_phisum();
        for kk in 0..4 {
            assert!(
                (soi.lambda.phisum[kk] - rebuilt.phisum[kk]).abs()
                    < rebuilt.phisum[kk].abs().max(1.0) * 1e-3,
                "k={kk}: {} vs {}",
                soi.lambda.phisum[kk],
                rebuilt.phisum[kk]
            );
        }
    }

    #[test]
    fn fit_improves_with_passes() {
        let c = generate(&SyntheticConfig::small(), 73);
        let cfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
        let s = CorpusStream::new(&c, cfg).batches_per_pass() as f64;
        let mut soi = Soi::new(8, c.n_words(), SoiConfig::paper(s), 1);
        let mb0 = CorpusStream::new(&c, cfg).next().unwrap();
        let early = soi.process_minibatch(&mb0).train_ll;
        for _ in 0..3 {
            for mb in CorpusStream::new(&c, cfg) {
                soi.process_minibatch(&mb);
            }
        }
        let late = soi.process_minibatch(&mb0).train_ll;
        assert!(late > early, "{late} !> {early}");
    }
}
