//! Online (collapsed) Gibbs sampling for LDA — the paper's "OGS"
//! comparator (Yao, Mimno & McCallum, KDD 2009).
//!
//! Per minibatch, every document's word *tokens* get topic assignments by
//! collapsed Gibbs sweeps against the global topic-word counts (the
//! paper's Eqs. 27-30: MCMC E-step samples `z` from
//! `(n_dk^{-i}+alpha)(phi_wk+beta)/(phi_k + W*beta)`), then the sampled
//! counts take a stepwise step into the global matrix like SEM (the
//! "sparse GS + stochastic gradients" combination of §2.5).
//!
//! Token-level sampling makes the cost `O(K * ntokens)` per sweep
//! (Table 3), slightly different from the NNZ-based EM family.

use super::OnlineLda;
use crate::em::sem::LearningRate;
use crate::em::{MinibatchReport, PhiStats};
use crate::stream::Minibatch;
use crate::util::{Rng, Timer};
use crate::LdaParams;

/// OGS hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct OgsConfig {
    pub alpha: f32,
    pub beta: f32,
    pub rate: LearningRate,
    pub scale_s: f64,
    /// Gibbs sweeps per minibatch (burn-in + 1 sample sweep).
    pub sweeps: usize,
}

impl OgsConfig {
    pub fn paper(scale_s: f64) -> Self {
        Self {
            alpha: 0.01,
            beta: 0.01,
            rate: LearningRate::paper(),
            scale_s,
            sweeps: 6,
        }
    }
}

/// Online Gibbs trainer.
pub struct Ogs {
    pub k: usize,
    pub n_words: usize,
    pub cfg: OgsConfig,
    /// Global expected topic-word counts.
    pub phi: PhiStats,
    pub step: usize,
    rng: Rng,
    params: LdaParams,
}

impl Ogs {
    pub fn new(k: usize, n_words: usize, cfg: OgsConfig, seed: u64) -> Self {
        Self {
            k,
            n_words,
            cfg,
            phi: PhiStats::zeros(k, n_words),
            step: 0,
            rng: Rng::new(seed),
            params: LdaParams {
                n_topics: k,
                alpha: 1.0 + cfg.alpha,
                beta: 1.0 + cfg.beta,
            },
        }
    }
}

impl OnlineLda for Ogs {
    fn name(&self) -> &'static str {
        "OGS"
    }

    fn params(&self) -> &LdaParams {
        &self.params
    }

    fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport {
        let timer = Timer::start();
        let k = self.k;
        let alpha = self.cfg.alpha;
        let beta = self.cfg.beta;
        let wbeta = self.n_words as f32 * beta;
        self.step += 1;
        let docs = &mb.docs;
        let tokens = docs.total_tokens();

        // Expand entries to tokens: (doc, word) per token; assignments z.
        let mut tok_doc: Vec<u32> = Vec::new();
        let mut tok_word: Vec<u32> = Vec::new();
        for d in 0..docs.n_docs {
            for (w, c) in docs.iter_doc(d) {
                for _ in 0..c.round() as usize {
                    tok_doc.push(d as u32);
                    tok_word.push(w);
                }
            }
        }
        let n_tok = tok_doc.len();
        let mut z = vec![0u32; n_tok];
        // Local doc-topic counts.
        let mut ndk = vec![0.0f32; docs.n_docs * k];
        // Minibatch topic-word sample counts (local words only).
        let local_index: std::collections::HashMap<u32, usize> = mb
            .local_words
            .iter()
            .enumerate()
            .map(|(i, &w)| (w, i))
            .collect();
        let mut nwk = vec![0.0f32; mb.local_words.len() * k];
        let mut nk = vec![0.0f32; k];

        // Random init assignments.
        for i in 0..n_tok {
            let t = self.rng.below(k) as u32;
            z[i] = t;
            ndk[tok_doc[i] as usize * k + t as usize] += 1.0;
            let lw = local_index[&tok_word[i]];
            nwk[lw * k + t as usize] += 1.0;
            nk[t as usize] += 1.0;
        }

        // Collapsed Gibbs sweeps. The *global* phi is frozen (it is the
        // stream prior); the minibatch's own counts are collapsed out.
        let mut weights = vec![0.0f32; k];
        let mut ll = 0.0f64;
        for sweep in 0..self.cfg.sweeps {
            ll = 0.0;
            for i in 0..n_tok {
                let d = tok_doc[i] as usize;
                let w = tok_word[i] as usize;
                let lw = local_index[&tok_word[i]];
                let old = z[i] as usize;
                // exclude token i
                ndk[d * k + old] -= 1.0;
                nwk[lw * k + old] -= 1.0;
                nk[old] -= 1.0;
                let gcol = self.phi.word(w);
                let mut zsum = 0.0f32;
                for kk in 0..k {
                    let wgt = (ndk[d * k + kk] + alpha)
                        * (gcol[kk] + nwk[lw * k + kk] + beta)
                        / (self.phi.phisum[kk] + nk[kk] + wbeta);
                    weights[kk] = wgt;
                    zsum += wgt;
                }
                let new = self.rng.categorical(&weights);
                z[i] = new as u32;
                ndk[d * k + new] += 1.0;
                nwk[lw * k + new] += 1.0;
                nk[new] += 1.0;
                if sweep + 1 == self.cfg.sweeps {
                    // Unnormalized token likelihood, normalized by the
                    // theta-mass like the EM family so magnitudes match.
                    let doc_mass = docs.doc_len(d) - 1.0 + k as f32 * alpha;
                    ll += ((zsum / doc_mass) as f64).max(1e-300).ln();
                }
            }
        }

        // Stepwise global update from the sampled counts (Eq. 20 analog).
        let rho = self.cfg.rate.rho(self.step) as f32;
        let scale = self.cfg.scale_s as f32 * rho;
        self.phi.raw_mut().iter_mut().for_each(|x| *x *= 1.0 - rho);
        self.phi.phisum.iter_mut().for_each(|x| *x *= 1.0 - rho);
        for (lw, &w) in mb.local_words.iter().enumerate() {
            let row = &nwk[lw * k..(lw + 1) * k];
            let (col, phisum) = self.phi.word_and_sum_mut(w as usize);
            for kk in 0..k {
                let v = scale * row[kk];
                col[kk] += v;
                phisum[kk] += v;
            }
        }

        MinibatchReport {
            inner_iters: self.cfg.sweeps,
            seconds: timer.seconds(),
            train_ll: ll,
            tokens,
            ..Default::default()
        }
    }

    fn export_phi(&mut self) -> PhiStats {
        self.phi.clone()
    }

    fn eval_params(&self) -> LdaParams {
        self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::stream::{CorpusStream, StreamConfig};

    #[test]
    fn counts_stay_consistent() {
        let c = generate(&SyntheticConfig::small(), 41);
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;
        let mut ogs = Ogs::new(6, c.n_words(), OgsConfig::paper(s), 0);
        for mb in CorpusStream::new(&c, scfg) {
            ogs.process_minibatch(&mb);
        }
        // phisum consistent with columns
        let mut rebuilt = ogs.phi.clone();
        rebuilt.rebuild_phisum();
        for kk in 0..6 {
            assert!(
                (ogs.phi.phisum[kk] - rebuilt.phisum[kk]).abs()
                    < rebuilt.phisum[kk].abs().max(1.0) * 1e-3
            );
        }
        assert!(ogs.phi.raw().iter().all(|&x| x >= 0.0 && x.is_finite()));
    }

    #[test]
    fn deterministic_under_seed() {
        let c = generate(&SyntheticConfig::small(), 42);
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;
        let run = |seed| {
            let mut ogs = Ogs::new(4, c.n_words(), OgsConfig::paper(s), seed);
            for mb in CorpusStream::new(&c, scfg) {
                ogs.process_minibatch(&mb);
            }
            ogs.phi.total_mass()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn fit_improves_with_passes() {
        let c = generate(&SyntheticConfig::small(), 43);
        let scfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
        let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;
        let mut ogs = Ogs::new(8, c.n_words(), OgsConfig::paper(s), 1);
        let mb0 = CorpusStream::new(&c, scfg).next().unwrap();
        let early = ogs.process_minibatch(&mb0).train_ll;
        for _ in 0..3 {
            for mb in CorpusStream::new(&c, scfg) {
                ogs.process_minibatch(&mb);
            }
        }
        let late = ogs.process_minibatch(&mb0).train_ll;
        assert!(late > early, "{late} !> {early}");
    }
}
