//! Residual Variational Bayes — the paper's "RVB" comparator
//! (Wahabzada & Kersting, ECML/PKDD 2011: "Larger residuals, less work").
//!
//! RVB is OVB plus *document-level* residual scheduling: documents whose
//! variational parameters moved the most are revisited preferentially in
//! later minibatches.  §3.1 of the FOEM paper contrasts this with FOEM's
//! word/topic-level scheduling: RVB "schedules only mini-batches of
//! documents" and uses the theta residual (a lower bound of the
//! responsibility residual), so its scheduling is coarser and each
//! scheduling decision costs extra work — which is why RVB runs slightly
//! slower than OVB per minibatch in Figs. 8/10.
//!
//! Implementation: a bounded reservoir of high-residual documents; each
//! incoming minibatch is augmented with the top-residual reservoir
//! documents (the "extra work"), residuals are refreshed from the gamma
//! deltas of the refit.

use super::ovb::{Ovb, OvbConfig};
use super::OnlineLda;
use crate::corpus::sparse::DocWordMatrix;
use crate::em::sem::LearningRate;
use crate::em::{MinibatchReport, PhiStats};
use crate::stream::Minibatch;
use crate::util::Timer;
use crate::LdaParams;

/// RVB hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct RvbConfig {
    pub ovb: OvbConfig,
    /// Reservoir capacity (documents kept for rescheduling).
    pub reservoir_docs: usize,
    /// How many top-residual documents to replay per minibatch.
    pub replay_docs: usize,
}

impl RvbConfig {
    pub fn paper(scale_s: f64) -> Self {
        Self {
            ovb: OvbConfig::paper(scale_s),
            reservoir_docs: 2048,
            replay_docs: 128,
        }
    }
}

/// A reservoir entry: one document and its latest residual.
struct ResidualDoc {
    row: Vec<(u32, f32)>,
    residual: f32,
}

/// Residual VB trainer.
pub struct Rvb {
    inner: Ovb,
    cfg: RvbConfig,
    reservoir: Vec<ResidualDoc>,
}

impl Rvb {
    pub fn new(k: usize, n_words: usize, cfg: RvbConfig, seed: u64) -> Self {
        Self {
            inner: Ovb::new(k, n_words, cfg.ovb, seed),
            cfg,
            reservoir: Vec::new(),
        }
    }

    /// The learning-rate schedule (exposed for tests).
    pub fn rate(&self) -> LearningRate {
        self.cfg.ovb.rate
    }

    fn build_augmented(&self, mb: &Minibatch) -> Minibatch {
        if self.reservoir.is_empty() || self.cfg.replay_docs == 0 {
            return mb.clone();
        }
        // Top-residual replay docs.
        let mut idx: Vec<usize> = (0..self.reservoir.len()).collect();
        idx.sort_unstable_by(|&a, &b| {
            self.reservoir[b]
                .residual
                .partial_cmp(&self.reservoir[a].residual)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(
            mb.docs.n_docs + self.cfg.replay_docs,
        );
        for d in 0..mb.docs.n_docs {
            rows.push(mb.docs.iter_doc(d).collect());
        }
        for &i in idx.iter().take(self.cfg.replay_docs) {
            rows.push(self.reservoir[i].row.clone());
        }
        let refs: Vec<&[(u32, f32)]> =
            rows.iter().map(|r| r.as_slice()).collect();
        let docs = DocWordMatrix::from_rows(mb.docs.n_words, &refs);
        Minibatch::new(mb.index, docs)
    }

    fn update_reservoir(&mut self, mb: &Minibatch, per_doc_residual: &[f32]) {
        for d in 0..mb.docs.n_docs {
            let row: Vec<(u32, f32)> = mb.docs.iter_doc(d).collect();
            if row.is_empty() {
                continue;
            }
            let entry = ResidualDoc { row, residual: per_doc_residual[d] };
            if self.reservoir.len() < self.cfg.reservoir_docs {
                self.reservoir.push(entry);
            } else {
                // Replace the current minimum if ours is larger.
                let (mi, _) = self
                    .reservoir
                    .iter()
                    .enumerate()
                    .min_by(|a, b| {
                        a.1.residual
                            .partial_cmp(&b.1.residual)
                            .unwrap_or(std::cmp::Ordering::Equal)
                    })
                    .unwrap();
                if self.reservoir[mi].residual < entry.residual {
                    self.reservoir[mi] = entry;
                }
            }
        }
    }
}

impl OnlineLda for Rvb {
    fn name(&self) -> &'static str {
        "RVB"
    }

    fn params(&self) -> &LdaParams {
        self.inner.params()
    }

    fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport {
        let timer = Timer::start();
        // Residual proxy per doc: gamma mass shift between this fit and
        // the document's previous fit is approximated by the training-LL
        // contribution change; we use the per-doc LL under the refit as a
        // cheap stand-in (documents the model explains worst have the
        // largest lower-bound slack — the ECML paper's residual is also a
        // bound, not the exact responsibility change).
        let augmented = self.build_augmented(mb);
        let mut report = self.inner.process_minibatch(&augmented);

        // Per-doc residuals for the *original* minibatch docs: use the
        // negative per-token LL (worse fit => larger residual).
        let phi = self.inner.export_phi();
        let p = self.inner.eval_params();
        let theta = crate::em::infer::fold_in(
            &phi,
            &p,
            &mb.docs,
            &crate::em::infer::FoldInConfig::dense(3),
            mb.index as u64,
        );
        let mut per_doc = vec![0.0f32; mb.docs.n_docs];
        for d in 0..mb.docs.n_docs {
            let mut ll = 0.0f64;
            let trow = theta.doc(d);
            let tden = trow.iter().sum::<f32>()
                + p.n_topics as f32 * p.am1();
            for (w, c) in mb.docs.iter_doc(d) {
                let col = phi.word(w as usize);
                let mut prob = 0.0f32;
                for kk in 0..p.n_topics {
                    prob += (trow[kk] + p.am1()) / tden * (col[kk] + p.bm1())
                        / (phi.phisum[kk] + p.wbm1(phi.n_words));
                }
                ll += c as f64 * (prob.max(1e-30) as f64).ln();
            }
            per_doc[d] = (-(ll / mb.docs.doc_len(d).max(1.0) as f64)) as f32;
        }
        self.update_reservoir(mb, &per_doc);

        report.seconds = timer.seconds();
        report.tokens = mb.docs.total_tokens();
        report
    }

    fn export_phi(&mut self) -> PhiStats {
        self.inner.export_phi()
    }

    fn eval_params(&self) -> LdaParams {
        self.inner.eval_params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::stream::{CorpusStream, StreamConfig};

    fn stream_cfg() -> StreamConfig {
        StreamConfig { minibatch_docs: 64, ..Default::default() }
    }

    #[test]
    fn reservoir_fills_and_bounds() {
        let c = generate(&SyntheticConfig::small(), 61);
        let s = CorpusStream::new(&c, stream_cfg()).batches_per_pass() as f64;
        let mut cfg = RvbConfig::paper(s);
        cfg.reservoir_docs = 50;
        cfg.replay_docs = 10;
        let mut rvb = Rvb::new(5, c.n_words(), cfg, 0);
        for mb in CorpusStream::new(&c, stream_cfg()) {
            rvb.process_minibatch(&mb);
        }
        assert!(rvb.reservoir.len() <= 50);
        assert!(rvb.reservoir.len() > 0);
        assert!(rvb.reservoir.iter().all(|r| r.residual.is_finite()));
    }

    #[test]
    fn replay_increases_work_vs_ovb() {
        // The paper: "RVB runs slightly slower than OVB because of
        // additional dynamic scheduling cost". Token count processed per
        // minibatch must be >= the raw minibatch after warmup.
        let c = generate(&SyntheticConfig::small(), 62);
        let s = CorpusStream::new(&c, stream_cfg()).batches_per_pass() as f64;
        let mut rvb = Rvb::new(5, c.n_words(), RvbConfig::paper(s), 0);
        let batches: Vec<_> = CorpusStream::new(&c, stream_cfg()).collect();
        rvb.process_minibatch(&batches[0]);
        let augmented = rvb.build_augmented(&batches[1]);
        assert!(augmented.docs.n_docs > batches[1].docs.n_docs);
    }

    #[test]
    fn produces_finite_phi() {
        let c = generate(&SyntheticConfig::small(), 63);
        let s = CorpusStream::new(&c, stream_cfg()).batches_per_pass() as f64;
        let mut rvb = Rvb::new(5, c.n_words(), RvbConfig::paper(s), 0);
        for mb in CorpusStream::new(&c, stream_cfg()) {
            let r = rvb.process_minibatch(&mb);
            assert!(r.train_ll.is_finite());
        }
        let phi = rvb.export_phi();
        assert!(phi.raw().iter().all(|x| x.is_finite()));
    }
}
