//! Stochastic Collapsed Variational Bayes (SCVB0) — the paper's "SCVB"
//! comparator (Foulds et al., KDD 2013).
//!
//! §2.5 of the paper observes that SCVB's zero-order update *is* SEM with
//! the CVB0 responsibility, i.e. the Eq. 11 E-step with the
//! hyperparameters un-shifted: `(theta+alpha)(phi+beta)/(phisum+W*beta)`
//! instead of the MAP `alpha-1 / beta-1` offsets.  We therefore implement
//! SCVB as the SEM core running with `LdaParams{alpha: 1+alpha_cvb,
//! beta: 1+beta_cvb}` (so `am1 = alpha_cvb`), which reproduces its
//! convergence behavior exactly while sharing the tested SEM machinery.

use super::OnlineLda;
use crate::em::sem::{LearningRate, Sem, SemConfig};
use crate::em::{MinibatchReport, PhiStats};
use crate::stream::Minibatch;
use crate::LdaParams;

/// SCVB hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct ScvbConfig {
    pub alpha: f32,
    pub beta: f32,
    pub rate: LearningRate,
    pub scale_s: f64,
    pub max_inner_iters: usize,
}

impl ScvbConfig {
    pub fn paper(scale_s: f64) -> Self {
        Self {
            alpha: 0.01,
            beta: 0.01,
            rate: LearningRate::paper(),
            scale_s,
            max_inner_iters: 100,
        }
    }
}

/// SCVB0 trainer (SEM core with CVB0 responsibilities).
pub struct Scvb {
    inner: Sem,
}

impl Scvb {
    pub fn new(k: usize, n_words: usize, cfg: ScvbConfig, seed: u64) -> Self {
        let params = LdaParams {
            n_topics: k,
            alpha: 1.0 + cfg.alpha,
            beta: 1.0 + cfg.beta,
        };
        let sem_cfg = SemConfig {
            rate: cfg.rate,
            scale_s: cfg.scale_s,
            threshold: 10.0,
            check_every: 1,
            max_inner_iters: cfg.max_inner_iters,
            n_workers: 1,
            kernel_backend: crate::em::simd::KernelBackend::Scalar,
        };
        Self { inner: Sem::new(params, n_words, sem_cfg, seed) }
    }

    pub fn phi(&self) -> &PhiStats {
        &self.inner.phi
    }
}

impl OnlineLda for Scvb {
    fn name(&self) -> &'static str {
        "SCVB"
    }

    fn params(&self) -> &LdaParams {
        &self.inner.params
    }

    fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport {
        self.inner.process_minibatch(mb)
    }

    fn export_phi(&mut self) -> PhiStats {
        self.inner.phi.clone()
    }

    fn eval_params(&self) -> LdaParams {
        self.inner.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::stream::{CorpusStream, StreamConfig};

    #[test]
    fn uses_cvb0_offsets() {
        let s = Scvb::new(5, 100, ScvbConfig::paper(4.0), 0);
        // am1 == alpha_cvb (0.01), not alpha-1 of the MAP family.
        assert!((s.params().am1() - 0.01).abs() < 1e-6);
        assert!((s.params().bm1() - 0.01).abs() < 1e-6);
    }

    #[test]
    fn runs_stream_and_improves() {
        let c = generate(&SyntheticConfig::small(), 51);
        let scfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
        let scale = CorpusStream::new(&c, scfg).batches_per_pass() as f64;
        let mut cfg = ScvbConfig::paper(scale);
        // Fast rate so a few passes visibly move phi in a short test.
        cfg.rate = LearningRate { tau0: 1.0, kappa: 0.7 };
        let mut scvb = Scvb::new(8, c.n_words(), cfg, 1);
        let mb0 = CorpusStream::new(&c, scfg).next().unwrap();
        let early = scvb.process_minibatch(&mb0).train_perplexity();
        for _ in 0..3 {
            for mb in CorpusStream::new(&c, scfg) {
                scvb.process_minibatch(&mb);
            }
        }
        let late = scvb.process_minibatch(&mb0).train_perplexity();
        assert!(late < early, "{late} !< {early}");
    }
}
