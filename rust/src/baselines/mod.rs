//! The five state-of-the-art online-LDA baselines the paper compares
//! against (§4.3), plus the common [`OnlineLda`] trait the experiment
//! harness drives:
//!
//! | paper | module | family |
//! |---|---|---|
//! | OGS  (Yao et al., KDD'09)       | [`ogs`]  | collapsed Gibbs |
//! | OVB  (Hoffman et al., NIPS'10)  | [`ovb`]  | variational Bayes |
//! | RVB  (Wahabzada & Kersting '11) | [`rvb`]  | VB + residual scheduling |
//! | SOI  (Mimno et al., ICML'12)    | [`soi`]  | hybrid VB/Gibbs |
//! | SCVB (Foulds et al., KDD'13)    | [`scvb`] | stochastic CVB0 (≡ SEM) |
//!
//! All of them are *online*: constant memory in the stream length,
//! one-look-per-minibatch, global state only in the K×W topic-word
//! statistics. The paper's claims that we reproduce (Figs. 8-12):
//! FOEM/OGS/SCVB converge faster and to lower perplexity than
//! OVB/RVB/SOI, and only FOEM's cost is ~flat in K.

pub mod ogs;
pub mod ovb;
pub mod rvb;
pub mod scvb;
pub mod soi;
pub mod special;

use crate::em::{MinibatchReport, PhiStats};
use crate::stream::Minibatch;
use crate::LdaParams;

/// Uniform driver interface over every online algorithm in the crate
/// (FOEM, SEM and the five baselines).
pub trait OnlineLda {
    /// Short name used in experiment tables ("FOEM", "OVB", ...).
    fn name(&self) -> &'static str;

    /// The model hyperparameters the algorithm was built with.
    fn params(&self) -> &LdaParams;

    /// Consume one minibatch of the stream.
    fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport;

    /// Export the global topic-word sufficient statistics for evaluation.
    fn export_phi(&mut self) -> PhiStats;

    /// A sparse evaluation view over just `words` (sorted ascending) —
    /// what periodic driver evaluation uses so a parameter-streaming
    /// store is never fully densified mid-run (that would defeat the
    /// §3.2 memory bound). The default copies out of `export_phi`, which
    /// is fine for memory-resident algorithms; streaming backends
    /// override it with a column-snapshot read.
    fn eval_view(&mut self, words: &[u32]) -> crate::em::EvalPhiView {
        crate::em::EvalPhiView::from_dense(&self.export_phi(), words)
    }

    /// Predictive perplexity of this model on `test_docs` through a
    /// sparse [`Self::eval_view`] over exactly the test vocabulary —
    /// THE way to evaluate a live model. Every caller (both driver run
    /// loops, the examples, the serving layer's publish path) routes
    /// through here instead of hand-rolling the view+evaluate snippet,
    /// so the "eval view over the test vocabulary" recipe exists once.
    fn eval_perplexity(
        &mut self,
        test_docs: &crate::corpus::sparse::DocWordMatrix,
        protocol: &crate::eval::EvalProtocol,
    ) -> f64 {
        let view = self.eval_view(&test_docs.distinct_words());
        crate::eval::predictive_perplexity(
            &view,
            &self.eval_params(),
            test_docs,
            protocol,
        )
    }

    /// The smoothing parameters the *evaluator* should use to normalize
    /// the exported statistics (Eqs. 9/10 form). EM-family algorithms use
    /// `alpha-1 = beta-1 = 0.01`; GS/CVB-family statistics are smoothed
    /// with `+alpha/+beta` instead, which is the same formula with the
    /// hyperparameters shifted by one.
    fn eval_params(&self) -> LdaParams {
        *self.params()
    }

    /// Persist restartable state (paged-store FOEM overrides this; other
    /// algorithms are memory-resident and checkpoint by re-export).
    fn checkpoint(&mut self) -> anyhow::Result<()> {
        Ok(())
    }

    /// Cumulative store I/O, when the algorithm streams parameters.
    fn io_stats(&self) -> Option<crate::store::IoStats> {
        None
    }

    /// Resident trainer state for a crash-safe coordinator checkpoint
    /// ([`crate::coordinator::checkpoint`]). `None` means the algorithm
    /// does not support `--resume` (only paged-store FOEM does today).
    fn export_resume_state(&self) -> Option<crate::em::foem::FoemTrainState> {
        None
    }

    /// Discard the write-ahead logs after a successful coordinator
    /// checkpoint (everything they protect is now durable elsewhere).
    /// No-op for algorithms without a WAL.
    fn truncate_wal(&mut self) -> anyhow::Result<()> {
        Ok(())
    }

    // --- Drift responses (coordinator::drift) -----------------------
    //
    // Invoked by the driver when the shift detector confirms a regime
    // change and the user opted into a response (`--drift-response`).
    // Each returns `true` iff the algorithm actually applied the
    // action; the defaults decline, so baselines without an adaptive
    // story are safely inert and the driver can report "response
    // unsupported" instead of silently doing nothing.

    /// Discount the accumulated sufficient statistics by `factor`
    /// (0 < factor < 1), restarting the implicit 1/s step-size
    /// schedule partway (DESIGN.md §15).
    fn reset_decay(&mut self, _factor: f32) -> bool {
        false
    }

    /// Permanently widen topic scheduling/exploration so starved
    /// topics can be rediscovered after a shift.
    fn widen_exploration(&mut self) -> bool {
        false
    }

    /// Grow the topic dimension by `extra` fresh topics through the
    /// parameter store. Returns `false` when the backing store pins K
    /// (paged / sharded column records).
    fn grow_topics(&mut self, _extra: usize) -> bool {
        false
    }
}

impl OnlineLda for crate::em::sem::Sem {
    fn name(&self) -> &'static str {
        "SEM"
    }

    fn params(&self) -> &LdaParams {
        &self.params
    }

    fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport {
        crate::em::sem::Sem::process_minibatch(self, mb)
    }

    fn export_phi(&mut self) -> PhiStats {
        self.phi.clone()
    }
}

impl<S: crate::store::PhiColumnStore> OnlineLda for crate::em::foem::Foem<S> {
    fn name(&self) -> &'static str {
        "FOEM"
    }

    fn params(&self) -> &LdaParams {
        &self.params
    }

    fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport {
        crate::em::foem::Foem::process_minibatch(self, mb)
    }

    fn export_phi(&mut self) -> PhiStats {
        crate::em::foem::Foem::export_phi(self)
    }

    fn eval_view(&mut self, words: &[u32]) -> crate::em::EvalPhiView {
        // One non-dirtying sequential read per requested column — counted
        // in IoStats like any other stream access — instead of the
        // O(K*W) densification of the default.
        let snap = self.store.snapshot_columns(words);
        // Zone-map stats ride along for free: a paged store answers from
        // its column directory (no decode), certifying cold columns so
        // view consumers can skip them; in-memory stores answer None.
        let col_stats: Vec<Option<crate::store::ColumnStats>> = words
            .iter()
            .map(|&w| self.store.column_stats(w as usize))
            .collect();
        crate::em::EvalPhiView::from_snapshot(
            snap,
            self.phisum.clone(),
            self.store.n_words(),
        )
        .with_column_stats(col_stats)
    }

    fn checkpoint(&mut self) -> anyhow::Result<()> {
        self.store.flush()?;
        self.res_store.flush()
    }

    fn io_stats(&self) -> Option<crate::store::IoStats> {
        Some(self.store.io_stats())
    }

    fn export_resume_state(
        &self,
    ) -> Option<crate::em::foem::FoemTrainState> {
        Some(self.export_train_state())
    }

    fn truncate_wal(&mut self) -> anyhow::Result<()> {
        self.store.truncate_wal()?;
        self.res_store.truncate_wal()
    }

    fn reset_decay(&mut self, factor: f32) -> bool {
        crate::em::foem::Foem::reset_decay(self, factor)
    }

    fn widen_exploration(&mut self) -> bool {
        crate::em::foem::Foem::widen_exploration(self)
    }

    fn grow_topics(&mut self, extra: usize) -> bool {
        crate::em::foem::Foem::grow_topics(self, extra)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::em::foem::{Foem, FoemConfig};
    use crate::em::sem::{Sem, SemConfig};
    use crate::store::InMemoryPhi;
    use crate::stream::{CorpusStream, StreamConfig};

    /// Every algorithm must run a small stream end-to-end through the
    /// trait object interface and export a usable phi.
    #[test]
    fn trait_drives_all_algorithms() {
        let c = generate(&SyntheticConfig::small(), 21);
        let k = 5;
        let p = LdaParams::paper_defaults(k);
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;

        let mut algos: Vec<Box<dyn OnlineLda>> = vec![
            Box::new(Sem::new(p, c.n_words(), SemConfig::paper(s), 0)),
            Box::new(Foem::new(
                p,
                InMemoryPhi::zeros(k, c.n_words()),
                FoemConfig::paper(),
                0,
            )),
            Box::new(ovb::Ovb::new(k, c.n_words(), ovb::OvbConfig::paper(s), 0)),
            Box::new(ogs::Ogs::new(k, c.n_words(), ogs::OgsConfig::paper(s), 0)),
            Box::new(scvb::Scvb::new(k, c.n_words(), scvb::ScvbConfig::paper(s), 0)),
            Box::new(rvb::Rvb::new(k, c.n_words(), rvb::RvbConfig::paper(s), 0)),
            Box::new(soi::Soi::new(k, c.n_words(), soi::SoiConfig::paper(s), 0)),
        ];
        for algo in &mut algos {
            for mb in CorpusStream::new(&c, scfg) {
                let r = algo.process_minibatch(&mb);
                assert!(r.seconds >= 0.0);
                assert!(r.tokens > 0.0, "{}", algo.name());
            }
            let phi = algo.export_phi();
            assert_eq!(phi.k, k, "{}", algo.name());
            assert!(
                phi.total_mass() > 0.0,
                "{} exported empty phi",
                algo.name()
            );
            // No NaNs anywhere.
            assert!(
                phi.raw().iter().all(|x| x.is_finite()),
                "{} produced non-finite phi",
                algo.name()
            );
        }
    }
}
