//! Online Variational Bayes for LDA (Hoffman, Blei & Bach, NIPS 2010) —
//! the paper's "OVB" comparator.
//!
//! Global state: variational Dirichlet parameters `lambda_{K×W}` over the
//! topic-word distributions. Per minibatch, each document's variational
//! posterior `(gamma_d, phi_dw)` is fit by coordinate ascent (Eq. 23-24 of
//! the paper's §2.5: the E-step multiplies `exp(Ψ(·))` factors — the
//! `digamma` cost that makes the VB family slow in Figs. 8/10), then
//! `lambda` takes a natural-gradient step with the Robbins-Monro rate
//! (Eq. 18).
//!
//! Perplexity evaluation uses the exported statistics `lambda - eta`
//! (expected topic-word counts), normalized by the shared evaluator.

use super::special::digamma;
use super::OnlineLda;
use crate::em::sem::LearningRate;
use crate::em::{MinibatchReport, PhiStats};
use crate::stream::Minibatch;
use crate::util::{Rng, Timer};
use crate::LdaParams;

/// OVB hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct OvbConfig {
    /// Dirichlet prior on theta (VB uses the un-shifted parameterization;
    /// footnote 9 recommends 0.5 for VB, but the paper's comparison runs
    /// every algorithm at its default — we use 0.01 to match §4's setup).
    pub alpha: f32,
    /// Dirichlet prior on phi.
    pub eta: f32,
    pub rate: LearningRate,
    /// Stream scale `D / D_s`.
    pub scale_s: f64,
    /// Per-document coordinate-ascent sweep budget.
    pub max_doc_iters: usize,
    /// Stop a document's inner loop when mean |Δgamma| < this.
    pub gamma_tol: f32,
}

impl OvbConfig {
    pub fn paper(scale_s: f64) -> Self {
        Self {
            alpha: 0.01,
            eta: 0.01,
            rate: LearningRate::paper(),
            scale_s,
            max_doc_iters: 100,
            gamma_tol: 1e-3,
        }
    }
}

/// Online VB trainer.
pub struct Ovb {
    pub k: usize,
    pub n_words: usize,
    pub cfg: OvbConfig,
    /// `lambda`, word-column-contiguous like [`PhiStats`].
    pub lambda: PhiStats,
    pub step: usize,
    params: LdaParams,
}

impl Ovb {
    pub fn new(k: usize, n_words: usize, cfg: OvbConfig, seed: u64) -> Self {
        // Standard init: lambda ~ Gamma(100, 1/100) (Hoffman's code).
        let mut rng = Rng::new(seed);
        let mut lambda = PhiStats::zeros(k, n_words);
        for w in 0..n_words {
            let mut col = vec![0.0f32; k];
            for x in col.iter_mut() {
                *x = (rng.gamma(100.0) / 100.0) as f32;
            }
            lambda.add_to_word(w, &col);
        }
        Self {
            k,
            n_words,
            cfg,
            lambda,
            step: 0,
            params: LdaParams { n_topics: k, alpha: 1.0 + cfg.alpha, beta: 1.0 + cfg.eta },
        }
    }

    /// `exp(E[log beta_{k,w}])` for the minibatch's local words:
    /// returns (per-local-word rows `[Ws][K]`, nothing); the shared
    /// denominator `Ψ(sum_w lambda)` is computed once per topic.
    fn exp_elog_beta_local(&self, local_words: &[u32]) -> Vec<f32> {
        let k = self.k;
        let mut psi_sum = vec![0.0f64; k];
        for (kk, &s) in self.lambda.phisum.iter().enumerate() {
            psi_sum[kk] = digamma((s as f64).max(1e-8));
        }
        let mut out = vec![0.0f32; local_words.len() * k];
        for (lw, &w) in local_words.iter().enumerate() {
            let col = self.lambda.word(w as usize);
            let row = &mut out[lw * k..(lw + 1) * k];
            for kk in 0..k {
                row[kk] = (digamma((col[kk] as f64).max(1e-8)) - psi_sum[kk])
                    .exp() as f32;
            }
        }
        out
    }
}

impl OnlineLda for Ovb {
    fn name(&self) -> &'static str {
        "OVB"
    }

    fn params(&self) -> &LdaParams {
        &self.params
    }

    fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport {
        let timer = Timer::start();
        let k = self.k;
        let alpha = self.cfg.alpha;
        self.step += 1;
        let docs = &mb.docs;
        let tokens = docs.total_tokens();

        // local word id -> row in exp_elog_beta
        let local_index: std::collections::HashMap<u32, usize> = mb
            .local_words
            .iter()
            .enumerate()
            .map(|(i, &w)| (w, i))
            .collect();
        let elog_beta = self.exp_elog_beta_local(&mb.local_words);

        // Accumulated sufficient statistics sum_d n_dw phi_dwk, stored per
        // local word.
        let mut sstats = vec![0.0f32; mb.local_words.len() * k];
        let mut ll = 0.0f64;
        let mut total_inner = 0usize;

        let mut gamma = vec![0.0f32; k];
        let mut exp_elog_theta = vec![0.0f32; k];
        let mut phi_norm: Vec<f32> = Vec::new();
        for d in 0..docs.n_docs {
            let words = docs.doc_words(d);
            let counts = docs.doc_counts(d);
            let n_w = words.len();
            phi_norm.resize(n_w, 0.0);
            gamma.iter_mut().for_each(|g| *g = alpha + 1.0); // gamma init
            // Coordinate ascent on (gamma, phi_dw).
            for it in 0..self.cfg.max_doc_iters {
                // exp(E[log theta]) given gamma
                let psi_gsum =
                    digamma(gamma.iter().map(|&g| g as f64).sum::<f64>().max(1e-8));
                for kk in 0..k {
                    exp_elog_theta[kk] =
                        (digamma((gamma[kk] as f64).max(1e-8)) - psi_gsum).exp()
                            as f32;
                }
                // gamma_new = alpha + sum_w n_w * (elog_theta*elog_beta_w)/norm_w
                let mut gamma_new = vec![alpha; k];
                for (i, (&w, &c)) in words.iter().zip(counts).enumerate() {
                    let lw = local_index[&w];
                    let row = &elog_beta[lw * k..(lw + 1) * k];
                    let mut z = 1e-30f32;
                    for kk in 0..k {
                        z += exp_elog_theta[kk] * row[kk];
                    }
                    phi_norm[i] = z;
                    for kk in 0..k {
                        gamma_new[kk] +=
                            c * exp_elog_theta[kk] * row[kk] / z;
                    }
                }
                let delta: f32 = gamma
                    .iter()
                    .zip(&gamma_new)
                    .map(|(a, b)| (a - b).abs())
                    .sum::<f32>()
                    / k as f32;
                gamma.copy_from_slice(&gamma_new);
                total_inner += 1;
                if delta < self.cfg.gamma_tol && it > 0 {
                    break;
                }
            }
            // Accumulate sstats with the converged phi_dw.
            let psi_gsum =
                digamma(gamma.iter().map(|&g| g as f64).sum::<f64>().max(1e-8));
            for kk in 0..k {
                exp_elog_theta[kk] =
                    (digamma((gamma[kk] as f64).max(1e-8)) - psi_gsum).exp() as f32;
            }
            for (&w, &c) in words.iter().zip(counts) {
                let lw = local_index[&w];
                let row = &elog_beta[lw * k..(lw + 1) * k];
                let mut z = 1e-30f32;
                for kk in 0..k {
                    z += exp_elog_theta[kk] * row[kk];
                }
                for kk in 0..k {
                    sstats[lw * k + kk] += c * exp_elog_theta[kk] * row[kk] / z;
                }
                ll += c as f64 * (z as f64).ln();
            }
        }

        // Natural-gradient lambda update with rate rho_s (Eq. 18).
        let rho = self.cfg.rate.rho(self.step) as f32;
        let scale = self.cfg.scale_s as f32;
        let eta = self.cfg.eta;
        self.lambda.raw_mut().iter_mut().for_each(|x| *x *= 1.0 - rho);
        self.lambda.phisum.iter_mut().for_each(|x| *x *= 1.0 - rho);
        // Every word gets the prior mass eta; streaming that over all W
        // words each step costs O(KW) like the reference implementation.
        let prior = rho * eta;
        for x in self.lambda.raw_mut().iter_mut() {
            *x += prior;
        }
        for s in self.lambda.phisum.iter_mut() {
            *s += prior * self.n_words as f32;
        }
        for (lw, &w) in mb.local_words.iter().enumerate() {
            let row = &sstats[lw * k..(lw + 1) * k];
            let (col, phisum) = self.lambda.word_and_sum_mut(w as usize);
            for kk in 0..k {
                let v = rho * scale * row[kk];
                col[kk] += v;
                phisum[kk] += v;
            }
        }

        MinibatchReport {
            inner_iters: total_inner / docs.n_docs.max(1),
            seconds: timer.seconds(),
            train_ll: ll,
            tokens,
            ..Default::default()
        }
    }

    fn export_phi(&mut self) -> PhiStats {
        // Expected counts: lambda - eta (clamped), matching the EM-side
        // sufficient-statistics convention.
        let mut phi = PhiStats::zeros(self.k, self.n_words);
        let eta = self.cfg.eta;
        for w in 0..self.n_words {
            let col: Vec<f32> = self
                .lambda
                .word(w)
                .iter()
                .map(|&x| (x - eta).max(0.0))
                .collect();
            phi.add_to_word(w, &col);
        }
        phi
    }

    fn eval_params(&self) -> LdaParams {
        self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::stream::{CorpusStream, StreamConfig};

    #[test]
    fn lambda_stays_positive_and_finite() {
        let c = generate(&SyntheticConfig::small(), 31);
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;
        let mut ovb = Ovb::new(6, c.n_words(), OvbConfig::paper(s), 0);
        for mb in CorpusStream::new(&c, scfg) {
            let r = ovb.process_minibatch(&mb);
            assert!(r.train_ll.is_finite());
        }
        assert!(ovb.lambda.raw().iter().all(|&x| x.is_finite() && x >= 0.0));
        assert!(ovb.lambda.total_mass() > 0.0);
    }

    #[test]
    fn doc_inner_loop_converges_before_budget() {
        let c = generate(&SyntheticConfig::small(), 32);
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;
        let mut ovb = Ovb::new(6, c.n_words(), OvbConfig::paper(s), 0);
        let mb = CorpusStream::new(&c, scfg).next().unwrap();
        let r = ovb.process_minibatch(&mb);
        assert!(
            r.inner_iters < ovb.cfg.max_doc_iters,
            "mean doc iters {} hit budget",
            r.inner_iters
        );
    }

    #[test]
    fn repeated_stream_improves_fit() {
        let c = generate(&SyntheticConfig::small(), 33);
        let scfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
        let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;
        let mut ovb = Ovb::new(8, c.n_words(), OvbConfig::paper(s), 1);
        let mb0 = CorpusStream::new(&c, scfg).next().unwrap();
        let early = ovb.process_minibatch(&mb0).train_ll;
        for _ in 0..3 {
            for mb in CorpusStream::new(&c, scfg) {
                ovb.process_minibatch(&mb);
            }
        }
        let late = ovb.process_minibatch(&mb0).train_ll;
        assert!(late > early, "{late} !> {early}");
    }
}
