//! Parameter streaming (paper §3.2): the global topic-word matrix
//! `phi_hat_{K×W}` behind a column-store abstraction.
//!
//! The *big model* problem is that `K×W` does not fit in memory (the
//! paper's example: K=10^5, W=10^6 → 400 GB). FOEM therefore keeps the
//! matrix in secondary storage and streams only the columns (words) the
//! current minibatch touches, plus a fixed-size buffer of hot columns
//! (Table 5 sweeps the buffer size; Fig. 4 lines 2, 8, 15).
//!
//! Two implementations of [`PhiColumnStore`]:
//! * [`InMemoryPhi`] — the whole matrix resident (the "in-memory" column
//!   of Table 5, and what every non-FOEM algorithm implicitly uses);
//! * [`paged::PagedPhi`] — a binary column file on disk with a hot-word
//!   buffer, write-back caching, I/O accounting and restart recovery
//!   (the fault-tolerance property of §3.2).

pub mod codec;
pub mod fault;
pub mod paged;
pub mod wal;

pub use codec::{Codec, ColumnStats};

/// I/O accounting used by the Table 5 experiment and the coordinator's
/// metrics.
///
/// The first four counters describe the synchronous column path. The last
/// three describe the overlapped path of the pipelined trainer
/// ([`crate::exec::pipeline`]): they stay exactly zero unless a backend's
/// background I/O mode ([`PhiColumnStore::set_async_io`]) is enabled, so
/// serial runs keep bit-identical stats.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IoStats {
    /// Columns read from disk on the caller's (critical) path.
    pub col_reads: u64,
    /// Columns written to disk on the caller's (critical) path.
    pub col_writes: u64,
    /// Column accesses served from the hot buffer.
    pub buffer_hits: u64,
    /// Column accesses that had to touch the backing store.
    pub buffer_misses: u64,
    /// Columns loaded ahead of use by the background prefetcher.
    pub prefetched_cols: u64,
    /// Column reads served from the prefetch cache instead of blocking on
    /// disk (these do NOT count as `buffer_misses`).
    pub prefetch_hits: u64,
    /// Dirty columns flushed by the write-behind thread, off the critical
    /// path. Timing-dependent upper-bounded by the logical write count
    /// (superseded versions of a column may be skipped).
    pub wb_writes: u64,
    /// Decoded (dense `K×4`-byte) volume of actual backing-store
    /// transfers, on both the sync and async paths. Cache hits of any
    /// kind (hot buffer, pending-write map, prefetch cache) count in
    /// neither byte counter — so `disk_bytes / logical_bytes` is exactly
    /// the compression ratio of real disk traffic. Stays zero for
    /// in-memory stores.
    pub logical_bytes: u64,
    /// Encoded (on-disk record) volume of those same transfers. An
    /// implicit all-zero column transfers 0 disk bytes (the zone-map
    /// skip) while still counting its logical volume.
    pub disk_bytes: u64,
}

impl IoStats {
    /// Accumulate another store's counters into this one — the
    /// per-shard aggregation of the vocabulary-sharded fleet
    /// ([`crate::shard::ShardedPhi::io_stats`] sums its owners with
    /// this), so coordinator telemetry stays truthful under N>1.
    pub fn absorb(&mut self, other: &IoStats) {
        self.col_reads += other.col_reads;
        self.col_writes += other.col_writes;
        self.buffer_hits += other.buffer_hits;
        self.buffer_misses += other.buffer_misses;
        self.prefetched_cols += other.prefetched_cols;
        self.prefetch_hits += other.prefetch_hits;
        self.wb_writes += other.wb_writes;
        self.logical_bytes += other.logical_bytes;
        self.disk_bytes += other.disk_bytes;
    }
}

/// A detached, read-only snapshot of a set of columns — the shared-read
/// path of the parallel E-step engine ([`crate::exec`]).
///
/// A snapshot is materialized once per minibatch (one sequential read per
/// touched column, same I/O discipline as a serial sweep) and then served
/// to every shard worker concurrently: it owns its data, so it is `Sync`
/// regardless of the backing store — `InMemoryPhi` and `PagedPhi` alike
/// can feed any number of concurrent readers this way without locking.
#[derive(Debug, Clone)]
pub struct PhiSnapshot {
    k: usize,
    /// Sorted global word ids the snapshot covers.
    words: Vec<u32>,
    /// `words.len() * k`; column `i` belongs to `words[i]`.
    data: Vec<f32>,
}

impl PhiSnapshot {
    /// Number of topics K (column length).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of columns captured.
    pub fn n_columns(&self) -> usize {
        self.words.len()
    }

    /// The sorted global word ids covered.
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    /// Snapshot-local index of global word `w`, if captured.
    #[inline]
    pub fn index_of(&self, w: u32) -> Option<usize> {
        self.words.binary_search(&w).ok()
    }

    /// Column of global word `w`, if captured.
    #[inline]
    pub fn column(&self, w: u32) -> Option<&[f32]> {
        self.index_of(w).map(|i| self.column_at(i))
    }

    /// Column by snapshot-local index.
    #[inline]
    pub fn column_at(&self, idx: usize) -> &[f32] {
        &self.data[idx * self.k..(idx + 1) * self.k]
    }

    /// Build a snapshot directly from its parts (used by in-memory
    /// trainers, e.g. `PhiStats::snapshot_columns`, to feed the same
    /// staged-compute path the stores use). `data` is column-contiguous,
    /// `words.len() * k` long.
    pub fn from_parts(k: usize, words: Vec<u32>, data: Vec<f32>) -> Self {
        debug_assert_eq!(data.len(), words.len() * k);
        debug_assert!(
            words.windows(2).all(|w| w[0] < w[1]),
            "snapshot words must be sorted and distinct"
        );
        Self { k, words, data }
    }

    /// Decompose into `(k, words, data)` — the inverse of
    /// [`Self::from_parts`], used to move snapshot storage into an
    /// evaluation view without a copy.
    pub fn into_parts(self) -> (usize, Vec<u32>, Vec<f32>) {
        (self.k, self.words, self.data)
    }
}

/// Column-store abstraction over `phi_hat_{K×W}`.
///
/// The topic totals `phisum` are *not* part of the store — they are a
/// K-vector owned by the algorithm (they must stay resident; they are the
/// denominator of every E-step).
pub trait PhiColumnStore {
    /// Number of topics K (column length).
    fn k(&self) -> usize;

    /// Current vocabulary capacity W.
    fn n_words(&self) -> usize;

    /// Grow capacity to at least `n_words` columns of zeros (lifelong
    /// vocabulary growth, `W ← W+1`).
    fn ensure_capacity(&mut self, n_words: usize);

    /// Access column `w` read-write. The store guarantees the slice holds
    /// the current value on entry and persists mutations (possibly
    /// write-back-cached) on exit.
    fn with_column<R>(&mut self, w: usize, f: impl FnOnce(&mut [f32]) -> R) -> R;

    /// Read-only convenience copy of a column.
    fn read_column(&mut self, w: usize) -> Vec<f32> {
        let mut out = vec![0.0; self.k()];
        self.load_column(w, &mut out);
        out
    }

    /// Read column `w` into `out` WITHOUT a write-back obligation.
    /// Backends should avoid dirtying storage on this path.
    fn load_column(&mut self, w: usize, out: &mut [f32]) {
        self.with_column(w, |col| out.copy_from_slice(col));
    }

    /// Overwrite column `w` with `data` (no prior read needed).
    fn store_column(&mut self, w: usize, data: &[f32]) {
        self.with_column(w, |col| col.copy_from_slice(data));
    }

    /// Merge `delta` into column `w` (`col[k] += delta[k]`) — the
    /// apply-phase accumulate verb ([`crate::em::SsDelta::apply_to_store`]).
    /// The default is exactly the [`Self::with_column`] closure it
    /// replaces (one read-modify-write access, identical accounting);
    /// it exists as a named verb so routing stores
    /// ([`crate::shard::ShardedPhi`]) can ship the operation as one
    /// explicit message to the owning shard instead of a closure.
    fn merge_column(&mut self, w: usize, delta: &[f32]) {
        self.with_column(w, |col| {
            for (c, &d) in col.iter_mut().zip(delta) {
                *c += d;
            }
        });
    }

    /// Merge `delta` into column `w` clamping every entry at zero, and
    /// return the clamped column's sum — the residual-store apply verb
    /// (FOEM keeps residuals non-negative and the dynamic scheduler
    /// needs the per-word total back). Same single read-modify-write
    /// access as the [`Self::with_column`] closure it replaces.
    fn clamp_add_column(&mut self, w: usize, delta: &[f32]) -> f32 {
        self.with_column(w, |col| {
            let mut total = 0.0f32;
            for (c, &d) in col.iter_mut().zip(delta) {
                *c = (*c + d).max(0.0);
                total += *c;
            }
            total
        })
    }

    /// Materialize a read-only [`PhiSnapshot`] of the given columns
    /// (`words` sorted ascending). Uses the non-dirtying [`Self::load_column`]
    /// path — one sequential read per column, no write-back obligation —
    /// so concurrent shard workers can then read the snapshot while the
    /// store itself stays untouched until the merge.
    fn snapshot_columns(&mut self, words: &[u32]) -> PhiSnapshot {
        debug_assert!(
            words.windows(2).all(|w| w[0] < w[1]),
            "snapshot words must be sorted and distinct"
        );
        let k = self.k();
        let mut data = vec![0.0f32; words.len() * k];
        for (i, &w) in words.iter().enumerate() {
            self.load_column(w as usize, &mut data[i * k..(i + 1) * k]);
        }
        PhiSnapshot { k, words: words.to_vec(), data }
    }

    /// Install the minibatch's hot words into the buffer (Fig. 4 line 2:
    /// "Replace most frequent vocabulary word-topic parameter matrix ...
    /// in buffer memory"). A no-op for in-memory stores.
    fn set_hot_words(&mut self, words: &[u32]);

    /// Hint that the given columns will be snapshotted soon — the
    /// pipelined trainer calls this with the *next* minibatch's local
    /// vocabulary while the current minibatch computes, so a disk-backed
    /// store can stage the reads on its background I/O thread. Only
    /// meaningful after [`Self::set_async_io`] enabled background I/O; a
    /// no-op otherwise and for in-memory stores.
    fn prefetch_columns(&mut self, _words: &[u32]) {}

    /// Switch background I/O (prefetch + write-behind) on or off. While
    /// enabled, column writes are buffered and flushed by a background
    /// thread and prefetched columns are served without touching disk on
    /// the caller's path; disabling drains all buffered state back to the
    /// backing store. Returns `true` if the backend supports the mode
    /// (in-memory stores return `false` and ignore the call).
    fn set_async_io(&mut self, _enabled: bool) -> bool {
        false
    }

    /// Does this store mirror its writes into a write-ahead log
    /// ([`wal::Wal`])? In-memory stores and WAL-off paged stores return
    /// `false`, and the trainer skips all batch bracketing — the WAL-off
    /// path stays bit-identical to pre-WAL behavior.
    fn wal_enabled(&self) -> bool {
        false
    }

    /// Open batch `batch_id` in the WAL (a `BeginBatch` intent frame).
    /// No-op unless [`Self::wal_enabled`].
    fn wal_begin(&mut self, _batch_id: u64) {}

    /// Commit batch `batch_id`: log every still-buffered (hot, dirty)
    /// column the batch may have touched, append the `Commit` frame
    /// carrying the owner's `state` blob, and fsync — the batch's
    /// durability point. Errors are recorded in the store's poison flag
    /// (surfaced at the next [`Self::flush`]) rather than returned, so
    /// the training hot loop stays infallible; an unpoisoned store
    /// guarantees the commit is durable. No-op unless
    /// [`Self::wal_enabled`].
    fn wal_commit(&mut self, _batch_id: u64, _state: &[u8]) {}

    /// Truncate the WAL after a successful checkpoint (which now covers
    /// everything the log was protecting). No-op without a WAL.
    fn truncate_wal(&mut self) -> anyhow::Result<()> {
        Ok(())
    }

    /// Persist all dirty state to the backing store.
    fn flush(&mut self) -> anyhow::Result<()>;

    /// Cumulative I/O counters.
    fn io_stats(&self) -> IoStats;

    /// Zone-map stats (nnz, max weight) for column `w` if the backend
    /// can answer *exactly* without decoding the column — `None` means
    /// "unknown, read the column" (in-memory stores, out-of-range words,
    /// or a paged column whose freshest state sits unencoded in the hot
    /// buffer). Never an approximation: callers use this to skip cold
    /// columns outright.
    fn column_stats(&self, _w: usize) -> Option<ColumnStats> {
        None
    }

    /// Grow the topic dimension to `new_k` (K ← new_k), zero-filling
    /// the fresh rows of every column. Returns `false` if the backend
    /// cannot change K after creation — paged and sharded stores pin K
    /// in their on-disk column records, so only fully resident stores
    /// support this (the drift responder's `grow` action,
    /// coordinator::drift). Implementations must grow atomically or
    /// not at all.
    fn grow_topics(&mut self, _new_k: usize) -> bool {
        false
    }

    /// Export the dense matrix (evaluation / checkpointing).
    fn export_dense(&mut self) -> crate::em::PhiStats {
        let k = self.k();
        let n_words = self.n_words();
        let mut phi = crate::em::PhiStats::zeros(k, n_words);
        for w in 0..n_words {
            let col = self.read_column(w);
            phi.add_to_word(w, &col);
        }
        phi
    }
}

/// Fully resident store — a thin wrapper around a flat `Vec<f32>`.
#[derive(Debug, Clone)]
pub struct InMemoryPhi {
    k: usize,
    data: Vec<f32>,
    stats: IoStats,
}

impl InMemoryPhi {
    pub fn zeros(k: usize, n_words: usize) -> Self {
        Self { k, data: vec![0.0; k * n_words], stats: IoStats::default() }
    }

    /// Wrap an existing dense matrix.
    pub fn from_dense(phi: &crate::em::PhiStats) -> Self {
        Self { k: phi.k, data: phi.raw().to_vec(), stats: IoStats::default() }
    }
}

impl PhiColumnStore for InMemoryPhi {
    fn k(&self) -> usize {
        self.k
    }

    fn n_words(&self) -> usize {
        self.data.len() / self.k
    }

    fn ensure_capacity(&mut self, n_words: usize) {
        if n_words * self.k > self.data.len() {
            self.data.resize(n_words * self.k, 0.0);
        }
    }

    fn grow_topics(&mut self, new_k: usize) -> bool {
        assert!(new_k >= self.k, "grow_topics cannot shrink K");
        if new_k == self.k {
            return true;
        }
        // Re-stride: each word's column keeps its K old entries and
        // gains zeros for the fresh topics.
        let n_words = self.n_words();
        let mut data = vec![0.0f32; new_k * n_words];
        for w in 0..n_words {
            data[w * new_k..w * new_k + self.k]
                .copy_from_slice(&self.data[w * self.k..(w + 1) * self.k]);
        }
        self.data = data;
        self.k = new_k;
        true
    }

    fn with_column<R>(&mut self, w: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
        self.stats.buffer_hits += 1;
        f(&mut self.data[w * self.k..(w + 1) * self.k])
    }

    fn set_hot_words(&mut self, _words: &[u32]) {}

    fn flush(&mut self) -> anyhow::Result<()> {
        Ok(())
    }

    fn io_stats(&self) -> IoStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_memory_read_write_round_trip() {
        let mut s = InMemoryPhi::zeros(4, 3);
        s.with_column(1, |col| col.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        assert_eq!(s.read_column(1), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.read_column(0), vec![0.0; 4]);
        assert_eq!(s.io_stats().buffer_hits, 3);
        assert_eq!(s.io_stats().col_reads, 0);
    }

    #[test]
    fn in_memory_capacity_growth_preserves_data() {
        let mut s = InMemoryPhi::zeros(2, 2);
        s.with_column(1, |col| col.copy_from_slice(&[5.0, 6.0]));
        s.ensure_capacity(10);
        assert_eq!(s.n_words(), 10);
        assert_eq!(s.read_column(1), vec![5.0, 6.0]);
        assert_eq!(s.read_column(9), vec![0.0, 0.0]);
    }

    #[test]
    fn snapshot_is_detached_and_thread_shareable() {
        let mut s = InMemoryPhi::zeros(3, 5);
        s.with_column(1, |c| c.copy_from_slice(&[1.0, 2.0, 3.0]));
        s.with_column(4, |c| c.copy_from_slice(&[4.0, 0.0, 1.0]));
        let snap = s.snapshot_columns(&[1, 2, 4]);
        assert_eq!(snap.k(), 3);
        assert_eq!(snap.n_columns(), 3);
        assert_eq!(snap.words(), &[1, 2, 4]);
        assert_eq!(snap.column(1).unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(snap.column(2).unwrap(), &[0.0; 3]);
        assert_eq!(snap.column_at(2), &[4.0, 0.0, 1.0]);
        assert!(snap.column(3).is_none());
        // Detached: later store writes must not show through.
        s.with_column(1, |c| c[0] = 9.0);
        assert_eq!(snap.column(1).unwrap()[0], 1.0);
        // Shared-read across threads (the parallel engine's access
        // pattern).
        std::thread::scope(|scope| {
            let a = scope.spawn(|| snap.column(4).unwrap()[0]);
            let b = scope.spawn(|| snap.column(1).unwrap()[1]);
            assert_eq!(a.join().unwrap(), 4.0);
            assert_eq!(b.join().unwrap(), 2.0);
        });
    }

    #[test]
    fn paged_snapshot_reads_without_dirtying() {
        let dir = crate::util::TempDir::new("snap");
        let mut s =
            paged::PagedPhi::create(&dir.path().join("p.bin"), 2, 6, 2 * 2 * 4)
                .unwrap();
        s.with_column(2, |c| c.copy_from_slice(&[1.0, 2.0]));
        let writes_before = s.io_stats().col_writes;
        let snap = s.snapshot_columns(&[0, 2, 5]);
        assert_eq!(snap.column(2).unwrap(), &[1.0, 2.0]);
        assert_eq!(snap.column(5).unwrap(), &[0.0, 0.0]);
        assert_eq!(
            s.io_stats().col_writes,
            writes_before,
            "snapshot must not write"
        );
        assert!(s.io_stats().col_reads >= 3);
    }

    #[test]
    fn grow_topics_preserves_columns_and_zero_fills() {
        let mut s = InMemoryPhi::zeros(2, 3);
        s.with_column(0, |c| c.copy_from_slice(&[1.0, 2.0]));
        s.with_column(2, |c| c.copy_from_slice(&[3.0, 4.0]));
        assert!(s.grow_topics(2), "no-op grow must succeed");
        assert!(s.grow_topics(4));
        assert_eq!(s.k(), 4);
        assert_eq!(s.n_words(), 3);
        assert_eq!(s.read_column(0), vec![1.0, 2.0, 0.0, 0.0]);
        assert_eq!(s.read_column(1), vec![0.0; 4]);
        assert_eq!(s.read_column(2), vec![3.0, 4.0, 0.0, 0.0]);
    }

    #[test]
    fn export_dense_matches_columns() {
        let mut s = InMemoryPhi::zeros(2, 3);
        s.with_column(0, |c| c.copy_from_slice(&[1.0, 0.0]));
        s.with_column(2, |c| c.copy_from_slice(&[0.0, 7.0]));
        let dense = s.export_dense();
        assert_eq!(dense.word(0), &[1.0, 0.0]);
        assert_eq!(dense.word(2), &[0.0, 7.0]);
        assert_eq!(dense.phisum, vec![1.0, 7.0]);
    }
}
