//! Lossless column codecs for the paged phi store (ROADMAP item:
//! compressed columnar storage).
//!
//! A K×W topic-word matrix is mostly near-zero at big-model scale, so the
//! paged store's disk traffic — not the SIMD E-step — bounds throughput.
//! Each on-disk column record is `[tag u8][payload]`, self-describing so
//! a reader never needs to know the writer's policy:
//!
//! * [`Codec::Raw`]    — tag 0: `k` little-endian f32 words. The
//!   uncompressed reference format (and the fallback `Auto` picks when a
//!   column is dense enough that neither compressor wins).
//! * [`Codec::Sparse`] — tag 1: a `ceil(k/8)`-byte nonzero-topic bitmap
//!   followed by the nonzero weights in topic order. Wins when
//!   `nnz ≪ K`, the common case for phi columns.
//! * [`Codec::Rle`]    — tag 2: `n_runs u32`, then `(count u32, bits u32)`
//!   per run of equal bit patterns. Wins for cold/constant columns.
//!
//! A column whose every weight is bit-pattern `+0.0` encodes to the
//! *empty* record (length 0) under every codec except forced `Raw`: the
//! store's column directory then serves it with no disk bytes and no
//! decode at all — the zone-map skip.
//!
//! **Losslessness is bit-exact**, not value-exact: "zero" means the u32
//! bit pattern `0x0000_0000`, so `-0.0`, NaNs and subnormals are all
//! stored explicitly and `decode(encode(x))` reproduces `x` bit for bit.
//! RLE compares run membership on bit patterns for the same reason
//! (`NaN != NaN` as values, but equal payloads must land in one run).
//! That is what lets the paged bit-identity and pipeline-equivalence
//! tests carry over unchanged across codecs.

/// Write-time column encoding policy for [`super::paged::PagedPhi`].
///
/// `Auto` (the default) predicts all three encoded sizes in one pass over
/// the column and emits the smallest, tie-breaking deterministically
/// toward the cheapest decoder: `Raw`, then `Sparse`, then `Rle`. Reads
/// are dispatched on the per-record tag, so stores written under
/// different policies (or a policy changed between runs) stay readable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    /// Always write the dense k×f32 payload (bit-identity reference).
    Raw,
    /// Always write bitmap + nonzero weights.
    Sparse,
    /// Always write (count, bits) runs.
    Rle,
    /// Pick the smallest encoding per column at write time.
    #[default]
    Auto,
}

impl Codec {
    /// Parse a CLI / config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "raw" => Some(Self::Raw),
            "sparse" => Some(Self::Sparse),
            "rle" => Some(Self::Rle),
            "auto" => Some(Self::Auto),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Raw => "raw",
            Self::Sparse => "sparse",
            Self::Rle => "rle",
            Self::Auto => "auto",
        }
    }

    /// All policies, in tag order (bench sweeps and tests).
    pub fn all() -> [Self; 4] {
        [Self::Raw, Self::Sparse, Self::Rle, Self::Auto]
    }

    /// Stable numeric id persisted in the store header (the write
    /// *policy*, distinct from the per-record tag).
    pub(crate) fn header_tag(self) -> u64 {
        match self {
            Self::Raw => 0,
            Self::Sparse => 1,
            Self::Rle => 2,
            Self::Auto => 3,
        }
    }

    pub(crate) fn from_header_tag(tag: u64) -> Option<Self> {
        match tag {
            0 => Some(Self::Raw),
            1 => Some(Self::Sparse),
            2 => Some(Self::Rle),
            3 => Some(Self::Auto),
            _ => None,
        }
    }
}

/// Zone-map style per-column statistics, computed at encode time and
/// persisted in the store's column directory so readers can skip or
/// prioritize columns without decoding them.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ColumnStats {
    /// Weights whose bit pattern is nonzero (so -0.0 / NaN / subnormals
    /// count — anything the decoder must materialize explicitly).
    pub nnz: u32,
    /// Largest weight by value comparison, ignoring NaNs; `0.0` for an
    /// all-zero column.
    pub max: f32,
}

pub(crate) const TAG_RAW: u8 = 0;
pub(crate) const TAG_SPARSE: u8 = 1;
pub(crate) const TAG_RLE: u8 = 2;

#[inline]
fn is_stored(x: f32) -> bool {
    x.to_bits() != 0
}

/// One pass over the column: nnz, max, and the RLE run count (equal bit
/// patterns), enough to predict every encoded size.
fn scan(col: &[f32]) -> (ColumnStats, usize) {
    let mut nnz = 0u32;
    let mut max: Option<f32> = None;
    let mut runs = 0usize;
    let mut prev_bits = None;
    for &x in col {
        let bits = x.to_bits();
        if bits != 0 {
            nnz += 1;
        }
        if !x.is_nan() && max.map_or(true, |m| x > m) {
            max = Some(x);
        }
        if prev_bits != Some(bits) {
            runs += 1;
            prev_bits = Some(bits);
        }
    }
    // All-NaN (or empty) columns report 0.0 rather than a sentinel that
    // would confuse zone-map consumers.
    (ColumnStats { nnz, max: max.unwrap_or(0.0) }, runs)
}

fn raw_size(k: usize) -> usize {
    1 + 4 * k
}

fn sparse_size(k: usize, nnz: u32) -> usize {
    1 + k.div_ceil(8) + 4 * nnz as usize
}

fn rle_size(runs: usize) -> usize {
    1 + 4 + 8 * runs
}

fn encode_raw(col: &[f32], out: &mut Vec<u8>) {
    out.reserve(raw_size(col.len()));
    out.push(TAG_RAW);
    for &x in col {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn encode_sparse(col: &[f32], out: &mut Vec<u8>) {
    out.push(TAG_SPARSE);
    let bm_start = out.len();
    out.resize(bm_start + col.len().div_ceil(8), 0);
    for (i, &x) in col.iter().enumerate() {
        if is_stored(x) {
            out[bm_start + i / 8] |= 1 << (i % 8);
        }
    }
    for &x in col {
        if is_stored(x) {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn encode_rle(col: &[f32], out: &mut Vec<u8>) {
    out.push(TAG_RLE);
    let nruns_pos = out.len();
    out.extend_from_slice(&0u32.to_le_bytes());
    let mut runs = 0u32;
    let mut i = 0;
    while i < col.len() {
        let bits = col[i].to_bits();
        let mut j = i + 1;
        while j < col.len() && col[j].to_bits() == bits {
            j += 1;
        }
        out.extend_from_slice(&((j - i) as u32).to_le_bytes());
        out.extend_from_slice(&bits.to_le_bytes());
        runs += 1;
        i = j;
    }
    out[nruns_pos..nruns_pos + 4].copy_from_slice(&runs.to_le_bytes());
}

/// Encode `col` under `codec` into `out` (cleared first) and return its
/// zone-map stats. An all-zero column encodes to the empty record under
/// every policy except forced `Raw`.
pub(crate) fn encode_column(
    codec: Codec,
    col: &[f32],
    out: &mut Vec<u8>,
) -> ColumnStats {
    out.clear();
    let (stats, runs) = scan(col);
    let zero = stats.nnz == 0;
    match codec {
        Codec::Raw => encode_raw(col, out),
        Codec::Sparse => {
            if !zero {
                encode_sparse(col, out);
            }
        }
        Codec::Rle => {
            if !zero {
                encode_rle(col, out);
            }
        }
        Codec::Auto => {
            if !zero {
                let (r, s, l) = (
                    raw_size(col.len()),
                    sparse_size(col.len(), stats.nnz),
                    rle_size(runs),
                );
                if r <= s && r <= l {
                    encode_raw(col, out);
                } else if s <= l {
                    encode_sparse(col, out);
                } else {
                    encode_rle(col, out);
                }
            }
        }
    }
    debug_assert!(
        codec != Codec::Sparse || out.is_empty() || out.len() == sparse_size(col.len(), stats.nnz)
    );
    stats
}

/// Decode a record produced by [`encode_column`] into `out`
/// (`out.len() == k`). The empty record is the implicit all-zero column.
/// Parses from the front and tolerates trailing slack, so a record read
/// with a stale (longer) length from a concurrent-version window still
/// decodes its own payload correctly.
pub(crate) fn decode_column(bytes: &[u8], out: &mut [f32]) {
    if bytes.is_empty() {
        out.fill(0.0);
        return;
    }
    let k = out.len();
    let payload = &bytes[1..];
    match bytes[0] {
        TAG_RAW => {
            assert!(payload.len() >= 4 * k, "truncated raw column record");
            for (dst, chunk) in out.iter_mut().zip(payload.chunks_exact(4)) {
                *dst = f32::from_le_bytes(chunk.try_into().unwrap());
            }
        }
        TAG_SPARSE => {
            let bm_len = k.div_ceil(8);
            assert!(payload.len() >= bm_len, "truncated sparse bitmap");
            let (bitmap, weights) = payload.split_at(bm_len);
            out.fill(0.0);
            let mut cursor = 0usize;
            for (i, slot) in out.iter_mut().enumerate() {
                if bitmap[i / 8] & (1 << (i % 8)) != 0 {
                    let b = weights
                        .get(cursor..cursor + 4)
                        .expect("truncated sparse weights");
                    *slot = f32::from_le_bytes(b.try_into().unwrap());
                    cursor += 4;
                }
            }
        }
        TAG_RLE => {
            assert!(payload.len() >= 4, "truncated rle header");
            let n_runs =
                u32::from_le_bytes(payload[..4].try_into().unwrap()) as usize;
            let mut pos = 4usize;
            let mut filled = 0usize;
            for _ in 0..n_runs {
                let rec = payload
                    .get(pos..pos + 8)
                    .expect("truncated rle run");
                let count =
                    u32::from_le_bytes(rec[..4].try_into().unwrap()) as usize;
                let x =
                    f32::from_bits(u32::from_le_bytes(rec[4..].try_into().unwrap()));
                out[filled..filled + count].fill(x);
                filled += count;
                pos += 8;
            }
            assert_eq!(filled, k, "rle runs do not cover the column");
        }
        t => panic!("corrupt phi column record: unknown codec tag {t}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(codec: Codec, col: &[f32]) -> (Vec<f32>, usize, ColumnStats) {
        let mut bytes = Vec::new();
        let stats = encode_column(codec, col, &mut bytes);
        let mut back = vec![7.0f32; col.len()];
        decode_column(&bytes, &mut back);
        (back, bytes.len(), stats)
    }

    fn assert_bit_exact(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "index {i}: {x:?} vs {y:?}");
        }
    }

    #[test]
    fn codec_round_trip_dense_column_every_codec() {
        let col: Vec<f32> = (0..97).map(|i| (i as f32) * 0.25 + 0.125).collect();
        for codec in Codec::all() {
            let (back, _, st) = round_trip(codec, &col);
            assert_bit_exact(&col, &back);
            assert_eq!(st.nnz, 97);
            assert_eq!(st.max, 96.0 * 0.25 + 0.125);
        }
    }

    #[test]
    fn codec_round_trip_all_zero_column_every_codec() {
        let col = vec![0.0f32; 64];
        for codec in Codec::all() {
            let (back, len, st) = round_trip(codec, &col);
            assert_bit_exact(&col, &back);
            assert_eq!(st, ColumnStats { nnz: 0, max: 0.0 });
            if codec == Codec::Raw {
                assert_eq!(len, 1 + 64 * 4, "forced raw always writes dense");
            } else {
                assert_eq!(len, 0, "all-zero must be the implicit record");
            }
        }
    }

    #[test]
    fn codec_round_trip_special_payloads_bit_exact() {
        // -0.0, NaN (two payloads), subnormals and infinities must all
        // survive bit-for-bit; +0.0 must stay implicit.
        let col = vec![
            0.0f32,
            -0.0,
            f32::NAN,
            f32::from_bits(0x7fc0_1234), // NaN with a payload
            f32::MIN_POSITIVE / 8.0,     // subnormal
            -f32::MIN_POSITIVE / 16.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            1.5,
            0.0,
        ];
        for codec in Codec::all() {
            let (back, _, st) = round_trip(codec, &col);
            assert_bit_exact(&col, &back);
            // +0.0 twice -> 8 stored weights; max ignores NaN.
            assert_eq!(st.nnz, 8);
            assert_eq!(st.max, f32::INFINITY);
        }
    }

    #[test]
    fn codec_sparse_beats_raw_on_sparse_columns() {
        let mut col = vec![0.0f32; 256];
        col[3] = 1.0;
        col[97] = 2.5;
        let mut sparse = Vec::new();
        let mut raw = Vec::new();
        encode_column(Codec::Sparse, &col, &mut sparse);
        encode_column(Codec::Raw, &col, &mut raw);
        assert!(sparse.len() < raw.len() / 3);
        // Auto must therefore not pick raw.
        let mut auto = Vec::new();
        encode_column(Codec::Auto, &col, &mut auto);
        assert!(auto.len() <= sparse.len());
    }

    #[test]
    fn codec_rle_wins_on_constant_runs() {
        let mut col = vec![2.0f32; 300];
        col[0] = 1.0;
        let mut rle = Vec::new();
        let mut sparse = Vec::new();
        encode_column(Codec::Rle, &col, &mut rle);
        encode_column(Codec::Sparse, &col, &mut sparse);
        assert_eq!(rle.len(), 1 + 4 + 2 * 8, "two runs");
        assert!(rle.len() < sparse.len());
        let mut auto = Vec::new();
        encode_column(Codec::Auto, &col, &mut auto);
        assert_eq!(auto.len(), rle.len());
        assert_eq!(auto[0], TAG_RLE);
    }

    #[test]
    fn codec_auto_picks_smallest_and_is_self_describing() {
        let mut rng = crate::util::Rng::new(42);
        for k in [1usize, 7, 8, 9, 64, 129] {
            for density_pct in [0u64, 5, 25, 60, 100] {
                let col: Vec<f32> = (0..k)
                    .map(|_| {
                        if rng.below(100) < density_pct as usize {
                            rng.next_f32() * 10.0
                        } else {
                            0.0
                        }
                    })
                    .collect();
                let mut auto = Vec::new();
                encode_column(Codec::Auto, &col, &mut auto);
                for forced in [Codec::Raw, Codec::Sparse, Codec::Rle] {
                    let mut b = Vec::new();
                    encode_column(forced, &col, &mut b);
                    // Forced raw is never empty, so compare only real
                    // encodings; auto includes the empty option.
                    if !b.is_empty() || forced != Codec::Raw {
                        assert!(
                            auto.len() <= b.len(),
                            "auto {} > {} {:?} (k={k} d={density_pct})",
                            auto.len(),
                            b.len(),
                            forced
                        );
                    }
                }
                let mut back = vec![3.0f32; k];
                decode_column(&auto, &mut back);
                assert_bit_exact(&col, &back);
            }
        }
    }

    #[test]
    fn codec_round_trip_randomized_sparsity_sweep() {
        // Property-style sweep: random columns at random sparsity levels,
        // with occasional special bit patterns mixed in, must round-trip
        // bit-exactly under every codec.
        let mut rng = crate::util::Rng::new(777);
        let specials = [
            f32::NAN,
            -0.0,
            f32::from_bits(1), // smallest subnormal
            f32::INFINITY,
            f32::MAX,
        ];
        for trial in 0..200 {
            let k = 1 + rng.below(200);
            let density = rng.below(101);
            let col: Vec<f32> = (0..k)
                .map(|_| {
                    if rng.below(100) >= density {
                        0.0
                    } else if rng.below(20) == 0 {
                        specials[rng.below(specials.len())]
                    } else {
                        rng.next_f32() * 100.0
                    }
                })
                .collect();
            for codec in Codec::all() {
                let (back, _, st) = round_trip(codec, &col);
                assert_bit_exact(&col, &back);
                let want_nnz =
                    col.iter().filter(|x| x.to_bits() != 0).count() as u32;
                assert_eq!(st.nnz, want_nnz, "trial {trial}");
            }
        }
    }

    #[test]
    fn codec_parse_and_names_round_trip() {
        for codec in Codec::all() {
            assert_eq!(Codec::parse(codec.name()), Some(codec));
            assert_eq!(Codec::from_header_tag(codec.header_tag()), Some(codec));
        }
        assert_eq!(Codec::parse("zstd"), None);
        assert_eq!(Codec::from_header_tag(9), None);
        assert_eq!(Codec::default(), Codec::Auto);
    }
}
