//! Disk-backed paged column store for `phi_hat_{K×W}` with a hot-word
//! buffer — the parameter-streaming engine of §3.2.
//!
//! Layout of the backing file (`<path>`):
//!   [magic u64][k u64][n_words u64]  then column `w` at byte offset
//!   `HEADER + w*k*4`, little-endian f32.
//!
//! The paper stores parameters in HDF5; we use a fixed-stride binary file,
//! which preserves the properties the paper relies on (one sequential I/O
//! run per column, restartability/fault tolerance, O(buffer) memory) with
//! zero dependency weight.  A sidecar `<path>.meta.json` carries the
//! algorithm state needed for restart (step counter, phisum), written by
//! [`PagedPhi::checkpoint`].
//!
//! Buffering policy (Fig. 4 line 2): at every minibatch the coordinator
//! calls `set_hot_words` with the minibatch's most frequent words; those
//! columns become buffer-resident (write-back) until replaced. Non-hot
//! columns are read, mutated and written straight back (one read + one
//! write per visit — exactly the paper's "read and write wth column of
//! phi only once at each iteration").
//!
//! # Background I/O mode (pipelined parameter streaming)
//!
//! [`PhiColumnStore::set_async_io`] switches the store into the overlapped
//! mode the software pipeline (`exec::pipeline`, `rust/DESIGN.md` §7)
//! runs on. A single background thread then owns **all** disk traffic:
//!
//! * **Prefetch** — [`PhiColumnStore::prefetch_columns`] queues the next
//!   minibatch's columns; the thread loads them into a prefetch cache
//!   while the current minibatch computes, so the stage-time snapshot
//!   reads become cache hits (`IoStats::prefetch_hits`) instead of
//!   blocking disk reads.
//! * **Write-behind** — column writes land in a versioned pending map and
//!   are flushed by the thread off the critical path
//!   (`IoStats::wb_writes`); reads are always served freshest-first
//!   (pending write → prefetch cache → disk).
//!
//! Because the foreground sends requests over a FIFO channel and blocks on
//! its own reads, the visible read results are exactly the synchronous
//! ones — overlap changes *when* I/O happens, never *what* a read sees.
//! With async I/O off (the default), behavior and [`IoStats`] are
//! bit-identical to the original synchronous store.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};

use super::{IoStats, PhiColumnStore};

const MAGIC: u64 = 0xF0E3_14DA_0001;
const HEADER_BYTES: u64 = 24;

fn col_offset(k: usize, w: usize) -> u64 {
    HEADER_BYTES + (w * k * 4) as u64
}

/// Uncounted column read used by both the foreground (sync mode) and the
/// background I/O thread.
fn raw_read_col(file: &mut File, k: usize, w: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), k);
    file.seek(SeekFrom::Start(col_offset(k, w))).expect("seek");
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 4)
    };
    file.read_exact(bytes).expect("column read");
}

/// Uncounted column write, shared like [`raw_read_col`].
fn raw_write_col(file: &mut File, k: usize, w: usize, data: &[f32]) {
    debug_assert_eq!(data.len(), k);
    file.seek(SeekFrom::Start(col_offset(k, w))).expect("seek");
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    file.write_all(bytes).expect("column write");
}

/// Where a routed (async-mode) column read was served from.
#[derive(Debug, Clone, Copy)]
enum ReadSource {
    Disk,
    Prefetched,
    WriteBuffer,
}

/// Requests to the background I/O thread. The channel is FIFO and the
/// foreground is the only sender, which is what makes the overlapped mode
/// deterministic: a read queued after a write signal for the same column
/// always observes the flushed state.
enum IoReq {
    /// Synchronous read round-trip (the caller blocks on `resp`).
    Read {
        w: usize,
        resp: SyncSender<(Vec<f32>, ReadSource)>,
    },
    /// A pending write was enqueued; flush it if `version` is still
    /// current (superseded versions are skipped — a later signal covers
    /// the column).
    WriteSignal { w: u32, version: u64 },
    /// Load these columns into the prefetch cache.
    Prefetch(Vec<u32>),
    /// Flush every pending write, fsync, then ack with the fsync result
    /// (so an async-mode checkpoint surfaces durability failures exactly
    /// like the synchronous path).
    DrainAndSync { ack: SyncSender<std::io::Result<()>> },
    Shutdown,
}

/// State shared between the store and its background I/O thread.
#[derive(Default)]
struct AsyncShared {
    /// Write-behind buffer: word -> (version, column). Freshest data for
    /// a column not in the hot buffer.
    pending: Mutex<HashMap<u32, (u64, Vec<f32>)>>,
    /// Prefetch cache: columns staged ahead of use. Entries are served by
    /// clone, invalidated whenever the column is written, and bounded by
    /// the size cap in the prefetch handler.
    prefetched: Mutex<HashMap<u32, Vec<f32>>>,
    /// Columns loaded by the prefetcher (background reads).
    prefetched_cols: AtomicU64,
    /// Columns flushed by the write-behind path (background writes).
    wb_writes: AtomicU64,
}

struct AsyncIo {
    tx: Sender<IoReq>,
    shared: Arc<AsyncShared>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Monotonic version for pending writes (MVCC-light: lets the daemon
    /// skip flushes that a newer write already superseded).
    next_version: u64,
}

/// The background I/O loop: sole owner of disk traffic while async mode
/// is on.
fn io_daemon(mut file: File, k: usize, rx: Receiver<IoReq>, shared: Arc<AsyncShared>) {
    let mut buf = vec![0.0f32; k];
    for req in rx {
        match req {
            IoReq::Read { w, resp } => {
                let from_pending = shared
                    .pending
                    .lock()
                    .unwrap()
                    .get(&(w as u32))
                    .map(|(_, col)| col.clone());
                let reply = if let Some(col) = from_pending {
                    (col, ReadSource::WriteBuffer)
                } else if let Some(col) = shared
                    .prefetched
                    .lock()
                    .unwrap()
                    .get(&(w as u32))
                    .cloned()
                {
                    // Served by CLONE, not removal: a mid-run evaluation
                    // pass reads many of the same columns the prefetcher
                    // just staged for the next batch — consuming the
                    // entries would evict them right before the stage
                    // that needed them. Entries are dropped on write
                    // invalidation or the size cap instead.
                    (col, ReadSource::Prefetched)
                } else {
                    raw_read_col(&mut file, k, w, &mut buf);
                    (buf.clone(), ReadSource::Disk)
                };
                let _ = resp.send(reply);
            }
            IoReq::WriteSignal { w, version } => {
                let col = match shared.pending.lock().unwrap().get(&w) {
                    Some((v, col)) if *v == version => Some(col.clone()),
                    _ => None, // superseded by a newer write
                };
                if let Some(col) = col {
                    raw_write_col(&mut file, k, w as usize, &col);
                    shared.wb_writes.fetch_add(1, Ordering::Relaxed);
                    // Invalidation order matters for the foreground fast
                    // path (pending first, then prefetched): the stale
                    // prefetch copy must be gone BEFORE the pending entry
                    // stops shadowing it.
                    shared.prefetched.lock().unwrap().remove(&w);
                    {
                        let mut pending = shared.pending.lock().unwrap();
                        if matches!(pending.get(&w), Some((v, _)) if *v == version)
                        {
                            pending.remove(&w);
                        }
                    }
                }
            }
            IoReq::Prefetch(words) => {
                {
                    // The cache is a hint; keep it bounded even if the
                    // caller never consumes some entries.
                    let mut pf = shared.prefetched.lock().unwrap();
                    if pf.len() > 4 * words.len() + 1024 {
                        pf.clear();
                    }
                }
                for w in words {
                    if shared.prefetched.lock().unwrap().contains_key(&w) {
                        continue;
                    }
                    // Freshest-first, same as Read: a pending write beats
                    // the disk copy.
                    let from_pending = shared
                        .pending
                        .lock()
                        .unwrap()
                        .get(&w)
                        .map(|(_, col)| col.clone());
                    let col = match from_pending {
                        Some(col) => col,
                        None => {
                            raw_read_col(&mut file, k, w as usize, &mut buf);
                            buf.clone()
                        }
                    };
                    shared.prefetched_cols.fetch_add(1, Ordering::Relaxed);
                    shared.prefetched.lock().unwrap().insert(w, col);
                }
            }
            IoReq::DrainAndSync { ack } => {
                loop {
                    let next = shared
                        .pending
                        .lock()
                        .unwrap()
                        .iter()
                        .next()
                        .map(|(w, (v, col))| (*w, *v, col.clone()));
                    let Some((w, version, col)) = next else { break };
                    raw_write_col(&mut file, k, w as usize, &col);
                    shared.wb_writes.fetch_add(1, Ordering::Relaxed);
                    // Same invalidation order as WriteSignal: prefetched
                    // copy first, then the shadowing pending entry.
                    shared.prefetched.lock().unwrap().remove(&w);
                    {
                        let mut pending = shared.pending.lock().unwrap();
                        if matches!(pending.get(&w), Some((v, _)) if *v == version)
                        {
                            pending.remove(&w);
                        }
                    }
                }
                let _ = ack.send(file.sync_data());
            }
            IoReq::Shutdown => break,
        }
    }
}

/// Disk-backed column store with a bounded hot buffer.
pub struct PagedPhi {
    k: usize,
    n_words: usize,
    file: File,
    path: PathBuf,
    /// Hot-word buffer: local slot per hot word, write-back.
    buffer: Vec<f32>,
    /// word id -> slot index in `buffer`.
    slot_of: std::collections::HashMap<u32, usize>,
    /// slot -> word id (for eviction write-back).
    word_of_slot: Vec<u32>,
    dirty: Vec<bool>,
    /// Maximum number of buffered columns (from the byte budget).
    max_slots: usize,
    stats: IoStats,
    /// Scratch for non-buffered column visits.
    scratch: Vec<f32>,
    /// Background prefetch/write-behind machinery; `None` = synchronous.
    async_io: Option<AsyncIo>,
}

impl PagedPhi {
    /// Create (or overwrite) a store of `n_words` zero columns with a hot
    /// buffer of `buffer_bytes`.
    pub fn create(
        path: &Path,
        k: usize,
        n_words: usize,
        buffer_bytes: usize,
    ) -> anyhow::Result<Self> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        let mut header = [0u8; HEADER_BYTES as usize];
        header[..8].copy_from_slice(&MAGIC.to_le_bytes());
        header[8..16].copy_from_slice(&(k as u64).to_le_bytes());
        header[16..24].copy_from_slice(&(n_words as u64).to_le_bytes());
        file.write_all(&header)?;
        // Extend to full size with zeros without materializing K*W memory.
        file.set_len(HEADER_BYTES + (k * n_words * 4) as u64)?;
        let max_slots = (buffer_bytes / (k * 4)).max(1);
        Ok(Self {
            k,
            n_words,
            file,
            path: path.to_path_buf(),
            buffer: Vec::new(),
            slot_of: std::collections::HashMap::new(),
            word_of_slot: Vec::new(),
            dirty: Vec::new(),
            max_slots,
            stats: IoStats::default(),
            scratch: vec![0.0; k],
            async_io: None,
        })
    }

    /// Reopen an existing store (restart / fault recovery).
    pub fn open(path: &Path, buffer_bytes: usize) -> anyhow::Result<Self> {
        let mut file = OpenOptions::new().read(true).write(true).open(path)?;
        let mut header = [0u8; HEADER_BYTES as usize];
        file.read_exact(&mut header)?;
        let magic = u64::from_le_bytes(header[..8].try_into().unwrap());
        anyhow::ensure!(magic == MAGIC, "not a PagedPhi file: {path:?}");
        let k = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let n_words =
            u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        let max_slots = (buffer_bytes / (k * 4)).max(1);
        Ok(Self {
            k,
            n_words,
            file,
            path: path.to_path_buf(),
            buffer: Vec::new(),
            slot_of: std::collections::HashMap::new(),
            word_of_slot: Vec::new(),
            dirty: Vec::new(),
            max_slots,
            stats: IoStats::default(),
            scratch: vec![0.0; k],
            async_io: None,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn max_buffered_columns(&self) -> usize {
        self.max_slots
    }

    pub fn buffered_columns(&self) -> usize {
        self.slot_of.len()
    }

    /// Whether background prefetch/write-behind is currently on.
    pub fn async_io_enabled(&self) -> bool {
        self.async_io.is_some()
    }

    fn read_col_from_disk(&mut self, w: usize, out: &mut [f32]) {
        self.stats.col_reads += 1;
        raw_read_col(&mut self.file, self.k, w, out);
    }

    fn write_col_to_disk(&mut self, w: usize, data: &[f32]) {
        self.stats.col_writes += 1;
        raw_write_col(&mut self.file, self.k, w, data);
    }

    /// Route a non-hot column read: in sync mode straight off disk; in
    /// async mode freshest-first — pending write, then prefetch cache
    /// (both served directly from the shared maps, no round trip), then a
    /// blocking read through the I/O thread. Counts by source — a
    /// prefetch hit is NOT a buffer miss, which is exactly the overlap
    /// the pipeline buys.
    ///
    /// The foreground fast path is safe because a stale prefetch copy
    /// only ever exists while the pending entry for the same column
    /// shadows it: writes invalidate the cache at enqueue time, and the
    /// I/O thread re-invalidates BEFORE it drops the pending entry.
    fn fetch_col(&mut self, w: usize, out: &mut [f32], count_miss: bool) {
        if let Some(aio) = &self.async_io {
            let served_pending = {
                let pending = aio.shared.pending.lock().unwrap();
                match pending.get(&(w as u32)) {
                    Some((_, col)) => {
                        out.copy_from_slice(col);
                        true
                    }
                    None => false,
                }
            };
            if served_pending {
                self.stats.buffer_hits += 1;
                return;
            }
            let served_prefetch = {
                let prefetched = aio.shared.prefetched.lock().unwrap();
                match prefetched.get(&(w as u32)) {
                    Some(col) => {
                        out.copy_from_slice(col);
                        true
                    }
                    None => false,
                }
            };
            if served_prefetch {
                self.stats.prefetch_hits += 1;
                return;
            }
            let (tx, rx) = std::sync::mpsc::sync_channel(1);
            aio.tx
                .send(IoReq::Read { w, resp: tx })
                .expect("store I/O thread alive");
            let (col, src) = rx.recv().expect("store I/O thread reply");
            out.copy_from_slice(&col);
            match src {
                ReadSource::Disk => {
                    self.stats.col_reads += 1;
                    if count_miss {
                        self.stats.buffer_misses += 1;
                    }
                }
                ReadSource::Prefetched => self.stats.prefetch_hits += 1,
                ReadSource::WriteBuffer => self.stats.buffer_hits += 1,
            }
        } else {
            if count_miss {
                self.stats.buffer_misses += 1;
            }
            self.read_col_from_disk(w, out);
        }
    }

    /// Route a non-hot column write: direct in sync mode, write-behind in
    /// async mode (versioned pending entry + flush signal; any prefetched
    /// copy of the column is invalidated immediately).
    fn put_col(&mut self, w: usize, data: &[f32]) {
        if let Some(aio) = &mut self.async_io {
            aio.next_version += 1;
            let version = aio.next_version;
            aio.shared.prefetched.lock().unwrap().remove(&(w as u32));
            aio.shared
                .pending
                .lock()
                .unwrap()
                .insert(w as u32, (version, data.to_vec()));
            aio.tx
                .send(IoReq::WriteSignal { w: w as u32, version })
                .expect("store I/O thread alive");
        } else {
            self.write_col_to_disk(w, data);
        }
    }

    /// Block until the I/O thread has flushed every pending write and
    /// fsynced, propagating the fsync result. No-op in sync mode.
    fn quiesce_async(&self) -> anyhow::Result<()> {
        if let Some(aio) = &self.async_io {
            let (ack, ack_rx) = std::sync::mpsc::sync_channel(1);
            aio.tx
                .send(IoReq::DrainAndSync { ack })
                .map_err(|_| anyhow::anyhow!("store I/O thread is gone"))?;
            ack_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("store I/O thread is gone"))??;
        }
        Ok(())
    }

    fn evict_slot(&mut self, slot: usize) {
        let w = self.word_of_slot[slot];
        if self.dirty[slot] {
            let col: Vec<f32> =
                self.buffer[slot * self.k..(slot + 1) * self.k].to_vec();
            self.put_col(w as usize, &col);
            self.dirty[slot] = false;
        }
        self.slot_of.remove(&w);
    }

    /// Write a checkpoint sidecar with algorithm state (fault tolerance:
    /// "the global topic-word matrix is stored in hard disk for
    /// restarting the online learning", §3.2).
    pub fn checkpoint(&mut self, step: usize, phisum: &[f32]) -> anyhow::Result<()> {
        self.flush()?;
        let mut meta = String::new();
        meta.push_str(&format!("step {step}\n"));
        meta.push_str(&format!("k {}\n", self.k));
        meta.push_str(&format!("n_words {}\n", self.n_words));
        meta.push_str("phisum");
        for &x in phisum {
            meta.push_str(&format!(" {x}"));
        }
        meta.push('\n');
        let meta_path = self.path.with_extension("meta");
        std::fs::write(meta_path, meta)?;
        Ok(())
    }

    /// Load the checkpoint sidecar: `(step, phisum)`.
    pub fn load_checkpoint(path: &Path) -> anyhow::Result<(usize, Vec<f32>)> {
        let meta_path = path.with_extension("meta");
        let text = std::fs::read_to_string(meta_path)?;
        let mut step = 0usize;
        let mut phisum = Vec::new();
        for line in text.lines() {
            let mut it = line.split_ascii_whitespace();
            match it.next() {
                Some("step") => {
                    step = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("bad checkpoint"))?
                        .parse()?;
                }
                Some("phisum") => {
                    phisum = it
                        .map(|x| x.parse::<f32>())
                        .collect::<Result<Vec<_>, _>>()?;
                }
                _ => {}
            }
        }
        anyhow::ensure!(!phisum.is_empty(), "bad checkpoint: no phisum");
        Ok((step, phisum))
    }
}

impl PhiColumnStore for PagedPhi {
    fn k(&self) -> usize {
        self.k
    }

    fn n_words(&self) -> usize {
        self.n_words
    }

    fn ensure_capacity(&mut self, n_words: usize) {
        if n_words <= self.n_words {
            return;
        }
        // Quiesce the I/O thread so the growth below cannot race an
        // in-flight background read or write.
        self.quiesce_async().expect("quiesce store I/O thread");
        self.n_words = n_words;
        self.file
            .set_len(HEADER_BYTES + (self.k * n_words * 4) as u64)
            .expect("grow file");
        // Persist the new W in the header.
        self.file.seek(SeekFrom::Start(16)).expect("seek header");
        self.file
            .write_all(&(n_words as u64).to_le_bytes())
            .expect("header write");
    }

    fn with_column<R>(&mut self, w: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
        assert!(w < self.n_words, "column {w} out of range {}", self.n_words);
        if let Some(&slot) = self.slot_of.get(&(w as u32)) {
            self.stats.buffer_hits += 1;
            self.dirty[slot] = true;
            return f(&mut self.buffer[slot * self.k..(slot + 1) * self.k]);
        }
        // Miss: stream through scratch — read, mutate, write back (Fig. 4
        // lines 8 and 15).
        let mut scratch = std::mem::take(&mut self.scratch);
        self.fetch_col(w, &mut scratch, true);
        let r = f(&mut scratch);
        self.put_col(w, &scratch);
        self.scratch = scratch;
        r
    }

    fn load_column(&mut self, w: usize, out: &mut [f32]) {
        assert!(w < self.n_words);
        if let Some(&slot) = self.slot_of.get(&(w as u32)) {
            self.stats.buffer_hits += 1;
            out.copy_from_slice(&self.buffer[slot * self.k..(slot + 1) * self.k]);
            return;
        }
        self.fetch_col(w, out, true);
    }

    fn store_column(&mut self, w: usize, data: &[f32]) {
        assert!(w < self.n_words);
        if let Some(&slot) = self.slot_of.get(&(w as u32)) {
            self.stats.buffer_hits += 1;
            self.buffer[slot * self.k..(slot + 1) * self.k]
                .copy_from_slice(data);
            self.dirty[slot] = true;
            return;
        }
        self.stats.buffer_misses += 1;
        self.put_col(w, data);
    }

    fn set_hot_words(&mut self, words: &[u32]) {
        use std::collections::HashSet;
        let want: HashSet<u32> =
            words.iter().copied().take(self.max_slots).collect();
        // Evict buffered columns that are no longer hot.
        let to_evict: Vec<usize> = self
            .slot_of
            .iter()
            .filter(|(w, _)| !want.contains(w))
            .map(|(_, &s)| s)
            .collect();
        for slot in to_evict {
            self.evict_slot(slot);
        }
        // Load newly hot columns into free slots.
        for &w in words.iter().take(self.max_slots) {
            if self.slot_of.contains_key(&w) {
                continue;
            }
            let slot = if self.word_of_slot.len() < self.max_slots {
                let slot = self.word_of_slot.len();
                self.word_of_slot.push(w);
                self.dirty.push(false);
                self.buffer.resize((slot + 1) * self.k, 0.0);
                slot
            } else {
                // Find a slot not mapped (evicted above).
                match (0..self.word_of_slot.len()).find(|&s| {
                    !self.slot_of.contains_key(&self.word_of_slot[s])
                        || self.slot_of[&self.word_of_slot[s]] != s
                }) {
                    Some(s) => s,
                    None => continue, // buffer full of still-hot words
                }
            };
            let mut col = vec![0.0f32; self.k];
            self.fetch_col(w as usize, &mut col, false);
            self.buffer[slot * self.k..(slot + 1) * self.k].copy_from_slice(&col);
            self.word_of_slot[slot] = w;
            self.dirty[slot] = false;
            self.slot_of.insert(w, slot);
        }
    }

    fn prefetch_columns(&mut self, words: &[u32]) {
        let Some(aio) = &self.async_io else { return };
        // Hot columns never touch the daemon, so prefetching them would
        // only orphan cache entries.
        let wanted: Vec<u32> = words
            .iter()
            .copied()
            .filter(|w| {
                (*w as usize) < self.n_words && !self.slot_of.contains_key(w)
            })
            .collect();
        if !wanted.is_empty() {
            let _ = aio.tx.send(IoReq::Prefetch(wanted));
        }
    }

    fn set_async_io(&mut self, enabled: bool) -> bool {
        if enabled {
            if self.async_io.is_none() {
                let file =
                    self.file.try_clone().expect("clone store file handle");
                let shared = Arc::new(AsyncShared::default());
                let worker_shared = Arc::clone(&shared);
                let (tx, rx) = std::sync::mpsc::channel();
                let k = self.k;
                let handle = std::thread::Builder::new()
                    .name("phi-io".into())
                    .spawn(move || io_daemon(file, k, rx, worker_shared))
                    .expect("spawn store I/O thread");
                self.async_io = Some(AsyncIo {
                    tx,
                    shared,
                    handle: Some(handle),
                    next_version: 0,
                });
            }
        } else if let Some(mut aio) = self.async_io.take() {
            // Drain the write-behind buffer, then stop the thread and fold
            // its counters into the resident stats.
            let (ack, ack_rx) = std::sync::mpsc::sync_channel(1);
            if aio.tx.send(IoReq::DrainAndSync { ack }).is_ok() {
                let _ = ack_rx.recv();
            }
            let _ = aio.tx.send(IoReq::Shutdown);
            if let Some(h) = aio.handle.take() {
                let _ = h.join();
            }
            self.stats.prefetched_cols +=
                aio.shared.prefetched_cols.load(Ordering::Relaxed);
            self.stats.wb_writes += aio.shared.wb_writes.load(Ordering::Relaxed);
        }
        true
    }

    fn flush(&mut self) -> anyhow::Result<()> {
        let slots: Vec<(usize, u32)> = self
            .word_of_slot
            .iter()
            .enumerate()
            .filter(|(s, w)| {
                self.slot_of.get(w) == Some(s) && self.dirty[*s]
            })
            .map(|(s, &w)| (s, w))
            .collect();
        if self.async_io.is_some() {
            // Route the hot-buffer write-backs through the write-behind
            // path, then drain everything and fsync on the I/O thread.
            for (slot, w) in slots {
                let col: Vec<f32> =
                    self.buffer[slot * self.k..(slot + 1) * self.k].to_vec();
                self.put_col(w as usize, &col);
                self.dirty[slot] = false;
            }
            self.quiesce_async()?;
            return Ok(());
        }
        for (slot, w) in slots {
            let col: Vec<f32> =
                self.buffer[slot * self.k..(slot + 1) * self.k].to_vec();
            self.write_col_to_disk(w as usize, &col);
            self.dirty[slot] = false;
        }
        self.file.sync_data()?;
        Ok(())
    }

    fn io_stats(&self) -> IoStats {
        let mut s = self.stats;
        if let Some(aio) = &self.async_io {
            s.prefetched_cols += aio.shared.prefetched_cols.load(Ordering::Relaxed);
            s.wb_writes += aio.shared.wb_writes.load(Ordering::Relaxed);
        }
        s
    }
}

impl Drop for PagedPhi {
    fn drop(&mut self) {
        // Stop the I/O thread first (drains pending writes), then persist
        // whatever is still dirty in the hot buffer.
        self.set_async_io(false);
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn new_store(k: usize, w: usize, buf_cols: usize) -> (crate::util::TempDir, PagedPhi) {
        let dir = crate::util::TempDir::new("t");
        let path = dir.path().join("phi.bin");
        let store = PagedPhi::create(&path, k, w, buf_cols * k * 4).unwrap();
        (dir, store)
    }

    #[test]
    fn read_write_round_trip_unbuffered() {
        let (_d, mut s) = new_store(4, 8, 1);
        s.with_column(3, |c| c.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        s.with_column(7, |c| c.copy_from_slice(&[9.0; 4]));
        assert_eq!(s.read_column(3), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.read_column(7), vec![9.0; 4]);
        assert_eq!(s.read_column(0), vec![0.0; 4]);
        // with_column misses read+write; read_column (load path) only
        // reads.
        assert!(s.io_stats().col_reads >= 5);
        assert_eq!(s.io_stats().col_writes, 2);
        // Background-I/O counters stay zero in synchronous mode.
        assert_eq!(s.io_stats().prefetched_cols, 0);
        assert_eq!(s.io_stats().prefetch_hits, 0);
        assert_eq!(s.io_stats().wb_writes, 0);
    }

    #[test]
    fn hot_buffer_avoids_disk_io() {
        let (_d, mut s) = new_store(4, 8, 4);
        s.set_hot_words(&[1, 2]);
        let base_reads = s.io_stats().col_reads;
        for _ in 0..10 {
            s.with_column(1, |c| c[0] += 1.0);
            s.with_column(2, |c| c[1] += 1.0);
        }
        assert_eq!(s.io_stats().col_reads, base_reads, "hits must not read");
        assert_eq!(s.io_stats().buffer_hits, 20);
        s.flush().unwrap();
        assert_eq!(s.read_column(1)[0], 10.0);
        assert_eq!(s.read_column(2)[1], 10.0);
    }

    #[test]
    fn eviction_writes_back_dirty_columns() {
        let (_d, mut s) = new_store(2, 6, 2);
        s.set_hot_words(&[0, 1]);
        s.with_column(0, |c| c.copy_from_slice(&[5.0, 5.0]));
        // Replace the hot set: column 0 must be written back.
        s.set_hot_words(&[2, 3]);
        assert_eq!(s.read_column(0), vec![5.0, 5.0]);
    }

    #[test]
    fn buffer_respects_budget() {
        let (_d, mut s) = new_store(2, 100, 3);
        s.set_hot_words(&(0u32..50).collect::<Vec<_>>());
        assert!(s.buffered_columns() <= 3);
    }

    #[test]
    fn restart_recovers_state() {
        let dir = crate::util::TempDir::new("t");
        let path = dir.path().join("phi.bin");
        {
            let mut s = PagedPhi::create(&path, 3, 5, 3 * 4 * 2).unwrap();
            s.set_hot_words(&[1]);
            s.with_column(1, |c| c.copy_from_slice(&[1.0, 2.0, 3.0]));
            s.with_column(4, |c| c.copy_from_slice(&[7.0, 8.0, 9.0]));
            s.checkpoint(42, &[6.0, 10.0, 12.0]).unwrap();
        } // dropped: flushed
        let mut s = PagedPhi::open(&path, 1024).unwrap();
        assert_eq!(s.k(), 3);
        assert_eq!(s.n_words(), 5);
        assert_eq!(s.read_column(1), vec![1.0, 2.0, 3.0]);
        assert_eq!(s.read_column(4), vec![7.0, 8.0, 9.0]);
        let (step, phisum) = PagedPhi::load_checkpoint(&path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(phisum, vec![6.0, 10.0, 12.0]);
    }

    #[test]
    fn capacity_growth_persists_and_zeroes() {
        let (_d, mut s) = new_store(2, 3, 1);
        s.with_column(2, |c| c.copy_from_slice(&[1.0, 1.0]));
        s.ensure_capacity(10);
        assert_eq!(s.n_words(), 10);
        assert_eq!(s.read_column(9), vec![0.0, 0.0]);
        assert_eq!(s.read_column(2), vec![1.0, 1.0]);
    }

    #[test]
    fn export_dense_round_trip() {
        let (_d, mut s) = new_store(2, 4, 2);
        s.with_column(0, |c| c.copy_from_slice(&[1.0, 0.5]));
        s.with_column(3, |c| c.copy_from_slice(&[0.0, 2.0]));
        let dense = s.export_dense();
        assert_eq!(dense.word(0), &[1.0, 0.5]);
        assert_eq!(dense.word(3), &[0.0, 2.0]);
        assert_eq!(dense.phisum, vec![1.0, 2.5]);
    }

    #[test]
    fn hot_set_changes_are_correct_across_many_rounds() {
        // Churn the hot set and verify contents never corrupt.
        let (_d, mut s) = new_store(2, 20, 4);
        let mut truth = vec![[0.0f32; 2]; 20];
        let mut rng = crate::util::Rng::new(5);
        for round in 0..30 {
            let hot: Vec<u32> =
                (0..4).map(|_| rng.below(20) as u32).collect();
            s.set_hot_words(&hot);
            for _ in 0..10 {
                let w = rng.below(20);
                let inc = (round + 1) as f32;
                s.with_column(w, |c| {
                    c[0] += inc;
                    c[1] += 0.5;
                });
                truth[w][0] += inc;
                truth[w][1] += 0.5;
            }
        }
        s.flush().unwrap();
        for w in 0..20 {
            let col = s.read_column(w);
            assert!((col[0] - truth[w][0]).abs() < 1e-4, "w={w}");
            assert!((col[1] - truth[w][1]).abs() < 1e-4, "w={w}");
        }
    }

    #[test]
    fn async_io_round_trip_prefetch_and_write_behind() {
        let (_d, mut s) = new_store(4, 16, 2);
        assert!(s.set_async_io(true));
        assert!(s.async_io_enabled());
        s.prefetch_columns(&[3, 5, 7]);
        // A write-behind write followed by a read must see the new data
        // (served from the pending buffer or the flushed file).
        s.with_column(3, |c| c.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        assert_eq!(s.read_column(3), vec![1.0, 2.0, 3.0, 4.0]);
        // A prefetched, never-written column reads its disk value.
        assert_eq!(s.read_column(5), vec![0.0; 4]);
        s.flush().unwrap();
        assert!(s.set_async_io(false));
        let io = s.io_stats();
        assert!(io.prefetched_cols >= 3, "{io:?}");
        assert!(io.prefetch_hits >= 1, "{io:?}");
        assert!(io.wb_writes >= 1, "{io:?}");
        // Back in synchronous mode the data is durable.
        assert_eq!(s.read_column(3), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn async_io_matches_sync_contents_under_churn() {
        // Same churn as the sync test, with the background I/O mode on:
        // prefetches, write-behind, hot-set evictions and reads must never
        // lose or reorder an update.
        let (_d, mut s) = new_store(2, 20, 4);
        s.set_async_io(true);
        let mut truth = vec![[0.0f32; 2]; 20];
        let mut rng = crate::util::Rng::new(5);
        for round in 0..30 {
            let hot: Vec<u32> =
                (0..4).map(|_| rng.below(20) as u32).collect();
            s.set_hot_words(&hot);
            let ahead: Vec<u32> =
                (0..6).map(|_| rng.below(20) as u32).collect();
            s.prefetch_columns(&ahead);
            for _ in 0..10 {
                let w = rng.below(20);
                let inc = (round + 1) as f32;
                s.with_column(w, |c| {
                    c[0] += inc;
                    c[1] += 0.5;
                });
                truth[w][0] += inc;
                truth[w][1] += 0.5;
            }
        }
        s.flush().unwrap();
        s.set_async_io(false);
        for w in 0..20 {
            let col = s.read_column(w);
            assert!((col[0] - truth[w][0]).abs() < 1e-4, "w={w}");
            assert!((col[1] - truth[w][1]).abs() < 1e-4, "w={w}");
        }
    }

    #[test]
    fn async_io_survives_capacity_growth() {
        let (_d, mut s) = new_store(2, 3, 1);
        s.set_async_io(true);
        s.with_column(2, |c| c.copy_from_slice(&[1.0, 1.0]));
        s.ensure_capacity(10);
        assert_eq!(s.n_words(), 10);
        assert_eq!(s.read_column(9), vec![0.0, 0.0]);
        assert_eq!(s.read_column(2), vec![1.0, 1.0]);
        s.set_async_io(false);
        assert_eq!(s.read_column(2), vec![1.0, 1.0]);
    }
}
