//! Disk-backed paged column store for `phi_hat_{K×W}` with a hot-word
//! buffer — the parameter-streaming engine of §3.2.
//!
//! Layout of the backing file (`<path>`):
//!   [magic u64][k u64][n_words u64]  then column `w` at byte offset
//!   `HEADER + w*k*4`, little-endian f32.
//!
//! The paper stores parameters in HDF5; we use a fixed-stride binary file,
//! which preserves the properties the paper relies on (one sequential I/O
//! run per column, restartability/fault tolerance, O(buffer) memory) with
//! zero dependency weight.  A sidecar `<path>.meta.json` carries the
//! algorithm state needed for restart (step counter, phisum), written by
//! [`PagedPhi::checkpoint`].
//!
//! Buffering policy (Fig. 4 line 2): at every minibatch the coordinator
//! calls `set_hot_words` with the minibatch's most frequent words; those
//! columns become buffer-resident (write-back) until replaced. Non-hot
//! columns are read, mutated and written straight back (one read + one
//! write per visit — exactly the paper's "read and write wth column of
//! phi only once at each iteration").

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::{IoStats, PhiColumnStore};

const MAGIC: u64 = 0xF0E3_14DA_0001;
const HEADER_BYTES: u64 = 24;

/// Disk-backed column store with a bounded hot buffer.
pub struct PagedPhi {
    k: usize,
    n_words: usize,
    file: File,
    path: PathBuf,
    /// Hot-word buffer: local slot per hot word, write-back.
    buffer: Vec<f32>,
    /// word id -> slot index in `buffer`.
    slot_of: std::collections::HashMap<u32, usize>,
    /// slot -> word id (for eviction write-back).
    word_of_slot: Vec<u32>,
    dirty: Vec<bool>,
    /// Maximum number of buffered columns (from the byte budget).
    max_slots: usize,
    stats: IoStats,
    /// Scratch for non-buffered column visits.
    scratch: Vec<f32>,
}

impl PagedPhi {
    /// Create (or overwrite) a store of `n_words` zero columns with a hot
    /// buffer of `buffer_bytes`.
    pub fn create(
        path: &Path,
        k: usize,
        n_words: usize,
        buffer_bytes: usize,
    ) -> anyhow::Result<Self> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        let mut header = [0u8; HEADER_BYTES as usize];
        header[..8].copy_from_slice(&MAGIC.to_le_bytes());
        header[8..16].copy_from_slice(&(k as u64).to_le_bytes());
        header[16..24].copy_from_slice(&(n_words as u64).to_le_bytes());
        file.write_all(&header)?;
        // Extend to full size with zeros without materializing K*W memory.
        file.set_len(HEADER_BYTES + (k * n_words * 4) as u64)?;
        let max_slots = (buffer_bytes / (k * 4)).max(1);
        Ok(Self {
            k,
            n_words,
            file,
            path: path.to_path_buf(),
            buffer: Vec::new(),
            slot_of: std::collections::HashMap::new(),
            word_of_slot: Vec::new(),
            dirty: Vec::new(),
            max_slots,
            stats: IoStats::default(),
            scratch: vec![0.0; k],
        })
    }

    /// Reopen an existing store (restart / fault recovery).
    pub fn open(path: &Path, buffer_bytes: usize) -> anyhow::Result<Self> {
        let mut file = OpenOptions::new().read(true).write(true).open(path)?;
        let mut header = [0u8; HEADER_BYTES as usize];
        file.read_exact(&mut header)?;
        let magic = u64::from_le_bytes(header[..8].try_into().unwrap());
        anyhow::ensure!(magic == MAGIC, "not a PagedPhi file: {path:?}");
        let k = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let n_words =
            u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        let max_slots = (buffer_bytes / (k * 4)).max(1);
        Ok(Self {
            k,
            n_words,
            file,
            path: path.to_path_buf(),
            buffer: Vec::new(),
            slot_of: std::collections::HashMap::new(),
            word_of_slot: Vec::new(),
            dirty: Vec::new(),
            max_slots,
            stats: IoStats::default(),
            scratch: vec![0.0; k],
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn max_buffered_columns(&self) -> usize {
        self.max_slots
    }

    pub fn buffered_columns(&self) -> usize {
        self.slot_of.len()
    }

    fn col_offset(&self, w: usize) -> u64 {
        HEADER_BYTES + (w * self.k * 4) as u64
    }

    fn read_col_from_disk(&mut self, w: usize, out: &mut [f32]) {
        self.stats.col_reads += 1;
        self.file
            .seek(SeekFrom::Start(self.col_offset(w)))
            .expect("seek");
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(
                out.as_mut_ptr() as *mut u8,
                out.len() * 4,
            )
        };
        self.file.read_exact(bytes).expect("column read");
    }

    fn write_col_to_disk(&mut self, w: usize, data: &[f32]) {
        self.stats.col_writes += 1;
        self.file
            .seek(SeekFrom::Start(self.col_offset(w)))
            .expect("seek");
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        self.file.write_all(bytes).expect("column write");
    }

    fn evict_slot(&mut self, slot: usize) {
        let w = self.word_of_slot[slot];
        if self.dirty[slot] {
            let col: Vec<f32> =
                self.buffer[slot * self.k..(slot + 1) * self.k].to_vec();
            self.write_col_to_disk(w as usize, &col);
            self.dirty[slot] = false;
        }
        self.slot_of.remove(&w);
    }

    /// Write a checkpoint sidecar with algorithm state (fault tolerance:
    /// "the global topic-word matrix is stored in hard disk for
    /// restarting the online learning", §3.2).
    pub fn checkpoint(&mut self, step: usize, phisum: &[f32]) -> anyhow::Result<()> {
        self.flush()?;
        let mut meta = String::new();
        meta.push_str(&format!("step {step}\n"));
        meta.push_str(&format!("k {}\n", self.k));
        meta.push_str(&format!("n_words {}\n", self.n_words));
        meta.push_str("phisum");
        for &x in phisum {
            meta.push_str(&format!(" {x}"));
        }
        meta.push('\n');
        let meta_path = self.path.with_extension("meta");
        std::fs::write(meta_path, meta)?;
        Ok(())
    }

    /// Load the checkpoint sidecar: `(step, phisum)`.
    pub fn load_checkpoint(path: &Path) -> anyhow::Result<(usize, Vec<f32>)> {
        let meta_path = path.with_extension("meta");
        let text = std::fs::read_to_string(meta_path)?;
        let mut step = 0usize;
        let mut phisum = Vec::new();
        for line in text.lines() {
            let mut it = line.split_ascii_whitespace();
            match it.next() {
                Some("step") => {
                    step = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("bad checkpoint"))?
                        .parse()?;
                }
                Some("phisum") => {
                    phisum = it
                        .map(|x| x.parse::<f32>())
                        .collect::<Result<Vec<_>, _>>()?;
                }
                _ => {}
            }
        }
        anyhow::ensure!(!phisum.is_empty(), "bad checkpoint: no phisum");
        Ok((step, phisum))
    }
}

impl PhiColumnStore for PagedPhi {
    fn k(&self) -> usize {
        self.k
    }

    fn n_words(&self) -> usize {
        self.n_words
    }

    fn ensure_capacity(&mut self, n_words: usize) {
        if n_words <= self.n_words {
            return;
        }
        self.n_words = n_words;
        self.file
            .set_len(HEADER_BYTES + (self.k * n_words * 4) as u64)
            .expect("grow file");
        // Persist the new W in the header.
        self.file.seek(SeekFrom::Start(16)).expect("seek header");
        self.file
            .write_all(&(n_words as u64).to_le_bytes())
            .expect("header write");
    }

    fn with_column<R>(&mut self, w: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
        assert!(w < self.n_words, "column {w} out of range {}", self.n_words);
        if let Some(&slot) = self.slot_of.get(&(w as u32)) {
            self.stats.buffer_hits += 1;
            self.dirty[slot] = true;
            return f(&mut self.buffer[slot * self.k..(slot + 1) * self.k]);
        }
        // Miss: stream through scratch — read, mutate, write back (Fig. 4
        // lines 8 and 15).
        self.stats.buffer_misses += 1;
        let mut scratch = std::mem::take(&mut self.scratch);
        self.read_col_from_disk(w, &mut scratch);
        let r = f(&mut scratch);
        self.write_col_to_disk(w, &scratch);
        self.scratch = scratch;
        r
    }

    fn load_column(&mut self, w: usize, out: &mut [f32]) {
        assert!(w < self.n_words);
        if let Some(&slot) = self.slot_of.get(&(w as u32)) {
            self.stats.buffer_hits += 1;
            out.copy_from_slice(&self.buffer[slot * self.k..(slot + 1) * self.k]);
            return;
        }
        self.stats.buffer_misses += 1;
        self.read_col_from_disk(w, out);
    }

    fn store_column(&mut self, w: usize, data: &[f32]) {
        assert!(w < self.n_words);
        if let Some(&slot) = self.slot_of.get(&(w as u32)) {
            self.stats.buffer_hits += 1;
            self.buffer[slot * self.k..(slot + 1) * self.k]
                .copy_from_slice(data);
            self.dirty[slot] = true;
            return;
        }
        self.stats.buffer_misses += 1;
        self.write_col_to_disk(w, data);
    }

    fn set_hot_words(&mut self, words: &[u32]) {
        use std::collections::HashSet;
        let want: HashSet<u32> =
            words.iter().copied().take(self.max_slots).collect();
        // Evict buffered columns that are no longer hot.
        let to_evict: Vec<usize> = self
            .slot_of
            .iter()
            .filter(|(w, _)| !want.contains(w))
            .map(|(_, &s)| s)
            .collect();
        for slot in to_evict {
            self.evict_slot(slot);
        }
        // Load newly hot columns into free slots.
        for &w in words.iter().take(self.max_slots) {
            if self.slot_of.contains_key(&w) {
                continue;
            }
            let slot = if self.word_of_slot.len() < self.max_slots {
                let slot = self.word_of_slot.len();
                self.word_of_slot.push(w);
                self.dirty.push(false);
                self.buffer.resize((slot + 1) * self.k, 0.0);
                slot
            } else {
                // Find a slot not mapped (evicted above).
                match (0..self.word_of_slot.len()).find(|&s| {
                    !self.slot_of.contains_key(&self.word_of_slot[s])
                        || self.slot_of[&self.word_of_slot[s]] != s
                }) {
                    Some(s) => s,
                    None => continue, // buffer full of still-hot words
                }
            };
            let mut col = vec![0.0f32; self.k];
            self.read_col_from_disk(w as usize, &mut col);
            self.buffer[slot * self.k..(slot + 1) * self.k].copy_from_slice(&col);
            self.word_of_slot[slot] = w;
            self.dirty[slot] = false;
            self.slot_of.insert(w, slot);
        }
    }

    fn flush(&mut self) -> anyhow::Result<()> {
        let slots: Vec<(usize, u32)> = self
            .word_of_slot
            .iter()
            .enumerate()
            .filter(|(s, w)| {
                self.slot_of.get(w) == Some(s) && self.dirty[*s]
            })
            .map(|(s, &w)| (s, w))
            .collect();
        for (slot, w) in slots {
            let col: Vec<f32> =
                self.buffer[slot * self.k..(slot + 1) * self.k].to_vec();
            self.write_col_to_disk(w as usize, &col);
            self.dirty[slot] = false;
        }
        self.file.sync_data()?;
        Ok(())
    }

    fn io_stats(&self) -> IoStats {
        self.stats
    }
}

impl Drop for PagedPhi {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    

    fn new_store(k: usize, w: usize, buf_cols: usize) -> (crate::util::TempDir, PagedPhi) {
        let dir = crate::util::TempDir::new("t");
        let path = dir.path().join("phi.bin");
        let store = PagedPhi::create(&path, k, w, buf_cols * k * 4).unwrap();
        (dir, store)
    }

    #[test]
    fn read_write_round_trip_unbuffered() {
        let (_d, mut s) = new_store(4, 8, 1);
        s.with_column(3, |c| c.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        s.with_column(7, |c| c.copy_from_slice(&[9.0; 4]));
        assert_eq!(s.read_column(3), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.read_column(7), vec![9.0; 4]);
        assert_eq!(s.read_column(0), vec![0.0; 4]);
        // with_column misses read+write; read_column (load path) only
        // reads.
        assert!(s.io_stats().col_reads >= 5);
        assert_eq!(s.io_stats().col_writes, 2);
    }

    #[test]
    fn hot_buffer_avoids_disk_io() {
        let (_d, mut s) = new_store(4, 8, 4);
        s.set_hot_words(&[1, 2]);
        let base_reads = s.io_stats().col_reads;
        for _ in 0..10 {
            s.with_column(1, |c| c[0] += 1.0);
            s.with_column(2, |c| c[1] += 1.0);
        }
        assert_eq!(s.io_stats().col_reads, base_reads, "hits must not read");
        assert_eq!(s.io_stats().buffer_hits, 20);
        s.flush().unwrap();
        assert_eq!(s.read_column(1)[0], 10.0);
        assert_eq!(s.read_column(2)[1], 10.0);
    }

    #[test]
    fn eviction_writes_back_dirty_columns() {
        let (_d, mut s) = new_store(2, 6, 2);
        s.set_hot_words(&[0, 1]);
        s.with_column(0, |c| c.copy_from_slice(&[5.0, 5.0]));
        // Replace the hot set: column 0 must be written back.
        s.set_hot_words(&[2, 3]);
        assert_eq!(s.read_column(0), vec![5.0, 5.0]);
    }

    #[test]
    fn buffer_respects_budget() {
        let (_d, mut s) = new_store(2, 100, 3);
        s.set_hot_words(&(0u32..50).collect::<Vec<_>>());
        assert!(s.buffered_columns() <= 3);
    }

    #[test]
    fn restart_recovers_state() {
        let dir = crate::util::TempDir::new("t");
        let path = dir.path().join("phi.bin");
        {
            let mut s = PagedPhi::create(&path, 3, 5, 3 * 4 * 2).unwrap();
            s.set_hot_words(&[1]);
            s.with_column(1, |c| c.copy_from_slice(&[1.0, 2.0, 3.0]));
            s.with_column(4, |c| c.copy_from_slice(&[7.0, 8.0, 9.0]));
            s.checkpoint(42, &[6.0, 10.0, 12.0]).unwrap();
        } // dropped: flushed
        let mut s = PagedPhi::open(&path, 1024).unwrap();
        assert_eq!(s.k(), 3);
        assert_eq!(s.n_words(), 5);
        assert_eq!(s.read_column(1), vec![1.0, 2.0, 3.0]);
        assert_eq!(s.read_column(4), vec![7.0, 8.0, 9.0]);
        let (step, phisum) = PagedPhi::load_checkpoint(&path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(phisum, vec![6.0, 10.0, 12.0]);
    }

    #[test]
    fn capacity_growth_persists_and_zeroes() {
        let (_d, mut s) = new_store(2, 3, 1);
        s.with_column(2, |c| c.copy_from_slice(&[1.0, 1.0]));
        s.ensure_capacity(10);
        assert_eq!(s.n_words(), 10);
        assert_eq!(s.read_column(9), vec![0.0, 0.0]);
        assert_eq!(s.read_column(2), vec![1.0, 1.0]);
    }

    #[test]
    fn export_dense_round_trip() {
        let (_d, mut s) = new_store(2, 4, 2);
        s.with_column(0, |c| c.copy_from_slice(&[1.0, 0.5]));
        s.with_column(3, |c| c.copy_from_slice(&[0.0, 2.0]));
        let dense = s.export_dense();
        assert_eq!(dense.word(0), &[1.0, 0.5]);
        assert_eq!(dense.word(3), &[0.0, 2.0]);
        assert_eq!(dense.phisum, vec![1.0, 2.5]);
    }

    #[test]
    fn hot_set_changes_are_correct_across_many_rounds() {
        // Churn the hot set and verify contents never corrupt.
        let (_d, mut s) = new_store(2, 20, 4);
        let mut truth = vec![[0.0f32; 2]; 20];
        let mut rng = crate::util::Rng::new(5);
        for round in 0..30 {
            let hot: Vec<u32> =
                (0..4).map(|_| rng.below(20) as u32).collect();
            s.set_hot_words(&hot);
            for _ in 0..10 {
                let w = rng.below(20);
                let inc = (round + 1) as f32;
                s.with_column(w, |c| {
                    c[0] += inc;
                    c[1] += 0.5;
                });
                truth[w][0] += inc;
                truth[w][1] += 0.5;
            }
        }
        s.flush().unwrap();
        for w in 0..20 {
            let col = s.read_column(w);
            assert!((col[0] - truth[w][0]).abs() < 1e-4, "w={w}");
            assert!((col[1] - truth[w][1]).abs() < 1e-4, "w={w}");
        }
    }
}
