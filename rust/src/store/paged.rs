//! Disk-backed paged column store for `phi_hat_{K×W}` with a hot-word
//! buffer — the parameter-streaming engine of §3.2, with compressed
//! columnar storage.
//!
//! Layout of the backing file (`<path>`):
//!   [magic u64][k u64][n_words u64][data_end u64][codec u64]
//! followed by variable-length column records allocated by a bump
//! allocator (`data_end` is the high-water mark). Each record is
//! `[tag u8][payload]`, one of the self-describing encodings in
//! [`super::codec`]; a record longer than its column's current encoding
//! keeps its slack so in-place overwrites are the common case, and a
//! column that outgrows its extent is relocated to the end (the old
//! extent is abandoned — bytes-on-disk is an honest high-water metric).
//!
//! A sidecar `<path>.idx` persists the column directory: per column the
//! extent `(offset, cap)`, the live record length `len` (0 = the
//! implicit all-zero column: **no disk bytes, no disk op, no decode** —
//! the zone-map skip), and zone-map stats `(nnz, max)` so eval-view
//! construction and the fold-in scheduler can classify columns without
//! decoding them. The directory is owned by the foreground and written
//! at every [`PagedPhi::flush`]; the `<path>.meta` sidecar carries the
//! algorithm state for restart (step counter, phisum), written by
//! [`PagedPhi::checkpoint`].
//!
//! Buffering policy (Fig. 4 line 2): at every minibatch the coordinator
//! calls `set_hot_words` with the minibatch's most frequent words; those
//! columns become buffer-resident (write-back) until replaced. Non-hot
//! columns are read, mutated and written straight back (one read + one
//! write per visit — exactly the paper's "read and write wth column of
//! phi only once at each iteration").
//!
//! # Background I/O mode (pipelined parameter streaming)
//!
//! [`PhiColumnStore::set_async_io`] switches the store into the overlapped
//! mode the software pipeline (`exec::pipeline`, `rust/DESIGN.md` §7)
//! runs on. A single background thread then owns **all** disk traffic:
//!
//! * **Prefetch** — [`PhiColumnStore::prefetch_columns`] queues the next
//!   minibatch's columns; the thread loads them into a prefetch cache
//!   while the current minibatch computes, so the stage-time snapshot
//!   reads become cache hits (`IoStats::prefetch_hits`) instead of
//!   blocking disk reads.
//! * **Write-behind** — column writes are *encoded and placed*
//!   (directory update + extent allocation) on the foreground, then land
//!   in a versioned pending map and are flushed by the thread off the
//!   critical path (`IoStats::wb_writes`); reads are always served
//!   freshest-first (pending write → prefetch cache → disk).
//!
//! The daemon never allocates: every request carries the resolved
//! `(offset, len)`. That split keeps the variable-length format safe
//! under overlap — the foreground is the only directory mutator, the
//! daemon is the only file writer, and FIFO ordering plus the pending
//! map's shadowing guarantee a read never observes a stale record.
//!
//! Because the foreground sends requests over a FIFO channel and blocks on
//! its own reads, the visible read results are exactly the synchronous
//! ones — overlap changes *when* I/O happens, never *what* a read sees.
//! With async I/O off (the default), behavior and [`IoStats`] are
//! bit-identical to the original synchronous store.
//!
//! # Byte accounting (`IoStats::logical_bytes` / `IoStats::disk_bytes`)
//!
//! Both counters tick at the same events — actual transfers between the
//! store and its backing file (including the zero-byte implicit-zero
//! "transfers" that replace them): sync reads/writes, daemon prefetch
//! loads and write-behind flushes, and daemon-served disk reads.
//! Cache hits of any kind (hot buffer, pending-write map, prefetch
//! cache) count in *neither*, and a prefetch satisfied by copying a
//! pending write moves no disk bytes so it also counts in neither — that
//! consistency is what makes `disk_bytes / logical_bytes` the exact
//! compression ratio of real disk traffic on both the sync and async
//! paths.
//!
//! # Crash consistency (write-ahead log, `rust/DESIGN.md` §13)
//!
//! With [`PagedPhi::enable_wal`] the store mirrors every column write
//! into a `<path>.wal` intent log ([`super::wal`]) *before* the extent
//! write happens (sync mode) or is even enqueued to the I/O daemon
//! (async mode), bracketed per training batch by
//! [`PhiColumnStore::wal_begin`] / [`PhiColumnStore::wal_commit`]. Two
//! invariants make the container + `.idx` pair recoverable at any kill
//! point:
//!
//! 1. **Checkpoint extents are immutable.** While the WAL is armed, the
//!    first write to a column since the last WAL truncation relocates to
//!    a fresh extent instead of overwriting in place — so every extent
//!    the last *durable* `.idx` references stays byte-intact until the
//!    next `.idx` replaces it atomically (temp + rename + parent-dir
//!    fsync, with a trailing CRC). Reopening after any crash therefore
//!    yields exactly the last flushed state; the abandoned post-flush
//!    extents are reclaimed automatically because the durable header's
//!    `data_end` still points below them.
//! 2. **Commits are self-contained.** `wal_commit` also logs every
//!    still-dirty hot-buffer column (whose mutations bypassed the
//!    per-write mirror) before the fsynced `Commit` frame, so replaying
//!    a committed batch restores the full end-of-batch column state —
//!    including data that only ever lived in the hot buffer.
//!
//! Recovery ([`PagedPhi::open_with_wal`] + [`PagedPhi::apply_wal_batch`])
//! is then: reopen the last flushed state, replay committed batches in
//! commit order, discard the torn tail. With the WAL off nothing in this
//! section runs and behavior (numerics *and* `IoStats`) is bit-identical
//! to the pre-WAL store.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};

use super::codec::{self, Codec, ColumnStats};
use super::wal::{self, Wal, WalBatch};
use super::{IoStats, PhiColumnStore};

const MAGIC: u64 = 0xF0E3_14DA_0002;
const HEADER_BYTES: u64 = 40;
const IDX_MAGIC: u64 = 0xF0E3_14DA_1D01;
const IDX_HEADER_BYTES: u64 = 16;
const DIR_ENT_BYTES: usize = 24;

/// Column directory entry: extent + live record + zone-map stats.
/// `len == 0` is the implicit all-zero column (no bytes on disk).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
struct DirEnt {
    offset: u64,
    cap: u32,
    len: u32,
    nnz: u32,
    max: f32,
}

/// `<path>.idx` — appended, not `with_extension` (which would collide
/// `phi.bin` and `phi.idx` across unrelated stores sharing a stem).
fn idx_path(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".idx");
    s.into()
}

/// Extent size for a fresh allocation: 25% growth slack, rounded up to
/// 64 bytes, so columns whose encodings grow as training adds mass
/// overwrite in place instead of relocating every write.
fn cap_for(len: usize) -> u32 {
    (len + len / 4).div_ceil(64) as u32 * 64
}

/// Positioned record read + decode, shared by the foreground (sync mode)
/// and the background I/O thread. `len == 0` never touches the file.
fn read_record_into(
    file: &mut File,
    offset: u64,
    len: u32,
    bbuf: &mut Vec<u8>,
    out: &mut [f32],
) {
    if len == 0 {
        out.fill(0.0);
        return;
    }
    bbuf.resize(len as usize, 0);
    file.seek(SeekFrom::Start(offset)).expect("seek");
    file.read_exact(bbuf).expect("column record read");
    codec::decode_column(bbuf, out);
}

/// Positioned record write, shared like [`read_record_into`]. The empty
/// record (implicit zero) is directory-only: nothing touches the file.
fn write_record(file: &mut File, offset: u64, bytes: &[u8]) {
    if bytes.is_empty() {
        return;
    }
    file.seek(SeekFrom::Start(offset)).expect("seek");
    file.write_all(bytes).expect("column record write");
}

/// Durability for a rename-into-place: fsync the parent directory so the
/// rename itself survives a crash. No-op off unix, where directory
/// handles cannot be opened.
fn sync_parent_dir(path: &Path) -> std::io::Result<()> {
    #[cfg(unix)]
    if let Some(parent) = path.parent() {
        File::open(parent)?.sync_all()?;
    }
    #[cfg(not(unix))]
    let _ = path;
    Ok(())
}

/// Where a routed (async-mode) column read was served from.
#[derive(Debug, Clone, Copy)]
enum ReadSource {
    Disk,
    Prefetched,
    WriteBuffer,
}

/// A column staged for write-behind: the decoded value (serves foreground
/// reads), the encoded record and its placed extent offset (what the
/// daemon writes).
struct PendingWrite {
    version: u64,
    col: Vec<f32>,
    bytes: Vec<u8>,
    offset: u64,
}

/// A prefetch target with its record location resolved at enqueue time
/// (the daemon has no directory access).
struct PrefetchItem {
    w: u32,
    offset: u64,
    len: u32,
}

/// Requests to the background I/O thread. The channel is FIFO and the
/// foreground is the only sender, which is what makes the overlapped mode
/// deterministic: a read queued after a write signal for the same column
/// always observes the flushed state, and a request's resolved
/// `(offset, len)` can never be overtaken by a later reallocation (any
/// fresher version sits in the pending map, which is checked first).
enum IoReq {
    /// Synchronous read round-trip (the caller blocks on `resp`).
    Read {
        w: u32,
        offset: u64,
        len: u32,
        resp: SyncSender<(Vec<f32>, ReadSource)>,
    },
    /// A pending write was enqueued; flush it if `version` is still
    /// current (superseded versions are skipped — a later signal covers
    /// the column).
    WriteSignal { w: u32, version: u64 },
    /// Load these columns into the prefetch cache.
    Prefetch(Vec<PrefetchItem>),
    /// Flush every pending write, fsync, then ack with the fsync result
    /// (so an async-mode checkpoint surfaces durability failures exactly
    /// like the synchronous path).
    DrainAndSync { ack: SyncSender<std::io::Result<()>> },
    Shutdown,
}

/// State shared between the store and its background I/O thread.
#[derive(Default)]
struct AsyncShared {
    /// Write-behind buffer: word -> pending write. Freshest data for a
    /// column not in the hot buffer.
    pending: Mutex<HashMap<u32, PendingWrite>>,
    /// Prefetch cache: columns staged ahead of use. Entries are served by
    /// clone, invalidated whenever the column is written, and bounded by
    /// the size cap in the prefetch handler.
    prefetched: Mutex<HashMap<u32, Vec<f32>>>,
    /// Columns loaded by the prefetcher (background reads).
    prefetched_cols: AtomicU64,
    /// Columns flushed by the write-behind path (background writes).
    wb_writes: AtomicU64,
    /// Decoded bytes of the daemon's own disk transfers (prefetch loads +
    /// write-behind flushes) — folded into `IoStats::logical_bytes`.
    bg_logical_bytes: AtomicU64,
    /// Encoded bytes of those same transfers — folded into
    /// `IoStats::disk_bytes`.
    bg_disk_bytes: AtomicU64,
}

struct AsyncIo {
    tx: Sender<IoReq>,
    shared: Arc<AsyncShared>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Monotonic version for pending writes (MVCC-light: lets the daemon
    /// skip flushes that a newer write already superseded).
    next_version: u64,
}

/// The background I/O loop: sole owner of disk traffic while async mode
/// is on.
fn io_daemon(mut file: File, k: usize, rx: Receiver<IoReq>, shared: Arc<AsyncShared>) {
    let logical = (k * 4) as u64;
    let mut buf = vec![0.0f32; k];
    let mut bbuf: Vec<u8> = Vec::new();
    for req in rx {
        match req {
            IoReq::Read { w, offset, len, resp } => {
                let from_pending = shared
                    .pending
                    .lock()
                    .unwrap()
                    .get(&w)
                    .map(|p| p.col.clone());
                let reply = if let Some(col) = from_pending {
                    (col, ReadSource::WriteBuffer)
                } else if let Some(col) =
                    shared.prefetched.lock().unwrap().get(&w).cloned()
                {
                    // Served by CLONE, not removal: a mid-run evaluation
                    // pass reads many of the same columns the prefetcher
                    // just staged for the next batch — consuming the
                    // entries would evict them right before the stage
                    // that needed them. Entries are dropped on write
                    // invalidation or the size cap instead.
                    (col, ReadSource::Prefetched)
                } else {
                    // Byte counting happens on the foreground, which
                    // learns the source (and knows `len`) from the reply.
                    read_record_into(&mut file, offset, len, &mut bbuf, &mut buf);
                    (buf.clone(), ReadSource::Disk)
                };
                let _ = resp.send(reply);
            }
            IoReq::WriteSignal { w, version } => {
                let job = match shared.pending.lock().unwrap().get(&w) {
                    Some(p) if p.version == version => {
                        Some((p.bytes.clone(), p.offset))
                    }
                    _ => None, // superseded by a newer write
                };
                if let Some((bytes, offset)) = job {
                    write_record(&mut file, offset, &bytes);
                    shared.wb_writes.fetch_add(1, Ordering::Relaxed);
                    shared.bg_logical_bytes.fetch_add(logical, Ordering::Relaxed);
                    shared
                        .bg_disk_bytes
                        .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    // Invalidation order matters for the foreground fast
                    // path (pending first, then prefetched): the stale
                    // prefetch copy must be gone BEFORE the pending entry
                    // stops shadowing it.
                    shared.prefetched.lock().unwrap().remove(&w);
                    {
                        let mut pending = shared.pending.lock().unwrap();
                        if matches!(pending.get(&w), Some(p) if p.version == version)
                        {
                            pending.remove(&w);
                        }
                    }
                }
            }
            IoReq::Prefetch(items) => {
                {
                    // The cache is a hint; keep it bounded even if the
                    // caller never consumes some entries.
                    let mut pf = shared.prefetched.lock().unwrap();
                    if pf.len() > 4 * items.len() + 1024 {
                        pf.clear();
                    }
                }
                for it in items {
                    if shared.prefetched.lock().unwrap().contains_key(&it.w) {
                        continue;
                    }
                    // Freshest-first, same as Read: a pending write beats
                    // the disk copy. A pending-map copy moves no disk
                    // bytes, so it counts in neither byte counter.
                    let from_pending = shared
                        .pending
                        .lock()
                        .unwrap()
                        .get(&it.w)
                        .map(|p| p.col.clone());
                    let col = match from_pending {
                        Some(col) => col,
                        None => {
                            read_record_into(
                                &mut file, it.offset, it.len, &mut bbuf, &mut buf,
                            );
                            shared
                                .bg_logical_bytes
                                .fetch_add(logical, Ordering::Relaxed);
                            shared
                                .bg_disk_bytes
                                .fetch_add(it.len as u64, Ordering::Relaxed);
                            buf.clone()
                        }
                    };
                    shared.prefetched_cols.fetch_add(1, Ordering::Relaxed);
                    shared.prefetched.lock().unwrap().insert(it.w, col);
                }
            }
            IoReq::DrainAndSync { ack } => {
                loop {
                    let next = shared
                        .pending
                        .lock()
                        .unwrap()
                        .iter()
                        .next()
                        .map(|(w, p)| (*w, p.version, p.bytes.clone(), p.offset));
                    let Some((w, version, bytes, offset)) = next else { break };
                    write_record(&mut file, offset, &bytes);
                    shared.wb_writes.fetch_add(1, Ordering::Relaxed);
                    shared.bg_logical_bytes.fetch_add(logical, Ordering::Relaxed);
                    shared
                        .bg_disk_bytes
                        .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    // Same invalidation order as WriteSignal: prefetched
                    // copy first, then the shadowing pending entry.
                    shared.prefetched.lock().unwrap().remove(&w);
                    {
                        let mut pending = shared.pending.lock().unwrap();
                        if matches!(pending.get(&w), Some(p) if p.version == version)
                        {
                            pending.remove(&w);
                        }
                    }
                }
                let _ = ack.send(file.sync_data());
            }
            IoReq::Shutdown => break,
        }
    }
}

/// Disk-backed column store with a bounded hot buffer.
pub struct PagedPhi {
    k: usize,
    n_words: usize,
    file: File,
    path: PathBuf,
    /// Write-time encoding policy (reads dispatch on per-record tags).
    codec: Codec,
    /// Column directory: extents + live lengths + zone-map stats. Owned
    /// and mutated exclusively by the foreground; persisted to
    /// `<path>.idx` on flush.
    dir: Vec<DirEnt>,
    /// Bump-allocator high-water mark (absolute file offset).
    data_end: u64,
    /// Hot-word buffer: local slot per hot word, write-back.
    buffer: Vec<f32>,
    /// word id -> slot index in `buffer`.
    slot_of: std::collections::HashMap<u32, usize>,
    /// slot -> word id (for eviction write-back).
    word_of_slot: Vec<u32>,
    dirty: Vec<bool>,
    /// Maximum number of buffered columns (from the byte budget).
    max_slots: usize,
    stats: IoStats,
    /// Scratch for non-buffered column visits.
    scratch: Vec<f32>,
    /// Encode scratch (reused across writes).
    enc_buf: Vec<u8>,
    /// Decode scratch (reused across sync reads).
    byte_scratch: Vec<u8>,
    /// Background prefetch/write-behind machinery; `None` = synchronous.
    async_io: Option<AsyncIo>,
    /// Intent log for crash consistency; `None` = WAL off (the default),
    /// in which case none of the WAL machinery below changes behavior.
    wal: Option<Wal>,
    /// Per-column "extent allocated since the last WAL truncation" flag.
    /// A clear flag means the column's extent may still be referenced by
    /// the last durable directory, so the next non-empty write must
    /// relocate instead of overwriting it (invariant 1 in the module
    /// docs). Sized `n_words` while the WAL is armed, empty otherwise.
    wal_fresh: Vec<bool>,
    /// Open batch bracket (`wal_begin` .. `wal_commit`). Column writes
    /// outside a bracket are not mirrored — they can only re-persist
    /// state some earlier commit already captured.
    wal_batch: Option<u64>,
    /// First durability error, if any. The write path cannot fail (it
    /// sits inside the E-step hot loop), so errors are parked here and
    /// surfaced at the next `flush`/`truncate_wal` — i.e. before any
    /// checkpoint can claim durability.
    poisoned: Option<String>,
}

impl PagedPhi {
    /// Create (or overwrite) a store of `n_words` zero columns with a hot
    /// buffer of `buffer_bytes`, writing columns under [`Codec::Auto`].
    pub fn create(
        path: &Path,
        k: usize,
        n_words: usize,
        buffer_bytes: usize,
    ) -> anyhow::Result<Self> {
        Self::create_with_codec(path, k, n_words, buffer_bytes, Codec::Auto)
    }

    /// [`Self::create`] with an explicit write codec (`--phi-codec`).
    pub fn create_with_codec(
        path: &Path,
        k: usize,
        n_words: usize,
        buffer_bytes: usize,
        codec: Codec,
    ) -> anyhow::Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        let mut this = Self {
            k,
            n_words,
            file,
            path: path.to_path_buf(),
            codec,
            dir: vec![DirEnt::default(); n_words],
            data_end: HEADER_BYTES,
            buffer: Vec::new(),
            slot_of: std::collections::HashMap::new(),
            word_of_slot: Vec::new(),
            dirty: Vec::new(),
            max_slots: (buffer_bytes / (k * 4)).max(1),
            stats: IoStats::default(),
            scratch: vec![0.0; k],
            enc_buf: Vec::new(),
            byte_scratch: Vec::new(),
            async_io: None,
            wal: None,
            wal_fresh: Vec::new(),
            wal_batch: None,
            poisoned: None,
        };
        this.write_header()?;
        // Seed the directory sidecar with the all-default (implicitly
        // all-zero) directory — through the same atomic, CRC-trailed
        // writer used at flush, so a reopen before the first flush sees
        // a valid directory.
        this.write_dir()?;
        Ok(this)
    }

    /// Reopen an existing store (restart / fault recovery). The write
    /// codec is restored from the header.
    pub fn open(path: &Path, buffer_bytes: usize) -> anyhow::Result<Self> {
        let mut file = OpenOptions::new().read(true).write(true).open(path)?;
        let mut header = [0u8; HEADER_BYTES as usize];
        file.read_exact(&mut header)?;
        let magic = u64::from_le_bytes(header[..8].try_into().unwrap());
        anyhow::ensure!(magic == MAGIC, "not a PagedPhi file: {path:?}");
        let k = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let n_words =
            u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        let data_end =
            u64::from_le_bytes(header[24..32].try_into().unwrap()).max(HEADER_BYTES);
        let codec_tag = u64::from_le_bytes(header[32..40].try_into().unwrap());
        let codec = Codec::from_header_tag(codec_tag).ok_or_else(|| {
            anyhow::anyhow!("unknown store codec tag {codec_tag} in {path:?}")
        })?;
        let dir = Self::read_dir_file(path, n_words)?;
        Ok(Self {
            k,
            n_words,
            file,
            path: path.to_path_buf(),
            codec,
            dir,
            data_end,
            buffer: Vec::new(),
            slot_of: std::collections::HashMap::new(),
            word_of_slot: Vec::new(),
            dirty: Vec::new(),
            max_slots: (buffer_bytes / (k * 4)).max(1),
            stats: IoStats::default(),
            scratch: vec![0.0; k],
            enc_buf: Vec::new(),
            byte_scratch: Vec::new(),
            async_io: None,
            wal: None,
            wal_fresh: Vec::new(),
            wal_batch: None,
            poisoned: None,
        })
    }

    fn read_dir_file(path: &Path, n_words: usize) -> anyhow::Result<Vec<DirEnt>> {
        let ip = idx_path(path);
        let bytes = std::fs::read(&ip).map_err(|e| {
            anyhow::anyhow!("column directory {ip:?} unreadable: {e}")
        })?;
        anyhow::ensure!(
            bytes.len() >= IDX_HEADER_BYTES as usize,
            "column directory {ip:?} truncated"
        );
        let magic = u64::from_le_bytes(bytes[..8].try_into().unwrap());
        anyhow::ensure!(magic == IDX_MAGIC, "not a column directory: {ip:?}");
        let n = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let body = IDX_HEADER_BYTES as usize + n * DIR_ENT_BYTES;
        anyhow::ensure!(
            bytes.len() >= body + 4,
            "column directory {ip:?} truncated"
        );
        let stored =
            u32::from_le_bytes(bytes[body..body + 4].try_into().unwrap());
        anyhow::ensure!(
            wal::crc32(&bytes[..body]) == stored,
            "column directory {ip:?} corrupt (CRC mismatch)"
        );
        // Capacity growth updates the data header immediately but the
        // directory only at flush; tolerate a shorter directory by
        // padding with implicit-zero entries.
        let mut dir = vec![DirEnt::default(); n_words];
        for (i, ent) in dir.iter_mut().enumerate().take(n.min(n_words)) {
            let at = IDX_HEADER_BYTES as usize + i * DIR_ENT_BYTES;
            let e = &bytes[at..at + DIR_ENT_BYTES];
            ent.offset = u64::from_le_bytes(e[..8].try_into().unwrap());
            ent.cap = u32::from_le_bytes(e[8..12].try_into().unwrap());
            ent.len = u32::from_le_bytes(e[12..16].try_into().unwrap());
            ent.nnz = u32::from_le_bytes(e[16..20].try_into().unwrap());
            ent.max = f32::from_le_bytes(e[20..24].try_into().unwrap());
        }
        Ok(dir)
    }

    fn write_header(&mut self) -> std::io::Result<()> {
        let mut h = [0u8; HEADER_BYTES as usize];
        h[..8].copy_from_slice(&MAGIC.to_le_bytes());
        h[8..16].copy_from_slice(&(self.k as u64).to_le_bytes());
        h[16..24].copy_from_slice(&(self.n_words as u64).to_le_bytes());
        h[24..32].copy_from_slice(&self.data_end.to_le_bytes());
        h[32..40].copy_from_slice(&self.codec.header_tag().to_le_bytes());
        self.file.seek(SeekFrom::Start(0))?;
        self.file.write_all(&h)
    }

    /// Atomically replace `<path>.idx`: serialize with a trailing CRC,
    /// write a temp file, fsync it, rename into place, fsync the parent
    /// directory. A crash at any point leaves either the old or the new
    /// directory — never a torn one — and the CRC catches partial or
    /// bit-rotted files on the read side.
    fn write_dir(&self) -> anyhow::Result<()> {
        let mut buf = Vec::with_capacity(
            IDX_HEADER_BYTES as usize + self.dir.len() * DIR_ENT_BYTES + 4,
        );
        buf.extend_from_slice(&IDX_MAGIC.to_le_bytes());
        buf.extend_from_slice(&(self.dir.len() as u64).to_le_bytes());
        for e in &self.dir {
            buf.extend_from_slice(&e.offset.to_le_bytes());
            buf.extend_from_slice(&e.cap.to_le_bytes());
            buf.extend_from_slice(&e.len.to_le_bytes());
            buf.extend_from_slice(&e.nnz.to_le_bytes());
            buf.extend_from_slice(&e.max.to_le_bytes());
        }
        buf.extend_from_slice(&wal::crc32(&buf).to_le_bytes());
        let ip = idx_path(&self.path);
        let tmp = {
            let mut s = ip.as_os_str().to_os_string();
            s.push(".tmp");
            PathBuf::from(s)
        };
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&buf)?;
            f.sync_data()?;
        }
        std::fs::rename(&tmp, &ip)?;
        sync_parent_dir(&ip)?;
        Ok(())
    }

    /// Persist the header + column directory (called under flush, after
    /// all record data is on disk).
    fn persist_metadata(&mut self) -> anyhow::Result<()> {
        self.write_header()?;
        self.write_dir()?;
        self.file.sync_data()?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The write-time encoding policy this store was created/reopened with.
    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Bytes of column-record storage allocated in the backing file
    /// (bump-allocator high-water mark, excluding the header) — the
    /// bytes-on-disk metric the bench trajectory tracks per codec.
    pub fn data_bytes_on_disk(&self) -> u64 {
        self.data_end - HEADER_BYTES
    }

    pub fn max_buffered_columns(&self) -> usize {
        self.max_slots
    }

    pub fn buffered_columns(&self) -> usize {
        self.slot_of.len()
    }

    /// Whether background prefetch/write-behind is currently on.
    pub fn async_io_enabled(&self) -> bool {
        self.async_io.is_some()
    }

    /// Encode `data` under the store codec, place the record (in-place
    /// overwrite when it fits the column's extent, bump-allocate +
    /// relocate otherwise) and update the directory entry + zone-map
    /// stats. The encoded record is left in `self.enc_buf`; returns the
    /// record's file offset. Counts nothing — the caller counts at the
    /// actual transfer.
    fn encode_and_place(&mut self, w: usize, data: &[f32]) -> u64 {
        let mut buf = std::mem::take(&mut self.enc_buf);
        let st = codec::encode_column(self.codec, data, &mut buf);
        let len = buf.len() as u32;
        // Crash-consistency invariant 1: while the WAL is armed, never
        // overwrite an extent the last durable directory may still
        // reference. The first non-empty write to a column since the last
        // WAL truncation relocates even when it would fit in place; empty
        // (implicit-zero) records write no bytes, so they never need to.
        let preserve = self.wal.is_some()
            && len > 0
            && !self.wal_fresh.get(w).copied().unwrap_or(false);
        let ent = &mut self.dir[w];
        if len > ent.cap || preserve {
            ent.offset = self.data_end;
            ent.cap = cap_for(buf.len());
            self.data_end += ent.cap as u64;
            if self.wal.is_some() {
                self.wal_fresh[w] = true;
            }
        }
        ent.len = len;
        ent.nnz = st.nnz;
        ent.max = st.max;
        let offset = ent.offset;
        self.enc_buf = buf;
        offset
    }

    fn read_col_from_disk(&mut self, w: usize, out: &mut [f32]) {
        self.stats.col_reads += 1;
        self.stats.logical_bytes += (self.k * 4) as u64;
        let ent = self.dir[w];
        self.stats.disk_bytes += ent.len as u64;
        if ent.len == 0 {
            // Zone-map skip: the directory already says all-zero.
            out.fill(0.0);
            return;
        }
        let mut bbuf = std::mem::take(&mut self.byte_scratch);
        read_record_into(&mut self.file, ent.offset, ent.len, &mut bbuf, out);
        self.byte_scratch = bbuf;
    }

    fn write_col_to_disk(&mut self, w: usize, data: &[f32]) {
        self.stats.col_writes += 1;
        self.stats.logical_bytes += (self.k * 4) as u64;
        let offset = self.encode_and_place(w, data);
        self.wal_log_column(w);
        self.stats.disk_bytes += self.enc_buf.len() as u64;
        let bytes = std::mem::take(&mut self.enc_buf);
        write_record(&mut self.file, offset, &bytes);
        self.enc_buf = bytes;
    }

    /// Route a non-hot column read: in sync mode straight off disk; in
    /// async mode freshest-first — pending write, then prefetch cache
    /// (both served directly from the shared maps, no round trip), then
    /// the directory's implicit-zero fast path, then a blocking read
    /// through the I/O thread. Counts by source — a prefetch hit is NOT a
    /// buffer miss, which is exactly the overlap the pipeline buys.
    ///
    /// The foreground fast path is safe because a stale prefetch copy
    /// only ever exists while the pending entry for the same column
    /// shadows it: writes invalidate the cache at enqueue time, and the
    /// I/O thread re-invalidates BEFORE it drops the pending entry. The
    /// directory consult is safe in the same way: the directory is
    /// updated at write-*enqueue* time, so once the pending map misses,
    /// the entry describes the freshest (already flushed) record.
    fn fetch_col(&mut self, w: usize, out: &mut [f32], count_miss: bool) {
        if let Some(aio) = &self.async_io {
            let served_pending = {
                let pending = aio.shared.pending.lock().unwrap();
                match pending.get(&(w as u32)) {
                    Some(p) => {
                        out.copy_from_slice(&p.col);
                        true
                    }
                    None => false,
                }
            };
            if served_pending {
                self.stats.buffer_hits += 1;
                return;
            }
            let served_prefetch = {
                let prefetched = aio.shared.prefetched.lock().unwrap();
                match prefetched.get(&(w as u32)) {
                    Some(col) => {
                        out.copy_from_slice(col);
                        true
                    }
                    None => false,
                }
            };
            if served_prefetch {
                self.stats.prefetch_hits += 1;
                return;
            }
            let ent = self.dir[w];
            if ent.len == 0 {
                // Zone-map skip, async flavor: no daemon round trip.
                self.stats.col_reads += 1;
                if count_miss {
                    self.stats.buffer_misses += 1;
                }
                self.stats.logical_bytes += (self.k * 4) as u64;
                out.fill(0.0);
                return;
            }
            let (tx, rx) = std::sync::mpsc::sync_channel(1);
            let aio = self.async_io.as_ref().unwrap();
            aio.tx
                .send(IoReq::Read {
                    w: w as u32,
                    offset: ent.offset,
                    len: ent.len,
                    resp: tx,
                })
                .expect("store I/O thread alive");
            let (col, src) = rx.recv().expect("store I/O thread reply");
            out.copy_from_slice(&col);
            match src {
                ReadSource::Disk => {
                    self.stats.col_reads += 1;
                    if count_miss {
                        self.stats.buffer_misses += 1;
                    }
                    self.stats.logical_bytes += (self.k * 4) as u64;
                    self.stats.disk_bytes += ent.len as u64;
                }
                ReadSource::Prefetched => self.stats.prefetch_hits += 1,
                ReadSource::WriteBuffer => self.stats.buffer_hits += 1,
            }
        } else {
            if count_miss {
                self.stats.buffer_misses += 1;
            }
            self.read_col_from_disk(w, out);
        }
    }

    /// Route a non-hot column write: direct in sync mode, write-behind in
    /// async mode. Either way the column is encoded and placed on the
    /// foreground (directory update included); async mode then parks the
    /// record in a versioned pending entry + flush signal, and any
    /// prefetched copy of the column is invalidated immediately.
    fn put_col(&mut self, w: usize, data: &[f32]) {
        if self.async_io.is_none() {
            self.write_col_to_disk(w, data);
            return;
        }
        let offset = self.encode_and_place(w, data);
        // Intent before action: the WAL frame is appended on the
        // foreground BEFORE the write is even enqueued, so the daemon can
        // never put bytes in an extent the log does not already explain.
        self.wal_log_column(w);
        let bytes = self.enc_buf.clone();
        let aio = self.async_io.as_mut().unwrap();
        aio.next_version += 1;
        let version = aio.next_version;
        aio.shared.prefetched.lock().unwrap().remove(&(w as u32));
        aio.shared.pending.lock().unwrap().insert(
            w as u32,
            PendingWrite { version, col: data.to_vec(), bytes, offset },
        );
        aio.tx
            .send(IoReq::WriteSignal { w: w as u32, version })
            .expect("store I/O thread alive");
    }

    /// Block until the I/O thread has flushed every pending write and
    /// fsynced, propagating the fsync result. No-op in sync mode.
    fn quiesce_async(&self) -> anyhow::Result<()> {
        if let Some(aio) = &self.async_io {
            let (ack, ack_rx) = std::sync::mpsc::sync_channel(1);
            aio.tx
                .send(IoReq::DrainAndSync { ack })
                .map_err(|_| anyhow::anyhow!("store I/O thread is gone"))?;
            ack_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("store I/O thread is gone"))??;
        }
        Ok(())
    }

    fn evict_slot(&mut self, slot: usize) {
        let w = self.word_of_slot[slot];
        if self.dirty[slot] {
            let col: Vec<f32> =
                self.buffer[slot * self.k..(slot + 1) * self.k].to_vec();
            self.put_col(w as usize, &col);
            self.dirty[slot] = false;
        }
        self.slot_of.remove(&w);
    }

    /// Mirror the record just placed by [`Self::encode_and_place`] (still
    /// sitting in `self.enc_buf`) into the WAL, if a batch bracket is
    /// open. Runs BEFORE the extent write happens (sync mode) or is
    /// enqueued (async mode) — intent first, always.
    fn wal_log_column(&mut self, w: usize) {
        let Some(batch) = self.wal_batch else { return };
        let res = match self.wal.as_mut() {
            Some(wal) => wal.append_column(batch, w as u32, &self.enc_buf),
            None => return,
        };
        if let Err(e) = res {
            self.note_poison(&format!("WAL append (column {w}): {e}"));
        }
    }

    /// Record a durability error. First error wins; every error is logged
    /// immediately so it cannot vanish into a swallowed `Drop`.
    fn note_poison(&mut self, msg: &str) {
        eprintln!("PagedPhi {:?}: {msg}", self.path);
        if self.poisoned.is_none() {
            self.poisoned = Some(msg.to_string());
        }
    }

    /// The first durability error this store hit, if any. Checkpointing
    /// code must consult this — or simply call `flush`/`truncate_wal`,
    /// both of which refuse to succeed on a poisoned store — before
    /// trusting what is on disk.
    pub fn poisoned(&self) -> Option<&str> {
        self.poisoned.as_deref()
    }

    /// Arm the write-ahead log (module docs, "Crash consistency").
    /// Creates/truncates `<path>.wal`; from here on every column write
    /// inside a [`PhiColumnStore::wal_begin`] /
    /// [`PhiColumnStore::wal_commit`] bracket is mirrored into the log
    /// before it touches an extent.
    pub fn enable_wal(&mut self) -> anyhow::Result<()> {
        anyhow::ensure!(self.wal.is_none(), "WAL already enabled");
        self.wal = Some(Wal::create(&wal::wal_path(&self.path))?);
        self.wal_fresh = vec![false; self.n_words];
        Ok(())
    }

    /// Reopen a store together with its WAL after a crash. The store
    /// itself reflects the last flushed (durable) state; the returned
    /// batches are the durably *committed* ones found in the log (torn
    /// tail already truncated away), in commit order, NOT yet applied —
    /// the caller filters them against its own checkpoint cursor and
    /// replays the survivors via [`Self::apply_wal_batch`].
    pub fn open_with_wal(
        path: &Path,
        buffer_bytes: usize,
    ) -> anyhow::Result<(Self, Vec<WalBatch>)> {
        let mut this = Self::open(path, buffer_bytes)?;
        let (w, batches) = Wal::open(&wal::wal_path(path))?;
        this.wal = Some(w);
        this.wal_fresh = vec![false; this.n_words];
        Ok((this, batches))
    }

    /// Replay one committed batch from [`Self::open_with_wal`]: decode
    /// each logged record and store it. Records are full column images,
    /// so replay is idempotent and last-wins within a batch; placement
    /// goes through the normal (preservation-guarded) write path, so a
    /// crash *during* recovery is itself recoverable.
    pub fn apply_wal_batch(&mut self, batch: &WalBatch) {
        let mut col = vec![0.0f32; self.k];
        for (w, rec) in &batch.writes {
            let w = *w as usize;
            if w >= self.n_words {
                self.ensure_capacity(w + 1);
            }
            codec::decode_column(rec, &mut col);
            self.store_column(w, &col);
        }
    }

    /// Total bytes ever appended to the WAL, across truncations — the
    /// write-amplification observable the bench WAL sweep reports.
    pub fn wal_bytes(&self) -> u64 {
        self.wal.as_ref().map(|w| w.bytes_appended()).unwrap_or(0)
    }

    /// Test-only: abandon the store the way `kill -9` would — no flush,
    /// no directory write, no WAL truncation. The I/O daemon (if any)
    /// finishes only what was already queued, and the store object is
    /// leaked so `Drop`'s flush can never tidy up. On-disk state is left
    /// exactly as a real crash would leave it — which is what recovery
    /// tests must cope with.
    pub fn simulate_crash(mut self) {
        if let Some(mut aio) = self.async_io.take() {
            let _ = aio.tx.send(IoReq::Shutdown);
            if let Some(h) = aio.handle.take() {
                let _ = h.join();
            }
        }
        std::mem::forget(self);
    }

    /// Write a checkpoint sidecar with algorithm state (fault tolerance:
    /// "the global topic-word matrix is stored in hard disk for
    /// restarting the online learning", §3.2).
    pub fn checkpoint(&mut self, step: usize, phisum: &[f32]) -> anyhow::Result<()> {
        self.flush()?;
        let mut meta = String::new();
        meta.push_str(&format!("step {step}\n"));
        meta.push_str(&format!("k {}\n", self.k));
        meta.push_str(&format!("n_words {}\n", self.n_words));
        meta.push_str("phisum");
        for &x in phisum {
            meta.push_str(&format!(" {x}"));
        }
        meta.push('\n');
        let meta_path = self.path.with_extension("meta");
        std::fs::write(meta_path, meta)?;
        Ok(())
    }

    /// Load the checkpoint sidecar: `(step, phisum)`.
    pub fn load_checkpoint(path: &Path) -> anyhow::Result<(usize, Vec<f32>)> {
        let meta_path = path.with_extension("meta");
        let text = std::fs::read_to_string(meta_path)?;
        let mut step = 0usize;
        let mut phisum = Vec::new();
        for line in text.lines() {
            let mut it = line.split_ascii_whitespace();
            match it.next() {
                Some("step") => {
                    step = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("bad checkpoint"))?
                        .parse()?;
                }
                Some("phisum") => {
                    phisum = it
                        .map(|x| x.parse::<f32>())
                        .collect::<Result<Vec<_>, _>>()?;
                }
                _ => {}
            }
        }
        anyhow::ensure!(!phisum.is_empty(), "bad checkpoint: no phisum");
        Ok((step, phisum))
    }
}

impl PhiColumnStore for PagedPhi {
    fn k(&self) -> usize {
        self.k
    }

    fn n_words(&self) -> usize {
        self.n_words
    }

    fn ensure_capacity(&mut self, n_words: usize) {
        if n_words <= self.n_words {
            return;
        }
        // Quiesce the I/O thread so the growth below cannot race an
        // in-flight background read or write.
        self.quiesce_async().expect("quiesce store I/O thread");
        self.n_words = n_words;
        // New columns are implicit zeros: directory entries only, no file
        // growth until something is written.
        self.dir.resize(n_words, DirEnt::default());
        if self.wal.is_some() {
            self.wal_fresh.resize(n_words, false);
        }
        self.write_header().expect("header write");
    }

    fn with_column<R>(&mut self, w: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
        assert!(w < self.n_words, "column {w} out of range {}", self.n_words);
        if let Some(&slot) = self.slot_of.get(&(w as u32)) {
            self.stats.buffer_hits += 1;
            self.dirty[slot] = true;
            return f(&mut self.buffer[slot * self.k..(slot + 1) * self.k]);
        }
        // Miss: stream through scratch — read, mutate, write back (Fig. 4
        // lines 8 and 15).
        let mut scratch = std::mem::take(&mut self.scratch);
        self.fetch_col(w, &mut scratch, true);
        let r = f(&mut scratch);
        self.put_col(w, &scratch);
        self.scratch = scratch;
        r
    }

    fn load_column(&mut self, w: usize, out: &mut [f32]) {
        assert!(w < self.n_words);
        if let Some(&slot) = self.slot_of.get(&(w as u32)) {
            self.stats.buffer_hits += 1;
            out.copy_from_slice(&self.buffer[slot * self.k..(slot + 1) * self.k]);
            return;
        }
        self.fetch_col(w, out, true);
    }

    fn store_column(&mut self, w: usize, data: &[f32]) {
        assert!(w < self.n_words);
        if let Some(&slot) = self.slot_of.get(&(w as u32)) {
            self.stats.buffer_hits += 1;
            self.buffer[slot * self.k..(slot + 1) * self.k]
                .copy_from_slice(data);
            self.dirty[slot] = true;
            return;
        }
        self.stats.buffer_misses += 1;
        self.put_col(w, data);
    }

    fn set_hot_words(&mut self, words: &[u32]) {
        use std::collections::HashSet;
        let want: HashSet<u32> =
            words.iter().copied().take(self.max_slots).collect();
        // Evict buffered columns that are no longer hot.
        let to_evict: Vec<usize> = self
            .slot_of
            .iter()
            .filter(|&(w, _)| !want.contains(w))
            .map(|(_, &s)| s)
            .collect();
        for slot in to_evict {
            self.evict_slot(slot);
        }
        // Load newly hot columns into free slots.
        for &w in words.iter().take(self.max_slots) {
            if self.slot_of.contains_key(&w) {
                continue;
            }
            let slot = if self.word_of_slot.len() < self.max_slots {
                let slot = self.word_of_slot.len();
                self.word_of_slot.push(w);
                self.dirty.push(false);
                self.buffer.resize((slot + 1) * self.k, 0.0);
                slot
            } else {
                // Find a slot not mapped (evicted above).
                match (0..self.word_of_slot.len()).find(|&s| {
                    !self.slot_of.contains_key(&self.word_of_slot[s])
                        || self.slot_of[&self.word_of_slot[s]] != s
                }) {
                    Some(s) => s,
                    None => continue, // buffer full of still-hot words
                }
            };
            let mut col = vec![0.0f32; self.k];
            self.fetch_col(w as usize, &mut col, false);
            self.buffer[slot * self.k..(slot + 1) * self.k].copy_from_slice(&col);
            self.word_of_slot[slot] = w;
            self.dirty[slot] = false;
            self.slot_of.insert(w, slot);
        }
    }

    fn prefetch_columns(&mut self, words: &[u32]) {
        let Some(aio) = &self.async_io else { return };
        // Hot columns never touch the daemon, so prefetching them would
        // only orphan cache entries. Record locations are resolved here
        // (the daemon has no directory); implicit-zero columns are staged
        // as zero-fill cache entries without a disk read.
        let wanted: Vec<PrefetchItem> = words
            .iter()
            .copied()
            .filter(|w| {
                (*w as usize) < self.n_words && !self.slot_of.contains_key(w)
            })
            .map(|w| {
                let e = self.dir[w as usize];
                PrefetchItem { w, offset: e.offset, len: e.len }
            })
            .collect();
        if !wanted.is_empty() {
            let _ = aio.tx.send(IoReq::Prefetch(wanted));
        }
    }

    fn set_async_io(&mut self, enabled: bool) -> bool {
        if enabled {
            if self.async_io.is_none() {
                let file =
                    self.file.try_clone().expect("clone store file handle");
                let shared = Arc::new(AsyncShared::default());
                let worker_shared = Arc::clone(&shared);
                let (tx, rx) = std::sync::mpsc::channel();
                let k = self.k;
                let handle = std::thread::Builder::new()
                    .name("phi-io".into())
                    .spawn(move || io_daemon(file, k, rx, worker_shared))
                    .expect("spawn store I/O thread");
                self.async_io = Some(AsyncIo {
                    tx,
                    shared,
                    handle: Some(handle),
                    next_version: 0,
                });
            }
        } else if let Some(mut aio) = self.async_io.take() {
            // Drain the write-behind buffer, then stop the thread and fold
            // its counters into the resident stats.
            let (ack, ack_rx) = std::sync::mpsc::sync_channel(1);
            if aio.tx.send(IoReq::DrainAndSync { ack }).is_ok() {
                let _ = ack_rx.recv();
            }
            let _ = aio.tx.send(IoReq::Shutdown);
            if let Some(h) = aio.handle.take() {
                let _ = h.join();
            }
            self.stats.prefetched_cols +=
                aio.shared.prefetched_cols.load(Ordering::Relaxed);
            self.stats.wb_writes += aio.shared.wb_writes.load(Ordering::Relaxed);
            self.stats.logical_bytes +=
                aio.shared.bg_logical_bytes.load(Ordering::Relaxed);
            self.stats.disk_bytes +=
                aio.shared.bg_disk_bytes.load(Ordering::Relaxed);
        }
        true
    }

    fn wal_enabled(&self) -> bool {
        self.wal.is_some()
    }

    fn wal_begin(&mut self, batch_id: u64) {
        if self.wal.is_none() {
            return;
        }
        self.wal_batch = Some(batch_id);
        let res = self.wal.as_mut().unwrap().append_begin(batch_id);
        if let Err(e) = res {
            self.note_poison(&format!("WAL begin (batch {batch_id}): {e}"));
        }
    }

    fn wal_commit(&mut self, batch_id: u64, state: &[u8]) {
        if self.wal.is_none() {
            return;
        }
        // Invariant 2: hot-buffer mutations bypass the per-write mirror,
        // so capture every still-dirty hot column under this batch before
        // the commit frame — each committed batch is then self-contained.
        let slots: Vec<(usize, u32)> = self
            .word_of_slot
            .iter()
            .enumerate()
            .filter(|&(s, &w)| {
                self.slot_of.get(&w) == Some(&s) && self.dirty[s]
            })
            .map(|(s, &w)| (s, w))
            .collect();
        let mut rec = Vec::new();
        for (slot, w) in slots {
            codec::encode_column(
                self.codec,
                &self.buffer[slot * self.k..(slot + 1) * self.k],
                &mut rec,
            );
            let res =
                self.wal.as_mut().unwrap().append_column(batch_id, w, &rec);
            if let Err(e) = res {
                self.note_poison(&format!("WAL append (hot column {w}): {e}"));
            }
        }
        let res = self.wal.as_mut().unwrap().append_commit(batch_id, state);
        if let Err(e) = res {
            self.note_poison(&format!("WAL commit (batch {batch_id}): {e}"));
        }
        self.wal_batch = None;
    }

    fn truncate_wal(&mut self) -> anyhow::Result<()> {
        if let Some(msg) = &self.poisoned {
            anyhow::bail!("store {:?} is poisoned: {msg}", self.path);
        }
        if let Some(wal) = self.wal.as_mut() {
            wal.reset()?;
            // The directory just made durable describes the current
            // extents: they become the protected base for the next
            // checkpoint interval.
            self.wal_fresh.fill(false);
        }
        Ok(())
    }

    fn flush(&mut self) -> anyhow::Result<()> {
        if let Some(msg) = &self.poisoned {
            anyhow::bail!(
                "refusing to flush poisoned store {:?}: {msg}",
                self.path
            );
        }
        let slots: Vec<(usize, u32)> = self
            .word_of_slot
            .iter()
            .enumerate()
            .filter(|&(s, &w)| {
                self.slot_of.get(&w) == Some(&s) && self.dirty[s]
            })
            .map(|(s, &w)| (s, w))
            .collect();
        if self.async_io.is_some() {
            // Route the hot-buffer write-backs through the write-behind
            // path, then drain everything and fsync on the I/O thread;
            // the foreground persists the header + directory after.
            for (slot, w) in slots {
                let col: Vec<f32> =
                    self.buffer[slot * self.k..(slot + 1) * self.k].to_vec();
                self.put_col(w as usize, &col);
                self.dirty[slot] = false;
            }
            self.quiesce_async()?;
            return self.persist_metadata();
        }
        for (slot, w) in slots {
            let col: Vec<f32> =
                self.buffer[slot * self.k..(slot + 1) * self.k].to_vec();
            self.write_col_to_disk(w as usize, &col);
            self.dirty[slot] = false;
        }
        self.persist_metadata()
    }

    fn io_stats(&self) -> IoStats {
        let mut s = self.stats;
        if let Some(aio) = &self.async_io {
            s.prefetched_cols += aio.shared.prefetched_cols.load(Ordering::Relaxed);
            s.wb_writes += aio.shared.wb_writes.load(Ordering::Relaxed);
            s.logical_bytes +=
                aio.shared.bg_logical_bytes.load(Ordering::Relaxed);
            s.disk_bytes += aio.shared.bg_disk_bytes.load(Ordering::Relaxed);
        }
        s
    }

    fn column_stats(&self, w: usize) -> Option<ColumnStats> {
        if w >= self.n_words {
            return None;
        }
        if let Some(&slot) = self.slot_of.get(&(w as u32)) {
            if self.dirty[slot] {
                // The hot buffer holds unencoded mutations; the directory
                // stats are stale. Exact-or-absent, never wrong.
                return None;
            }
        }
        // Not hot-dirty: the directory entry describes the freshest
        // encoded state (it is updated at write-enqueue time, so pending
        // async writes are already reflected).
        let e = self.dir[w];
        Some(ColumnStats { nnz: e.nnz, max: e.max })
    }
}

impl Drop for PagedPhi {
    fn drop(&mut self) {
        // Stop the I/O thread first (drains pending writes), then persist
        // whatever is still dirty in the hot buffer. The error cannot
        // propagate out of `drop`, but it must not vanish silently: a
        // failed final flush means the on-disk state is the previous
        // durable one, and whoever reopens the store should know why.
        self.set_async_io(false);
        if let Err(e) = self.flush() {
            eprintln!("PagedPhi {:?}: flush on drop failed: {e}", self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn new_store(k: usize, w: usize, buf_cols: usize) -> (crate::util::TempDir, PagedPhi) {
        let dir = crate::util::TempDir::new("t");
        let path = dir.path().join("phi.bin");
        let store = PagedPhi::create(&path, k, w, buf_cols * k * 4).unwrap();
        (dir, store)
    }

    #[test]
    fn read_write_round_trip_unbuffered() {
        let (_d, mut s) = new_store(4, 8, 1);
        s.with_column(3, |c| c.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        s.with_column(7, |c| c.copy_from_slice(&[9.0; 4]));
        assert_eq!(s.read_column(3), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.read_column(7), vec![9.0; 4]);
        assert_eq!(s.read_column(0), vec![0.0; 4]);
        // with_column misses read+write; read_column (load path) only
        // reads.
        assert!(s.io_stats().col_reads >= 5);
        assert_eq!(s.io_stats().col_writes, 2);
        // Background-I/O counters stay zero in synchronous mode.
        assert_eq!(s.io_stats().prefetched_cols, 0);
        assert_eq!(s.io_stats().prefetch_hits, 0);
        assert_eq!(s.io_stats().wb_writes, 0);
    }

    #[test]
    fn hot_buffer_avoids_disk_io() {
        let (_d, mut s) = new_store(4, 8, 4);
        s.set_hot_words(&[1, 2]);
        let base_reads = s.io_stats().col_reads;
        for _ in 0..10 {
            s.with_column(1, |c| c[0] += 1.0);
            s.with_column(2, |c| c[1] += 1.0);
        }
        assert_eq!(s.io_stats().col_reads, base_reads, "hits must not read");
        assert_eq!(s.io_stats().buffer_hits, 20);
        s.flush().unwrap();
        assert_eq!(s.read_column(1)[0], 10.0);
        assert_eq!(s.read_column(2)[1], 10.0);
    }

    #[test]
    fn eviction_writes_back_dirty_columns() {
        let (_d, mut s) = new_store(2, 6, 2);
        s.set_hot_words(&[0, 1]);
        s.with_column(0, |c| c.copy_from_slice(&[5.0, 5.0]));
        // Replace the hot set: column 0 must be written back.
        s.set_hot_words(&[2, 3]);
        assert_eq!(s.read_column(0), vec![5.0, 5.0]);
    }

    #[test]
    fn buffer_respects_budget() {
        let (_d, mut s) = new_store(2, 100, 3);
        s.set_hot_words(&(0u32..50).collect::<Vec<_>>());
        assert!(s.buffered_columns() <= 3);
    }

    #[test]
    fn restart_recovers_state() {
        let dir = crate::util::TempDir::new("t");
        let path = dir.path().join("phi.bin");
        {
            let mut s = PagedPhi::create(&path, 3, 5, 3 * 4 * 2).unwrap();
            s.set_hot_words(&[1]);
            s.with_column(1, |c| c.copy_from_slice(&[1.0, 2.0, 3.0]));
            s.with_column(4, |c| c.copy_from_slice(&[7.0, 8.0, 9.0]));
            s.checkpoint(42, &[6.0, 10.0, 12.0]).unwrap();
        } // dropped: flushed
        let mut s = PagedPhi::open(&path, 1024).unwrap();
        assert_eq!(s.k(), 3);
        assert_eq!(s.n_words(), 5);
        assert_eq!(s.read_column(1), vec![1.0, 2.0, 3.0]);
        assert_eq!(s.read_column(4), vec![7.0, 8.0, 9.0]);
        let (step, phisum) = PagedPhi::load_checkpoint(&path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(phisum, vec![6.0, 10.0, 12.0]);
    }

    #[test]
    fn capacity_growth_persists_and_zeroes() {
        let (_d, mut s) = new_store(2, 3, 1);
        s.with_column(2, |c| c.copy_from_slice(&[1.0, 1.0]));
        s.ensure_capacity(10);
        assert_eq!(s.n_words(), 10);
        assert_eq!(s.read_column(9), vec![0.0, 0.0]);
        assert_eq!(s.read_column(2), vec![1.0, 1.0]);
    }

    #[test]
    fn export_dense_round_trip() {
        let (_d, mut s) = new_store(2, 4, 2);
        s.with_column(0, |c| c.copy_from_slice(&[1.0, 0.5]));
        s.with_column(3, |c| c.copy_from_slice(&[0.0, 2.0]));
        let dense = s.export_dense();
        assert_eq!(dense.word(0), &[1.0, 0.5]);
        assert_eq!(dense.word(3), &[0.0, 2.0]);
        assert_eq!(dense.phisum, vec![1.0, 2.5]);
    }

    #[test]
    fn hot_set_changes_are_correct_across_many_rounds() {
        // Churn the hot set and verify contents never corrupt.
        let (_d, mut s) = new_store(2, 20, 4);
        let mut truth = vec![[0.0f32; 2]; 20];
        let mut rng = crate::util::Rng::new(5);
        for round in 0..30 {
            let hot: Vec<u32> =
                (0..4).map(|_| rng.below(20) as u32).collect();
            s.set_hot_words(&hot);
            for _ in 0..10 {
                let w = rng.below(20);
                let inc = (round + 1) as f32;
                s.with_column(w, |c| {
                    c[0] += inc;
                    c[1] += 0.5;
                });
                truth[w][0] += inc;
                truth[w][1] += 0.5;
            }
        }
        s.flush().unwrap();
        for w in 0..20 {
            let col = s.read_column(w);
            assert!((col[0] - truth[w][0]).abs() < 1e-4, "w={w}");
            assert!((col[1] - truth[w][1]).abs() < 1e-4, "w={w}");
        }
    }

    #[test]
    fn async_io_round_trip_prefetch_and_write_behind() {
        let (_d, mut s) = new_store(4, 16, 2);
        assert!(s.set_async_io(true));
        assert!(s.async_io_enabled());
        s.prefetch_columns(&[3, 5, 7]);
        // A write-behind write followed by a read must see the new data
        // (served from the pending buffer or the flushed file).
        s.with_column(3, |c| c.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        assert_eq!(s.read_column(3), vec![1.0, 2.0, 3.0, 4.0]);
        // A prefetched, never-written column reads its disk value.
        assert_eq!(s.read_column(5), vec![0.0; 4]);
        s.flush().unwrap();
        assert!(s.set_async_io(false));
        let io = s.io_stats();
        assert!(io.prefetched_cols >= 3, "{io:?}");
        assert!(io.prefetch_hits >= 1, "{io:?}");
        assert!(io.wb_writes >= 1, "{io:?}");
        // Back in synchronous mode the data is durable.
        assert_eq!(s.read_column(3), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn async_io_matches_sync_contents_under_churn() {
        // Same churn as the sync test, with the background I/O mode on:
        // prefetches, write-behind, hot-set evictions and reads must never
        // lose or reorder an update.
        let (_d, mut s) = new_store(2, 20, 4);
        s.set_async_io(true);
        let mut truth = vec![[0.0f32; 2]; 20];
        let mut rng = crate::util::Rng::new(5);
        for round in 0..30 {
            let hot: Vec<u32> =
                (0..4).map(|_| rng.below(20) as u32).collect();
            s.set_hot_words(&hot);
            let ahead: Vec<u32> =
                (0..6).map(|_| rng.below(20) as u32).collect();
            s.prefetch_columns(&ahead);
            for _ in 0..10 {
                let w = rng.below(20);
                let inc = (round + 1) as f32;
                s.with_column(w, |c| {
                    c[0] += inc;
                    c[1] += 0.5;
                });
                truth[w][0] += inc;
                truth[w][1] += 0.5;
            }
        }
        s.flush().unwrap();
        s.set_async_io(false);
        for w in 0..20 {
            let col = s.read_column(w);
            assert!((col[0] - truth[w][0]).abs() < 1e-4, "w={w}");
            assert!((col[1] - truth[w][1]).abs() < 1e-4, "w={w}");
        }
    }

    #[test]
    fn async_io_survives_capacity_growth() {
        let (_d, mut s) = new_store(2, 3, 1);
        s.set_async_io(true);
        s.with_column(2, |c| c.copy_from_slice(&[1.0, 1.0]));
        s.ensure_capacity(10);
        assert_eq!(s.n_words(), 10);
        assert_eq!(s.read_column(9), vec![0.0, 0.0]);
        assert_eq!(s.read_column(2), vec![1.0, 1.0]);
        s.set_async_io(false);
        assert_eq!(s.read_column(2), vec![1.0, 1.0]);
    }

    /// A column set exercising every record shape: implicit zero, one-hot
    /// sparse, constant run, dense ramp, and special bit patterns.
    fn codec_fixture(k: usize) -> Vec<(usize, Vec<f32>)> {
        let mut one_hot = vec![0.0f32; k];
        one_hot[k / 2] = 3.5;
        let mut specials = vec![0.0f32; k];
        specials[0] = -0.0;
        specials[1] = f32::MIN_POSITIVE / 4.0;
        if k > 2 {
            specials[2] = f32::NAN;
        }
        vec![
            (0, vec![0.0; k]),
            (1, one_hot),
            (2, vec![2.25; k]),
            (3, (0..k).map(|i| i as f32 * 0.5 + 0.25).collect()),
            (5, specials),
        ]
    }

    #[test]
    fn codec_container_round_trip_and_reopen_every_codec() {
        for codec in Codec::all() {
            let dir = crate::util::TempDir::new("cdc");
            let path = dir.path().join("phi.bin");
            let k = 7;
            {
                let mut s =
                    PagedPhi::create_with_codec(&path, k, 8, k * 4, codec)
                        .unwrap();
                assert_eq!(s.codec(), codec);
                for (w, col) in codec_fixture(k) {
                    s.store_column(w, &col);
                }
                // Overwrite in place and grow a column's encoding.
                s.store_column(1, &vec![1.0; k]);
                s.flush().unwrap();
                for (w, col) in codec_fixture(k) {
                    if w == 1 {
                        continue;
                    }
                    let got = s.read_column(w);
                    for (a, b) in got.iter().zip(&col) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{codec:?} w={w}");
                    }
                }
            }
            let mut s = PagedPhi::open(&path, 1024).unwrap();
            assert_eq!(s.codec(), codec, "codec must persist across reopen");
            assert_eq!(s.read_column(1), vec![1.0; k]);
            for (w, col) in codec_fixture(k) {
                if w == 1 {
                    continue;
                }
                let got = s.read_column(w);
                for (a, b) in got.iter().zip(&col) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{codec:?} w={w}");
                }
            }
        }
    }

    #[test]
    fn codec_async_churn_mixes_codecs_under_prefetch_and_write_behind() {
        // Satellite: the variable-length record path must survive the
        // full overlapped protocol under every write policy, including
        // columns that oscillate between zero / sparse / dense (changing
        // record length and forcing relocations mid-run).
        for codec in Codec::all() {
            let dir = crate::util::TempDir::new("cdc-async");
            let path = dir.path().join("phi.bin");
            let k = 6;
            let n = 20;
            let mut s =
                PagedPhi::create_with_codec(&path, k, n, 4 * k * 4, codec)
                    .unwrap();
            s.set_async_io(true);
            let mut truth = vec![vec![0.0f32; k]; n];
            let mut rng = crate::util::Rng::new(11);
            for round in 0..25 {
                let hot: Vec<u32> =
                    (0..4).map(|_| rng.below(n) as u32).collect();
                s.set_hot_words(&hot);
                let ahead: Vec<u32> =
                    (0..6).map(|_| rng.below(n) as u32).collect();
                s.prefetch_columns(&ahead);
                for _ in 0..8 {
                    let w = rng.below(n);
                    match rng.below(3) {
                        0 => {
                            // Sparse-ify: zero all but one topic.
                            let hit = rng.below(k);
                            let v = (round + 1) as f32;
                            s.with_column(w, |c| {
                                c.fill(0.0);
                                c[hit] = v;
                            });
                            truth[w].fill(0.0);
                            truth[w][hit] = v;
                        }
                        1 => {
                            // Dense increment.
                            s.with_column(w, |c| {
                                for x in c.iter_mut() {
                                    *x += 0.25;
                                }
                            });
                            for x in truth[w].iter_mut() {
                                *x += 0.25;
                            }
                        }
                        _ => {
                            // Zero out (back to the implicit record).
                            s.with_column(w, |c| c.fill(0.0));
                            truth[w].fill(0.0);
                        }
                    }
                }
            }
            s.flush().unwrap();
            s.set_async_io(false);
            for w in 0..n {
                let col = s.read_column(w);
                for (i, (a, b)) in col.iter().zip(&truth[w]).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{codec:?} w={w} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn codec_byte_counters_track_compression() {
        // Auto on sparse columns: fewer disk bytes than logical bytes.
        let (_d, mut s) = new_store(64, 8, 1);
        let mut col = vec![0.0f32; 64];
        col[5] = 1.0;
        for w in 0..8 {
            s.store_column(w, &col);
        }
        for w in 0..8 {
            let _ = s.read_column(w);
        }
        let io = s.io_stats();
        assert_eq!(io.logical_bytes, 16 * 64 * 4, "8 writes + 8 reads");
        assert!(io.disk_bytes > 0);
        assert!(
            io.disk_bytes * 3 < io.logical_bytes,
            "sparse columns must compress >3x: {io:?}"
        );

        // Forced raw: disk bytes exceed logical (tag byte overhead).
        let dir = crate::util::TempDir::new("raw");
        let mut r = PagedPhi::create_with_codec(
            &dir.path().join("phi.bin"),
            64,
            8,
            64 * 4,
            Codec::Raw,
        )
        .unwrap();
        r.store_column(0, &col);
        let rio = r.io_stats();
        assert_eq!(rio.logical_bytes, 64 * 4);
        assert_eq!(rio.disk_bytes, 1 + 64 * 4);

        // Reading a never-written column costs zero disk bytes but still
        // counts as a logical transfer (the zone-map skip).
        let before = r.io_stats();
        assert_eq!(r.read_column(7), vec![0.0; 64]);
        let after = r.io_stats();
        assert_eq!(after.col_reads, before.col_reads + 1);
        assert_eq!(after.logical_bytes, before.logical_bytes + 64 * 4);
        assert_eq!(after.disk_bytes, before.disk_bytes);
    }

    #[test]
    fn codec_zone_map_stats_are_exact_or_absent() {
        let (_d, mut s) = new_store(8, 6, 2);
        let mut col = vec![0.0f32; 8];
        col[2] = 4.5;
        col[6] = 1.25;
        s.store_column(1, &col);
        // Never-written and written columns report exact directory stats.
        assert_eq!(s.column_stats(0), Some(ColumnStats { nnz: 0, max: 0.0 }));
        assert_eq!(s.column_stats(1), Some(ColumnStats { nnz: 2, max: 4.5 }));
        assert_eq!(s.column_stats(99), None, "out of range");
        // A clean hot column still reports; a dirty one must not (the
        // directory is stale until write-back).
        s.set_hot_words(&[1]);
        assert_eq!(s.column_stats(1), Some(ColumnStats { nnz: 2, max: 4.5 }));
        s.with_column(1, |c| c[0] = 9.0);
        assert_eq!(s.column_stats(1), None, "hot-dirty stats are stale");
        s.set_hot_words(&[]);
        // Written back: exact again, reflecting the mutation.
        assert_eq!(s.column_stats(1), Some(ColumnStats { nnz: 3, max: 9.0 }));
        // Async mode: stats reflect pending (unflushed) writes too,
        // because the directory is updated at write-enqueue time.
        s.set_async_io(true);
        let mut dense = vec![0.5f32; 8];
        dense[3] = 7.0;
        s.store_column(4, &dense);
        assert_eq!(s.column_stats(4), Some(ColumnStats { nnz: 8, max: 7.0 }));
        s.set_async_io(false);
    }

    #[test]
    fn codec_raw_and_auto_agree_bitwise_with_identical_logical_iostats() {
        // The acceptance contract at store level: the same op sequence
        // under Raw and Auto produces bit-identical contents and
        // identical IoStats in every field except disk_bytes.
        let run = |codec: Codec| {
            let dir = crate::util::TempDir::new("eq");
            let path = dir.path().join("phi.bin");
            let k = 5;
            let n = 12;
            let mut s =
                PagedPhi::create_with_codec(&path, k, n, 3 * k * 4, codec)
                    .unwrap();
            let mut rng = crate::util::Rng::new(31);
            for round in 0..20 {
                let hot: Vec<u32> =
                    (0..3).map(|_| rng.below(n) as u32).collect();
                s.set_hot_words(&hot);
                for _ in 0..6 {
                    let w = rng.below(n);
                    let t = rng.below(k);
                    s.with_column(w, |c| c[t] += (round + 1) as f32 * 0.125);
                }
            }
            s.flush().unwrap();
            let contents: Vec<Vec<u32>> = (0..n)
                .map(|w| {
                    s.read_column(w).iter().map(|x| x.to_bits()).collect()
                })
                .collect();
            (contents, s.io_stats())
        };
        let (raw_data, raw_io) = run(Codec::Raw);
        let (auto_data, auto_io) = run(Codec::Auto);
        assert_eq!(raw_data, auto_data, "contents must be bit-identical");
        let logical = |io: IoStats| IoStats { disk_bytes: 0, ..io };
        assert_eq!(
            logical(raw_io),
            logical(auto_io),
            "logical IoStats must not depend on the codec"
        );
        assert_ne!(raw_io.disk_bytes, auto_io.disk_bytes);
        assert!(auto_io.disk_bytes < raw_io.disk_bytes);
    }

    #[test]
    fn recovery_idx_crc_detects_corruption() {
        let dir = crate::util::TempDir::new("idxcrc");
        let path = dir.path().join("phi.bin");
        {
            let mut s = PagedPhi::create(&path, 3, 4, 1024).unwrap();
            s.store_column(2, &[1.0, 2.0, 3.0]);
            s.flush().unwrap();
        }
        // Flip one byte of a directory entry; the trailing CRC must catch
        // it on reopen.
        let ip = idx_path(&path);
        let mut bytes = std::fs::read(&ip).unwrap();
        let at = IDX_HEADER_BYTES as usize + 2 * DIR_ENT_BYTES;
        bytes[at] ^= 0xFF;
        std::fs::write(&ip, bytes).unwrap();
        let err = PagedPhi::open(&path, 1024).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn recovery_idx_shorter_than_header_claims_rejected() {
        let dir = crate::util::TempDir::new("idxtrunc");
        let path = dir.path().join("phi.bin");
        {
            let mut s = PagedPhi::create(&path, 2, 6, 1024).unwrap();
            s.store_column(5, &[1.0, 1.0]);
            s.flush().unwrap();
        }
        // Chop the file short of what its own header claims: must be
        // rejected as truncated, never zero-padded into a "valid" but
        // wrong directory.
        let ip = idx_path(&path);
        let bytes = std::fs::read(&ip).unwrap();
        std::fs::write(&ip, &bytes[..bytes.len() - 10]).unwrap();
        let err = PagedPhi::open(&path, 1024).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn recovery_wal_replay_restores_committed_batches_only() {
        let dir = crate::util::TempDir::new("walrec");
        let path = dir.path().join("phi.bin");
        {
            let mut s = PagedPhi::create(&path, 3, 6, 2 * 3 * 4).unwrap();
            s.enable_wal().unwrap();
            s.wal_begin(1);
            s.store_column(0, &[1.0, 0.0, 0.0]);
            s.store_column(1, &[0.0, 2.0, 0.0]);
            s.wal_commit(1, b"s1");
            s.wal_begin(2);
            s.store_column(0, &[5.0, 5.0, 5.0]);
            s.wal_commit(2, b"s2");
            s.wal_begin(3);
            s.store_column(1, &[9.0, 9.0, 9.0]); // never committed
            s.simulate_crash();
        }
        let (mut s, batches) = PagedPhi::open_with_wal(&path, 1024).unwrap();
        // Nothing was ever flushed, so the durable base is all-zero and
        // the WAL holds exactly the two committed batches.
        let ids: Vec<u64> = batches.iter().map(|b| b.batch_id).collect();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(batches[1].state, b"s2");
        for b in &batches {
            s.apply_wal_batch(b);
        }
        assert_eq!(s.read_column(0), vec![5.0, 5.0, 5.0]);
        assert_eq!(
            s.read_column(1),
            vec![0.0, 2.0, 0.0],
            "uncommitted batch 3 rolled back"
        );
    }

    #[test]
    fn recovery_uncommitted_writes_never_touch_checkpoint_extents() {
        let dir = crate::util::TempDir::new("walext");
        let path = dir.path().join("phi.bin");
        {
            let mut s = PagedPhi::create(&path, 2, 4, 2 * 2 * 4).unwrap();
            s.enable_wal().unwrap();
            s.wal_begin(1);
            s.store_column(0, &[3.0, 4.0]);
            s.wal_commit(1, b"");
            // Checkpoint: make the directory durable, then truncate the
            // WAL — column 0's extent is now part of the protected base.
            s.flush().unwrap();
            s.truncate_wal().unwrap();
            // A post-checkpoint overwrite that would FIT in place: the
            // preservation guard must relocate it anyway. Crash before
            // the batch commits.
            s.wal_begin(2);
            s.store_column(0, &[8.0, 8.0]);
            s.simulate_crash();
        }
        let (mut s, batches) = PagedPhi::open_with_wal(&path, 1024).unwrap();
        assert!(batches.is_empty(), "batch 2 never committed");
        assert_eq!(
            s.read_column(0),
            vec![3.0, 4.0],
            "checkpoint extent must be byte-intact after the crash"
        );
    }

    #[test]
    fn recovery_async_mode_committed_batches_survive_crash() {
        let dir = crate::util::TempDir::new("walasync");
        let path = dir.path().join("phi.bin");
        {
            let mut s = PagedPhi::create(&path, 3, 8, 2 * 3 * 4).unwrap();
            s.enable_wal().unwrap();
            s.set_async_io(true);
            s.set_hot_words(&[1]);
            s.wal_begin(1);
            s.with_column(1, |c| c.copy_from_slice(&[1.0, 2.0, 3.0])); // hot
            s.with_column(5, |c| c[2] = 7.0); // streamed, write-behind
            s.wal_commit(1, b"t");
            s.wal_begin(2);
            s.with_column(5, |c| c[0] = 1.0); // never committed
            s.simulate_crash();
        }
        let (mut s, batches) = PagedPhi::open_with_wal(&path, 1024).unwrap();
        assert_eq!(batches.len(), 1);
        for b in &batches {
            s.apply_wal_batch(b);
        }
        assert_eq!(
            s.read_column(1),
            vec![1.0, 2.0, 3.0],
            "hot-buffer column captured by the commit sweep"
        );
        assert_eq!(s.read_column(5), vec![0.0, 0.0, 7.0]);
    }

    #[test]
    fn recovery_truncate_wal_resets_log_and_rearms_guard() {
        let dir = crate::util::TempDir::new("waltrunc");
        let path = dir.path().join("phi.bin");
        let mut s = PagedPhi::create(&path, 2, 4, 1024).unwrap();
        s.enable_wal().unwrap();
        assert!(s.wal_enabled());
        s.wal_begin(1);
        s.store_column(0, &[1.0, 1.0]);
        s.wal_commit(1, b"");
        let appended = s.wal_bytes();
        assert!(appended > 0);
        assert!(std::fs::metadata(wal::wal_path(&path)).unwrap().len() > 0);
        s.flush().unwrap();
        s.truncate_wal().unwrap();
        assert_eq!(std::fs::metadata(wal::wal_path(&path)).unwrap().len(), 0);
        // The lifetime append counter keeps counting across truncations.
        s.wal_begin(2);
        s.store_column(0, &[2.0, 2.0]);
        s.wal_commit(2, b"");
        assert!(s.wal_bytes() > appended);
    }

    #[test]
    fn recovery_wal_off_store_leaves_no_wal_artifacts() {
        let (_d, mut s) = new_store(2, 4, 2);
        assert!(!s.wal_enabled());
        // Bracket calls are no-ops with the WAL off.
        s.wal_begin(1);
        s.store_column(0, &[1.0, 2.0]);
        s.wal_commit(1, b"ignored");
        s.truncate_wal().unwrap();
        assert_eq!(s.wal_bytes(), 0);
        assert!(!wal::wal_path(s.path()).exists());
        assert!(s.poisoned().is_none());
    }

    #[test]
    fn recovery_wal_errors_poison_store_and_block_flush() {
        use crate::store::fault::{FaultFile, FaultMode};
        let dir = crate::util::TempDir::new("walpoison");
        let path = dir.path().join("phi.bin");
        let mut s = PagedPhi::create(&path, 2, 4, 1024).unwrap();
        s.enable_wal().unwrap();
        // Swap in a backing whose commit fsync fails: ops are begin
        // append (1), column append (2), commit append (3), commit
        // sync (4) — fault after 3 good ops.
        let shim = FaultFile::create(
            &wal::wal_path(&path),
            FaultMode::FailSync,
            3,
        )
        .unwrap();
        s.wal = Some(Wal::from_backing(Box::new(shim), 0));
        s.wal_fresh = vec![false; 4];
        s.wal_begin(1);
        s.store_column(0, &[1.0, 1.0]);
        s.wal_commit(1, b"");
        assert!(s.poisoned().is_some(), "commit fsync failure must poison");
        let err = s.flush().unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        let err = s.truncate_wal().unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        // Reads still work (the data is in memory / on disk), so a caller
        // can salvage state; only durability claims are refused.
        assert_eq!(s.read_column(0), vec![1.0, 1.0]);
    }
}
