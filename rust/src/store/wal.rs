//! Write-ahead log for the paged phi store (`rust/DESIGN.md` §13).
//!
//! An append-only, CRC-framed, fsync-on-commit intent log owned by
//! [`crate::store::paged::PagedPhi`]. Between two checkpoints every
//! column write is mirrored here as an already-encoded codec payload
//! (the same bytes [`crate::store::codec::encode_column`] produced for
//! the extent write), bracketed by batch markers:
//!
//! ```text
//! BeginBatch{b} → ColumnWrite{b, w, record}* → Commit{b, trainer-state}
//! ```
//!
//! Frames are self-delimiting — `[payload_len u32][crc32 u32][payload]`,
//! all little-endian — so recovery scans forward, keeps every frame whose
//! CRC matches, and discards the torn tail from the first bad frame on
//! (a kill mid-append leaves at most one torn frame at the end; a torn
//! frame *within* the prefix means the log itself was corrupted, and the
//! conservative response is the same: trust only the clean prefix).
//! Only batches whose `Commit` frame survives are replayed; an open
//! batch at the tail is rolled back by construction.
//!
//! Under the pipelined executor frames of neighbouring batches interleave
//! (batch `t+1` is staged — and its hot-buffer evictions logged — before
//! batch `t` commits). Every frame carries its `batch_id`, so replay
//! groups records by batch and orders batches by their `Commit` frames;
//! interleaving is harmless.
//!
//! Durability contract: `append_*` buffers in the OS (no fsync);
//! [`Wal::append_commit`] appends the commit frame and then fsyncs the
//! log, so a batch is either durably committed in full or invisible.
//! [`Wal::reset`] truncates the log after a successful checkpoint (the
//! checkpoint supersedes everything the log was protecting).
//!
//! The backing file is abstracted behind [`WalBacking`] so the
//! fault-injection shim ([`crate::store::fault::FaultFile`]) can stand in
//! for a real file in crash-recovery tests (short writes, failed fsyncs,
//! kill-after-N-ops) without real process kills.

use std::fs::{File, OpenOptions};
use std::io::{self, Write};
use std::path::Path;

/// Frame kinds (first payload byte).
const KIND_BEGIN: u8 = 1;
const KIND_COLUMN: u8 = 2;
const KIND_COMMIT: u8 = 3;

/// Frame header: payload length + payload CRC, both u32 LE.
const FRAME_HEADER_BYTES: usize = 8;

/// Parse guard: a claimed payload longer than this is treated as a torn
/// frame rather than a real allocation request (the largest legitimate
/// payload is one encoded column plus a few bytes of framing).
const MAX_PAYLOAD_BYTES: usize = 1 << 30;

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the framing
/// checksum for WAL frames and the `.idx` sidecar trailer. Hand-rolled:
/// the crate takes no external dependencies.
const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut j = 0;
        while j < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            j += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = build_crc_table();

/// CRC-32 (IEEE) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// The backing sink a [`Wal`] appends to. Production uses a real
/// append-mode [`File`]; tests substitute
/// [`crate::store::fault::FaultFile`] to inject short writes, fsync
/// failures and kill-after-N-ops.
pub trait WalBacking: Send {
    /// Append `buf` at the end of the log.
    fn append(&mut self, buf: &[u8]) -> io::Result<()>;
    /// Make everything appended so far durable (fsync).
    fn sync(&mut self) -> io::Result<()>;
    /// Truncate the log to `len` bytes.
    fn truncate(&mut self, len: u64) -> io::Result<()>;
}

impl WalBacking for File {
    fn append(&mut self, buf: &[u8]) -> io::Result<()> {
        self.write_all(buf)
    }

    fn sync(&mut self) -> io::Result<()> {
        self.sync_data()
    }

    fn truncate(&mut self, len: u64) -> io::Result<()> {
        self.set_len(len)
    }
}

/// One committed batch recovered from the log: the column records in
/// append order (later records for the same word supersede earlier ones)
/// plus the opaque trainer-state blob the owner attached at commit.
#[derive(Debug, Clone, PartialEq)]
pub struct WalBatch {
    pub batch_id: u64,
    /// `(word, encoded column record)` in append order.
    pub writes: Vec<(u32, Vec<u8>)>,
    /// Owner-defined commit payload (FOEM: step, RNG state, phisum,
    /// touched residual totals — see `em::foem`). Empty if none.
    pub state: Vec<u8>,
}

/// The append-only batch-intent log. See the module docs for the frame
/// format and durability contract.
pub struct Wal {
    backing: Box<dyn WalBacking>,
    /// Current log length in bytes (frames appended and not truncated).
    len: u64,
    /// Total bytes appended over this handle's lifetime (bench metric;
    /// survives `reset`).
    appended: u64,
    frame: Vec<u8>,
}

impl std::fmt::Debug for Wal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Wal")
            .field("len", &self.len)
            .field("appended", &self.appended)
            .finish()
    }
}

/// Scan `bytes` as a frame sequence: committed batches in commit order,
/// plus the length of the clean prefix (everything past it is torn or
/// garbage and must be truncated away). Pure, so torn-tail handling is
/// unit-testable byte-by-byte.
pub fn parse(bytes: &[u8]) -> (Vec<WalBatch>, u64) {
    let mut open: Vec<WalBatch> = Vec::new();
    let mut committed: Vec<WalBatch> = Vec::new();
    let mut pos = 0usize;
    let mut valid = 0usize;
    while bytes.len() - pos >= FRAME_HEADER_BYTES {
        let len =
            u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if len < 9 || len > MAX_PAYLOAD_BYTES {
            break; // impossible payload: torn or garbage
        }
        let start = pos + FRAME_HEADER_BYTES;
        let Some(end) = start.checked_add(len) else { break };
        if end > bytes.len() {
            break; // frame extends past EOF: torn tail
        }
        let payload = &bytes[start..end];
        if crc32(payload) != crc {
            break;
        }
        let kind = payload[0];
        let batch_id = u64::from_le_bytes(payload[1..9].try_into().unwrap());
        match kind {
            KIND_BEGIN => {
                open.push(WalBatch { batch_id, writes: Vec::new(), state: Vec::new() });
            }
            KIND_COLUMN if payload.len() >= 13 => {
                let word =
                    u32::from_le_bytes(payload[9..13].try_into().unwrap());
                let rec = payload[13..].to_vec();
                // Tolerate a record without an explicit Begin (a reset
                // that raced a crash can drop the marker): open the
                // batch implicitly.
                let batch = match open.iter_mut().find(|b| b.batch_id == batch_id) {
                    Some(b) => b,
                    None => {
                        open.push(WalBatch {
                            batch_id,
                            writes: Vec::new(),
                            state: Vec::new(),
                        });
                        open.last_mut().unwrap()
                    }
                };
                batch.writes.push((word, rec));
            }
            KIND_COMMIT => {
                let state = payload[9..].to_vec();
                let mut batch = match open.iter().position(|b| b.batch_id == batch_id) {
                    Some(i) => open.remove(i),
                    None => WalBatch {
                        batch_id,
                        writes: Vec::new(),
                        state: Vec::new(),
                    },
                };
                batch.state = state;
                committed.push(batch);
            }
            _ => break, // unknown kind: treat as corruption, keep the prefix
        }
        pos = end;
        valid = pos;
    }
    // Batches still open at the clean tail are rolled back (dropped).
    (committed, valid as u64)
}

impl Wal {
    /// Create (or truncate) a fresh, empty log at `path`.
    pub fn create(path: &Path) -> io::Result<Self> {
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)?;
        Ok(Self::from_backing(Box::new(file), 0))
    }

    /// Open the log at `path` (creating it if absent), recover the
    /// committed batches, and truncate the torn tail so subsequent
    /// appends extend a clean prefix.
    pub fn open(path: &Path) -> io::Result<(Self, Vec<WalBatch>)> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e),
        };
        let (batches, valid) = parse(&bytes);
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        let mut wal = Self::from_backing(Box::new(file), bytes.len() as u64);
        if valid < bytes.len() as u64 {
            wal.backing.truncate(valid)?;
            wal.backing.sync()?;
            wal.len = valid;
        }
        Ok((wal, batches))
    }

    /// Build a log over an arbitrary backing (fault-injection tests).
    pub fn from_backing(backing: Box<dyn WalBacking>, len: u64) -> Self {
        Self { backing, len, appended: 0, frame: Vec::new() }
    }

    fn append_frame(&mut self, payload_fn: impl FnOnce(&mut Vec<u8>)) -> io::Result<()> {
        self.frame.clear();
        self.frame.resize(FRAME_HEADER_BYTES, 0);
        payload_fn(&mut self.frame);
        let payload_len = (self.frame.len() - FRAME_HEADER_BYTES) as u32;
        let crc = crc32(&self.frame[FRAME_HEADER_BYTES..]);
        self.frame[0..4].copy_from_slice(&payload_len.to_le_bytes());
        self.frame[4..8].copy_from_slice(&crc.to_le_bytes());
        self.backing.append(&self.frame)?;
        self.len += self.frame.len() as u64;
        self.appended += self.frame.len() as u64;
        Ok(())
    }

    /// Append a `BeginBatch{batch_id}` marker (no fsync).
    pub fn append_begin(&mut self, batch_id: u64) -> io::Result<()> {
        self.append_frame(|p| {
            p.push(KIND_BEGIN);
            p.extend_from_slice(&batch_id.to_le_bytes());
        })
    }

    /// Append one column-write intent: the already-encoded codec record
    /// that is (or will be) written to the extent (no fsync).
    pub fn append_column(
        &mut self,
        batch_id: u64,
        word: u32,
        record: &[u8],
    ) -> io::Result<()> {
        self.append_frame(|p| {
            p.push(KIND_COLUMN);
            p.extend_from_slice(&batch_id.to_le_bytes());
            p.extend_from_slice(&word.to_le_bytes());
            p.extend_from_slice(record);
        })
    }

    /// Append `Commit{batch_id}` carrying the owner's state blob, then
    /// fsync — the batch's durability point.
    pub fn append_commit(&mut self, batch_id: u64, state: &[u8]) -> io::Result<()> {
        self.append_frame(|p| {
            p.push(KIND_COMMIT);
            p.extend_from_slice(&batch_id.to_le_bytes());
            p.extend_from_slice(state);
        })?;
        self.backing.sync()
    }

    /// Truncate the log after a successful checkpoint (which now covers
    /// everything the log was protecting).
    pub fn reset(&mut self) -> io::Result<()> {
        self.backing.truncate(0)?;
        self.backing.sync()?;
        self.len = 0;
        Ok(())
    }

    /// Current log length in bytes.
    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total bytes appended over this handle's lifetime (not reduced by
    /// [`Self::reset`]) — the write-amplification metric the
    /// `streaming_pipeline` bench reports as `wal_bytes`.
    pub fn bytes_appended(&self) -> u64 {
        self.appended
    }
}

/// `<store path>.wal` — sibling of the container, like the `.idx`
/// sidecar (extension *appended*, so `phi.bin` and `phi.res.bin` get
/// distinct logs).
pub fn wal_path(path: &Path) -> std::path::PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".wal");
    std::path::PathBuf::from(os)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_reference_vectors() {
        // IEEE CRC-32 check values ("check" column of the catalogue).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    fn temp_wal(label: &str) -> (crate::util::TempDir, std::path::PathBuf) {
        let dir = crate::util::TempDir::new(label);
        let path = dir.path().join("t.wal");
        (dir, path)
    }

    #[test]
    fn recovery_wal_round_trip_replays_committed_batches() {
        let (_dir, path) = temp_wal("walrt");
        let mut wal = Wal::create(&path).unwrap();
        wal.append_begin(1).unwrap();
        wal.append_column(1, 7, &[1, 2, 3]).unwrap();
        wal.append_column(1, 9, &[4, 5]).unwrap();
        wal.append_commit(1, b"state-1").unwrap();
        wal.append_begin(2).unwrap();
        wal.append_column(2, 7, &[6]).unwrap();
        wal.append_commit(2, b"").unwrap();
        // Batch 3 never commits: rolled back on recovery.
        wal.append_begin(3).unwrap();
        wal.append_column(3, 1, &[9, 9]).unwrap();
        assert!(wal.bytes_appended() > 0);
        drop(wal);

        let (wal, batches) = Wal::open(&path).unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].batch_id, 1);
        assert_eq!(batches[0].writes, vec![(7, vec![1, 2, 3]), (9, vec![4, 5])]);
        assert_eq!(batches[0].state, b"state-1");
        assert_eq!(batches[1].batch_id, 2);
        assert_eq!(batches[1].writes, vec![(7, vec![6])]);
        // The uncommitted batch-3 frames survive in the file (they are
        // intact frames, not torn), but are not replayed.
        assert!(wal.len() > 0);
    }

    #[test]
    fn recovery_wal_interleaved_batches_group_by_id() {
        // Pipelined executors interleave frames: Begin(2) before
        // Commit(1). Replay must group by batch_id, order by commit.
        let (_dir, path) = temp_wal("walint");
        let mut wal = Wal::create(&path).unwrap();
        wal.append_begin(1).unwrap();
        wal.append_column(1, 0, &[1]).unwrap();
        wal.append_begin(2).unwrap();
        wal.append_column(2, 5, &[2]).unwrap();
        wal.append_column(1, 3, &[3]).unwrap();
        wal.append_commit(1, b"a").unwrap();
        wal.append_commit(2, b"b").unwrap();
        drop(wal);
        let (_wal, batches) = Wal::open(&path).unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].batch_id, 1);
        assert_eq!(batches[0].writes, vec![(0, vec![1]), (3, vec![3])]);
        assert_eq!(batches[1].batch_id, 2);
        assert_eq!(batches[1].writes, vec![(5, vec![2])]);
    }

    #[test]
    fn recovery_wal_discards_garbage_tail_and_truncates() {
        let (_dir, path) = temp_wal("walgar");
        let mut wal = Wal::create(&path).unwrap();
        wal.append_begin(1).unwrap();
        wal.append_column(1, 2, &[8, 8, 8]).unwrap();
        wal.append_commit(1, b"").unwrap();
        let clean = wal.len();
        drop(wal);
        // A kill mid-append leaves arbitrary bytes at the tail.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xDE, 0xAD, 0xBE, 0xEF, 0x01, 0x02, 0x03]);
        std::fs::write(&path, &bytes).unwrap();

        let (wal, batches) = Wal::open(&path).unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(wal.len(), clean, "torn tail must be truncated away");
        assert_eq!(std::fs::metadata(&path).unwrap().len(), clean);
    }

    #[test]
    fn recovery_wal_every_truncation_point_yields_a_clean_prefix() {
        // Byte-exact torn-tail sweep: for EVERY possible kill point the
        // log must recover some prefix of the committed batches, never
        // error, never invent data.
        let (_dir, path) = temp_wal("walsweep");
        let mut wal = Wal::create(&path).unwrap();
        for b in 1..=3u64 {
            wal.append_begin(b).unwrap();
            wal.append_column(b, b as u32, &[b as u8; 5]).unwrap();
            wal.append_commit(b, &b.to_le_bytes()).unwrap();
        }
        drop(wal);
        let full = std::fs::read(&path).unwrap();
        for cut in 0..=full.len() {
            let (batches, valid) = parse(&full[..cut]);
            assert!(valid as usize <= cut);
            // Committed batches recovered in order, a prefix of 1..=3.
            let ids: Vec<u64> = batches.iter().map(|b| b.batch_id).collect();
            let expect: Vec<u64> = (1..=ids.len() as u64).collect();
            assert_eq!(ids, expect, "cut at {cut}");
            for b in &batches {
                assert_eq!(b.writes, vec![(b.batch_id as u32, vec![b.batch_id as u8; 5])]);
            }
        }
    }

    #[test]
    fn recovery_wal_corrupt_interior_frame_keeps_clean_prefix() {
        let (_dir, path) = temp_wal("walflip");
        let mut wal = Wal::create(&path).unwrap();
        wal.append_begin(1).unwrap();
        wal.append_commit(1, b"first").unwrap();
        let first_end = wal.len() as usize;
        wal.append_begin(2).unwrap();
        wal.append_commit(2, b"second").unwrap();
        drop(wal);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one payload byte inside the *second* batch's frames.
        bytes[first_end + FRAME_HEADER_BYTES] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let (_wal, batches) = Wal::open(&path).unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].state, b"first");
    }

    #[test]
    fn recovery_wal_reset_truncates_but_keeps_append_counter() {
        let (_dir, path) = temp_wal("walreset");
        let mut wal = Wal::create(&path).unwrap();
        wal.append_begin(1).unwrap();
        wal.append_commit(1, b"x").unwrap();
        let appended = wal.bytes_appended();
        assert!(appended > 0);
        wal.reset().unwrap();
        assert_eq!(wal.len(), 0);
        assert_eq!(wal.bytes_appended(), appended);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        // Appends continue cleanly after a reset.
        wal.append_begin(2).unwrap();
        wal.append_commit(2, b"y").unwrap();
        drop(wal);
        let (_wal, batches) = Wal::open(&path).unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].batch_id, 2);
    }
}
