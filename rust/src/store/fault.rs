//! Fault-injection I/O shim for crash-recovery tests (test/bench only —
//! no production path constructs one of these).
//!
//! Real kill-the-process crash tests are slow, flaky and hard to aim: the
//! interesting window (a half-appended WAL frame, an fsync that never
//! happened) is microseconds wide. [`FaultFile`] makes the window
//! deterministic by wrapping the backing file and misbehaving on cue:
//!
//! * [`FaultMode::ShortWrite`] — the Nth operation persists only a prefix
//!   of its buffer, then the "process" is dead: exactly the torn frame a
//!   power cut leaves.
//! * [`FaultMode::FailSync`] — writes land in the page cache but the Nth
//!   fsync reports failure (and the file is dead after), modeling a
//!   device error at the durability point.
//! * [`FaultMode::Kill`] — the Nth operation does nothing at all and every
//!   later one fails: a clean kill between ops.
//!
//! The shim implements [`crate::store::wal::WalBacking`], so recovery
//! tests drive the *real* WAL append/commit code over it and then reopen
//! the real file to assert what survived. See
//! `tests/recovery_equivalence.rs` and the `recovery_` unit tests.

use std::fs::{File, OpenOptions};
use std::io::{self, Write};
use std::path::Path;

use super::wal::WalBacking;

/// What goes wrong, once the op countdown reaches zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// The faulting write persists only the first `keep` bytes.
    ShortWrite { keep: usize },
    /// The faulting fsync fails (writes before it stay buffered).
    FailSync,
    /// The faulting operation is dropped entirely.
    Kill,
}

/// A backing file that dies on the Nth operation. Every operation after
/// the fault fails with `ErrorKind::Other("simulated crash")`, so code
/// under test cannot accidentally keep making progress past its death.
pub struct FaultFile {
    inner: File,
    mode: FaultMode,
    /// Operations (append/sync/truncate) left before the fault fires.
    ops_left: u64,
    dead: bool,
}

impl FaultFile {
    /// Wrap an already-open file.
    pub fn new(inner: File, mode: FaultMode, ops_before_fault: u64) -> Self {
        Self { inner, mode, ops_left: ops_before_fault, dead: false }
    }

    /// Create/truncate a file at `path` and wrap it.
    pub fn create(
        path: &Path,
        mode: FaultMode,
        ops_before_fault: u64,
    ) -> io::Result<Self> {
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)?;
        Ok(Self::new(file, mode, ops_before_fault))
    }

    /// Has the fault fired yet?
    pub fn crashed(&self) -> bool {
        self.dead
    }

    fn dead_err() -> io::Error {
        io::Error::other("simulated crash: file is dead")
    }

    /// Returns `true` if this op is the faulting one.
    fn tick(&mut self) -> io::Result<bool> {
        if self.dead {
            return Err(Self::dead_err());
        }
        if self.ops_left == 0 {
            self.dead = true;
            return Ok(true);
        }
        self.ops_left -= 1;
        Ok(false)
    }
}

impl WalBacking for FaultFile {
    fn append(&mut self, buf: &[u8]) -> io::Result<()> {
        if self.tick()? {
            return match self.mode {
                FaultMode::ShortWrite { keep } => {
                    let keep = keep.min(buf.len());
                    self.inner.write_all(&buf[..keep])?;
                    let _ = self.inner.sync_data();
                    Err(io::Error::other("simulated crash: short write"))
                }
                FaultMode::FailSync => {
                    // The fault is aimed at fsync; an append that draws
                    // the short straw just dies without writing.
                    Err(Self::dead_err())
                }
                FaultMode::Kill => Err(Self::dead_err()),
            };
        }
        self.inner.write_all(buf)
    }

    fn sync(&mut self) -> io::Result<()> {
        if self.tick()? {
            return Err(io::Error::other("simulated crash: fsync failed"));
        }
        self.inner.sync_data()
    }

    fn truncate(&mut self, len: u64) -> io::Result<()> {
        if self.tick()? {
            return Err(Self::dead_err());
        }
        self.inner.set_len(len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::wal::Wal;

    #[test]
    fn recovery_short_write_tears_exactly_one_frame() {
        let dir = crate::util::TempDir::new("fault");
        let path = dir.path().join("t.wal");
        // Ops per batch: begin(1 append) + column(1 append) + commit
        // (1 append + 1 sync) = 4. Let batch 1 complete (4 ops), then
        // tear the 5th op — batch 2's Begin frame — after 3 bytes.
        let shim = FaultFile::create(&path, FaultMode::ShortWrite { keep: 3 }, 4).unwrap();
        let mut wal = Wal::from_backing(Box::new(shim), 0);
        wal.append_begin(1).unwrap();
        wal.append_column(1, 4, &[1, 2, 3, 4]).unwrap();
        wal.append_commit(1, b"s1").unwrap();
        let err = wal.append_begin(2).unwrap_err();
        assert!(err.to_string().contains("short write"), "{err}");
        // Everything after the crash fails too.
        assert!(wal.append_commit(2, b"").is_err());
        drop(wal);

        let (wal2, batches) = Wal::open(&path).unwrap();
        assert_eq!(batches.len(), 1, "only the durably committed batch");
        assert_eq!(batches[0].batch_id, 1);
        assert_eq!(batches[0].writes, vec![(4, vec![1, 2, 3, 4])]);
        // The 3 torn bytes were discarded and truncated away.
        assert_eq!(std::fs::metadata(&path).unwrap().len(), wal2.len());
    }

    #[test]
    fn recovery_failed_fsync_surfaces_at_commit() {
        let dir = crate::util::TempDir::new("faultsync");
        let path = dir.path().join("t.wal");
        // Batch 1 completes (4 ops); batch 2's commit fsync (op index
        // 4+3=7, the 8th op) fails.
        let shim = FaultFile::create(&path, FaultMode::FailSync, 7).unwrap();
        let mut wal = Wal::from_backing(Box::new(shim), 0);
        wal.append_begin(1).unwrap();
        wal.append_column(1, 0, &[7]).unwrap();
        wal.append_commit(1, b"").unwrap();
        wal.append_begin(2).unwrap();
        wal.append_column(2, 1, &[8]).unwrap();
        let err = wal.append_commit(2, b"").unwrap_err();
        assert!(err.to_string().contains("fsync"), "{err}");
        // The caller must treat batch 2 as NOT committed even though the
        // frames may be present in the page cache: recovery semantics
        // are defined by what an fsync confirmed.
    }

    #[test]
    fn recovery_kill_between_ops_loses_nothing_committed() {
        let dir = crate::util::TempDir::new("faultkill");
        let path = dir.path().join("t.wal");
        let shim = FaultFile::create(&path, FaultMode::Kill, 4).unwrap();
        let mut wal = Wal::from_backing(Box::new(shim), 0);
        wal.append_begin(1).unwrap();
        wal.append_column(1, 2, &[5, 5]).unwrap();
        wal.append_commit(1, b"done").unwrap();
        assert!(wal.append_begin(2).is_err());
        drop(wal);
        let (_w, batches) = Wal::open(&path).unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].state, b"done");
    }
}
