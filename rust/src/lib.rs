//! # foem — Fast Online EM for Big Topic Modeling
//!
//! A production-grade reproduction of *"Fast Online EM for Big Topic
//! Modeling"* (Jia Zeng, Zhi-Qiang Liu, Xiao-Qin Cao; IEEE TKDE,
//! DOI 10.1109/TKDE.2015.2492565) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 3 (this crate)** — the paper's system contribution: the
//!   streaming coordinator, the residual-based **dynamic scheduler**
//!   ([`em::schedule`] policy + the slot-compressed **responsibility
//!   arena** and shared sweep kernel, [`em::resp`]), the disk-backed
//!   **parameter streaming** store
//!   ([`store`]), the online EM family (BEM / IEM / SEM / **FOEM**,
//!   [`em`]), the **parallel sharded E-step engine** ([`exec`]) that runs
//!   each minibatch across `n_workers` document shards with deterministic
//!   merges, the **pipelined parameter streaming** runner
//!   ([`exec::pipeline`]) that overlaps column prefetch and write-behind
//!   with compute, the **fold-in inference engine** ([`em::infer`]) that
//!   serves unseen-document inference through the same scheduled sparse
//!   kernel, the **snapshot-isolated serving layer** ([`serve`]) that
//!   batches live inference traffic against epoch-tagged model snapshots
//!   while training continues, the **runtime-dispatched SIMD E-step
//!   kernel** ([`em::simd`]: AVX2+FMA / portable tiers behind one
//!   `KernelBackend` knob, with the scalar tier as the bit-identity
//!   reference), five state-of-the-art online-LDA
//!   baselines ([`baselines`]), and the evaluation harness ([`eval`]).
//! * **Layer 2/1 (build time, `python/`)** — the dense minibatch EM
//!   graphs and the Pallas E-step kernels, AOT-lowered to HLO text and
//!   executed from Rust through PJRT ([`runtime`]). Python never runs on
//!   the hot path.
//!
//! ## Quick start
//!
//! ```no_run
//! use foem::corpus::synthetic::{SyntheticConfig, generate};
//! use foem::coordinator::config::RunConfig;
//! use foem::coordinator::driver::Driver;
//!
//! let corpus = generate(&SyntheticConfig::small(), 42);
//! let cfg = RunConfig { n_topics: 50, ..RunConfig::default() };
//! let mut driver = Driver::new(cfg);
//! let report = driver.train_corpus(&corpus).unwrap();
//! println!("perplexity = {:.1}", report.final_perplexity);
//! ```
//!
//! See `examples/` for runnable end-to-end drivers and `rust/DESIGN.md`
//! for the architecture notes and the experiment-by-experiment map back
//! to the paper.

pub mod baselines;
pub mod coordinator;
pub mod corpus;
pub mod em;
pub mod eval;
pub mod exec;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod store;
pub mod stream;
pub mod util;

/// LDA model hyperparameters shared across every algorithm in the crate.
///
/// The paper's EM family works with the MAP parameterization: the E-step
/// (Eq. 11) uses `alpha - 1` and `beta - 1`, and experiments set
/// `alpha - 1 = beta - 1 = 0.01` (§4). VB-family baselines use `alpha`,
/// `beta` directly (footnote 9 recommends 0.5 for those).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LdaParams {
    /// Number of topics K.
    pub n_topics: usize,
    /// Dirichlet hyperparameter on document-topic distributions.
    pub alpha: f32,
    /// Dirichlet hyperparameter on topic-word distributions.
    pub beta: f32,
}

impl LdaParams {
    /// Paper defaults: `alpha - 1 = beta - 1 = 0.01` (§4).
    pub fn paper_defaults(n_topics: usize) -> Self {
        Self { n_topics, alpha: 1.01, beta: 1.01 }
    }

    /// `alpha - 1`, the numerator offset of Eq. 11.
    #[inline]
    pub fn am1(&self) -> f32 {
        self.alpha - 1.0
    }

    /// `beta - 1`, the numerator offset of Eq. 11.
    #[inline]
    pub fn bm1(&self) -> f32 {
        self.beta - 1.0
    }

    /// `W * (beta - 1)`, the denominator offset of Eq. 11 for vocabulary
    /// size `w`.
    #[inline]
    pub fn wbm1(&self, w: usize) -> f32 {
        w as f32 * self.bm1()
    }
}
