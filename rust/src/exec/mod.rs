//! Parallel sharded minibatch execution — the multi-worker E-step engine.
//!
//! The paper's FOEM processes each minibatch serially; its own complexity
//! argument (Table 3) and the production north star demand multi-core
//! execution. Document-sharded parallel LDA with periodic
//! sufficient-statistics merges preserves model quality (Yan et al.,
//! *Towards Big Topic Modeling*), and the stochastic-approximation frame
//! of Cappé & Moulines' online EM is indifferent to whether a minibatch's
//! statistics were gathered by one sweep or by `P` merged shard sweeps.
//! This module is the seam where that parallelism lives:
//!
//! 1. [`crate::stream::Minibatch::shard`] splits an incoming minibatch
//!    into `P` contiguous document shards, each keeping the vocab-major
//!    layout over its own documents;
//! 2. the store layer materializes a read-only
//!    [`crate::store::PhiSnapshot`] of the minibatch's local columns
//!    (one sequential read per column), shared by all workers —
//!    `InMemoryPhi` and `PagedPhi` alike serve concurrent readers this
//!    way without locking;
//! 3. [`ParallelExecutor::run_sharded`] runs one worker per shard on
//!    scoped `std::thread`s; each fills a private [`crate::em::SsDelta`];
//! 4. [`ParallelExecutor::reduce`] merges the per-shard deltas in fixed
//!    shard order, and the trainer applies the result to the global
//!    stores — so results are reproducible for a given seed and `P`.
//!
//! `P = 1` bypasses the engine entirely: the trainers keep their serial
//! paths, bit-identical to the pre-engine code (same numerics, same
//! [`crate::store::IoStats`]). See `rust/DESIGN.md` §6 for the full
//! architecture and the equivalence argument.
//!
//! The [`pipeline`] submodule layers a depth-`d` software pipeline on top
//! of this engine: batches are staged (snapshot + shard), computed on
//! background threads, and applied in strict batch order, overlapping
//! parameter I/O with compute (`rust/DESIGN.md` §7).

pub mod pipeline;

use crate::em::SsDelta;
use crate::stream::{Minibatch, MinibatchShard};

pub mod scratch {
    //! Grow-only worker scratch recycling for the shard kernels.
    //!
    //! The serial trainer paths recycle their big per-minibatch buffers
    //! through `&mut self` fields; shard workers can't — they run as
    //! scoped threads inside an associated `compute` function with no
    //! trainer to hang state off. This process-wide pool restores the
    //! grow-only discipline: a worker checks a [`WorkerScratch`] out at
    //! shard entry and returns it at exit, so steady-state minibatches
    //! allocate nothing on the shard path either. Buffers are fully
    //! re-initialized on reuse (`RespArena::reset`, clear + refill), so
    //! which worker gets which buffer never reaches the numerics.

    use crate::em::resp::{RespArena, SweepKernel};
    use std::sync::Mutex;

    /// One worker's reusable buffers. Field roles by kernel:
    /// FOEM shard — `col_a` = private phi columns, `col_b` = private
    /// residual columns, `idx` = sweep order; SEM shard — `col_a` =
    /// frozen-phi copies, `theta`/`col_b` = the doc-topic double buffer,
    /// `idx` = entry→slot map.
    #[derive(Debug, Default)]
    pub struct WorkerScratch {
        pub arena: RespArena,
        pub kern: SweepKernel,
        pub theta: Vec<f32>,
        pub col_a: Vec<f32>,
        pub col_b: Vec<f32>,
        pub idx: Vec<u32>,
    }

    /// Upper bound on pooled bundles/buffers: enough for any sane
    /// worker × pipeline-depth product, small enough that a burst can't
    /// pin unbounded memory.
    const POOL_MAX: usize = 64;

    static POOL: Mutex<Vec<WorkerScratch>> = Mutex::new(Vec::new());
    static F32_POOL: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());

    /// Check a scratch bundle out (empty bundle if the pool is dry).
    pub fn take() -> WorkerScratch {
        POOL.lock().ok().and_then(|mut p| p.pop()).unwrap_or_default()
    }

    /// Return a bundle for reuse.
    pub fn put(s: WorkerScratch) {
        if let Ok(mut p) = POOL.lock() {
            if p.len() < POOL_MAX {
                p.push(s);
            }
        }
    }

    /// Check a loose `f32` buffer out — for buffers that outlive the
    /// bundle (e.g. the FOEM shard theta, which travels in the shard
    /// result until the apply phase's exact-LL pass is done).
    pub fn take_f32() -> Vec<f32> {
        F32_POOL.lock().ok().and_then(|mut p| p.pop()).unwrap_or_default()
    }

    /// Return a loose buffer for reuse.
    pub fn put_f32(mut v: Vec<f32>) {
        v.clear();
        if let Ok(mut p) = F32_POOL.lock() {
            if p.len() < POOL_MAX {
                p.push(v);
            }
        }
    }
}

/// The parallel minibatch executor: worker-count policy plus the fan-out
/// and deterministic-reduce primitives every parallel trainer routes
/// through.
#[derive(Debug, Clone, Copy)]
pub struct ParallelExecutor {
    n_workers: usize,
}

impl ParallelExecutor {
    pub fn new(n_workers: usize) -> Self {
        Self { n_workers: n_workers.max(1) }
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Shard a minibatch for this executor: at most `n_workers` contiguous
    /// document shards (see [`Minibatch::shard`]).
    pub fn shard(&self, mb: &Minibatch) -> Vec<MinibatchShard> {
        mb.shard(self.n_workers)
    }

    /// Run `worker` once per shard. A single shard runs inline on the
    /// calling thread; otherwise each shard gets a scoped OS thread.
    /// Results come back indexed in shard order regardless of completion
    /// order — the precondition for a deterministic reduce.
    pub fn run_sharded<T, F>(&self, shards: &[MinibatchShard], worker: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&MinibatchShard) -> T + Sync,
    {
        if shards.len() <= 1 {
            return shards.iter().map(|s| worker(s)).collect();
        }
        std::thread::scope(|scope| {
            let worker = &worker;
            let handles: Vec<_> = shards
                .iter()
                .map(|shard| scope.spawn(move || worker(shard)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("E-step shard worker panicked"))
                .collect()
        })
    }

    /// Split `[0, n_items)` into at most `n_workers` contiguous ranges —
    /// the same even-split rule as [`Minibatch::shard`], for workloads
    /// that shard by plain index ranges instead of minibatch structure
    /// (the fold-in engine's document sharding, `em::infer`).
    pub fn partition(&self, n_items: usize) -> Vec<std::ops::Range<usize>> {
        let p = self.n_workers.clamp(1, n_items.max(1));
        let mut out = Vec::with_capacity(p);
        let mut start = 0usize;
        for i in 0..p {
            let remaining = p - i;
            let take = (n_items - start).div_ceil(remaining);
            out.push(start..start + take);
            start += take;
            if start >= n_items {
                break;
            }
        }
        out
    }

    /// Run `worker(shard_index, range)` once per [`Self::partition`]
    /// range. A single range runs inline on the calling thread (the exact
    /// serial path); otherwise each range gets a scoped OS thread.
    /// Results come back in range order regardless of completion order.
    pub fn run_ranged<T, F>(&self, n_items: usize, worker: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, std::ops::Range<usize>) -> T + Sync,
    {
        let ranges = self.partition(n_items);
        if ranges.len() <= 1 {
            return ranges
                .into_iter()
                .enumerate()
                .map(|(i, r)| worker(i, r))
                .collect();
        }
        std::thread::scope(|scope| {
            let worker = &worker;
            let handles: Vec<_> = ranges
                .into_iter()
                .enumerate()
                .map(|(i, r)| scope.spawn(move || worker(i, r)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("ranged worker panicked"))
                .collect()
        })
    }

    /// Deterministic reduction: merge per-shard deltas, in the order the
    /// iterator yields them (callers pass shard order), into a fresh
    /// accumulator over `words` (the minibatch's local vocabulary).
    pub fn reduce<'a, I>(&self, k: usize, words: &[u32], deltas: I) -> SsDelta
    where
        I: IntoIterator<Item = &'a SsDelta>,
    {
        let mut acc = SsDelta::zeros(k, words.to_vec());
        for d in deltas {
            acc.merge(d);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::stream::{CorpusStream, StreamConfig};

    fn minibatch() -> Minibatch {
        let c = generate(&SyntheticConfig::small(), 3);
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        CorpusStream::new(&c, scfg).next().unwrap()
    }

    #[test]
    fn run_sharded_returns_results_in_shard_order() {
        let mb = minibatch();
        let exec = ParallelExecutor::new(4);
        let shards = exec.shard(&mb);
        assert!(shards.len() >= 2);
        let idx: Vec<usize> = exec.run_sharded(&shards, |s| s.shard_index);
        assert_eq!(idx, (0..shards.len()).collect::<Vec<_>>());
    }

    #[test]
    fn run_sharded_uses_worker_threads() {
        let mb = minibatch();
        let exec = ParallelExecutor::new(4);
        let shards = exec.shard(&mb);
        let main_id = std::thread::current().id();
        let ids = exec.run_sharded(&shards, |_| std::thread::current().id());
        assert_eq!(ids.len(), shards.len());
        assert!(ids.iter().all(|&id| id != main_id));
    }

    #[test]
    fn single_shard_runs_inline() {
        let mb = minibatch();
        let exec = ParallelExecutor::new(1);
        let shards = exec.shard(&mb);
        assert_eq!(shards.len(), 1);
        let main_id = std::thread::current().id();
        let ids = exec.run_sharded(&shards, |_| std::thread::current().id());
        assert_eq!(ids, vec![main_id]);
    }

    #[test]
    fn reduce_merges_in_order_over_minibatch_vocab() {
        let words = vec![1u32, 3, 5];
        let mut a = SsDelta::zeros(2, vec![1u32, 3]);
        a.add_at(0, 0, 1.0);
        a.add_at(1, 1, 2.0);
        let mut b = SsDelta::zeros(2, vec![3u32, 5]);
        b.add_at(0, 1, 4.0);
        b.add_at(1, 0, 8.0);
        let exec = ParallelExecutor::new(2);
        let acc = exec.reduce(2, &words, [&a, &b]);
        assert_eq!(acc.col(0), &[1.0, 0.0]);
        assert_eq!(acc.col(1), &[0.0, 6.0]);
        assert_eq!(acc.col(2), &[8.0, 0.0]);
        assert_eq!(acc.phisum, vec![9.0, 6.0]);
    }

    #[test]
    fn worker_count_is_clamped() {
        assert_eq!(ParallelExecutor::new(0).n_workers(), 1);
        assert_eq!(ParallelExecutor::new(8).n_workers(), 8);
    }

    #[test]
    fn partition_covers_range_evenly() {
        let exec = ParallelExecutor::new(4);
        let ranges = exec.partition(10);
        assert_eq!(ranges.len(), 4);
        // Contiguous, exhaustive, near-even.
        let mut cursor = 0usize;
        for r in &ranges {
            assert_eq!(r.start, cursor);
            assert!(r.len() == 2 || r.len() == 3);
            cursor = r.end;
        }
        assert_eq!(cursor, 10);
        // Fewer items than workers: one range per item.
        assert_eq!(exec.partition(2).len(), 2);
        assert_eq!(exec.partition(2), vec![0..1, 1..2]);
        // Empty input degrades to one empty range.
        assert_eq!(exec.partition(0), vec![0..0]);
        // Serial executor returns the identity range.
        assert_eq!(ParallelExecutor::new(1).partition(7), vec![0..7]);
    }

    #[test]
    fn run_ranged_returns_in_range_order_and_parallelizes() {
        let exec = ParallelExecutor::new(3);
        let out = exec.run_ranged(9, |i, r| (i, r.start, r.end));
        assert_eq!(out, vec![(0, 0, 3), (1, 3, 6), (2, 6, 9)]);
        let main_id = std::thread::current().id();
        let ids = exec.run_ranged(9, |_, _| std::thread::current().id());
        assert!(ids.iter().all(|&id| id != main_id));
        // Single range runs inline.
        let ids = ParallelExecutor::new(1)
            .run_ranged(9, |_, _| std::thread::current().id());
        assert_eq!(ids, vec![main_id]);
    }
}
