//! Pipelined parameter streaming: a depth-`d` software pipeline over the
//! minibatch stream (`rust/DESIGN.md` §7).
//!
//! The synchronous trainer alternates store I/O and compute on one
//! thread: snapshot batch `t`'s columns, sweep, write back, repeat — so a
//! paged [`crate::store::paged::PagedPhi`] run pays full disk latency on
//! the hot path even though batch `t+1`'s column set is known while batch
//! `t` computes. This module overlaps the two, the pipelined
//! communication/computation discipline of Yan et al. (*Towards Big Topic
//! Modeling*), without changing what any single batch computes:
//!
//! 1. the trainer is split into the three-phase [`PhasedTrainer`] seam —
//!    `stage` (store reads → self-contained [`PhasedTrainer::Staged`]),
//!    `compute` (pure, store-free, runs on a worker thread), `apply`
//!    (store writes, **strict batch order**);
//! 2. [`Pipeline::run`] keeps up to `depth` batches in flight: while
//!    batch `t` computes in the background, the coordinator thread
//!    applies finished batches and stages the next ones;
//! 3. a [`crate::stream::Lookahead`] window feeds upcoming batches'
//!    vocabularies to [`PhasedTrainer::prefetch`], so a store in
//!    background-I/O mode ([`crate::store::PhiColumnStore::set_async_io`])
//!    loads batch `t+1`'s columns while batch `t` computes, and flushes
//!    batch `t-1`'s dirty columns behind the same thread.
//!
//! **Determinism / equivalence.** `depth = 0` bypasses the pipeline
//! entirely ([`PhasedTrainer::process_direct`]) and is bit-identical to
//! the plain trainer loop — numerics *and* `IoStats` — extending the
//! `n_workers = 1` invariant of the parallel executor. For `depth >= 1`,
//! applies happen in strict batch order at fixed points of the loop, and
//! every RNG draw happens in `stage` (batch order), so a run is exactly
//! reproducible for a given `(seed, n_workers, depth)`. What changes
//! versus depth 0 is only *staleness*: a batch is staged against the
//! store state with up to `depth` applies still pending, the usual
//! stochastic-approximation trade (Cappé & Moulines' online EM is
//! indifferent to when statistics are staged as long as the update order
//! is preserved) — perplexity parity is asserted in
//! `tests/pipeline_equivalence.rs`.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::em::MinibatchReport;
use crate::stream::{Lookahead, Minibatch};

/// The three-phase trainer contract the pipeline drives.
///
/// The phases split `process_minibatch` at its natural I/O boundaries:
///
/// * [`stage`](Self::stage) — everything that touches the stores or the
///   trainer's RNG: frame shards, materialize column snapshots, draw
///   per-shard seeds. Returns a self-contained `Staged` bundle.
/// * [`compute`](Self::compute) — the E-step sweeps, pure over `Staged`
///   (no `self`): safe to run on a background thread while the trainer
///   stages/applies other batches.
/// * [`apply`](Self::apply) — merge the computed delta into the global
///   stores and scheduler state. The pipeline calls this in strict batch
///   order.
///
/// # Examples
///
/// A minimal phased trainer whose whole "model" is the token mass it has
/// absorbed — `stage` snapshots the batch, `compute` is pure, `apply`
/// merges — driven through a depth-2 pipeline:
///
/// ```
/// use foem::corpus::sparse::DocWordMatrix;
/// use foem::em::MinibatchReport;
/// use foem::exec::pipeline::{PhasedTrainer, Pipeline};
/// use foem::stream::Minibatch;
///
/// struct MassTrainer {
///     total: f64,
/// }
///
/// impl PhasedTrainer for MassTrainer {
///     type Staged = DocWordMatrix;
///     type Delta = f64;
///
///     fn stage(&mut self, mb: &Minibatch) -> DocWordMatrix {
///         mb.docs.clone()
///     }
///
///     fn compute(staged: &DocWordMatrix) -> f64 {
///         staged.total_tokens()
///     }
///
///     fn apply(&mut self, _s: &DocWordMatrix, d: f64) -> MinibatchReport {
///         self.total += d;
///         MinibatchReport { tokens: d, ..Default::default() }
///     }
///
///     fn process_direct(&mut self, mb: &Minibatch) -> MinibatchReport {
///         let staged = self.stage(mb);
///         let delta = Self::compute(&staged);
///         self.apply(&staged, delta)
///     }
/// }
///
/// let batches: Vec<Minibatch> = (0..4)
///     .map(|i| {
///         let row: &[(u32, f32)] = &[(0, 1.0 + i as f32)];
///         Minibatch::new(i + 1, DocWordMatrix::from_rows(1, &[row]))
///     })
///     .collect();
///
/// // Depth 2: up to two batches in flight; applies stay in batch order.
/// let mut trainer = MassTrainer { total: 0.0 };
/// Pipeline::new(2)
///     .run(&mut trainer, batches.clone().into_iter(), |_, _, _| Ok(()))
///     .unwrap();
/// assert_eq!(trainer.total, 1.0 + 2.0 + 3.0 + 4.0);
///
/// // Depth 0 bypasses the pipeline (`process_direct`) — same result.
/// let mut serial = MassTrainer { total: 0.0 };
/// Pipeline::new(0)
///     .run(&mut serial, batches.into_iter(), |_, _, _| Ok(()))
///     .unwrap();
/// assert_eq!(serial.total, trainer.total);
/// ```
pub trait PhasedTrainer {
    /// Self-contained staged batch (snapshots + shards + seeds).
    type Staged: Send + Sync + 'static;
    /// The computed sufficient-statistics delta.
    type Delta: Send + 'static;

    /// Phase 1: store reads + RNG draws; no global mutation visible to
    /// `compute`.
    fn stage(&mut self, mb: &Minibatch) -> Self::Staged;

    /// Phase 2: pure compute over the staged batch (associated function —
    /// no `self`, so it can run while the trainer is busy elsewhere).
    fn compute(staged: &Self::Staged) -> Self::Delta;

    /// Phase 3: merge into the global state; strict batch order.
    fn apply(&mut self, staged: &Self::Staged, delta: Self::Delta) -> MinibatchReport;

    /// The trainer's plain (non-pipelined) path — what `depth = 0` runs.
    /// Must be the exact `process_minibatch` dispatch so the bypass is
    /// bit-identical to a hand-written loop.
    fn process_direct(&mut self, mb: &Minibatch) -> MinibatchReport;

    /// Hint that `mb` will be staged soon (forwarded to the stores'
    /// background prefetchers). Default: no-op.
    fn prefetch(&mut self, _mb: &Minibatch) {}

    /// Called once before a pipelined run — e.g. switch stores into
    /// background-I/O mode. Default: no-op.
    fn begin_pipeline(&mut self) {}

    /// Called once after a pipelined run (also on error) — e.g. drain
    /// write-behind buffers and stop I/O threads. Default: no-op.
    fn end_pipeline(&mut self) {}
}

/// One batch in flight: its staged bundle (shared with the compute
/// worker) and the worker's join handle.
struct InFlight<T: PhasedTrainer> {
    staged: Arc<T::Staged>,
    handle: std::thread::JoinHandle<T::Delta>,
}

/// The depth-`d` software pipeline runner.
#[derive(Debug, Clone, Copy)]
pub struct Pipeline {
    depth: usize,
}

impl Pipeline {
    /// `depth` = maximum batches in flight past the apply cursor; `0`
    /// bypasses the pipeline entirely (bit-identical serial execution).
    pub fn new(depth: usize) -> Self {
        Self { depth }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Drive `trainer` over `stream`. `sink` runs after every apply, in
    /// batch order, with the trainer quiescent (no outstanding borrow of
    /// its stores) — the coordinator hooks evaluation, checkpointing and
    /// metrics here.
    pub fn run<T, I, F>(
        &self,
        trainer: &mut T,
        stream: I,
        mut sink: F,
    ) -> anyhow::Result<()>
    where
        T: PhasedTrainer,
        I: Iterator<Item = Minibatch>,
        F: FnMut(&mut T, usize, &MinibatchReport) -> anyhow::Result<()>,
    {
        if self.depth == 0 {
            for (i, mb) in stream.enumerate() {
                let report = trainer.process_direct(&mb);
                sink(trainer, i + 1, &report)?;
            }
            return Ok(());
        }
        trainer.begin_pipeline();
        let result = self.run_pipelined(trainer, stream, &mut sink);
        trainer.end_pipeline();
        result
    }

    fn run_pipelined<T, I, F>(
        &self,
        trainer: &mut T,
        stream: I,
        sink: &mut F,
    ) -> anyhow::Result<()>
    where
        T: PhasedTrainer,
        I: Iterator<Item = Minibatch>,
        F: FnMut(&mut T, usize, &MinibatchReport) -> anyhow::Result<()>,
    {
        let mut look = Lookahead::new(stream, self.depth);
        let mut inflight: VecDeque<InFlight<T>> = VecDeque::new();
        let mut batch_no = 0usize;
        // Captured as a plain fn pointer so the spawned closure's type
        // involves only `T::Staged`/`T::Delta` (both `'static` by the
        // trait bounds), not `T` itself — the trainer may borrow.
        let compute: fn(&T::Staged) -> T::Delta = T::compute;
        let mut retire = |trainer: &mut T,
                          inflight: &mut VecDeque<InFlight<T>>,
                          batch_no: &mut usize|
         -> anyhow::Result<()> {
            let InFlight { staged, handle } =
                inflight.pop_front().expect("in-flight batch");
            let delta = handle
                .join()
                .map_err(|_| anyhow::anyhow!("pipeline compute worker panicked"))?;
            *batch_no += 1;
            let report = trainer.apply(&staged, delta);
            sink(trainer, *batch_no, &report)
        };
        let mut failure: Option<anyhow::Error> = None;
        while let Some(mb) = look.next() {
            // Stage this batch (store reads happen here, overlapped with
            // the in-flight computes), then hand the sweep to a worker.
            let staged = Arc::new(trainer.stage(&mb));
            // Queue prefetches for the lookahead window AFTER staging, so
            // the stage-time reads are not stuck behind them in the I/O
            // thread's queue.
            for i in 0..self.depth {
                if let Some(upcoming) = look.peek(i) {
                    trainer.prefetch(upcoming);
                }
            }
            let worker = Arc::clone(&staged);
            let handle = std::thread::spawn(move || compute(&worker));
            inflight.push_back(InFlight { staged, handle });
            // Keep at most `depth` batches in flight: retire (apply) the
            // oldest once the window is full — strict batch order.
            if inflight.len() > self.depth {
                if let Err(e) = retire(trainer, &mut inflight, &mut batch_no) {
                    failure = Some(e);
                    break;
                }
            }
        }
        while !inflight.is_empty() {
            if failure.is_some() {
                // A sink/apply error already stopped the run: applying
                // further batches would break strict order, but the
                // workers must still be joined so no compute thread (and
                // its staged snapshots) outlives the pipeline.
                let InFlight { handle, .. } =
                    inflight.pop_front().expect("checked non-empty");
                let _ = handle.join();
                continue;
            }
            if let Err(e) = retire(trainer, &mut inflight, &mut batch_no) {
                failure = Some(e);
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}
