//! Stepwise EM for LDA (paper Fig. 3).
//!
//! The stochastic-approximation combination of BEM with minibatch
//! streams: for each minibatch `x^s`, run the BEM inner loop (E-step +
//! local-theta M-step, global phi frozen) until the training-perplexity
//! delta converges, then blend the minibatch's sufficient statistics into
//! the global topic-word matrix with the Robbins-Monro learning rate
//! (Eqs. 18, 20):
//!
//!   rho_s = (tau0 + s)^-kappa,
//!   phi^s = (1 - rho_s) phi^{s-1} + rho_s * S * sum_d x^s mu^s.
//!
//! SCVB (Foulds et al.) is equivalent to this algorithm (§2.5); the
//! `baselines::scvb` wrapper reuses this core with its own defaults.

use super::resp::RespArena;
use super::{
    perplexity, ConvergenceCheck, MinibatchReport, PhiStats, SsDelta,
    ThetaStats,
};
use crate::exec::ParallelExecutor;
use crate::stream::{Minibatch, MinibatchShard};
use crate::util::{Rng, Timer};
use crate::LdaParams;

/// Learning-rate schedule (Eq. 18).
#[derive(Debug, Clone, Copy)]
pub struct LearningRate {
    pub tau0: f64,
    pub kappa: f64,
}

impl LearningRate {
    /// The paper's comparison defaults (tau0=1024, kappa=0.5, §4).
    pub fn paper() -> Self {
        Self { tau0: 1024.0, kappa: 0.5 }
    }

    #[inline]
    pub fn rho(&self, s: usize) -> f64 {
        (self.tau0 + s as f64).powf(-self.kappa)
    }
}

/// Configuration of the SEM trainer.
#[derive(Debug, Clone, Copy)]
pub struct SemConfig {
    pub rate: LearningRate,
    /// Scaling coefficient `S = D / D_s` (Eq. 20). Online algorithms must
    /// be told the (estimated) stream length; the paper notes one may
    /// "predefine a fixed large number" for endless streams.
    pub scale_s: f64,
    /// Inner-loop convergence: perplexity delta threshold.
    pub threshold: f64,
    /// Inner-loop convergence: check cadence in sweeps.
    pub check_every: usize,
    /// Inner-loop sweep budget per minibatch.
    pub max_inner_iters: usize,
    /// E-step worker threads ([`crate::exec`]): the minibatch's documents
    /// are sharded across this many scoped threads, each running the
    /// inner BEM loop against the frozen global phi, and the per-shard
    /// sufficient statistics are folded in with a fixed merge order.
    /// `1` = the exact serial path.
    pub n_workers: usize,
    /// E-step kernel backend ([`crate::em::simd::KernelBackend`]):
    /// `Scalar` is the bit-identity reference; the SIMD tiers are
    /// tolerance-class equivalents.
    pub kernel_backend: crate::em::simd::KernelBackend,
}

impl SemConfig {
    pub fn paper(scale_s: f64) -> Self {
        Self {
            rate: LearningRate::paper(),
            scale_s,
            threshold: 10.0,
            check_every: 1,
            max_inner_iters: 100,
            n_workers: 1,
            kernel_backend: crate::em::simd::KernelBackend::Scalar,
        }
    }
}

/// Stepwise EM trainer.
pub struct Sem {
    pub params: LdaParams,
    pub cfg: SemConfig,
    pub phi: PhiStats,
    /// Minibatches processed so far (the paper's `s`).
    pub step: usize,
    rng: Rng,
    /// Whether a staged batch has already claimed the cold-start
    /// bootstrap. Under pipelining several batches are staged before the
    /// first apply lands, so `phi.total_mass() == 0` alone would make
    /// each of them re-seed the global stats; only the first may.
    boot_staged: bool,
    /// Grow-only scratch reused across minibatches: the dense-layout
    /// responsibility arena (SEM recomputes every entry over all K, so
    /// its responsibilities are inherently dense) and the theta double
    /// buffer — avoids the historical nnz×K + per-sweep allocations.
    resp_scratch: RespArena,
    theta_scratch: Vec<f32>,
    theta_new_scratch: Vec<f32>,
}

impl Sem {
    pub fn new(params: LdaParams, n_words: usize, cfg: SemConfig, seed: u64) -> Self {
        Self {
            phi: PhiStats::zeros(params.n_topics, n_words),
            params,
            cfg,
            step: 0,
            rng: Rng::new(seed),
            boot_staged: false,
            resp_scratch: RespArena::new(),
            theta_scratch: Vec::new(),
            theta_new_scratch: Vec::new(),
        }
    }

    /// Run the Fig. 3 inner loop on one minibatch and fold the result into
    /// the global phi.
    ///
    /// With `cfg.n_workers == 1` this is the serial Fig. 3 algorithm;
    /// otherwise the inner loop runs document-sharded on the parallel
    /// executor (the global phi is frozen during the loop, so shards are
    /// independent; see [`crate::exec`]).
    pub fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport {
        if self.cfg.n_workers <= 1 {
            self.process_minibatch_serial(mb)
        } else {
            self.process_minibatch_parallel(mb)
        }
    }

    /// The serial Fig. 3 path — exposed so the equivalence tests can pin
    /// `process_minibatch(n_workers = 1)` against it bit-for-bit.
    pub fn process_minibatch_serial(&mut self, mb: &Minibatch) -> MinibatchReport {
        let timer = Timer::start();
        let k = self.params.n_topics;
        let w_dim = self.phi.n_words;
        let docs = &mb.docs;
        let tokens = docs.total_tokens();
        self.step += 1;

        // Local init (Fig. 3 line 2): random hard assignments -> theta.
        // SEM recomputes every entry's responsibility over all K topics
        // per sweep (Eq. 11), so the arena runs in its dense layout —
        // the historical nnz×K buffer, now grow-only reused across
        // minibatches instead of re-allocated from scratch.
        let mut theta = ThetaStats::from_buffer(
            k,
            docs.n_docs,
            std::mem::take(&mut self.theta_scratch),
        );
        let nnz = docs.nnz();
        let mut mu = std::mem::take(&mut self.resp_scratch);
        mu.reset(k, nnz, k);
        let bootstrap = self.phi.total_mass() == 0.0;
        {
            let mut e = 0usize;
            for d in 0..docs.n_docs {
                for (w, c) in docs.iter_doc(d) {
                    let topic = self.rng.below(k);
                    mu.set_one_hot(e, topic);
                    theta.doc_mut(d)[topic] += c;
                    if bootstrap {
                        // Cold start (phi_hat^0 == 0): seed the global
                        // stats from the same random assignments so the
                        // first inner loop sees word-differentiated
                        // topics — the paper's "same random
                        // initializations" (§4). Decayed away by the
                        // Eq. 20 updates.
                        let (col, phisum) =
                            self.phi.word_and_sum_mut(w as usize);
                        col[topic] += c;
                        phisum[topic] += c;
                    }
                    e += 1;
                }
            }
        }

        // Inner BEM on theta with phi^{s-1} frozen (lines 4-8).
        let am1 = self.params.am1();
        let bm1 = self.params.bm1();
        let wbm1 = self.params.wbm1(w_dim);
        // Resolve the kernel tier once per minibatch, not per token.
        let isa = self.cfg.kernel_backend.resolve();
        let mut check =
            ConvergenceCheck::new(self.cfg.threshold, self.cfg.check_every,
                                  self.cfg.max_inner_iters);
        let mut iters = 0usize;
        let mut last_ll = f64::NEG_INFINITY;
        let kam1 = k as f32 * am1;
        // Double-buffered doc-topic stats: zero + swap per sweep instead
        // of a fresh allocation per sweep.
        let mut theta_new = ThetaStats::from_buffer(
            k,
            docs.n_docs,
            std::mem::take(&mut self.theta_new_scratch),
        );
        for t in 0..self.cfg.max_inner_iters {
            let mut ll = 0.0f64;
            let mut e = 0usize;
            theta_new.fill_zero();
            for d in 0..docs.n_docs {
                let theta_d = theta.doc(d);
                let doc_norm =
                    ((docs.doc_len(d) + kam1) as f64).max(1e-300).ln();
                for (w, c) in docs.iter_doc(d) {
                    let w = w as usize;
                    let mu_row = mu.lane_dense_mut(e);
                    let z = super::estep_unnormalized_isa(
                        isa,
                        theta_d,
                        self.phi.word(w),
                        &self.phi.phisum,
                        am1,
                        bm1,
                        wbm1,
                        mu_row,
                    );
                    if z > 0.0 {
                        let inv = 1.0 / z;
                        mu_row.iter_mut().for_each(|m| *m *= inv);
                    }
                    ll += c as f64
                        * (((z as f64).max(1e-300)).ln() - doc_norm);
                    let trow = theta_new.doc_mut(d);
                    let mu_row = mu.lane_dense(e);
                    for i in 0..k {
                        trow[i] += c * mu_row[i];
                    }
                    e += 1;
                }
            }
            std::mem::swap(&mut theta, &mut theta_new);
            last_ll = ll;
            iters = t + 1;
            if check.update(t, perplexity(ll, tokens)) {
                break;
            }
        }

        // Global update (line 10, Eq. 20).
        let rho = self.cfg.rate.rho(self.step) as f32;
        let scale = (self.cfg.scale_s as f32) * rho;
        // Decay the whole matrix, then scatter the minibatch stats.
        self.phi.raw_mut().iter_mut().for_each(|x| *x *= 1.0 - rho);
        self.phi.phisum.iter_mut().for_each(|x| *x *= 1.0 - rho);
        let mut e = 0usize;
        for d in 0..docs.n_docs {
            for (w, c) in docs.iter_doc(d) {
                let mu_row = mu.lane_dense(e);
                let (col, phisum) = self.phi.word_and_sum_mut(w as usize);
                for i in 0..k {
                    let v = scale * c * mu_row[i];
                    col[i] += v;
                    phisum[i] += v;
                }
                e += 1;
            }
        }

        let resp_bytes = mu.bytes();
        let scratch_bytes = (theta.raw().len() + theta_new.raw().len()) * 4;
        // Hand the scratch buffers back for the next minibatch.
        self.resp_scratch = mu;
        self.theta_scratch = theta.into_buffer();
        self.theta_new_scratch = theta_new.into_buffer();

        MinibatchReport {
            inner_iters: iters,
            seconds: timer.seconds(),
            train_ll: last_ll,
            tokens,
            resp_bytes,
            scratch_bytes,
        }
    }

    /// Document-sharded parallel path: one stage → compute → apply round
    /// trip of the three-phase trainer seam (the same phases the software
    /// pipeline [`crate::exec::pipeline`] overlaps across batches). The
    /// Fig. 3 inner loop freezes the global phi, so shards only couple
    /// through their private theta — workers read a staged column
    /// snapshot, and the Eq. 20 fold-in scatters the per-shard
    /// [`SsDelta`]s in fixed shard order. The scattered mass is
    /// `scale * tokens` regardless of how responsibilities distribute, so
    /// the global mass trajectory matches the serial path exactly.
    fn process_minibatch_parallel(&mut self, mb: &Minibatch) -> MinibatchReport {
        let staged = self.stage_batch(mb);
        let delta = Self::compute_batch(&staged);
        self.apply_batch(&staged, delta)
    }

    /// Phase 1 (stage): step accounting, sharding, per-shard RNG streams
    /// (drawn in shard order), and a read-only snapshot of the minibatch's
    /// frozen phi columns + topic totals, so compute is store-free.
    pub fn stage_batch(&mut self, mb: &Minibatch) -> SemStaged {
        let timer = Timer::start();
        self.step += 1;
        // Exactly ONE batch may claim the cold-start bootstrap: under
        // pipelining, later batches are staged before the first apply
        // lands, so the mass check alone would re-seed per batch.
        let bootstrap = !self.boot_staged && self.phi.total_mass() == 0.0;
        if bootstrap {
            self.boot_staged = true;
        }
        let exec = ParallelExecutor::new(self.cfg.n_workers);
        let shards = exec.shard(mb);
        let seeds: Vec<u64> =
            shards.iter().map(|_| self.rng.next_u64()).collect();
        let phi_snap = self.phi.snapshot_columns(&mb.local_words);
        SemStaged {
            params: self.params,
            cfg: self.cfg,
            shards,
            phi_snap,
            phisum0: self.phi.phisum.clone(),
            w_dim: self.phi.n_words,
            bootstrap,
            seeds,
            step: self.step,
            tokens: mb.docs.total_tokens(),
            stage_seconds: timer.seconds(),
        }
    }

    /// Phase 2 (compute): the Fig. 3 inner loops, pure over the staged
    /// snapshot — safe to run on a background thread.
    pub fn compute_batch(staged: &SemStaged) -> SemDelta {
        let timer = Timer::start();
        let exec = ParallelExecutor::new(staged.cfg.n_workers);
        let results = exec.run_sharded(&staged.shards, |shard| {
            run_sem_shard(
                &staged.params,
                &staged.cfg,
                shard,
                &staged.phi_snap,
                &staged.phisum0,
                staged.w_dim,
                staged.bootstrap,
                staged.seeds[shard.shard_index],
            )
        });
        SemDelta { results, compute_seconds: timer.seconds() }
    }

    /// Phase 3 (apply): cold-start seeding first, mirroring the serial
    /// order (seed the global stats during init, decay afterwards), then
    /// the Eq. 20 decay + fixed-order scatter. `rho` uses the step number
    /// recorded at stage time, so pipelined execution preserves the
    /// learning-rate schedule exactly.
    pub fn apply_batch(
        &mut self,
        staged: &SemStaged,
        delta: SemDelta,
    ) -> MinibatchReport {
        let timer = Timer::start();
        let k = self.params.n_topics;
        let SemDelta { results, compute_seconds } = delta;
        if staged.bootstrap {
            for r in &results {
                for (i, &w) in r.boot.words().iter().enumerate() {
                    let src = r.boot.col(i);
                    let (col, phisum) = self.phi.word_and_sum_mut(w as usize);
                    for kk in 0..k {
                        col[kk] += src[kk];
                        phisum[kk] += src[kk];
                    }
                }
            }
        }

        // Global update (Fig. 3 line 10, Eq. 20): decay, then scatter the
        // per-shard sufficient statistics in fixed shard order.
        let rho = self.cfg.rate.rho(staged.step) as f32;
        let scale = (self.cfg.scale_s as f32) * rho;
        self.phi.raw_mut().iter_mut().for_each(|x| *x *= 1.0 - rho);
        self.phi.phisum.iter_mut().for_each(|x| *x *= 1.0 - rho);
        for r in &results {
            for (i, &w) in r.stats.words().iter().enumerate() {
                let src = r.stats.col(i);
                let (col, phisum) = self.phi.word_and_sum_mut(w as usize);
                for kk in 0..k {
                    let v = scale * src[kk];
                    col[kk] += v;
                    phisum[kk] += v;
                }
            }
        }

        let iters = results.iter().map(|r| r.inner_iters).max().unwrap_or(0);
        let ll: f64 = results.iter().map(|r| r.train_ll).sum();
        MinibatchReport {
            inner_iters: iters,
            // Busy time of this batch's three phases. Under pipelining the
            // phases of different batches overlap in wall time, so summing
            // stage+compute+apply (not stage-to-apply elapsed) keeps
            // Metrics' totals meaningful.
            seconds: staged.stage_seconds + compute_seconds + timer.seconds(),
            train_ll: ll,
            tokens: staged.tokens,
            // Workers ran concurrently: the batch's peak working set is
            // the sum of the per-shard arenas and scratch.
            resp_bytes: results.iter().map(|r| r.resp_bytes).sum(),
            scratch_bytes: results.iter().map(|r| r.scratch_bytes).sum(),
        }
    }
}

/// Phase-1 output of the three-phase SEM seam: a self-contained staged
/// minibatch (shards, frozen-phi column snapshot, resident totals,
/// per-shard seeds, the Eq. 18 step number).
pub struct SemStaged {
    params: LdaParams,
    cfg: SemConfig,
    shards: Vec<MinibatchShard>,
    phi_snap: crate::store::PhiSnapshot,
    phisum0: Vec<f32>,
    w_dim: usize,
    bootstrap: bool,
    seeds: Vec<u64>,
    step: usize,
    tokens: f64,
    stage_seconds: f64,
}

/// Phase-2 output: per-shard inner-loop results awaiting the ordered
/// Eq. 20 scatter of [`Sem::apply_batch`].
pub struct SemDelta {
    results: Vec<SemShardResult>,
    compute_seconds: f64,
}

impl crate::exec::pipeline::PhasedTrainer for Sem {
    type Staged = SemStaged;
    type Delta = SemDelta;

    fn stage(&mut self, mb: &Minibatch) -> SemStaged {
        self.stage_batch(mb)
    }

    fn compute(staged: &SemStaged) -> SemDelta {
        Sem::compute_batch(staged)
    }

    fn apply(&mut self, staged: &SemStaged, delta: SemDelta) -> MinibatchReport {
        self.apply_batch(staged, delta)
    }

    fn process_direct(&mut self, mb: &Minibatch) -> MinibatchReport {
        self.process_minibatch(mb)
    }
}

/// Result of one SEM shard worker.
struct SemShardResult {
    inner_iters: usize,
    train_ll: f64,
    /// `sum_d x_{w,d} mu` sufficient statistics over the shard's words.
    stats: SsDelta,
    /// Cold-start hard-init mass (empty unless bootstrapping).
    boot: SsDelta,
    /// This worker's responsibility-arena bytes (dense layout).
    resp_bytes: usize,
    /// This worker's auxiliary scratch bytes.
    scratch_bytes: usize,
}

/// The Fig. 3 inner loop for one document shard: private theta and
/// responsibilities against the staged snapshot of the frozen phi (copied
/// locally per shard so an optional bootstrap overlay needs no branching
/// in the hot loop), with a shard-local convergence check. Store-free by
/// construction — the snapshot is the only view of the global state.
#[allow(clippy::too_many_arguments)]
fn run_sem_shard(
    params: &LdaParams,
    cfg: &SemConfig,
    shard: &MinibatchShard,
    phi_snap: &crate::store::PhiSnapshot,
    phisum0: &[f32],
    w_dim: usize,
    bootstrap: bool,
    seed: u64,
) -> SemShardResult {
    let k = params.n_topics;
    let docs = &shard.docs;
    let tokens = docs.total_tokens();
    let words = &shard.local_words;
    let n_local = words.len();
    let mut rng = Rng::new(seed);

    // Worker scratch from the grow-only pool: frozen-phi copies, the
    // dense-layout responsibility arena, the theta double buffer, the
    // entry→slot map.
    let mut ws = crate::exec::scratch::take();

    // Private copies of the frozen phi columns the shard touches.
    let mut lphi = std::mem::take(&mut ws.col_a);
    lphi.clear();
    for &gw in words.iter() {
        lphi.extend_from_slice(
            phi_snap.column(gw).expect("shard word missing from snapshot"),
        );
    }
    let mut lphisum = phisum0.to_vec();
    // Per-entry shard-local word slots, resolved off the hot loop.
    let mut entry_slot = std::mem::take(&mut ws.idx);
    entry_slot.clear();
    entry_slot.extend(docs.word_ids.iter().map(|w| {
        words.binary_search(w).expect("entry word in shard vocabulary") as u32
    }));

    // Local init (Fig. 3 line 2): random hard assignments -> theta, plus
    // cold-start seeding of the (private) global stats.
    let mut theta =
        ThetaStats::from_buffer(k, docs.n_docs, std::mem::take(&mut ws.theta));
    let nnz = docs.nnz();
    let mut mu = std::mem::take(&mut ws.arena);
    mu.reset(k, nnz, k);
    let mut boot =
        SsDelta::zeros(k, if bootstrap { words.clone() } else { Vec::new() });
    {
        let mut e = 0usize;
        for d in 0..docs.n_docs {
            for (_w, c) in docs.iter_doc(d) {
                let topic = rng.below(k);
                mu.set_one_hot(e, topic);
                theta.doc_mut(d)[topic] += c;
                if bootstrap {
                    let lw = entry_slot[e] as usize;
                    lphi[lw * k + topic] += c;
                    lphisum[topic] += c;
                    boot.add_at(lw, topic, c);
                }
                e += 1;
            }
        }
    }

    // Inner BEM on theta with phi frozen (Fig. 3 lines 4-8).
    let am1 = params.am1();
    let bm1 = params.bm1();
    let wbm1 = params.wbm1(w_dim);
    let kam1 = k as f32 * am1;
    // Resolve the kernel tier once per shard, not per token.
    let isa = cfg.kernel_backend.resolve();
    let mut check =
        ConvergenceCheck::new(cfg.threshold, cfg.check_every, cfg.max_inner_iters);
    let mut iters = 0usize;
    let mut last_ll = f64::NEG_INFINITY;
    // Double-buffered doc-topic stats (zero + swap per sweep).
    let mut theta_new =
        ThetaStats::from_buffer(k, docs.n_docs, std::mem::take(&mut ws.col_b));
    for t in 0..cfg.max_inner_iters {
        let mut ll = 0.0f64;
        let mut e = 0usize;
        theta_new.fill_zero();
        for d in 0..docs.n_docs {
            let theta_d = theta.doc(d);
            let doc_norm = ((docs.doc_len(d) + kam1) as f64).max(1e-300).ln();
            for (_w, c) in docs.iter_doc(d) {
                let lw = entry_slot[e] as usize;
                let mu_row = mu.lane_dense_mut(e);
                let z = super::estep_unnormalized_isa(
                    isa,
                    theta_d,
                    &lphi[lw * k..(lw + 1) * k],
                    &lphisum,
                    am1,
                    bm1,
                    wbm1,
                    mu_row,
                );
                if z > 0.0 {
                    let inv = 1.0 / z;
                    mu_row.iter_mut().for_each(|m| *m *= inv);
                }
                ll += c as f64 * (((z as f64).max(1e-300)).ln() - doc_norm);
                let trow = theta_new.doc_mut(d);
                for i in 0..k {
                    trow[i] += c * mu_row[i];
                }
                e += 1;
            }
        }
        std::mem::swap(&mut theta, &mut theta_new);
        last_ll = ll;
        iters = t + 1;
        if check.update(t, perplexity(ll, tokens)) {
            break;
        }
    }

    // Shard sufficient statistics for the Eq. 20 scatter.
    let mut stats = SsDelta::zeros(k, words.clone());
    let mut e = 0usize;
    for d in 0..docs.n_docs {
        for (_w, c) in docs.iter_doc(d) {
            let lw = entry_slot[e] as usize;
            let mu_row = mu.lane_dense(e);
            for i in 0..k {
                if mu_row[i] != 0.0 {
                    stats.add_at(lw, i, c * mu_row[i]);
                }
            }
            e += 1;
        }
    }

    let resp_bytes = mu.bytes();
    let scratch_bytes = (theta.raw().len()
        + theta_new.raw().len()
        + lphi.len()
        + lphisum.len()) * 4
        + entry_slot.len() * 4;

    // Return the bundle for the next shard/batch.
    ws.arena = mu;
    ws.col_a = lphi;
    ws.col_b = theta_new.into_buffer();
    ws.theta = theta.into_buffer();
    ws.idx = entry_slot;
    crate::exec::scratch::put(ws);

    SemShardResult {
        inner_iters: iters,
        train_ll: last_ll,
        stats,
        boot,
        resp_bytes,
        scratch_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::stream::{CorpusStream, StreamConfig};

    fn run_sem(minibatch_docs: usize, seed: u64) -> (Sem, Vec<MinibatchReport>) {
        let corpus = generate(&SyntheticConfig::small(), 11);
        let p = LdaParams::paper_defaults(8);
        let scfg = StreamConfig { minibatch_docs, ..Default::default() };
        let stream = CorpusStream::new(&corpus, scfg);
        let s = stream.batches_per_pass() as f64;
        let mut sem = Sem::new(p, corpus.n_words(), SemConfig::paper(s), seed);
        let reports: Vec<_> =
            CorpusStream::new(&corpus, scfg).map(|mb| sem.process_minibatch(&mb)).collect();
        (sem, reports)
    }

    #[test]
    fn learning_rate_schedule_matches_eq18() {
        let r = LearningRate::paper();
        assert!((r.rho(1) - (1025f64).powf(-0.5)).abs() < 1e-12);
        assert!(r.rho(1) > r.rho(2));
    }

    #[test]
    fn processes_stream_and_accumulates_phi() {
        let (sem, reports) = run_sem(64, 0);
        assert_eq!(reports.len(), 4);
        assert!(sem.phi.total_mass() > 0.0);
        assert!(reports.iter().all(|r| r.inner_iters >= 1));
        assert!(reports.iter().all(|r| r.train_perplexity().is_finite()));
    }

    #[test]
    fn phisum_consistent_with_columns() {
        let (mut sem, _) = run_sem(64, 1);
        let mut rebuilt = sem.phi.clone();
        rebuilt.rebuild_phisum();
        for i in 0..sem.params.n_topics {
            let a = sem.phi.phisum[i];
            let b = rebuilt.phisum[i];
            assert!((a - b).abs() < a.abs().max(1.0) * 1e-4, "{a} vs {b}");
        }
        sem.phi.phisum = rebuilt.phisum;
    }

    #[test]
    fn inner_loops_converge_within_budget() {
        let (sem, reports) = run_sem(32, 2);
        for r in &reports {
            assert!(
                r.inner_iters < sem.cfg.max_inner_iters,
                "inner loop hit budget: {}",
                r.inner_iters
            );
        }
    }

    #[test]
    fn parallel_sem_matches_serial_mass_trajectory() {
        let corpus = generate(&SyntheticConfig::small(), 11);
        let p = LdaParams::paper_defaults(8);
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let s = CorpusStream::new(&corpus, scfg).batches_per_pass() as f64;
        let run = |workers: usize| {
            let mut cfg = SemConfig::paper(s);
            cfg.n_workers = workers;
            let mut sem = Sem::new(p, corpus.n_words(), cfg, 4);
            let mut last = f64::NAN;
            for mb in CorpusStream::new(&corpus, scfg) {
                last = sem.process_minibatch(&mb).train_perplexity();
            }
            (sem, last)
        };
        let (serial, ppx1) = run(1);
        let (par, ppx4) = run(4);
        // The Eq. 20 scatter moves exactly scale * tokens of mass no
        // matter how responsibilities distribute, so the total-mass
        // trajectory is P-invariant.
        let (m1, m4) = (serial.phi.total_mass(), par.phi.total_mass());
        assert!((m1 - m4).abs() < m1.abs().max(1.0) * 1e-3, "{m1} vs {m4}");
        // And quality lands in the same neighbourhood.
        assert!(ppx1.is_finite() && ppx4.is_finite());
        assert!((ppx4 - ppx1).abs() < ppx1 * 0.25, "{ppx4} vs {ppx1}");
        // phisum stays consistent with the columns after parallel folds.
        let mut rebuilt = par.phi.clone();
        rebuilt.rebuild_phisum();
        for i in 0..8 {
            let (a, b) = (par.phi.phisum[i], rebuilt.phisum[i]);
            assert!((a - b).abs() < a.abs().max(1.0) * 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn train_perplexity_improves_across_stream() {
        let corpus = generate(&SyntheticConfig::small(), 13);
        let p = LdaParams::paper_defaults(8);
        let scfg = StreamConfig { minibatch_docs: 50, ..Default::default() };
        let s = CorpusStream::new(&corpus, scfg).batches_per_pass() as f64;
        // Fast learning rate so few passes visibly move phi (tau0=1024
        // would need hundreds of minibatches).
        let mut cfg = SemConfig::paper(s);
        cfg.rate = LearningRate { tau0: 1.0, kappa: 0.7 };
        let mut sem = Sem::new(p, corpus.n_words(), cfg, 3);
        // two passes; record perplexity of the SAME first minibatch before
        // and after the stream to factor out minibatch difficulty
        let first_mb: Vec<_> = CorpusStream::new(&corpus, scfg).take(1).collect();
        let early = sem.process_minibatch(&first_mb[0]).train_perplexity();
        for _ in 0..2 {
            for mb in CorpusStream::new(&corpus, scfg) {
                sem.process_minibatch(&mb);
            }
        }
        let late = sem.process_minibatch(&first_mb[0]).train_perplexity();
        assert!(late < early, "{late} !< {early}");
    }
}
