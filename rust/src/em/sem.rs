//! Stepwise EM for LDA (paper Fig. 3).
//!
//! The stochastic-approximation combination of BEM with minibatch
//! streams: for each minibatch `x^s`, run the BEM inner loop (E-step +
//! local-theta M-step, global phi frozen) until the training-perplexity
//! delta converges, then blend the minibatch's sufficient statistics into
//! the global topic-word matrix with the Robbins-Monro learning rate
//! (Eqs. 18, 20):
//!
//!   rho_s = (tau0 + s)^-kappa,
//!   phi^s = (1 - rho_s) phi^{s-1} + rho_s * S * sum_d x^s mu^s.
//!
//! SCVB (Foulds et al.) is equivalent to this algorithm (§2.5); the
//! `baselines::scvb` wrapper reuses this core with its own defaults.

use super::{perplexity, ConvergenceCheck, MinibatchReport, PhiStats, ThetaStats};
use crate::stream::Minibatch;
use crate::util::{Rng, Timer};
use crate::LdaParams;

/// Learning-rate schedule (Eq. 18).
#[derive(Debug, Clone, Copy)]
pub struct LearningRate {
    pub tau0: f64,
    pub kappa: f64,
}

impl LearningRate {
    /// The paper's comparison defaults (tau0=1024, kappa=0.5, §4).
    pub fn paper() -> Self {
        Self { tau0: 1024.0, kappa: 0.5 }
    }

    #[inline]
    pub fn rho(&self, s: usize) -> f64 {
        (self.tau0 + s as f64).powf(-self.kappa)
    }
}

/// Configuration of the SEM trainer.
#[derive(Debug, Clone, Copy)]
pub struct SemConfig {
    pub rate: LearningRate,
    /// Scaling coefficient `S = D / D_s` (Eq. 20). Online algorithms must
    /// be told the (estimated) stream length; the paper notes one may
    /// "predefine a fixed large number" for endless streams.
    pub scale_s: f64,
    /// Inner-loop convergence: perplexity delta threshold.
    pub threshold: f64,
    /// Inner-loop convergence: check cadence in sweeps.
    pub check_every: usize,
    /// Inner-loop sweep budget per minibatch.
    pub max_inner_iters: usize,
}

impl SemConfig {
    pub fn paper(scale_s: f64) -> Self {
        Self {
            rate: LearningRate::paper(),
            scale_s,
            threshold: 10.0,
            check_every: 1,
            max_inner_iters: 100,
        }
    }
}

/// Stepwise EM trainer.
pub struct Sem {
    pub params: LdaParams,
    pub cfg: SemConfig,
    pub phi: PhiStats,
    /// Minibatches processed so far (the paper's `s`).
    pub step: usize,
    rng: Rng,
}

impl Sem {
    pub fn new(params: LdaParams, n_words: usize, cfg: SemConfig, seed: u64) -> Self {
        Self {
            phi: PhiStats::zeros(params.n_topics, n_words),
            params,
            cfg,
            step: 0,
            rng: Rng::new(seed),
        }
    }

    /// Run the Fig. 3 inner loop on one minibatch and fold the result into
    /// the global phi.
    pub fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport {
        let timer = Timer::start();
        let k = self.params.n_topics;
        let w_dim = self.phi.n_words;
        let docs = &mb.docs;
        let tokens = docs.total_tokens();
        self.step += 1;

        // Local init (Fig. 3 line 2): random hard assignments -> theta.
        let mut theta = ThetaStats::zeros(k, docs.n_docs);
        let nnz = docs.nnz();
        let mut mu = vec![0.0f32; nnz * k];
        let bootstrap = self.phi.total_mass() == 0.0;
        {
            let mut e = 0usize;
            for d in 0..docs.n_docs {
                for (w, c) in docs.iter_doc(d) {
                    let topic = self.rng.below(k);
                    mu[e * k + topic] = 1.0;
                    theta.doc_mut(d)[topic] += c;
                    if bootstrap {
                        // Cold start (phi_hat^0 == 0): seed the global
                        // stats from the same random assignments so the
                        // first inner loop sees word-differentiated
                        // topics — the paper's "same random
                        // initializations" (§4). Decayed away by the
                        // Eq. 20 updates.
                        let (col, phisum) =
                            self.phi.word_and_sum_mut(w as usize);
                        col[topic] += c;
                        phisum[topic] += c;
                    }
                    e += 1;
                }
            }
        }

        // Inner BEM on theta with phi^{s-1} frozen (lines 4-8).
        let am1 = self.params.am1();
        let bm1 = self.params.bm1();
        let wbm1 = self.params.wbm1(w_dim);
        let mut check =
            ConvergenceCheck::new(self.cfg.threshold, self.cfg.check_every,
                                  self.cfg.max_inner_iters);
        let mut iters = 0usize;
        let mut last_ll = f64::NEG_INFINITY;
        let kam1 = k as f32 * am1;
        for t in 0..self.cfg.max_inner_iters {
            let mut ll = 0.0f64;
            let mut e = 0usize;
            let mut theta_new = ThetaStats::zeros(k, docs.n_docs);
            for d in 0..docs.n_docs {
                let theta_d = theta.doc(d);
                let doc_norm =
                    ((docs.doc_len(d) + kam1) as f64).max(1e-300).ln();
                for (w, c) in docs.iter_doc(d) {
                    let w = w as usize;
                    let mu_row = &mut mu[e * k..(e + 1) * k];
                    let z = super::estep_unnormalized(
                        theta_d,
                        self.phi.word(w),
                        &self.phi.phisum,
                        am1,
                        bm1,
                        wbm1,
                        mu_row,
                    );
                    if z > 0.0 {
                        let inv = 1.0 / z;
                        mu_row.iter_mut().for_each(|m| *m *= inv);
                    }
                    ll += c as f64
                        * (((z as f64).max(1e-300)).ln() - doc_norm);
                    let trow = theta_new.doc_mut(d);
                    for i in 0..k {
                        trow[i] += c * mu_row[i];
                    }
                    e += 1;
                }
            }
            theta = theta_new;
            last_ll = ll;
            iters = t + 1;
            if check.update(t, perplexity(ll, tokens)) {
                break;
            }
        }

        // Global update (line 10, Eq. 20).
        let rho = self.cfg.rate.rho(self.step) as f32;
        let scale = (self.cfg.scale_s as f32) * rho;
        // Decay the whole matrix, then scatter the minibatch stats.
        self.phi.raw_mut().iter_mut().for_each(|x| *x *= 1.0 - rho);
        self.phi.phisum.iter_mut().for_each(|x| *x *= 1.0 - rho);
        let mut e = 0usize;
        for d in 0..docs.n_docs {
            for (w, c) in docs.iter_doc(d) {
                let mu_row = &mu[e * k..(e + 1) * k];
                let (col, phisum) = self.phi.word_and_sum_mut(w as usize);
                for i in 0..k {
                    let v = scale * c * mu_row[i];
                    col[i] += v;
                    phisum[i] += v;
                }
                e += 1;
            }
        }

        MinibatchReport {
            inner_iters: iters,
            seconds: timer.seconds(),
            train_ll: last_ll,
            tokens,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::stream::{CorpusStream, StreamConfig};

    fn run_sem(minibatch_docs: usize, seed: u64) -> (Sem, Vec<MinibatchReport>) {
        let corpus = generate(&SyntheticConfig::small(), 11);
        let p = LdaParams::paper_defaults(8);
        let scfg = StreamConfig { minibatch_docs, ..Default::default() };
        let stream = CorpusStream::new(&corpus, scfg);
        let s = stream.batches_per_pass() as f64;
        let mut sem = Sem::new(p, corpus.n_words(), SemConfig::paper(s), seed);
        let reports: Vec<_> =
            CorpusStream::new(&corpus, scfg).map(|mb| sem.process_minibatch(&mb)).collect();
        (sem, reports)
    }

    #[test]
    fn learning_rate_schedule_matches_eq18() {
        let r = LearningRate::paper();
        assert!((r.rho(1) - (1025f64).powf(-0.5)).abs() < 1e-12);
        assert!(r.rho(1) > r.rho(2));
    }

    #[test]
    fn processes_stream_and_accumulates_phi() {
        let (sem, reports) = run_sem(64, 0);
        assert_eq!(reports.len(), 4);
        assert!(sem.phi.total_mass() > 0.0);
        assert!(reports.iter().all(|r| r.inner_iters >= 1));
        assert!(reports.iter().all(|r| r.train_perplexity().is_finite()));
    }

    #[test]
    fn phisum_consistent_with_columns() {
        let (mut sem, _) = run_sem(64, 1);
        let mut rebuilt = sem.phi.clone();
        rebuilt.rebuild_phisum();
        for i in 0..sem.params.n_topics {
            let a = sem.phi.phisum[i];
            let b = rebuilt.phisum[i];
            assert!((a - b).abs() < a.abs().max(1.0) * 1e-4, "{a} vs {b}");
        }
        sem.phi.phisum = rebuilt.phisum;
    }

    #[test]
    fn inner_loops_converge_within_budget() {
        let (sem, reports) = run_sem(32, 2);
        for r in &reports {
            assert!(
                r.inner_iters < sem.cfg.max_inner_iters,
                "inner loop hit budget: {}",
                r.inner_iters
            );
        }
    }

    #[test]
    fn train_perplexity_improves_across_stream() {
        let corpus = generate(&SyntheticConfig::small(), 13);
        let p = LdaParams::paper_defaults(8);
        let scfg = StreamConfig { minibatch_docs: 50, ..Default::default() };
        let s = CorpusStream::new(&corpus, scfg).batches_per_pass() as f64;
        // Fast learning rate so few passes visibly move phi (tau0=1024
        // would need hundreds of minibatches).
        let mut cfg = SemConfig::paper(s);
        cfg.rate = LearningRate { tau0: 1.0, kappa: 0.7 };
        let mut sem = Sem::new(p, corpus.n_words(), cfg, 3);
        // two passes; record perplexity of the SAME first minibatch before
        // and after the stream to factor out minibatch difficulty
        let first_mb: Vec<_> = CorpusStream::new(&corpus, scfg).take(1).collect();
        let early = sem.process_minibatch(&first_mb[0]).train_perplexity();
        for _ in 0..2 {
            for mb in CorpusStream::new(&corpus, scfg) {
                sem.process_minibatch(&mb);
            }
        }
        let late = sem.process_minibatch(&first_mb[0]).train_perplexity();
        assert!(late < early, "{late} !< {early}");
    }
}
