//! Incremental EM for LDA (paper Fig. 2).
//!
//! Alternates a single E-step and M-step per non-zero element: the entry's
//! current contribution is *excluded* from the sufficient statistics
//! (Eqs. 13-16), the responsibility recomputed, and the statistics updated
//! immediately, so every update influences all subsequent ones within the
//! same sweep.  Equivalent to CVB0 and asynchronous BP (§2.2); converges
//! in fewer sweeps than BEM at the price of storing the full
//! responsibility matrix `mu_{K×NNZ}` (the memory wall motivating FOEM).
//!
//! The exclude/include update itself is the shared kernel
//! [`resp::update_entry`] over the full-K selection — the same code FOEM
//! runs on its scheduled subsets — so the Eq. 13 loop exists once in the
//! crate. Two deliberate differences vs the pre-kernel loop:
//!
//! * the renormalization is the kernel's mass-preserving Eq. 38 form
//!   (`m_old / z`, not `1 / z`); since IEM rows always hold mass ≈ 1
//!   this matches to float accuracy and keeps row sums from drifting;
//! * the degenerate `z <= 0` recompute (only reachable when `alpha < 1`
//!   / `beta < 1` make the Eq. 13 factors negative — never with this
//!   crate's MAP setting `alpha = beta = 1.01`) now *skips* the entry,
//!   keeping its last valid responsibilities and mass-consistent stats,
//!   where the historical loop zeroed the row and removed its mass.

use super::resp::{self, RespArena, SweepKernel};
use super::{perplexity, ConvergenceCheck, MinibatchReport, PhiStats, ThetaStats};
use crate::corpus::sparse::DocWordMatrix;
use crate::util::{Rng, Timer};
use crate::LdaParams;

/// Incremental EM trainer state. Responsibilities live in a dense-layout
/// [`RespArena`] (IEM updates every coordinate, so there is no sparsity
/// to exploit — `resp.lane_dense(e)` is the historical `mu[e*k..(e+1)*k]`
/// row in the doc-major order of the input matrix).
pub struct Iem {
    pub params: LdaParams,
    pub theta: ThetaStats,
    pub phi: PhiStats,
    /// Responsibilities, entry-major, dense arena layout.
    pub resp: RespArena,
    /// Sweep order of entries; reshuffled per sweep ("in random order",
    /// Fig. 2 line 3).
    order: Vec<u32>,
    /// The identity selection (all K topics) fed to the shared kernel.
    sel_all: Vec<u32>,
    kern: SweepKernel,
    rng: Rng,
    pub perplexity_trace: Vec<f64>,
}

impl Iem {
    pub fn init(docs: &DocWordMatrix, params: LdaParams, seed: u64) -> Self {
        let k = params.n_topics;
        let nnz = docs.nnz();
        let mut theta = ThetaStats::zeros(k, docs.n_docs);
        let mut phi = PhiStats::zeros(k, docs.n_words);
        let mut resp = RespArena::new();
        resp.reset(k, nnz, k);
        let mut rng = Rng::new(seed);
        // Hard init: entry e's mass on one topic; mu row is the indicator.
        let mut e = 0usize;
        for d in 0..docs.n_docs {
            for (w, c) in docs.iter_doc(d) {
                let topic = rng.below(k);
                resp.set_one_hot(e, topic);
                theta.doc_mut(d)[topic] += c;
                phi.word_mut(w as usize)[topic] += c;
                phi.phisum[topic] += c;
                e += 1;
            }
        }
        let order: Vec<u32> = (0..nnz as u32).collect();
        Self {
            params,
            theta,
            phi,
            resp,
            order,
            sel_all: (0..k as u32).collect(),
            kern: SweepKernel::new(),
            rng,
            perplexity_trace: Vec::new(),
        }
    }

    /// One full IEM sweep (Fig. 2 lines 3-6) over all entries in random
    /// order. Returns the training log-likelihood accumulated during the
    /// sweep (under the continuously-updated parameters).
    pub fn sweep(&mut self, docs: &DocWordMatrix) -> f64 {
        let k = self.params.n_topics;
        let am1 = self.params.am1();
        let bm1 = self.params.bm1();
        let wbm1 = self.params.wbm1(docs.n_words);

        // entry -> (doc, word, count) lookup built once per sweep.
        // doc id per entry from the CSR pointers.
        let mut entry_doc = vec![0u32; docs.nnz()];
        for d in 0..docs.n_docs {
            let (s, e) = docs.doc_range(d);
            entry_doc[s..e].iter_mut().for_each(|x| *x = d as u32);
        }

        self.rng.shuffle(&mut self.order);
        let kam1 = k as f32 * am1;
        let doc_lens: Vec<f32> =
            (0..docs.n_docs).map(|d| docs.doc_len(d)).collect();
        // Residual accumulator required by the kernel signature; IEM has
        // no scheduler to feed, so it is write-only here.
        let mut fresh_res = vec![0.0f32; k];
        let mut ll = 0.0f64;
        // The selection never changes within a sweep, so the kernel
        // bracket (selection mark + scratch sizing) is opened once.
        self.kern.begin_selection(k, &self.sel_all);
        for &e in &self.order {
            let e = e as usize;
            let d = entry_doc[e] as usize;
            let w = docs.word_ids[e] as usize;
            let c = docs.counts[e];
            let theta_d = self.theta.doc_mut(d);
            let (phi_w, phisum) = self.phi.word_and_sum_mut(w);
            // Exclude + recompute + include over all K topics — the
            // shared Eq. 13/38 kernel with the identity selection.
            let out = resp::update_entry(
                &mut self.resp,
                &mut self.kern,
                e,
                &self.sel_all,
                c,
                theta_d,
                phi_w,
                phisum,
                am1,
                bm1,
                wbm1,
                &mut fresh_res,
            );
            // z excludes this entry's own mass c, so the theta normalizer
            // is (doc mass - c + K*(alpha-1)).
            let doc_norm =
                (((doc_lens[d] - c + kam1) as f64).max(1e-300)).ln();
            ll += c as f64 * (((out.z as f64).max(1e-300)).ln() - doc_norm);
        }
        self.kern.end_selection(&self.sel_all);
        ll
    }

    pub fn train(
        &mut self,
        docs: &DocWordMatrix,
        check: &mut ConvergenceCheck,
    ) -> MinibatchReport {
        let timer = Timer::start();
        let tokens = docs.total_tokens();
        let mut iters = 0usize;
        let mut last_ll = f64::NEG_INFINITY;
        for t in 0..check.max_iters {
            last_ll = self.sweep(docs);
            let ppx = perplexity(last_ll, tokens);
            self.perplexity_trace.push(ppx);
            iters = t + 1;
            if check.update(t, ppx) {
                break;
            }
        }
        MinibatchReport {
            inner_iters: iters,
            seconds: timer.seconds(),
            train_ll: last_ll,
            tokens,
            resp_bytes: self.resp.bytes(),
            scratch_bytes: self.kern.bytes(),
        }
    }

    /// Exact invariant check (tests): rebuild stats from mu and compare.
    #[cfg(test)]
    fn stats_from_mu(&self, docs: &DocWordMatrix) -> (ThetaStats, PhiStats) {
        let k = self.params.n_topics;
        let mut theta = ThetaStats::zeros(k, docs.n_docs);
        let mut phi = PhiStats::zeros(k, docs.n_words);
        let mut e = 0usize;
        for d in 0..docs.n_docs {
            for (w, c) in docs.iter_doc(d) {
                let mu_row = self.resp.lane_dense(e);
                for i in 0..k {
                    theta.doc_mut(d)[i] += c * mu_row[i];
                }
                let (col, phisum) = phi.word_and_sum_mut(w as usize);
                for i in 0..k {
                    col[i] += c * mu_row[i];
                    phisum[i] += c * mu_row[i];
                }
                e += 1;
            }
        }
        (theta, phi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};

    fn small_docs() -> DocWordMatrix {
        let mut cfg = SyntheticConfig::small();
        cfg.n_docs = 80;
        generate(&cfg, 3).docs
    }

    #[test]
    fn stats_stay_consistent_with_mu() {
        // The exclude/include round trip must keep theta/phi == f(mu)
        // after arbitrary sweeps (DESIGN.md invariant).
        let docs = small_docs();
        let p = LdaParams::paper_defaults(6);
        let mut iem = Iem::init(&docs, p, 0);
        for _ in 0..3 {
            iem.sweep(&docs);
        }
        let (theta_ref, phi_ref) = iem.stats_from_mu(&docs);
        for d in 0..docs.n_docs {
            for i in 0..p.n_topics {
                assert!(
                    (iem.theta.doc(d)[i] - theta_ref.doc(d)[i]).abs() < 1e-2,
                    "theta drift at d={d} k={i}"
                );
            }
        }
        for i in 0..p.n_topics {
            assert!((iem.phi.phisum[i] - phi_ref.phisum[i]).abs() < 0.5);
        }
    }

    #[test]
    fn mu_rows_stay_normalized() {
        let docs = small_docs();
        let p = LdaParams::paper_defaults(6);
        let mut iem = Iem::init(&docs, p, 1);
        iem.sweep(&docs);
        for e in 0..docs.nnz() {
            let s: f32 = iem.resp.lane_dense(e).iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "entry {e}: {s}");
        }
    }

    #[test]
    fn iem_converges_not_slower_than_bem() {
        // T_IEM < T_BEM (paper §2.3): compare sweeps to reach a loose
        // perplexity target on the same data and seed, measuring the
        // exact post-sweep log-likelihood for both algorithms.
        let docs = small_docs();
        let p = LdaParams::paper_defaults(8);
        let mut bem = super::super::bem::Bem::init(&docs, p, 7);
        let mut iem = Iem::init(&docs, p, 7);
        let tokens = docs.total_tokens();
        let exact_ppx = |theta: &ThetaStats, phi: &PhiStats| -> f64 {
            perplexity(
                super::super::train_log_likelihood(&docs, theta, phi, &p),
                tokens,
            )
        };
        let target = {
            // converge IEM fully first to get a reachable target
            let mut tmp = Iem::init(&docs, p, 7);
            for _ in 0..30 {
                tmp.sweep(&docs);
            }
            exact_ppx(&tmp.theta, &tmp.phi) * 1.05
        };
        let mut bem_sweeps = 61;
        for t in 1..=60 {
            bem.sweep(&docs);
            if exact_ppx(&bem.theta, &bem.phi) <= target {
                bem_sweeps = t;
                break;
            }
        }
        let mut iem_sweeps = 61;
        for t in 1..=60 {
            iem.sweep(&docs);
            if exact_ppx(&iem.theta, &iem.phi) <= target {
                iem_sweeps = t;
                break;
            }
        }
        assert!(
            iem_sweeps <= bem_sweeps,
            "IEM {iem_sweeps} sweeps vs BEM {bem_sweeps}"
        );
    }

    #[test]
    fn train_reports_sane_numbers() {
        let docs = small_docs();
        let p = LdaParams::paper_defaults(4);
        let mut iem = Iem::init(&docs, p, 5);
        let mut check = ConvergenceCheck::new(5.0, 5, 100);
        let r = iem.train(&docs, &mut check);
        assert!(r.inner_iters >= 5 && r.inner_iters < 100);
        assert!(r.train_perplexity() > 1.0);
    }
}
