//! The responsibility arena — FOEM's O(NNZ·S) E-step working set — and
//! the shared exclude–recompute–renormalize sweep kernel.
//!
//! The paper's complexity table (Table 3) charges FOEM `O(K·NNZ_s)`
//! *space* for the per-minibatch responsibility matrix even though
//! dynamic scheduling (§3.1) only ever rewrites the `lambda_k·K ≈ 10`
//! scheduled coordinates per entry. This module drops that gap: each
//! non-zero entry stores its active `(topic, weight)` pairs in a
//! fixed-width **lane** of `S = n_sel + explore_slots` slots, with a
//! growable **spill** chain for the rare entry whose selected support
//! keeps widening across sweeps, so the working set is O(NNZ·S) instead
//! of O(NNZ·K) and the Eq. 13/38 sweep reads contiguous lanes instead of
//! K-strided rows (*Inference in topic models: sparsity and trade-off*,
//! Than & Ho, studies exactly this trade).
//!
//! **Bit-identity contract.** The arena is a drop-in for the dense
//! `nnz × K` buffer: a lookup of a topic that was never written returns
//! exactly `0.0`, writes at the scheduled coordinates store exactly the
//! value the dense code stored, and [`update_entry`] performs the same
//! float operations in the same `sel` order as the historical dense
//! loops in `em::foem` / `em::iem`. Serial FOEM, the sharded executor
//! and the pipelined runner therefore produce bit-identical numerics
//! (and `IoStats`) to the pre-arena dense implementation — no config
//! flag needed. Guarded by the `dense_ref` tests in `em::foem` and the
//! sparse-vs-dense kernel tests below. See `rust/DESIGN.md` §8.
//!
//! When the scheduled subset covers all K topics (`TopicSubset::All`,
//! IEM, SEM's inherently dense responsibilities) the arena switches to a
//! **dense layout** — direct-indexed K-wide lanes, i.e. exactly the old
//! buffer — so one storage type serves all four trainer kernels.
//!
//! **Kernel backends.** [`SweepKernel`] carries a resolved
//! [`KernelIsa`] tier (set through [`SweepKernel::set_backend`] from the
//! `kernel_backend` config knob). The default `Scalar` tier runs the
//! historical loops below verbatim — every bit-identity contract above
//! holds unconditionally. The SIMD tiers (`em::simd`) run the same
//! exclude–recompute–renormalize phases with vectorized loads and
//! reassociated reductions: tolerance-class numerics, gated by the
//! scalar-vs-SIMD equivalence tests below and the end-to-end perplexity
//! bands. See `rust/DESIGN.md` §11.

use crate::em::simd::{self, KernelBackend, KernelIsa};
use crate::util::AlignedF32;

/// Sentinel for an empty lane slot.
pub const NO_TOPIC: u32 = u32::MAX;
/// Sentinel for "no spill chain" / end of chain.
const NO_SPILL: u32 = u32::MAX;
/// Sentinel for "topic not present in this entry" during slot resolve.
const NO_SLOT: u32 = u32::MAX;
/// High bit marks a resolved slot as living in the spill arena.
const SPILL_BIT: u32 = 1 << 31;

/// Lane width for a scheduled sweep: the selected subset plus the
/// ε-greedy exploration slots, clamped at K (at which point the arena
/// uses the dense layout — a sparse lane as wide as K would be slower
/// than direct indexing).
pub fn lane_capacity(n_sel: usize, explore_slots: usize, k: usize) -> usize {
    (n_sel + explore_slots).min(k)
}

/// Slot-compressed responsibility storage for the non-zero entries of
/// one minibatch (or shard). Grow-only: [`RespArena::reset`] reshapes
/// the arena for the next batch without releasing capacity, so a reused
/// arena allocates nothing in steady state.
#[derive(Debug, Default)]
pub struct RespArena {
    k: usize,
    /// Slots per entry. `lane_cap == k` selects the dense layout.
    lane_cap: usize,
    n_entries: usize,
    /// Sparse layout only: `n_entries * lane_cap` topic ids
    /// (`NO_TOPIC` = free; occupied slots are a prefix of the lane).
    topics: Vec<u32>,
    /// Weights: `n_entries * lane_cap` (sparse) or `n_entries * k`
    /// (dense, direct-indexed — the historical layout). 32-byte aligned
    /// for the SIMD tiers' row loads.
    weights: AlignedF32,
    /// Sparse layout only: head of entry `e`'s spill chain.
    spill_head: Vec<u32>,
    spill_topics: Vec<u32>,
    spill_weights: Vec<f32>,
    spill_next: Vec<u32>,
}

impl RespArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reshape for a batch of `n_entries` entries over `k` topics with
    /// `lane_cap` slots per entry (`>= k` selects the dense layout).
    /// Keeps capacity; O(n_entries · lane_cap) zeroing, the same cost
    /// the dense buffer paid per batch at width K.
    pub fn reset(&mut self, k: usize, n_entries: usize, lane_cap: usize) {
        assert!(k > 0, "RespArena needs k > 0");
        self.k = k;
        self.lane_cap = lane_cap.clamp(1, k);
        self.n_entries = n_entries;
        self.topics.clear();
        self.weights.clear();
        self.spill_head.clear();
        self.spill_topics.clear();
        self.spill_weights.clear();
        self.spill_next.clear();
        if self.is_dense() {
            self.weights.resize(n_entries * k, 0.0);
        } else {
            self.topics.resize(n_entries * self.lane_cap, NO_TOPIC);
            self.weights.resize(n_entries * self.lane_cap, 0.0);
            self.spill_head.resize(n_entries, NO_SPILL);
        }
    }

    /// Dense (direct-indexed) layout?
    #[inline]
    pub fn is_dense(&self) -> bool {
        self.lane_cap == self.k
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn n_entries(&self) -> usize {
        self.n_entries
    }

    #[inline]
    pub fn lane_cap(&self) -> usize {
        self.lane_cap
    }

    /// Number of spill nodes allocated so far (diagnostics/tests).
    pub fn spill_len(&self) -> usize {
        self.spill_topics.len()
    }

    /// Bytes of backing storage currently committed — the telemetry
    /// behind `MinibatchReport::resp_bytes`.
    pub fn bytes(&self) -> usize {
        self.topics.len() * 4
            + self.weights.len() * 4
            + self.spill_head.len() * 4
            + self.spill_topics.len() * 4
            + self.spill_weights.len() * 4
            + self.spill_next.len() * 4
    }

    /// Hard-init entry `e` to all mass on `topic` (Fig. 2/3/4 line "random
    /// hard assignments"). The entry's lane must still be empty.
    #[inline]
    pub fn set_one_hot(&mut self, e: usize, topic: usize) {
        if self.is_dense() {
            self.weights[e * self.k + topic] = 1.0;
        } else {
            let base = e * self.lane_cap;
            debug_assert_eq!(self.topics[base], NO_TOPIC, "lane not empty");
            self.topics[base] = topic as u32;
            self.weights[base] = 1.0;
        }
    }

    /// Responsibility of `(e, topic)`; exactly `0.0` when the coordinate
    /// was never written — the dense-buffer semantics.
    pub fn get(&self, e: usize, topic: usize) -> f32 {
        if self.is_dense() {
            return self.weights[e * self.k + topic];
        }
        let base = e * self.lane_cap;
        let t = topic as u32;
        for s in 0..self.lane_cap {
            let lt = self.topics[base + s];
            if lt == NO_TOPIC {
                return 0.0;
            }
            if lt == t {
                return self.weights[base + s];
            }
        }
        let mut idx = self.spill_head[e];
        while idx != NO_SPILL {
            let i = idx as usize;
            if self.spill_topics[i] == t {
                return self.spill_weights[i];
            }
            idx = self.spill_next[i];
        }
        0.0
    }

    /// Write `(e, topic) = v`, inserting the coordinate if absent (a
    /// fresh zero is not inserted — indistinguishable from absent).
    pub fn set(&mut self, e: usize, topic: usize, v: f32) {
        if self.is_dense() {
            self.weights[e * self.k + topic] = v;
            return;
        }
        let base = e * self.lane_cap;
        let t = topic as u32;
        for s in 0..self.lane_cap {
            let lt = self.topics[base + s];
            if lt == t {
                self.weights[base + s] = v;
                return;
            }
            if lt == NO_TOPIC {
                if v != 0.0 {
                    self.topics[base + s] = t;
                    self.weights[base + s] = v;
                }
                return;
            }
        }
        let mut idx = self.spill_head[e];
        while idx != NO_SPILL {
            let i = idx as usize;
            if self.spill_topics[i] == t {
                self.spill_weights[i] = v;
                return;
            }
            idx = self.spill_next[i];
        }
        if v != 0.0 {
            self.push_spill(e, t, v);
        }
    }

    /// Entry support: occupied lane slots + spill-chain length.
    pub fn support(&self, e: usize) -> usize {
        if self.is_dense() {
            return self
                .weights[e * self.k..(e + 1) * self.k]
                .iter()
                .filter(|&&w| w != 0.0)
                .count();
        }
        let base = e * self.lane_cap;
        let mut n = 0usize;
        for s in 0..self.lane_cap {
            if self.topics[base + s] == NO_TOPIC {
                break;
            }
            n += 1;
        }
        let mut idx = self.spill_head[e];
        while idx != NO_SPILL {
            n += 1;
            idx = self.spill_next[idx as usize];
        }
        n
    }

    /// Dense-layout lane of entry `e` — the historical `mu[e*k..(e+1)*k]`
    /// row, for the inherently dense kernels (SEM's Eq. 11 E-step, IEM).
    #[inline]
    pub fn lane_dense(&self, e: usize) -> &[f32] {
        debug_assert!(self.is_dense(), "lane_dense needs the dense layout");
        &self.weights[e * self.k..(e + 1) * self.k]
    }

    /// Mutable dense-layout lane of entry `e`.
    #[inline]
    pub fn lane_dense_mut(&mut self, e: usize) -> &mut [f32] {
        debug_assert!(self.is_dense(), "lane_dense needs the dense layout");
        &mut self.weights[e * self.k..(e + 1) * self.k]
    }

    #[inline]
    fn push_spill(&mut self, e: usize, topic: u32, v: f32) -> u32 {
        let idx = self.spill_topics.len() as u32;
        debug_assert!(idx & SPILL_BIT == 0, "spill arena overflow");
        self.spill_topics.push(topic);
        self.spill_weights.push(v);
        self.spill_next.push(self.spill_head[e]);
        self.spill_head[e] = idx;
        idx
    }
}

/// Per-sweep scratch of the shared kernel: the K-length selection mark
/// (topic → position in `sel`, maintained per word by [`sweep_word`]) and
/// the `n_sel`-length resolve/recompute buffers. Grow-only; one per
/// worker.
#[derive(Debug, Default)]
pub struct SweepKernel {
    /// `mark[topic] = j + 1` when `sel[j] == topic`, else 0.
    mark: Vec<u32>,
    /// Entry's current responsibility at each `sel` position (32-byte
    /// aligned for the SIMD tiers).
    mu_old: AlignedF32,
    /// Resolved storage slot per `sel` position (`NO_SLOT`, lane index,
    /// or `SPILL_BIT | spill index`).
    slot_of: Vec<u32>,
    /// Recomputed unnormalized responsibilities (the Eq. 13 numerators).
    scratch_mu: AlignedF32,
    /// Per-`sel` writeback deltas (SIMD include loop only).
    delta: AlignedF32,
    /// Resolved instruction tier; `Scalar` (the default) runs the
    /// historical loops verbatim.
    isa: KernelIsa,
    /// Was the bracket's `sel` the identity `0..n`? Recomputed by
    /// `begin_word` when a SIMD tier is active; enables the contiguous
    /// no-gather fast path on the dense layout.
    sel_identity: bool,
}

impl SweepKernel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Select the kernel backend for subsequent sweeps. Pooled worker
    /// scratch is grow-only and can carry a stale tier between runs, so
    /// every scratch checkout re-sets this explicitly.
    pub fn set_backend(&mut self, backend: KernelBackend) {
        self.isa = backend.resolve();
    }

    /// The resolved instruction tier this kernel dispatches to.
    #[inline]
    pub fn isa(&self) -> KernelIsa {
        self.isa
    }

    /// Scratch bytes currently committed (telemetry).
    pub fn bytes(&self) -> usize {
        self.mark.len() * 4
            + self.mu_old.len() * 4
            + self.slot_of.len() * 4
            + self.scratch_mu.len() * 4
            + self.delta.len() * 4
    }

    #[inline]
    fn ensure_sel(&mut self, n_sel: usize) {
        if self.scratch_mu.len() < n_sel {
            self.mu_old.resize(n_sel, 0.0);
            self.slot_of.resize(n_sel, NO_SLOT);
            self.scratch_mu.resize(n_sel, 0.0);
            self.delta.resize(n_sel, 0.0);
        }
    }

    /// Install the selection mark for one word's sweep (sparse layout).
    #[inline]
    fn begin_word(&mut self, k: usize, sel: &[u32]) {
        self.ensure_sel(sel.len());
        if self.mark.len() < k {
            self.mark.resize(k, 0);
        }
        for (j, &kk) in sel.iter().enumerate() {
            self.mark[kk as usize] = j as u32 + 1;
        }
        self.sel_identity = self.isa != KernelIsa::Scalar
            && sel.iter().enumerate().all(|(j, &kk)| kk as usize == j);
    }

    /// Clear the selection mark (only the touched coordinates).
    #[inline]
    fn end_word(&mut self, sel: &[u32]) {
        for &kk in sel {
            self.mark[kk as usize] = 0;
        }
    }

    /// Public selection bracket: install the mark for a run of
    /// [`update_entry`] / [`update_entry_theta`] calls sharing one `sel`.
    /// The training sweep brackets per *word* ([`sweep_word`]); the
    /// fold-in engine (`em::infer`) brackets per *document* — same
    /// mechanism, different grain.
    #[inline]
    pub fn begin_selection(&mut self, k: usize, sel: &[u32]) {
        self.begin_word(k, sel);
    }

    /// Close a [`SweepKernel::begin_selection`] bracket.
    #[inline]
    pub fn end_selection(&mut self, sel: &[u32]) {
        self.end_word(sel);
    }
}

/// Resolve entry `e`'s stored coordinates against the installed selection
/// mark: one scan of the contiguous lane (+ rare spill chain) fills
/// `kern.mu_old` / `kern.slot_of` for every `sel` position, instead of
/// `n_sel` strided probes of a K-wide row. Returns `(base, n_occ)` — the
/// entry's lane base index and occupied-slot count. Shared by the
/// training ([`update_entry`]) and fold-in ([`update_entry_theta`])
/// variants of the kernel.
#[inline]
fn resolve_sparse(
    arena: &RespArena,
    kern: &mut SweepKernel,
    e: usize,
    n_sel: usize,
) -> (usize, usize) {
    kern.mu_old[..n_sel].fill(0.0);
    kern.slot_of[..n_sel].fill(NO_SLOT);
    let cap = arena.lane_cap;
    let base = e * cap;
    let mut n_occ = cap;
    for s in 0..cap {
        let t = arena.topics[base + s];
        if t == NO_TOPIC {
            n_occ = s;
            break;
        }
        let m = kern.mark[t as usize];
        if m != 0 {
            kern.mu_old[(m - 1) as usize] = arena.weights[base + s];
            kern.slot_of[(m - 1) as usize] = s as u32;
        }
    }
    let mut idx = arena.spill_head[e];
    while idx != NO_SPILL {
        let i = idx as usize;
        let m = kern.mark[arena.spill_topics[i] as usize];
        if m != 0 {
            kern.mu_old[(m - 1) as usize] = arena.spill_weights[i];
            kern.slot_of[(m - 1) as usize] = SPILL_BIT | idx;
        }
        idx = arena.spill_next[i];
    }
    (base, n_occ)
}

/// Write `new` back at a [`resolve_sparse`]-resolved `slot` of entry `e`
/// (in-place lane / in-place spill / lane append / spill insert) — the
/// storage half shared by both kernel variants. A fresh zero is
/// indistinguishable from absent, so it never consumes a slot.
#[inline]
fn store_resolved(
    arena: &mut RespArena,
    e: usize,
    base: usize,
    n_occ: &mut usize,
    slot: u32,
    kk: usize,
    new: f32,
) {
    if slot == NO_SLOT {
        if new != 0.0 {
            if *n_occ < arena.lane_cap {
                arena.topics[base + *n_occ] = kk as u32;
                arena.weights[base + *n_occ] = new;
                *n_occ += 1;
            } else {
                arena.push_spill(e, kk as u32, new);
            }
        }
    } else if slot & SPILL_BIT != 0 {
        arena.spill_weights[(slot & !SPILL_BIT) as usize] = new;
    } else {
        arena.weights[base + slot as usize] = new;
    }
}

/// Outcome of one entry update — what callers need for convergence
/// bookkeeping (FOEM) and log-likelihood accumulation (IEM).
#[derive(Debug, Clone, Copy)]
pub struct EntryOutcome {
    /// Responsibility mass the entry held on `sel` before the update
    /// (the Eq. 38 renormalization budget).
    pub m_old: f32,
    /// Unnormalized recompute total (the Eq. 13 normalizer over `sel`);
    /// `0.0` when the update was skipped before the recompute.
    pub z: f32,
    /// False when a degenerate guard (`m_old ≈ 0` or `z <= 0`) skipped
    /// the update, leaving all state untouched.
    pub updated: bool,
}

/// The shared Eq. 13/38 exclude–recompute–renormalize update of a single
/// non-zero entry over the scheduled subset `sel` — the one copy of the
/// loop previously hand-rolled in FOEM's serial path, FOEM's shard
/// worker, and IEM.
///
/// Exactly the historical dense float ops, in `sel` order:
/// `m_old = Σ_j mu[sel_j]`; skip if `m_old <= 1e-12`; per `j` exclude the
/// entry's own mass and recompute `u_j` (clamped at 0); skip if
/// `z = Σ u_j <= 0`; include `new_j = u_j · m_old / z`, pushing
/// `delta_j = c·(new_j − mu[sel_j])` into `th`/`col`/`phisum` and
/// `|delta_j|` into `fresh_res[j]`.
///
/// Must run inside a [`sweep_word`] / [`SweepKernel::begin_selection`]
/// bracket: the bracket installs the sparse-layout selection mark *and*
/// sizes the kernel scratch once per selection (the per-entry
/// `ensure_sel` re-check was hoisted off this hottest path).
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn update_entry(
    arena: &mut RespArena,
    kern: &mut SweepKernel,
    e: usize,
    sel: &[u32],
    c: f32,
    th: &mut [f32],
    col: &mut [f32],
    phisum: &mut [f32],
    am1: f32,
    bm1: f32,
    wbm1: f32,
    fresh_res: &mut [f32],
) -> EntryOutcome {
    debug_assert!(
        kern.scratch_mu.len() >= sel.len(),
        "update_entry outside a begin_selection/sweep_word bracket"
    );
    if arena.is_dense() {
        update_entry_dense(arena, kern, e, sel, c, th, col, phisum, am1, bm1, wbm1, fresh_res)
    } else {
        update_entry_sparse(arena, kern, e, sel, c, th, col, phisum, am1, bm1, wbm1, fresh_res)
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn update_entry_dense(
    arena: &mut RespArena,
    kern: &mut SweepKernel,
    e: usize,
    sel: &[u32],
    c: f32,
    th: &mut [f32],
    col: &mut [f32],
    phisum: &mut [f32],
    am1: f32,
    bm1: f32,
    wbm1: f32,
    fresh_res: &mut [f32],
) -> EntryOutcome {
    let k = arena.k;
    let isa = kern.isa;
    let row = &mut arena.weights[e * k..(e + 1) * k];
    if isa == KernelIsa::Scalar {
        // Reference scalar path — the bit-identity contract. Do not
        // reorder these float ops.
        let mut m_old = 0.0f32;
        for &kk in sel {
            m_old += row[kk as usize];
        }
        if m_old <= 1e-12 {
            return EntryOutcome { m_old, z: 0.0, updated: false };
        }
        // Exclude + recompute on the subset (Eq. 13).
        let mut z = 0.0f32;
        for (j, &kk) in sel.iter().enumerate() {
            let kk = kk as usize;
            let excl = c * row[kk];
            let u = (th[kk] - excl + am1) * (col[kk] - excl + bm1)
                / (phisum[kk] - excl + wbm1);
            kern.scratch_mu[j] = u.max(0.0);
            z += kern.scratch_mu[j];
        }
        if z <= 0.0 {
            return EntryOutcome { m_old, z, updated: false };
        }
        let renorm = m_old / z;
        // Include new responsibilities + residuals (Fig. 4 lines 12-13).
        for (j, &kk) in sel.iter().enumerate() {
            let kk = kk as usize;
            let new = kern.scratch_mu[j] * renorm;
            let delta = c * (new - row[kk]);
            th[kk] += delta;
            col[kk] += delta;
            phisum[kk] += delta;
            fresh_res[j] += delta.abs();
            row[kk] = new;
        }
        return EntryOutcome { m_old, z, updated: true };
    }

    // SIMD-structured path: same three phases, vectorized primitives.
    let n = sel.len();
    if kern.sel_identity {
        // Identity selection (TopicSubset::All): every operand loads
        // contiguously — no gathers, no scatter loop.
        let m_old = simd::sum(isa, &row[..n]);
        if m_old <= 1e-12 {
            return EntryOutcome { m_old, z: 0.0, updated: false };
        }
        let z = simd::recompute_u_contig(
            isa,
            &row[..n],
            &th[..n],
            &col[..n],
            &phisum[..n],
            c,
            am1,
            bm1,
            wbm1,
            true,
            &mut kern.scratch_mu[..n],
        );
        if z <= 0.0 {
            return EntryOutcome { m_old, z, updated: false };
        }
        let renorm = m_old / z;
        simd::finalize_delta(
            isa,
            renorm,
            c,
            &row[..n],
            &mut kern.scratch_mu[..n],
            &mut kern.delta[..n],
            fresh_res,
        );
        simd::add_assign(isa, &mut th[..n], &kern.delta[..n]);
        simd::add_assign(isa, &mut col[..n], &kern.delta[..n]);
        simd::add_assign(isa, &mut phisum[..n], &kern.delta[..n]);
        row[..n].copy_from_slice(&kern.scratch_mu[..n]);
        return EntryOutcome { m_old, z, updated: true };
    }
    simd::gather(isa, row, sel, &mut kern.mu_old[..n]);
    let m_old = simd::sum(isa, &kern.mu_old[..n]);
    if m_old <= 1e-12 {
        return EntryOutcome { m_old, z: 0.0, updated: false };
    }
    let z = simd::recompute_u(
        isa,
        sel,
        &kern.mu_old[..n],
        th,
        col,
        phisum,
        c,
        am1,
        bm1,
        wbm1,
        true,
        &mut kern.scratch_mu[..n],
    );
    if z <= 0.0 {
        return EntryOutcome { m_old, z, updated: false };
    }
    let renorm = m_old / z;
    simd::finalize_delta(
        isa,
        renorm,
        c,
        &kern.mu_old[..n],
        &mut kern.scratch_mu[..n],
        &mut kern.delta[..n],
        fresh_res,
    );
    // AVX2 has no f32 scatter; the subset writeback stays scalar.
    for (j, &kk) in sel.iter().enumerate() {
        let kk = kk as usize;
        let d = kern.delta[j];
        th[kk] += d;
        col[kk] += d;
        phisum[kk] += d;
        row[kk] = kern.scratch_mu[j];
    }
    EntryOutcome { m_old, z, updated: true }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn update_entry_sparse(
    arena: &mut RespArena,
    kern: &mut SweepKernel,
    e: usize,
    sel: &[u32],
    c: f32,
    th: &mut [f32],
    col: &mut [f32],
    phisum: &mut [f32],
    am1: f32,
    bm1: f32,
    wbm1: f32,
    fresh_res: &mut [f32],
) -> EntryOutcome {
    let n_sel = sel.len();
    debug_assert!(kern.mark.len() >= arena.k, "sparse update outside sweep_word");
    let (base, mut n_occ) = resolve_sparse(arena, kern, e, n_sel);
    let isa = kern.isa;

    if isa == KernelIsa::Scalar {
        // Reference scalar path — the bit-identity contract.
        // Retained mass within the subset (Eq. 38) — summed in `sel`
        // order, matching the dense loop's float rounding exactly.
        let mut m_old = 0.0f32;
        for &m in &kern.mu_old[..n_sel] {
            m_old += m;
        }
        if m_old <= 1e-12 {
            return EntryOutcome { m_old, z: 0.0, updated: false };
        }
        // Exclude + recompute on the subset (Eq. 13).
        let mut z = 0.0f32;
        for (j, &kk) in sel.iter().enumerate() {
            let kk = kk as usize;
            let excl = c * kern.mu_old[j];
            let u = (th[kk] - excl + am1) * (col[kk] - excl + bm1)
                / (phisum[kk] - excl + wbm1);
            kern.scratch_mu[j] = u.max(0.0);
            z += kern.scratch_mu[j];
        }
        if z <= 0.0 {
            return EntryOutcome { m_old, z, updated: false };
        }
        let renorm = m_old / z;
        // Include new responsibilities + residuals (Fig. 4 lines 12-13).
        for (j, &kk) in sel.iter().enumerate() {
            let new = kern.scratch_mu[j] * renorm;
            let delta = c * (new - kern.mu_old[j]);
            let kk = kk as usize;
            th[kk] += delta;
            col[kk] += delta;
            phisum[kk] += delta;
            fresh_res[j] += delta.abs();
            let slot = kern.slot_of[j];
            store_resolved(arena, e, base, &mut n_occ, slot, kk, new);
        }
        return EntryOutcome { m_old, z, updated: true };
    }

    // SIMD path: the lane/spill resolve above already densified the
    // entry's subset view into `mu_old`; recompute vectorizes over it.
    let m_old = simd::sum(isa, &kern.mu_old[..n_sel]);
    if m_old <= 1e-12 {
        return EntryOutcome { m_old, z: 0.0, updated: false };
    }
    let z = simd::recompute_u(
        isa,
        sel,
        &kern.mu_old[..n_sel],
        th,
        col,
        phisum,
        c,
        am1,
        bm1,
        wbm1,
        true,
        &mut kern.scratch_mu[..n_sel],
    );
    if z <= 0.0 {
        return EntryOutcome { m_old, z, updated: false };
    }
    let renorm = m_old / z;
    simd::finalize_delta(
        isa,
        renorm,
        c,
        &kern.mu_old[..n_sel],
        &mut kern.scratch_mu[..n_sel],
        &mut kern.delta[..n_sel],
        fresh_res,
    );
    // Slot-compressed writeback is inherently scalar (lane append /
    // spill insert can reshape storage per element).
    for (j, &kk) in sel.iter().enumerate() {
        let kk = kk as usize;
        let d = kern.delta[j];
        th[kk] += d;
        col[kk] += d;
        phisum[kk] += d;
        let slot = kern.slot_of[j];
        store_resolved(arena, e, base, &mut n_occ, slot, kk, kern.scratch_mu[j]);
    }
    EntryOutcome { m_old, z, updated: true }
}

/// The fold-in variant of [`update_entry`]: the same
/// exclude–recompute–renormalize update with a **theta-only M-step**.
/// An unseen document's mass was never accumulated into the topic-word
/// statistics, so there is nothing to exclude from `col`/`phisum` and
/// nothing to write back there — `phi` stays frozen (read-only) and only
/// the document's theta row moves. Everything else is the Eq. 13/38
/// kernel verbatim: same resolve (`resolve_sparse`), same `sel`-order
/// float ops, same mass-preserving renormalization, same write-back
/// (`store_resolved`). Used by the fold-in inference engine
/// (`em::infer`); sparse layouts must run inside a
/// [`SweepKernel::begin_selection`] bracket.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn update_entry_theta(
    arena: &mut RespArena,
    kern: &mut SweepKernel,
    e: usize,
    sel: &[u32],
    c: f32,
    th: &mut [f32],
    col: &[f32],
    phisum: &[f32],
    am1: f32,
    bm1: f32,
    wbm1: f32,
    fresh_res: &mut [f32],
) -> EntryOutcome {
    debug_assert!(
        kern.scratch_mu.len() >= sel.len(),
        "update_entry_theta outside a begin_selection bracket"
    );
    let isa = kern.isa;
    if arena.is_dense() {
        let k = arena.k;
        let row = &mut arena.weights[e * k..(e + 1) * k];
        if isa == KernelIsa::Scalar {
            // Reference scalar path — the bit-identity contract.
            let mut m_old = 0.0f32;
            for &kk in sel {
                m_old += row[kk as usize];
            }
            if m_old <= 1e-12 {
                return EntryOutcome { m_old, z: 0.0, updated: false };
            }
            let mut z = 0.0f32;
            for (j, &kk) in sel.iter().enumerate() {
                let kk = kk as usize;
                let excl = c * row[kk];
                let u = (th[kk] - excl + am1) * (col[kk] + bm1)
                    / (phisum[kk] + wbm1);
                kern.scratch_mu[j] = u.max(0.0);
                z += kern.scratch_mu[j];
            }
            if z <= 0.0 {
                return EntryOutcome { m_old, z, updated: false };
            }
            let renorm = m_old / z;
            for (j, &kk) in sel.iter().enumerate() {
                let kk = kk as usize;
                let new = kern.scratch_mu[j] * renorm;
                let delta = c * (new - row[kk]);
                th[kk] += delta;
                fresh_res[j] += delta.abs();
                row[kk] = new;
            }
            return EntryOutcome { m_old, z, updated: true };
        }

        // SIMD path — `phi_excl: false` zeroes the phi-factor exclusion
        // (exact: `x - 0.0 == x`), reproducing the frozen-phi formula.
        let n = sel.len();
        if kern.sel_identity {
            let m_old = simd::sum(isa, &row[..n]);
            if m_old <= 1e-12 {
                return EntryOutcome { m_old, z: 0.0, updated: false };
            }
            let z = simd::recompute_u_contig(
                isa,
                &row[..n],
                &th[..n],
                &col[..n],
                &phisum[..n],
                c,
                am1,
                bm1,
                wbm1,
                false,
                &mut kern.scratch_mu[..n],
            );
            if z <= 0.0 {
                return EntryOutcome { m_old, z, updated: false };
            }
            let renorm = m_old / z;
            simd::finalize_delta(
                isa,
                renorm,
                c,
                &row[..n],
                &mut kern.scratch_mu[..n],
                &mut kern.delta[..n],
                fresh_res,
            );
            simd::add_assign(isa, &mut th[..n], &kern.delta[..n]);
            row[..n].copy_from_slice(&kern.scratch_mu[..n]);
            return EntryOutcome { m_old, z, updated: true };
        }
        simd::gather(isa, row, sel, &mut kern.mu_old[..n]);
        let m_old = simd::sum(isa, &kern.mu_old[..n]);
        if m_old <= 1e-12 {
            return EntryOutcome { m_old, z: 0.0, updated: false };
        }
        let z = simd::recompute_u(
            isa,
            sel,
            &kern.mu_old[..n],
            th,
            col,
            phisum,
            c,
            am1,
            bm1,
            wbm1,
            false,
            &mut kern.scratch_mu[..n],
        );
        if z <= 0.0 {
            return EntryOutcome { m_old, z, updated: false };
        }
        let renorm = m_old / z;
        simd::finalize_delta(
            isa,
            renorm,
            c,
            &kern.mu_old[..n],
            &mut kern.scratch_mu[..n],
            &mut kern.delta[..n],
            fresh_res,
        );
        for (j, &kk) in sel.iter().enumerate() {
            let kk = kk as usize;
            th[kk] += kern.delta[j];
            row[kk] = kern.scratch_mu[j];
        }
        return EntryOutcome { m_old, z, updated: true };
    }

    let n_sel = sel.len();
    debug_assert!(
        kern.mark.len() >= arena.k,
        "sparse theta update outside begin_selection"
    );
    let (base, mut n_occ) = resolve_sparse(arena, kern, e, n_sel);
    if isa == KernelIsa::Scalar {
        // Reference scalar path — the bit-identity contract.
        let mut m_old = 0.0f32;
        for &m in &kern.mu_old[..n_sel] {
            m_old += m;
        }
        if m_old <= 1e-12 {
            return EntryOutcome { m_old, z: 0.0, updated: false };
        }
        let mut z = 0.0f32;
        for (j, &kk) in sel.iter().enumerate() {
            let kk = kk as usize;
            let excl = c * kern.mu_old[j];
            let u =
                (th[kk] - excl + am1) * (col[kk] + bm1) / (phisum[kk] + wbm1);
            kern.scratch_mu[j] = u.max(0.0);
            z += kern.scratch_mu[j];
        }
        if z <= 0.0 {
            return EntryOutcome { m_old, z, updated: false };
        }
        let renorm = m_old / z;
        for (j, &kk) in sel.iter().enumerate() {
            let new = kern.scratch_mu[j] * renorm;
            let delta = c * (new - kern.mu_old[j]);
            let kk = kk as usize;
            th[kk] += delta;
            fresh_res[j] += delta.abs();
            store_resolved(arena, e, base, &mut n_occ, kern.slot_of[j], kk, new);
        }
        return EntryOutcome { m_old, z, updated: true };
    }

    // SIMD path over the resolved subset view.
    let m_old = simd::sum(isa, &kern.mu_old[..n_sel]);
    if m_old <= 1e-12 {
        return EntryOutcome { m_old, z: 0.0, updated: false };
    }
    let z = simd::recompute_u(
        isa,
        sel,
        &kern.mu_old[..n_sel],
        th,
        col,
        phisum,
        c,
        am1,
        bm1,
        wbm1,
        false,
        &mut kern.scratch_mu[..n_sel],
    );
    if z <= 0.0 {
        return EntryOutcome { m_old, z, updated: false };
    }
    let renorm = m_old / z;
    simd::finalize_delta(
        isa,
        renorm,
        c,
        &kern.mu_old[..n_sel],
        &mut kern.scratch_mu[..n_sel],
        &mut kern.delta[..n_sel],
        fresh_res,
    );
    for (j, &kk) in sel.iter().enumerate() {
        let kk = kk as usize;
        th[kk] += kern.delta[j];
        let slot = kern.slot_of[j];
        store_resolved(arena, e, base, &mut n_occ, slot, kk, kern.scratch_mu[j]);
    }
    EntryOutcome { m_old, z, updated: true }
}

/// The cache-blocked per-word sweep shared by FOEM's serial path and its
/// shard worker: with the word's phi column, the selection, and the
/// selection mark pinned, linearly scan the word's contiguous entry
/// range (vocab-major order) applying [`update_entry`] to each non-zero.
/// `doc_ids`/`counts` are the word's slices of the vocab-major matrix;
/// `entry_base` is the word's first arena entry index; `theta` is the
/// K-strided doc-topic buffer.
#[allow(clippy::too_many_arguments)]
pub fn sweep_word(
    arena: &mut RespArena,
    kern: &mut SweepKernel,
    sel: &[u32],
    entry_base: usize,
    doc_ids: &[u32],
    counts: &[f32],
    theta: &mut [f32],
    col: &mut [f32],
    phisum: &mut [f32],
    am1: f32,
    bm1: f32,
    wbm1: f32,
    fresh_res: &mut [f32],
) {
    let k = arena.k;
    kern.begin_word(k, sel);
    for (off, (&d, &c)) in doc_ids.iter().zip(counts).enumerate() {
        let d = d as usize;
        let th = &mut theta[d * k..(d + 1) * k];
        update_entry(
            arena,
            kern,
            entry_base + off,
            sel,
            c,
            th,
            col,
            phisum,
            am1,
            bm1,
            wbm1,
            fresh_res,
        );
    }
    kern.end_word(sel);
}

/// Scan-based top-`n` selection: one pass over `vals`, maintaining the
/// current top set in `out` (descending-ish, unordered). ~K comparisons
/// with a tiny constant — measurably faster than quickselect on an index
/// array for the n=10 regime FOEM lives in (`rust/DESIGN.md` §8).
#[inline]
pub fn top_n_indices(vals: &[f32], n: usize, out: &mut Vec<u32>) {
    out.clear();
    if n >= vals.len() {
        out.extend(0..vals.len() as u32);
        return;
    }
    // Seed with the first n indices, tracking the minimum.
    let mut min_pos = 0usize;
    for i in 0..n {
        out.push(i as u32);
        if vals[i] < vals[out[min_pos] as usize] {
            min_pos = i;
        }
    }
    let mut min_val = vals[out[min_pos] as usize];
    for (i, &v) in vals.iter().enumerate().skip(n) {
        if v > min_val {
            out[min_pos] = i as u32;
            // Re-find the minimum of the small set.
            min_pos = 0;
            for j in 1..n {
                if vals[out[j] as usize] < vals[out[min_pos] as usize] {
                    min_pos = j;
                }
            }
            min_val = vals[out[min_pos] as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dense_layout_is_direct_indexed() {
        let mut a = RespArena::new();
        a.reset(8, 4, 8);
        assert!(a.is_dense());
        a.set_one_hot(2, 5);
        assert_eq!(a.get(2, 5), 1.0);
        assert_eq!(a.get(2, 4), 0.0);
        a.set(2, 4, 0.25);
        assert_eq!(a.lane_dense(2)[4], 0.25);
        assert_eq!(a.bytes(), 4 * 8 * 4);
    }

    #[test]
    fn sparse_get_set_roundtrip_with_spill() {
        let mut a = RespArena::new();
        // Lane of 2 slots over K=16: the third distinct topic spills.
        a.reset(16, 3, 2);
        assert!(!a.is_dense());
        a.set(1, 3, 0.5);
        a.set(1, 9, 0.25);
        assert_eq!(a.spill_len(), 0);
        a.set(1, 12, 0.125); // lane full -> spill
        a.set(1, 14, 0.0625); // deeper chain
        assert_eq!(a.spill_len(), 2);
        assert_eq!(a.get(1, 3), 0.5);
        assert_eq!(a.get(1, 9), 0.25);
        assert_eq!(a.get(1, 12), 0.125);
        assert_eq!(a.get(1, 14), 0.0625);
        assert_eq!(a.get(1, 0), 0.0);
        assert_eq!(a.support(1), 4);
        // Updates in place, both lane and spill.
        a.set(1, 9, 0.75);
        a.set(1, 14, 0.875);
        assert_eq!(a.get(1, 9), 0.75);
        assert_eq!(a.get(1, 14), 0.875);
        assert_eq!(a.spill_len(), 2, "update must not re-insert");
        // Other entries untouched.
        assert_eq!(a.support(0), 0);
        assert_eq!(a.get(0, 3), 0.0);
    }

    #[test]
    fn fresh_zero_writes_do_not_consume_slots() {
        let mut a = RespArena::new();
        a.reset(16, 1, 2);
        a.set(0, 5, 0.0);
        assert_eq!(a.support(0), 0);
        a.set(0, 1, 1.0);
        a.set(0, 2, 1.0);
        a.set(0, 7, 0.0); // lane full, but zero -> no spill
        assert_eq!(a.spill_len(), 0);
        assert_eq!(a.get(0, 7), 0.0);
        // A present coordinate CAN hold zero (written as an update).
        a.set(0, 1, 0.0);
        assert_eq!(a.get(0, 1), 0.0);
        assert_eq!(a.support(0), 2);
    }

    #[test]
    fn reset_reuses_capacity_and_clears_state() {
        let mut a = RespArena::new();
        a.reset(16, 2, 2);
        a.set(0, 1, 1.0);
        a.set(0, 2, 1.0);
        a.set(0, 3, 1.0); // spill
        assert_eq!(a.spill_len(), 1);
        a.reset(16, 2, 2);
        assert_eq!(a.spill_len(), 0);
        for t in 0..16 {
            assert_eq!(a.get(0, t), 0.0);
            assert_eq!(a.get(1, t), 0.0);
        }
        // Dense <-> sparse flips are clean too.
        a.reset(4, 2, 8);
        assert!(a.is_dense());
        assert_eq!(a.get(1, 3), 0.0);
    }

    /// The load-bearing property: the sparse kernel performs exactly the
    /// dense kernel's float ops — same inputs, bitwise-equal outputs on
    /// every mutated buffer — including when lanes overflow into spill.
    #[test]
    fn sparse_kernel_bit_identical_to_dense_kernel() {
        let k = 32usize;
        let n_entries = 12usize;
        let mut rng = Rng::new(42);
        // Tiny lane (2 slots) + 6-topic selections force heavy spill.
        for &lane_cap in &[2usize, 6, 10] {
            let mut dense = RespArena::new();
            dense.reset(k, n_entries, k);
            let mut sparse = RespArena::new();
            sparse.reset(k, n_entries, lane_cap);
            let mut kd = SweepKernel::new();
            let mut ks = SweepKernel::new();

            let mut th_d: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 4.0).collect();
            let mut col_d: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 2.0).collect();
            let mut ps_d: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 50.0 + 1.0).collect();
            let mut th_s = th_d.clone();
            let mut col_s = col_d.clone();
            let mut ps_s = ps_d.clone();

            for e in 0..n_entries {
                let t = rng.below(k);
                dense.set_one_hot(e, t);
                sparse.set_one_hot(e, t);
            }

            for round in 0..8 {
                // A fresh selection of 6 distinct topics per round.
                let mut sel: Vec<u32> = Vec::new();
                while sel.len() < 6 {
                    let cand = rng.below(k) as u32;
                    if !sel.contains(&cand) {
                        sel.push(cand);
                    }
                }
                let mut fr_d = vec![0.0f32; sel.len()];
                let mut fr_s = vec![0.0f32; sel.len()];
                let counts: Vec<f32> =
                    (0..n_entries).map(|e| (e % 3 + 1) as f32).collect();
                let docs: Vec<u32> = vec![0; n_entries];
                sweep_word(
                    &mut dense, &mut kd, &sel, 0, &docs, &counts,
                    &mut th_d, &mut col_d, &mut ps_d, 0.01, 0.01, 0.32,
                    &mut fr_d,
                );
                sweep_word(
                    &mut sparse, &mut ks, &sel, 0, &docs, &counts,
                    &mut th_s, &mut col_s, &mut ps_s, 0.01, 0.01, 0.32,
                    &mut fr_s,
                );
                for i in 0..k {
                    assert_eq!(
                        th_d[i].to_bits(),
                        th_s[i].to_bits(),
                        "theta diverged (cap={lane_cap} round={round} k={i})"
                    );
                    assert_eq!(col_d[i].to_bits(), col_s[i].to_bits());
                    assert_eq!(ps_d[i].to_bits(), ps_s[i].to_bits());
                }
                for j in 0..sel.len() {
                    assert_eq!(fr_d[j].to_bits(), fr_s[j].to_bits());
                }
                for e in 0..n_entries {
                    for t in 0..k {
                        assert_eq!(
                            dense.get(e, t).to_bits(),
                            sparse.get(e, t).to_bits(),
                            "mu diverged (cap={lane_cap} e={e} t={t})"
                        );
                    }
                }
            }
            if lane_cap == 2 {
                assert!(sparse.spill_len() > 0, "spill path never exercised");
            }
            assert!(
                sparse.bytes() < dense.bytes(),
                "sparse arena not smaller: {} vs {}",
                sparse.bytes(),
                dense.bytes()
            );
        }
    }

    /// Same property for the fold-in variant: sparse lanes and the dense
    /// layout perform identical float ops — and phi stays untouched.
    #[test]
    fn theta_kernel_sparse_bit_identical_to_dense_layout() {
        let k = 24usize;
        let n_entries = 10usize;
        let mut rng = Rng::new(11);
        for &lane_cap in &[2usize, 5, 8] {
            let mut dense = RespArena::new();
            dense.reset(k, n_entries, k);
            let mut sparse = RespArena::new();
            sparse.reset(k, n_entries, lane_cap);
            let mut kd = SweepKernel::new();
            let mut ks = SweepKernel::new();

            let mut th_d: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 4.0).collect();
            let mut th_s = th_d.clone();
            let col: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 2.0 + 0.1).collect();
            let phisum: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 50.0 + 1.0).collect();
            let (col0, ps0) = (col.clone(), phisum.clone());

            for e in 0..n_entries {
                let t = rng.below(k);
                dense.set_one_hot(e, t);
                sparse.set_one_hot(e, t);
            }

            for round in 0..6 {
                let mut sel: Vec<u32> = Vec::new();
                while sel.len() < 5 {
                    let cand = rng.below(k) as u32;
                    if !sel.contains(&cand) {
                        sel.push(cand);
                    }
                }
                let mut fr_d = vec![0.0f32; sel.len()];
                let mut fr_s = vec![0.0f32; sel.len()];
                kd.begin_selection(k, &sel);
                ks.begin_selection(k, &sel);
                for e in 0..n_entries {
                    let c = (e % 3 + 1) as f32;
                    update_entry_theta(
                        &mut dense, &mut kd, e, &sel, c, &mut th_d, &col,
                        &phisum, 0.01, 0.01, 0.32, &mut fr_d,
                    );
                    update_entry_theta(
                        &mut sparse, &mut ks, e, &sel, c, &mut th_s, &col,
                        &phisum, 0.01, 0.01, 0.32, &mut fr_s,
                    );
                }
                kd.end_selection(&sel);
                ks.end_selection(&sel);
                for i in 0..k {
                    assert_eq!(
                        th_d[i].to_bits(),
                        th_s[i].to_bits(),
                        "theta diverged (cap={lane_cap} round={round} k={i})"
                    );
                }
                for j in 0..sel.len() {
                    assert_eq!(fr_d[j].to_bits(), fr_s[j].to_bits());
                }
                for e in 0..n_entries {
                    for t in 0..k {
                        assert_eq!(
                            dense.get(e, t).to_bits(),
                            sparse.get(e, t).to_bits(),
                            "mu diverged (cap={lane_cap} e={e} t={t})"
                        );
                    }
                }
            }
            // The theta-only M-step must leave phi frozen.
            assert_eq!(col, col0);
            assert_eq!(phisum, ps0);
            if lane_cap == 2 {
                assert!(sparse.spill_len() > 0, "spill path never exercised");
            }
        }
    }

    /// The fold-in kernel preserves each entry's responsibility mass (and
    /// therefore each document's theta mass) up to float noise — the
    /// Eq. 38 renormalization budget is redistributed, never created.
    #[test]
    fn theta_kernel_preserves_entry_mass() {
        let k = 16usize;
        let mut a = RespArena::new();
        a.reset(k, 1, k);
        a.set_one_hot(0, 3);
        let mut kern = SweepKernel::new();
        let mut th: Vec<f32> = (0..k).map(|i| i as f32 * 0.1 + 0.5).collect();
        let col: Vec<f32> = (0..k).map(|i| (i % 5) as f32 + 0.2).collect();
        let phisum: Vec<f32> = vec![20.0; k];
        let sel: Vec<u32> = (0..k as u32).collect();
        let th_mass0: f32 = th.iter().sum();
        let mut fr = vec![0.0f32; k];
        for _ in 0..5 {
            kern.begin_selection(k, &sel);
            let out = update_entry_theta(
                &mut a, &mut kern, 0, &sel, 2.0, &mut th, &col, &phisum,
                0.01, 0.01, 0.16, &mut fr,
            );
            kern.end_selection(&sel);
            assert!(out.updated);
            let mass: f32 = (0..k).map(|t| a.get(0, t)).sum();
            assert!((mass - 1.0).abs() < 1e-5, "entry mass drifted: {mass}");
        }
        let th_mass: f32 = th.iter().sum();
        assert!(
            (th_mass - th_mass0).abs() < 1e-3,
            "theta mass drifted: {th_mass0} -> {th_mass}"
        );
    }

    #[test]
    fn lane_capacity_clamps_at_k() {
        assert_eq!(lane_capacity(10, 4, 1024), 14);
        assert_eq!(lane_capacity(10, 4, 8), 8);
        assert_eq!(lane_capacity(8, 0, 8), 8);
    }

    #[test]
    fn top_n_indices_returns_true_top_set() {
        let vals = [0.1f32, 5.0, 0.2, 9.0, 0.0, 3.0];
        let mut out = Vec::new();
        top_n_indices(&vals, 3, &mut out);
        let mut top = out.clone();
        top.sort_unstable();
        assert_eq!(top, vec![1, 3, 5]);
        // n >= len is the identity.
        top_n_indices(&vals, 6, &mut out);
        assert_eq!(out.len(), 6);
    }

    /// One small sweep under the given backend, over both the dense
    /// layout and a spilling sparse layout, with a full (identity) and a
    /// gathered selection — shared body for the blocking `backend_*` CI
    /// smoke tests.
    fn run_backend_smoke(backend: KernelBackend) {
        let k = 24usize;
        let n_entries = 9usize;
        let mut rng = Rng::new(7);
        for &lane_cap in &[24usize, 3] {
            let mut a = RespArena::new();
            a.reset(k, n_entries, lane_cap);
            let mut kern = SweepKernel::new();
            kern.set_backend(backend);
            let mut th: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 4.0).collect();
            let mut col: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 2.0).collect();
            let mut ps: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 50.0 + 1.0).collect();
            for e in 0..n_entries {
                a.set_one_hot(e, rng.below(k));
            }
            let counts: Vec<f32> =
                (0..n_entries).map(|e| (e % 3 + 1) as f32).collect();
            let docs: Vec<u32> = vec![0; n_entries];
            let sel_all: Vec<u32> = (0..k as u32).collect();
            let mut sel6: Vec<u32> = Vec::new();
            while sel6.len() < 6 {
                let cand = rng.below(k) as u32;
                if !sel6.contains(&cand) {
                    sel6.push(cand);
                }
            }
            for sel in [&sel_all[..], &sel6[..]] {
                let mut fr = vec![0.0f32; sel.len()];
                sweep_word(
                    &mut a, &mut kern, sel, 0, &docs, &counts, &mut th,
                    &mut col, &mut ps, 0.01, 0.01, 0.32, &mut fr,
                );
                for v in th.iter().chain(col.iter()).chain(ps.iter()) {
                    assert!(v.is_finite(), "non-finite stat under {backend:?}");
                }
                for &r in &fr {
                    assert!(r.is_finite() && r >= 0.0);
                }
            }
            // Renormalization preserves each entry's responsibility mass.
            for e in 0..n_entries {
                let mass: f32 = (0..k).map(|t| a.get(e, t)).sum();
                assert!(
                    (mass - 1.0).abs() < 1e-4,
                    "entry {e} mass {mass} under {backend:?} cap={lane_cap}"
                );
            }
        }
    }

    #[test]
    fn backend_scalar_smoke() {
        run_backend_smoke(KernelBackend::Scalar);
    }

    #[test]
    fn backend_simd_smoke() {
        run_backend_smoke(KernelBackend::Simd);
    }

    #[test]
    fn backend_auto_smoke() {
        run_backend_smoke(KernelBackend::Auto);
    }

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-5 + 1e-4 * a.abs().max(b.abs())
    }

    /// Scalar-vs-SIMD equivalence on the training kernel over random
    /// (K, sel, lane_cap, spill) configurations: tolerance-class outputs
    /// on every mutated buffer, and the degenerate-skip guards
    /// (`m_old ≤ 1e-12`, `z ≤ 0`) taken identically in both backends.
    /// On AVX2 hosts this exercises the vector tiers; elsewhere the
    /// portable 4-lane tier — both must agree with the scalar reference.
    #[test]
    fn simd_training_kernel_matches_scalar_within_tolerance() {
        let mut rng = Rng::new(1234);
        for &(k, n_sel, lane_cap) in &[
            (8usize, 8usize, 8usize), // dense, identity sel, one full vector
            (32, 32, 32),             // dense, identity sel
            (33, 33, 33),             // dense, identity sel, odd tail
            (64, 10, 64),             // dense, gathered subset
            (32, 10, 4),              // sparse lanes + spill
            (48, 12, 2),              // heavy spill
        ] {
            let n_entries = 10usize;
            let mut a_s = RespArena::new();
            a_s.reset(k, n_entries, lane_cap);
            let mut a_v = RespArena::new();
            a_v.reset(k, n_entries, lane_cap);
            let mut ks = SweepKernel::new();
            let mut kv = SweepKernel::new();
            kv.set_backend(KernelBackend::Simd);

            let mut th_s: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 4.0).collect();
            let mut col_s: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 2.0).collect();
            let mut ps_s: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 50.0 + 1.0).collect();
            let mut th_v = th_s.clone();
            let mut col_v = col_s.clone();
            let mut ps_v = ps_s.clone();

            let sel: Vec<u32> = if n_sel >= k {
                (0..k as u32).collect()
            } else {
                let mut s = Vec::new();
                while s.len() < n_sel {
                    let cand = rng.below(k) as u32;
                    if !s.contains(&cand) {
                        s.push(cand);
                    }
                }
                s
            };
            for e in 0..n_entries {
                // Entry 0 gets an engineered zero on `sel` when the
                // subset is proper: its one-hot topic lies outside, so
                // the m_old guard must trip in BOTH backends.
                let t = if e == 0 && n_sel < k {
                    (0..k).find(|t| !sel.contains(&(*t as u32))).unwrap()
                } else {
                    rng.below(k)
                };
                a_s.set_one_hot(e, t);
                a_v.set_one_hot(e, t);
            }

            for round in 0..3 {
                let mut fr_s = vec![0.0f32; sel.len()];
                let mut fr_v = vec![0.0f32; sel.len()];
                ks.begin_selection(k, &sel);
                kv.begin_selection(k, &sel);
                for e in 0..n_entries {
                    let c = (e % 3 + 1) as f32;
                    let out_s = update_entry(
                        &mut a_s, &mut ks, e, &sel, c, &mut th_s,
                        &mut col_s, &mut ps_s, 0.01, 0.01, 0.32, &mut fr_s,
                    );
                    let out_v = update_entry(
                        &mut a_v, &mut kv, e, &sel, c, &mut th_v,
                        &mut col_v, &mut ps_v, 0.01, 0.01, 0.32, &mut fr_v,
                    );
                    assert_eq!(
                        out_s.updated, out_v.updated,
                        "guard divergence (k={k} e={e} round={round})"
                    );
                    assert!(close(out_s.m_old, out_v.m_old));
                }
                ks.end_selection(&sel);
                kv.end_selection(&sel);
                for i in 0..k {
                    assert!(
                        close(th_s[i], th_v[i]),
                        "theta (k={k} cap={lane_cap} i={i}): {} vs {}",
                        th_s[i],
                        th_v[i]
                    );
                    assert!(close(col_s[i], col_v[i]));
                    assert!(close(ps_s[i], ps_v[i]));
                }
                for j in 0..sel.len() {
                    assert!(close(fr_s[j], fr_v[j]));
                }
                for e in 0..n_entries {
                    for t in 0..k {
                        assert!(
                            close(a_s.get(e, t), a_v.get(e, t)),
                            "mu (k={k} cap={lane_cap} e={e} t={t})"
                        );
                    }
                }
            }
            if lane_cap == 2 {
                assert!(a_v.spill_len() > 0, "spill path never exercised");
            }
        }
    }

    /// Same equivalence for the fold-in (theta-only) kernel variant —
    /// and phi stays frozen under both backends.
    #[test]
    fn simd_theta_kernel_matches_scalar_within_tolerance() {
        let mut rng = Rng::new(99);
        for &(k, n_sel, lane_cap) in &[
            (24usize, 24usize, 24usize), // dense, identity sel
            (40, 9, 40),                 // dense, gathered subset
            (32, 8, 3),                  // sparse lanes + spill
        ] {
            let n_entries = 8usize;
            let mut a_s = RespArena::new();
            a_s.reset(k, n_entries, lane_cap);
            let mut a_v = RespArena::new();
            a_v.reset(k, n_entries, lane_cap);
            let mut ks = SweepKernel::new();
            let mut kv = SweepKernel::new();
            kv.set_backend(KernelBackend::Simd);

            let mut th_s: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 4.0).collect();
            let mut th_v = th_s.clone();
            let col: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 2.0 + 0.1).collect();
            let phisum: Vec<f32> =
                (0..k).map(|_| rng.next_f32() * 50.0 + 1.0).collect();
            let (col0, ps0) = (col.clone(), phisum.clone());

            let sel: Vec<u32> = if n_sel >= k {
                (0..k as u32).collect()
            } else {
                let mut s = Vec::new();
                while s.len() < n_sel {
                    let cand = rng.below(k) as u32;
                    if !s.contains(&cand) {
                        s.push(cand);
                    }
                }
                s
            };
            for e in 0..n_entries {
                let t = rng.below(k);
                a_s.set_one_hot(e, t);
                a_v.set_one_hot(e, t);
            }

            for _round in 0..3 {
                let mut fr_s = vec![0.0f32; sel.len()];
                let mut fr_v = vec![0.0f32; sel.len()];
                ks.begin_selection(k, &sel);
                kv.begin_selection(k, &sel);
                for e in 0..n_entries {
                    let c = (e % 2 + 1) as f32;
                    let out_s = update_entry_theta(
                        &mut a_s, &mut ks, e, &sel, c, &mut th_s, &col,
                        &phisum, 0.01, 0.01, 0.32, &mut fr_s,
                    );
                    let out_v = update_entry_theta(
                        &mut a_v, &mut kv, e, &sel, c, &mut th_v, &col,
                        &phisum, 0.01, 0.01, 0.32, &mut fr_v,
                    );
                    assert_eq!(out_s.updated, out_v.updated);
                }
                ks.end_selection(&sel);
                kv.end_selection(&sel);
                for i in 0..k {
                    assert!(close(th_s[i], th_v[i]));
                }
                for j in 0..sel.len() {
                    assert!(close(fr_s[j], fr_v[j]));
                }
                for e in 0..n_entries {
                    for t in 0..k {
                        assert!(close(a_s.get(e, t), a_v.get(e, t)));
                    }
                }
            }
            assert_eq!(col, col0, "theta kernel mutated phi column");
            assert_eq!(phisum, ps0, "theta kernel mutated phisum");
        }
    }

    /// Satellite contract: arena weight lanes and every kernel scratch
    /// buffer stay 32-byte aligned through reset, regrow, spill, and
    /// selection growth.
    #[test]
    fn arena_and_kernel_scratch_stay_32_byte_aligned() {
        let mut a = RespArena::new();
        a.reset(64, 100, 64);
        assert_eq!(a.weights.as_ptr() as usize % 32, 0);
        // Sparse regrow, then force lane appends + spill inserts.
        a.reset(512, 300, 2);
        assert_eq!(a.weights.as_ptr() as usize % 32, 0);
        for e in 0..300 {
            for t in 0..4 {
                a.set(e, t * 7, 0.25);
            }
        }
        assert!(a.spill_len() > 0);
        assert_eq!(a.weights.as_ptr() as usize % 32, 0);

        let mut kern = SweepKernel::new();
        let sel: Vec<u32> = (0..7u32).collect();
        kern.begin_selection(512, &sel);
        assert_eq!(kern.mu_old.as_ptr() as usize % 32, 0);
        assert_eq!(kern.scratch_mu.as_ptr() as usize % 32, 0);
        assert_eq!(kern.delta.as_ptr() as usize % 32, 0);
        kern.end_selection(&sel);
        // Scratch growth across a much larger selection.
        let sel2: Vec<u32> = (0..500u32).collect();
        kern.begin_selection(512, &sel2);
        assert_eq!(kern.mu_old.as_ptr() as usize % 32, 0);
        assert_eq!(kern.scratch_mu.as_ptr() as usize % 32, 0);
        assert_eq!(kern.delta.as_ptr() as usize % 32, 0);
        kern.end_selection(&sel2);
    }
}
