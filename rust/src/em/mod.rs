//! The EM framework for LDA (paper §2): shared sufficient-statistics
//! types, the Eq. 11 / Eq. 13 E-step inner loops, the slot-compressed
//! responsibility arena and shared sweep kernel ([`resp`]), the four
//! EM algorithms — batch ([`bem`]), incremental ([`iem`]), stepwise
//! ([`sem`]) and the paper's contribution, fast online EM ([`foem`])
//! with its subset schedule ([`schedule`]) — plus the fold-in inference
//! engine for unseen documents ([`infer`]).

pub mod bem;
pub mod foem;
pub mod iem;
pub mod infer;
pub mod resp;
pub mod schedule;
pub mod sem;
pub mod simd;

use crate::corpus::sparse::DocWordMatrix;
use crate::LdaParams;

/// Global topic-word sufficient statistics `phi_hat_{K×W}` (+ topic
/// totals), stored word-column-contiguous: `phi[w*k .. (w+1)*k]` is word
/// `w`'s K-vector.  Column-contiguity is what makes parameter streaming
/// (§3.2) a sequential-I/O problem — one column = one disk page run.
#[derive(Debug, Clone, PartialEq)]
pub struct PhiStats {
    pub k: usize,
    pub n_words: usize,
    data: Vec<f32>,
    /// `phisum[k] = sum_w phi[w][k]` (the paper's phi_hat(k)).
    pub phisum: Vec<f32>,
}

impl PhiStats {
    pub fn zeros(k: usize, n_words: usize) -> Self {
        Self { k, n_words, data: vec![0.0; k * n_words], phisum: vec![0.0; k] }
    }

    #[inline]
    pub fn word(&self, w: usize) -> &[f32] {
        &self.data[w * self.k..(w + 1) * self.k]
    }

    #[inline]
    pub fn word_mut(&mut self, w: usize) -> &mut [f32] {
        &mut self.data[w * self.k..(w + 1) * self.k]
    }

    /// Add `delta` into column `w` and the totals.
    #[inline]
    pub fn add_to_word(&mut self, w: usize, delta: &[f32]) {
        let col = &mut self.data[w * self.k..(w + 1) * self.k];
        for ((c, s), &d) in col.iter_mut().zip(self.phisum.iter_mut()).zip(delta) {
            *c += d;
            *s += d;
        }
    }

    /// Split borrow: word column `w` and the totals, both mutable.
    /// Needed by the IEM-style in-place exclude/include updates.
    #[inline]
    pub fn word_and_sum_mut(&mut self, w: usize) -> (&mut [f32], &mut [f32]) {
        let col = &mut self.data[w * self.k..(w + 1) * self.k];
        (col, &mut self.phisum)
    }

    /// Recompute `phisum` from scratch (used after bulk overwrites).
    pub fn rebuild_phisum(&mut self) {
        self.phisum.iter_mut().for_each(|s| *s = 0.0);
        for w in 0..self.n_words {
            let col = &self.data[w * self.k..(w + 1) * self.k];
            for (s, &c) in self.phisum.iter_mut().zip(col) {
                *s += c;
            }
        }
    }

    /// Total accumulated mass `sum_k phisum(k)`.
    pub fn total_mass(&self) -> f64 {
        self.phisum.iter().map(|&x| x as f64).sum()
    }

    /// Normalized topic-word probability `phi_w(k)` (Eq. 10).
    pub fn prob(&self, w: usize, params: &LdaParams) -> Vec<f32> {
        let bm1 = params.bm1();
        let wbm1 = params.wbm1(self.n_words);
        self.word(w)
            .iter()
            .zip(&self.phisum)
            .map(|(&pw, &ps)| (pw + bm1) / (ps + wbm1))
            .collect()
    }

    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    pub fn raw_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Materialize a read-only [`crate::store::PhiSnapshot`] of the given
    /// columns (`words` sorted ascending) — the in-memory counterpart of
    /// `PhiColumnStore::snapshot_columns`, used by the staged trainer
    /// phases ([`crate::exec::pipeline`]) so SEM's compute phase is
    /// store-free like FOEM's.
    pub fn snapshot_columns(&self, words: &[u32]) -> crate::store::PhiSnapshot {
        let k = self.k;
        let mut data = vec![0.0f32; words.len() * k];
        for (i, &w) in words.iter().enumerate() {
            data[i * k..(i + 1) * k].copy_from_slice(self.word(w as usize));
        }
        crate::store::PhiSnapshot::from_parts(k, words.to_vec(), data)
    }
}

/// Read-only access to normalizable topic-word statistics — what the
/// evaluator ([`crate::eval`]) and the fold-in E-step actually need from a
/// model. Implemented by the dense [`PhiStats`] and by the sparse
/// [`EvalPhiView`], so evaluation can run against a column subset without
/// densifying a paged store (which would defeat its memory bound).
pub trait PhiAccess {
    /// Number of topics K.
    fn k(&self) -> usize;

    /// Full vocabulary size W (the Eq. 10 denominator uses `W*(beta-1)`
    /// regardless of which columns are materialized).
    fn n_words(&self) -> usize;

    /// Topic totals `phisum(k)`.
    fn phisum(&self) -> &[f32];

    /// Column of word `w`. Panics if the word is not materialized.
    fn word(&self, w: usize) -> &[f32];
}

impl PhiAccess for PhiStats {
    fn k(&self) -> usize {
        self.k
    }

    fn n_words(&self) -> usize {
        self.n_words
    }

    fn phisum(&self) -> &[f32] {
        &self.phisum
    }

    fn word(&self, w: usize) -> &[f32] {
        &self.data[w * self.k..(w + 1) * self.k]
    }
}

/// A sparse, evaluation-ready view of the topic-word statistics: the
/// columns of a chosen word set plus the resident topic totals. For a
/// paged store this is O(|words| * K) memory instead of the O(K * W)
/// `export_dense` would cost — the driver evaluates through this view so
/// periodic evaluation respects the §3.2 memory bound (and its column
/// reads show up in [`crate::store::IoStats`] like any other stream
/// access).
#[derive(Debug, Clone)]
pub struct EvalPhiView {
    k: usize,
    /// FULL vocabulary size (denominator dimension), not `words.len()`.
    n_words: usize,
    /// Sorted global word ids materialized in `data`.
    words: Vec<u32>,
    /// `words.len() * k`, column-contiguous.
    data: Vec<f32>,
    phisum: Vec<f32>,
    /// Per-materialized-column zone-map stats, parallel to `words`
    /// (empty = none attached). `Some` entries are exact
    /// ([`crate::store::ColumnStats`] is populated from a paged store's
    /// column directory without decoding); `None` means unknown.
    col_stats: Vec<Option<crate::store::ColumnStats>>,
}

impl EvalPhiView {
    /// Copy the given columns out of a dense [`PhiStats`].
    pub fn from_dense(phi: &PhiStats, words: &[u32]) -> Self {
        Self::from_snapshot(
            phi.snapshot_columns(words),
            phi.phisum.clone(),
            phi.n_words,
        )
    }

    /// Wrap a store snapshot (already one non-dirtying sequential read per
    /// column) plus the algorithm's resident topic totals.
    pub fn from_snapshot(
        snap: crate::store::PhiSnapshot,
        phisum: Vec<f32>,
        n_words: usize,
    ) -> Self {
        let (k, words, data) = snap.into_parts();
        debug_assert_eq!(phisum.len(), k);
        Self { k, n_words, words, data, phisum, col_stats: Vec::new() }
    }

    /// Attach per-column zone-map stats (parallel to [`Self::words`], as
    /// returned by `PhiColumnStore::column_stats` at view-build time).
    pub fn with_column_stats(
        mut self,
        col_stats: Vec<Option<crate::store::ColumnStats>>,
    ) -> Self {
        debug_assert!(
            col_stats.is_empty() || col_stats.len() == self.words.len(),
            "column stats must be parallel to the materialized words"
        );
        self.col_stats = col_stats;
        self
    }

    /// Zone-map stats for materialized word `w`, if attached and known.
    /// `Some` answers are exact — in particular `nnz == 0` certifies the
    /// column is all-zero without touching its data.
    pub fn column_stats(&self, w: u32) -> Option<crate::store::ColumnStats> {
        let i = self.words.binary_search(&w).ok()?;
        self.col_stats.get(i).copied().flatten()
    }

    /// How many materialized columns the zone maps certify as all-zero
    /// (cold): those columns decoded nothing at build time and consumers
    /// like the fold-in scheduler can skip them outright.
    pub fn known_cold_columns(&self) -> usize {
        self.col_stats
            .iter()
            .filter(|s| matches!(s, Some(st) if st.nnz == 0))
            .count()
    }

    /// Number of materialized columns.
    pub fn n_columns(&self) -> usize {
        self.words.len()
    }

    /// The sorted global word ids materialized in this view.
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    /// Whether word `w`'s column is materialized — callers that cannot
    /// tolerate the [`PhiAccess::word`] panic (e.g. the serving layer
    /// validating request vocabularies) check this first.
    pub fn has_word(&self, w: u32) -> bool {
        self.words.binary_search(&w).is_ok()
    }

    /// Gather per-shard views into one — the serve-side merge of the
    /// vocabulary-sharded fleet's scatter ([`crate::shard`]): each shard
    /// contributes the columns of its contiguous word range, in shard
    /// (= ascending word-range) order, and all parts carry the same
    /// coordinator-resident `phisum`, so concatenation is the whole
    /// merge. Panics if the parts are empty, disagree on K/phisum, or
    /// their word ranges are not disjoint and ascending — those are
    /// router bugs, not data conditions.
    pub fn merge_shards(parts: Vec<EvalPhiView>) -> Self {
        let mut it = parts.into_iter();
        let mut out = it.next().expect("merge_shards: no shard views");
        debug_assert!(
            out.col_stats.is_empty() || out.col_stats.len() == out.words.len(),
            "merge_shards: part stats not parallel to its words"
        );
        for part in it {
            let any_stats = !out.col_stats.is_empty();
            assert_eq!(out.k, part.k, "merge_shards: K mismatch");
            assert_eq!(
                out.phisum, part.phisum,
                "merge_shards: shards disagree on the topic totals"
            );
            if let (Some(&last), Some(&first)) =
                (out.words.last(), part.words.first())
            {
                assert!(
                    last < first,
                    "merge_shards: shard word ranges overlap or are out of \
                     order ({last} >= {first})"
                );
            }
            // A view without stats contributes explicit unknowns so the
            // merged stats stay parallel to the merged words.
            if any_stats || !part.col_stats.is_empty() {
                out.col_stats.resize(out.words.len(), None);
                if part.col_stats.is_empty() {
                    out.col_stats
                        .resize(out.words.len() + part.words.len(), None);
                } else {
                    out.col_stats.extend(part.col_stats);
                }
            }
            out.words.extend(part.words);
            out.data.extend(part.data);
            out.n_words = out.n_words.max(part.n_words);
        }
        out
    }
}

impl PhiAccess for EvalPhiView {
    fn k(&self) -> usize {
        self.k
    }

    fn n_words(&self) -> usize {
        self.n_words
    }

    fn phisum(&self) -> &[f32] {
        &self.phisum
    }

    fn word(&self, w: usize) -> &[f32] {
        let i = self
            .words
            .binary_search(&(w as u32))
            .unwrap_or_else(|_| panic!("EvalPhiView: word {w} not captured"));
        &self.data[i * self.k..(i + 1) * self.k]
    }
}

/// Document-topic sufficient statistics `theta_hat_{K×D}`, row-contiguous
/// per document.
#[derive(Debug, Clone, PartialEq)]
pub struct ThetaStats {
    pub k: usize,
    pub n_docs: usize,
    data: Vec<f32>,
}

impl ThetaStats {
    pub fn zeros(k: usize, n_docs: usize) -> Self {
        Self { k, n_docs, data: vec![0.0; k * n_docs] }
    }

    /// Like [`ThetaStats::zeros`], but over a recycled backing buffer
    /// (grow-only scratch discipline — see [`crate::exec::scratch`]).
    pub fn from_buffer(k: usize, n_docs: usize, mut buf: Vec<f32>) -> Self {
        buf.clear();
        buf.resize(k * n_docs, 0.0);
        Self { k, n_docs, data: buf }
    }

    /// Hand the backing buffer back for recycling.
    pub fn into_buffer(self) -> Vec<f32> {
        self.data
    }

    /// Wrap an already-filled row-contiguous buffer (`k * n_docs` long) —
    /// the fold-in engine ([`infer`]) assembles per-shard results into
    /// one buffer and lifts it into stats without a copy.
    pub fn from_raw(k: usize, n_docs: usize, data: Vec<f32>) -> Self {
        debug_assert_eq!(data.len(), k * n_docs);
        Self { k, n_docs, data }
    }

    #[inline]
    pub fn doc(&self, d: usize) -> &[f32] {
        &self.data[d * self.k..(d + 1) * self.k]
    }

    #[inline]
    pub fn doc_mut(&mut self, d: usize) -> &mut [f32] {
        &mut self.data[d * self.k..(d + 1) * self.k]
    }

    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Per-document total `sum_k theta_hat_d(k)` (== doc token mass once
    /// stats are consistent).
    pub fn doc_total(&self, d: usize) -> f32 {
        self.doc(d).iter().sum()
    }

    /// Normalized document-topic probability `theta_d(k)` (Eq. 9).
    pub fn prob(&self, d: usize, params: &LdaParams) -> Vec<f32> {
        let am1 = params.am1();
        let row = self.doc(d);
        let denom = row.iter().sum::<f32>() + params.n_topics as f32 * am1;
        row.iter().map(|&t| (t + am1) / denom).collect()
    }

    pub fn raw(&self) -> &[f32] {
        &self.data
    }
}

/// A privately-filled sufficient-statistics delta over a sparse set of
/// vocabulary columns — the unit of communication of the parallel E-step
/// engine ([`crate::exec`]).
///
/// Each shard worker accumulates its updates into its own `SsDelta`; the
/// executor then [`SsDelta::merge`]s the per-shard deltas in a fixed
/// (shard-index) order and applies the result to the global stores with
/// [`SsDelta::apply_to_store`], so a run is reproducible for a given seed
/// and worker count.
#[derive(Debug, Clone, PartialEq)]
pub struct SsDelta {
    pub k: usize,
    /// Sorted global word ids this delta covers.
    words: Vec<u32>,
    /// `words.len() * k`; column `i` belongs to `words[i]`.
    data: Vec<f32>,
    /// Per-topic totals: `phisum[k] = sum_w data[w][k]`.
    pub phisum: Vec<f32>,
}

impl SsDelta {
    pub fn zeros(k: usize, words: Vec<u32>) -> Self {
        debug_assert!(
            words.windows(2).all(|w| w[0] < w[1]),
            "SsDelta words must be sorted and distinct"
        );
        let n = words.len();
        Self { k, words, data: vec![0.0; k * n], phisum: vec![0.0; k] }
    }

    /// The sorted global word ids covered.
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    pub fn n_columns(&self) -> usize {
        self.words.len()
    }

    /// Delta-local index of global word `w`, if covered.
    #[inline]
    pub fn index_of(&self, w: u32) -> Option<usize> {
        self.words.binary_search(&w).ok()
    }

    /// Column by delta-local index.
    #[inline]
    pub fn col(&self, idx: usize) -> &[f32] {
        &self.data[idx * self.k..(idx + 1) * self.k]
    }

    /// Add `v` at (delta-local column `idx`, `topic`), updating totals.
    #[inline]
    pub fn add_at(&mut self, idx: usize, topic: usize, v: f32) {
        self.data[idx * self.k + topic] += v;
        self.phisum[topic] += v;
    }

    /// Accumulate `other` into `self`. `other`'s words must be a subset
    /// of this delta's words (shard vocabularies are subsets of the
    /// minibatch vocabulary). Calling this per shard in shard order is
    /// the executor's deterministic reduction.
    pub fn merge(&mut self, other: &SsDelta) {
        assert_eq!(self.k, other.k, "K mismatch in SsDelta::merge");
        for (i, &w) in other.words.iter().enumerate() {
            let j = self
                .index_of(w)
                .expect("SsDelta::merge: word not covered by accumulator");
            let src = &other.data[i * self.k..(i + 1) * self.k];
            let dst = &mut self.data[j * self.k..(j + 1) * self.k];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        for (p, &q) in self.phisum.iter_mut().zip(&other.phisum) {
            *p += q;
        }
    }

    /// Apply to a column store plus the resident topic totals: one
    /// read-modify-write per covered column (the Fig. 4 line 8/15 I/O
    /// discipline, now at merge time instead of per entry).
    pub fn apply_to_store<S: crate::store::PhiColumnStore>(
        &self,
        store: &mut S,
        phisum: &mut [f32],
    ) {
        for (i, &w) in self.words.iter().enumerate() {
            store.merge_column(w as usize, self.col(i));
        }
        for (p, &d) in phisum.iter_mut().zip(&self.phisum) {
            *p += d;
        }
    }

    /// Total signed mass of the delta.
    pub fn total_mass(&self) -> f64 {
        self.phisum.iter().map(|&x| x as f64).sum()
    }
}

/// The Eq. 11 E-step for one non-zero entry: writes the *unnormalized*
/// responsibility into `mu` and returns the normalizer `Z`.
///
/// This is the hottest loop in the whole system; it is kept branch-free
/// and slice-length-pinned so LLVM auto-vectorizes it.
#[inline]
pub fn estep_unnormalized(
    theta_d: &[f32],
    phi_w: &[f32],
    phisum: &[f32],
    am1: f32,
    bm1: f32,
    wbm1: f32,
    mu: &mut [f32],
) -> f32 {
    let k = mu.len();
    let (theta_d, phi_w, phisum) = (&theta_d[..k], &phi_w[..k], &phisum[..k]);
    let mut z = 0.0f32;
    for i in 0..k {
        let v = (theta_d[i] + am1) * (phi_w[i] + bm1) / (phisum[i] + wbm1);
        mu[i] = v;
        z += v;
    }
    z
}

/// [`estep_unnormalized`] dispatched on a resolved kernel tier:
/// `Scalar` runs the reference loop above bit-for-bit; the SIMD tiers
/// run the explicitly vectorized equivalent from [`simd`]
/// (tolerance-class, not bit-identical — reductions reassociate).
/// Callers resolve the tier once per run/shard, not per entry.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn estep_unnormalized_isa(
    isa: simd::KernelIsa,
    theta_d: &[f32],
    phi_w: &[f32],
    phisum: &[f32],
    am1: f32,
    bm1: f32,
    wbm1: f32,
    mu: &mut [f32],
) -> f32 {
    if isa == simd::KernelIsa::Scalar {
        estep_unnormalized(theta_d, phi_w, phisum, am1, bm1, wbm1, mu)
    } else {
        let k = mu.len();
        simd::estep_unnorm(
            isa,
            &theta_d[..k],
            &phi_w[..k],
            &phisum[..k],
            am1,
            bm1,
            wbm1,
            mu,
        )
    }
}

/// Full E-step (Eq. 11): normalized responsibility into `mu`.
#[inline]
pub fn estep(
    theta_d: &[f32],
    phi_w: &[f32],
    phisum: &[f32],
    params: &LdaParams,
    w_dim: usize,
    mu: &mut [f32],
) {
    let z = estep_unnormalized(
        theta_d,
        phi_w,
        phisum,
        params.am1(),
        params.bm1(),
        params.wbm1(w_dim),
        mu,
    );
    if z > 0.0 {
        let inv = 1.0 / z;
        mu.iter_mut().for_each(|m| *m *= inv);
    }
}

/// [`estep`] dispatched on a resolved kernel tier — `Scalar` performs
/// [`estep`]'s float ops bit-for-bit (see [`estep_unnormalized_isa`]).
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn estep_isa(
    isa: simd::KernelIsa,
    theta_d: &[f32],
    phi_w: &[f32],
    phisum: &[f32],
    params: &LdaParams,
    w_dim: usize,
    mu: &mut [f32],
) {
    let z = estep_unnormalized_isa(
        isa,
        theta_d,
        phi_w,
        phisum,
        params.am1(),
        params.bm1(),
        params.wbm1(w_dim),
        mu,
    );
    if z > 0.0 {
        let inv = 1.0 / z;
        mu.iter_mut().for_each(|m| *m *= inv);
    }
}

/// Random hard initialization of responsibilities: all of an entry's mass
/// on one uniformly random topic. This is the standard LDA-EM
/// initialization (equivalent to GS's random topic assignment) and keeps
/// initial sufficient statistics consistent by construction.
pub fn init_hard_assignments(
    docs: &DocWordMatrix,
    k: usize,
    rng: &mut crate::util::Rng,
    mut sink: impl FnMut(usize, u32, f32, usize),
) {
    for d in 0..docs.n_docs {
        for (w, c) in docs.iter_doc(d) {
            let topic = rng.below(k);
            sink(d, w, c, topic);
        }
    }
}

/// Training-set word log-likelihood of a (theta, phi) state:
/// `sum_{w,d} x_{w,d} log sum_k theta_d(k) phi_w(k)` with the Eq. 9/10
/// normalizations. `exp(-ll/ntokens)` is the paper's training perplexity.
///
/// The per-token mixture probability and the theta normalizer accumulate
/// in f64: a K-term f32 sum loses ~`K·ε` relative accuracy, which is
/// material at K ≥ 1024 (same eval-path fix as
/// `eval::predictive_perplexity`).
pub fn train_log_likelihood(
    docs: &DocWordMatrix,
    theta: &ThetaStats,
    phi: &PhiStats,
    params: &LdaParams,
) -> f64 {
    let am1 = params.am1();
    let bm1 = params.bm1();
    let wbm1 = params.wbm1(phi.n_words);
    let kam1 = (params.n_topics as f32 * am1) as f64;
    let mut ll = 0.0f64;
    for d in 0..docs.n_docs {
        let trow = theta.doc(d);
        let tden =
            trow.iter().map(|&x| x as f64).sum::<f64>() + kam1;
        for (w, c) in docs.iter_doc(d) {
            let pcol = phi.word(w as usize);
            let mut p = 0.0f64;
            for i in 0..params.n_topics {
                p += (trow[i] + am1) as f64 / tden * (pcol[i] + bm1) as f64
                    / (phi.phisum[i] + wbm1) as f64;
            }
            ll += c as f64 * p.max(1e-300).ln();
        }
    }
    ll
}

/// Perplexity from a log-likelihood total and token mass (Eq. 21 outer
/// form).
pub fn perplexity(ll: f64, n_tokens: f64) -> f64 {
    (-ll / n_tokens.max(1.0)).exp()
}

/// Report of one algorithm invocation on one minibatch (or one batch
/// sweep), consumed by the coordinator's metrics and the experiment
/// harness.
#[derive(Debug, Clone, Copy, Default)]
pub struct MinibatchReport {
    /// Inner sweeps actually run before the convergence check fired.
    pub inner_iters: usize,
    /// Seconds of work spent on this minibatch. For phased trainers this
    /// is the sum of the stage/compute/apply durations — under pipelining
    /// those overlap *other* batches' phases in wall time, so per-batch
    /// values sum to busy time, not elapsed time.
    pub seconds: f64,
    /// Training log-likelihood of the minibatch at exit.
    pub train_ll: f64,
    /// Token mass of the minibatch.
    pub tokens: f64,
    /// Peak bytes of responsibility storage this minibatch — the
    /// [`crate::em::resp::RespArena`] backing store (summed across
    /// concurrent shard workers), i.e. the O(NNZ·S) working-set claim
    /// made observable. `0` for algorithms without per-entry
    /// responsibilities.
    pub resp_bytes: usize,
    /// Bytes of auxiliary per-minibatch scratch (doc-topic buffers,
    /// column copies, sweep-order/selection scratch), summed across
    /// concurrent shard workers.
    pub scratch_bytes: usize,
}

impl MinibatchReport {
    pub fn train_perplexity(&self) -> f64 {
        perplexity(self.train_ll, self.tokens)
    }
}

/// Convergence test the paper uses per minibatch (§4): stop when the
/// training-perplexity delta between two successive checks is below
/// `threshold` (default 10), checking every `check_every` sweeps
/// (footnote 8: every 10).
#[derive(Debug, Clone, Copy)]
pub struct ConvergenceCheck {
    pub threshold: f64,
    pub check_every: usize,
    pub max_iters: usize,
    last: Option<f64>,
}

impl ConvergenceCheck {
    pub fn new(threshold: f64, check_every: usize, max_iters: usize) -> Self {
        Self { threshold, check_every, max_iters, last: None }
    }

    /// Paper defaults.
    pub fn paper() -> Self {
        Self::new(10.0, 10, 500)
    }

    /// Feed the perplexity measured at iteration `t` (0-based); returns
    /// true when converged or out of budget.
    pub fn update(&mut self, t: usize, perplexity: f64) -> bool {
        if t + 1 >= self.max_iters {
            return true;
        }
        let fire = (t + 1) % self.check_every == 0;
        if !fire {
            return false;
        }
        let done = match self.last {
            Some(prev) => (prev - perplexity).abs() < self.threshold,
            None => false,
        };
        self.last = Some(perplexity);
        done
    }

    pub fn reset(&mut self) {
        self.last = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn params(k: usize) -> LdaParams {
        LdaParams::paper_defaults(k)
    }

    #[test]
    fn phi_stats_add_and_sum() {
        let mut phi = PhiStats::zeros(3, 4);
        phi.add_to_word(2, &[1.0, 2.0, 3.0]);
        phi.add_to_word(0, &[0.5, 0.0, 0.0]);
        assert_eq!(phi.word(2), &[1.0, 2.0, 3.0]);
        assert_eq!(phi.phisum, vec![1.5, 2.0, 3.0]);
        assert_eq!(phi.total_mass(), 6.5);
        let mut phi2 = phi.clone();
        phi2.rebuild_phisum();
        assert_eq!(phi.phisum, phi2.phisum);
    }

    #[test]
    fn phi_prob_normalizes_over_words() {
        let mut phi = PhiStats::zeros(2, 3);
        phi.add_to_word(0, &[4.0, 1.0]);
        phi.add_to_word(1, &[2.0, 2.0]);
        phi.add_to_word(2, &[1.0, 6.0]);
        let p = params(2);
        let mut per_topic = [0.0f32; 2];
        for w in 0..3 {
            let pr = phi.prob(w, &p);
            for k in 0..2 {
                per_topic[k] += pr[k];
            }
        }
        // sum_w phi_w(k) == 1 per topic
        assert!((per_topic[0] - 1.0).abs() < 1e-5);
        assert!((per_topic[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn theta_prob_normalizes_over_topics() {
        let mut th = ThetaStats::zeros(4, 2);
        th.doc_mut(0).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let pr = th.prob(0, &params(4));
        let s: f32 = pr.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ss_delta_accumulates_and_applies() {
        let mut d = SsDelta::zeros(3, vec![2u32, 7]);
        d.add_at(0, 1, 2.0);
        d.add_at(1, 0, 1.5);
        d.add_at(1, 1, 0.5);
        assert_eq!(d.col(0), &[0.0, 2.0, 0.0]);
        assert_eq!(d.col(1), &[1.5, 0.5, 0.0]);
        assert_eq!(d.phisum, vec![1.5, 2.5, 0.0]);
        assert_eq!(d.index_of(7), Some(1));
        assert_eq!(d.index_of(3), None);
        assert!((d.total_mass() - 4.0).abs() < 1e-9);

        use crate::store::PhiColumnStore;
        let mut store = crate::store::InMemoryPhi::zeros(3, 10);
        let mut phisum = vec![0.0f32; 3];
        d.apply_to_store(&mut store, &mut phisum);
        assert_eq!(store.read_column(2), vec![0.0, 2.0, 0.0]);
        assert_eq!(store.read_column(7), vec![1.5, 0.5, 0.0]);
        assert_eq!(phisum, vec![1.5, 2.5, 0.0]);
    }

    #[test]
    fn ss_delta_merge_aligns_word_subsets() {
        let mut acc = SsDelta::zeros(2, vec![1u32, 4, 9]);
        let mut a = SsDelta::zeros(2, vec![4u32]);
        a.add_at(0, 0, 3.0);
        let mut b = SsDelta::zeros(2, vec![1u32, 9]);
        b.add_at(0, 1, 1.0);
        b.add_at(1, 0, 2.0);
        acc.merge(&a);
        acc.merge(&b);
        assert_eq!(acc.col(0), &[0.0, 1.0]);
        assert_eq!(acc.col(1), &[3.0, 0.0]);
        assert_eq!(acc.col(2), &[2.0, 0.0]);
        assert_eq!(acc.phisum, vec![5.0, 1.0]);
    }

    #[test]
    fn estep_matches_manual() {
        let p = params(2);
        let theta = [1.0f32, 3.0];
        let phi = [2.0f32, 2.0];
        let phisum = [10.0f32, 20.0];
        let w = 100usize;
        let mut mu = [0.0f32; 2];
        estep(&theta, &phi, &phisum, &p, w, &mut mu);
        let am1 = p.am1();
        let bm1 = p.bm1();
        let wbm1 = p.wbm1(w);
        let u0 = (1.0 + am1) * (2.0 + bm1) / (10.0 + wbm1);
        let u1 = (3.0 + am1) * (2.0 + bm1) / (20.0 + wbm1);
        assert!((mu[0] - u0 / (u0 + u1)).abs() < 1e-6);
        assert!((mu[0] + mu[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn init_hard_assignments_covers_all_entries() {
        let docs = DocWordMatrix::from_triplets(
            2,
            3,
            &[(0, 0, 2.0), (0, 1, 1.0), (1, 2, 4.0)],
        );
        let mut rng = Rng::new(0);
        let mut seen = 0usize;
        let mut mass = 0.0f32;
        init_hard_assignments(&docs, 5, &mut rng, |_, _, c, topic| {
            assert!(topic < 5);
            seen += 1;
            mass += c;
        });
        assert_eq!(seen, 3);
        assert_eq!(mass, 7.0);
    }

    #[test]
    fn convergence_check_fires_on_small_delta() {
        let mut c = ConvergenceCheck::new(10.0, 10, 1000);
        // first check at t=9 establishes baseline
        for t in 0..9 {
            assert!(!c.update(t, 1000.0));
        }
        assert!(!c.update(9, 1000.0));
        // big improvement: keep going
        for t in 10..19 {
            assert!(!c.update(t, 900.0));
        }
        assert!(!c.update(19, 900.0));
        // small delta now: converged at the next check
        for t in 20..29 {
            assert!(!c.update(t, 895.0));
        }
        assert!(c.update(29, 895.0));
    }

    #[test]
    fn convergence_check_respects_budget() {
        let mut c = ConvergenceCheck::new(0.0, 10, 5);
        assert!(!c.update(0, 1.0));
        assert!(c.update(4, 1.0));
    }

    #[test]
    fn perplexity_of_uniform_model() {
        // uniform over V words => perplexity == V
        let v = 64f64;
        let ll = (1.0 / v).ln() * 100.0;
        assert!((perplexity(ll, 100.0) - v).abs() < 1e-6);
    }
}
