//! Residual-based dynamic scheduling (paper §3.1, Fig. 5).
//!
//! IEM's responsibilities converge to fixed points; the triangle
//! inequality (Eq. 34) bounds the distance to the fixed point from below
//! by the inter-iteration residual (Eq. 35), so updating the
//! largest-residual coordinates first propagates information fastest.
//! FOEM tracks residuals accumulated per vocabulary word
//! `r_w(k) = sum_d x_{w,d} |mu^t - mu^{t-1}|` (Eq. 36) and
//! `r_w = sum_k r_w(k)` (Eq. 37), then each sweep
//!   * visits words in descending `r_w` order (top `lambda_w * W_s`), and
//!   * per word updates only the `lambda_k * K` topics with the largest
//!     `r_w(k)` (partial selection, not a full sort — §3.1's "partial
//!     sorting" note), renormalizing within the subset by Eq. 38.

/// How many topics to schedule per word.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TopicSubset {
    /// All K topics (plain IEM).
    All,
    /// `ceil(lambda_k * K)` topics.
    Fraction(f32),
    /// Fixed count — the paper's production setting `lambda_k * K = 10`.
    Fixed(usize),
}

impl TopicSubset {
    pub fn size(&self, k: usize) -> usize {
        match *self {
            TopicSubset::All => k,
            TopicSubset::Fraction(f) => {
                // Relative epsilon so f32 artifacts like 0.1 ->
                // 0.100000001 don't bump ceil() to the next integer.
                let x = f as f64 * k as f64;
                ((x - x.abs() * 1e-6).ceil() as usize).clamp(1, k)
            }
            TopicSubset::Fixed(n) => n.clamp(1, k),
        }
    }
}

/// Residual state for one minibatch: `[W_s local words][K]` residual
/// matrix, per-word totals, and scratch for top-k selection.
pub struct ResidualScheduler {
    pub k: usize,
    /// Number of local words W_s.
    pub n_local: usize,
    /// `r_w(k)`, local-word-major.
    r_wk: Vec<f32>,
    /// `r_w = sum_k r_w(k)`.
    r_w: Vec<f32>,
    /// Scratch index buffer for partial selection.
    idx: Vec<u32>,
}

impl ResidualScheduler {
    pub fn new(k: usize, n_local: usize) -> Self {
        Self {
            k,
            n_local,
            r_wk: vec![0.0; k * n_local],
            r_w: vec![0.0; n_local],
            idx: (0..k as u32).collect(),
        }
    }

    #[inline]
    pub fn word_residuals(&self, lw: usize) -> &[f32] {
        &self.r_wk[lw * self.k..(lw + 1) * self.k]
    }

    #[inline]
    pub fn word_total(&self, lw: usize) -> f32 {
        self.r_w[lw]
    }

    /// Overwrite word `lw`'s residual vector with freshly accumulated
    /// values (Fig. 4 line 12 computes them during the column visit).
    pub fn set_word_residuals(&mut self, lw: usize, fresh: &[f32]) {
        let row = &mut self.r_wk[lw * self.k..(lw + 1) * self.k];
        row.copy_from_slice(fresh);
        self.r_w[lw] = fresh.iter().sum();
    }

    /// Update only the entries in `topics`, leaving the rest (their
    /// residual information is retained so unvisited topics can win
    /// selection later — without this, scheduling starves).
    pub fn set_word_residuals_sparse(
        &mut self,
        lw: usize,
        topics: &[u32],
        fresh: &[f32],
    ) {
        let row = &mut self.r_wk[lw * self.k..(lw + 1) * self.k];
        for (&t, &f) in topics.iter().zip(fresh) {
            row[t as usize] = f;
        }
        self.r_w[lw] = row.iter().sum();
    }

    /// Select the `subset.size(k)` topics of word `lw` with the largest
    /// residuals. Returns a sorted-by-residual-descending slice of topic
    /// ids. `O(K)` via `select_nth_unstable`, matching the paper's
    /// partial-sorting cost argument.
    pub fn top_topics(&mut self, lw: usize, subset: TopicSubset) -> &[u32] {
        let n = subset.size(self.k);
        if n >= self.k {
            // Identity order; no selection needed.
            for (i, x) in self.idx.iter_mut().enumerate() {
                *x = i as u32;
            }
            return &self.idx;
        }
        let row = &self.r_wk[lw * self.k..(lw + 1) * self.k];
        for (i, x) in self.idx.iter_mut().enumerate() {
            *x = i as u32;
        }
        self.idx.select_nth_unstable_by(n - 1, |&a, &b| {
            row[b as usize]
                .partial_cmp(&row[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        &self.idx[..n]
    }

    /// Word visit order for one sweep: local word ids sorted by `r_w`
    /// descending, truncated to `ceil(lambda_w * W_s)`.
    pub fn word_order(&self, lambda_w: f32) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.n_local as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            self.r_w[b as usize]
                .partial_cmp(&self.r_w[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let keep = ((lambda_w as f64 * self.n_local as f64).ceil() as usize)
            .clamp(1, self.n_local);
        order.truncate(keep);
        order
    }

    /// Total residual mass (convergence diagnostic: → 0 as IEM converges).
    pub fn total_residual(&self) -> f64 {
        self.r_w.iter().map(|&x| x as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_sizes() {
        assert_eq!(TopicSubset::All.size(100), 100);
        assert_eq!(TopicSubset::Fraction(0.1).size(100), 10);
        assert_eq!(TopicSubset::Fraction(0.101).size(100), 11);
        assert_eq!(TopicSubset::Fixed(10).size(100), 10);
        assert_eq!(TopicSubset::Fixed(10).size(4), 4);
        assert_eq!(TopicSubset::Fraction(1e-9).size(100), 1);
    }

    #[test]
    fn top_topics_returns_true_top_set() {
        let mut s = ResidualScheduler::new(6, 2);
        s.set_word_residuals(0, &[0.1, 5.0, 0.2, 9.0, 0.0, 3.0]);
        let mut top: Vec<u32> =
            s.top_topics(0, TopicSubset::Fixed(3)).to_vec();
        top.sort_unstable();
        assert_eq!(top, vec![1, 3, 5]);
    }

    #[test]
    fn top_topics_all_is_identity() {
        let mut s = ResidualScheduler::new(4, 1);
        s.set_word_residuals(0, &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(s.top_topics(0, TopicSubset::All).len(), 4);
    }

    #[test]
    fn word_order_sorts_by_residual() {
        let mut s = ResidualScheduler::new(2, 4);
        s.set_word_residuals(0, &[1.0, 0.0]);
        s.set_word_residuals(1, &[5.0, 1.0]);
        s.set_word_residuals(2, &[0.0, 0.5]);
        s.set_word_residuals(3, &[2.0, 2.0]);
        assert_eq!(s.word_order(1.0), vec![1, 3, 0, 2]);
        assert_eq!(s.word_order(0.5), vec![1, 3]);
        assert_eq!(s.word_order(0.0), vec![1]); // clamped to >= 1
    }

    #[test]
    fn sparse_update_preserves_unvisited_residuals() {
        let mut s = ResidualScheduler::new(4, 1);
        s.set_word_residuals(0, &[1.0, 2.0, 3.0, 4.0]);
        s.set_word_residuals_sparse(0, &[1, 3], &[0.5, 0.1]);
        assert_eq!(s.word_residuals(0), &[1.0, 0.5, 3.0, 0.1]);
        assert!((s.word_total(0) - 4.6).abs() < 1e-6);
    }

    #[test]
    fn total_residual_tracks_mass() {
        let mut s = ResidualScheduler::new(2, 2);
        s.set_word_residuals(0, &[1.0, 1.0]);
        s.set_word_residuals(1, &[0.5, 0.0]);
        assert!((s.total_residual() - 2.5).abs() < 1e-9);
    }
}
