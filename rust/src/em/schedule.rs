//! Residual-based dynamic scheduling policy (paper §3.1, Fig. 5).
//!
//! IEM's responsibilities converge to fixed points; the triangle
//! inequality (Eq. 34) bounds the distance to the fixed point from below
//! by the inter-iteration residual (Eq. 35), so updating the
//! largest-residual coordinates first propagates information fastest.
//! FOEM tracks residuals accumulated per vocabulary word
//! `r_w(k) = sum_d x_{w,d} |mu^t - mu^{t-1}|` (Eq. 36) and
//! `r_w = sum_k r_w(k)` (Eq. 37), then each sweep
//!   * visits words in descending `r_w` order (top `lambda_w * W_s`), and
//!   * per word updates only the `lambda_k * K` topics with the largest
//!     `r_w(k)` (partial selection, not a full sort — §3.1's "partial
//!     sorting" note), renormalizing within the subset by Eq. 38.
//!
//! This module holds the *policy knob* ([`TopicSubset`], how many topics
//! to schedule). The mechanism lives where it runs: the trainers derive
//! the word visit order directly from their resident `r_totals` (the
//! `r_w` of Eq. 37, streamed with the residual matrix per §3.2), and the
//! per-word topic selection is [`crate::em::resp::top_n_indices`] over
//! the word's residual column, feeding the shared sweep kernel in
//! [`crate::em::resp`].

/// How many topics to schedule per word.
///
/// # Examples
///
/// [`TopicSubset::size`] resolves the policy against a concrete K —
/// fractions round up with a float-artifact guard, fixed counts clamp
/// into `[1, K]`:
///
/// ```
/// use foem::em::schedule::TopicSubset;
///
/// assert_eq!(TopicSubset::All.size(100), 100);
/// assert_eq!(TopicSubset::Fixed(10).size(100), 10);
/// assert_eq!(TopicSubset::Fixed(10).size(4), 4); // clamped to K
/// assert_eq!(TopicSubset::Fraction(0.1).size(100), 10);
/// // A subset that covers all of K degrades to the dense (`All`) path
/// // in every consumer (trainers, fold-in, serving).
/// assert_eq!(TopicSubset::Fixed(64).size(32), 32);
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TopicSubset {
    /// All K topics (plain IEM).
    All,
    /// `ceil(lambda_k * K)` topics.
    Fraction(f32),
    /// Fixed count — the paper's production setting `lambda_k * K = 10`.
    Fixed(usize),
}

impl TopicSubset {
    pub fn size(&self, k: usize) -> usize {
        match *self {
            TopicSubset::All => k,
            TopicSubset::Fraction(f) => {
                // Relative epsilon so f32 artifacts like 0.1 ->
                // 0.100000001 don't bump ceil() to the next integer.
                let x = f as f64 * k as f64;
                ((x - x.abs() * 1e-6).ceil() as usize).clamp(1, k)
            }
            TopicSubset::Fixed(n) => n.clamp(1, k),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::em::resp::top_n_indices;

    #[test]
    fn subset_sizes() {
        assert_eq!(TopicSubset::All.size(100), 100);
        assert_eq!(TopicSubset::Fraction(0.1).size(100), 10);
        assert_eq!(TopicSubset::Fraction(0.101).size(100), 11);
        assert_eq!(TopicSubset::Fixed(10).size(100), 10);
        assert_eq!(TopicSubset::Fixed(10).size(4), 4);
        assert_eq!(TopicSubset::Fraction(1e-9).size(100), 1);
    }

    #[test]
    fn subset_sized_selection_returns_true_top_set() {
        // The §3.1 partial selection at a TopicSubset-derived size must
        // return the true top set of a residual column.
        let res = [0.1f32, 5.0, 0.2, 9.0, 0.0, 3.0];
        let n = TopicSubset::Fixed(3).size(res.len());
        let mut top = Vec::new();
        top_n_indices(&res, n, &mut top);
        top.sort_unstable();
        assert_eq!(top, vec![1, 3, 5]);
    }

    #[test]
    fn all_subset_selection_is_identity_sized() {
        let res = [0.0f32, 1.0, 2.0, 3.0];
        let n = TopicSubset::All.size(res.len());
        let mut top = Vec::new();
        top_n_indices(&res, n, &mut top);
        assert_eq!(top.len(), 4);
    }
}
