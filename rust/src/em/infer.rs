//! Fold-in inference engine: fit `theta` for **unseen** documents with
//! the topic-word statistics frozen — the serving path behind the
//! paper's predictive-perplexity protocol (§2.4) and its "infers the
//! topic distribution from the previously unseen documents incrementally
//! with constant memory" claim.
//!
//! The engine reuses the training machinery instead of duplicating it:
//!
//! * **Shared kernel.** Scheduled configurations run every non-zero
//!   entry through the Eq. 13/38 exclude–recompute–renormalize kernel
//!   ([`resp::update_entry_theta`] — the theta-only M-step variant of
//!   the training kernel: an unseen document's mass was never
//!   accumulated into `phi`, so `col`/`phisum` stay frozen) over a
//!   slot-compressed [`resp::RespArena`], so the per-document working
//!   set is O(NNZ·S), not O(NNZ·K).
//! * **Residual scheduling** (§3.1, per document instead of per word):
//!   each document keeps a K-length residual row; every sweep updates
//!   only its top `n_sel` residual topics plus ε-greedy exploration
//!   slots, and a document whose residual mass falls below the per-token
//!   tolerance is skipped for the rest of the fold-in — FOEM's inner
//!   convergence cutoff, applied per doc.
//! * **Worker parallelism.** Documents are independent given a frozen
//!   `phi`, so the engine shards the document range across
//!   [`crate::exec::ParallelExecutor::run_ranged`] workers; worker
//!   buffers come from the grow-only [`crate::exec::scratch`] pool, so a
//!   steady-state evaluation loop allocates almost nothing.
//! * **Storage-generic.** Generic over [`PhiAccess`], so it serves the
//!   dense in-memory [`super::PhiStats`] and the paged store's sparse
//!   [`super::EvalPhiView`] (the §3.2 memory-bounded evaluation path —
//!   its column reads are counted in `IoStats` at snapshot time)
//!   identically.
//!
//! **Determinism / equivalence contract.** `TopicSubset::All` selects
//! the *synchronous* full-K sweep — per document, Eq. 11
//! responsibilities from the pre-sweep theta, rebuilt row — which with
//! one worker and `tol = 0` performs bit-for-bit the float ops of the
//! historical dense `Bem::fold_in` (retained verbatim as
//! `dense_ref::fold_in` under `#[cfg(test)]`, the same oracle pattern
//! as `em::foem::dense_ref`). Scheduled subsets run the incremental kernel
//! and stay within a small relative perplexity of the dense protocol
//! (tolerance-tested). Every configuration is deterministic in
//! `(seed, n_workers)`: shard `i` draws its hard-init stream from a
//! seed derived from `(seed, i)`, with shard 0 using `seed` itself so a
//! 1-worker run reproduces the reference exactly. See `rust/DESIGN.md`
//! §9.

use super::resp;
use super::schedule::TopicSubset;
use super::{estep_isa, PhiAccess, ThetaStats};
use crate::corpus::sparse::DocWordMatrix;
use crate::util::Rng;
use crate::LdaParams;

/// Fold-in engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct FoldInConfig {
    /// Topics scheduled per document and sweep. `All` (or any size that
    /// clamps to K) selects the synchronous dense sweep — the historical
    /// fold-in protocol; smaller subsets run the scheduled incremental
    /// kernel.
    pub subset: TopicSubset,
    /// ε-greedy exploration slots inside the scheduled subset (ignored
    /// by the dense path) — same discovery mechanism as
    /// `FoemConfig::explore_slots`.
    pub explore_slots: usize,
    /// Sweep budget.
    pub max_sweeps: usize,
    /// Per-document convergence cutoff: a document is skipped once the
    /// responsibility mass moved per token falls below this, and the
    /// shard stops early once every document converged. `0.0` disables
    /// the cutoff (fixed budget — the bitwise-reference configuration).
    pub tol: f64,
    /// Worker threads ([`crate::exec::ParallelExecutor::run_ranged`]
    /// over contiguous document ranges). `1` is the exact serial path.
    pub n_workers: usize,
    /// E-step kernel backend ([`crate::em::simd::KernelBackend`]):
    /// `Scalar` is the bit-identity reference; the SIMD tiers are
    /// tolerance-class equivalents.
    pub kernel_backend: crate::em::simd::KernelBackend,
}

impl FoldInConfig {
    /// The historical dense protocol: synchronous full-K sweeps, fixed
    /// budget, serial. Bit-identical to the pre-engine `Bem::fold_in`.
    pub fn dense(max_sweeps: usize) -> Self {
        Self {
            subset: TopicSubset::All,
            explore_slots: 0,
            max_sweeps,
            tol: 0.0,
            n_workers: 1,
            kernel_backend: crate::em::simd::KernelBackend::Scalar,
        }
    }

    /// The paper-shaped scheduled protocol: `n_sel` topics per document
    /// per sweep plus exploration, with the per-document cutoff on.
    /// Exploration defaults to 2 slots: enough for topic discovery,
    /// while keeping entry support — and with it the O(NNZ·S) arena —
    /// from widening toward K over a long sweep budget.
    pub fn scheduled(n_sel: usize, max_sweeps: usize) -> Self {
        Self {
            subset: TopicSubset::Fixed(n_sel),
            explore_slots: 2,
            max_sweeps,
            tol: 1e-2,
            n_workers: 1,
            kernel_backend: crate::em::simd::KernelBackend::Scalar,
        }
    }
}

/// Telemetry of one fold-in invocation.
#[derive(Debug, Clone, Copy, Default)]
pub struct FoldInReport {
    /// Sweeps actually run (max across shards).
    pub sweeps: usize,
    /// Peak responsibility-arena bytes, summed across concurrent shards
    /// (`0` for the memoryless dense path).
    pub resp_bytes: usize,
    /// Auxiliary scratch bytes (theta, residual rows, kernel buffers),
    /// summed across concurrent shards.
    pub scratch_bytes: usize,
}

/// Fold-in: fit theta for `docs` with `phi` frozen. See the module docs
/// for the scheduling and determinism contract.
pub fn fold_in<P: PhiAccess + Sync>(
    phi: &P,
    params: &LdaParams,
    docs: &DocWordMatrix,
    cfg: &FoldInConfig,
    seed: u64,
) -> ThetaStats {
    fold_in_with_report(phi, params, docs, cfg, seed).0
}

/// [`fold_in`] plus the working-set / convergence telemetry.
pub fn fold_in_with_report<P: PhiAccess + Sync>(
    phi: &P,
    params: &LdaParams,
    docs: &DocWordMatrix,
    cfg: &FoldInConfig,
    seed: u64,
) -> (ThetaStats, FoldInReport) {
    let k = params.n_topics;
    let exec = crate::exec::ParallelExecutor::new(cfg.n_workers);
    let outs = exec.run_ranged(docs.n_docs, |i, range| {
        fold_shard(phi, params, docs, cfg, range, shard_seed(seed, i as u64))
    });
    // Assemble the contiguous per-shard theta chunks into one buffer and
    // recycle the shard buffers.
    let mut data = vec![0.0f32; k * docs.n_docs];
    let mut report = FoldInReport::default();
    let mut cursor = 0usize;
    for out in outs {
        data[cursor..cursor + out.theta.len()].copy_from_slice(&out.theta);
        cursor += out.theta.len();
        crate::exec::scratch::put_f32(out.theta);
        report.sweeps = report.sweeps.max(out.sweeps);
        report.resp_bytes += out.resp_bytes;
        report.scratch_bytes += out.scratch_bytes;
    }
    debug_assert_eq!(cursor, data.len());
    (ThetaStats::from_raw(k, docs.n_docs, data), report)
}

/// Shard `i`'s hard-init stream seed. Shard 0 uses `seed` verbatim so a
/// 1-worker run draws exactly the historical `Bem::fold_in` init stream.
#[inline]
fn shard_seed(seed: u64, i: u64) -> u64 {
    seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// One shard worker's output: its contiguous theta rows (a recycled pool
/// buffer — the caller copies and returns it) plus telemetry.
struct ShardOut {
    theta: Vec<f32>,
    sweeps: usize,
    resp_bytes: usize,
    scratch_bytes: usize,
}

/// Fold one contiguous document range in. Dispatches on the *effective*
/// subset size: a subset that covers all K topics runs the synchronous
/// dense sweep (the bitwise-reference path); anything smaller runs the
/// residual-scheduled incremental kernel.
fn fold_shard<P: PhiAccess>(
    phi: &P,
    params: &LdaParams,
    docs: &DocWordMatrix,
    cfg: &FoldInConfig,
    range: std::ops::Range<usize>,
    seed: u64,
) -> ShardOut {
    let n_sel = cfg.subset.size(params.n_topics);
    if n_sel >= params.n_topics {
        fold_shard_dense(phi, params, docs, cfg, range, seed)
    } else {
        fold_shard_scheduled(phi, params, docs, cfg, range, seed, n_sel)
    }
}

/// Synchronous full-K fold-in of one document range: per document, all
/// responsibilities from the pre-sweep theta row ([`estep`], Eq. 11),
/// re-accumulated into a fresh row — exactly the historical
/// `Bem::fold_in` float ops (no responsibility storage needed: the
/// synchronous iterate is memoryless in mu). With `tol > 0`, converged
/// documents are skipped and the shard exits once all have converged.
fn fold_shard_dense<P: PhiAccess>(
    phi: &P,
    params: &LdaParams,
    docs: &DocWordMatrix,
    cfg: &FoldInConfig,
    range: std::ops::Range<usize>,
    seed: u64,
) -> ShardOut {
    let k = params.n_topics;
    let n = range.len();
    let w_dim = phi.n_words();
    // Resolve the kernel tier once per shard, not per token.
    let isa = cfg.kernel_backend.resolve();
    let mut ws = crate::exec::scratch::take();
    let mut theta = crate::exec::scratch::take_f32();
    theta.resize(n * k, 0.0);
    let mut mu = std::mem::take(&mut ws.col_a);
    mu.clear();
    mu.resize(k, 0.0);
    let mut fresh = std::mem::take(&mut ws.col_b);
    fresh.clear();
    fresh.resize(k, 0.0);

    // Hard init (the historical init_hard_assignments stream).
    let mut rng = Rng::new(seed);
    for (ld, d) in range.clone().enumerate() {
        for (_w, c) in docs.iter_doc(d) {
            let topic = rng.below(k);
            theta[ld * k + topic] += c;
        }
    }

    let use_cutoff = cfg.tol > 0.0;
    let doc_lens: Vec<f32> =
        range.clone().map(|d| docs.doc_len(d)).collect();
    let mut active: Vec<bool> = range
        .clone()
        .map(|d| {
            let (s, e) = docs.doc_range(d);
            s != e
        })
        .collect();

    let mut sweeps = 0usize;
    for _ in 0..cfg.max_sweeps {
        sweeps += 1;
        let mut any_moved = !use_cutoff;
        for (ld, d) in range.clone().enumerate() {
            if use_cutoff && !active[ld] {
                continue;
            }
            let th = &mut theta[ld * k..(ld + 1) * k];
            fresh.iter_mut().for_each(|x| *x = 0.0);
            for (w, c) in docs.iter_doc(d) {
                estep_isa(
                    isa,
                    th,
                    phi.word(w as usize),
                    phi.phisum(),
                    params,
                    w_dim,
                    &mut mu,
                );
                for i in 0..k {
                    fresh[i] += c * mu[i];
                }
            }
            if use_cutoff {
                let mut moved = 0.0f64;
                for i in 0..k {
                    moved += (fresh[i] - th[i]).abs() as f64;
                }
                if moved < cfg.tol * doc_lens[ld] as f64 {
                    active[ld] = false;
                } else {
                    any_moved = true;
                }
            }
            th.copy_from_slice(&fresh[..k]);
        }
        if use_cutoff && !any_moved {
            break;
        }
    }

    let scratch_bytes = theta.len() * 4
        + mu.len() * 4
        + fresh.len() * 4
        + doc_lens.len() * 4
        + active.len();
    ws.col_a = mu;
    ws.col_b = fresh;
    crate::exec::scratch::put(ws);
    ShardOut { theta, sweeps, resp_bytes: 0, scratch_bytes }
}

/// Residual-scheduled fold-in of one document range through the shared
/// theta-only kernel over a slot-compressed arena (`n_sel < K`).
#[allow(clippy::too_many_arguments)]
fn fold_shard_scheduled<P: PhiAccess>(
    phi: &P,
    params: &LdaParams,
    docs: &DocWordMatrix,
    cfg: &FoldInConfig,
    range: std::ops::Range<usize>,
    seed: u64,
    n_sel: usize,
) -> ShardOut {
    let k = params.n_topics;
    let n = range.len();
    let am1 = params.am1();
    let bm1 = params.bm1();
    let wbm1 = params.wbm1(phi.n_words());
    let entry_start = docs.doc_ptr[range.start] as usize;
    let nnz = docs.doc_ptr[range.end] as usize - entry_start;

    let mut ws = crate::exec::scratch::take();
    let mut arena = std::mem::take(&mut ws.arena);
    arena.reset(k, nnz, resp::lane_capacity(n_sel, cfg.explore_slots, k));
    let mut kern = std::mem::take(&mut ws.kern);
    // Pooled scratch is grow-only and can carry a stale tier.
    kern.set_backend(cfg.kernel_backend);
    let mut theta = crate::exec::scratch::take_f32();
    theta.resize(n * k, 0.0);
    // Per-document residual rows `r_d(k)` + resident totals — the §3.1
    // scheduling state, per doc instead of per word.
    let mut res = std::mem::take(&mut ws.col_a);
    res.clear();
    res.resize(n * k, 0.0);
    let mut r_tot = std::mem::take(&mut ws.col_b);
    r_tot.clear();
    r_tot.resize(n, 0.0);

    // Hard init: one-hot responsibilities accumulated into theta; the
    // moved mass seeds the residuals so selection immediately favors
    // each document's assigned topics (Fig. 4 line 3's pattern).
    let mut rng = Rng::new(seed);
    {
        let mut e = 0usize;
        for (ld, d) in range.clone().enumerate() {
            for (_w, c) in docs.iter_doc(d) {
                let topic = rng.below(k);
                arena.set_one_hot(e, topic);
                theta[ld * k + topic] += c;
                res[ld * k + topic] += c;
                r_tot[ld] += c;
                e += 1;
            }
        }
    }

    let use_cutoff = cfg.tol > 0.0;
    let doc_lens: Vec<f32> =
        range.clone().map(|d| docs.doc_len(d)).collect();
    let tokens: f64 = doc_lens.iter().map(|&x| x as f64).sum();

    let mut sel: Vec<u32> = Vec::with_capacity(n_sel);
    let mut fresh_res = vec![0.0f32; n_sel];
    let mut sweeps = 0usize;
    for _ in 0..cfg.max_sweeps {
        sweeps += 1;
        let mut moved_total = 0.0f64;
        for ld in 0..n {
            if use_cutoff
                && (r_tot[ld] as f64) < cfg.tol * doc_lens[ld] as f64
            {
                continue;
            }
            let d = range.start + ld;
            let (s, en) = docs.doc_range(d);
            if s == en {
                continue;
            }
            // Topic selection from the doc's residual row (Eq. 36/37
            // applied per document) + ε-greedy exploration.
            let rcol = &mut res[ld * k..(ld + 1) * k];
            resp::top_n_indices(rcol, n_sel, &mut sel);
            if cfg.explore_slots > 0 {
                let swaps = cfg.explore_slots.min(n_sel / 2);
                for j in 0..swaps {
                    let cand = rng.below(k) as u32;
                    if !sel.contains(&cand) {
                        let pos = sel.len() - 1 - j;
                        sel[pos] = cand;
                    }
                }
            }
            // Selected residuals are re-accumulated below (assignment
            // semantics); track removed mass for the incremental total.
            let mut removed = 0.0f32;
            for &kk in &sel {
                removed += rcol[kk as usize];
                rcol[kk as usize] = 0.0;
            }
            fresh_res.iter_mut().for_each(|x| *x = 0.0);
            kern.begin_selection(k, &sel);
            let th = &mut theta[ld * k..(ld + 1) * k];
            let e_base = docs.doc_ptr[d] as usize - entry_start;
            for (off, i) in (s..en).enumerate() {
                resp::update_entry_theta(
                    &mut arena,
                    &mut kern,
                    e_base + off,
                    &sel,
                    docs.counts[i],
                    th,
                    phi.word(docs.word_ids[i] as usize),
                    phi.phisum(),
                    am1,
                    bm1,
                    wbm1,
                    &mut fresh_res,
                );
            }
            kern.end_selection(&sel);
            let mut doc_moved = 0.0f32;
            for (j, &kk) in sel.iter().enumerate() {
                rcol[kk as usize] += fresh_res[j];
                doc_moved += fresh_res[j];
            }
            r_tot[ld] = (r_tot[ld] - removed + doc_moved).max(0.0);
            moved_total += doc_moved as f64;
        }
        if use_cutoff && moved_total / tokens.max(1.0) < cfg.tol {
            break;
        }
    }

    let resp_bytes = arena.bytes();
    let scratch_bytes = theta.len() * 4
        + res.len() * 4
        + r_tot.len() * 4
        + doc_lens.len() * 4
        + kern.bytes()
        + (sel.capacity() + fresh_res.len()) * 4;
    ws.arena = arena;
    ws.kern = kern;
    ws.col_a = res;
    ws.col_b = r_tot;
    crate::exec::scratch::put(ws);
    ShardOut { theta, sweeps, resp_bytes, scratch_bytes }
}

/// The historical `Bem::fold_in` (pre-engine), kept verbatim as the
/// bitwise oracle for the dense/serial configuration — the same pattern
/// as `em::foem::dense_ref`. Only change: the per-doc `fresh` buffer is
/// hoisted out of the sweep loop (same values, no per-doc allocation —
/// the satellite fix the engine gets from the scratch pool).
#[cfg(test)]
pub(crate) mod dense_ref {
    use super::*;
    use crate::em::estep;

    pub fn fold_in<P: PhiAccess>(
        phi: &P,
        params: &LdaParams,
        docs: &DocWordMatrix,
        n_iters: usize,
        seed: u64,
    ) -> ThetaStats {
        let k = params.n_topics;
        let mut theta = ThetaStats::zeros(k, docs.n_docs);
        let mut rng = Rng::new(seed);
        super::super::init_hard_assignments(docs, k, &mut rng, |d, _, c, topic| {
            theta.doc_mut(d)[topic] += c;
        });
        let mut mu = vec![0.0f32; k];
        let mut fresh = vec![0.0f32; k];
        let w_dim = phi.n_words();
        for _ in 0..n_iters {
            for d in 0..docs.n_docs {
                fresh.iter_mut().for_each(|x| *x = 0.0);
                for (w, c) in docs.iter_doc(d) {
                    estep(
                        theta.doc(d),
                        phi.word(w as usize),
                        phi.phisum(),
                        params,
                        w_dim,
                        &mut mu,
                    );
                    for i in 0..k {
                        fresh[i] += c * mu[i];
                    }
                }
                theta.doc_mut(d).copy_from_slice(&fresh);
            }
        }
        theta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::em::bem::Bem;
    use crate::em::PhiStats;

    fn trained_phi(k: usize, seed: u64) -> (PhiStats, crate::corpus::Corpus) {
        let c = generate(&SyntheticConfig::small(), seed);
        let p = LdaParams::paper_defaults(k);
        let mut bem = Bem::init(&c.docs, p, seed);
        for _ in 0..6 {
            bem.sweep(&c.docs);
        }
        (bem.phi.clone(), c)
    }

    #[test]
    fn dense_serial_bit_identical_to_reference() {
        let k = 12;
        let (phi, c) = trained_phi(k, 31);
        let p = LdaParams::paper_defaults(k);
        let cfg = FoldInConfig::dense(10);
        let theta = fold_in(&phi, &p, &c.docs, &cfg, 99);
        let reference = dense_ref::fold_in(&phi, &p, &c.docs, 10, 99);
        assert_eq!(theta.raw().len(), reference.raw().len());
        for (i, (a, b)) in theta.raw().iter().zip(reference.raw()).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "theta diverged at {i}");
        }
    }

    #[test]
    fn oversized_fixed_subset_degrades_to_dense_path() {
        // Fixed(n >= K) clamps to All: same dispatch, same bits.
        let k = 8;
        let (phi, c) = trained_phi(k, 32);
        let p = LdaParams::paper_defaults(k);
        let mut cfg = FoldInConfig::dense(8);
        cfg.subset = TopicSubset::Fixed(10);
        let a = fold_in(&phi, &p, &c.docs, &cfg, 5);
        let b = fold_in(&phi, &p, &c.docs, &FoldInConfig::dense(8), 5);
        assert_eq!(a.raw(), b.raw());
    }

    #[test]
    fn fold_in_produces_consistent_theta() {
        // Per-doc theta mass == doc token mass, on BOTH paths (the
        // scheduled kernel is mass-preserving per entry).
        let k = 24;
        let (phi, c) = trained_phi(k, 33);
        let p = LdaParams::paper_defaults(k);
        for cfg in [FoldInConfig::dense(10), FoldInConfig::scheduled(8, 30)] {
            let theta = fold_in(&phi, &p, &c.docs, &cfg, 9);
            for d in 0..c.docs.n_docs {
                assert!(
                    (theta.doc_total(d) - c.docs.doc_len(d)).abs() < 1e-2,
                    "doc {d} ({cfg:?})"
                );
            }
        }
    }

    #[test]
    fn parallel_fold_in_is_deterministic() {
        let k = 16;
        let (phi, c) = trained_phi(k, 34);
        let p = LdaParams::paper_defaults(k);
        for mut cfg in [FoldInConfig::dense(6), FoldInConfig::scheduled(6, 20)]
        {
            cfg.n_workers = 4;
            let a = fold_in(&phi, &p, &c.docs, &cfg, 77);
            let b = fold_in(&phi, &p, &c.docs, &cfg, 77);
            assert_eq!(a.raw(), b.raw(), "{cfg:?}");
        }
    }

    #[test]
    fn per_doc_cutoff_stops_early_and_stays_close() {
        // A sharply trained phi (K matches the generator) makes per-doc
        // fold-in converge quickly, so the cutoff has real headroom.
        let k = 10;
        let c = generate(&SyntheticConfig::small(), 35);
        let p = LdaParams::paper_defaults(k);
        let mut bem = Bem::init(&c.docs, p, 35);
        for _ in 0..25 {
            bem.sweep(&c.docs);
        }
        let phi = bem.phi.clone();
        let full = FoldInConfig::dense(80);
        let (theta_full, rep_full) =
            fold_in_with_report(&phi, &p, &c.docs, &full, 3);
        assert_eq!(rep_full.sweeps, 80, "tol=0 must run the whole budget");
        let mut cut = full;
        cut.tol = 3e-3;
        let (theta_cut, rep_cut) =
            fold_in_with_report(&phi, &p, &c.docs, &cut, 3);
        assert!(
            rep_cut.sweeps < 80,
            "cutoff never fired: {} sweeps",
            rep_cut.sweeps
        );
        for d in 0..c.docs.n_docs {
            let l1: f32 = theta_full
                .doc(d)
                .iter()
                .zip(theta_cut.doc(d))
                .map(|(a, b)| (a - b).abs())
                .sum();
            assert!(
                l1 < c.docs.doc_len(d) * 0.08,
                "doc {d} drifted: L1 {l1}"
            );
        }
    }

    #[test]
    fn scheduled_engine_reports_sub_dense_working_set() {
        // A bounded sweep budget bounds each entry's cumulative support
        // (every sweep can insert at most the selected coordinates), so
        // the arena undercuts the dense nnz × K buffer.
        let k = 256;
        let (phi, c) = trained_phi(k, 36);
        let p = LdaParams::paper_defaults(k);
        let mut cfg = FoldInConfig::scheduled(10, 10);
        cfg.explore_slots = 0;
        let (_, rep) = fold_in_with_report(&phi, &p, &c.docs, &cfg, 1);
        let dense_bytes = c.docs.nnz() * k * 4;
        assert!(rep.resp_bytes > 0);
        assert!(
            rep.resp_bytes < dense_bytes,
            "arena {} not below dense {dense_bytes}",
            rep.resp_bytes
        );
    }

    #[test]
    fn empty_documents_are_handled() {
        let k = 6;
        let (phi, _) = trained_phi(k, 37);
        let p = LdaParams::paper_defaults(k);
        let r0: &[(u32, f32)] = &[(0, 2.0), (3, 1.0)];
        let r1: &[(u32, f32)] = &[]; // empty doc
        let r2: &[(u32, f32)] = &[(5, 4.0)];
        let docs = DocWordMatrix::from_rows(phi.n_words, &[r0, r1, r2]);
        for mut cfg in
            [FoldInConfig::dense(20), FoldInConfig::scheduled(3, 20)]
        {
            cfg.tol = 1e-3;
            let theta = fold_in(&phi, &p, &docs, &cfg, 4);
            assert_eq!(theta.doc_total(1), 0.0, "{cfg:?}");
            assert!((theta.doc_total(0) - 3.0).abs() < 1e-3);
            assert!((theta.doc_total(2) - 4.0).abs() < 1e-3);
        }
    }
}
