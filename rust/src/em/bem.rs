//! Batch EM for LDA (paper Fig. 1).
//!
//! Sweeps every non-zero of the document-word matrix, computing all
//! responsibilities from the *previous* iteration's sufficient statistics
//! (synchronous schedule — the paper notes this is exactly synchronous
//! belief propagation), then swaps in the freshly accumulated statistics.
//! Monotonically improves the LDA log-likelihood (Eq. 12).

use super::{
    perplexity, train_log_likelihood, ConvergenceCheck, MinibatchReport,
    PhiStats, ThetaStats,
};
use crate::corpus::sparse::DocWordMatrix;
use crate::util::{Rng, Timer};
use crate::LdaParams;

/// Batch EM trainer state.
pub struct Bem {
    pub params: LdaParams,
    pub theta: ThetaStats,
    pub phi: PhiStats,
    theta_new: ThetaStats,
    phi_new: PhiStats,
    /// Per-iteration training perplexity trace (for convergence plots).
    pub perplexity_trace: Vec<f64>,
}

impl Bem {
    /// Random hard initialization (Fig. 1 line 1).
    pub fn init(docs: &DocWordMatrix, params: LdaParams, seed: u64) -> Self {
        let k = params.n_topics;
        let mut theta = ThetaStats::zeros(k, docs.n_docs);
        let mut phi = PhiStats::zeros(k, docs.n_words);
        let mut rng = Rng::new(seed);
        super::init_hard_assignments(docs, k, &mut rng, |d, w, c, topic| {
            theta.doc_mut(d)[topic] += c;
            let col = phi.word_mut(w as usize);
            col[topic] += c;
            phi.phisum[topic] += c;
        });
        Self {
            params,
            theta_new: ThetaStats::zeros(k, docs.n_docs),
            phi_new: PhiStats::zeros(k, docs.n_words),
            theta,
            phi,
            perplexity_trace: Vec::new(),
        }
    }

    /// One synchronous sweep (Fig. 1 lines 3-7). Returns the training
    /// log-likelihood *under the pre-sweep parameters* (free to compute
    /// during the sweep).
    pub fn sweep(&mut self, docs: &DocWordMatrix) -> f64 {
        let k = self.params.n_topics;
        let w_dim = docs.n_words;
        let mut mu = vec![0.0f32; k];
        self.theta_new.fill_zero();
        self.phi_new.raw_mut().iter_mut().for_each(|x| *x = 0.0);
        self.phi_new.phisum.iter_mut().for_each(|x| *x = 0.0);
        let mut ll = 0.0f64;

        let kam1 = self.params.n_topics as f32 * self.params.am1();
        for d in 0..docs.n_docs {
            let theta_d = self.theta.doc(d);
            // z is computed from *unnormalized* theta stats; dividing by
            // the per-doc total turns it into the true word likelihood
            // p(w|d) = sum_k theta_d(k) phi_w(k).
            let doc_norm = ((docs.doc_len(d) + kam1) as f64).max(1e-300).ln();
            for (w, c) in docs.iter_doc(d) {
                let w = w as usize;
                let z = super::estep_unnormalized(
                    theta_d,
                    self.phi.word(w),
                    &self.phi.phisum,
                    self.params.am1(),
                    self.params.bm1(),
                    self.params.wbm1(w_dim),
                    &mut mu,
                );
                if z > 0.0 {
                    let inv = 1.0 / z;
                    mu.iter_mut().for_each(|m| *m *= inv);
                }
                ll += c as f64 * (((z as f64).max(1e-300)).ln() - doc_norm);
                // M-step accumulation (Fig. 1 line 6)
                let trow = self.theta_new.doc_mut(d);
                for i in 0..k {
                    trow[i] += c * mu[i];
                }
                let (col, phisum) = self.phi_new.word_and_sum_mut(w);
                for i in 0..k {
                    col[i] += c * mu[i];
                    phisum[i] += c * mu[i];
                }
            }
        }
        std::mem::swap(&mut self.theta, &mut self.theta_new);
        std::mem::swap(&mut self.phi, &mut self.phi_new);
        ll
    }

    /// Train until the paper's convergence test fires. Returns the usual
    /// report.
    pub fn train(
        &mut self,
        docs: &DocWordMatrix,
        check: &mut ConvergenceCheck,
    ) -> MinibatchReport {
        let timer = Timer::start();
        let tokens = docs.total_tokens();
        let mut iters = 0usize;
        let mut last_ll = f64::NEG_INFINITY;
        for t in 0..check.max_iters {
            last_ll = self.sweep(docs);
            let ppx = perplexity(last_ll, tokens);
            self.perplexity_trace.push(ppx);
            iters = t + 1;
            if check.update(t, ppx) {
                break;
            }
        }
        MinibatchReport {
            inner_iters: iters,
            seconds: timer.seconds(),
            train_ll: last_ll,
            tokens,
            ..Default::default()
        }
    }

    /// Exact training log-likelihood under current parameters.
    pub fn log_likelihood(&self, docs: &DocWordMatrix) -> f64 {
        train_log_likelihood(docs, &self.theta, &self.phi, &self.params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};

    fn small_docs() -> DocWordMatrix {
        generate(&SyntheticConfig::small(), 3).docs
    }

    #[test]
    fn init_stats_are_consistent() {
        let docs = small_docs();
        let p = LdaParams::paper_defaults(8);
        let bem = Bem::init(&docs, p, 0);
        // per-doc theta mass == doc token mass
        for d in 0..docs.n_docs {
            assert!(
                (bem.theta.doc_total(d) - docs.doc_len(d)).abs() < 1e-3,
                "doc {d}"
            );
        }
        // phi mass == corpus mass
        assert!((bem.phi.total_mass() - docs.total_tokens()).abs() < 1e-2);
    }

    #[test]
    fn sweep_preserves_mass() {
        let docs = small_docs();
        let p = LdaParams::paper_defaults(8);
        let mut bem = Bem::init(&docs, p, 0);
        bem.sweep(&docs);
        let total = docs.total_tokens();
        assert!((bem.phi.total_mass() - total).abs() < total * 1e-5);
        for d in 0..docs.n_docs {
            assert!((bem.theta.doc_total(d) - docs.doc_len(d)).abs() < 1e-2);
        }
    }

    #[test]
    fn log_likelihood_monotone_improves() {
        // Eq. 12: every sweep must not decrease the log-likelihood.
        let docs = small_docs();
        let p = LdaParams::paper_defaults(5);
        let mut bem = Bem::init(&docs, p, 1);
        let mut prev = bem.log_likelihood(&docs);
        for _ in 0..10 {
            bem.sweep(&docs);
            let ll = bem.log_likelihood(&docs);
            assert!(
                ll >= prev - prev.abs() * 1e-6,
                "LL decreased: {prev} -> {ll}"
            );
            prev = ll;
        }
    }

    #[test]
    fn train_converges_and_reports() {
        let docs = small_docs();
        let p = LdaParams::paper_defaults(5);
        let mut bem = Bem::init(&docs, p, 2);
        let mut check = ConvergenceCheck::new(5.0, 5, 200);
        let report = bem.train(&docs, &mut check);
        assert!(report.inner_iters >= 5);
        assert!(report.inner_iters < 200, "{}", report.inner_iters);
        assert!(report.train_perplexity() > 1.0);
        assert!(report.train_perplexity() < 500.0);
        // trace is recorded and generally decreasing front-to-back
        let tr = &bem.perplexity_trace;
        assert_eq!(tr.len(), report.inner_iters);
        assert!(tr[tr.len() - 1] <= tr[0]);
    }

}
