//! Fast online EM (FOEM) for LDA — the paper's contribution (Fig. 4).
//!
//! FOEM = memory-efficient SEM whose inner loop is the *time-efficient
//! IEM*:
//!
//! * **Online accumulation** (Eq. 33): with learning rate `rho_s = 1/s`
//!   the stepwise update reduces to plain accumulation of every
//!   minibatch's sufficient statistics into the global topic-word matrix,
//!   so the matrix is updated *in place* by IEM-style exclude/include
//!   steps and never rescaled.
//! * **Dynamic scheduling** (§3.1): per vocabulary word only the
//!   `lambda_k*K` topics with the largest residuals are recomputed
//!   (Eq. 36), renormalized within the subset by the mass-preserving
//!   Eq. 38; words are visited in descending residual order (Eq. 37).
//!   The residual matrix `r_{K×W}` is *global and streamed* exactly like
//!   `phi_hat` (§3.2: "the residual matrix can be also processed as a
//!   parameter stream") — it persists across minibatches, which is what
//!   makes FOEM's per-minibatch cost `O(20·NNZ_s + W_s·K log K)`
//!   (Table 3) rather than `O(K·NNZ_s)`: there is NO per-minibatch
//!   full-K scan.
//! * **Parameter streaming** (§3.2): both global matrices live behind
//!   [`PhiColumnStore`] backends; the minibatch is processed
//!   vocabulary-major so each column pair is acquired exactly once per
//!   sweep, and the minibatch's most frequent words are pinned in the
//!   stores' hot buffers.
//!
//! Resident state is O(K + W): the topic totals `phisum` and the
//! per-word residual totals `r_w` (Eq. 37).

use super::resp::{self, RespArena, SweepKernel};
use super::schedule::TopicSubset;
use super::{MinibatchReport, SsDelta};
use crate::corpus::vocab::VocabGrowth;
use crate::exec::ParallelExecutor;
use crate::store::{PhiColumnStore, PhiSnapshot};
use crate::stream::{Minibatch, MinibatchShard};
use crate::util::{Rng, Timer};
use crate::LdaParams;

/// FOEM tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct FoemConfig {
    /// Topics scheduled per word (paper production setting: `Fixed(10)`).
    pub topic_subset: TopicSubset,
    /// Fraction of local words visited per sweep (paper fixes 1.0).
    pub lambda_w: f32,
    /// Inner sweeps stop when the responsibility mass moved per token in
    /// the last sweep falls below this (the in-loop proxy for the
    /// paper's ΔPerplexity < 10 test).
    pub residual_tol: f64,
    /// Sweep budget per minibatch.
    pub max_inner_iters: usize,
    /// How many of the minibatch's most frequent words to pin in the
    /// stores' hot buffers (Fig. 4 line 2). 0 disables pinning.
    pub hot_words: usize,
    /// Exploration slots inside the scheduled subset: this many of the
    /// `lambda_k*K` selected topics are drawn uniformly instead of by
    /// residual. Without exploration, a topic whose residual never grew
    /// (because it was never computed) can stay invisible forever — the
    /// paper plugs this hole with a full-K first iteration per minibatch,
    /// which costs O(K·NNZ_s); epsilon-greedy slots achieve the same
    /// discovery at O(1) per entry, keeping the cost flat in K (see
    /// `rust/DESIGN.md` §8).
    pub explore_slots: usize,
    /// Compute the exact full-K training log-likelihood at minibatch exit
    /// (one O(K*NNZ_s) pass; needed for training-perplexity traces,
    /// skipped in throughput runs — predictive evaluation via
    /// `eval::predictive_perplexity` does not need it).
    pub exact_ll: bool,
    /// Lifelong mode: grow W as unseen words appear (`W ← W+1`, §3.2).
    pub open_vocabulary: bool,
    /// E-step worker threads for the parallel executor ([`crate::exec`]):
    /// each minibatch is split into this many document shards, swept
    /// concurrently against read-only column snapshots, with the
    /// per-shard deltas merged deterministically. `1` = the exact serial
    /// path (bit-identical numerics and I/O counters).
    pub n_workers: usize,
    /// E-step kernel backend ([`crate::em::simd::KernelBackend`]):
    /// `Scalar` is the bit-identity reference; the SIMD tiers are
    /// tolerance-class equivalents of the same Eq. 13/38 float program.
    pub kernel_backend: crate::em::simd::KernelBackend,
}

impl FoemConfig {
    /// Paper defaults (§3.1: `lambda_k*K = 10`, `lambda_w = 1`).
    pub fn paper() -> Self {
        Self {
            topic_subset: TopicSubset::Fixed(10),
            lambda_w: 1.0,
            residual_tol: 0.03,
            max_inner_iters: 50,
            hot_words: 0,
            explore_slots: 4,
            exact_ll: true,
            open_vocabulary: false,
            n_workers: 1,
            kernel_backend: crate::em::simd::KernelBackend::Scalar,
        }
    }

    /// Throughput mode: no exact-LL pass (reports carry `train_ll = 0`).
    pub fn throughput() -> Self {
        Self { exact_ll: false, ..Self::paper() }
    }
}

/// The FOEM trainer, generic over the storage backend shared by the
/// topic-word matrix and the residual matrix.
pub struct Foem<S: PhiColumnStore> {
    pub params: LdaParams,
    pub cfg: FoemConfig,
    /// Global topic-word sufficient statistics `phi_hat_{K×W}`.
    pub store: S,
    /// Global residual matrix `r_{K×W}` (streamed like phi, §3.2).
    pub res_store: S,
    /// Topic totals `phisum(k)` — always memory-resident (K floats).
    pub phisum: Vec<f32>,
    /// Per-word residual totals `r_w` (Eq. 37) — resident (W floats).
    pub r_totals: Vec<f32>,
    /// Minibatches processed (the paper's `s`).
    pub step: usize,
    growth: VocabGrowth,
    rng: Rng,
    /// `(batch_id, post-stage rng)` of the last *applied* batch. Under
    /// pipelining the live `step`/`rng` run ahead of the strict-order
    /// apply cursor, so coordinator checkpoints snapshot from here
    /// instead ([`crate::baselines::OnlineLda::export_resume_state`]) —
    /// phisum/r_totals ARE apply-cursor-consistent already.
    last_applied: Option<(u64, [u64; 4])>,
    /// Inner iterations of the last minibatch (diagnostics).
    pub last_inner_iters: usize,
    /// Grow-only scratch reused across minibatches (responsibility
    /// arena, sweep kernel, theta) — avoids a multi-MB allocate+zero on
    /// every minibatch (§Perf, `rust/DESIGN.md` §8).
    resp_scratch: RespArena,
    kern_scratch: SweepKernel,
    theta_scratch: Vec<f32>,
}

impl<S: PhiColumnStore> Foem<S> {
    /// Build from a phi store and a residual store (same capacity/K).
    pub fn with_stores(
        params: LdaParams,
        store: S,
        res_store: S,
        cfg: FoemConfig,
        seed: u64,
    ) -> Self {
        let k = params.n_topics;
        assert_eq!(store.k(), k, "store K must match model K");
        assert_eq!(res_store.k(), k, "residual store K must match model K");
        let w = store.n_words();
        Self {
            params,
            cfg,
            store,
            res_store,
            phisum: vec![0.0; k],
            r_totals: vec![0.0; w],
            step: 0,
            growth: VocabGrowth::new(),
            rng: Rng::new(seed),
            last_applied: None,
            last_inner_iters: 0,
            resp_scratch: RespArena::new(),
            kern_scratch: SweepKernel::new(),
            theta_scratch: Vec::new(),
        }
    }

    /// Effective vocabulary size used in the Eq. 13 denominator.
    pub fn effective_w(&self) -> usize {
        if self.cfg.open_vocabulary {
            self.growth.effective_w()
        } else {
            self.store.n_words()
        }
    }

    /// Process one minibatch (Fig. 4). Returns the usual report.
    ///
    /// With `cfg.n_workers == 1` this is the serial Fig. 4 algorithm;
    /// otherwise the E-step sweeps run document-sharded on the parallel
    /// executor (see [`crate::exec`] and `rust/DESIGN.md` §6).
    pub fn process_minibatch(&mut self, mb: &Minibatch) -> MinibatchReport {
        if self.cfg.n_workers <= 1 {
            self.process_minibatch_serial(mb)
        } else {
            self.process_minibatch_parallel(mb)
        }
    }

    /// Per-minibatch entry work shared by the serial and parallel paths:
    /// step counter, lifelong vocabulary growth (§3.2), resident residual
    /// sizing, and hot-word pinning (Fig. 4 line 2). Returns the
    /// effective W for the Eq. 13 denominator.
    fn begin_minibatch(&mut self, mb: &Minibatch) -> usize {
        self.step += 1;

        // Lifelong vocabulary growth (§3.2).
        self.growth.observe(mb.local_words.iter().copied());
        if self.cfg.open_vocabulary {
            let need = mb.local_words.last().map_or(0, |&w| w as usize + 1);
            self.store.ensure_capacity(need);
            self.res_store.ensure_capacity(need);
        }
        if self.r_totals.len() < self.store.n_words() {
            self.r_totals.resize(self.store.n_words(), 0.0);
        }

        // Hot-word buffer replacement (Fig. 4 line 2): pin the minibatch's
        // most frequent words in BOTH stores.
        if self.cfg.hot_words > 0 {
            let mut by_mass: Vec<(f32, u32)> = mb
                .local_words
                .iter()
                .map(|&w| {
                    let mass: f32 =
                        mb.vocab_major.word_counts(w as usize).iter().sum();
                    (mass, w)
                })
                .collect();
            by_mass.sort_unstable_by(|a, b| {
                b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal)
            });
            let hot: Vec<u32> = by_mass
                .iter()
                .take(self.cfg.hot_words)
                .map(|&(_, w)| w)
                .collect();
            self.store.set_hot_words(&hot);
            self.res_store.set_hot_words(&hot);
        }
        self.effective_w()
    }

    /// The serial Fig. 4 path — exposed so the equivalence tests can pin
    /// `process_minibatch(n_workers = 1)` against it bit-for-bit.
    pub fn process_minibatch_serial(&mut self, mb: &Minibatch) -> MinibatchReport {
        let timer = Timer::start();
        let k = self.params.n_topics;
        let w_dim = self.begin_minibatch(mb);
        // WAL bracket (no-op when disabled): every store write from here
        // to the commit at the end of this method is logged under this
        // batch's step. Evictions inside `begin_minibatch` fall OUTSIDE
        // the bracket on purpose — they carry column state the previous
        // batch's commit already captured durably.
        let wal_on = self.store.wal_enabled();
        if wal_on {
            self.res_store.wal_begin(self.step as u64);
            self.store.wal_begin(self.step as u64);
        }
        let am1 = self.params.am1();
        let bm1 = self.params.bm1();
        let wbm1 = self.params.wbm1(w_dim);

        let vm = &mb.vocab_major;
        let n_local = mb.local_words.len();
        let nnz = vm.nnz();
        let tokens = mb.docs.total_tokens();

        // Local state: slot-compressed responsibilities (vocab-major
        // entry order) and local doc-topic stats. Only the scheduled
        // coordinates of an entry are ever written, so the arena holds
        // them in O(NNZ_s·S) lanes instead of the Table 3 dense
        // K×NNZ_s matrix — bit-identical semantics, see `em::resp`.
        // Buffers are reused across minibatches.
        let n_sel = self.cfg.topic_subset.size(k);
        let lane_cap = resp::lane_capacity(n_sel, self.cfg.explore_slots, k);
        let mut mu = std::mem::take(&mut self.resp_scratch);
        mu.reset(k, nnz, lane_cap);
        let mut kern = std::mem::take(&mut self.kern_scratch);
        kern.set_backend(self.cfg.kernel_backend);
        let mut theta = std::mem::take(&mut self.theta_scratch);
        theta.clear();
        theta.resize(mb.docs.n_docs * k, 0.0);

        // --- Init (Fig. 4 line 3): random hard assignments accumulated
        // into theta AND the global store (Eq. 33 accumulation form);
        // the moved mass seeds the streamed residuals, so topic selection
        // immediately favors each word's newly-assigned topics. O(NNZ_s).
        {
            let store = &mut self.store;
            let res_store = &mut self.res_store;
            let phisum = &mut self.phisum;
            let r_totals = &mut self.r_totals;
            let rng = &mut self.rng;
            let mut e_base = 0usize;
            let mut assigned: Vec<u32> = Vec::new();
            for &gw in &mb.local_words {
                let gw = gw as usize;
                let (s, en) = vm.word_range(gw);
                assigned.clear();
                let mut delta_r = 0.0f32;
                store.with_column(gw, |col| {
                    for (off, i) in (s..en).enumerate() {
                        let d = vm.doc_ids[i] as usize;
                        let c = vm.counts[i];
                        let topic = rng.below(k);
                        assigned.push(topic as u32);
                        mu.set_one_hot(e_base + off, topic);
                        theta[d * k + topic] += c;
                        col[topic] += c;
                        phisum[topic] += c;
                    }
                });
                res_store.with_column(gw, |rcol| {
                    for (off, i) in (s..en).enumerate() {
                        let c = vm.counts[i];
                        rcol[assigned[off] as usize] += c;
                        delta_r += c;
                    }
                });
                r_totals[gw] += delta_r;
                e_base += en - s;
            }
        }

        // Map: local word -> base entry offset in `mu`; per-word token
        // mass for the per-word convergence cutoff.
        let mut entry_base = vec![0usize; n_local + 1];
        let mut word_mass = vec![0.0f32; n_local];
        for (lw, &gw) in mb.local_words.iter().enumerate() {
            let (s, e) = vm.word_range(gw as usize);
            entry_base[lw + 1] = entry_base[lw] + (e - s);
            word_mass[lw] = vm.word_counts(gw as usize).iter().sum();
        }

        // --- Inner time-efficient IEM sweeps (Fig. 4 lines 5-18). ---
        // No full-K scan: topic subsets come from the persistent streamed
        // residual columns. The exclude/recompute/renormalize work runs
        // through the shared cache-blocked kernel (`resp::sweep_word`).
        let mut inner = 0usize;
        let mut sel: Vec<u32> = Vec::with_capacity(n_sel);
        let mut fresh_res = vec![0.0f32; n_sel];
        let mut rcol_buf = vec![0.0f32; k];
        // Visit-order scratch, hoisted out of the sweep loop (refilled
        // and re-sorted per sweep, never re-allocated).
        let mut order: Vec<u32> = Vec::with_capacity(n_local);
        for t in 0..self.cfg.max_inner_iters {
            // Word visit order: descending r_w, top lambda_w fraction
            // (Eq. 37 / Fig. 4 line 17).
            order.clear();
            order.extend(0..n_local as u32);
            {
                let r_totals = &self.r_totals;
                let words = &mb.local_words;
                order.sort_unstable_by(|&a, &b| {
                    let ra = r_totals[words[a as usize] as usize];
                    let rb = r_totals[words[b as usize] as usize];
                    rb.partial_cmp(&ra).unwrap_or(std::cmp::Ordering::Equal)
                });
            }
            let keep = ((self.cfg.lambda_w as f64 * n_local as f64).ceil()
                as usize)
                .clamp(1, n_local);
            order.truncate(keep);

            let mut moved = 0.0f64;
            for &lw in &order {
                let lw = lw as usize;
                let gw = mb.local_words[lw] as usize;
                // Early exit: the order is descending in r_w, so once a
                // word is individually converged (residual mass below the
                // per-token tolerance for its own mass), every later word
                // is too — this is what visiting by Eq. 37 order buys.
                if (self.r_totals[gw] as f64)
                    < self.cfg.residual_tol * word_mass[lw] as f64
                {
                    break;
                }
                let (s, en) = vm.word_range(gw);
                let base = entry_base[lw];
                let store = &mut self.store;
                let res_store = &mut self.res_store;
                let phisum = &mut self.phisum;
                let r_totals = &mut self.r_totals;
                let mu = &mut mu;
                let kern = &mut kern;
                let theta = &mut theta;
                // Residual column: one read (topic selection) + one write
                // (fresh residuals) per visit — the Fig. 4 line 8/15
                // streaming discipline, applied to r as per §3.2.
                res_store.load_column(gw, &mut rcol_buf);
                resp::top_n_indices(&rcol_buf, n_sel, &mut sel);
                // Epsilon-greedy exploration: swap the tail of the
                // selection for uniform random topics so unvisited-but-
                // good topics can surface (see FoemConfig::explore_slots).
                if n_sel < k && self.cfg.explore_slots > 0 {
                    let swaps = self.cfg.explore_slots.min(n_sel / 2);
                    for j in 0..swaps {
                        let cand = self.rng.below(k) as u32;
                        if !sel.contains(&cand) {
                            let pos = sel.len() - 1 - j;
                            sel[pos] = cand;
                        }
                    }
                }
                // Selected entries are re-accumulated below (Fig. 4
                // line 12's assignment semantics); track the removed mass
                // so the resident total updates incrementally.
                let mut removed = 0.0f32;
                for &kk in &sel {
                    removed += rcol_buf[kk as usize];
                    rcol_buf[kk as usize] = 0.0;
                }
                fresh_res.iter_mut().for_each(|x| *x = 0.0);
                store.with_column(gw, |col| {
                    resp::sweep_word(
                        mu,
                        kern,
                        &sel,
                        base,
                        &vm.doc_ids[s..en],
                        &vm.counts[s..en],
                        theta,
                        col,
                        phisum,
                        am1,
                        bm1,
                        wbm1,
                        &mut fresh_res,
                    );
                });
                // Write the fresh residuals back into the streamed
                // column; update the resident total incrementally.
                let mut word_moved = 0.0f32;
                for (j, &kk) in sel.iter().enumerate() {
                    rcol_buf[kk as usize] += fresh_res[j];
                    word_moved += fresh_res[j];
                }
                res_store.store_column(gw, &rcol_buf);
                r_totals[gw] = (r_totals[gw] - removed + word_moved).max(0.0);
                moved += word_moved as f64;
            }
            inner = t + 1;
            // Converged when the last sweep moved little mass per token.
            if moved / tokens < self.cfg.residual_tol {
                break;
            }
        }
        self.last_inner_iters = inner;

        // Exact training LL (optional O(K*NNZ_s) pass).
        let mut ll = 0.0f64;
        if self.cfg.exact_ll {
            let kam1 = k as f32 * am1;
            let doc_norms: Vec<f64> = (0..mb.docs.n_docs)
                .map(|d| ((mb.docs.doc_len(d) + kam1) as f64).max(1e-300).ln())
                .collect();
            for &gw in &mb.local_words {
                let gw = gw as usize;
                let (s, en) = vm.word_range(gw);
                let col = self.store.read_column(gw);
                for i in s..en {
                    let d = vm.doc_ids[i] as usize;
                    let c = vm.counts[i];
                    let th = &theta[d * k..(d + 1) * k];
                    let mut z = 0.0f32;
                    for kk in 0..k {
                        z += (th[kk] + am1) * (col[kk] + bm1)
                            / (self.phisum[kk] + wbm1);
                    }
                    ll += c as f64
                        * (((z as f64).max(1e-300)).ln() - doc_norms[d]);
                }
            }
        }

        // Working-set telemetry: the O(NNZ·S) arena plus the principal
        // auxiliary scratch of this minibatch.
        let resp_bytes = mu.bytes();
        let scratch_bytes = theta.len() * 4
            + rcol_buf.len() * 4
            + order.capacity() * 4
            + kern.bytes()
            + entry_base.len() * std::mem::size_of::<usize>()
            + word_mass.len() * 4
            + (sel.capacity() + fresh_res.len()) * 4;

        // Hand the scratch buffers back for the next minibatch.
        self.resp_scratch = mu;
        self.kern_scratch = kern;
        self.theta_scratch = theta;

        self.last_applied = Some((self.step as u64, self.rng.state()));
        if wal_on {
            let id = self.step as u64;
            // Residual log first, phi last: the phi commit carries the
            // trainer blob and is the authoritative marker, so a crash
            // between the two fsyncs leaves at worst an orphaned residual
            // commit that recovery ignores.
            self.res_store.wal_commit(id, &[]);
            let blob = encode_commit_state(
                id,
                self.rng.state(),
                &self.phisum,
                &mb.local_words,
                &self.r_totals,
            );
            self.store.wal_commit(id, &blob);
        }

        MinibatchReport {
            inner_iters: inner,
            seconds: timer.seconds(),
            train_ll: ll,
            tokens,
            resp_bytes,
            scratch_bytes,
        }
    }

    /// Document-sharded parallel path: one stage → compute → apply round
    /// trip of the three-phase trainer seam (the same phases the software
    /// pipeline [`crate::exec::pipeline`] overlaps across batches).
    /// Eq. 33 accumulation semantics are preserved: each shard
    /// contributes exactly its token mass, so the global mass invariant
    /// holds for any `P` — and, because deltas are taken against the
    /// staged snapshots and applied additively, for any pipeline depth.
    fn process_minibatch_parallel(&mut self, mb: &Minibatch) -> MinibatchReport {
        let staged = self.stage_batch(mb);
        let delta = Self::compute_batch(&staged);
        self.apply_batch(&staged, delta)
    }

    /// Phase 1 (stage): per-minibatch entry work plus shared-read
    /// snapshots of the touched columns of BOTH streams — one sequential,
    /// non-dirtying read per column, after which the stores sit untouched
    /// until [`Self::apply_batch`]. Shards the minibatch and draws the
    /// per-shard RNG streams in shard order (deterministic for a given
    /// `(seed, n_workers)`), so the returned bundle is fully
    /// self-contained.
    pub fn stage_batch(&mut self, mb: &Minibatch) -> FoemStaged {
        let timer = Timer::start();
        let w_dim = self.begin_minibatch(mb);
        let phi_snap = self.store.snapshot_columns(&mb.local_words);
        let res_snap = self.res_store.snapshot_columns(&mb.local_words);
        let exec = ParallelExecutor::new(self.cfg.n_workers);
        let shards = exec.shard(mb);
        let seeds: Vec<u64> =
            shards.iter().map(|_| self.rng.next_u64()).collect();
        FoemStaged {
            params: self.params,
            cfg: self.cfg,
            shards,
            phi_snap,
            res_snap,
            phisum0: self.phisum.clone(),
            w_dim,
            seeds,
            local_words: mb.local_words.clone(),
            tokens: mb.docs.total_tokens(),
            stage_seconds: timer.seconds(),
            batch_id: self.step as u64,
            // RNG snapshot AFTER this batch's shard seeds were drawn.
            // Under pipelining the live `self.rng` will have advanced
            // through stage(t+1..t+d) by the time apply(t) commits, but
            // the coordinator RNG is touched ONLY by stage — so the
            // post-stage(t) state is exactly the pre-stage(t+1) state a
            // resumed run must start from for bit-identical staging.
            rng_state: self.rng.state(),
        }
    }

    /// Phase 2 (compute): the shard sweeps against the staged snapshots.
    /// Pure — it touches neither the trainer nor the stores — so the
    /// pipeline can run it on a background thread while other batches
    /// stage and apply.
    pub fn compute_batch(staged: &FoemStaged) -> FoemDelta {
        let timer = Timer::start();
        let exec = ParallelExecutor::new(staged.cfg.n_workers);
        let results = exec.run_sharded(&staged.shards, |shard| {
            run_foem_shard(
                &staged.params,
                &staged.cfg,
                shard,
                &staged.phi_snap,
                &staged.res_snap,
                &staged.phisum0,
                staged.w_dim,
                staged.seeds[shard.shard_index],
            )
        });
        FoemDelta { results, compute_seconds: timer.seconds() }
    }

    /// Phase 3 (apply): deterministic reduce (fixed shard order), then
    /// ONE read-modify-write per global column — the Fig. 4 line 8/15 I/O
    /// discipline, paid once per minibatch instead of once per shard.
    /// Called in strict batch order by the pipeline.
    pub fn apply_batch(
        &mut self,
        staged: &FoemStaged,
        delta: FoemDelta,
    ) -> MinibatchReport {
        let timer = Timer::start();
        // WAL bracket for this batch's store mutations. Under pipelining
        // `self.step` has already advanced past this batch (stage(t+1)
        // runs before apply(t)), so the bracket id comes from the staged
        // bundle, never from the live step counter.
        let wal_on = self.store.wal_enabled();
        if wal_on {
            self.res_store.wal_begin(staged.batch_id);
            self.store.wal_begin(staged.batch_id);
        }
        let k = self.params.n_topics;
        let am1 = self.params.am1();
        let bm1 = self.params.bm1();
        let wbm1 = self.params.wbm1(staged.w_dim);
        let FoemDelta { results, compute_seconds } = delta;
        let exec = ParallelExecutor::new(staged.cfg.n_workers);

        let phi_delta = exec.reduce(
            k,
            &staged.local_words,
            results.iter().map(|r| &r.phi_delta),
        );
        let res_delta = exec.reduce(
            k,
            &staged.local_words,
            results.iter().map(|r| &r.res_delta),
        );
        phi_delta.apply_to_store(&mut self.store, &mut self.phisum);

        // Residual columns merge additively, clamped at zero: workers
        // each re-derive the selected coordinates from the same snapshot,
        // so overlapping zero-outs may overshoot — residuals are a
        // scheduling heuristic and must only stay non-negative.
        for (i, &gw) in staged.local_words.iter().enumerate() {
            let gw = gw as usize;
            self.r_totals[gw] =
                self.res_store.clamp_add_column(gw, res_delta.col(i));
        }

        let inner = results.iter().map(|r| r.inner_iters).max().unwrap_or(0);
        self.last_inner_iters = inner;

        // Exact training LL (optional O(K*NNZ_s) pass) on the merged
        // global state. Word-major outer loop so each column is read
        // from the store exactly ONCE even when the word appears in
        // every shard (frequent words do) — the serial I/O discipline.
        let mut ll = 0.0f64;
        if self.cfg.exact_ll {
            let kam1 = k as f32 * am1;
            let doc_norms: Vec<Vec<f64>> = staged
                .shards
                .iter()
                .map(|shard| {
                    (0..shard.docs.n_docs)
                        .map(|d| {
                            ((shard.docs.doc_len(d) + kam1) as f64)
                                .max(1e-300)
                                .ln()
                        })
                        .collect()
                })
                .collect();
            let mut col = vec![0.0f32; k];
            for &gw in &staged.local_words {
                let gw = gw as usize;
                self.store.load_column(gw, &mut col);
                for (si, (r, shard)) in
                    results.iter().zip(&staged.shards).enumerate()
                {
                    let vm = &shard.vocab_major;
                    let (s, en) = vm.word_range(gw);
                    for i in s..en {
                        let d = vm.doc_ids[i] as usize;
                        let c = vm.counts[i];
                        let th = &r.theta[d * k..(d + 1) * k];
                        let mut z = 0.0f32;
                        for kk in 0..k {
                            z += (th[kk] + am1) * (col[kk] + bm1)
                                / (self.phisum[kk] + wbm1);
                        }
                        ll += c as f64
                            * (((z as f64).max(1e-300)).ln()
                                - doc_norms[si][d]);
                    }
                }
            }
        }

        // Workers ran concurrently, so the batch's peak working set is
        // the SUM of the per-shard arenas and scratch.
        let resp_bytes = results.iter().map(|r| r.resp_bytes).sum();
        let scratch_bytes = results.iter().map(|r| r.scratch_bytes).sum();
        // The shard thetas are no longer needed — recycle them.
        for r in results {
            crate::exec::scratch::put_f32(r.theta);
        }

        self.last_applied = Some((staged.batch_id, staged.rng_state));
        if wal_on {
            // Residual first, phi (with the trainer blob) last — the phi
            // commit is the authoritative durability marker.
            self.res_store.wal_commit(staged.batch_id, &[]);
            let blob = encode_commit_state(
                staged.batch_id,
                staged.rng_state,
                &self.phisum,
                &staged.local_words,
                &self.r_totals,
            );
            self.store.wal_commit(staged.batch_id, &blob);
        }

        MinibatchReport {
            inner_iters: inner,
            // Busy time of this batch's three phases. Under pipelining the
            // phases of different batches overlap in wall time, so summing
            // stage+compute+apply (not stage-to-apply elapsed) keeps
            // Metrics' totals meaningful.
            seconds: staged.stage_seconds + compute_seconds + timer.seconds(),
            train_ll: ll,
            tokens: staged.tokens,
            resp_bytes,
            scratch_bytes,
        }
    }

    /// Checkpoint-friendly view of the resident state.
    pub fn phisum_total(&self) -> f64 {
        self.phisum.iter().map(|&x| x as f64).sum()
    }

    /// Export the dense phi for evaluation.
    pub fn export_phi(&mut self) -> crate::em::PhiStats {
        self.store.export_dense()
    }

    /// Snapshot the resident state for a coordinator checkpoint
    /// ([`crate::coordinator::checkpoint`]). Pair with a store flush:
    /// the snapshot + the flushed stores reproduce the exact mid-run
    /// trainer.
    pub fn export_train_state(&self) -> FoemTrainState {
        // Under pipelining the live `step`/`rng` have run ahead through
        // staged-but-unapplied batches; the snapshot must sit exactly at
        // the apply cursor, whose `(id, rng)` every apply records.
        let (step, rng) = self
            .last_applied
            .unwrap_or((self.step as u64, self.rng.state()));
        FoemTrainState {
            step,
            rng,
            phisum: self.phisum.clone(),
            r_totals: self.r_totals.clone(),
            seen_words: self.growth.seen_words(),
        }
    }

    /// Restore a [`Self::export_train_state`] snapshot. The stores must
    /// already hold the matching flushed column state (reopen first).
    pub fn import_train_state(&mut self, st: &FoemTrainState) {
        self.step = st.step as usize;
        self.rng = Rng::from_state(st.rng);
        self.last_applied = Some((st.step, st.rng));
        self.phisum = st.phisum.clone();
        self.r_totals = st.r_totals.clone();
        if self.r_totals.len() < self.store.n_words() {
            self.r_totals.resize(self.store.n_words(), 0.0);
        }
        self.growth = VocabGrowth::restore(&st.seen_words);
    }

    /// Restore resident state from a replayed phi WAL commit blob
    /// (recovery path). Column contents come from
    /// `PagedPhi::apply_wal_batch`; this applies the matching
    /// O(K + W_s) resident piece so the trainer lands exactly where the
    /// committed batch left it.
    pub fn apply_commit_state(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let (step, rng, phisum, touched) = decode_commit_state(blob)?;
        anyhow::ensure!(
            phisum.len() == self.params.n_topics,
            "WAL commit blob has K = {} but the model has K = {}",
            phisum.len(),
            self.params.n_topics
        );
        self.step = step as usize;
        self.rng = Rng::from_state(rng);
        self.last_applied = Some((step, rng));
        self.phisum = phisum;
        self.growth.observe(touched.iter().map(|&(w, _)| w));
        for &(w, r) in &touched {
            let w = w as usize;
            if self.r_totals.len() <= w {
                self.r_totals.resize(w + 1, 0.0);
            }
            self.r_totals[w] = r;
        }
        Ok(())
    }

    // --- Drift responses (coordinator::drift, DESIGN.md §15) --------

    /// Discount the accumulated sufficient statistics: `phi_hat *= γ`,
    /// `phisum *= γ` for `0 < γ < 1`. Because the Eq. 33 estimator is a
    /// running sum with the implicit step size `rho_s = 1/s`, scaling
    /// all statistics by γ is exactly restarting that schedule at
    /// `s_eff = γ·s` — the posterior flattens toward the prior and new
    /// (post-shift) data re-sharpens it at the weight it had early in
    /// training. Residuals are left untouched: they encode *relative*
    /// scheduling priority, which a uniform rescale would not change.
    pub fn reset_decay(&mut self, factor: f32) -> bool {
        assert!(factor > 0.0 && factor < 1.0, "decay factor must be in (0, 1)");
        let n_words = self.store.n_words();
        for w in 0..n_words {
            self.store.with_column(w, |col| {
                for x in col.iter_mut() {
                    *x *= factor;
                }
            });
        }
        for s in self.phisum.iter_mut() {
            *s *= factor;
        }
        true
    }

    /// Permanently widen the dynamic scheduler: double the scheduled
    /// topic subset (capped at K) and double the epsilon-greedy
    /// exploration slots. After a shift the residual matrix still
    /// reflects the *old* regime, so topics the old schedule starved
    /// need extra discovery bandwidth to be rediscovered.
    pub fn widen_exploration(&mut self) -> bool {
        let k = self.params.n_topics;
        self.cfg.topic_subset = match self.cfg.topic_subset {
            TopicSubset::All => TopicSubset::All,
            TopicSubset::Fraction(f) => TopicSubset::Fraction((f * 2.0).min(1.0)),
            TopicSubset::Fixed(n) => TopicSubset::Fixed((n.max(1) * 2).min(k)),
        };
        self.cfg.explore_slots = (self.cfg.explore_slots.max(1) * 2).min(k);
        true
    }

    /// Grow the model by `extra` fresh zero-mass topics through the
    /// store seam. Declines (returns `false`, model untouched) when the
    /// backend pins K — paged/sharded column records fix K at creation,
    /// so this is an in-memory-store capability.
    pub fn grow_topics(&mut self, extra: usize) -> bool {
        if extra == 0 {
            return true;
        }
        let new_k = self.params.n_topics + extra;
        if !self.store.grow_topics(new_k) {
            return false;
        }
        // Same backend type: if phi grew, the residual store must too.
        assert!(
            self.res_store.grow_topics(new_k),
            "phi store grew to K={new_k} but residual store declined"
        );
        self.params.n_topics = new_k;
        self.phisum.resize(new_k, 0.0);
        true
    }
}

/// Resident trainer state captured by coordinator checkpoints and (per
/// batch) by phi WAL commit frames: everything [`Foem`] holds outside
/// the two streamed matrices.
#[derive(Debug, Clone, PartialEq)]
pub struct FoemTrainState {
    /// Minibatches processed so far (the batch cursor resumes after it).
    pub step: u64,
    /// Coordinator RNG state (4×u64 xoshiro words).
    pub rng: [u64; 4],
    /// Topic totals (Eq. 33 denominator), length K.
    pub phisum: Vec<f32>,
    /// Per-word residual totals (Eq. 37 visit order). Exact
    /// incrementally-maintained values — a restart-time column rescan
    /// differs in the last ulp and would break bit-identical resume.
    pub r_totals: Vec<f32>,
    /// Words observed so far (open-vocabulary growth state).
    pub seen_words: Vec<u32>,
}

/// Serialize the per-batch resident state carried by a phi WAL commit
/// frame:
/// `[step u64][rng 4×u64][k u32][phisum k×f32][n u32][(word u32, r_total f32)×n]`
/// (little-endian). Only the batch's local words need residual totals —
/// all other words were untouched, so their totals are already covered
/// by the last checkpoint or an earlier replayed commit.
fn encode_commit_state(
    step: u64,
    rng: [u64; 4],
    phisum: &[f32],
    touched: &[u32],
    r_totals: &[f32],
) -> Vec<u8> {
    let mut b = Vec::with_capacity(
        8 + 32 + 4 + phisum.len() * 4 + 4 + touched.len() * 8,
    );
    b.extend_from_slice(&step.to_le_bytes());
    for s in rng {
        b.extend_from_slice(&s.to_le_bytes());
    }
    b.extend_from_slice(&(phisum.len() as u32).to_le_bytes());
    for &x in phisum {
        b.extend_from_slice(&x.to_le_bytes());
    }
    b.extend_from_slice(&(touched.len() as u32).to_le_bytes());
    for &w in touched {
        b.extend_from_slice(&w.to_le_bytes());
        b.extend_from_slice(&r_totals[w as usize].to_le_bytes());
    }
    b
}

fn rd_u64(b: &[u8], p: &mut usize) -> anyhow::Result<u64> {
    let s = b
        .get(*p..*p + 8)
        .ok_or_else(|| anyhow::anyhow!("WAL commit blob truncated"))?;
    *p += 8;
    Ok(u64::from_le_bytes(s.try_into().unwrap()))
}

fn rd_u32(b: &[u8], p: &mut usize) -> anyhow::Result<u32> {
    let s = b
        .get(*p..*p + 4)
        .ok_or_else(|| anyhow::anyhow!("WAL commit blob truncated"))?;
    *p += 4;
    Ok(u32::from_le_bytes(s.try_into().unwrap()))
}

fn rd_f32(b: &[u8], p: &mut usize) -> anyhow::Result<f32> {
    Ok(f32::from_bits(rd_u32(b, p)?))
}

/// Parse an [`encode_commit_state`] blob:
/// `(step, rng, phisum, touched (word, r_total) pairs)`.
fn decode_commit_state(
    b: &[u8],
) -> anyhow::Result<(u64, [u64; 4], Vec<f32>, Vec<(u32, f32)>)> {
    let mut p = 0usize;
    let step = rd_u64(b, &mut p)?;
    let mut rng = [0u64; 4];
    for s in &mut rng {
        *s = rd_u64(b, &mut p)?;
    }
    let k = rd_u32(b, &mut p)? as usize;
    anyhow::ensure!(
        k <= b.len().saturating_sub(p) / 4,
        "WAL commit blob truncated: claims {k} phisum entries"
    );
    let mut phisum = Vec::with_capacity(k);
    for _ in 0..k {
        phisum.push(rd_f32(b, &mut p)?);
    }
    let n = rd_u32(b, &mut p)? as usize;
    anyhow::ensure!(
        n <= b.len().saturating_sub(p) / 8,
        "WAL commit blob truncated: claims {n} residual totals"
    );
    let mut touched = Vec::with_capacity(n);
    for _ in 0..n {
        let w = rd_u32(b, &mut p)?;
        let r = rd_f32(b, &mut p)?;
        touched.push((w, r));
    }
    Ok((step, rng, phisum, touched))
}

/// Phase-1 output of the three-phase FOEM seam: a self-contained staged
/// minibatch (shards, column snapshots of both streams, resident totals,
/// per-shard seeds). Owns everything, so the pipeline can hold several in
/// flight and hand them to compute workers on other threads.
pub struct FoemStaged {
    params: LdaParams,
    cfg: FoemConfig,
    shards: Vec<MinibatchShard>,
    phi_snap: PhiSnapshot,
    res_snap: PhiSnapshot,
    phisum0: Vec<f32>,
    w_dim: usize,
    seeds: Vec<u64>,
    local_words: Vec<u32>,
    tokens: f64,
    stage_seconds: f64,
    /// The step this batch was staged as — the WAL batch id its apply
    /// phase commits under (apply runs in strict batch order, but under
    /// pipelining `self.step` has already advanced past it).
    batch_id: u64,
    /// Coordinator RNG state at the end of this batch's stage phase (the
    /// state a resumed run needs to stage batch `batch_id + 1`
    /// bit-identically); carried into the WAL commit blob.
    rng_state: [u64; 4],
}

impl FoemStaged {
    /// The staged minibatch's local vocabulary.
    pub fn local_words(&self) -> &[u32] {
        &self.local_words
    }
}

/// Phase-2 output: per-shard sweep results awaiting the ordered reduce of
/// [`Foem::apply_batch`].
pub struct FoemDelta {
    results: Vec<FoemShardResult>,
    compute_seconds: f64,
}

impl<S: PhiColumnStore> crate::exec::pipeline::PhasedTrainer for Foem<S> {
    type Staged = FoemStaged;
    type Delta = FoemDelta;

    fn stage(&mut self, mb: &Minibatch) -> FoemStaged {
        self.stage_batch(mb)
    }

    fn compute(staged: &FoemStaged) -> FoemDelta {
        Foem::<S>::compute_batch(staged)
    }

    fn apply(&mut self, staged: &FoemStaged, delta: FoemDelta) -> MinibatchReport {
        self.apply_batch(staged, delta)
    }

    fn process_direct(&mut self, mb: &Minibatch) -> MinibatchReport {
        self.process_minibatch(mb)
    }

    fn prefetch(&mut self, mb: &Minibatch) {
        // Both streams (§3.2): phi and the residual matrix are staged in
        // lockstep.
        self.store.prefetch_columns(&mb.local_words);
        self.res_store.prefetch_columns(&mb.local_words);
    }

    fn begin_pipeline(&mut self) {
        self.store.set_async_io(true);
        self.res_store.set_async_io(true);
    }

    fn end_pipeline(&mut self) {
        self.store.set_async_io(false);
        self.res_store.set_async_io(false);
    }
}

/// Result of one shard worker's E-step sweeps.
struct FoemShardResult {
    inner_iters: usize,
    /// Topic-word delta vs the phi snapshot, over the shard's words.
    phi_delta: SsDelta,
    /// Residual delta vs the residual snapshot.
    res_delta: SsDelta,
    /// Shard-local doc-topic stats (kept for the optional exact-LL pass;
    /// recycled into [`crate::exec::scratch`] by the apply phase).
    theta: Vec<f32>,
    /// This worker's peak responsibility-arena bytes.
    resp_bytes: usize,
    /// This worker's auxiliary scratch bytes.
    scratch_bytes: usize,
}

/// The FOEM inner loop (Fig. 4 lines 3-18) for one document shard, run
/// against worker-private copies of the snapshot columns. The math is the
/// serial algorithm's verbatim — the same shared kernel
/// ([`resp::sweep_word`]) over a worker-private responsibility arena;
/// only the storage differs: updates land in private arrays checked out
/// of the grow-only [`crate::exec::scratch`] pool, and the net change vs
/// the snapshot is returned as [`SsDelta`]s for the executor's
/// deterministic merge.
#[allow(clippy::too_many_arguments)]
fn run_foem_shard(
    params: &LdaParams,
    cfg: &FoemConfig,
    shard: &MinibatchShard,
    phi_snap: &PhiSnapshot,
    res_snap: &PhiSnapshot,
    phisum0: &[f32],
    w_dim: usize,
    seed: u64,
) -> FoemShardResult {
    let k = params.n_topics;
    let am1 = params.am1();
    let bm1 = params.bm1();
    let wbm1 = params.wbm1(w_dim);
    let vm = &shard.vocab_major;
    let words = &shard.local_words;
    let n_local = words.len();
    let nnz = vm.nnz();
    let tokens = shard.docs.total_tokens();
    let mut rng = Rng::new(seed);

    // Worker scratch: arena + kernel + column copies from the grow-only
    // pool; theta is a loose pool buffer because it outlives this
    // function inside the shard result (exact-LL pass at apply time).
    let mut ws = crate::exec::scratch::take();
    let mut kern = std::mem::take(&mut ws.kern);
    // Pooled scratch is grow-only and can carry a stale tier.
    kern.set_backend(cfg.kernel_backend);
    let mut mu = std::mem::take(&mut ws.arena);
    let n_sel = cfg.topic_subset.size(k);
    mu.reset(k, nnz, resp::lane_capacity(n_sel, cfg.explore_slots, k));

    // Private working copies of the touched columns plus resident totals.
    let mut phi = std::mem::take(&mut ws.col_a);
    phi.clear();
    let mut res = std::mem::take(&mut ws.col_b);
    res.clear();
    for &gw in words.iter() {
        phi.extend_from_slice(
            phi_snap.column(gw).expect("shard word missing from snapshot"),
        );
        res.extend_from_slice(
            res_snap.column(gw).expect("shard word missing from snapshot"),
        );
    }
    let mut phisum = phisum0.to_vec();
    let mut r_totals: Vec<f32> = (0..n_local)
        .map(|lw| res[lw * k..(lw + 1) * k].iter().sum())
        .collect();

    let mut theta = crate::exec::scratch::take_f32();
    theta.resize(shard.docs.n_docs * k, 0.0);

    // Init (Fig. 4 line 3): random hard assignments accumulated into the
    // private state (Eq. 33 accumulation form).
    {
        let mut e_base = 0usize;
        for (lw, &gw) in words.iter().enumerate() {
            let (s, en) = vm.word_range(gw as usize);
            let col = &mut phi[lw * k..(lw + 1) * k];
            let rcol = &mut res[lw * k..(lw + 1) * k];
            for (off, i) in (s..en).enumerate() {
                let d = vm.doc_ids[i] as usize;
                let c = vm.counts[i];
                let topic = rng.below(k);
                mu.set_one_hot(e_base + off, topic);
                theta[d * k + topic] += c;
                col[topic] += c;
                phisum[topic] += c;
                rcol[topic] += c;
                r_totals[lw] += c;
            }
            e_base += en - s;
        }
    }

    // Local word -> base entry offset in the arena; per-word token mass
    // for the per-word convergence cutoff.
    let mut entry_base = vec![0usize; n_local + 1];
    let mut word_mass = vec![0.0f32; n_local];
    for (lw, &gw) in words.iter().enumerate() {
        let (s, e) = vm.word_range(gw as usize);
        entry_base[lw + 1] = entry_base[lw] + (e - s);
        word_mass[lw] = vm.word_counts(gw as usize).iter().sum();
    }

    // Inner time-efficient IEM sweeps (Fig. 4 lines 5-18), private state,
    // through the shared kernel. The visit-order Vec is hoisted out of
    // the sweep loop (pool-recycled across batches).
    let mut inner = 0usize;
    let mut sel: Vec<u32> = Vec::with_capacity(n_sel);
    let mut fresh_res = vec![0.0f32; n_sel];
    let mut order = std::mem::take(&mut ws.idx);
    for t in 0..cfg.max_inner_iters {
        order.clear();
        order.extend(0..n_local as u32);
        order.sort_unstable_by(|&a, &b| {
            let ra = r_totals[a as usize];
            let rb = r_totals[b as usize];
            rb.partial_cmp(&ra).unwrap_or(std::cmp::Ordering::Equal)
        });
        let keep = ((cfg.lambda_w as f64 * n_local as f64).ceil() as usize)
            .clamp(1, n_local);
        order.truncate(keep);

        let mut moved = 0.0f64;
        for &lw in &order {
            let lw = lw as usize;
            let gw = words[lw] as usize;
            if (r_totals[lw] as f64) < cfg.residual_tol * word_mass[lw] as f64
            {
                break;
            }
            let (s, en) = vm.word_range(gw);
            let base = entry_base[lw];
            let rcol = &mut res[lw * k..(lw + 1) * k];
            resp::top_n_indices(rcol, n_sel, &mut sel);
            if n_sel < k && cfg.explore_slots > 0 {
                let swaps = cfg.explore_slots.min(n_sel / 2);
                for j in 0..swaps {
                    let cand = rng.below(k) as u32;
                    if !sel.contains(&cand) {
                        let pos = sel.len() - 1 - j;
                        sel[pos] = cand;
                    }
                }
            }
            let mut removed = 0.0f32;
            for &kk in &sel {
                removed += rcol[kk as usize];
                rcol[kk as usize] = 0.0;
            }
            fresh_res.iter_mut().for_each(|x| *x = 0.0);
            let col = &mut phi[lw * k..(lw + 1) * k];
            resp::sweep_word(
                &mut mu,
                &mut kern,
                &sel,
                base,
                &vm.doc_ids[s..en],
                &vm.counts[s..en],
                &mut theta,
                col,
                &mut phisum,
                am1,
                bm1,
                wbm1,
                &mut fresh_res,
            );
            let mut word_moved = 0.0f32;
            for (j, &kk) in sel.iter().enumerate() {
                rcol[kk as usize] += fresh_res[j];
                word_moved += fresh_res[j];
            }
            r_totals[lw] = (r_totals[lw] - removed + word_moved).max(0.0);
            moved += word_moved as f64;
        }
        inner = t + 1;
        if moved / tokens.max(1.0) < cfg.residual_tol {
            break;
        }
    }

    // Net change vs the snapshots — what the executor reduces & applies.
    let mut phi_delta = SsDelta::zeros(k, words.clone());
    let mut res_delta = SsDelta::zeros(k, words.clone());
    for (lw, &gw) in words.iter().enumerate() {
        let psnap = phi_snap.column(gw).expect("snapshot column");
        let rsnap = res_snap.column(gw).expect("snapshot column");
        for kk in 0..k {
            let dp = phi[lw * k + kk] - psnap[kk];
            if dp != 0.0 {
                phi_delta.add_at(lw, kk, dp);
            }
            let dr = res[lw * k + kk] - rsnap[kk];
            if dr != 0.0 {
                res_delta.add_at(lw, kk, dr);
            }
        }
    }

    let resp_bytes = mu.bytes();
    let scratch_bytes = theta.len() * 4
        + phi.len() * 4
        + res.len() * 4
        + phisum.len() * 4
        + r_totals.len() * 4
        + order.capacity() * 4
        + kern.bytes()
        + entry_base.len() * std::mem::size_of::<usize>()
        + word_mass.len() * 4
        + (sel.capacity() + fresh_res.len()) * 4;

    // Return the bundle for the next shard/batch.
    ws.arena = mu;
    ws.kern = kern;
    ws.col_a = phi;
    ws.col_b = res;
    ws.idx = order;
    crate::exec::scratch::put(ws);

    FoemShardResult {
        inner_iters: inner,
        phi_delta,
        res_delta,
        theta,
        resp_bytes,
        scratch_bytes,
    }
}

impl Foem<crate::store::InMemoryPhi> {
    /// Convenience constructor with in-memory phi + residual stores.
    pub fn new(
        params: LdaParams,
        store: crate::store::InMemoryPhi,
        cfg: FoemConfig,
        seed: u64,
    ) -> Self {
        let res = crate::store::InMemoryPhi::zeros(
            params.n_topics,
            store.n_words(),
        );
        Self::with_stores(params, store, res, cfg, seed)
    }
}

impl Foem<crate::store::paged::PagedPhi> {
    /// Residual-store path derived from a phi-store path
    /// (`phi.bin` -> `phi.res.bin`).
    pub fn residual_path(phi_path: &std::path::Path) -> std::path::PathBuf {
        phi_path.with_extension("res.bin")
    }

    /// Create a fresh disk-backed trainer: phi at `path`, residuals at
    /// `residual_path(path)`, each with `buffer_bytes / 2` of hot buffer
    /// (the two matrices are streamed in lockstep, so the budget splits
    /// evenly).
    pub fn paged_create(
        params: LdaParams,
        path: &std::path::Path,
        n_words: usize,
        buffer_bytes: usize,
        cfg: FoemConfig,
        seed: u64,
    ) -> anyhow::Result<Self> {
        Self::paged_create_with_codec(
            params,
            path,
            n_words,
            buffer_bytes,
            cfg,
            seed,
            crate::store::Codec::Auto,
        )
    }

    /// [`Self::paged_create`] with an explicit column codec
    /// (`--phi-codec`). Both streamed matrices use the same write policy
    /// (the residual matrix is at least as sparse as phi, so whatever
    /// compresses phi compresses it too); reads are per-record
    /// self-describing either way.
    #[allow(clippy::too_many_arguments)]
    pub fn paged_create_with_codec(
        params: LdaParams,
        path: &std::path::Path,
        n_words: usize,
        buffer_bytes: usize,
        cfg: FoemConfig,
        seed: u64,
        codec: crate::store::Codec,
    ) -> anyhow::Result<Self> {
        let k = params.n_topics;
        let half = (buffer_bytes / 2).max(k * 4);
        let store = crate::store::paged::PagedPhi::create_with_codec(
            path, k, n_words, half, codec,
        )?;
        let res = crate::store::paged::PagedPhi::create_with_codec(
            &Self::residual_path(path),
            k,
            n_words,
            half,
            codec,
        )?;
        Ok(Self::with_stores(params, store, res, cfg, seed))
    }

    /// Reopen after a restart; pair with `PagedPhi::load_checkpoint` to
    /// restore `step`/`phisum`.
    pub fn paged_open(
        params: LdaParams,
        path: &std::path::Path,
        buffer_bytes: usize,
        cfg: FoemConfig,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let half = (buffer_bytes / 2).max(params.n_topics * 4);
        let store = crate::store::paged::PagedPhi::open(path, half)?;
        let res = crate::store::paged::PagedPhi::open(
            &Self::residual_path(path),
            half,
        )?;
        let mut this = Self::with_stores(params, store, res, cfg, seed);
        // Rebuild the resident residual totals from the streamed matrix
        // (one restart-time scan).
        this.r_totals = (0..this.res_store.n_words())
            .map(|w| this.res_store.read_column(w).iter().sum())
            .collect();
        Ok(this)
    }

    /// Flush + checkpoint both stores and the resident state.
    pub fn checkpoint_paged(&mut self) -> anyhow::Result<()> {
        self.store.checkpoint(self.step, &self.phisum)?;
        self.res_store.flush()?;
        Ok(())
    }

    /// Arm the write-ahead log on both streamed stores (`--wal`). Every
    /// minibatch from now on appends its column writes plus a resident
    /// trainer blob to `<store>.wal` before any extent is touched, and
    /// fsyncs once per store at commit.
    pub fn enable_wal(&mut self) -> anyhow::Result<()> {
        self.store.enable_wal()?;
        self.res_store.enable_wal()?;
        Ok(())
    }

    /// Crash recovery: reopen both stores with their WALs, restore the
    /// trainer checkpoint `state`, replay every batch committed after
    /// the checkpoint cursor (columns AND the resident blob), and leave
    /// the logs armed for further training. Returns the trainer plus the
    /// id of the last batch whose effects are now durable — the batch
    /// cursor the driver resumes after.
    pub fn paged_resume(
        params: LdaParams,
        path: &std::path::Path,
        buffer_bytes: usize,
        cfg: FoemConfig,
        state: &FoemTrainState,
    ) -> anyhow::Result<(Self, u64)> {
        let half = (buffer_bytes / 2).max(params.n_topics * 4);
        let (store, phi_batches) =
            crate::store::paged::PagedPhi::open_with_wal(path, half)?;
        let (res, res_batches) = crate::store::paged::PagedPhi::open_with_wal(
            &Self::residual_path(path),
            half,
        )?;
        let mut this = Self::with_stores(params, store, res, cfg, 0);
        this.import_train_state(state);

        // Replay only batches the checkpoint does not already cover. The
        // phi log is authoritative: its commit frame carries the trainer
        // blob and is fsynced AFTER the residual commit, so a
        // phi-committed batch always has its residual twin — and an
        // orphaned residual-only commit is correctly ignored here.
        let cursor = state.step;
        let phi_committed: std::collections::HashSet<u64> =
            phi_batches.iter().map(|b| b.batch_id).collect();
        for b in &res_batches {
            if b.batch_id > cursor && phi_committed.contains(&b.batch_id) {
                this.res_store.apply_wal_batch(b);
            }
        }
        let mut last = cursor;
        for b in &phi_batches {
            if b.batch_id > cursor {
                this.store.apply_wal_batch(b);
                this.apply_commit_state(&b.state)?;
                last = last.max(b.batch_id);
            }
        }
        Ok((this, last))
    }
}

/// The pre-arena dense E-step implementation, kept verbatim as the
/// bit-identity oracle for the responsibility arena: `mu` is the full
/// `nnz × K` matrix and every loop is the historical code. The
/// equivalence tests drive the serial, sharded and pipelined paths
/// through BOTH implementations from identical seeds and assert bitwise
/// equality of every number (and of `IoStats`).
#[cfg(test)]
pub(crate) mod dense_ref {
    use super::*;

    /// The historical serial Fig. 4 path (dense `nnz × K` mu).
    pub fn process_minibatch_serial_dense<S: PhiColumnStore>(
        f: &mut Foem<S>,
        mb: &Minibatch,
    ) -> MinibatchReport {
        let timer = Timer::start();
        let k = f.params.n_topics;
        let w_dim = f.begin_minibatch(mb);
        let am1 = f.params.am1();
        let bm1 = f.params.bm1();
        let wbm1 = f.params.wbm1(w_dim);

        let vm = &mb.vocab_major;
        let n_local = mb.local_words.len();
        let nnz = vm.nnz();
        let tokens = mb.docs.total_tokens();

        let mut mu = vec![0.0f32; nnz * k];
        let mut theta = vec![0.0f32; mb.docs.n_docs * k];

        // Init (Fig. 4 line 3).
        {
            let store = &mut f.store;
            let res_store = &mut f.res_store;
            let phisum = &mut f.phisum;
            let r_totals = &mut f.r_totals;
            let rng = &mut f.rng;
            let mut e_base = 0usize;
            let mut assigned: Vec<u32> = Vec::new();
            for &gw in &mb.local_words {
                let gw = gw as usize;
                let (s, en) = vm.word_range(gw);
                assigned.clear();
                let mut delta_r = 0.0f32;
                store.with_column(gw, |col| {
                    for (off, i) in (s..en).enumerate() {
                        let d = vm.doc_ids[i] as usize;
                        let c = vm.counts[i];
                        let topic = rng.below(k);
                        assigned.push(topic as u32);
                        mu[(e_base + off) * k + topic] = 1.0;
                        theta[d * k + topic] += c;
                        col[topic] += c;
                        phisum[topic] += c;
                    }
                });
                res_store.with_column(gw, |rcol| {
                    for (off, i) in (s..en).enumerate() {
                        let c = vm.counts[i];
                        rcol[assigned[off] as usize] += c;
                        delta_r += c;
                    }
                });
                r_totals[gw] += delta_r;
                e_base += en - s;
            }
        }

        let mut entry_base = vec![0usize; n_local + 1];
        let mut word_mass = vec![0.0f32; n_local];
        for (lw, &gw) in mb.local_words.iter().enumerate() {
            let (s, e) = vm.word_range(gw as usize);
            entry_base[lw + 1] = entry_base[lw] + (e - s);
            word_mass[lw] = vm.word_counts(gw as usize).iter().sum();
        }

        // Inner sweeps (Fig. 4 lines 5-18), dense exclude/include.
        let n_sel = f.cfg.topic_subset.size(k);
        let mut inner = 0usize;
        let mut sel: Vec<u32> = Vec::with_capacity(n_sel);
        let mut scratch_mu = vec![0.0f32; n_sel];
        let mut fresh_res = vec![0.0f32; n_sel];
        let mut rcol_buf = vec![0.0f32; k];
        for t in 0..f.cfg.max_inner_iters {
            let mut order: Vec<u32> = (0..n_local as u32).collect();
            {
                let r_totals = &f.r_totals;
                let words = &mb.local_words;
                order.sort_unstable_by(|&a, &b| {
                    let ra = r_totals[words[a as usize] as usize];
                    let rb = r_totals[words[b as usize] as usize];
                    rb.partial_cmp(&ra).unwrap_or(std::cmp::Ordering::Equal)
                });
            }
            let keep = ((f.cfg.lambda_w as f64 * n_local as f64).ceil()
                as usize)
                .clamp(1, n_local);
            order.truncate(keep);

            let mut moved = 0.0f64;
            for &lw in &order {
                let lw = lw as usize;
                let gw = mb.local_words[lw] as usize;
                if (f.r_totals[gw] as f64)
                    < f.cfg.residual_tol * word_mass[lw] as f64
                {
                    break;
                }
                let (s, en) = vm.word_range(gw);
                let base = entry_base[lw];
                let store = &mut f.store;
                let res_store = &mut f.res_store;
                let phisum = &mut f.phisum;
                let r_totals = &mut f.r_totals;
                let mu = &mut mu;
                let theta = &mut theta;
                res_store.load_column(gw, &mut rcol_buf);
                resp::top_n_indices(&rcol_buf, n_sel, &mut sel);
                if n_sel < k && f.cfg.explore_slots > 0 {
                    let swaps = f.cfg.explore_slots.min(n_sel / 2);
                    for j in 0..swaps {
                        let cand = f.rng.below(k) as u32;
                        if !sel.contains(&cand) {
                            let pos = sel.len() - 1 - j;
                            sel[pos] = cand;
                        }
                    }
                }
                let mut removed = 0.0f32;
                for &kk in &sel {
                    removed += rcol_buf[kk as usize];
                    rcol_buf[kk as usize] = 0.0;
                }
                fresh_res.iter_mut().for_each(|x| *x = 0.0);
                store.with_column(gw, |col| {
                    for (off, i) in (s..en).enumerate() {
                        let e = base + off;
                        let d = vm.doc_ids[i] as usize;
                        let c = vm.counts[i];
                        let mu_row = &mut mu[e * k..(e + 1) * k];
                        let th = &mut theta[d * k..(d + 1) * k];
                        let mut m_old = 0.0f32;
                        for &kk in &sel {
                            m_old += mu_row[kk as usize];
                        }
                        if m_old <= 1e-12 {
                            continue;
                        }
                        let mut z = 0.0f32;
                        for (j, &kk) in sel.iter().enumerate() {
                            let kk = kk as usize;
                            let excl = c * mu_row[kk];
                            let u = (th[kk] - excl + am1)
                                * (col[kk] - excl + bm1)
                                / (phisum[kk] - excl + wbm1);
                            scratch_mu[j] = u.max(0.0);
                            z += scratch_mu[j];
                        }
                        if z <= 0.0 {
                            continue;
                        }
                        let renorm = m_old / z;
                        for (j, &kk) in sel.iter().enumerate() {
                            let kk = kk as usize;
                            let new = scratch_mu[j] * renorm;
                            let delta = c * (new - mu_row[kk]);
                            th[kk] += delta;
                            col[kk] += delta;
                            phisum[kk] += delta;
                            fresh_res[j] += delta.abs();
                            mu_row[kk] = new;
                        }
                    }
                });
                let mut word_moved = 0.0f32;
                for (j, &kk) in sel.iter().enumerate() {
                    rcol_buf[kk as usize] += fresh_res[j];
                    word_moved += fresh_res[j];
                }
                res_store.store_column(gw, &rcol_buf);
                r_totals[gw] = (r_totals[gw] - removed + word_moved).max(0.0);
                moved += word_moved as f64;
            }
            inner = t + 1;
            if moved / tokens < f.cfg.residual_tol {
                break;
            }
        }
        f.last_inner_iters = inner;

        // Exact training LL (optional O(K*NNZ_s) pass).
        let mut ll = 0.0f64;
        if f.cfg.exact_ll {
            let kam1 = k as f32 * am1;
            let doc_norms: Vec<f64> = (0..mb.docs.n_docs)
                .map(|d| ((mb.docs.doc_len(d) + kam1) as f64).max(1e-300).ln())
                .collect();
            for &gw in &mb.local_words {
                let gw = gw as usize;
                let (s, en) = vm.word_range(gw);
                let col = f.store.read_column(gw);
                for i in s..en {
                    let d = vm.doc_ids[i] as usize;
                    let c = vm.counts[i];
                    let th = &theta[d * k..(d + 1) * k];
                    let mut z = 0.0f32;
                    for kk in 0..k {
                        z += (th[kk] + am1) * (col[kk] + bm1)
                            / (f.phisum[kk] + wbm1);
                    }
                    ll += c as f64
                        * (((z as f64).max(1e-300)).ln() - doc_norms[d]);
                }
            }
        }

        MinibatchReport {
            inner_iters: inner,
            seconds: timer.seconds(),
            train_ll: ll,
            tokens,
            resp_bytes: mu.len() * 4,
            scratch_bytes: theta.len() * 4,
        }
    }

    /// The historical shard worker (dense `nnz × K` mu).
    #[allow(clippy::too_many_arguments)]
    pub fn run_foem_shard_dense(
        params: &LdaParams,
        cfg: &FoemConfig,
        shard: &MinibatchShard,
        phi_snap: &PhiSnapshot,
        res_snap: &PhiSnapshot,
        phisum0: &[f32],
        w_dim: usize,
        seed: u64,
    ) -> FoemShardResult {
        let k = params.n_topics;
        let am1 = params.am1();
        let bm1 = params.bm1();
        let wbm1 = params.wbm1(w_dim);
        let vm = &shard.vocab_major;
        let words = &shard.local_words;
        let n_local = words.len();
        let nnz = vm.nnz();
        let tokens = shard.docs.total_tokens();
        let mut rng = Rng::new(seed);

        let mut phi = vec![0.0f32; n_local * k];
        let mut res = vec![0.0f32; n_local * k];
        for (lw, &gw) in words.iter().enumerate() {
            phi[lw * k..(lw + 1) * k].copy_from_slice(
                phi_snap.column(gw).expect("shard word missing from snapshot"),
            );
            res[lw * k..(lw + 1) * k].copy_from_slice(
                res_snap.column(gw).expect("shard word missing from snapshot"),
            );
        }
        let mut phisum = phisum0.to_vec();
        let mut r_totals: Vec<f32> = (0..n_local)
            .map(|lw| res[lw * k..(lw + 1) * k].iter().sum())
            .collect();

        let mut mu = vec![0.0f32; nnz * k];
        let mut theta = vec![0.0f32; shard.docs.n_docs * k];

        {
            let mut e_base = 0usize;
            for (lw, &gw) in words.iter().enumerate() {
                let (s, en) = vm.word_range(gw as usize);
                let col = &mut phi[lw * k..(lw + 1) * k];
                let rcol = &mut res[lw * k..(lw + 1) * k];
                for (off, i) in (s..en).enumerate() {
                    let d = vm.doc_ids[i] as usize;
                    let c = vm.counts[i];
                    let topic = rng.below(k);
                    mu[(e_base + off) * k + topic] = 1.0;
                    theta[d * k + topic] += c;
                    col[topic] += c;
                    phisum[topic] += c;
                    rcol[topic] += c;
                    r_totals[lw] += c;
                }
                e_base += en - s;
            }
        }

        let mut entry_base = vec![0usize; n_local + 1];
        let mut word_mass = vec![0.0f32; n_local];
        for (lw, &gw) in words.iter().enumerate() {
            let (s, e) = vm.word_range(gw as usize);
            entry_base[lw + 1] = entry_base[lw] + (e - s);
            word_mass[lw] = vm.word_counts(gw as usize).iter().sum();
        }

        let n_sel = cfg.topic_subset.size(k);
        let mut inner = 0usize;
        let mut sel: Vec<u32> = Vec::with_capacity(n_sel);
        let mut scratch_mu = vec![0.0f32; n_sel];
        let mut fresh_res = vec![0.0f32; n_sel];
        for t in 0..cfg.max_inner_iters {
            let mut order: Vec<u32> = (0..n_local as u32).collect();
            order.sort_unstable_by(|&a, &b| {
                let ra = r_totals[a as usize];
                let rb = r_totals[b as usize];
                rb.partial_cmp(&ra).unwrap_or(std::cmp::Ordering::Equal)
            });
            let keep =
                ((cfg.lambda_w as f64 * n_local as f64).ceil() as usize)
                    .clamp(1, n_local);
            order.truncate(keep);

            let mut moved = 0.0f64;
            for &lw in &order {
                let lw = lw as usize;
                let gw = words[lw] as usize;
                if (r_totals[lw] as f64)
                    < cfg.residual_tol * word_mass[lw] as f64
                {
                    break;
                }
                let (s, en) = vm.word_range(gw);
                let base = entry_base[lw];
                let rcol = &mut res[lw * k..(lw + 1) * k];
                resp::top_n_indices(rcol, n_sel, &mut sel);
                if n_sel < k && cfg.explore_slots > 0 {
                    let swaps = cfg.explore_slots.min(n_sel / 2);
                    for j in 0..swaps {
                        let cand = rng.below(k) as u32;
                        if !sel.contains(&cand) {
                            let pos = sel.len() - 1 - j;
                            sel[pos] = cand;
                        }
                    }
                }
                let mut removed = 0.0f32;
                for &kk in &sel {
                    removed += rcol[kk as usize];
                    rcol[kk as usize] = 0.0;
                }
                fresh_res.iter_mut().for_each(|x| *x = 0.0);
                let col = &mut phi[lw * k..(lw + 1) * k];
                for (off, i) in (s..en).enumerate() {
                    let e = base + off;
                    let d = vm.doc_ids[i] as usize;
                    let c = vm.counts[i];
                    let mu_row = &mut mu[e * k..(e + 1) * k];
                    let th = &mut theta[d * k..(d + 1) * k];
                    let mut m_old = 0.0f32;
                    for &kk in &sel {
                        m_old += mu_row[kk as usize];
                    }
                    if m_old <= 1e-12 {
                        continue;
                    }
                    let mut z = 0.0f32;
                    for (j, &kk) in sel.iter().enumerate() {
                        let kk = kk as usize;
                        let excl = c * mu_row[kk];
                        let u = (th[kk] - excl + am1)
                            * (col[kk] - excl + bm1)
                            / (phisum[kk] - excl + wbm1);
                        scratch_mu[j] = u.max(0.0);
                        z += scratch_mu[j];
                    }
                    if z <= 0.0 {
                        continue;
                    }
                    let renorm = m_old / z;
                    for (j, &kk) in sel.iter().enumerate() {
                        let kk = kk as usize;
                        let new = scratch_mu[j] * renorm;
                        let delta = c * (new - mu_row[kk]);
                        th[kk] += delta;
                        col[kk] += delta;
                        phisum[kk] += delta;
                        fresh_res[j] += delta.abs();
                        mu_row[kk] = new;
                    }
                }
                let mut word_moved = 0.0f32;
                for (j, &kk) in sel.iter().enumerate() {
                    rcol[kk as usize] += fresh_res[j];
                    word_moved += fresh_res[j];
                }
                r_totals[lw] =
                    (r_totals[lw] - removed + word_moved).max(0.0);
                moved += word_moved as f64;
            }
            inner = t + 1;
            if moved / tokens.max(1.0) < cfg.residual_tol {
                break;
            }
        }

        let mut phi_delta = SsDelta::zeros(k, words.clone());
        let mut res_delta = SsDelta::zeros(k, words.clone());
        for (lw, &gw) in words.iter().enumerate() {
            let psnap = phi_snap.column(gw).expect("snapshot column");
            let rsnap = res_snap.column(gw).expect("snapshot column");
            for kk in 0..k {
                let dp = phi[lw * k + kk] - psnap[kk];
                if dp != 0.0 {
                    phi_delta.add_at(lw, kk, dp);
                }
                let dr = res[lw * k + kk] - rsnap[kk];
                if dr != 0.0 {
                    res_delta.add_at(lw, kk, dr);
                }
            }
        }
        FoemShardResult {
            inner_iters: inner,
            phi_delta,
            res_delta,
            theta,
            resp_bytes: mu.len() * 4,
            scratch_bytes: 0,
        }
    }

    /// Phase-2 compute through the dense shard worker.
    pub fn compute_batch_dense(staged: &FoemStaged) -> FoemDelta {
        let timer = Timer::start();
        let exec = ParallelExecutor::new(staged.cfg.n_workers);
        let results = exec.run_sharded(&staged.shards, |shard| {
            run_foem_shard_dense(
                &staged.params,
                &staged.cfg,
                shard,
                &staged.phi_snap,
                &staged.res_snap,
                &staged.phisum0,
                staged.w_dim,
                staged.seeds[shard.shard_index],
            )
        });
        FoemDelta { results, compute_seconds: timer.seconds() }
    }

    /// A [`PhasedTrainer`] whose compute phase is the dense reference —
    /// drives the REAL stage/apply/pipeline code, so a pipelined run of
    /// this wrapper is exactly what `main`'s pre-arena build produced.
    pub struct DenseFoem<S: PhiColumnStore>(pub Foem<S>);

    impl<S: PhiColumnStore> crate::exec::pipeline::PhasedTrainer
        for DenseFoem<S>
    {
        type Staged = FoemStaged;
        type Delta = FoemDelta;

        fn stage(&mut self, mb: &Minibatch) -> FoemStaged {
            self.0.stage_batch(mb)
        }

        fn compute(staged: &FoemStaged) -> FoemDelta {
            compute_batch_dense(staged)
        }

        fn apply(
            &mut self,
            staged: &FoemStaged,
            delta: FoemDelta,
        ) -> MinibatchReport {
            self.0.apply_batch(staged, delta)
        }

        fn process_direct(&mut self, mb: &Minibatch) -> MinibatchReport {
            if self.0.cfg.n_workers <= 1 {
                process_minibatch_serial_dense(&mut self.0, mb)
            } else {
                let staged = self.0.stage_batch(mb);
                let delta = compute_batch_dense(&staged);
                self.0.apply_batch(&staged, delta)
            }
        }

        fn prefetch(&mut self, mb: &Minibatch) {
            self.0.store.prefetch_columns(&mb.local_words);
            self.0.res_store.prefetch_columns(&mb.local_words);
        }

        fn begin_pipeline(&mut self) {
            self.0.store.set_async_io(true);
            self.0.res_store.set_async_io(true);
        }

        fn end_pipeline(&mut self) {
            self.0.store.set_async_io(false);
            self.0.res_store.set_async_io(false);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};
    use crate::store::InMemoryPhi;
    use crate::stream::{CorpusStream, StreamConfig};

    fn corpus() -> crate::corpus::Corpus {
        generate(&SyntheticConfig::small(), 17)
    }

    fn run_foem(
        cfg: FoemConfig,
        k: usize,
        minibatch_docs: usize,
    ) -> (Foem<InMemoryPhi>, Vec<MinibatchReport>) {
        let c = corpus();
        let p = LdaParams::paper_defaults(k);
        let store = InMemoryPhi::zeros(k, c.n_words());
        let mut foem = Foem::new(p, store, cfg, 0);
        let scfg = StreamConfig { minibatch_docs, ..Default::default() };
        let reports: Vec<_> = CorpusStream::new(&c, scfg)
            .map(|mb| foem.process_minibatch(&mb))
            .collect();
        (foem, reports)
    }

    #[test]
    fn accumulates_full_corpus_mass() {
        // Eq. 33 accumulation: after the stream, phi holds exactly the
        // corpus token mass (contributions are moved, never rescaled).
        let (mut foem, _) = run_foem(FoemConfig::paper(), 8, 64);
        let c = corpus();
        let total = c.n_tokens();
        assert!(
            (foem.phisum_total() - total).abs() < total * 1e-4,
            "{} vs {total}",
            foem.phisum_total()
        );
        // phisum consistent with columns
        let dense = foem.export_phi();
        for kk in 0..8 {
            assert!(
                (dense.phisum[kk] - foem.phisum[kk]).abs()
                    < foem.phisum[kk].abs().max(1.0) * 1e-3
            );
        }
    }

    #[test]
    fn subset_scheduling_converges() {
        let mut cfg = FoemConfig::paper();
        cfg.topic_subset = TopicSubset::Fixed(3);
        let (_, reports) = run_foem(cfg, 10, 64);
        for r in &reports {
            assert!(r.inner_iters <= cfg.max_inner_iters);
            assert!(r.train_perplexity().is_finite());
        }
        // At least one minibatch must converge before the budget (the
        // scheduler is doing *something*).
        assert!(reports.iter().any(|r| r.inner_iters < cfg.max_inner_iters));
    }

    #[test]
    fn full_subset_equals_iem_semantics() {
        // lambda_k = 1, lambda_w = 1, one giant minibatch: the inner loop
        // is plain IEM; perplexity must come out close to the standalone
        // IEM implementation on the same data.
        let c = corpus();
        let k = 6;
        let p = LdaParams::paper_defaults(k);
        let mut cfg = FoemConfig::paper();
        cfg.topic_subset = TopicSubset::All;
        cfg.residual_tol = 1e-4;
        cfg.max_inner_iters = 60;
        let store = InMemoryPhi::zeros(k, c.n_words());
        let mut foem = Foem::new(p, store, cfg, 3);
        let scfg = StreamConfig {
            minibatch_docs: c.n_docs(),
            ..Default::default()
        };
        let report = CorpusStream::new(&c, scfg)
            .map(|mb| foem.process_minibatch(&mb))
            .next()
            .unwrap();

        let mut iem = crate::em::iem::Iem::init(&c.docs, p, 3);
        let mut last = f64::INFINITY;
        for _ in 0..60 {
            last = crate::em::perplexity(iem.sweep(&c.docs), c.n_tokens());
        }
        // Both run the same update rule but in different entry orders
        // (vocab-major vs shuffled) from different random inits, so they
        // land in nearby — not identical — local optima.
        let foem_ppx = report.train_perplexity();
        assert!(
            (foem_ppx - last).abs() < last * 0.25,
            "FOEM {foem_ppx} vs IEM {last}"
        );
    }

    #[test]
    fn scheduled_foem_close_to_full_foem() {
        // Fig. 7's claim: lambda_k scheduling barely changes accuracy —
        // and less so the larger lambda_k*K is (the paper's plot shows
        // the gap closing with K; its production bound is
        // lambda_k*K = 10). At this miniature K=32 we check half-K
        // scheduling lands near the full run AND that accuracy improves
        // monotonically with the subset size.
        let k = 32;
        let run = |subset| {
            let mut cfg = FoemConfig::paper();
            cfg.topic_subset = subset;
            cfg.residual_tol = 0.005;
            run_foem(cfg, k, 100).0
        };
        let mut full = run(TopicSubset::All);
        let mut half = run(TopicSubset::Fraction(0.5));
        let mut tiny = run(TopicSubset::Fraction(0.1));
        let c = corpus();
        let p = LdaParams::paper_defaults(k);
        let ppx_full = eval_ppx(&mut full, &c, &p);
        let ppx_half = eval_ppx(&mut half, &c, &p);
        let ppx_tiny = eval_ppx(&mut tiny, &c, &p);
        assert!(
            (ppx_half - ppx_full).abs() < ppx_full * 0.20,
            "full={ppx_full} half={ppx_half}"
        );
        // Larger subsets must not be (meaningfully) worse than smaller.
        assert!(
            ppx_half <= ppx_tiny * 1.05,
            "half={ppx_half} tiny={ppx_tiny}"
        );
    }

    fn eval_ppx<S: PhiColumnStore>(
        foem: &mut Foem<S>,
        c: &crate::corpus::Corpus,
        p: &LdaParams,
    ) -> f64 {
        let phi = foem.export_phi();
        let theta = crate::em::infer::fold_in(
            &phi,
            p,
            &c.docs,
            &crate::em::infer::FoldInConfig::dense(20),
            1,
        );
        let ll = crate::em::train_log_likelihood(&c.docs, &theta, &phi, p);
        crate::em::perplexity(ll, c.n_tokens())
    }

    #[test]
    fn parallel_workers_preserve_mass_and_quality() {
        // Eq. 33 accumulation must survive document sharding: for any P,
        // the merged global stats hold exactly the stream's token mass,
        // and phisum stays consistent with the columns.
        let c = corpus();
        let k = 8;
        let p = LdaParams::paper_defaults(k);
        for workers in [2usize, 4] {
            let mut cfg = FoemConfig::paper();
            cfg.n_workers = workers;
            let store = InMemoryPhi::zeros(k, c.n_words());
            let mut foem = Foem::new(p, store, cfg, 7);
            let scfg =
                StreamConfig { minibatch_docs: 64, ..Default::default() };
            for mb in CorpusStream::new(&c, scfg) {
                let r = foem.process_minibatch(&mb);
                assert!(r.train_perplexity().is_finite(), "P={workers}");
                assert!(r.inner_iters >= 1);
            }
            let total = c.n_tokens();
            assert!(
                (foem.phisum_total() - total).abs() < total * 1e-3,
                "P={workers}: {} vs {total}",
                foem.phisum_total()
            );
            let dense = foem.export_phi();
            for kk in 0..k {
                assert!(
                    (dense.phisum[kk] - foem.phisum[kk]).abs()
                        < foem.phisum[kk].abs().max(1.0) * 1e-3,
                    "P={workers} topic {kk}"
                );
            }
            assert!(foem.r_totals.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn parallel_works_with_paged_store() {
        // The snapshot/merge path must serve the disk-backed store too:
        // columns are read once into the snapshot and merged back with
        // one read-modify-write each.
        let dir = crate::util::TempDir::new("par");
        let c = corpus();
        let k = 6;
        let p = LdaParams::paper_defaults(k);
        let mut cfg = FoemConfig::paper();
        cfg.n_workers = 2;
        cfg.hot_words = 16;
        let mut foem = Foem::paged_create(
            p,
            &dir.path().join("phi.bin"),
            c.n_words(),
            32 * k * 4,
            cfg,
            0,
        )
        .unwrap();
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        for mb in CorpusStream::new(&c, scfg) {
            foem.process_minibatch(&mb);
        }
        let io = foem.store.io_stats();
        assert!(io.col_reads > 0, "no streaming happened");
        let total = c.n_tokens();
        assert!((foem.phisum_total() - total).abs() < total * 1e-3);
    }

    #[test]
    fn residuals_decay_across_stream() {
        // The streamed residual totals must shrink as the model settles
        // (they measure distance from the fixed point, §3.1).
        let (foem, reports) = run_foem(FoemConfig::paper(), 8, 50);
        assert!(reports.len() >= 3);
        let total_res: f64 =
            foem.r_totals.iter().map(|&x| x as f64).sum();
        // Residual mass per token far below 1 after convergence.
        let c = corpus();
        assert!(
            total_res / c.n_tokens() < 0.5,
            "residuals did not decay: {total_res}"
        );
    }

    #[test]
    fn works_with_paged_store() {
        let dir = crate::util::TempDir::new("t");
        let c = corpus();
        let k = 6;
        let p = LdaParams::paper_defaults(k);
        let store = crate::store::paged::PagedPhi::create(
            &dir.path().join("phi.bin"),
            k,
            c.n_words(),
            16 * k * 4,
        )
        .unwrap();
        let res = crate::store::paged::PagedPhi::create(
            &dir.path().join("phi.res.bin"),
            k,
            c.n_words(),
            16 * k * 4,
        )
        .unwrap();
        let mut cfg = FoemConfig::paper();
        cfg.hot_words = 16;
        let mut foem = Foem::with_stores(p, store, res, cfg, 0);
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        for mb in CorpusStream::new(&c, scfg) {
            foem.process_minibatch(&mb);
        }
        let io = foem.store.io_stats();
        assert!(io.buffer_hits > 0, "hot buffer unused");
        assert!(io.col_reads > 0, "no streaming happened");
        // Same mass invariant as in-memory.
        let total = c.n_tokens();
        assert!((foem.phisum_total() - total).abs() < total * 1e-4);
    }

    #[test]
    fn paged_equals_in_memory_numerics() {
        // The storage backend must not change the math at all.
        let dir = crate::util::TempDir::new("t");
        let c = corpus();
        let k = 5;
        let p = LdaParams::paper_defaults(k);
        let cfg = FoemConfig::paper();
        let mut a = Foem::new(p, InMemoryPhi::zeros(k, c.n_words()), cfg, 9);
        let store = crate::store::paged::PagedPhi::create(
            &dir.path().join("phi.bin"),
            k,
            c.n_words(),
            8 * k * 4,
        )
        .unwrap();
        let res = crate::store::paged::PagedPhi::create(
            &dir.path().join("phi.res.bin"),
            k,
            c.n_words(),
            8 * k * 4,
        )
        .unwrap();
        let mut b = Foem::with_stores(p, store, res, cfg, 9);
        let scfg = StreamConfig { minibatch_docs: 80, ..Default::default() };
        for mb in CorpusStream::new(&c, scfg) {
            a.process_minibatch(&mb);
        }
        for mb in CorpusStream::new(&c, scfg) {
            b.process_minibatch(&mb);
        }
        let da = a.export_phi();
        let db = b.export_phi();
        for w in 0..c.n_words() {
            for kk in 0..k {
                let x = da.word(w)[kk];
                let y = db.word(w)[kk];
                assert!(
                    (x - y).abs() <= x.abs().max(1.0) * 1e-4,
                    "w={w} k={kk}: {x} vs {y}"
                );
            }
        }
    }

    /// Bitwise comparison of two trained FOEM states (phi, phisum,
    /// residual totals).
    fn assert_states_identical<S: PhiColumnStore>(
        a: &mut Foem<S>,
        b: &mut Foem<S>,
    ) {
        let da = a.export_phi();
        let db = b.export_phi();
        assert_eq!(da.raw().len(), db.raw().len());
        for (i, (x, y)) in da.raw().iter().zip(db.raw()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "phi diverged at {i}");
        }
        for (i, (x, y)) in a.phisum.iter().zip(&b.phisum).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "phisum diverged at {i}");
        }
        for (i, (x, y)) in a.r_totals.iter().zip(&b.r_totals).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "r_totals diverged at {i}");
        }
    }

    #[test]
    fn arena_serial_bit_identical_to_dense_reference() {
        // The tentpole invariant: the slot-compressed arena changes the
        // storage, not one bit of the math — across sparse lanes, lanes
        // with exploration, and the dense-layout (All) fallback.
        let c = corpus();
        let k = 32;
        let p = LdaParams::paper_defaults(k);
        for (subset, explore) in [
            (TopicSubset::Fixed(3), 1usize), // tiny lanes -> spill path
            (TopicSubset::Fixed(10), 4),     // paper production shape
            (TopicSubset::All, 4),           // dense-layout fallback
        ] {
            let mut cfg = FoemConfig::paper();
            cfg.topic_subset = subset;
            cfg.explore_slots = explore;
            let mk = || Foem::new(p, InMemoryPhi::zeros(k, c.n_words()), cfg, 123);
            let (mut a, mut b) = (mk(), mk());
            let scfg =
                StreamConfig { minibatch_docs: 64, ..Default::default() };
            let mut spilled = false;
            for mb in CorpusStream::new(&c, scfg) {
                let ra = a.process_minibatch_serial(&mb);
                let rb = dense_ref::process_minibatch_serial_dense(&mut b, &mb);
                assert_eq!(
                    ra.train_ll.to_bits(),
                    rb.train_ll.to_bits(),
                    "{subset:?} ll diverged"
                );
                assert_eq!(ra.inner_iters, rb.inner_iters, "{subset:?}");
                spilled |= a.resp_scratch.spill_len() > 0;
            }
            assert_states_identical(&mut a, &mut b);
            if subset == TopicSubset::Fixed(3) {
                assert!(spilled, "spill path never exercised");
            }
        }
    }

    #[test]
    fn arena_serial_paged_matches_dense_reference_io() {
        // Same invariant on the disk-backed store, including the full
        // IoStats: the arena must not change WHAT the store sees either.
        let dir = crate::util::TempDir::new("arena-io");
        let c = corpus();
        let k = 16;
        let p = LdaParams::paper_defaults(k);
        let mut cfg = FoemConfig::paper();
        cfg.topic_subset = TopicSubset::Fixed(4);
        cfg.hot_words = 8;
        let mk = |name: &str| {
            Foem::paged_create(
                p,
                &dir.path().join(name),
                c.n_words(),
                16 * k * 4,
                cfg,
                9,
            )
            .unwrap()
        };
        let (mut a, mut b) = (mk("a.bin"), mk("b.bin"));
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        for mb in CorpusStream::new(&c, scfg) {
            let ra = a.process_minibatch_serial(&mb);
            let rb = dense_ref::process_minibatch_serial_dense(&mut b, &mb);
            assert_eq!(ra.train_ll.to_bits(), rb.train_ll.to_bits());
        }
        assert_eq!(a.store.io_stats(), b.store.io_stats());
        assert_eq!(a.res_store.io_stats(), b.res_store.io_stats());
        assert_states_identical(&mut a, &mut b);
    }

    #[test]
    fn codec_raw_auto_foem_bit_identical_with_identical_logical_io() {
        // The compressed-store acceptance contract: Codec::Auto changes
        // how many bytes hit the disk, not one bit of the model and not
        // one logical I/O count. Serial path (depth 0 / P=1), same seed,
        // forced-Raw vs auto-selected stores.
        let dir = crate::util::TempDir::new("codec-eq");
        let c = corpus();
        let k = 16;
        let p = LdaParams::paper_defaults(k);
        let mut cfg = FoemConfig::paper();
        cfg.topic_subset = TopicSubset::Fixed(4);
        cfg.hot_words = 8;
        let mk = |name: &str, codec: crate::store::Codec| {
            Foem::paged_create_with_codec(
                p,
                &dir.path().join(name),
                c.n_words(),
                16 * k * 4,
                cfg,
                9,
                codec,
            )
            .unwrap()
        };
        let mut a = mk("raw.bin", crate::store::Codec::Raw);
        let mut b = mk("auto.bin", crate::store::Codec::Auto);
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        for mb in CorpusStream::new(&c, scfg) {
            let ra = a.process_minibatch_serial(&mb);
            let rb = b.process_minibatch_serial(&mb);
            assert_eq!(ra.train_ll.to_bits(), rb.train_ll.to_bits());
            assert_eq!(ra.inner_iters, rb.inner_iters);
        }
        // Every IoStats field except disk_bytes is codec-independent.
        let logical = |io: crate::store::IoStats| crate::store::IoStats {
            disk_bytes: 0,
            ..io
        };
        assert_eq!(
            logical(a.store.io_stats()),
            logical(b.store.io_stats()),
            "phi-store logical IoStats diverged across codecs"
        );
        assert_eq!(
            logical(a.res_store.io_stats()),
            logical(b.res_store.io_stats()),
            "residual-store logical IoStats diverged across codecs"
        );
        // ...while the physical traffic and the file itself shrink (the
        // subsetted E-step keeps columns sparse, so Auto beats Raw).
        assert!(
            b.store.io_stats().disk_bytes < a.store.io_stats().disk_bytes,
            "auto failed to compress disk traffic"
        );
        assert!(b.store.data_bytes_on_disk() < a.store.data_bytes_on_disk());
        assert_states_identical(&mut a, &mut b);
    }

    #[test]
    fn arena_parallel_bit_identical_to_dense_reference() {
        // n_workers = 4: identical per-shard seeds + identical shard
        // kernels must reduce to identical deltas, applies and reports.
        let c = corpus();
        let k = 32;
        let p = LdaParams::paper_defaults(k);
        let mut cfg = FoemConfig::paper();
        cfg.topic_subset = TopicSubset::Fixed(6);
        cfg.explore_slots = 2;
        cfg.n_workers = 4;
        let mk = || Foem::new(p, InMemoryPhi::zeros(k, c.n_words()), cfg, 7);
        let (mut a, mut b) = (mk(), mk());
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        for mb in CorpusStream::new(&c, scfg) {
            let sa = a.stage_batch(&mb);
            let da = Foem::<InMemoryPhi>::compute_batch(&sa);
            let ra = a.apply_batch(&sa, da);
            let sb = b.stage_batch(&mb);
            let db = dense_ref::compute_batch_dense(&sb);
            let rb = b.apply_batch(&sb, db);
            assert_eq!(ra.train_ll.to_bits(), rb.train_ll.to_bits());
            assert_eq!(ra.inner_iters, rb.inner_iters);
        }
        assert_states_identical(&mut a, &mut b);
    }

    #[test]
    fn arena_pipelined_paged_bit_identical_to_dense_reference() {
        // depth = 2 over the paged store: the arena side and the dense
        // reference (wrapped as a PhasedTrainer) run the SAME pipeline
        // machinery, so numerics must agree bit-for-bit. Of the IoStats
        // only the deterministic counters are compared: at depth >= 1
        // the write-behind supersede counter (wb_writes) and the
        // pending/prefetch hit split race against the I/O thread by
        // design (see store/paged.rs) — the depth-0 and serial tests pin
        // the full struct.
        use crate::exec::pipeline::Pipeline;
        let dir = crate::util::TempDir::new("arena-pipe");
        let c = corpus();
        let k = 16;
        let p = LdaParams::paper_defaults(k);
        let mut cfg = FoemConfig::paper();
        cfg.topic_subset = TopicSubset::Fixed(4);
        cfg.explore_slots = 2;
        cfg.n_workers = 2;
        cfg.hot_words = 8;
        let mk = |name: &str| {
            Foem::paged_create(
                p,
                &dir.path().join(name),
                c.n_words(),
                16 * k * 4,
                cfg,
                5,
            )
            .unwrap()
        };
        let mut a = mk("a.bin");
        let mut b = dense_ref::DenseFoem(mk("b.bin"));
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };

        let mut trace_a: Vec<(u64, usize)> = Vec::new();
        Pipeline::new(2)
            .run(&mut a, CorpusStream::new(&c, scfg), |_, _, r| {
                trace_a.push((r.train_ll.to_bits(), r.inner_iters));
                Ok(())
            })
            .unwrap();
        let mut trace_b: Vec<(u64, usize)> = Vec::new();
        Pipeline::new(2)
            .run(&mut b, CorpusStream::new(&c, scfg), |_, _, r| {
                trace_b.push((r.train_ll.to_bits(), r.inner_iters));
                Ok(())
            })
            .unwrap();

        assert_eq!(trace_a, trace_b, "pipelined trace diverged");
        let (ia, ib) = (a.store.io_stats(), b.0.store.io_stats());
        assert_eq!(ia.col_writes, ib.col_writes);
        let total_reads = |io: &crate::store::IoStats| {
            io.col_reads + io.buffer_hits + io.prefetch_hits
        };
        assert_eq!(total_reads(&ia), total_reads(&ib));
        assert_states_identical(&mut a, &mut b.0);
    }

    #[test]
    fn open_vocabulary_grows_denominator() {
        let c = corpus();
        let k = 4;
        let p = LdaParams::paper_defaults(k);
        let mut cfg = FoemConfig::paper();
        cfg.open_vocabulary = true;
        let store = InMemoryPhi::zeros(k, 1);
        let mut foem = Foem::new(p, store, cfg, 0);
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let mut last_w = 0usize;
        for mb in CorpusStream::new(&c, scfg) {
            foem.process_minibatch(&mb);
            let w = foem.effective_w();
            assert!(w >= last_w, "W must grow monotonically");
            last_w = w;
        }
        assert!(last_w > 100, "vocabulary never grew: {last_w}");
        assert!(foem.store.n_words() >= last_w);
    }

    #[test]
    fn recovery_commit_blob_roundtrips_exactly() {
        let r_totals = vec![0.5f32, 0.0, 3.25, 7.75];
        let blob = encode_commit_state(
            9,
            [1, 2, 3, u64::MAX],
            &[1.0, f32::MIN_POSITIVE, 3.5],
            &[2, 0],
            &r_totals,
        );
        let (step, rng, phisum, touched) =
            decode_commit_state(&blob).unwrap();
        assert_eq!(step, 9);
        assert_eq!(rng, [1, 2, 3, u64::MAX]);
        assert_eq!(phisum, vec![1.0, f32::MIN_POSITIVE, 3.5]);
        assert_eq!(touched, vec![(2, 3.25), (0, 0.5)]);
        // Truncated blobs are rejected, not misread.
        assert!(decode_commit_state(&blob[..blob.len() - 3]).is_err());
        assert!(decode_commit_state(&[]).is_err());
    }

    #[test]
    fn recovery_crash_after_commit_resumes_bit_identical() {
        // The headline PR-8 guarantee at the trainer level: checkpoint
        // after batch 2, kill WITHOUT any flush after batch 4, recover
        // (checkpoint + WAL replay of batches 3-4), finish the stream —
        // every number bitwise equal to the uninterrupted run.
        let c = corpus();
        let k = 6;
        let p = LdaParams::paper_defaults(k);
        let mut cfg = FoemConfig::paper();
        cfg.hot_words = 8;
        let scfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let mbs: Vec<_> = CorpusStream::new(&c, scfg).collect();
        assert!(mbs.len() >= 5, "need a multi-batch stream");

        // Uninterrupted reference run (WAL off — also pins the WAL-off
        // path to identical numerics).
        let dir_a = crate::util::TempDir::new("rec-ref");
        let mut a = Foem::paged_create(
            p,
            &dir_a.path().join("phi.bin"),
            c.n_words(),
            16 * k * 4,
            cfg,
            42,
        )
        .unwrap();
        for mb in &mbs {
            a.process_minibatch(mb);
        }

        // Crashing run with the WAL armed.
        let dir_b = crate::util::TempDir::new("rec-crash");
        let path = dir_b.path().join("phi.bin");
        let mut b =
            Foem::paged_create(p, &path, c.n_words(), 16 * k * 4, cfg, 42)
                .unwrap();
        b.enable_wal().unwrap();
        let mut state = None;
        for (i, mb) in mbs.iter().enumerate() {
            b.process_minibatch(mb);
            if i + 1 == 2 {
                b.checkpoint_paged().unwrap();
                state = Some(b.export_train_state());
                b.store.truncate_wal().unwrap();
                b.res_store.truncate_wal().unwrap();
            }
            if i + 1 == 4 {
                break;
            }
        }
        assert!(b.store.poisoned().is_none());
        // Crash: hot buffers and the in-memory directory die un-flushed.
        // (Leaks the store handles — fine for a test; Drop would flush
        // and defeat the point.)
        std::mem::forget(b);

        let (mut r, last) =
            Foem::paged_resume(p, &path, 16 * k * 4, cfg, state.as_ref().unwrap())
                .unwrap();
        assert_eq!(last, 4, "batches 3-4 were committed and must replay");
        assert_eq!(r.step, 4);
        for mb in mbs.iter().skip(last as usize) {
            r.process_minibatch(mb);
        }
        assert_eq!(r.step, a.step);
        assert_eq!(r.rng.state(), a.rng.state());
        assert_states_identical(&mut a, &mut r);
    }
}
