//! Runtime-dispatched SIMD primitives for the shared E-step kernel.
//!
//! The paper's speed argument (§3, Fig. 4) rests on the per-token
//! exclude–recompute–renormalize update being cheap; PR 3 collapsed all
//! trainer/fold-in/serve paths onto one copy of that Eq. 13/38 loop in
//! [`crate::em::resp`]. This module vectorizes its three hot phases —
//! subset gather + `m_old` reduction, the exclude/recompute `u_j` loop,
//! and the include/renormalize writeback — as slice-level primitives
//! dispatched over a [`KernelIsa`] tier resolved once at startup:
//!
//! * **`Scalar`** — never reaches this module. The callers in
//!   `em::resp` keep the historical scalar loops verbatim, preserving
//!   the bit-identity contracts (`dense_ref`, sparse-vs-dense tests).
//! * **`Portable`** — 4-lane-unrolled scalar with split accumulators.
//!   Same element-wise float ops as `Scalar`; only the *reduction order*
//!   of `m_old`/`z` differs (tolerance-class reassociation). Selected
//!   when [`KernelBackend::Simd`] is forced on a host without AVX2.
//! * **`Avx2`** — explicit `std::arch` AVX2+FMA: 8-wide gathers for the
//!   scheduled-subset loads, fused `(th−excl+am1)(col−excl+bm1)/(…)`
//!   via `fnmadd`, `max_ps` clamping, and a tree horizontal sum.
//!   Requires runtime `avx2` **and** `fma` detection (checked once,
//!   cached); the stable toolchain compiles it on every x86-64 because
//!   the intrinsics are gated per-function with `#[target_feature]`,
//!   not per-crate with `-C target-cpu`.
//!
//! One flag (`phi_excl`) serves both kernel variants: the training
//! update excludes the entry's own mass from `col`/`phisum`, the
//! fold-in theta-only update does not. Because `x - 0.0 == x` exactly
//! for every finite `f32`, passing a zero exclusion coefficient for the
//! phi factors reproduces the theta-variant formula bit-for-bit in the
//! scalar tiers, so one code path covers Eq. 13 and the frozen-phi
//! Eq. 38 fold-in without a second kernel.
//!
//! The backend seam ([`KernelBackend`] on `RunConfig` → `SweepKernel`)
//! is deliberately the same seam ROADMAP item 3 earmarks for a future
//! `pjrt`/XLA `compute_batch` offload: anything that can service the
//! three primitive phases can be slotted in behind the same enum.

use std::sync::OnceLock;

/// User-facing kernel-backend knob (`--kernel-backend`, config key
/// `kernel_backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelBackend {
    /// The reference scalar kernel — bit-identical to the historical
    /// dense loops; the determinism anchor for all `dense_ref` tests.
    #[default]
    Scalar,
    /// Force SIMD: AVX2+FMA when the host has it, else the portable
    /// unrolled tier. Tolerance-class numerics (reductions reassociate).
    Simd,
    /// AVX2+FMA when detected, otherwise fall back to `Scalar` so the
    /// default numerics stay deterministic on unknown hardware.
    Auto,
}

impl KernelBackend {
    /// Parse a CLI/config value (`scalar` | `simd` | `auto`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        for b in Self::all() {
            if s == b.name() {
                return Ok(b);
            }
        }
        anyhow::bail!("unknown kernel backend {s:?} (scalar|simd|auto)")
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Simd => "simd",
            KernelBackend::Auto => "auto",
        }
    }

    pub fn all() -> [KernelBackend; 3] {
        [KernelBackend::Scalar, KernelBackend::Simd, KernelBackend::Auto]
    }

    /// Resolve the knob to a concrete instruction tier (detection runs
    /// once per process and is cached).
    pub fn resolve(self) -> KernelIsa {
        match self {
            KernelBackend::Scalar => KernelIsa::Scalar,
            KernelBackend::Simd => {
                if avx2_available() {
                    KernelIsa::Avx2
                } else {
                    KernelIsa::Portable
                }
            }
            KernelBackend::Auto => {
                if avx2_available() {
                    KernelIsa::Avx2
                } else {
                    KernelIsa::Scalar
                }
            }
        }
    }
}

/// Concrete instruction tier a [`KernelBackend`] resolves to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelIsa {
    /// Reference scalar loops (handled by the callers, not here).
    #[default]
    Scalar,
    /// 4-lane-unrolled scalar with split reduction accumulators.
    Portable,
    /// 8-wide AVX2 + FMA (`x86_64` with runtime `avx2`+`fma`).
    Avx2,
}

impl KernelIsa {
    pub fn name(&self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Portable => "portable",
            KernelIsa::Avx2 => "avx2",
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_avx2() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_avx2() -> bool {
    false
}

/// Does this host support the AVX2+FMA tier? Detected once, cached.
pub fn avx2_available() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(detect_avx2)
}

/// One element of the unified exclude/recompute: `c` excludes from the
/// theta factor, `c_phi` from the phi factors (`0.0` for the fold-in
/// theta-only variant — `x - 0.0 == x` exactly, so the formula
/// degenerates to the frozen-phi Eq. 38 form bit-for-bit).
#[inline(always)]
fn recompute_one(
    mu: f32,
    th: f32,
    col: f32,
    ps: f32,
    c: f32,
    c_phi: f32,
    am1: f32,
    bm1: f32,
    wbm1: f32,
) -> f32 {
    let excl_t = c * mu;
    let excl_p = c_phi * mu;
    let u = (th - excl_t + am1) * (col - excl_p + bm1) / (ps - excl_p + wbm1);
    u.max(0.0)
}

/// `dst[j] = src[sel[j]]` — the subset gather. Exact in every tier.
pub fn gather(isa: KernelIsa, src: &[f32], sel: &[u32], dst: &mut [f32]) {
    debug_assert_eq!(sel.len(), dst.len());
    match isa {
        KernelIsa::Avx2 => gather_avx2(src, sel, dst),
        _ => {
            for (d, &kk) in dst.iter_mut().zip(sel) {
                *d = src[kk as usize];
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn gather_avx2(src: &[f32], sel: &[u32], dst: &mut [f32]) {
    // SAFETY: Avx2 is only resolved after runtime avx2+fma detection.
    unsafe { avx2::gather(src, sel, dst) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn gather_avx2(src: &[f32], sel: &[u32], dst: &mut [f32]) {
    for (d, &kk) in dst.iter_mut().zip(sel) {
        *d = src[kk as usize];
    }
}

/// Σ `xs` — the `m_old` reduction. `Scalar` keeps the sequential order;
/// the SIMD tiers reassociate (tolerance-class).
pub fn sum(isa: KernelIsa, xs: &[f32]) -> f32 {
    match isa {
        KernelIsa::Scalar => xs.iter().sum(),
        KernelIsa::Portable => sum_portable(xs),
        KernelIsa::Avx2 => sum_avx2(xs),
    }
}

fn sum_portable(xs: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let mut it = xs.chunks_exact(4);
    for ch in it.by_ref() {
        acc[0] += ch[0];
        acc[1] += ch[1];
        acc[2] += ch[2];
        acc[3] += ch[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for &x in it.remainder() {
        s += x;
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn sum_avx2(xs: &[f32]) -> f32 {
    // SAFETY: Avx2 is only resolved after runtime avx2+fma detection.
    unsafe { avx2::sum(xs) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn sum_avx2(xs: &[f32]) -> f32 {
    sum_portable(xs)
}

/// The exclude/recompute loop over a gathered subset: for each `j`,
/// `u_out[j] = max(0, (th[sel_j]−c·mu_j+am1)(col[sel_j]−c_phi·mu_j+bm1)
/// / (phisum[sel_j]−c_phi·mu_j+wbm1))` with `c_phi = c` when `phi_excl`
/// else `0.0`; returns `z = Σ u_out`.
#[allow(clippy::too_many_arguments)]
pub fn recompute_u(
    isa: KernelIsa,
    sel: &[u32],
    mu_old: &[f32],
    th: &[f32],
    col: &[f32],
    phisum: &[f32],
    c: f32,
    am1: f32,
    bm1: f32,
    wbm1: f32,
    phi_excl: bool,
    u_out: &mut [f32],
) -> f32 {
    debug_assert_eq!(sel.len(), u_out.len());
    let c_phi = if phi_excl { c } else { 0.0 };
    if isa == KernelIsa::Avx2 {
        return recompute_u_avx2(sel, mu_old, th, col, phisum, c, c_phi, am1, bm1, wbm1, u_out);
    }
    for (j, &kk) in sel.iter().enumerate() {
        let kk = kk as usize;
        u_out[j] = recompute_one(mu_old[j], th[kk], col[kk], phisum[kk], c, c_phi, am1, bm1, wbm1);
    }
    sum(isa, u_out)
}

#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[inline]
fn recompute_u_avx2(
    sel: &[u32],
    mu_old: &[f32],
    th: &[f32],
    col: &[f32],
    phisum: &[f32],
    c: f32,
    c_phi: f32,
    am1: f32,
    bm1: f32,
    wbm1: f32,
    u_out: &mut [f32],
) -> f32 {
    // SAFETY: Avx2 is only resolved after runtime avx2+fma detection;
    // sel indices are checked against the operand lengths in debug.
    unsafe {
        avx2::recompute_u_gather(sel, mu_old, th, col, phisum, c, c_phi, am1, bm1, wbm1, u_out)
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
#[inline]
fn recompute_u_avx2(
    sel: &[u32],
    mu_old: &[f32],
    th: &[f32],
    col: &[f32],
    phisum: &[f32],
    c: f32,
    c_phi: f32,
    am1: f32,
    bm1: f32,
    wbm1: f32,
    u_out: &mut [f32],
) -> f32 {
    for (j, &kk) in sel.iter().enumerate() {
        let kk = kk as usize;
        u_out[j] = recompute_one(mu_old[j], th[kk], col[kk], phisum[kk], c, c_phi, am1, bm1, wbm1);
    }
    sum_portable(u_out)
}

/// [`recompute_u`] for the identity selection (`sel[j] == j`, the dense
/// `TopicSubset::All` sweep): all operands load contiguously — no
/// gathers — which is where the ≥1.5× dense-layout win comes from.
#[allow(clippy::too_many_arguments)]
pub fn recompute_u_contig(
    isa: KernelIsa,
    mu_old: &[f32],
    th: &[f32],
    col: &[f32],
    phisum: &[f32],
    c: f32,
    am1: f32,
    bm1: f32,
    wbm1: f32,
    phi_excl: bool,
    u_out: &mut [f32],
) -> f32 {
    let n = u_out.len();
    debug_assert!(mu_old.len() >= n && th.len() >= n && col.len() >= n && phisum.len() >= n);
    let c_phi = if phi_excl { c } else { 0.0 };
    if isa == KernelIsa::Avx2 {
        return recompute_u_contig_avx2(mu_old, th, col, phisum, c, c_phi, am1, bm1, wbm1, u_out);
    }
    for j in 0..n {
        u_out[j] = recompute_one(mu_old[j], th[j], col[j], phisum[j], c, c_phi, am1, bm1, wbm1);
    }
    sum(isa, u_out)
}

#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[inline]
fn recompute_u_contig_avx2(
    mu_old: &[f32],
    th: &[f32],
    col: &[f32],
    phisum: &[f32],
    c: f32,
    c_phi: f32,
    am1: f32,
    bm1: f32,
    wbm1: f32,
    u_out: &mut [f32],
) -> f32 {
    // SAFETY: Avx2 is only resolved after runtime avx2+fma detection.
    unsafe { avx2::recompute_u_contig(mu_old, th, col, phisum, c, c_phi, am1, bm1, wbm1, u_out) }
}

#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
#[inline]
fn recompute_u_contig_avx2(
    mu_old: &[f32],
    th: &[f32],
    col: &[f32],
    phisum: &[f32],
    c: f32,
    c_phi: f32,
    am1: f32,
    bm1: f32,
    wbm1: f32,
    u_out: &mut [f32],
) -> f32 {
    for (j, u) in u_out.iter_mut().enumerate() {
        *u = recompute_one(mu_old[j], th[j], col[j], phisum[j], c, c_phi, am1, bm1, wbm1);
    }
    sum_portable(u_out)
}

/// The include/renormalize step: `u[j] ← u[j]·renorm` (the new
/// responsibility), `delta[j] = c·(new − mu_old[j])`, and
/// `fresh_res[j] += |delta[j]|` (the residual accumulation feeding the
/// scheduler).
#[allow(clippy::too_many_arguments)]
pub fn finalize_delta(
    isa: KernelIsa,
    renorm: f32,
    c: f32,
    mu_old: &[f32],
    u: &mut [f32],
    delta: &mut [f32],
    fresh_res: &mut [f32],
) {
    let n = u.len();
    debug_assert!(mu_old.len() >= n && delta.len() >= n && fresh_res.len() >= n);
    if isa == KernelIsa::Avx2 {
        finalize_delta_avx2(renorm, c, mu_old, u, delta, fresh_res);
        return;
    }
    for j in 0..n {
        let new = u[j] * renorm;
        let d = c * (new - mu_old[j]);
        u[j] = new;
        delta[j] = d;
        fresh_res[j] += d.abs();
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn finalize_delta_avx2(
    renorm: f32,
    c: f32,
    mu_old: &[f32],
    u: &mut [f32],
    delta: &mut [f32],
    fresh_res: &mut [f32],
) {
    // SAFETY: Avx2 is only resolved after runtime avx2+fma detection.
    unsafe { avx2::finalize_delta(renorm, c, mu_old, u, delta, fresh_res) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn finalize_delta_avx2(
    renorm: f32,
    c: f32,
    mu_old: &[f32],
    u: &mut [f32],
    delta: &mut [f32],
    fresh_res: &mut [f32],
) {
    for (j, x) in u.iter_mut().enumerate() {
        let new = *x * renorm;
        let d = c * (new - mu_old[j]);
        *x = new;
        delta[j] = d;
        fresh_res[j] += d.abs();
    }
}

/// `dst[i] += src[i]` — the contiguous scatter-add of the identity
/// selection's writeback.
pub fn add_assign(isa: KernelIsa, dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    if isa == KernelIsa::Avx2 {
        add_assign_avx2(dst, src);
        return;
    }
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn add_assign_avx2(dst: &mut [f32], src: &[f32]) {
    // SAFETY: Avx2 is only resolved after runtime avx2+fma detection.
    unsafe { avx2::add_assign(dst, src) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn add_assign_avx2(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// The dense Eq. 11 E-step numerator (`em::estep_unnormalized` with an
/// explicit tier): `mu[i] = (th[i]+am1)(phi[i]+bm1)/(phisum[i]+wbm1)`,
/// returning `z = Σ mu`. Used by SEM's minibatch E-step and the dense
/// fold-in path.
#[allow(clippy::too_many_arguments)]
pub fn estep_unnorm(
    isa: KernelIsa,
    theta_d: &[f32],
    phi_w: &[f32],
    phisum: &[f32],
    am1: f32,
    bm1: f32,
    wbm1: f32,
    mu: &mut [f32],
) -> f32 {
    let n = mu.len();
    debug_assert!(theta_d.len() >= n && phi_w.len() >= n && phisum.len() >= n);
    if isa == KernelIsa::Avx2 {
        return estep_unnorm_avx2(theta_d, phi_w, phisum, am1, bm1, wbm1, mu);
    }
    for (i, m) in mu.iter_mut().enumerate() {
        *m = (theta_d[i] + am1) * (phi_w[i] + bm1) / (phisum[i] + wbm1);
    }
    sum(isa, mu)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn estep_unnorm_avx2(
    theta_d: &[f32],
    phi_w: &[f32],
    phisum: &[f32],
    am1: f32,
    bm1: f32,
    wbm1: f32,
    mu: &mut [f32],
) -> f32 {
    // SAFETY: Avx2 is only resolved after runtime avx2+fma detection.
    unsafe { avx2::estep_unnorm(theta_d, phi_w, phisum, am1, bm1, wbm1, mu) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn estep_unnorm_avx2(
    theta_d: &[f32],
    phi_w: &[f32],
    phisum: &[f32],
    am1: f32,
    bm1: f32,
    wbm1: f32,
    mu: &mut [f32],
) -> f32 {
    for (i, m) in mu.iter_mut().enumerate() {
        *m = (theta_d[i] + am1) * (phi_w[i] + bm1) / (phisum[i] + wbm1);
    }
    sum_portable(mu)
}

/// The explicit AVX2+FMA tier. Every function is compiled with
/// `#[target_feature]` on every x86-64 build (stable toolchain, no
/// `-C target-cpu` needed) and must only be *called* after
/// [`avx2_available`] returned true — which [`KernelBackend::resolve`]
/// guarantees before ever producing [`KernelIsa::Avx2`].
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::recompute_one;
    use std::arch::x86_64::*;

    /// Tree-reduce the 8 lanes of `v`.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps::<1>(v);
        let lo = _mm256_castps256_ps128(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
        _mm_cvtss_f32(s)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sum(xs: &[f32]) -> f32 {
        let n = xs.len();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(xs.as_ptr().add(i)));
            i += 8;
        }
        let mut s = hsum(acc);
        while i < n {
            s += xs[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gather(src: &[f32], sel: &[u32], dst: &mut [f32]) {
        let n = sel.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let idx = _mm256_loadu_si256(sel.as_ptr().add(i) as *const __m256i);
            let v = _mm256_i32gather_ps::<4>(src.as_ptr(), idx);
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), v);
            i += 8;
        }
        while i < n {
            dst[i] = src[sel[i] as usize];
            i += 1;
        }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn recompute_u_gather(
        sel: &[u32],
        mu_old: &[f32],
        th: &[f32],
        col: &[f32],
        phisum: &[f32],
        c: f32,
        c_phi: f32,
        am1: f32,
        bm1: f32,
        wbm1: f32,
        u_out: &mut [f32],
    ) -> f32 {
        let n = sel.len();
        let cv = _mm256_set1_ps(c);
        let cpv = _mm256_set1_ps(c_phi);
        let am1v = _mm256_set1_ps(am1);
        let bm1v = _mm256_set1_ps(bm1);
        let wbm1v = _mm256_set1_ps(wbm1);
        let zero = _mm256_setzero_ps();
        let mut zacc = zero;
        let mut i = 0usize;
        while i + 8 <= n {
            let idx = _mm256_loadu_si256(sel.as_ptr().add(i) as *const __m256i);
            let mu = _mm256_loadu_ps(mu_old.as_ptr().add(i));
            let thv = _mm256_i32gather_ps::<4>(th.as_ptr(), idx);
            let colv = _mm256_i32gather_ps::<4>(col.as_ptr(), idx);
            let psv = _mm256_i32gather_ps::<4>(phisum.as_ptr(), idx);
            let num1 = _mm256_fnmadd_ps(cv, mu, _mm256_add_ps(thv, am1v));
            let num2 = _mm256_fnmadd_ps(cpv, mu, _mm256_add_ps(colv, bm1v));
            let den = _mm256_fnmadd_ps(cpv, mu, _mm256_add_ps(psv, wbm1v));
            let u = _mm256_max_ps(_mm256_div_ps(_mm256_mul_ps(num1, num2), den), zero);
            _mm256_storeu_ps(u_out.as_mut_ptr().add(i), u);
            zacc = _mm256_add_ps(zacc, u);
            i += 8;
        }
        let mut z = hsum(zacc);
        while i < n {
            let kk = sel[i] as usize;
            let u = recompute_one(mu_old[i], th[kk], col[kk], phisum[kk], c, c_phi, am1, bm1, wbm1);
            u_out[i] = u;
            z += u;
            i += 1;
        }
        z
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn recompute_u_contig(
        mu_old: &[f32],
        th: &[f32],
        col: &[f32],
        phisum: &[f32],
        c: f32,
        c_phi: f32,
        am1: f32,
        bm1: f32,
        wbm1: f32,
        u_out: &mut [f32],
    ) -> f32 {
        let n = u_out.len();
        let cv = _mm256_set1_ps(c);
        let cpv = _mm256_set1_ps(c_phi);
        let am1v = _mm256_set1_ps(am1);
        let bm1v = _mm256_set1_ps(bm1);
        let wbm1v = _mm256_set1_ps(wbm1);
        let zero = _mm256_setzero_ps();
        let mut zacc = zero;
        let mut i = 0usize;
        while i + 8 <= n {
            let mu = _mm256_loadu_ps(mu_old.as_ptr().add(i));
            let thv = _mm256_loadu_ps(th.as_ptr().add(i));
            let colv = _mm256_loadu_ps(col.as_ptr().add(i));
            let psv = _mm256_loadu_ps(phisum.as_ptr().add(i));
            let num1 = _mm256_fnmadd_ps(cv, mu, _mm256_add_ps(thv, am1v));
            let num2 = _mm256_fnmadd_ps(cpv, mu, _mm256_add_ps(colv, bm1v));
            let den = _mm256_fnmadd_ps(cpv, mu, _mm256_add_ps(psv, wbm1v));
            let u = _mm256_max_ps(_mm256_div_ps(_mm256_mul_ps(num1, num2), den), zero);
            _mm256_storeu_ps(u_out.as_mut_ptr().add(i), u);
            zacc = _mm256_add_ps(zacc, u);
            i += 8;
        }
        let mut z = hsum(zacc);
        while i < n {
            let u = recompute_one(mu_old[i], th[i], col[i], phisum[i], c, c_phi, am1, bm1, wbm1);
            u_out[i] = u;
            z += u;
            i += 1;
        }
        z
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn finalize_delta(
        renorm: f32,
        c: f32,
        mu_old: &[f32],
        u: &mut [f32],
        delta: &mut [f32],
        fresh_res: &mut [f32],
    ) {
        let n = u.len();
        let rv = _mm256_set1_ps(renorm);
        let cv = _mm256_set1_ps(c);
        let absmask = _mm256_set1_ps(-0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            let new = _mm256_mul_ps(_mm256_loadu_ps(u.as_ptr().add(i)), rv);
            let mu = _mm256_loadu_ps(mu_old.as_ptr().add(i));
            let d = _mm256_mul_ps(cv, _mm256_sub_ps(new, mu));
            _mm256_storeu_ps(u.as_mut_ptr().add(i), new);
            _mm256_storeu_ps(delta.as_mut_ptr().add(i), d);
            let fr = _mm256_loadu_ps(fresh_res.as_ptr().add(i));
            let abs_d = _mm256_andnot_ps(absmask, d);
            _mm256_storeu_ps(fresh_res.as_mut_ptr().add(i), _mm256_add_ps(fr, abs_d));
            i += 8;
        }
        while i < n {
            let new = u[i] * renorm;
            let d = c * (new - mu_old[i]);
            u[i] = new;
            delta[i] = d;
            fresh_res[i] += d.abs();
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn add_assign(dst: &mut [f32], src: &[f32]) {
        let n = dst.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(d, s));
            i += 8;
        }
        while i < n {
            dst[i] += src[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn estep_unnorm(
        theta_d: &[f32],
        phi_w: &[f32],
        phisum: &[f32],
        am1: f32,
        bm1: f32,
        wbm1: f32,
        mu: &mut [f32],
    ) -> f32 {
        let n = mu.len();
        let am1v = _mm256_set1_ps(am1);
        let bm1v = _mm256_set1_ps(bm1);
        let wbm1v = _mm256_set1_ps(wbm1);
        let mut zacc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let thv = _mm256_add_ps(_mm256_loadu_ps(theta_d.as_ptr().add(i)), am1v);
            let phv = _mm256_add_ps(_mm256_loadu_ps(phi_w.as_ptr().add(i)), bm1v);
            let psv = _mm256_add_ps(_mm256_loadu_ps(phisum.as_ptr().add(i)), wbm1v);
            let v = _mm256_div_ps(_mm256_mul_ps(thv, phv), psv);
            _mm256_storeu_ps(mu.as_mut_ptr().add(i), v);
            zacc = _mm256_add_ps(zacc, v);
            i += 8;
        }
        let mut z = hsum(zacc);
        while i < n {
            let v = (theta_d[i] + am1) * (phi_w[i] + bm1) / (phisum[i] + wbm1);
            mu[i] = v;
            z += v;
            i += 1;
        }
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Tiers worth testing on this host: always Portable, plus whatever
    /// `Simd` resolves to (Avx2 on capable x86-64).
    fn test_isas() -> Vec<KernelIsa> {
        let mut v = vec![KernelIsa::Portable];
        let forced = KernelBackend::Simd.resolve();
        if !v.contains(&forced) {
            v.push(forced);
        }
        v
    }

    #[test]
    fn backend_parse_round_trips() {
        for b in KernelBackend::all() {
            assert_eq!(KernelBackend::parse(b.name()).unwrap(), b);
        }
        assert!(KernelBackend::parse("sse9").is_err());
        assert_eq!(KernelBackend::default(), KernelBackend::Scalar);
    }

    #[test]
    fn auto_never_resolves_to_portable() {
        // Auto must fall back to the deterministic Scalar tier on hosts
        // without AVX2 — never the reassociating portable tier.
        let isa = KernelBackend::Auto.resolve();
        assert!(isa == KernelIsa::Scalar || isa == KernelIsa::Avx2, "auto resolved to {isa:?}");
        assert_eq!(KernelBackend::Scalar.resolve(), KernelIsa::Scalar);
    }

    #[test]
    fn gather_is_exact_in_every_tier() {
        let mut rng = Rng::new(1);
        let src: Vec<f32> = (0..100).map(|_| rng.next_f32()).collect();
        let sel: Vec<u32> = (0..37).map(|_| rng.below(100) as u32).collect();
        let mut want = vec![0.0f32; sel.len()];
        gather(KernelIsa::Scalar, &src, &sel, &mut want);
        for isa in test_isas() {
            let mut got = vec![0.0f32; sel.len()];
            gather(isa, &src, &sel, &mut got);
            assert_eq!(got, want, "{isa:?}");
        }
    }

    #[test]
    fn sum_matches_scalar_within_tolerance() {
        let mut rng = Rng::new(2);
        for n in [0usize, 1, 7, 8, 9, 31, 64, 1000] {
            let xs: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 0.5).collect();
            let want: f32 = xs.iter().sum();
            for isa in test_isas() {
                let got = sum(isa, &xs);
                let tol = 1e-5 * want.abs().max(1.0);
                assert!((got - want).abs() <= tol, "{isa:?} n={n}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn recompute_matches_scalar_reference() {
        let mut rng = Rng::new(3);
        let k = 97usize;
        let th: Vec<f32> = (0..k).map(|_| rng.next_f32() * 4.0).collect();
        let col: Vec<f32> = (0..k).map(|_| rng.next_f32() * 2.0).collect();
        let ps: Vec<f32> = (0..k).map(|_| rng.next_f32() * 50.0 + 1.0).collect();
        let (c, am1, bm1, wbm1) = (2.0f32, 0.01f32, 0.01f32, 0.97f32);
        for &n in &[1usize, 5, 8, 13, 64, 97] {
            let sel: Vec<u32> = (0..n as u32).map(|j| (j * 7) % k as u32).collect();
            let mu: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            for phi_excl in [true, false] {
                let scalar = KernelIsa::Scalar;
                let mut want = vec![0.0f32; n];
                let wz = recompute_u(
                    scalar, &sel, &mu, &th, &col, &ps, c, am1, bm1, wbm1, phi_excl, &mut want,
                );
                for isa in test_isas() {
                    let mut got = vec![0.0f32; n];
                    let gz = recompute_u(
                        isa, &sel, &mu, &th, &col, &ps, c, am1, bm1, wbm1, phi_excl, &mut got,
                    );
                    for j in 0..n {
                        let tol = 1e-5 * want[j].abs().max(1e-3);
                        assert!(
                            (got[j] - want[j]).abs() <= tol,
                            "{isa:?} n={n} j={j} phi_excl={phi_excl}: {} vs {}",
                            got[j],
                            want[j]
                        );
                    }
                    let ztol = 1e-4 * wz.abs().max(1e-3);
                    assert!((gz - wz).abs() <= ztol, "{isa:?} z: {gz} vs {wz}");
                }
            }
        }
    }

    #[test]
    fn contig_recompute_matches_gathered_identity() {
        let mut rng = Rng::new(4);
        let n = 53usize;
        let sel: Vec<u32> = (0..n as u32).collect();
        let mu: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let th: Vec<f32> = (0..n).map(|_| rng.next_f32() * 4.0).collect();
        let col: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0).collect();
        let ps: Vec<f32> = (0..n).map(|_| rng.next_f32() * 50.0 + 1.0).collect();
        let (c, am1, bm1, wbm1) = (1.5f32, 0.01f32, 0.01f32, 0.53f32);
        for isa in test_isas() {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            let za = recompute_u(isa, &sel, &mu, &th, &col, &ps, c, am1, bm1, wbm1, true, &mut a);
            let zb = recompute_u_contig(isa, &mu, &th, &col, &ps, c, am1, bm1, wbm1, true, &mut b);
            // Identical math, identical order — exact agreement.
            assert_eq!(za.to_bits(), zb.to_bits(), "{isa:?}");
            for j in 0..n {
                assert_eq!(a[j].to_bits(), b[j].to_bits(), "{isa:?} j={j}");
            }
        }
    }

    #[test]
    fn finalize_and_add_assign_match_scalar() {
        let mut rng = Rng::new(5);
        let n = 29usize;
        let mu: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let u0: Vec<f32> = (0..n).map(|_| rng.next_f32() * 3.0).collect();
        let (renorm, c) = (0.37f32, 2.0f32);
        let mut uw = u0.clone();
        let mut dw = vec![0.0f32; n];
        let mut fw = vec![0.1f32; n];
        finalize_delta(KernelIsa::Scalar, renorm, c, &mu, &mut uw, &mut dw, &mut fw);
        for isa in test_isas() {
            let mut ug = u0.clone();
            let mut dg = vec![0.0f32; n];
            let mut fg = vec![0.1f32; n];
            finalize_delta(isa, renorm, c, &mu, &mut ug, &mut dg, &mut fg);
            for j in 0..n {
                assert!((ug[j] - uw[j]).abs() <= 1e-6, "{isa:?} u[{j}]");
                assert!((dg[j] - dw[j]).abs() <= 1e-6, "{isa:?} delta[{j}]");
                assert!((fg[j] - fw[j]).abs() <= 1e-6, "{isa:?} fresh[{j}]");
            }
            let mut acc = vec![1.0f32; n];
            add_assign(isa, &mut acc, &dg);
            for j in 0..n {
                assert!((acc[j] - (1.0 + dg[j])).abs() <= 1e-6, "{isa:?} acc[{j}]");
            }
        }
    }

    #[test]
    fn estep_unnorm_matches_reference() {
        let mut rng = Rng::new(6);
        for n in [1usize, 8, 17, 100] {
            let th: Vec<f32> = (0..n).map(|_| rng.next_f32() * 5.0).collect();
            let ph: Vec<f32> = (0..n).map(|_| rng.next_f32() * 3.0).collect();
            let ps: Vec<f32> = (0..n).map(|_| rng.next_f32() * 100.0 + 1.0).collect();
            let mut want = vec![0.0f32; n];
            let wz = estep_unnorm(KernelIsa::Scalar, &th, &ph, &ps, 0.01, 0.01, 1.0, &mut want);
            for isa in test_isas() {
                let mut got = vec![0.0f32; n];
                let gz = estep_unnorm(isa, &th, &ph, &ps, 0.01, 0.01, 1.0, &mut got);
                for j in 0..n {
                    assert!((got[j] - want[j]).abs() <= 1e-5 * want[j].abs().max(1e-3), "{isa:?}");
                }
                assert!((gz - wz).abs() <= 1e-4 * wz.abs().max(1e-3), "{isa:?} n={n}");
            }
        }
    }
}
