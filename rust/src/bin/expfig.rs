//! `expfig` — regenerates every table and figure of the paper's
//! evaluation section (see DESIGN.md §4 for the experiment index):
//!
//!   table3   complexity table (analytic columns + measured memory)
//!   fig7     relative training perplexity vs K for lambda_k sweeps
//!   table5   time per minibatch vs phi-buffer size (parameter streaming)
//!   fig8     training convergence time vs minibatch size D_s (K fixed)
//!   fig9     predictive perplexity vs minibatch size D_s
//!   fig10    training convergence time vs number of topics K
//!   fig11    predictive perplexity vs K
//!   fig12    predictive perplexity vs wall-clock training time
//!   all      everything above
//!
//! Corpora are the synthetic stand-ins for ENRON/WIKI/NYTIMES/PUBMED
//! (offline environment — see DESIGN.md substitution note); every
//! algorithm consumes identical streams, so the *relative* shapes are the
//! reproduction target. `--scale paper` runs closer-to-paper sweeps;
//! the default `--scale small` finishes on a laptop-class single core.
//!
//! Output: aligned tables on stdout + CSV files under `results/`.

use anyhow::Result;
use foem::baselines::OnlineLda;
use foem::coordinator::config::{Algorithm, RunConfig, StoreKind};
use foem::coordinator::driver::Driver;
use foem::corpus::synthetic::{generate, SyntheticConfig};
use foem::corpus::Corpus;
use foem::em::foem::{Foem, FoemConfig};
use foem::em::schedule::TopicSubset;
use foem::eval::{predictive_perplexity, EvalProtocol};
use foem::store::{InMemoryPhi, PhiColumnStore};
use foem::stream::{CorpusStream, StreamConfig};
use foem::util::Timer;
use foem::LdaParams;
use std::fmt::Write as _;
use std::io::Write as _;

struct Scale {
    /// Corpus doc-count multiplier.
    corpus_mult: usize,
    /// D_s sweep (fig 8/9).
    ds_sweep: Vec<usize>,
    /// K sweep (fig 10/11).
    k_sweep: Vec<usize>,
    /// K for fig 8/9/12.
    k_fixed: usize,
    /// D_s for fig 10/11/12.
    ds_fixed: usize,
    /// Passes to run per training ("stream length").
    passes: usize,
    /// Buffer sweep for table 5, in columns-of-phi units per GB analog.
    table5_buffers: Vec<usize>,
    /// K for table 5 / fig 7 sweeps.
    k_table5: usize,
    fig7_k: Vec<usize>,
}

impl Scale {
    fn small() -> Self {
        Self {
            corpus_mult: 1,
            ds_sweep: vec![64, 128, 256, 512, 1024],
            k_sweep: vec![25, 50, 75, 100, 125],
            k_fixed: 50,
            ds_fixed: 256,
            passes: 2,
            table5_buffers: vec![0, 32, 128, 512, 2048],
            k_table5: 256,
            fig7_k: vec![25, 50, 100, 150],
        }
    }

    fn paper() -> Self {
        Self {
            corpus_mult: 4,
            ds_sweep: vec![256, 512, 1024, 2048, 4096],
            k_sweep: vec![100, 200, 300, 400, 500],
            k_fixed: 100,
            ds_fixed: 1024,
            passes: 2,
            table5_buffers: vec![0, 64, 256, 1024, 4096, 16384],
            k_table5: 1024,
            fig7_k: vec![100, 300, 500, 700, 900],
        }
    }
}

fn results_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&d).ok();
    d
}

fn save_csv(name: &str, content: &str) {
    let path = results_dir().join(name);
    std::fs::write(&path, content).expect("write csv");
    println!("  -> {}", path.display());
}

fn corpora(scale: &Scale) -> Vec<(Corpus, Corpus)> {
    SyntheticConfig::paper_suite()
        .into_iter()
        .map(|mut cfg| {
            cfg.n_docs *= scale.corpus_mult;
            let c = generate(&cfg, 101);
            let test = (c.n_docs() / 20).clamp(1, 1000);
            c.split(test, 2)
        })
        .collect()
}

/// Train `algo` for `passes` passes; returns (seconds, final predictive
/// perplexity, perplexity-vs-time trace sampled per minibatch-group).
fn train_timed(
    algo: &mut dyn OnlineLda,
    train: &Corpus,
    test: &Corpus,
    ds: usize,
    passes: usize,
    trace_every: usize,
) -> (f64, f64, Vec<(f64, f64)>) {
    let scfg = StreamConfig { minibatch_docs: ds, shuffle: false, seed: 3 };
    let proto = EvalProtocol { fold_in_iters: 20, seed: 0, ..Default::default() };
    let mut train_secs = 0.0f64;
    let mut trace = Vec::new();
    let mut batch_no = 0usize;
    for _ in 0..passes {
        for mb in CorpusStream::new(train, scfg) {
            let t = Timer::start();
            algo.process_minibatch(&mb);
            train_secs += t.seconds();
            batch_no += 1;
            if trace_every > 0 && batch_no % trace_every == 0 {
                let phi = algo.export_phi();
                let ppx = predictive_perplexity(
                    &phi,
                    &algo.eval_params(),
                    &test.docs,
                    &proto,
                );
                trace.push((train_secs, ppx));
            }
        }
    }
    let phi = algo.export_phi();
    let ppx =
        predictive_perplexity(&phi, &algo.eval_params(), &test.docs, &proto);
    trace.push((train_secs, ppx));
    (train_secs, ppx, trace)
}

fn build(
    algo: Algorithm,
    k: usize,
    n_words: usize,
    scale_s: f64,
    seed: u64,
) -> Box<dyn OnlineLda> {
    let cfg = RunConfig {
        algorithm: algo,
        n_topics: k,
        store: StoreKind::InMemory,
        seed,
        ..RunConfig::default()
    };
    Driver::new(cfg).build_algorithm(n_words, scale_s).unwrap()
}

// ---------------------------------------------------------------------
// Table 3: complexities. Analytic formulas + measured resident sizes.
// ---------------------------------------------------------------------
fn table3() {
    println!("\n== Table 3: time and space complexities ==");
    println!("(analytic, with the paper's symbols; FOEM's measured memory");
    println!(" is validated by the buffer-bounded store in table5)\n");
    let rows = [
        ("BEM (BP)", "2·K·NNZ", "D + 2·NNZ + 2·K·(D+W)"),
        ("IEM (CVB0/BP)", "2·K·NNZ", "D + 2·NNZ + K·(D+NNZ+W)"),
        ("SEM (SCVB)", "2·K·NNZ", "Ds + 2·NNZs + K·(Ds+NNZs+W)"),
        ("FOEM", "20·NNZ + Ws·K·logK", "Ds + 2·NNZs + K·(Ds+NNZs+W*)"),
        ("VB", "2·K·NNZ·digamma", "D + 2·NNZ + 2·K·(D+W)"),
        ("GS", "δ1·K·ntokens", "δ2·K·W + 2·ntokens"),
        ("CVB", "δ3·2·K·NNZ", "D + 2·NNZ + K·(2(W+D)+NNZ)"),
    ];
    println!("{:<16} {:<22} {}", "algorithm", "time/iteration", "space");
    for (a, t, s) in rows {
        println!("{a:<16} {t:<22} {s}");
    }

    // Empirical spot-check of the *shape*: FOEM per-minibatch cost vs K
    // (flat) against SEM (linear) on one corpus.
    let mut cfg = SyntheticConfig::enron_like();
    cfg.n_docs = 512;
    let c = generate(&cfg, 7);
    let mut csv = String::from("k,foem_s_per_batch,sem_s_per_batch\n");
    println!("\nempirical time/minibatch (s) — FOEM flat vs SEM linear in K:");
    println!("{:<8} {:<12} {}", "K", "FOEM", "SEM");
    for &k in &[32usize, 64, 128, 256] {
        let scfg = StreamConfig { minibatch_docs: 256, ..Default::default() };
        let s = CorpusStream::new(&c, scfg).batches_per_pass() as f64;
        let mut foem_algo = build(Algorithm::Foem, k, c.n_words(), s, 1);
        let mut sem_algo = build(Algorithm::Sem, k, c.n_words(), s, 1);
        let time_of = |a: &mut Box<dyn OnlineLda>| {
            let t = Timer::start();
            for mb in CorpusStream::new(&c, scfg) {
                a.process_minibatch(&mb);
            }
            t.seconds() / s
        };
        let tf = time_of(&mut foem_algo);
        let ts = time_of(&mut sem_algo);
        println!("{k:<8} {tf:<12.4} {ts:.4}");
        writeln!(csv, "{k},{tf:.6},{ts:.6}").unwrap();
    }
    save_csv("table3_empirical.csv", &csv);
}

// ---------------------------------------------------------------------
// Fig. 7: dynamic scheduling — relative training perplexity vs K for
// lambda_k in {0.1..0.5} on the NIPS-like corpus.
// ---------------------------------------------------------------------
fn fig7(scale: &Scale) {
    println!("\n== Fig. 7: dynamic scheduling (lambda_k sweep, NIPS-like) ==");
    let c = generate(&SyntheticConfig::nips_like(), 31);
    let lambdas = [0.1f32, 0.2, 0.3, 0.4, 0.5];
    let mut csv = String::from("k,lambda,ppx,ppx_benchmark,relative\n");
    println!(
        "{:<6} {:<10} {:<12} {:<12} {}",
        "K", "lambda_k", "train ppx", "ppx(λ=1)", "relative"
    );
    for &k in &scale.fig7_k {
        let p = LdaParams::paper_defaults(k);
        let run = |subset: TopicSubset| -> f64 {
            let mut fc = FoemConfig::paper();
            fc.topic_subset = subset;
            let mut algo =
                Foem::new(p, InMemoryPhi::zeros(k, c.n_words()), fc, 5);
            let scfg =
                StreamConfig { minibatch_docs: 500, ..Default::default() };
            let mut last = f64::NAN;
            for _ in 0..2 {
                for mb in CorpusStream::new(&c, scfg) {
                    last = algo.process_minibatch(&mb).train_perplexity();
                }
            }
            last
        };
        let benchmark = run(TopicSubset::All);
        for &l in &lambdas {
            let ppx = run(TopicSubset::Fraction(l));
            let rel = ppx - benchmark;
            println!(
                "{k:<6} {l:<10} {ppx:<12.2} {benchmark:<12.2} {rel:+.2}"
            );
            writeln!(csv, "{k},{l},{ppx:.3},{benchmark:.3},{rel:.3}").unwrap();
        }
    }
    save_csv("fig7.csv", &csv);
    println!(
        "(paper: relative perplexity shrinks as K grows; lambda_k=0.1..0.5\n\
         nearly indistinguishable at large K)"
    );
}

// ---------------------------------------------------------------------
// Table 5: training time per minibatch vs phi-buffer size.
// ---------------------------------------------------------------------
fn table5(scale: &Scale) {
    println!("\n== Table 5: time per minibatch vs buffer size (K={}) ==", scale.k_table5);
    let k = scale.k_table5;
    let suite = corpora(scale);
    let mut csv = String::from("corpus,buffer_cols,s_per_batch,col_reads,buffer_hits\n");
    let mut header = format!("{:<14}", "corpus");
    for &b in &scale.table5_buffers {
        write!(header, "{:<11}", format!("buf={b}")).unwrap();
    }
    write!(header, "{:<11}", "in-memory").unwrap();
    println!("{header}");
    for (train, _) in &suite {
        let name = train.name.trim_end_matches("-train");
        let mut row = format!("{name:<14}");
        let scfg = StreamConfig { minibatch_docs: 512, ..Default::default() };
        let n_batches =
            CorpusStream::new(train, scfg).batches_per_pass() as f64;
        for &buf_cols in &scale.table5_buffers {
            let dir = foem::util::TempDir::new("t5");
            let p = LdaParams::paper_defaults(k);
            let mut fc = FoemConfig::paper();
            fc.hot_words = buf_cols;
            fc.exact_ll = false;
            fc.max_inner_iters = 10;
            // buffer budget covers phi + residual stores (split inside).
            let mut algo = Foem::paged_create(
                p,
                &dir.path().join("phi.bin"),
                train.n_words(),
                (buf_cols * k * 4 * 2).max(2),
                fc,
                1,
            )
            .unwrap();
            let t = Timer::start();
            for mb in CorpusStream::new(train, scfg) {
                algo.process_minibatch(&mb);
            }
            let per_batch = t.seconds() / n_batches;
            let io = algo.store.io_stats();
            write!(row, "{:<11.3}", per_batch).unwrap();
            writeln!(
                csv,
                "{name},{buf_cols},{per_batch:.5},{},{}",
                io.col_reads, io.buffer_hits
            )
            .unwrap();
        }
        // In-memory reference.
        {
            let p = LdaParams::paper_defaults(k);
            let mut fc = FoemConfig::paper();
            fc.exact_ll = false;
            fc.max_inner_iters = 10;
            let mut algo =
                Foem::new(p, InMemoryPhi::zeros(k, train.n_words()), fc, 1);
            let t = Timer::start();
            for mb in CorpusStream::new(train, scfg) {
                algo.process_minibatch(&mb);
            }
            let per_batch = t.seconds() / n_batches;
            write!(row, "{:<11.3}", per_batch).unwrap();
            writeln!(csv, "{name},inmem,{per_batch:.5},0,0").unwrap();
        }
        println!("{row}");
    }
    save_csv("table5.csv", &csv);
    println!(
        "(paper: zero buffer ≈3x slower than in-memory; time decreases\n\
         monotonically as the buffer grows)"
    );
}

// ---------------------------------------------------------------------
// Figs. 8/9: sweep minibatch size D_s at fixed K.
// ---------------------------------------------------------------------
fn fig8_9(scale: &Scale) {
    println!(
        "\n== Figs. 8+9: convergence time & perplexity vs D_s (K={}) ==",
        scale.k_fixed
    );
    let k = scale.k_fixed;
    let suite = corpora(scale);
    let algos = Algorithm::all();
    let mut csv =
        String::from("corpus,algorithm,ds,train_seconds,perplexity\n");
    for (train, test) in &suite {
        let name = train.name.trim_end_matches("-train");
        println!("\n--- {name} ---");
        let mut time_hdr = format!("{:<7}", "Ds");
        for a in algos {
            write!(time_hdr, "{:<9}", a.name()).unwrap();
        }
        println!("time(s): {time_hdr}  |  ppx: (same order)");
        for &ds in &scale.ds_sweep {
            let mut times = format!("{ds:<7}");
            let mut ppxs = String::new();
            for a in algos {
                let scfg =
                    StreamConfig { minibatch_docs: ds, ..Default::default() };
                let s =
                    CorpusStream::new(train, scfg).batches_per_pass() as f64;
                let mut algo = build(a, k, train.n_words(), s, 1);
                let (secs, ppx, _) =
                    train_timed(&mut *algo, train, test, ds, scale.passes, 0);
                write!(times, "{secs:<9.2}").unwrap();
                write!(ppxs, "{ppx:<9.1}").unwrap();
                writeln!(
                    csv,
                    "{name},{},{ds},{secs:.4},{ppx:.2}",
                    a.name()
                )
                .unwrap();
            }
            println!("         {times}  |  {ppxs}");
        }
    }
    save_csv("fig8_9.csv", &csv);
    println!(
        "(paper: FOEM fastest at every Ds and ~flat; OVB/RVB/SOI speed up\n\
         with larger Ds; FOEM/OGS/SCVB reach lower perplexity than\n\
         OVB/RVB/SOI; perplexity falls as Ds grows)"
    );
}

// ---------------------------------------------------------------------
// Figs. 10/11: sweep K at fixed D_s.
// ---------------------------------------------------------------------
fn fig10_11(scale: &Scale) {
    println!(
        "\n== Figs. 10+11: convergence time & perplexity vs K (Ds={}) ==",
        scale.ds_fixed
    );
    let ds = scale.ds_fixed;
    let suite = corpora(scale);
    let algos = Algorithm::all();
    let mut csv =
        String::from("corpus,algorithm,k,train_seconds,perplexity\n");
    for (train, test) in &suite {
        let name = train.name.trim_end_matches("-train");
        println!("\n--- {name} ---");
        let mut hdr = format!("{:<7}", "K");
        for a in algos {
            write!(hdr, "{:<9}", a.name()).unwrap();
        }
        println!("time(s): {hdr}  |  ppx: (same order)");
        for &k in &scale.k_sweep {
            let mut times = format!("{k:<7}");
            let mut ppxs = String::new();
            for a in algos {
                let scfg =
                    StreamConfig { minibatch_docs: ds, ..Default::default() };
                let s =
                    CorpusStream::new(train, scfg).batches_per_pass() as f64;
                let mut algo = build(a, k, train.n_words(), s, 1);
                let (secs, ppx, _) =
                    train_timed(&mut *algo, train, test, ds, scale.passes, 0);
                write!(times, "{secs:<9.2}").unwrap();
                write!(ppxs, "{ppx:<9.1}").unwrap();
                writeln!(csv, "{name},{},{k},{secs:.4},{ppx:.2}", a.name())
                    .unwrap();
            }
            println!("         {times}  |  {ppxs}");
        }
    }
    save_csv("fig10_11.csv", &csv);
    println!(
        "(paper: every algorithm's time grows ~linearly in K except FOEM,\n\
         whose cost is ~flat; FOEM lowest perplexity at every K)"
    );
}

// ---------------------------------------------------------------------
// Fig. 12: perplexity vs training time trajectories.
// ---------------------------------------------------------------------
fn fig12(scale: &Scale) {
    println!(
        "\n== Fig. 12: perplexity vs training time (K={}, Ds={}) ==",
        scale.k_fixed, scale.ds_fixed
    );
    let k = scale.k_fixed;
    let ds = scale.ds_fixed;
    let suite = corpora(scale);
    let mut csv = String::from("corpus,algorithm,seconds,perplexity\n");
    for (train, test) in &suite {
        let name = train.name.trim_end_matches("-train");
        println!("\n--- {name} ---");
        for a in Algorithm::all() {
            let scfg =
                StreamConfig { minibatch_docs: ds, ..Default::default() };
            let s = CorpusStream::new(train, scfg).batches_per_pass() as f64;
            let trace_every = (s as usize / 3).max(1);
            let mut algo = build(a, k, train.n_words(), s, 1);
            let (_, _, trace) = train_timed(
                &mut *algo,
                train,
                test,
                ds,
                scale.passes,
                trace_every,
            );
            let line: Vec<String> = trace
                .iter()
                .map(|(t, p)| format!("({t:.1}s,{p:.0})"))
                .collect();
            println!("{:<6} {}", a.name(), line.join(" "));
            for (t, p) in trace {
                writeln!(csv, "{name},{},{t:.4},{p:.2}", a.name()).unwrap();
            }
        }
    }
    save_csv("fig12.csv", &csv);
    println!(
        "(paper: FOEM/OGS/SCVB trajectories drop faster and end lower\n\
         than OVB/RVB/SOI)"
    );
}

// ---------------------------------------------------------------------
// Ablation: which of FOEM's ingredients buys what (DESIGN.md §8).
// ---------------------------------------------------------------------
fn ablation() {
    println!("\n== Ablation: FOEM design choices (NYTIMES-like, K=50, Ds=256) ==");
    let corpus = generate(&SyntheticConfig::nytimes_like(), 11);
    let (train, test) = corpus.split(200, 1);
    let k = 50;
    let p = LdaParams::paper_defaults(k);
    let scfg = StreamConfig { minibatch_docs: 256, shuffle: false, seed: 3 };
    let proto = EvalProtocol { fold_in_iters: 20, seed: 0, ..Default::default() };
    let variants: Vec<(&str, FoemConfig)> = vec![
        ("full FOEM (default)", FoemConfig::paper()),
        ("no exploration", {
            let mut c = FoemConfig::paper();
            c.explore_slots = 0;
            c
        }),
        ("no topic scheduling (lambda_k = 1)", {
            let mut c = FoemConfig::paper();
            c.topic_subset = TopicSubset::All;
            c
        }),
        ("half the words per sweep (lambda_w = 0.5)", {
            let mut c = FoemConfig::paper();
            c.lambda_w = 0.5;
            c
        }),
        ("loose tolerance (throughput mode)", {
            let mut c = FoemConfig::paper();
            c.residual_tol = 0.05;
            c.explore_slots = 0;
            c
        }),
        ("single inner sweep (no inner convergence)", {
            let mut c = FoemConfig::paper();
            c.max_inner_iters = 1;
            c
        }),
    ];
    let mut csv = String::from("variant,train_seconds,perplexity\n");
    println!("{:<46} {:>10} {:>12}", "variant", "time", "perplexity");
    for (name, mut fc) in variants {
        fc.exact_ll = false;
        let mut algo =
            Foem::new(p, InMemoryPhi::zeros(k, train.n_words()), fc, 7);
        let t = Timer::start();
        for _ in 0..2 {
            for mb in CorpusStream::new(&train, scfg) {
                algo.process_minibatch(&mb);
            }
        }
        let secs = t.seconds();
        let phi = algo.export_phi();
        let ppx = predictive_perplexity(&phi, &p, &test.docs, &proto);
        println!("{name:<46} {secs:>9.2}s {ppx:>12.1}");
        writeln!(csv, "{name},{secs:.4},{ppx:.2}").unwrap();
    }
    save_csv("ablation.csv", &csv);
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let scale = if args.iter().any(|a| a == "paper") {
        Scale::paper()
    } else {
        Scale::small()
    };
    let t = Timer::start();
    match cmd {
        "table3" => table3(),
        "fig7" => fig7(&scale),
        "table5" => table5(&scale),
        "fig8" | "fig9" | "fig8_9" => fig8_9(&scale),
        "fig10" | "fig11" | "fig10_11" => fig10_11(&scale),
        "fig12" => fig12(&scale),
        "ablation" => ablation(),
        "all" => {
            table3();
            fig7(&scale);
            table5(&scale);
            fig8_9(&scale);
            fig10_11(&scale);
            fig12(&scale);
            ablation();
        }
        _ => {
            eprintln!(
                "usage: expfig <table3|fig7|table5|fig8|fig10|fig12|ablation|all> [paper]"
            );
            std::process::exit(2);
        }
    }
    println!("\n[expfig {cmd} done in {:.1}s]", t.seconds());
    // stdout may be piped into EXPERIMENTS.md fragments; flush.
    std::io::stdout().flush().ok();
    Ok(())
}
