//! The coordinator ↔ shard-owner wire protocol.
//!
//! Every interaction is a strict request/response pair of *plain-data*
//! messages: owned buffers, ids and flags only — no closures, no
//! borrows, no shared memory. That is deliberate: the in-process
//! [`ChannelTransport`] moves these enums over `std::sync::mpsc`
//! channels today, and a future socket transport can serialize the
//! exact same frames to a remote owner process without touching the
//! trainer (the store's closure-taking `with_column` access is the one
//! thing that cannot cross a wire, which is why the hot apply-phase
//! verbs exist as explicit messages: [`ShardRequest::MergeColumn`],
//! [`ShardRequest::ClampAddColumn`]).
//!
//! Word ids in every message are GLOBAL: the owner translates to its
//! local column index (`w - lo`). This keeps the coordinator free of
//! per-shard index arithmetic and makes request frames meaningful on
//! their own — a requirement for debuggable socket traffic later.

use crate::store::{ColumnStats, IoStats};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Mutex;

/// Which of the owner's two streamed matrices a request addresses. A
/// [`PhiShardOwner`](super::PhiShardOwner) owns the phi AND residual
/// store of its word range (they are streamed in lockstep, exactly as
/// the unsharded trainer pairs them), and replies on the selected
/// stream's channel — the phi and residual facades of
/// [`super::ShardedPhi`] share one owner without interleaving replies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreSel {
    /// The topic-word statistics matrix `phi_hat`.
    Phi,
    /// The residual matrix `r_hat` of the dynamic scheduler.
    Res,
}

/// A coordinator → owner request. One reply ([`ShardResponse`]) per
/// request, always, on the `sel` stream's reply channel — except
/// [`ShardRequest::Shutdown`], which has no reply and ends the owner's
/// service loop.
#[derive(Debug)]
pub enum ShardRequest {
    /// Grow the shard's slice of a global vocabulary of `n_words`
    /// columns (the owner clamps to its range). → [`ShardResponse::Unit`]
    EnsureCapacity { sel: StoreSel, n_words: usize },
    /// Non-dirtying read of global column `w`.
    /// → [`ShardResponse::Column`]
    LoadColumn { sel: StoreSel, w: usize },
    /// Overwrite global column `w`. → [`ShardResponse::Unit`]
    StoreColumn { sel: StoreSel, w: usize, data: Vec<f32> },
    /// `col += delta` on global column `w` — the apply-phase verb, one
    /// owner-side read-modify-write access. → [`ShardResponse::Unit`]
    MergeColumn { sel: StoreSel, w: usize, delta: Vec<f32> },
    /// `col = max(col + delta, 0)` on global column `w`, returning the
    /// clamped column total — the residual apply verb.
    /// → [`ShardResponse::Total`]
    ClampAddColumn { sel: StoreSel, w: usize, delta: Vec<f32> },
    /// Snapshot the given (sorted, range-owned, global) words.
    /// → [`ShardResponse::Snapshot`]
    SnapshotColumns { sel: StoreSel, words: Vec<u32> },
    /// Install the minibatch's hot set; the owner pins the subset of
    /// `words` inside its range (order preserved).
    /// → [`ShardResponse::Unit`]
    SetHotWords { sel: StoreSel, words: Vec<u32> },
    /// Prefetch hint (pipelined trainer); the owner filters to its
    /// range. → [`ShardResponse::Unit`]
    PrefetchColumns { sel: StoreSel, words: Vec<u32> },
    /// Toggle background I/O. → [`ShardResponse::Bool`] (supported?)
    SetAsyncIo { sel: StoreSel, enabled: bool },
    /// Zone-map stats of global column `w`. → [`ShardResponse::ColStats`]
    ColumnStats { sel: StoreSel, w: usize },
    /// The shard store's current column count. → [`ShardResponse::Count`]
    NWords { sel: StoreSel },
    /// Arm the write-ahead log. → [`ShardResponse::Done`]
    EnableWal { sel: StoreSel },
    /// Open batch `batch_id` in the shard's WAL. → [`ShardResponse::Unit`]
    WalBegin { sel: StoreSel, batch_id: u64 },
    /// Commit batch `batch_id`, carrying the coordinator's resident
    /// state blob (every shard's phi log stores the SAME blob — any
    /// shard can replay the trainer state). → [`ShardResponse::Unit`]
    WalCommit { sel: StoreSel, batch_id: u64, state: Vec<u8> },
    /// Truncate the WAL after a checkpoint. → [`ShardResponse::Done`]
    TruncateWal { sel: StoreSel },
    /// Flush dirty state to the backing file. → [`ShardResponse::Done`]
    Flush { sel: StoreSel },
    /// Cumulative I/O counters. → [`ShardResponse::Stats`]
    IoStats { sel: StoreSel },
    /// Total WAL bytes ever appended. → [`ShardResponse::Bytes`]
    WalBytes { sel: StoreSel },
    /// End the owner's service loop (no reply).
    Shutdown,
}

/// An owner → coordinator reply. Variants mirror the request
/// contracts above; `Done` carries fallible-operation errors as
/// strings so the frame stays serialization-ready.
#[derive(Debug)]
pub enum ShardResponse {
    Unit,
    Bool(bool),
    Count(usize),
    Bytes(u64),
    Total(f32),
    Column(Vec<f32>),
    /// Global word ids + column-contiguous data (`words.len() * k`).
    Snapshot { words: Vec<u32>, data: Vec<f32> },
    Stats(IoStats),
    ColStats(Option<ColumnStats>),
    Done(Result<(), String>),
}

/// One coordinator-side endpoint of a request/response stream to one
/// shard owner.
///
/// Implementations must be synchronous and ordered: after `send(req)`,
/// the next `recv()` returns that request's reply. The facade leans on
/// this for the scatter-gather pattern (send to every owner, then
/// collect in fixed shard order) and for the durability ordering of
/// WAL commits (send → recv per shard, so shard `i`'s fsync completes
/// before shard `i+1`'s commit is even requested).
pub trait ShardTransport: Send + Sync {
    /// Ship a request to the owner. Panics if the owner is gone — a
    /// dead shard thread is unrecoverable mid-run, exactly like a
    /// poisoned store.
    fn send(&self, req: ShardRequest);
    /// Block for the next reply from the owner.
    fn recv(&self) -> ShardResponse;
}

/// The in-process transport: an `mpsc` request channel into the owner
/// thread plus this stream's private reply channel back. The receiver
/// sits behind a `Mutex` only to make the endpoint `Sync`; the facade
/// serializes its own calls, so the lock is never contended.
pub struct ChannelTransport {
    tx: Sender<ShardRequest>,
    rx: Mutex<Receiver<ShardResponse>>,
}

impl ChannelTransport {
    pub fn new(tx: Sender<ShardRequest>, rx: Receiver<ShardResponse>) -> Self {
        Self { tx, rx: Mutex::new(rx) }
    }
}

impl ShardTransport for ChannelTransport {
    fn send(&self, req: ShardRequest) {
        self.tx
            .send(req)
            .expect("shard owner thread terminated unexpectedly");
    }

    fn recv(&self) -> ShardResponse {
        self.rx
            .lock()
            .expect("shard transport reply lock")
            .recv()
            .expect("shard owner thread terminated unexpectedly")
    }
}

impl std::fmt::Debug for ChannelTransport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChannelTransport").finish_non_exhaustive()
    }
}
