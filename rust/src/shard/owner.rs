//! The shard owner: one thread, one contiguous vocabulary range, one
//! pair of paged stores (phi + residual) with their own codec
//! directories, write-ahead logs and checkpoints — the PR-7/8 single
//! store machinery instantiated per shard, unchanged.
//!
//! The owner is a pure servant: it never initiates anything, it
//! executes [`ShardRequest`]s from its channel in arrival order and
//! replies on the requesting stream's channel. All EM semantics
//! (phisum, residual totals, RNG, batch ordering) stay resident in the
//! coordinator's trainer; the owner only materializes column state.
//! That split is what makes the sharded fleet bit-identical to the
//! single store: a column's value history is the same sequence of
//! merge/clamp deltas no matter which owner holds it.

use super::transport::{ShardRequest, ShardResponse, StoreSel};
use crate::store::paged::PagedPhi;
use crate::store::PhiColumnStore;
use std::sync::mpsc::{Receiver, Sender};

/// One vocabulary shard: the owning word range plus its two stores.
///
/// `hi == usize::MAX` marks the LAST shard, whose range is open-ended —
/// lifelong vocabulary growth (`W ← W+1`) lands entirely in the last
/// shard so earlier shards' extents never move.
#[derive(Debug)]
pub struct PhiShardOwner {
    index: usize,
    lo: usize,
    hi: usize,
    phi: PagedPhi,
    res: PagedPhi,
}

impl PhiShardOwner {
    pub fn new(
        index: usize,
        lo: usize,
        hi: usize,
        phi: PagedPhi,
        res: PagedPhi,
    ) -> Self {
        Self { index, lo, hi, phi, res }
    }

    fn store(&mut self, sel: StoreSel) -> &mut PagedPhi {
        match sel {
            StoreSel::Phi => &mut self.phi,
            StoreSel::Res => &mut self.res,
        }
    }

    /// Global word id → this shard's local column index.
    fn local(&self, w: usize) -> usize {
        debug_assert!(
            self.lo <= w && w < self.hi,
            "shard {}: word {w} outside owned range [{}, {})",
            self.index,
            self.lo,
            self.hi
        );
        w - self.lo
    }

    /// Localize a sorted global word list that the router already
    /// restricted to this shard's range (subtracting `lo` preserves
    /// order and distinctness).
    fn localize(&self, words: &[u32]) -> Vec<u32> {
        words.iter().map(|&w| (w as usize - self.lo) as u32).collect()
    }

    /// Keep only this shard's words, localized, ORDER PRESERVED — hot
    /// sets arrive in mass order, not sorted, and the buffer-pinning
    /// priority must survive the filter.
    fn filter_localize(&self, words: &[u32]) -> Vec<u32> {
        words
            .iter()
            .filter(|&&w| self.lo <= w as usize && (w as usize) < self.hi)
            .map(|&w| (w as usize - self.lo) as u32)
            .collect()
    }

    /// The request service loop. Runs until [`ShardRequest::Shutdown`],
    /// a closed request channel, or a facade that stopped listening —
    /// all three mean the coordinator is done with this shard.
    pub fn serve(
        mut self,
        rx: Receiver<ShardRequest>,
        phi_reply: Sender<ShardResponse>,
        res_reply: Sender<ShardResponse>,
    ) {
        while let Ok(req) = rx.recv() {
            let sel = match &req {
                ShardRequest::Shutdown => break,
                ShardRequest::EnsureCapacity { sel, .. }
                | ShardRequest::LoadColumn { sel, .. }
                | ShardRequest::StoreColumn { sel, .. }
                | ShardRequest::MergeColumn { sel, .. }
                | ShardRequest::ClampAddColumn { sel, .. }
                | ShardRequest::SnapshotColumns { sel, .. }
                | ShardRequest::SetHotWords { sel, .. }
                | ShardRequest::PrefetchColumns { sel, .. }
                | ShardRequest::SetAsyncIo { sel, .. }
                | ShardRequest::ColumnStats { sel, .. }
                | ShardRequest::NWords { sel }
                | ShardRequest::EnableWal { sel }
                | ShardRequest::WalBegin { sel, .. }
                | ShardRequest::WalCommit { sel, .. }
                | ShardRequest::TruncateWal { sel }
                | ShardRequest::Flush { sel }
                | ShardRequest::IoStats { sel }
                | ShardRequest::WalBytes { sel } => *sel,
            };
            let resp = self.execute(req);
            let reply = match sel {
                StoreSel::Phi => &phi_reply,
                StoreSel::Res => &res_reply,
            };
            if reply.send(resp).is_err() {
                break;
            }
        }
    }

    fn execute(&mut self, req: ShardRequest) -> ShardResponse {
        match req {
            ShardRequest::EnsureCapacity { sel, n_words } => {
                let local = n_words.min(self.hi).saturating_sub(self.lo);
                self.store(sel).ensure_capacity(local);
                ShardResponse::Unit
            }
            ShardRequest::LoadColumn { sel, w } => {
                let (lw, k) = (self.local(w), self.phi.k());
                let mut out = vec![0.0f32; k];
                self.store(sel).load_column(lw, &mut out);
                ShardResponse::Column(out)
            }
            ShardRequest::StoreColumn { sel, w, data } => {
                let lw = self.local(w);
                self.store(sel).store_column(lw, &data);
                ShardResponse::Unit
            }
            ShardRequest::MergeColumn { sel, w, delta } => {
                let lw = self.local(w);
                self.store(sel).merge_column(lw, &delta);
                ShardResponse::Unit
            }
            ShardRequest::ClampAddColumn { sel, w, delta } => {
                let lw = self.local(w);
                ShardResponse::Total(self.store(sel).clamp_add_column(lw, &delta))
            }
            ShardRequest::SnapshotColumns { sel, words } => {
                let local = self.localize(&words);
                let snap = self.store(sel).snapshot_columns(&local);
                let (_, _, data) = snap.into_parts();
                ShardResponse::Snapshot { words, data }
            }
            ShardRequest::SetHotWords { sel, words } => {
                let local = self.filter_localize(&words);
                self.store(sel).set_hot_words(&local);
                ShardResponse::Unit
            }
            ShardRequest::PrefetchColumns { sel, words } => {
                let local = self.filter_localize(&words);
                self.store(sel).prefetch_columns(&local);
                ShardResponse::Unit
            }
            ShardRequest::SetAsyncIo { sel, enabled } => {
                ShardResponse::Bool(self.store(sel).set_async_io(enabled))
            }
            ShardRequest::ColumnStats { sel, w } => {
                if w < self.lo || w >= self.hi {
                    return ShardResponse::ColStats(None);
                }
                let lw = w - self.lo;
                ShardResponse::ColStats(self.store(sel).column_stats(lw))
            }
            ShardRequest::NWords { sel } => {
                ShardResponse::Count(self.store(sel).n_words())
            }
            ShardRequest::EnableWal { sel } => ShardResponse::Done(
                self.store(sel).enable_wal().map_err(|e| e.to_string()),
            ),
            ShardRequest::WalBegin { sel, batch_id } => {
                self.store(sel).wal_begin(batch_id);
                ShardResponse::Unit
            }
            ShardRequest::WalCommit { sel, batch_id, state } => {
                self.store(sel).wal_commit(batch_id, &state);
                ShardResponse::Unit
            }
            ShardRequest::TruncateWal { sel } => ShardResponse::Done(
                self.store(sel).truncate_wal().map_err(|e| e.to_string()),
            ),
            ShardRequest::Flush { sel } => ShardResponse::Done(
                self.store(sel).flush().map_err(|e| e.to_string()),
            ),
            ShardRequest::IoStats { sel } => {
                ShardResponse::Stats(self.store(sel).io_stats())
            }
            ShardRequest::WalBytes { sel } => {
                ShardResponse::Bytes(self.store(sel).wal_bytes())
            }
            ShardRequest::Shutdown => unreachable!("handled in serve()"),
        }
    }
}
