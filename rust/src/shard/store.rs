//! [`ShardedPhi`]: the coordinator-side store facade over the shard
//! fleet.
//!
//! One facade instance is one *stream* (phi or residual) over ALL
//! shards: it implements [`PhiColumnStore`], so the trainer
//! (`Foem<ShardedPhi>`) is the unmodified single-store trainer — the
//! three-phase seam, the blanket [`crate::baselines::OnlineLda`] impl
//! and the [`crate::exec::pipeline::PhasedTrainer`] impl all come for
//! free. Every column operation is routed to the owner of the word's
//! range as one explicit [`ShardRequest`]; reads scatter-gather
//! (send to every owning shard, collect in fixed shard order), WAL
//! brackets walk the shards sequentially so commit durability is
//! ordered.
//!
//! **Accounting bit-identity.** The facade never adds or removes a
//! store access: the owner executes the *same* `PagedPhi` call the
//! unsharded trainer would have made (`load_column`,
//! `snapshot_columns`, `merge_column`, `clamp_add_column`, ...), so at
//! N=1 the per-counter [`IoStats`] are bit-identical to the single
//! store, and at N>1 only buffer-dynamics counters (hits/misses,
//! write-behind) may shift while logical read/write counts stay exact.
//! The one exception is the generic closure access
//! [`PhiColumnStore::with_column`], which a wire protocol cannot carry
//! and the facade emulates as load + store (two accesses). The
//! three-phase executor path — every sharded production configuration
//! (`n_workers >= 2` or any pipeline depth) — never touches it; only
//! the single-worker serial sweep does, and there the emulation is
//! still content-identical (the load returns the current value, the
//! store persists the closure's mutation), with only the access
//! counters shifting.

use super::owner::PhiShardOwner;
use super::transport::{
    ChannelTransport, ShardRequest, ShardResponse, ShardTransport, StoreSel,
};
use super::ShardRouter;
use crate::store::{ColumnStats, IoStats, PhiColumnStore, PhiSnapshot};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Owns the request senders and join handles of the shard threads.
/// Dropped when the LAST facade over the fleet drops: sends `Shutdown`
/// to every owner and joins, so shard threads never outlive the
/// trainer.
struct Fleet {
    txs: Vec<mpsc::Sender<ShardRequest>>,
    joins: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for tx in &self.txs {
            // A send error only means the owner already exited.
            let _ = tx.send(ShardRequest::Shutdown);
        }
        let mut joins = match self.joins.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        for j in joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// One stream (phi or residual) of the vocabulary-sharded fleet,
/// behind the ordinary [`PhiColumnStore`] interface. See the module
/// docs for the routing and bit-identity contracts.
pub struct ShardedPhi {
    sel: StoreSel,
    k: usize,
    router: ShardRouter,
    transports: Vec<Box<dyn ShardTransport>>,
    fleet: Arc<Fleet>,
    wal_on: bool,
}

impl std::fmt::Debug for ShardedPhi {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedPhi")
            .field("sel", &self.sel)
            .field("k", &self.k)
            .field("n_shards", &self.transports.len())
            .field("wal_on", &self.wal_on)
            .finish_non_exhaustive()
    }
}

impl ShardedPhi {
    /// Spawn one owner thread per shard and return the two store
    /// facades over the fleet: `(phi, residual)`. `wal_armed` seeds the
    /// facades' cached WAL flag — `true` when the owners' stores were
    /// reopened with their logs already armed
    /// ([`crate::store::paged::PagedPhi::open_with_wal`]).
    pub fn spawn_fleet(
        owners: Vec<PhiShardOwner>,
        k: usize,
        router: ShardRouter,
        wal_armed: bool,
    ) -> (ShardedPhi, ShardedPhi) {
        assert_eq!(owners.len(), router.n_shards(), "owner/router mismatch");
        let mut txs = Vec::new();
        let mut joins = Vec::new();
        let mut phi_tr: Vec<Box<dyn ShardTransport>> = Vec::new();
        let mut res_tr: Vec<Box<dyn ShardTransport>> = Vec::new();
        for (i, owner) in owners.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            let (phi_tx, phi_rx) = mpsc::channel();
            let (res_tx, res_rx) = mpsc::channel();
            let join = std::thread::Builder::new()
                .name(format!("phi-shard-{i}"))
                .spawn(move || owner.serve(rx, phi_tx, res_tx))
                .expect("spawn shard owner thread");
            phi_tr.push(Box::new(ChannelTransport::new(tx.clone(), phi_rx)));
            res_tr.push(Box::new(ChannelTransport::new(tx.clone(), res_rx)));
            txs.push(tx);
            joins.push(join);
        }
        let fleet = Arc::new(Fleet { txs, joins: Mutex::new(joins) });
        let phi = ShardedPhi {
            sel: StoreSel::Phi,
            k,
            router: router.clone(),
            transports: phi_tr,
            fleet: Arc::clone(&fleet),
            wal_on: wal_armed,
        };
        let res = ShardedPhi {
            sel: StoreSel::Res,
            k,
            router,
            transports: res_tr,
            fleet,
            wal_on: wal_armed,
        };
        (phi, res)
    }

    pub fn n_shards(&self) -> usize {
        self.transports.len()
    }

    pub fn router(&self) -> &ShardRouter {
        &self.router
    }

    /// One request to one shard, strict RPC.
    fn call(&self, shard: usize, req: ShardRequest) -> ShardResponse {
        let t = &self.transports[shard];
        t.send(req);
        t.recv()
    }

    /// Scatter a request to every shard, then gather replies in fixed
    /// shard order — owners work concurrently, the result order is
    /// deterministic.
    fn scatter(
        &self,
        mk: impl Fn(usize) -> ShardRequest,
    ) -> Vec<ShardResponse> {
        for (i, t) in self.transports.iter().enumerate() {
            t.send(mk(i));
        }
        self.transports.iter().map(|t| t.recv()).collect()
    }

    /// Walk the shards one by one (send → recv before the next shard) —
    /// the durability-ordered broadcast used for WAL brackets.
    fn sequential(
        &self,
        mk: impl Fn(usize) -> ShardRequest,
    ) -> Vec<ShardResponse> {
        (0..self.transports.len())
            .map(|i| self.call(i, mk(i)))
            .collect()
    }

    fn expect_unit(resp: ShardResponse) {
        match resp {
            ShardResponse::Unit => {}
            other => panic!("shard protocol error: expected Unit, got {other:?}"),
        }
    }

    fn expect_done(resp: ShardResponse) -> anyhow::Result<()> {
        match resp {
            ShardResponse::Done(Ok(())) => Ok(()),
            ShardResponse::Done(Err(e)) => Err(anyhow::anyhow!(e)),
            other => {
                panic!("shard protocol error: expected Done, got {other:?}")
            }
        }
    }

    /// Arm the write-ahead log on every shard store of this stream.
    pub fn enable_wal(&mut self) -> anyhow::Result<()> {
        let sel = self.sel;
        for resp in self.sequential(|_| ShardRequest::EnableWal { sel }) {
            Self::expect_done(resp)?;
        }
        self.wal_on = true;
        Ok(())
    }

    /// Total WAL bytes ever appended across the shards of this stream
    /// (survives truncation — the perf-trajectory counter).
    pub fn wal_bytes(&self) -> u64 {
        let sel = self.sel;
        self.scatter(|_| ShardRequest::WalBytes { sel })
            .into_iter()
            .map(|r| match r {
                ShardResponse::Bytes(b) => b,
                other => panic!(
                    "shard protocol error: expected Bytes, got {other:?}"
                ),
            })
            .sum()
    }

    /// Per-shard I/O counters of this stream, in shard order — the
    /// truthful-telemetry breakdown behind the summed
    /// [`PhiColumnStore::io_stats`].
    pub fn shard_io_stats(&self) -> Vec<IoStats> {
        let sel = self.sel;
        self.scatter(|_| ShardRequest::IoStats { sel })
            .into_iter()
            .map(|r| match r {
                ShardResponse::Stats(s) => s,
                other => panic!(
                    "shard protocol error: expected Stats, got {other:?}"
                ),
            })
            .collect()
    }

    /// Scatter-gather a snapshot as PER-SHARD parts (global word ids),
    /// in shard order — the serve layer assembles these into per-shard
    /// [`crate::em::EvalPhiView`]s and merges them into one distributed
    /// snapshot ([`crate::em::EvalPhiView::merge_shards`]). The plain
    /// [`PhiColumnStore::snapshot_columns`] is exactly the
    /// concatenation of these parts.
    pub fn shard_snapshots(&mut self, words: &[u32]) -> Vec<PhiSnapshot> {
        let sel = self.sel;
        let runs = self.router.split_words(words);
        for &(shard, ref range) in &runs {
            self.transports[shard].send(ShardRequest::SnapshotColumns {
                sel,
                words: words[range.clone()].to_vec(),
            });
        }
        runs.iter()
            .map(|(shard, _)| match self.transports[*shard].recv() {
                ShardResponse::Snapshot { words, data } => {
                    PhiSnapshot::from_parts(self.k, words, data)
                }
                other => panic!(
                    "shard protocol error: expected Snapshot, got {other:?}"
                ),
            })
            .collect()
    }
}

impl PhiColumnStore for ShardedPhi {
    fn k(&self) -> usize {
        self.k
    }

    fn n_words(&self) -> usize {
        // Only the LAST shard's range is open-ended, so the global
        // vocabulary is its low cut plus its current column count.
        let last = self.transports.len() - 1;
        let sel = self.sel;
        match self.call(last, ShardRequest::NWords { sel }) {
            ShardResponse::Count(n) => self.router.lo(last) + n,
            other => {
                panic!("shard protocol error: expected Count, got {other:?}")
            }
        }
    }

    fn ensure_capacity(&mut self, n_words: usize) {
        let sel = self.sel;
        for resp in
            self.scatter(|_| ShardRequest::EnsureCapacity { sel, n_words })
        {
            Self::expect_unit(resp);
        }
    }

    fn with_column<R>(
        &mut self,
        w: usize,
        f: impl FnOnce(&mut [f32]) -> R,
    ) -> R {
        // Closures cannot cross the transport: emulate as a
        // load + store round trip. Never on a trainer hot path — the
        // apply phase uses the explicit merge/clamp verbs below.
        let sel = self.sel;
        let shard = self.router.owner_of(w);
        let mut col =
            match self.call(shard, ShardRequest::LoadColumn { sel, w }) {
                ShardResponse::Column(c) => c,
                other => panic!(
                    "shard protocol error: expected Column, got {other:?}"
                ),
            };
        let r = f(&mut col);
        Self::expect_unit(self.call(
            shard,
            ShardRequest::StoreColumn { sel, w, data: col },
        ));
        r
    }

    fn load_column(&mut self, w: usize, out: &mut [f32]) {
        let sel = self.sel;
        let shard = self.router.owner_of(w);
        match self.call(shard, ShardRequest::LoadColumn { sel, w }) {
            ShardResponse::Column(c) => out.copy_from_slice(&c),
            other => {
                panic!("shard protocol error: expected Column, got {other:?}")
            }
        }
    }

    fn store_column(&mut self, w: usize, data: &[f32]) {
        let sel = self.sel;
        let shard = self.router.owner_of(w);
        Self::expect_unit(self.call(
            shard,
            ShardRequest::StoreColumn { sel, w, data: data.to_vec() },
        ));
    }

    fn merge_column(&mut self, w: usize, delta: &[f32]) {
        let sel = self.sel;
        let shard = self.router.owner_of(w);
        Self::expect_unit(self.call(
            shard,
            ShardRequest::MergeColumn { sel, w, delta: delta.to_vec() },
        ));
    }

    fn clamp_add_column(&mut self, w: usize, delta: &[f32]) -> f32 {
        let sel = self.sel;
        let shard = self.router.owner_of(w);
        match self.call(
            shard,
            ShardRequest::ClampAddColumn { sel, w, delta: delta.to_vec() },
        ) {
            ShardResponse::Total(t) => t,
            other => {
                panic!("shard protocol error: expected Total, got {other:?}")
            }
        }
    }

    fn snapshot_columns(&mut self, words: &[u32]) -> PhiSnapshot {
        debug_assert!(
            words.windows(2).all(|w| w[0] < w[1]),
            "snapshot words must be sorted and distinct"
        );
        // Shard ranges are contiguous and ascending, so concatenating
        // the per-shard parts in shard order preserves the global sort.
        let parts = self.shard_snapshots(words);
        let mut out_words = Vec::with_capacity(words.len());
        let mut data = Vec::with_capacity(words.len() * self.k);
        for part in parts {
            let (_, w, d) = part.into_parts();
            out_words.extend(w);
            data.extend(d);
        }
        PhiSnapshot::from_parts(self.k, out_words, data)
    }

    fn set_hot_words(&mut self, words: &[u32]) {
        let sel = self.sel;
        for resp in self.scatter(|_| ShardRequest::SetHotWords {
            sel,
            words: words.to_vec(),
        }) {
            Self::expect_unit(resp);
        }
    }

    fn prefetch_columns(&mut self, words: &[u32]) {
        let sel = self.sel;
        for resp in self.scatter(|_| ShardRequest::PrefetchColumns {
            sel,
            words: words.to_vec(),
        }) {
            Self::expect_unit(resp);
        }
    }

    fn set_async_io(&mut self, enabled: bool) -> bool {
        let sel = self.sel;
        self.scatter(|_| ShardRequest::SetAsyncIo { sel, enabled })
            .into_iter()
            .all(|r| match r {
                ShardResponse::Bool(b) => b,
                other => panic!(
                    "shard protocol error: expected Bool, got {other:?}"
                ),
            })
    }

    fn wal_enabled(&self) -> bool {
        self.wal_on
    }

    fn wal_begin(&mut self, batch_id: u64) {
        if !self.wal_on {
            return;
        }
        let sel = self.sel;
        for resp in self.sequential(|_| ShardRequest::WalBegin { sel, batch_id })
        {
            Self::expect_unit(resp);
        }
    }

    fn wal_commit(&mut self, batch_id: u64, state: &[u8]) {
        if !self.wal_on {
            return;
        }
        // Sequential walk: shard i's commit (one fsync) completes
        // before shard i+1's is requested, so a crash leaves committed
        // batches as a PREFIX in shard order — and recovery's
        // min-across-shards cursor is exact, never racy.
        let sel = self.sel;
        for resp in self.sequential(|_| ShardRequest::WalCommit {
            sel,
            batch_id,
            state: state.to_vec(),
        }) {
            Self::expect_unit(resp);
        }
    }

    fn truncate_wal(&mut self) -> anyhow::Result<()> {
        let sel = self.sel;
        for resp in self.sequential(|_| ShardRequest::TruncateWal { sel }) {
            Self::expect_done(resp)?;
        }
        Ok(())
    }

    fn flush(&mut self) -> anyhow::Result<()> {
        let sel = self.sel;
        for resp in self.scatter(|_| ShardRequest::Flush { sel }) {
            Self::expect_done(resp)?;
        }
        Ok(())
    }

    fn io_stats(&self) -> IoStats {
        // Satellite contract: the coordinator's telemetry is the SUM of
        // the per-shard stores, not one shard's view.
        let mut total = IoStats::default();
        for s in self.shard_io_stats() {
            total.absorb(&s);
        }
        total
    }

    fn column_stats(&self, w: usize) -> Option<ColumnStats> {
        let sel = self.sel;
        let shard = self.router.owner_of(w);
        match self.call(shard, ShardRequest::ColumnStats { sel, w }) {
            ShardResponse::ColStats(s) => s,
            other => {
                panic!("shard protocol error: expected ColStats, got {other:?}")
            }
        }
    }
}
