//! Vocabulary-sharded scale-out: a fleet of phi-shard owners behind
//! the single-store interface.
//!
//! The vocabulary `[0, W)` is partitioned into N contiguous ranges by
//! a [`ShardRouter`]; each range is owned by a [`PhiShardOwner`] on
//! its own thread with its OWN paged store pair (phi + residual),
//! codec directory, write-ahead log and checkpoint — the existing
//! single-store machinery instantiated per shard, unchanged. The
//! coordinator talks to owners only through the request/response
//! protocol in [`transport`] (in-process channels today,
//! serialization-ready frames for sockets later).
//!
//! The seam is the store, not the trainer: [`ShardedPhi`] implements
//! [`crate::store::PhiColumnStore`], so `Foem<ShardedPhi>` IS the
//! unmodified FOEM trainer — its three-phase stage/compute/apply
//! split, the doc-sharded executor reduction, the pipelined driver
//! and the serve fold-in all run verbatim over the fleet. All
//! resident EM state (phisum, residual totals, RNG, step) stays in
//! the coordinator; owners only materialize column state. A column's
//! value history is therefore the same sequence of deltas no matter
//! which owner holds it, which is what makes the sharded run
//! content-identical to the unsharded run at any N — and, on the
//! three-phase executor path, fully bit-identical (including
//! [`crate::store::IoStats`]) at N=1.
//!
//! Layout: shard `i` of an even split over `W` words owns
//! `[i*ceil(W/N), (i+1)*ceil(W/N))`, clamped to `W`; the LAST shard's
//! range is open-ended so lifelong vocabulary growth lands entirely
//! in it and earlier shards' extents never move. That invariant is
//! what lets [`Foem::sharded_resume`] rebuild the router from the
//! on-disk shard extents alone.

pub mod owner;
pub mod store;
pub mod transport;

pub use owner::PhiShardOwner;
pub use store::ShardedPhi;
pub use transport::{
    ChannelTransport, ShardRequest, ShardResponse, ShardTransport, StoreSel,
};

use crate::em::foem::{Foem, FoemConfig, FoemTrainState};
use crate::em::EvalPhiView;
use crate::store::paged::PagedPhi;
use crate::store::{Codec, PhiColumnStore, PhiSnapshot};
use crate::LdaParams;
use std::path::{Path, PathBuf};

/// The contiguous range partition of the vocabulary. `cuts[i]` is
/// shard `i`'s first word; shard `i` owns `[cuts[i], cuts[i+1])`, and
/// the last shard owns `[cuts[N-1], ∞)` — open-ended for vocabulary
/// growth.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardRouter {
    cuts: Vec<usize>,
}

impl ShardRouter {
    /// Even split of an initial vocabulary of `n_words` over
    /// `n_shards` ranges of `ceil(n_words / n_shards)` words each
    /// (clamped at `n_words`; trailing shards may start empty, and
    /// with `n_words == 0` the last shard owns everything).
    pub fn even(n_words: usize, n_shards: usize) -> Self {
        let n = n_shards.max(1);
        let span = n_words.div_ceil(n).max(1);
        let cuts = (0..n).map(|i| (i * span).min(n_words)).collect();
        Self { cuts }
    }

    /// Rebuild a router from explicit range starts — the resume path,
    /// where the cuts are recovered from the on-disk shard extents.
    pub fn from_cuts(cuts: Vec<usize>) -> Self {
        assert!(!cuts.is_empty(), "router needs at least one shard");
        assert_eq!(cuts[0], 0, "shard 0 must start at word 0");
        debug_assert!(
            cuts.windows(2).all(|c| c[0] <= c[1]),
            "shard cuts must be non-decreasing"
        );
        Self { cuts }
    }

    pub fn n_shards(&self) -> usize {
        self.cuts.len()
    }

    /// First word of shard `i`'s range.
    pub fn lo(&self, i: usize) -> usize {
        self.cuts[i]
    }

    /// One past the last word of shard `i`'s range; `usize::MAX` for
    /// the open-ended last shard.
    pub fn hi(&self, i: usize) -> usize {
        if i + 1 == self.cuts.len() {
            usize::MAX
        } else {
            self.cuts[i + 1]
        }
    }

    /// The shard owning global word `w`. With duplicate cuts (empty
    /// shards) the last shard at that cut wins, so empty shards never
    /// own a word.
    pub fn owner_of(&self, w: usize) -> usize {
        self.cuts.partition_point(|&c| c <= w) - 1
    }

    /// Split a sorted global word list into per-shard runs, in shard
    /// order: `(shard, index range into `words`)`. Only shards that
    /// own at least one of the words appear.
    pub fn split_words(
        &self,
        words: &[u32],
    ) -> Vec<(usize, std::ops::Range<usize>)> {
        debug_assert!(
            words.windows(2).all(|w| w[0] < w[1]),
            "split_words needs sorted, distinct words"
        );
        let mut runs = Vec::new();
        let mut start = 0;
        while start < words.len() {
            let shard = self.owner_of(words[start] as usize);
            let hi = self.hi(shard);
            let end = start
                + words[start..].partition_point(|&w| (w as usize) < hi);
            runs.push((shard, start..end));
            start = end;
        }
        runs
    }
}

/// Shard `i`'s store path derived from the run's phi path:
/// `phi.bin` → `phi.s<i>.bin` (the residual twin then follows from
/// [`Foem::residual_path`]: `phi.s<i>.res.bin`).
pub fn shard_path(path: &Path, shard: usize) -> PathBuf {
    let stem = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("phi");
    let ext = path
        .extension()
        .and_then(|s| s.to_str())
        .unwrap_or("bin");
    path.with_file_name(format!("{stem}.s{shard}.{ext}"))
}

impl Foem<ShardedPhi> {
    /// Create a fresh vocabulary-sharded trainer: one owner thread per
    /// shard, each with its own phi/residual store pair at
    /// [`shard_path`]. The hot-buffer budget splits evenly across
    /// shards, then evenly across the two matrices within each shard —
    /// at N=1 this is byte-for-byte the unsharded budget split.
    #[allow(clippy::too_many_arguments)]
    pub fn sharded_create_with_codec(
        params: LdaParams,
        path: &Path,
        n_shards: usize,
        n_words: usize,
        buffer_bytes: usize,
        cfg: FoemConfig,
        seed: u64,
        codec: Codec,
    ) -> anyhow::Result<Self> {
        let k = params.n_topics;
        let router = ShardRouter::even(n_words, n_shards);
        let n = router.n_shards();
        let half = ((buffer_bytes / n) / 2).max(k * 4);
        let mut owners = Vec::with_capacity(n);
        for i in 0..n {
            let (lo, hi) = (router.lo(i), router.hi(i));
            let local = n_words.min(hi).saturating_sub(lo);
            let p = shard_path(path, i);
            let phi =
                PagedPhi::create_with_codec(&p, k, local, half, codec)?;
            let res = PagedPhi::create_with_codec(
                &Foem::<PagedPhi>::residual_path(&p),
                k,
                local,
                half,
                codec,
            )?;
            owners.push(PhiShardOwner::new(i, lo, hi, phi, res));
        }
        let (phi, res) = ShardedPhi::spawn_fleet(owners, k, router, false);
        Ok(Self::with_stores(params, phi, res, cfg, seed))
    }

    /// Arm the write-ahead log on every shard of both streams
    /// (`--wal` / checkpointing under `--shards`).
    pub fn enable_wal(&mut self) -> anyhow::Result<()> {
        self.store.enable_wal()?;
        self.res_store.enable_wal()
    }

    /// Crash recovery for a sharded run. Reopens every shard pair with
    /// its WAL on the coordinator thread, replays, then spawns the
    /// fleet with logs still armed. Returns the trainer plus the last
    /// GLOBALLY durable batch id — the cursor the driver resumes after.
    ///
    /// A batch is globally durable only when EVERY shard committed it.
    /// Commits walk the shards sequentially in shard order (shard
    /// `i`'s fsync completes before shard `i+1`'s commit is
    /// requested), so each shard's committed set covers every batch id
    /// up to its own maximum, and the durable cursor is exactly the
    /// minimum of the per-shard maxima. Batches beyond that cursor are
    /// NOT replayed anywhere — checkpoint extents are immutable while
    /// the WAL is armed, so skipping a record leaves the shard at the
    /// state after the cursor, and the driver's deterministic re-run
    /// of later batches regenerates bit-identical deltas (their stale
    /// log records are superseded by the re-run's identical full
    /// column images). At N=1 this degenerates to the single-store
    /// [`Foem::paged_resume`].
    pub fn sharded_resume(
        params: LdaParams,
        path: &Path,
        n_shards: usize,
        buffer_bytes: usize,
        cfg: FoemConfig,
        state: &FoemTrainState,
    ) -> anyhow::Result<(Self, u64)> {
        let k = params.n_topics;
        let n = n_shards.max(1);
        for i in 0..n {
            let p = shard_path(path, i);
            if !p.exists() {
                anyhow::bail!(
                    "missing shard store {}: was this run created with a \
                     different --shards?",
                    p.display()
                );
            }
        }
        let extra = shard_path(path, n);
        if extra.exists() {
            anyhow::bail!(
                "unexpected extra shard store {}: was this run created \
                 with a different --shards?",
                extra.display()
            );
        }

        let half = ((buffer_bytes / n) / 2).max(k * 4);
        let mut opened = Vec::with_capacity(n);
        for i in 0..n {
            let p = shard_path(path, i);
            let (phi, phi_batches) = PagedPhi::open_with_wal(&p, half)?;
            let (res, res_batches) = PagedPhi::open_with_wal(
                &Foem::<PagedPhi>::residual_path(&p),
                half,
            )?;
            opened.push((phi, phi_batches, res, res_batches));
        }

        // Non-last shard extents are fixed at creation (growth only
        // lands in the open-ended last shard), so the on-disk column
        // counts reconstruct the original cuts exactly.
        let mut cuts = Vec::with_capacity(n);
        let mut acc = 0usize;
        for entry in &opened {
            cuts.push(acc);
            acc += entry.0.n_words();
        }
        let router = ShardRouter::from_cuts(cuts);

        let cursor0 = state.step;
        let mut cursor = u64::MAX;
        for entry in &opened {
            let max_committed = entry
                .1
                .iter()
                .map(|b| b.batch_id)
                .max()
                .unwrap_or(cursor0);
            cursor = cursor.min(max_committed);
        }
        let cursor = cursor.max(cursor0);

        // The phi commit of a batch happens only after its residual
        // commit completed on ALL shards, so every batch in
        // (cursor0, cursor] is present in both logs of every shard —
        // and an orphaned residual-only commit necessarily sits beyond
        // the cursor and is correctly skipped by the range check.
        for (phi, phi_batches, res, res_batches) in &mut opened {
            for b in res_batches.iter() {
                if b.batch_id > cursor0 && b.batch_id <= cursor {
                    res.apply_wal_batch(b);
                }
            }
            for b in phi_batches.iter() {
                if b.batch_id > cursor0 && b.batch_id <= cursor {
                    phi.apply_wal_batch(b);
                }
            }
        }

        // Every shard's phi log carries the SAME coordinator state
        // blob per commit; shard 0's log is as good as any.
        let blobs: Vec<Vec<u8>> = opened[0]
            .1
            .iter()
            .filter(|b| b.batch_id > cursor0 && b.batch_id <= cursor)
            .map(|b| b.state.clone())
            .collect();

        let mut owners = Vec::with_capacity(n);
        for (i, (phi, _, res, _)) in opened.into_iter().enumerate() {
            owners.push(PhiShardOwner::new(
                i,
                router.lo(i),
                router.hi(i),
                phi,
                res,
            ));
        }
        let (phi, res) = ShardedPhi::spawn_fleet(owners, k, router, true);
        let mut this = Self::with_stores(params, phi, res, cfg, 0);
        this.import_train_state(state);
        for blob in &blobs {
            this.apply_commit_state(blob)?;
        }
        Ok((this, cursor))
    }

    /// Per-shard [`EvalPhiView`] parts over the requested (sorted,
    /// global) words, in shard order — the scatter half of the serve
    /// router. Concatenating these via [`EvalPhiView::merge_shards`]
    /// is bit-identical to the single
    /// [`crate::baselines::OnlineLda::eval_view`] over the same words:
    /// each part is built exactly like the single view (one
    /// non-dirtying snapshot read per column, zone-map stats riding
    /// along, the coordinator's resident `phisum` as the shared
    /// denominator), just restricted to one shard's range.
    pub fn shard_eval_views(&mut self, words: &[u32]) -> Vec<EvalPhiView> {
        let n_words = self.store.n_words();
        let parts = self.store.shard_snapshots(words);
        parts
            .into_iter()
            .map(|snap| {
                let (k, part_words, data) = snap.into_parts();
                let col_stats: Vec<Option<crate::store::ColumnStats>> =
                    part_words
                        .iter()
                        .map(|&w| self.store.column_stats(w as usize))
                        .collect();
                EvalPhiView::from_snapshot(
                    PhiSnapshot::from_parts(k, part_words, data),
                    self.phisum.clone(),
                    n_words,
                )
                .with_column_stats(col_stats)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_router_even_split_covers_vocab() {
        let r = ShardRouter::even(10, 4);
        // ceil(10/4) = 3 → cuts 0,3,6,9.
        assert_eq!(r.n_shards(), 4);
        assert_eq!((r.lo(0), r.hi(0)), (0, 3));
        assert_eq!((r.lo(1), r.hi(1)), (3, 6));
        assert_eq!((r.lo(2), r.hi(2)), (6, 9));
        assert_eq!((r.lo(3), r.hi(3)), (9, usize::MAX));
        for w in 0..10 {
            let s = r.owner_of(w);
            assert!(r.lo(s) <= w && w < r.hi(s), "word {w} misrouted");
        }
        // Vocabulary growth beyond the initial W lands in the last shard.
        assert_eq!(r.owner_of(10_000), 3);
    }

    #[test]
    fn shard_router_more_shards_than_words() {
        let r = ShardRouter::even(2, 4);
        // span = max(ceil(2/4), 1) = 1 → cuts 0,1,2,2; shard 2 is empty.
        assert_eq!(r.owner_of(0), 0);
        assert_eq!(r.owner_of(1), 1);
        // Duplicate cuts: the LAST shard at the cut owns the range, so
        // the empty shard never receives a word.
        assert_eq!(r.owner_of(2), 3);
        assert_eq!(r.lo(2), r.hi(2));
    }

    #[test]
    fn shard_router_single_shard_owns_everything() {
        let r = ShardRouter::even(100, 1);
        assert_eq!(r.n_shards(), 1);
        assert_eq!(r.owner_of(0), 0);
        assert_eq!(r.owner_of(99), 0);
        assert_eq!(r.hi(0), usize::MAX);
    }

    #[test]
    fn shard_router_split_words_runs() {
        let r = ShardRouter::even(10, 4);
        let words = [0u32, 2, 3, 7, 8, 9];
        let runs = r.split_words(&words);
        assert_eq!(
            runs,
            vec![(0usize, 0..2), (1usize, 2..3), (2usize, 3..5), (3usize, 5..6)]
        );
        // Shards owning none of the words do not appear.
        let runs = r.split_words(&[4u32, 5]);
        assert_eq!(runs, vec![(1usize, 0..2)]);
        assert!(r.split_words(&[]).is_empty());
    }

    #[test]
    fn shard_router_from_cuts_round_trip() {
        let r = ShardRouter::even(10, 3);
        let cuts: Vec<usize> = (0..r.n_shards()).map(|i| r.lo(i)).collect();
        assert_eq!(ShardRouter::from_cuts(cuts), r);
    }

    #[test]
    fn shard_path_naming() {
        let p = Path::new("/tmp/run/phi.bin");
        assert_eq!(shard_path(p, 0), Path::new("/tmp/run/phi.s0.bin"));
        assert_eq!(shard_path(p, 3), Path::new("/tmp/run/phi.s3.bin"));
        // The residual twin of a shard store keeps the shard tag.
        assert_eq!(
            Foem::<PagedPhi>::residual_path(&shard_path(p, 1)),
            Path::new("/tmp/run/phi.s1.res.bin")
        );
    }
}
