//! Sparse document-word matrices in the two layouts the paper uses:
//! doc-major (CSR over documents — the input layout of Figs. 1-3) and
//! vocab-major (CSC — Fig. 4 reorganizes every minibatch vocabulary-major
//! so each column of the streamed `phi` store is touched exactly once per
//! sweep).

/// Doc-major sparse matrix: row `d` lists the distinct words of document
/// `d` with their counts. `O(D + 2*NNZ)` memory, matching Table 3's
/// "compressed document-major format".
#[derive(Debug, Clone, PartialEq)]
pub struct DocWordMatrix {
    pub n_docs: usize,
    /// Vocabulary size W (upper bound on word ids + 1).
    pub n_words: usize,
    /// CSR row pointers, `len == n_docs + 1`.
    pub doc_ptr: Vec<u32>,
    /// Column (word) indices, `len == nnz`.
    pub word_ids: Vec<u32>,
    /// Word counts `x_{w,d}`, `len == nnz`.
    pub counts: Vec<f32>,
}

impl DocWordMatrix {
    /// Build from per-document `(word_id, count)` slices.
    pub fn from_rows(n_words: usize, rows: &[&[(u32, f32)]]) -> Self {
        let nnz: usize = rows.iter().map(|r| r.len()).sum();
        let mut doc_ptr = Vec::with_capacity(rows.len() + 1);
        let mut word_ids = Vec::with_capacity(nnz);
        let mut counts = Vec::with_capacity(nnz);
        doc_ptr.push(0u32);
        for row in rows {
            for &(w, c) in *row {
                debug_assert!((w as usize) < n_words);
                debug_assert!(c > 0.0);
                word_ids.push(w);
                counts.push(c);
            }
            doc_ptr.push(word_ids.len() as u32);
        }
        Self { n_docs: rows.len(), n_words, doc_ptr, word_ids, counts }
    }

    /// Build from `(doc, word, count)` triplets (any order; duplicates
    /// summed).
    pub fn from_triplets(
        n_docs: usize,
        n_words: usize,
        triplets: &[(u32, u32, f32)],
    ) -> Self {
        use std::collections::BTreeMap;
        let mut rows: Vec<BTreeMap<u32, f32>> = vec![BTreeMap::new(); n_docs];
        for &(d, w, c) in triplets {
            *rows[d as usize].entry(w).or_insert(0.0) += c;
        }
        let collected: Vec<Vec<(u32, f32)>> = rows
            .into_iter()
            .map(|m| m.into_iter().collect())
            .collect();
        let refs: Vec<&[(u32, f32)]> =
            collected.iter().map(|r| r.as_slice()).collect();
        Self::from_rows(n_words, &refs)
    }

    /// Number of non-zero entries (the paper's NNZ).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.word_ids.len()
    }

    /// Total token mass `sum_{w,d} x_{w,d}` (the paper's `ntokens`).
    pub fn total_tokens(&self) -> f64 {
        self.counts.iter().map(|&c| c as f64).sum()
    }

    /// Word ids of document `d`.
    #[inline]
    pub fn doc_words(&self, d: usize) -> &[u32] {
        let (s, e) = self.doc_range(d);
        &self.word_ids[s..e]
    }

    /// Counts of document `d`.
    #[inline]
    pub fn doc_counts(&self, d: usize) -> &[f32] {
        let (s, e) = self.doc_range(d);
        &self.counts[s..e]
    }

    #[inline]
    pub fn doc_range(&self, d: usize) -> (usize, usize) {
        (self.doc_ptr[d] as usize, self.doc_ptr[d + 1] as usize)
    }

    /// Iterate `(word, count)` pairs of document `d`.
    #[inline]
    pub fn iter_doc(&self, d: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let (s, e) = self.doc_range(d);
        self.word_ids[s..e]
            .iter()
            .copied()
            .zip(self.counts[s..e].iter().copied())
    }

    /// Token mass of one document.
    pub fn doc_len(&self, d: usize) -> f32 {
        self.doc_counts(d).iter().sum()
    }

    /// Reorganize into the vocab-major layout (Fig. 4 line note / §3.2).
    pub fn to_vocab_major(&self) -> VocabMajorMatrix {
        let nnz = self.nnz();
        let mut word_ptr = vec![0u32; self.n_words + 1];
        for &w in &self.word_ids {
            word_ptr[w as usize + 1] += 1;
        }
        for i in 0..self.n_words {
            word_ptr[i + 1] += word_ptr[i];
        }
        let mut doc_ids = vec![0u32; nnz];
        let mut counts = vec![0f32; nnz];
        let mut cursor = word_ptr.clone();
        for d in 0..self.n_docs {
            let (s, e) = self.doc_range(d);
            for i in s..e {
                let w = self.word_ids[i] as usize;
                let pos = cursor[w] as usize;
                doc_ids[pos] = d as u32;
                counts[pos] = self.counts[i];
                cursor[w] += 1;
            }
        }
        VocabMajorMatrix {
            n_docs: self.n_docs,
            n_words: self.n_words,
            word_ptr,
            doc_ids,
            counts,
        }
    }

    /// The set of distinct word ids present, ascending. This is the
    /// minibatch's local vocabulary `W_s`.
    pub fn distinct_words(&self) -> Vec<u32> {
        let mut seen = vec![false; self.n_words];
        for &w in &self.word_ids {
            seen[w as usize] = true;
        }
        (0..self.n_words as u32)
            .filter(|&w| seen[w as usize])
            .collect()
    }

    /// Extract the sub-matrix of a contiguous document range
    /// `[start, end)`; word ids are preserved (global).
    pub fn slice_docs(&self, start: usize, end: usize) -> DocWordMatrix {
        let end = end.min(self.n_docs);
        let s0 = self.doc_ptr[start] as usize;
        let e0 = self.doc_ptr[end] as usize;
        let doc_ptr = self.doc_ptr[start..=end]
            .iter()
            .map(|&p| p - s0 as u32)
            .collect();
        DocWordMatrix {
            n_docs: end - start,
            n_words: self.n_words,
            doc_ptr,
            word_ids: self.word_ids[s0..e0].to_vec(),
            counts: self.counts[s0..e0].to_vec(),
        }
    }

    /// Split each document's tokens into (observed ~80%, held-out ~20%)
    /// by *word tokens* as in §2.4's perplexity protocol. Deterministic in
    /// `seed`. Entries with fractional counts round per-token.
    pub fn split_tokens_80_20(
        &self,
        seed: u64,
    ) -> (DocWordMatrix, DocWordMatrix) {
        let mut rng = crate::util::Rng::new(seed);
        let mut obs_rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(self.n_docs);
        let mut held_rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(self.n_docs);
        for d in 0..self.n_docs {
            let mut obs = Vec::new();
            let mut held = Vec::new();
            for (w, c) in self.iter_doc(d) {
                let n = c.round() as usize;
                let mut h = 0usize;
                for _ in 0..n {
                    if rng.next_f32() < 0.2 {
                        h += 1;
                    }
                }
                // Keep at least one observed token per entry when possible
                // so fold-in always sees the document.
                if h == n && n > 1 {
                    h = n - 1;
                }
                let o = n - h;
                if o > 0 {
                    obs.push((w, o as f32));
                }
                if h > 0 {
                    held.push((w, h as f32));
                }
            }
            obs_rows.push(obs);
            held_rows.push(held);
        }
        let obs_refs: Vec<&[(u32, f32)]> =
            obs_rows.iter().map(|r| r.as_slice()).collect();
        let held_refs: Vec<&[(u32, f32)]> =
            held_rows.iter().map(|r| r.as_slice()).collect();
        (
            DocWordMatrix::from_rows(self.n_words, &obs_refs),
            DocWordMatrix::from_rows(self.n_words, &held_refs),
        )
    }
}

/// Vocab-major sparse matrix: column `w` lists the documents containing
/// word `w`. `O(W + 2*NNZ)` memory ("compressed vocabulary-major format").
#[derive(Debug, Clone, PartialEq)]
pub struct VocabMajorMatrix {
    pub n_docs: usize,
    pub n_words: usize,
    /// CSC column pointers, `len == n_words + 1`.
    pub word_ptr: Vec<u32>,
    /// Row (document) indices, `len == nnz`.
    pub doc_ids: Vec<u32>,
    /// Word counts, `len == nnz`.
    pub counts: Vec<f32>,
}

impl VocabMajorMatrix {
    #[inline]
    pub fn nnz(&self) -> usize {
        self.doc_ids.len()
    }

    #[inline]
    pub fn word_range(&self, w: usize) -> (usize, usize) {
        (self.word_ptr[w] as usize, self.word_ptr[w + 1] as usize)
    }

    /// Documents containing word `w`.
    #[inline]
    pub fn word_docs(&self, w: usize) -> &[u32] {
        let (s, e) = self.word_range(w);
        &self.doc_ids[s..e]
    }

    /// Counts parallel to [`Self::word_docs`].
    #[inline]
    pub fn word_counts(&self, w: usize) -> &[f32] {
        let (s, e) = self.word_range(w);
        &self.counts[s..e]
    }

    /// Iterate `(doc, count)` pairs of word `w`.
    #[inline]
    pub fn iter_word(&self, w: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let (s, e) = self.word_range(w);
        self.doc_ids[s..e]
            .iter()
            .copied()
            .zip(self.counts[s..e].iter().copied())
    }

    pub fn total_tokens(&self) -> f64 {
        self.counts.iter().map(|&c| c as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DocWordMatrix {
        DocWordMatrix::from_triplets(
            3,
            4,
            &[
                (0, 0, 2.0),
                (0, 2, 1.0),
                (1, 1, 3.0),
                (1, 2, 1.0),
                (2, 3, 5.0),
                (2, 0, 1.0),
            ],
        )
    }

    #[test]
    fn from_triplets_sums_duplicates() {
        let m = DocWordMatrix::from_triplets(1, 2, &[(0, 1, 1.0), (0, 1, 2.0)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.counts[0], 3.0);
    }

    #[test]
    fn csr_layout_is_consistent() {
        let m = sample();
        assert_eq!(m.nnz(), 6);
        assert_eq!(m.doc_ptr.len(), 4);
        assert_eq!(m.doc_words(0), &[0, 2]);
        assert_eq!(m.doc_counts(2), &[1.0, 5.0]);
        assert_eq!(m.total_tokens(), 13.0);
        assert_eq!(m.doc_len(1), 4.0);
    }

    #[test]
    fn vocab_major_round_trip_mass() {
        let m = sample();
        let vm = m.to_vocab_major();
        assert_eq!(vm.nnz(), m.nnz());
        assert_eq!(vm.total_tokens(), m.total_tokens());
        // word 0 appears in docs 0 and 2
        assert_eq!(vm.word_docs(0), &[0, 2]);
        assert_eq!(vm.word_counts(0), &[2.0, 1.0]);
        // word 3 only in doc 2
        assert_eq!(vm.word_docs(3), &[2]);
    }

    #[test]
    fn vocab_major_columns_cover_all_entries() {
        let m = sample();
        let vm = m.to_vocab_major();
        let mut mass = 0.0f64;
        for w in 0..vm.n_words {
            for (_, c) in vm.iter_word(w) {
                mass += c as f64;
            }
        }
        assert_eq!(mass, m.total_tokens());
    }

    #[test]
    fn distinct_words_sorted() {
        let m = sample();
        assert_eq!(m.distinct_words(), vec![0, 1, 2, 3]);
        let m2 = DocWordMatrix::from_triplets(1, 10, &[(0, 7, 1.0), (0, 2, 1.0)]);
        assert_eq!(m2.distinct_words(), vec![2, 7]);
    }

    #[test]
    fn slice_docs_preserves_rows() {
        let m = sample();
        let s = m.slice_docs(1, 3);
        assert_eq!(s.n_docs, 2);
        assert_eq!(s.doc_words(0), m.doc_words(1));
        assert_eq!(s.doc_counts(1), m.doc_counts(2));
    }

    #[test]
    fn token_split_preserves_mass() {
        let m = sample();
        let (obs, held) = m.split_tokens_80_20(3);
        assert_eq!(
            obs.total_tokens() + held.total_tokens(),
            m.total_tokens()
        );
        // ~20% held out, loose bounds for a tiny sample
        let frac = held.total_tokens() / m.total_tokens();
        assert!(frac < 0.6, "{frac}");
    }

    #[test]
    fn token_split_keeps_observed_nonempty() {
        // Every doc with >1 token in an entry must keep >=1 observed token.
        let m = DocWordMatrix::from_triplets(1, 1, &[(0, 0, 10.0)]);
        for seed in 0..20 {
            let (obs, _) = m.split_tokens_80_20(seed);
            assert!(obs.total_tokens() >= 1.0);
        }
    }
}
