//! Open-vocabulary manager for lifelong streams (§3.2).
//!
//! The paper's FOEM "can possibly process both infinite documents and
//! vocabulary words in the data stream without ending": when a new
//! vocabulary word is met, the vocabulary size is incremented (`W ← W+1`)
//! and the denominator `W(β−1)` of Eq. 13 grows accordingly.  This module
//! owns the string↔id mapping and the monotonically growing `W` that the
//! FOEM denominator reads.

use std::collections::HashMap;

/// Monotone string-to-id vocabulary. Ids are dense `0..len`.
#[derive(Debug, Default, Clone)]
pub struct Vocabulary {
    by_name: HashMap<String, u32>,
    names: Vec<String>,
}

impl Vocabulary {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current vocabulary size W.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Intern a word, growing W when it is unseen (the paper's `W ← W+1`).
    pub fn intern(&mut self, word: &str) -> u32 {
        if let Some(&id) = self.by_name.get(word) {
            return id;
        }
        let id = self.names.len() as u32;
        self.by_name.insert(word.to_string(), id);
        self.names.push(word.to_string());
        id
    }

    /// Lookup without growing.
    pub fn get(&self, word: &str) -> Option<u32> {
        self.by_name.get(word).copied()
    }

    pub fn name(&self, id: u32) -> Option<&str> {
        self.names.get(id as usize).map(|s| s.as_str())
    }

    /// Pre-register `n` anonymous words `w0..w{n-1}` (synthetic corpora).
    pub fn with_anonymous(n: usize) -> Self {
        let mut v = Self::new();
        for i in 0..n {
            v.intern(&format!("w{i}"));
        }
        v
    }
}

/// Tracks the vocabulary-growth statistics of a lifelong stream:
/// how many ids were first seen in each minibatch. Used by the
/// `lifelong_stream` example and the coordinator's metrics.
#[derive(Debug, Default, Clone)]
pub struct VocabGrowth {
    /// `seen[w] == true` once word id `w` has appeared in the stream.
    seen: Vec<bool>,
    /// Number of distinct ids observed so far (the *effective* W the
    /// FOEM denominator uses).
    pub n_seen: usize,
    /// Per-minibatch count of first-time words.
    pub new_per_batch: Vec<usize>,
}

impl VocabGrowth {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one minibatch's word ids; returns the number of new words.
    pub fn observe(&mut self, word_ids: impl Iterator<Item = u32>) -> usize {
        let mut fresh = 0usize;
        for w in word_ids {
            let w = w as usize;
            if w >= self.seen.len() {
                self.seen.resize(w + 1, false);
            }
            if !self.seen[w] {
                self.seen[w] = true;
                self.n_seen += 1;
                fresh += 1;
            }
        }
        self.new_per_batch.push(fresh);
        fresh
    }

    /// The effective vocabulary size after the batches observed so far —
    /// what FOEM plugs into `W(β−1)` (never less than 1).
    pub fn effective_w(&self) -> usize {
        self.n_seen.max(1)
    }

    /// Ids observed so far, ascending — the crash-recovery checkpoint
    /// persists this so a resumed lifelong run keeps its effective `W`
    /// and first-appearance dedup exact.
    pub fn seen_words(&self) -> Vec<u32> {
        (0..self.seen.len() as u32)
            .filter(|&w| self.seen[w as usize])
            .collect()
    }

    /// Rebuild growth state from a [`Self::seen_words`] snapshot. The
    /// per-batch first-appearance trace (`new_per_batch`) is diagnostics
    /// only and restarts empty.
    pub fn restore(words: &[u32]) -> Self {
        let mut g = Self::default();
        for &w in words {
            let w = w as usize;
            if w >= g.seen.len() {
                g.seen.resize(w + 1, false);
            }
            if !g.seen[w] {
                g.seen[w] = true;
                g.n_seen += 1;
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_grows_monotonically() {
        let mut v = Vocabulary::new();
        assert_eq!(v.intern("alpha"), 0);
        assert_eq!(v.intern("beta"), 1);
        assert_eq!(v.intern("alpha"), 0);
        assert_eq!(v.len(), 2);
        assert_eq!(v.name(1), Some("beta"));
        assert_eq!(v.get("gamma"), None);
    }

    #[test]
    fn anonymous_vocab() {
        let v = Vocabulary::with_anonymous(5);
        assert_eq!(v.len(), 5);
        assert_eq!(v.get("w3"), Some(3));
    }

    #[test]
    fn growth_counts_first_appearances() {
        let mut g = VocabGrowth::new();
        assert_eq!(g.observe([0u32, 1, 1, 2].into_iter()), 3);
        assert_eq!(g.observe([1u32, 2, 5].into_iter()), 1);
        assert_eq!(g.n_seen, 4);
        assert_eq!(g.effective_w(), 4);
        assert_eq!(g.new_per_batch, vec![3, 1]);
    }

    #[test]
    fn effective_w_never_zero() {
        let g = VocabGrowth::new();
        assert_eq!(g.effective_w(), 1);
    }
}
