//! Synthetic LDA corpus generator — the substitute for the paper's UCI
//! bag-of-words corpora (ENRON / WIKI / NYTIMES / PUBMED / NIPS), which
//! are multi-GB downloads unavailable in this offline environment (see
//! DESIGN.md §4 for the substitution argument).
//!
//! Documents are sampled from the LDA generative process itself:
//! `phi_k ~ Dir(beta_gen)`, `theta_d ~ Dir(alpha_gen)`, doc length
//! `~ Poisson(mean_len)`, each token `z ~ theta_d`, `w ~ phi_z`.  Because
//! every algorithm under comparison consumes *identical* streams, the
//! paper's relative claims (who converges faster, who reaches lower
//! perplexity, how cost scales with K and D_s) are preserved even though
//! absolute perplexities differ from the real corpora.
//!
//! Profiles below mirror each paper corpus' shape statistics (documents,
//! vocabulary, NNZ density) scaled to this testbed.

use super::{Corpus, DocWordMatrix};
use crate::stream::Minibatch;
use crate::util::Rng;

/// Parameters of the generative sampler.
#[derive(Debug, Clone)]
pub struct SyntheticConfig {
    pub name: String,
    /// Number of documents D.
    pub n_docs: usize,
    /// Vocabulary size W.
    pub n_words: usize,
    /// Number of generating topics (independent of the K later fitted).
    pub n_topics: usize,
    /// Mean document length in tokens (Poisson).
    pub mean_doc_len: f64,
    /// Dirichlet concentration for document-topic draws.
    pub alpha_gen: f64,
    /// Dirichlet concentration for topic-word draws (small => sparse,
    /// word-sense-like topics as in real corpora).
    pub beta_gen: f64,
}

impl SyntheticConfig {
    /// Tiny corpus for unit tests and doc examples (~seconds).
    pub fn small() -> Self {
        Self {
            name: "synth-small".into(),
            n_docs: 200,
            n_words: 500,
            n_topics: 10,
            mean_doc_len: 60.0,
            alpha_gen: 0.1,
            beta_gen: 0.05,
        }
    }

    /// NIPS-like profile (paper §4.1: D=1500, W=12419): used for the
    /// Fig. 7 dynamic-scheduling sweep. Scaled ~4x down in W.
    pub fn nips_like() -> Self {
        Self {
            name: "NIPS-like".into(),
            n_docs: 1_500,
            n_words: 3_000,
            n_topics: 50,
            mean_doc_len: 400.0,
            alpha_gen: 0.1,
            beta_gen: 0.02,
        }
    }

    /// ENRON-like profile (paper: D=39861, W=28102, NNZ=3.7M), ~20x down.
    pub fn enron_like() -> Self {
        Self {
            name: "ENRON-like".into(),
            n_docs: 2_000,
            n_words: 1_400,
            n_topics: 40,
            mean_doc_len: 95.0,
            alpha_gen: 0.1,
            beta_gen: 0.03,
        }
    }

    /// WIKI-like profile (paper: D=20758, W=83470, NNZ=9.3M), ~20x down.
    /// Distinctive trait kept: large vocabulary relative to D, long docs.
    pub fn wiki_like() -> Self {
        Self {
            name: "WIKI-like".into(),
            n_docs: 1_000,
            n_words: 4_000,
            n_topics: 40,
            mean_doc_len: 450.0,
            alpha_gen: 0.1,
            beta_gen: 0.02,
        }
    }

    /// NYTIMES-like profile (paper: D=300000, W=102660, NNZ=69.7M),
    /// ~100x down. Trait kept: many docs, large vocab, dense rows.
    pub fn nytimes_like() -> Self {
        Self {
            name: "NYTIMES-like".into(),
            n_docs: 3_000,
            n_words: 5_000,
            n_topics: 60,
            mean_doc_len: 230.0,
            alpha_gen: 0.08,
            beta_gen: 0.02,
        }
    }

    /// PUBMED-like profile (paper: D=8.2M, W=141043, NNZ=483M), ~1600x
    /// down. Trait kept: short docs, huge D relative to W.
    pub fn pubmed_like() -> Self {
        Self {
            name: "PUBMED-like".into(),
            n_docs: 5_000,
            n_words: 2_500,
            n_topics: 60,
            mean_doc_len: 60.0,
            alpha_gen: 0.08,
            beta_gen: 0.03,
        }
    }

    /// The four comparison corpora of §4.3, in paper order.
    pub fn paper_suite() -> Vec<Self> {
        vec![
            Self::enron_like(),
            Self::wiki_like(),
            Self::nytimes_like(),
            Self::pubmed_like(),
        ]
    }
}

/// Ground-truth parameters kept alongside a generated corpus (useful for
/// topic-recovery sanity checks in tests).
pub struct GroundTruth {
    /// `[n_topics][n_words]` rows are the generating topic-word
    /// distributions.
    pub phi: Vec<Vec<f32>>,
}

/// Sample a corpus from the LDA generative process. Deterministic in
/// `seed`.
pub fn generate(cfg: &SyntheticConfig, seed: u64) -> Corpus {
    generate_with_truth(cfg, seed).0
}

/// As [`generate`], also returning the generating topics.
pub fn generate_with_truth(cfg: &SyntheticConfig, seed: u64) -> (Corpus, GroundTruth) {
    let mut rng = Rng::new(seed);
    // Topic-word distributions.
    let phi: Vec<Vec<f32>> = (0..cfg.n_topics)
        .map(|_| {
            rng.dirichlet_sym(cfg.beta_gen, cfg.n_words)
                .into_iter()
                .map(|x| x as f32)
                .collect()
        })
        .collect();

    // Precompute cumulative distributions for O(log W) word sampling.
    let cum_phi: Vec<Vec<f32>> = phi
        .iter()
        .map(|row| {
            let mut acc = 0.0f32;
            row.iter()
                .map(|&p| {
                    acc += p;
                    acc
                })
                .collect()
        })
        .collect();

    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(cfg.n_docs);
    let mut counts = std::collections::HashMap::new();
    for _ in 0..cfg.n_docs {
        let theta: Vec<f64> = rng.dirichlet_sym(cfg.alpha_gen, cfg.n_topics);
        let len = rng.poisson(cfg.mean_doc_len).max(2);
        counts.clear();
        for _ in 0..len {
            // z ~ theta
            let mut r = rng.next_f64();
            let mut z = cfg.n_topics - 1;
            for (k, &t) in theta.iter().enumerate() {
                r -= t;
                if r <= 0.0 {
                    z = k;
                    break;
                }
            }
            // w ~ phi_z via binary search on the cdf
            let target = rng.next_f32();
            let cdf = &cum_phi[z];
            let w = match cdf.binary_search_by(|p| {
                p.partial_cmp(&target).unwrap_or(std::cmp::Ordering::Equal)
            }) {
                Ok(i) | Err(i) => i.min(cfg.n_words - 1),
            };
            *counts.entry(w as u32).or_insert(0f32) += 1.0;
        }
        let mut row: Vec<(u32, f32)> = counts.drain().collect();
        row.sort_unstable_by_key(|&(w, _)| w);
        rows.push(row);
    }
    let refs: Vec<&[(u32, f32)]> = rows.iter().map(|r| r.as_slice()).collect();
    let docs = DocWordMatrix::from_rows(cfg.n_words, &refs);
    (Corpus::new(cfg.name.clone(), docs), GroundTruth { phi })
}

// ---------------------------------------------------------------------
// Non-stationary streams: the ground-truth drift generator.
//
// A `DriftingCorpus` is an endless-stream stand-in whose generative
// process *changes* at known batch indices. Every change is logged in a
// `DriftTruth`, so tests can assert detection latency and false-alarm
// rates against exact change points instead of eyeballing loss curves
// (ISSUE 10; detector in coordinator::drift, harness in
// tests/drift_equivalence.rs).
// ---------------------------------------------------------------------

/// One kind of regime change the generator can inject.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DriftKind {
    /// Redraw a deterministic prefix of `ceil(fraction * K)` topic-word
    /// distributions from a fresh Dirichlet — a piecewise mixture
    /// shift. `fraction = 1.0` replaces every topic (the brutal case
    /// the detection-latency tests use).
    MixtureShift { fraction: f32 },
    /// Append one freshly drawn topic (K grows by 1).
    TopicBirth,
    /// Remove one topic, chosen uniformly at random (K shrinks by 1).
    TopicDeath,
    /// Extend the active vocabulary by `new_words` columns; every topic
    /// row gets fresh Gamma(beta_gen) mass there and renormalizes.
    VocabGrowth { new_words: usize },
}

/// A scheduled change: `kind` is applied just before batch `batch` is
/// sampled.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftPoint {
    pub batch: usize,
    pub kind: DriftKind,
}

/// The ground-truth change-point log of one generated stream.
#[derive(Debug, Clone, Default)]
pub struct DriftTruth {
    /// Every injected change, sorted by batch (stable for equal
    /// batches, in application order).
    pub points: Vec<DriftPoint>,
    /// Active vocabulary size over time as `(batch, n_words)` steps:
    /// entry 0 is `(0, base_words)` and one entry is appended per
    /// `VocabGrowth` event. Both coordinates are non-decreasing.
    pub vocab_sizes: Vec<(usize, usize)>,
}

impl DriftTruth {
    /// Batch indices of every change point, sorted, deduplicated.
    pub fn shift_batches(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.points.iter().map(|p| p.batch).collect();
        b.dedup();
        b
    }

    /// Active vocabulary just before `batch` is sampled.
    pub fn vocab_at(&self, batch: usize) -> usize {
        self.vocab_sizes
            .iter()
            .rev()
            .find(|&&(b, _)| b <= batch)
            .map(|&(_, w)| w)
            .unwrap_or(0)
    }
}

/// Parameters of a drifting stream.
#[derive(Debug, Clone)]
pub struct DriftConfig {
    /// Regime-0 generative parameters (topics, vocab, doc shape).
    pub base: SyntheticConfig,
    /// Documents per emitted minibatch.
    pub docs_per_batch: usize,
    /// Total batches the iterator yields.
    pub n_batches: usize,
    /// Scheduled changes; must be sorted by batch and in-range.
    pub events: Vec<DriftPoint>,
    /// Fixed stream width (matrix `n_words` of every batch). Must cover
    /// `base.n_words` plus all scheduled vocabulary growth so batch
    /// shapes stay constant across regime changes.
    pub max_words: usize,
}

impl DriftConfig {
    /// A control stream with no change points — same sampler, same
    /// seed discipline, zero drift. The detector must stay silent on
    /// this (asserted in tests/drift_equivalence.rs).
    pub fn stationary(base: SyntheticConfig, docs_per_batch: usize, n_batches: usize) -> Self {
        let max_words = base.n_words;
        Self { base, docs_per_batch, n_batches, events: Vec::new(), max_words }
    }
}

/// Seeded, deterministic generator of a non-stationary minibatch
/// stream. Implements `Iterator<Item = Minibatch>`; the ground-truth
/// change log is available via [`DriftingCorpus::truth`] up front.
pub struct DriftingCorpus {
    cfg: DriftConfig,
    rng: Rng,
    /// Current topic-word rows at `active_words` width.
    phi: Vec<Vec<f32>>,
    cum_phi: Vec<Vec<f32>>,
    active_words: usize,
    next_batch: usize,
    next_event: usize,
    truth: DriftTruth,
}

impl DriftingCorpus {
    /// Build the stream and precompute its [`DriftTruth`]. Panics on an
    /// inconsistent schedule (unsorted events, out-of-range batches,
    /// growth past `max_words`, death below one topic) — these are test
    /// harness bugs, not runtime conditions.
    pub fn new(cfg: DriftConfig, seed: u64) -> Self {
        assert!(cfg.docs_per_batch > 0 && cfg.n_batches > 0);
        assert!(cfg.max_words >= cfg.base.n_words, "max_words below base vocabulary");
        assert!(
            cfg.events.windows(2).all(|w| w[0].batch <= w[1].batch),
            "drift events must be sorted by batch"
        );
        // Precompute the truth log (and validate the schedule) without
        // touching the sampling RNG.
        let mut truth = DriftTruth {
            points: cfg.events.clone(),
            vocab_sizes: vec![(0, cfg.base.n_words)],
        };
        let mut words = cfg.base.n_words;
        let mut topics = cfg.base.n_topics;
        for p in &cfg.events {
            assert!(p.batch < cfg.n_batches, "drift event past end of stream");
            match p.kind {
                DriftKind::MixtureShift { fraction } => {
                    assert!(fraction > 0.0 && fraction <= 1.0);
                }
                DriftKind::TopicBirth => topics += 1,
                DriftKind::TopicDeath => {
                    assert!(topics > 1, "topic death would leave zero topics");
                    topics -= 1;
                }
                DriftKind::VocabGrowth { new_words } => {
                    words += new_words;
                    assert!(words <= cfg.max_words, "vocab growth exceeds max_words");
                    truth.vocab_sizes.push((p.batch, words));
                }
            }
        }

        let mut rng = Rng::new(seed);
        let phi: Vec<Vec<f32>> = (0..cfg.base.n_topics)
            .map(|_| draw_topic(&mut rng, cfg.base.beta_gen, cfg.base.n_words))
            .collect();
        let cum_phi = phi.iter().map(|row| cumulative(row)).collect();
        let active_words = cfg.base.n_words;
        Self { cfg, rng, phi, cum_phi, active_words, next_batch: 0, next_event: 0, truth }
    }

    /// The precomputed change-point log (valid before iteration).
    pub fn truth(&self) -> &DriftTruth {
        &self.truth
    }

    /// Current number of generating topics.
    pub fn n_topics(&self) -> usize {
        self.phi.len()
    }

    /// Apply every event scheduled for `batch`, then rebuild CDFs.
    fn apply_due_events(&mut self, batch: usize) {
        let mut changed = false;
        while self.next_event < self.cfg.events.len()
            && self.cfg.events[self.next_event].batch == batch
        {
            let kind = self.cfg.events[self.next_event].kind;
            self.next_event += 1;
            changed = true;
            match kind {
                DriftKind::MixtureShift { fraction } => {
                    let m = ((fraction as f64) * self.phi.len() as f64).ceil() as usize;
                    for k in 0..m.clamp(1, self.phi.len()) {
                        self.phi[k] =
                            draw_topic(&mut self.rng, self.cfg.base.beta_gen, self.active_words);
                    }
                }
                DriftKind::TopicBirth => {
                    let row =
                        draw_topic(&mut self.rng, self.cfg.base.beta_gen, self.active_words);
                    self.phi.push(row);
                }
                DriftKind::TopicDeath => {
                    let victim = self.rng.below(self.phi.len());
                    self.phi.remove(victim);
                }
                DriftKind::VocabGrowth { new_words } => {
                    self.active_words += new_words;
                    for row in &mut self.phi {
                        let mut total = 1.0f64;
                        for _ in 0..new_words {
                            let g = self.rng.gamma(self.cfg.base.beta_gen) as f32;
                            total += g as f64;
                            row.push(g);
                        }
                        let inv = (1.0 / total) as f32;
                        for p in row.iter_mut() {
                            *p *= inv;
                        }
                    }
                }
            }
        }
        if changed {
            self.cum_phi = self.phi.iter().map(|row| cumulative(row)).collect();
        }
    }

    /// Sample the next minibatch (mirrors [`generate_with_truth`]'s
    /// document loop, against the *current* regime).
    fn sample_batch(&mut self) -> Minibatch {
        let batch = self.next_batch;
        self.apply_due_events(batch);
        let n_topics = self.phi.len();
        let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(self.cfg.docs_per_batch);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..self.cfg.docs_per_batch {
            let theta: Vec<f64> = self.rng.dirichlet_sym(self.cfg.base.alpha_gen, n_topics);
            let len = self.rng.poisson(self.cfg.base.mean_doc_len).max(2);
            counts.clear();
            for _ in 0..len {
                let mut r = self.rng.next_f64();
                let mut z = n_topics - 1;
                for (k, &t) in theta.iter().enumerate() {
                    r -= t;
                    if r <= 0.0 {
                        z = k;
                        break;
                    }
                }
                let target = self.rng.next_f32();
                let cdf = &self.cum_phi[z];
                let w = match cdf.binary_search_by(|p| {
                    p.partial_cmp(&target).unwrap_or(std::cmp::Ordering::Equal)
                }) {
                    Ok(i) | Err(i) => i.min(self.active_words - 1),
                };
                *counts.entry(w as u32).or_insert(0f32) += 1.0;
            }
            let mut row: Vec<(u32, f32)> = counts.drain().collect();
            row.sort_unstable_by_key(|&(w, _)| w);
            rows.push(row);
        }
        let refs: Vec<&[(u32, f32)]> = rows.iter().map(|r| r.as_slice()).collect();
        // Fixed max_words width keeps batch shapes stable across
        // vocabulary growth (consumers size buffers once).
        let docs = DocWordMatrix::from_rows(self.cfg.max_words, &refs);
        self.next_batch += 1;
        Minibatch::new(batch, docs)
    }
}

impl Iterator for DriftingCorpus {
    type Item = Minibatch;

    fn next(&mut self) -> Option<Minibatch> {
        if self.next_batch >= self.cfg.n_batches {
            return None;
        }
        Some(self.sample_batch())
    }
}

fn draw_topic(rng: &mut Rng, beta_gen: f64, n_words: usize) -> Vec<f32> {
    rng.dirichlet_sym(beta_gen, n_words).into_iter().map(|x| x as f32).collect()
}

fn cumulative(row: &[f32]) -> Vec<f32> {
    let mut acc = 0.0f32;
    row.iter()
        .map(|&p| {
            acc += p;
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_deterministic() {
        let cfg = SyntheticConfig::small();
        let a = generate(&cfg, 1);
        let b = generate(&cfg, 1);
        assert_eq!(a.docs.word_ids, b.docs.word_ids);
        assert_eq!(a.docs.counts, b.docs.counts);
    }

    #[test]
    fn different_seeds_differ() {
        let cfg = SyntheticConfig::small();
        let a = generate(&cfg, 1);
        let b = generate(&cfg, 2);
        assert_ne!(a.docs.word_ids, b.docs.word_ids);
    }

    #[test]
    fn shape_statistics_match_config() {
        let cfg = SyntheticConfig::small();
        let c = generate(&cfg, 7);
        assert_eq!(c.n_docs(), cfg.n_docs);
        assert_eq!(c.n_words(), cfg.n_words);
        let mean_len = c.n_tokens() / c.n_docs() as f64;
        assert!(
            (mean_len - cfg.mean_doc_len).abs() < cfg.mean_doc_len * 0.15,
            "mean_len={mean_len}"
        );
        // Every document non-empty.
        for d in 0..c.n_docs() {
            assert!(c.docs.doc_len(d) >= 2.0);
        }
    }

    #[test]
    fn ground_truth_topics_are_distributions() {
        let cfg = SyntheticConfig::small();
        let (_, truth) = generate_with_truth(&cfg, 3);
        assert_eq!(truth.phi.len(), cfg.n_topics);
        for row in &truth.phi {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-3, "{s}");
        }
    }

    #[test]
    fn word_ids_in_range() {
        let cfg = SyntheticConfig::small();
        let c = generate(&cfg, 11);
        assert!(c
            .docs
            .word_ids
            .iter()
            .all(|&w| (w as usize) < cfg.n_words));
    }

    fn drift_cfg(events: Vec<DriftPoint>, max_words: usize) -> DriftConfig {
        DriftConfig {
            base: SyntheticConfig::small(),
            docs_per_batch: 16,
            n_batches: 12,
            events,
            max_words,
        }
    }

    #[test]
    fn drifting_corpus_is_deterministic() {
        let events = vec![
            DriftPoint { batch: 3, kind: DriftKind::MixtureShift { fraction: 1.0 } },
            DriftPoint { batch: 6, kind: DriftKind::TopicBirth },
            DriftPoint { batch: 9, kind: DriftKind::VocabGrowth { new_words: 50 } },
        ];
        let a: Vec<_> = DriftingCorpus::new(drift_cfg(events.clone(), 550), 5).collect();
        let b: Vec<_> = DriftingCorpus::new(drift_cfg(events, 550), 5).collect();
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.docs.word_ids, y.docs.word_ids);
            assert_eq!(x.docs.counts, y.docs.counts);
        }
    }

    #[test]
    fn drifting_corpus_stationary_matches_no_event_schedule() {
        // An empty schedule and the stationary() helper draw the same
        // stream for the same seed.
        let a: Vec<_> =
            DriftingCorpus::new(DriftConfig::stationary(SyntheticConfig::small(), 16, 12), 5)
                .collect();
        let b: Vec<_> = DriftingCorpus::new(drift_cfg(Vec::new(), 500), 5).collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.docs.word_ids, y.docs.word_ids);
            assert_eq!(x.docs.counts, y.docs.counts);
        }
    }

    #[test]
    fn drift_changes_stream_after_change_point_only() {
        let shifted = vec![DriftPoint { batch: 4, kind: DriftKind::MixtureShift { fraction: 1.0 } }];
        let a: Vec<_> = DriftingCorpus::new(drift_cfg(Vec::new(), 500), 9).collect();
        let b: Vec<_> = DriftingCorpus::new(drift_cfg(shifted, 500), 9).collect();
        for i in 0..4 {
            assert_eq!(a[i].docs.word_ids, b[i].docs.word_ids, "pre-shift batch {i} diverged");
        }
        assert_ne!(a[4].docs.word_ids, b[4].docs.word_ids, "shift had no effect");
    }

    #[test]
    fn drift_truth_bookkeeping() {
        let events = vec![
            DriftPoint { batch: 2, kind: DriftKind::TopicBirth },
            DriftPoint { batch: 4, kind: DriftKind::VocabGrowth { new_words: 30 } },
            DriftPoint { batch: 5, kind: DriftKind::TopicDeath },
            DriftPoint { batch: 8, kind: DriftKind::VocabGrowth { new_words: 20 } },
        ];
        let c = DriftingCorpus::new(drift_cfg(events, 600), 1);
        let t = c.truth();
        // Sorted change points, deduped batch list.
        assert!(t.points.windows(2).all(|w| w[0].batch <= w[1].batch));
        assert_eq!(t.shift_batches(), vec![2, 4, 5, 8]);
        // Vocabulary growth is monotone in batch and size.
        assert_eq!(t.vocab_sizes, vec![(0, 500), (4, 530), (8, 550)]);
        assert!(t.vocab_sizes.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 <= w[1].1));
        assert_eq!(t.vocab_at(0), 500);
        assert_eq!(t.vocab_at(4), 530);
        assert_eq!(t.vocab_at(11), 550);
    }

    #[test]
    fn drift_birth_and_death_track_topic_count() {
        let events = vec![
            DriftPoint { batch: 1, kind: DriftKind::TopicBirth },
            DriftPoint { batch: 2, kind: DriftKind::TopicBirth },
            DriftPoint { batch: 3, kind: DriftKind::TopicDeath },
        ];
        let mut c = DriftingCorpus::new(drift_cfg(events, 500), 3);
        assert_eq!(c.n_topics(), 10);
        c.next();
        assert_eq!(c.n_topics(), 10);
        c.next();
        assert_eq!(c.n_topics(), 11);
        c.next();
        assert_eq!(c.n_topics(), 12);
        c.next();
        assert_eq!(c.n_topics(), 11);
    }

    #[test]
    fn drift_vocab_growth_emits_new_words_at_fixed_width() {
        let events = vec![DriftPoint { batch: 2, kind: DriftKind::VocabGrowth { new_words: 400 } }];
        let batches: Vec<_> = DriftingCorpus::new(drift_cfg(events, 900), 7).collect();
        // Every batch reports the fixed stream width...
        assert!(batches.iter().all(|m| m.docs.n_words == 900));
        // ...but words beyond the base vocabulary appear only after the
        // growth event.
        let max_word = |m: &Minibatch| m.docs.word_ids.iter().copied().max().unwrap();
        assert!(batches[..2].iter().all(|m| (max_word(m) as usize) < 500));
        let post_max = batches[2..].iter().map(|m| max_word(m)).max().unwrap();
        assert!((post_max as usize) >= 500, "no new-vocabulary tokens sampled");
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn drift_unsorted_schedule_panics() {
        let events = vec![
            DriftPoint { batch: 5, kind: DriftKind::TopicBirth },
            DriftPoint { batch: 2, kind: DriftKind::TopicBirth },
        ];
        DriftingCorpus::new(drift_cfg(events, 500), 1);
    }

    #[test]
    #[should_panic(expected = "max_words")]
    fn drift_vocab_overflow_panics() {
        let events = vec![DriftPoint { batch: 1, kind: DriftKind::VocabGrowth { new_words: 10 } }];
        DriftingCorpus::new(drift_cfg(events, 505), 1);
    }
}
