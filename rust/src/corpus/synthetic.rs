//! Synthetic LDA corpus generator — the substitute for the paper's UCI
//! bag-of-words corpora (ENRON / WIKI / NYTIMES / PUBMED / NIPS), which
//! are multi-GB downloads unavailable in this offline environment (see
//! DESIGN.md §4 for the substitution argument).
//!
//! Documents are sampled from the LDA generative process itself:
//! `phi_k ~ Dir(beta_gen)`, `theta_d ~ Dir(alpha_gen)`, doc length
//! `~ Poisson(mean_len)`, each token `z ~ theta_d`, `w ~ phi_z`.  Because
//! every algorithm under comparison consumes *identical* streams, the
//! paper's relative claims (who converges faster, who reaches lower
//! perplexity, how cost scales with K and D_s) are preserved even though
//! absolute perplexities differ from the real corpora.
//!
//! Profiles below mirror each paper corpus' shape statistics (documents,
//! vocabulary, NNZ density) scaled to this testbed.

use super::{Corpus, DocWordMatrix};
use crate::util::Rng;

/// Parameters of the generative sampler.
#[derive(Debug, Clone)]
pub struct SyntheticConfig {
    pub name: String,
    /// Number of documents D.
    pub n_docs: usize,
    /// Vocabulary size W.
    pub n_words: usize,
    /// Number of generating topics (independent of the K later fitted).
    pub n_topics: usize,
    /// Mean document length in tokens (Poisson).
    pub mean_doc_len: f64,
    /// Dirichlet concentration for document-topic draws.
    pub alpha_gen: f64,
    /// Dirichlet concentration for topic-word draws (small => sparse,
    /// word-sense-like topics as in real corpora).
    pub beta_gen: f64,
}

impl SyntheticConfig {
    /// Tiny corpus for unit tests and doc examples (~seconds).
    pub fn small() -> Self {
        Self {
            name: "synth-small".into(),
            n_docs: 200,
            n_words: 500,
            n_topics: 10,
            mean_doc_len: 60.0,
            alpha_gen: 0.1,
            beta_gen: 0.05,
        }
    }

    /// NIPS-like profile (paper §4.1: D=1500, W=12419): used for the
    /// Fig. 7 dynamic-scheduling sweep. Scaled ~4x down in W.
    pub fn nips_like() -> Self {
        Self {
            name: "NIPS-like".into(),
            n_docs: 1_500,
            n_words: 3_000,
            n_topics: 50,
            mean_doc_len: 400.0,
            alpha_gen: 0.1,
            beta_gen: 0.02,
        }
    }

    /// ENRON-like profile (paper: D=39861, W=28102, NNZ=3.7M), ~20x down.
    pub fn enron_like() -> Self {
        Self {
            name: "ENRON-like".into(),
            n_docs: 2_000,
            n_words: 1_400,
            n_topics: 40,
            mean_doc_len: 95.0,
            alpha_gen: 0.1,
            beta_gen: 0.03,
        }
    }

    /// WIKI-like profile (paper: D=20758, W=83470, NNZ=9.3M), ~20x down.
    /// Distinctive trait kept: large vocabulary relative to D, long docs.
    pub fn wiki_like() -> Self {
        Self {
            name: "WIKI-like".into(),
            n_docs: 1_000,
            n_words: 4_000,
            n_topics: 40,
            mean_doc_len: 450.0,
            alpha_gen: 0.1,
            beta_gen: 0.02,
        }
    }

    /// NYTIMES-like profile (paper: D=300000, W=102660, NNZ=69.7M),
    /// ~100x down. Trait kept: many docs, large vocab, dense rows.
    pub fn nytimes_like() -> Self {
        Self {
            name: "NYTIMES-like".into(),
            n_docs: 3_000,
            n_words: 5_000,
            n_topics: 60,
            mean_doc_len: 230.0,
            alpha_gen: 0.08,
            beta_gen: 0.02,
        }
    }

    /// PUBMED-like profile (paper: D=8.2M, W=141043, NNZ=483M), ~1600x
    /// down. Trait kept: short docs, huge D relative to W.
    pub fn pubmed_like() -> Self {
        Self {
            name: "PUBMED-like".into(),
            n_docs: 5_000,
            n_words: 2_500,
            n_topics: 60,
            mean_doc_len: 60.0,
            alpha_gen: 0.08,
            beta_gen: 0.03,
        }
    }

    /// The four comparison corpora of §4.3, in paper order.
    pub fn paper_suite() -> Vec<Self> {
        vec![
            Self::enron_like(),
            Self::wiki_like(),
            Self::nytimes_like(),
            Self::pubmed_like(),
        ]
    }
}

/// Ground-truth parameters kept alongside a generated corpus (useful for
/// topic-recovery sanity checks in tests).
pub struct GroundTruth {
    /// `[n_topics][n_words]` rows are the generating topic-word
    /// distributions.
    pub phi: Vec<Vec<f32>>,
}

/// Sample a corpus from the LDA generative process. Deterministic in
/// `seed`.
pub fn generate(cfg: &SyntheticConfig, seed: u64) -> Corpus {
    generate_with_truth(cfg, seed).0
}

/// As [`generate`], also returning the generating topics.
pub fn generate_with_truth(cfg: &SyntheticConfig, seed: u64) -> (Corpus, GroundTruth) {
    let mut rng = Rng::new(seed);
    // Topic-word distributions.
    let phi: Vec<Vec<f32>> = (0..cfg.n_topics)
        .map(|_| {
            rng.dirichlet_sym(cfg.beta_gen, cfg.n_words)
                .into_iter()
                .map(|x| x as f32)
                .collect()
        })
        .collect();

    // Precompute cumulative distributions for O(log W) word sampling.
    let cum_phi: Vec<Vec<f32>> = phi
        .iter()
        .map(|row| {
            let mut acc = 0.0f32;
            row.iter()
                .map(|&p| {
                    acc += p;
                    acc
                })
                .collect()
        })
        .collect();

    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(cfg.n_docs);
    let mut counts = std::collections::HashMap::new();
    for _ in 0..cfg.n_docs {
        let theta: Vec<f64> = rng.dirichlet_sym(cfg.alpha_gen, cfg.n_topics);
        let len = rng.poisson(cfg.mean_doc_len).max(2);
        counts.clear();
        for _ in 0..len {
            // z ~ theta
            let mut r = rng.next_f64();
            let mut z = cfg.n_topics - 1;
            for (k, &t) in theta.iter().enumerate() {
                r -= t;
                if r <= 0.0 {
                    z = k;
                    break;
                }
            }
            // w ~ phi_z via binary search on the cdf
            let target = rng.next_f32();
            let cdf = &cum_phi[z];
            let w = match cdf.binary_search_by(|p| {
                p.partial_cmp(&target).unwrap_or(std::cmp::Ordering::Equal)
            }) {
                Ok(i) | Err(i) => i.min(cfg.n_words - 1),
            };
            *counts.entry(w as u32).or_insert(0f32) += 1.0;
        }
        let mut row: Vec<(u32, f32)> = counts.drain().collect();
        row.sort_unstable_by_key(|&(w, _)| w);
        rows.push(row);
    }
    let refs: Vec<&[(u32, f32)]> = rows.iter().map(|r| r.as_slice()).collect();
    let docs = DocWordMatrix::from_rows(cfg.n_words, &refs);
    (Corpus::new(cfg.name.clone(), docs), GroundTruth { phi })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_deterministic() {
        let cfg = SyntheticConfig::small();
        let a = generate(&cfg, 1);
        let b = generate(&cfg, 1);
        assert_eq!(a.docs.word_ids, b.docs.word_ids);
        assert_eq!(a.docs.counts, b.docs.counts);
    }

    #[test]
    fn different_seeds_differ() {
        let cfg = SyntheticConfig::small();
        let a = generate(&cfg, 1);
        let b = generate(&cfg, 2);
        assert_ne!(a.docs.word_ids, b.docs.word_ids);
    }

    #[test]
    fn shape_statistics_match_config() {
        let cfg = SyntheticConfig::small();
        let c = generate(&cfg, 7);
        assert_eq!(c.n_docs(), cfg.n_docs);
        assert_eq!(c.n_words(), cfg.n_words);
        let mean_len = c.n_tokens() / c.n_docs() as f64;
        assert!(
            (mean_len - cfg.mean_doc_len).abs() < cfg.mean_doc_len * 0.15,
            "mean_len={mean_len}"
        );
        // Every document non-empty.
        for d in 0..c.n_docs() {
            assert!(c.docs.doc_len(d) >= 2.0);
        }
    }

    #[test]
    fn ground_truth_topics_are_distributions() {
        let cfg = SyntheticConfig::small();
        let (_, truth) = generate_with_truth(&cfg, 3);
        assert_eq!(truth.phi.len(), cfg.n_topics);
        for row in &truth.phi {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-3, "{s}");
        }
    }

    #[test]
    fn word_ids_in_range() {
        let cfg = SyntheticConfig::small();
        let c = generate(&cfg, 11);
        assert!(c
            .docs
            .word_ids
            .iter()
            .all(|&w| (w as usize) < cfg.n_words));
    }
}
